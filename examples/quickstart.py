"""Quickstart: build a geo-distributed graph store with GeoLayer placement,
serve online pattern requests, and plan an offline analytics run.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.geolayer import CONFIG
from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.store import GeoGraphStore
from repro.data.synthetic import make_benchmark_graph


def main() -> None:
    # 1. a geo-partitioned graph across the paper's five DCs (Table I WAN)
    env = make_paper_env()
    g = make_benchmark_graph("snb", n_dcs=env.n_dcs)
    print(f"graph: {g.n_nodes} vertices, {g.n_edges} edges, {env.n_dcs} DCs")

    # 2. historical access patterns (3-hop traversals, Zipf-skewed sources)
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, 200, seed=0, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats[:160], g.n_items, env.n_dcs)

    # 3. GeoLayer: layered graph -> overlap-centric placement -> routing
    store = GeoGraphStore(g, env, wl, config=CONFIG.placement_config())
    print(store.lg.summary())
    print("placement stats:", store.stats.placement_stats)
    print("cost breakdown:", {k: f"{v:.4g}" for k, v in store.cost().as_dict().items()})

    # 4. online mode: stepwise layered routing of pattern requests
    lat = []
    for p in pats[160:]:
        origin = int(np.argmax(p.r_py))
        res = store.serve_online(p, origin)
        lat.append(res.latency_s)
    print(f"online: {len(lat)} requests, mean latency {np.mean(lat)*1e3:.2f} ms, "
          f"p99 {np.percentile(lat, 99)*1e3:.2f} ms")

    # 5. offline mode: top-down localization + bottom-up assembly
    plan = store.plan_offline(np.arange(g.n_nodes), n_iters=15)
    print(f"offline: {len(plan.sites)} execution sites, "
          f"{plan.wan_bytes/1e6:.2f} MB assembly WAN, "
          f"{len(plan.migrated)} items migrated")

    # 6. periodic maintenance: heat diffusion + cold-replica eviction
    print("maintenance:", store.maintain())


if __name__ == "__main__":
    main()
