"""Quickstart: build a geo-distributed graph store with GeoLayer placement,
serve online pattern requests, and plan an offline analytics run.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.geolayer import CONFIG
from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.store import GeoGraphStore
from repro.data.synthetic import make_benchmark_graph


def main() -> None:
    # 1. a geo-partitioned graph across the paper's five DCs (Table I WAN)
    env = make_paper_env()
    g = make_benchmark_graph("snb", n_dcs=env.n_dcs)
    print(f"graph: {g.n_nodes} vertices, {g.n_edges} edges, {env.n_dcs} DCs")

    # 2. historical access patterns (3-hop traversals, Zipf-skewed sources)
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, 200, seed=0, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats[:160], g.n_items, env.n_dcs)

    # 3. GeoLayer: layered graph -> overlap-centric placement -> routing
    store = GeoGraphStore(g, env, wl, config=CONFIG.placement_config())
    print(store.lg.summary())
    print("placement stats:", store.stats.placement_stats)
    print("cost breakdown:", {k: f"{v:.4g}" for k, v in store.cost().as_dict().items()})

    # 4. online mode through the serving control plane: submit requests with
    # origin + deadline + priority, let the AdmissionController form batches
    # adaptively (closing the loop on measured RouteResult.latency_s) and
    # interleave background maintenance into the idle gaps
    from repro.serve import (AdmissionConfig, AdmissionController,
                             MaintenanceConfig, MaintenancePolicy, StoreClient)

    policy = MaintenancePolicy(
        store,
        MaintenanceConfig(maintain_every_s=0.05, maintain_cost_s=0.002),
    )
    controller = AdmissionController(store, AdmissionConfig(), policy=policy)
    client = StoreClient(controller)
    rng = np.random.default_rng(0)
    t = 0.0
    handles = []
    for p in pats[160:]:
        origin = int(np.argmax(p.r_py))
        t += float(rng.exponential(0.005))
        handles.append(client.submit_pattern(p, origin, at=t, deadline_s=0.5))
    controller.run_until_idle()
    lat = [h.latency_s for h in handles]
    m = controller.metrics()
    print(f"online: {m['completed']} requests, mean latency "
          f"{np.mean(lat)*1e3:.2f} ms, p99 {np.percentile(lat, 99)*1e3:.2f} ms, "
          f"{m['deadline_misses']} deadline misses, "
          f"mean batch {m['mean_batch']:.1f}")
    print("background maintenance:", policy.stats())

    # 5. offline mode: top-down localization + bottom-up assembly
    plan = store.plan_offline(np.arange(g.n_nodes), n_iters=15)
    print(f"offline: {len(plan.sites)} execution sites, "
          f"{plan.wan_bytes/1e6:.2f} MB assembly WAN, "
          f"{len(plan.migrated)} items migrated")

    # 6. explicit maintenance entry (the policy calls this in idle gaps)
    print("maintenance:", store.maintain())


if __name__ == "__main__":
    main()
