"""GeoLayer at mesh scale: plan halo replication for distributed GNN
training — the paper's replica-placement logic applied to a TPU mesh
(DESIGN §4.2).  Shows cut-edge resolution vs replication budget, i.e. the
collective-traffic reduction the §Perf hillclimb measures.

    PYTHONPATH=src python examples/gnn_halo_placement.py
"""
import numpy as np

from repro.core.layered_graph import build_layered_graph
from repro.distributed.geo_sharding import mesh_env, plan_gnn_halo
from repro.data.synthetic import make_benchmark_graph
from repro.data.partition import balanced_bfs_partition


def main() -> None:
    n_shards = 16
    g = make_benchmark_graph("tw", n_dcs=n_shards)
    g.partition = balanced_bfs_partition(g.n_nodes, g.src, g.dst, n_shards)
    heat = np.random.default_rng(0).zipf(1.5, g.n_nodes).astype(float)
    heat = np.minimum(heat, 50)

    env = mesh_env(n_shards, shards_per_pod=8)
    lg = build_layered_graph(g, env, thresholds_s=[1e-5])
    print("mesh-level layered graph (shards = DCs, ICI/DCN = WAN tiers):")
    print(lg.summary())

    print("\nbudget  halo_vertices  cut_edges_resolved")
    for budget in [0.05, 0.1, 0.25, 0.5]:
        plan = plan_gnn_halo(g, n_shards, vertex_heat=heat,
                             n_layers=15, budget_frac=budget)
        n_halo = sum(len(h) for h in plan.halo)
        print(f"{budget:5.2f}  {n_halo:12d}  {plan.resolve_frac*100:17.1f}%")
    print("\nresolved cut edges skip the per-layer cross-shard gather ->")
    print("collective roofline term drops proportionally (EXPERIMENTS §Perf).")


if __name__ == "__main__":
    main()
