"""End-to-end driver: train a ~10M-param LM for a few hundred steps with the
fault-tolerant trainer (async checkpoints, int8-EF gradient compression,
injected node failure + recovery), then serve it with continuous batching.

    PYTHONPATH=src python examples/train_lm_geo.py [--steps 200]
"""
import argparse
import time

import jax
import numpy as np

from repro.data.pipeline import TokenPipeline
from repro.distributed.fault import FailureSimulator
from repro.models.transformer import LMConfig, init_params, train_loss
from repro.serve.engine import Engine, Request, ServeConfig
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_geo")
    args = ap.parse_args()

    # ~10M params: a miniature qwen3 (qk_norm GQA + SwiGLU)
    cfg = LMConfig(name="mini-qwen", n_layers=4, d_model=256, n_heads=8,
                   n_kv_heads=4, d_ff=768, vocab_size=4096, qk_norm=True,
                   remat=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    print(f"model: {n/1e6:.1f}M params")

    pipe = TokenPipeline(cfg.vocab_size, batch=16, seq_len=128, seed=0)
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=max(args.steps // 5, 10),
        ckpt_dir=args.ckpt,
        grad_compression="int8",
        microbatch=2,
        opt=OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps),
    )
    trainer = Trainer(
        lambda p, b: train_loss(p, b, cfg), params, tcfg,
        failure_sim=FailureSimulator([(args.steps // 2, 1)]),
    )
    t0 = time.perf_counter()
    metrics = trainer.run(iter(pipe))
    dt = time.perf_counter() - t0
    losses = metrics["loss"]
    toks = args.steps * 16 * 128
    print(f"trained {len(losses)} steps in {dt:.1f}s ({toks/dt:.0f} tok/s)")
    print(f"loss: {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"(uniform = {np.log(cfg.vocab_size):.3f})")
    print(f"recoveries: {metrics.get('recoveries', [])}")

    # serve the trained model
    eng = Engine(trainer.params, cfg, ServeConfig(n_slots=4, max_len=160))
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(Request(rid=i, prompt=rng.integers(0, 4096, 12),
                           max_new_tokens=16))
    done = eng.run_to_completion()
    print(f"served {len(done)} requests, "
          f"{sum(len(r.out_tokens) for r in done)} tokens generated")


if __name__ == "__main__":
    main()
