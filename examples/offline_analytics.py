"""Offline geo-analytics: route a graph with GeoLayer's offline mode, then
run PageRank / SSSP / k-core with the JAX engines and price the execution
(WAN bytes + straggler time) against the RAGraph baseline layout.

    PYTHONPATH=src python examples/offline_analytics.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import analytics
from repro.core.baselines import layout_ragraph
from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore
from repro.data.synthetic import make_benchmark_graph


def main() -> None:
    env = make_paper_env()
    g = make_benchmark_graph("uk", n_dcs=env.n_dcs)
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, 150, seed=2, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    store = GeoGraphStore(g, env, wl, config=PlacementConfig(precache=False))

    plan = store.plan_offline(np.arange(g.n_nodes), n_iters=15)
    site_geo = plan.item_site[: g.n_nodes].copy()
    site_geo[site_geo < 0] = g.partition[site_geo < 0]
    site_base = layout_ragraph(g, env)

    src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
    print("running PageRank (15 it.), SSSP (10 it.), k-core ...")
    pr = analytics.pagerank(src, dst, g.n_nodes, 15)
    dist = analytics.sssp(src, dst, jnp.ones(g.n_edges), 0, g.n_nodes, 10)
    core, rounds = analytics.core_decomposition(g.n_nodes, g.src, g.dst)
    print(f"pagerank top vertex: {int(jnp.argmax(pr))}  "
          f"reachable<=10 hops: {int(jnp.isfinite(dist).sum())}  "
          f"max core: {core.max()} ({rounds} peel rounds)")

    for name, site, assembly in [
        ("geolayer", site_geo, plan.wan_bytes),
        ("ragraph ", site_base, 0.0),
    ]:
        ex = analytics.simulate_execution(env, g, site, 15, assembly_bytes=assembly)
        print(f"{name}: sites={ex.n_sites} cut_edges={ex.cut_edges} "
              f"wan={ex.wan_bytes/1e6:.1f}MB time={ex.time_s:.2f}s")


if __name__ == "__main__":
    main()
