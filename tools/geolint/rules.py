"""The six GL rules.  Each rule is ``rule(ctx) -> List[Violation]``.

Scope conventions (``ctx.tail`` is the repo-relative posix path):

* GL001 — everything under ``src/repro/``
* GL002 — ``src/repro/serve/``, ``src/repro/demand/``,
  ``src/repro/streaming/migration.py``
* GL003 — everywhere *except* ``src/repro/demand/``
* GL004 — ``src/repro/core/routing.py`` and ``src/repro/serve/``
* GL005 — ``src/repro/kernels/``
* GL006 — any file defining ``class GeoGraphStore``

Inline ``# geolint: allow[GLxxx]`` pragmas suppress a finding on that
line.  GL001 pragmas are only honored when the module also exposes a
reset path for the allowlisted name: a module-level ``*reset*``/
``*clear*`` function referencing it, or the value being constructed
from a same-module class that defines ``reset()`` — the contract that
makes test isolation possible for the registry/autotuner singletons.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Sequence, Set, Tuple

from .engine import RuleContext, Violation

__all__ = ["ALL_RULES"]


# --------------------------------------------------------------- helpers
def _dotted(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``a.b.c`` -> ("a", "b", "c"); None when the root is not a Name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _v(ctx: RuleContext, rule: str, node: ast.AST, msg: str) -> Violation:
    return Violation(rule, ctx.path, node.lineno, node.col_offset, msg)


# ----------------------------------------------------------------- GL001
_MUTABLE_CALLS = {
    "dict", "list", "set", "OrderedDict", "defaultdict", "deque", "Counter",
}
_MUTATOR_METHODS = {
    "append", "appendleft", "add", "update", "setdefault", "pop", "popitem",
    "clear", "extend", "insert", "remove", "discard", "move_to_end",
}


def _is_mutable_value(value: ast.AST) -> bool:
    if isinstance(value, (ast.Dict, ast.List, ast.Set,
                          ast.DictComp, ast.ListComp, ast.SetComp)):
        return True
    if isinstance(value, ast.Call):
        ch = _dotted(value.func)
        return bool(ch) and ch[-1] in _MUTABLE_CALLS
    return False


def _mutated_names(tree: ast.Module) -> Tuple[Set[str], Set[str]]:
    """(names mutated in place, names declared ``global`` somewhere)."""
    mutated: Set[str] = set()
    global_names: Set[str] = set()

    def sub_name(t: ast.AST) -> Optional[str]:
        if isinstance(t, ast.Subscript) and isinstance(t.value, ast.Name):
            return t.value.id
        return None

    for node in ast.walk(tree):
        if isinstance(node, ast.Global):
            global_names.update(node.names)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                n = sub_name(t)
                if n:
                    mutated.add(n)
        elif isinstance(node, ast.AugAssign):
            n = sub_name(node.target)
            if n:
                mutated.add(n)
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                n = sub_name(t)
                if n:
                    mutated.add(n)
        elif isinstance(node, ast.Call):
            f = node.func
            if (
                isinstance(f, ast.Attribute)
                and f.attr in _MUTATOR_METHODS
                and isinstance(f.value, ast.Name)
            ):
                mutated.add(f.value.id)
    # module-level AugAssign on a bare name rebinds module state in place
    for stmt in tree.body:
        if isinstance(stmt, ast.AugAssign) and isinstance(stmt.target, ast.Name):
            mutated.add(stmt.target.id)
    return mutated, global_names


def _has_reset_exposure(tree: ast.Module, name: str, value: ast.AST) -> bool:
    for stmt in tree.body:
        if isinstance(stmt, ast.FunctionDef) and (
            "reset" in stmt.name.lower() or "clear" in stmt.name.lower()
        ):
            for n in ast.walk(stmt):
                if isinstance(n, ast.Name) and n.id == name:
                    return True
                if isinstance(n, ast.Global) and name in n.names:
                    return True
    if isinstance(value, ast.Call) and isinstance(value.func, ast.Name):
        cls_name = value.func.id
        for stmt in tree.body:
            if isinstance(stmt, ast.ClassDef) and stmt.name == cls_name:
                if any(
                    isinstance(m, ast.FunctionDef) and m.name == "reset"
                    for m in stmt.body
                ):
                    return True
    return False


def gl001_module_mutable_state(ctx: RuleContext) -> List[Violation]:
    if not ctx.tail.startswith("src/repro/"):
        return []
    mutated, global_names = _mutated_names(ctx.tree)
    out: List[Violation] = []
    for stmt in ctx.tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            target, value = stmt.targets[0], stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            target, value = stmt.target, stmt.value
        else:
            continue
        if not isinstance(target, ast.Name):
            continue
        name = target.id
        is_state = (_is_mutable_value(value) and name in mutated) or (
            name in global_names
        )
        if not is_state:
            continue
        if ctx.allowed("GL001", stmt.lineno):
            if _has_reset_exposure(ctx.tree, name, value):
                continue
            out.append(_v(
                ctx, "GL001", stmt,
                f"allowlisted module-level state '{name}' has no reset() "
                f"exposure (add a *reset*/*clear* function referencing it, "
                f"or give its class a reset() method)",
            ))
            continue
        out.append(_v(
            ctx, "GL001", stmt,
            f"module-level mutable state '{name}' (mutated in this module); "
            f"move it behind an injected object, or allowlist with "
            f"'# geolint: allow[GL001]' plus a reset() exposure",
        ))
    return out


# ----------------------------------------------------------------- GL002
_CLOCK_FNS = {"time", "perf_counter", "monotonic", "clock", "process_time"}
_GL002_SCOPES = ("src/repro/serve/", "src/repro/demand/")
_GL002_FILES = ("src/repro/streaming/migration.py",)


def gl002_sim_clock_purity(ctx: RuleContext) -> List[Violation]:
    if not (
        ctx.tail.startswith(_GL002_SCOPES) or ctx.tail in _GL002_FILES
    ):
        return []
    # bare names imported straight off the clock/RNG modules
    bare_clocks: Set[str] = set()
    bare_rngs: Set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom):
            if node.module == "time":
                bare_clocks.update(
                    a.asname or a.name for a in node.names
                    if a.name in _CLOCK_FNS
                )
            elif node.module in ("numpy.random", "numpy.random._generator"):
                bare_rngs.update(a.asname or a.name for a in node.names)
    out: List[Violation] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        ch = _dotted(node.func)
        if ch is None:
            continue
        if ctx.allowed("GL002", node.lineno):
            continue
        if (len(ch) == 2 and ch[0] == "time" and ch[1] in _CLOCK_FNS) or (
            len(ch) == 1 and ch[0] in bare_clocks
        ):
            out.append(_v(
                ctx, "GL002", node,
                f"wall-clock call {'.'.join(ch)}() in a control-plane module; "
                f"inject a clock (a bare default like "
                f"'clock=time.perf_counter' is fine — calling it here is not)",
            ))
            continue
        is_np_random = len(ch) >= 3 and ch[0] in ("np", "numpy") and ch[1] == "random"
        if is_np_random:
            fn = ch[2]
            if fn in ("Generator", "SeedSequence", "BitGenerator", "Philox",
                      "PCG64"):
                continue
            if fn == "default_rng" and node.args:
                continue  # seeded construction is deterministic
            out.append(_v(
                ctx, "GL002", node,
                f"unseeded numpy RNG {'.'.join(ch)}() in a control-plane "
                f"module; inject a seeded np.random.Generator",
            ))
        elif len(ch) == 1 and ch[0] in bare_rngs and not node.args:
            out.append(_v(
                ctx, "GL002", node,
                f"unseeded numpy RNG {ch[0]}() in a control-plane module; "
                f"inject a seeded np.random.Generator",
            ))
    return out


# ----------------------------------------------------------------- GL003
def _heat_receiver(target: ast.AST) -> Optional[ast.AST]:
    """The receiver expr when ``target`` writes through ``.heat``."""
    t = target
    if isinstance(t, ast.Subscript):
        t = t.value
    if isinstance(t, ast.Attribute) and t.attr == "heat":
        return t.value
    return None


class _HeatWriteVisitor(ast.NodeVisitor):
    def __init__(self, ctx: RuleContext) -> None:
        self.ctx = ctx
        self.out: List[Violation] = []
        self._class_heat_prop: List[bool] = []

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        has_prop = any(
            isinstance(m, ast.FunctionDef) and m.name == "heat"
            and any(
                (d_ch := _dotted(d)) is not None
                and d_ch[-1] in ("property", "cached_property")
                for d in m.decorator_list
            )
            for m in node.body
        )
        self._class_heat_prop.append(has_prop)
        self.generic_visit(node)
        self._class_heat_prop.pop()

    def _check_target(self, target: ast.AST, stmt: ast.AST) -> None:
        recv = _heat_receiver(target)
        if recv is None:
            return
        if self.ctx.allowed("GL003", stmt.lineno):
            return
        if isinstance(recv, ast.Name) and recv.id == "self":
            # plain attribute on the owning object is fine; a write through
            # a `heat` *property* (the HeatCache shared-storage view) is not
            if not (self._class_heat_prop and self._class_heat_prop[-1]):
                return
        self.out.append(_v(
            self.ctx, "GL003", stmt,
            "write to a '.heat' view outside src/repro/demand/ — heat is "
            "single-owned by ODDemandLayer; add a write-back method on the "
            "demand layer instead",
        ))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._check_target(t, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_target(node.target, node)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        self._check_target(node.target, node)
        self.generic_visit(node)


def gl003_heat_ownership(ctx: RuleContext) -> List[Violation]:
    if ctx.tail.startswith("src/repro/demand/"):
        return []
    v = _HeatWriteVisitor(ctx)
    v.visit(ctx.tree)
    return v.out


# ----------------------------------------------------------------- GL004
_GL004_FILES = ("src/repro/core/routing.py",)
_GL004_SCOPES = ("src/repro/serve/",)
_STRING_KEYED = {"counter", "histogram"}


class _HotLoopVisitor(ast.NodeVisitor):
    def __init__(self, ctx: RuleContext) -> None:
        self.ctx = ctx
        self.out: List[Violation] = []
        self._loop_depth = 0

    def _visit_loop(self, node: ast.AST) -> None:
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_For = visit_While = visit_AsyncFor = _visit_loop

    def _visit_fn(self, node: ast.AST) -> None:
        # a nested def runs later, not per loop iteration
        saved, self._loop_depth = self._loop_depth, 0
        self.generic_visit(node)
        self._loop_depth = saved

    visit_FunctionDef = visit_AsyncFunctionDef = visit_Lambda = _visit_fn

    def visit_Call(self, node: ast.Call) -> None:
        if (
            self._loop_depth > 0
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in _STRING_KEYED
            and node.args
            and isinstance(node.args[0], ast.Constant)
            and isinstance(node.args[0].value, str)
            and not self.ctx.allowed("GL004", node.lineno)
        ):
            self.out.append(_v(
                self.ctx, "GL004", node,
                f"string-keyed registry.{node.func.attr}"
                f"({node.args[0].value!r}) lookup inside a loop; hoist the "
                f"handle, or use counter_keyed/counter_grid",
            ))
        self.generic_visit(node)


def gl004_hot_path_telemetry(ctx: RuleContext) -> List[Violation]:
    if not (ctx.tail in _GL004_FILES or ctx.tail.startswith(_GL004_SCOPES)):
        return []
    v = _HotLoopVisitor(ctx)
    v.visit(ctx.tree)
    return v.out


# ----------------------------------------------------------------- GL005
def _is_jit_decorator(dec: ast.AST) -> bool:
    ch = _dotted(dec)
    if ch is not None and ch[-1] == "jit":
        return True
    if isinstance(dec, ast.Call):
        fch = _dotted(dec.func)
        if fch is not None and fch[-1] == "jit":
            return True
        if fch is not None and fch[-1] == "partial" and dec.args:
            ach = _dotted(dec.args[0])
            if ach is not None and ach[-1] == "jit":
                return True
    return False


def _pallas_kernel_names(tree: ast.Module) -> Set[str]:
    names: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        ch = _dotted(node.func)
        if ch is None or ch[-1] != "pallas_call" or not node.args:
            continue
        body = node.args[0]
        if isinstance(body, ast.Name):
            names.add(body.id)
        elif isinstance(body, ast.Call):
            fch = _dotted(body.func)
            if fch is not None and fch[-1] == "partial" and body.args:
                if isinstance(body.args[0], ast.Name):
                    names.add(body.args[0].id)
    return names


def gl005_traced_purity(ctx: RuleContext) -> List[Violation]:
    if not ctx.tail.startswith("src/repro/kernels/"):
        return []
    kernel_names = _pallas_kernel_names(ctx.tree)
    out: List[Violation] = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        is_traced = node.name in kernel_names or any(
            _is_jit_decorator(d) for d in node.decorator_list
        )
        if not is_traced:
            continue
        where = (
            "Pallas kernel body" if node.name in kernel_names
            else "@jax.jit function"
        )
        for sub in ast.walk(node):
            line = getattr(sub, "lineno", node.lineno)
            if ctx.allowed("GL005", line):
                continue
            if isinstance(sub, ast.Call):
                ch = _dotted(sub.func)
                if ch is None:
                    continue
                if ch == ("print",):
                    out.append(_v(
                        ctx, "GL005", sub,
                        f"print() inside {where} '{node.name}' — Python side "
                        f"effects do not trace; use jax.debug.print",
                    ))
                elif ch[0] in ("np", "numpy") and len(ch) > 1:
                    out.append(_v(
                        ctx, "GL005", sub,
                        f"host numpy call {'.'.join(ch)}() inside {where} "
                        f"'{node.name}' — silently constant-folds traced "
                        f"values; use jnp, or allowlist if provably static",
                    ))
            elif isinstance(sub, (ast.Global, ast.Nonlocal)):
                out.append(_v(
                    ctx, "GL005", sub,
                    f"global/nonlocal inside {where} '{node.name}' — traced "
                    f"code must be side-effect free",
                ))
            elif isinstance(sub, ast.Attribute) and sub.attr == "float64":
                ch = _dotted(sub)
                if ch is not None and ch[0] in ("np", "numpy", "jnp"):
                    out.append(_v(
                        ctx, "GL005", sub,
                        f"float64 reference inside {where} '{node.name}' — "
                        f"kernels are f32; implicit f64 mixing breaks TPU "
                        f"lowering",
                    ))
    return out


# ----------------------------------------------------------------- GL006
def _writes_self_attr(node: ast.AST, attr: str) -> bool:
    return (
        isinstance(node, ast.Attribute)
        and node.attr == attr
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    )


def gl006_epoch_guard(ctx: RuleContext) -> List[Violation]:
    out: List[Violation] = []
    for cls in ast.walk(ctx.tree):
        if not isinstance(cls, ast.ClassDef) or cls.name != "GeoGraphStore":
            continue
        for fn in cls.body:
            if not isinstance(fn, ast.FunctionDef) or fn.name == "__init__":
                continue
            rekeys: List[ast.AST] = []
            bumps_epoch = False
            fires_remap = False
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Assign):
                    for t in sub.targets:
                        if _writes_self_attr(t, "_item_uid"):
                            rekeys.append(sub)
                        if _writes_self_attr(t, "_id_epoch"):
                            bumps_epoch = True
                elif isinstance(sub, ast.AugAssign):
                    if _writes_self_attr(sub.target, "_id_epoch"):
                        bumps_epoch = True
                elif isinstance(sub, ast.Call):
                    ch = _dotted(sub.func)
                    if ch is not None and ch[-1] == "_fire_remap_listeners":
                        fires_remap = True
            for stmt in rekeys:
                if ctx.allowed("GL006", stmt.lineno):
                    continue
                missing = []
                if not bumps_epoch:
                    missing.append("bump self._id_epoch")
                if not fires_remap:
                    missing.append("call self._fire_remap_listeners(imap)")
                if missing:
                    out.append(_v(
                        ctx, "GL006", stmt,
                        f"'{fn.name}' re-keys the row layout "
                        f"(assigns self._item_uid) but does not "
                        f"{' or '.join(missing)} — in-flight flushes and "
                        f"subscribers would silently desync",
                    ))
    return out


ALL_RULES: Sequence = (
    gl001_module_mutable_state,
    gl002_sim_clock_purity,
    gl003_heat_ownership,
    gl004_hot_path_telemetry,
    gl005_traced_purity,
    gl006_epoch_guard,
)
