"""geolint — repo-specific AST invariant linter for the GeoLayer stack.

Zero-dependency (stdlib ``ast`` only).  Each rule encodes an invariant a
prior PR established and that the differential test suites *assume*:

=======  ==============================================================
GL001    no module-level mutable state in ``src/repro`` (allowlisted
         singletons must expose ``reset()``)
GL002    sim-clock purity: no wall-clock / unseeded-RNG calls in the
         control plane (``serve/``, ``demand/``, ``streaming/migration.py``)
GL003    heat single-ownership: ``HeatCache.heat`` is only written
         through ``src/repro/demand/``
GL004    telemetry hot-path discipline: no string-keyed instrument
         lookups inside loops in ``core/routing.py`` / ``serve/``
GL005    jit / Pallas purity: no side effects, host ``np.*`` calls or
         float64 mixing inside jitted functions and kernel bodies
GL006    epoch-guard coverage: re-keying ``GeoGraphStore`` row layout
         must bump the flush epoch and fire remap listeners
=======  ==============================================================

Run ``python -m tools.geolint src tests benchmarks`` from the repo root.
Suppress a finding with an inline ``# geolint: allow[GLxxx]`` pragma
(GL001 additionally requires a ``reset()`` exposure — see rules.py).
"""
from .engine import Violation, lint_file, lint_paths, lint_source, main

__all__ = ["Violation", "lint_file", "lint_paths", "lint_source", "main"]
