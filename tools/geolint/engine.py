"""geolint driver: file collection, pragma parsing, reporting, CLI.

The engine is rule-agnostic: it parses each file once, extracts the
inline ``# geolint: allow[GLxxx]`` pragmas, and hands a
:class:`RuleContext` to every rule in :mod:`tools.geolint.rules`.
Rules decide their own path scope from ``ctx.tail`` (the repo-relative
posix path), which is recovered from *anywhere* in the absolute path —
so fixture trees under ``/tmp/.../src/repro/serve/x.py`` scope exactly
like the real tree and the rule tests need no repo checkout.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import os
import re
import sys
import time
from typing import Dict, List, Optional, Sequence, Set

__all__ = ["Violation", "RuleContext", "lint_source", "lint_file", "lint_paths", "main"]

_PRAGMA_RE = re.compile(r"#\s*geolint:\s*allow\[([A-Z0-9_,\s]+)\]")

# path segments that anchor scope resolution (checked in order; the
# *last* occurrence wins so scratch dirs containing a marker still work)
_MARKERS = ("src/repro/", "tests/", "benchmarks/", "tools/", "examples/")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RuleContext:
    """Everything one rule needs to scan one file."""

    path: str  # path as passed on the command line (diagnostics)
    tail: str  # repo-relative posix path (scope decisions)
    tree: ast.Module
    source: str
    pragmas: Dict[int, Set[str]]  # line -> rules allowed on that line

    def allowed(self, rule: str, line: int) -> bool:
        return rule in self.pragmas.get(line, ())


def _path_tail(path: str) -> str:
    """Repo-relative posix tail of ``path`` (see module docstring)."""
    p = path.replace(os.sep, "/")
    best = None
    for marker in _MARKERS:
        i = p.rfind("/" + marker)
        if i >= 0:
            cand = p[i + 1 :]
        elif p.startswith(marker):
            cand = p
        else:
            continue
        if best is None or len(cand) < len(best):
            best = cand  # innermost marker = shortest tail
    return best if best is not None else p


def _parse_pragmas(source: str) -> Dict[int, Set[str]]:
    pragmas: Dict[int, Set[str]] = {}
    for lineno, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            pragmas[lineno] = rules
    return pragmas


def lint_source(source: str, path: str) -> List[Violation]:
    """Lint one file's source; ``path`` drives rule scoping."""
    from . import rules  # late import: rules imports Violation from here

    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [
            Violation(
                "GL000", path, e.lineno or 1, (e.offset or 1) - 1,
                f"syntax error: {e.msg}",
            )
        ]
    ctx = RuleContext(
        path=path,
        tail=_path_tail(path),
        tree=tree,
        source=source,
        pragmas=_parse_pragmas(source),
    )
    out: List[Violation] = []
    for rule in rules.ALL_RULES:
        out.extend(rule(ctx))
    return out


def lint_file(path: str) -> List[Violation]:
    with open(path, "r", encoding="utf-8") as f:
        return lint_source(f.read(), path)


def _collect(paths: Sequence[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                files.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [
                d for d in dirnames
                if d != "__pycache__" and not d.startswith(".")
            ]
            files.extend(
                os.path.join(dirpath, f) for f in filenames if f.endswith(".py")
            )
    return sorted(files)


def lint_paths(paths: Sequence[str]) -> List[Violation]:
    out: List[Violation] = []
    for f in _collect(paths):
        out.extend(lint_file(f))
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.geolint",
        description="GeoLayer repo-specific AST invariant linter",
    )
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument(
        "--json", dest="json_out", default=None,
        help="also write a JSON report to this path",
    )
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    files = _collect(args.paths)
    violations: List[Violation] = []
    for f in files:
        violations.extend(lint_file(f))
    violations.sort(key=lambda v: (v.path, v.line, v.rule))
    elapsed = time.perf_counter() - t0

    for v in violations:
        print(v.format())
    if args.json_out:
        report = {
            "files_scanned": len(files),
            "elapsed_s": round(elapsed, 3),
            "n_violations": len(violations),
            "violations": [v.as_dict() for v in violations],
        }
        with open(args.json_out, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
    print(
        f"geolint: {len(violations)} violation(s) across {len(files)} files "
        f"in {elapsed:.2f}s",
        file=sys.stderr,
    )
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
