"""Shared neural-net building blocks (pure-functional, pytree params).

No flax/haiku in this environment — params are plain dicts of jnp arrays,
initialized by ``init_*`` helpers and consumed by matching ``apply``-style
functions.  Compute dtype is bf16 by default (TPU target); params stay fp32.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "dense",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "mlp_init",
    "mlp",
    "swiglu_init",
    "swiglu",
    "embedding_init",
    "rope",
    "cross_entropy",
]

Params = Dict[str, jnp.ndarray]


def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None) -> Params:
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    return {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}


def dense(p: Params, x: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return x.astype(dtype) @ p["w"].astype(dtype)


def rmsnorm_init(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * p["g"]).astype(dt)


def layernorm_init(d: int) -> Params:
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]).astype(dt)


def mlp_init(key, dims: Sequence[int]) -> Params:
    keys = jax.random.split(key, len(dims) - 1)
    p: Params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        p[f"w{i}"] = jax.random.normal(keys[i], (a, b), jnp.float32) / math.sqrt(a)
        p[f"b{i}"] = jnp.zeros((b,), jnp.float32)
    return p


def mlp(
    p: Params, x: jnp.ndarray, act=jax.nn.silu, final_act: bool = False,
    dtype=jnp.bfloat16,
) -> jnp.ndarray:
    n = len([k for k in p if k.startswith("w")])
    h = x.astype(dtype)
    for i in range(n):
        h = h @ p[f"w{i}"].astype(dtype) + p[f"b{i}"].astype(dtype)
        if i < n - 1 or final_act:
            h = act(h)
    return h


def swiglu_init(key, d: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    return {
        "w_gate": jax.random.normal(k1, (d, d_ff), jnp.float32) * s,
        "w_up": jax.random.normal(k2, (d, d_ff), jnp.float32) * s,
        "w_down": jax.random.normal(k3, (d_ff, d), jnp.float32) / math.sqrt(d_ff),
    }


def swiglu(p: Params, x: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    xd = x.astype(dtype)
    g = jax.nn.silu(xd @ p["w_gate"].astype(dtype))
    u = xd @ p["w_up"].astype(dtype)
    return (g * u) @ p["w_down"].astype(dtype)


def embedding_init(key, vocab: int, d: int, scale: float = 0.02) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * scale}


def rope(
    x: jnp.ndarray,  # [..., S, D] (D even)
    positions: jnp.ndarray,  # [..., S] or [S]
    base: float = 10000.0,
) -> jnp.ndarray:
    """Rotary position embedding over the last dim (half-split convention)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(base) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    while ang.ndim < x.ndim:  # insert head axis: [..., 1, S, half]
        ang = jnp.expand_dims(ang, -3)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(
    logits: jnp.ndarray,  # [..., V]
    labels: jnp.ndarray,  # [...]
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
