"""Mixture-of-Experts FFN with capacity-bounded sort dispatch.

Design (TPU/EP-friendly, DESIGN §7):
  * router: softmax top-k with optional shared experts (DeepSeekMoE style);
  * dispatch: tokens sorted by expert id, positions within expert via a
    cumulative count, **capacity-clamped scatter** into a dense
    ``[E, C, d]`` buffer — all static shapes, no one-hot ``[T, E, C]`` blowup;
  * expert compute: two batched einsums over the expert axis (SwiGLU), so the
    ``E`` axis shards cleanly over the ``model`` mesh axis (expert
    parallelism) and XLA inserts the token all-to-all at the scatter/gather;
  * combine: weighted gather-back; dropped tokens (over capacity) fall
    through with zero contribution (standard GShard semantics).

GeoLayer integration: per-expert routing counts are the *heat* signal; the
placement layer (distributed/geo_sharding.py) can mark hot experts for
replication, which here simply widens the expert buffer's replica group.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.constraints import constrain
from .layers import Params

__all__ = ["moe_init", "moe_forward"]


def _pick_groups(t: int, target: int = 0) -> int:
    """Dispatch group count: aligned with the mesh's data-parallel extent
    (pod x data) so every group is shard-local — a 16-group dispatch on a
    32-way dp mesh can't be sharded on the group axis and silently crosses
    pods (EXPERIMENTS §Perf it. 9).  Falls back to 16 without a mesh."""
    if target <= 0:
        try:
            from ..distributed.constraints import current_mesh

            m = current_mesh()
            target = 1
            if m is not None:
                sizes = dict(zip(m.axis_names, m.devices.shape))
                for ax in ("pod", "data"):
                    target *= sizes.get(ax, 1)
            if target <= 1:
                target = 16
        except Exception:  # pragma: no cover
            target = 16
    g = target
    while g > 1 and t % g != 0:
        g //= 2
    return g


def moe_init(
    key,
    d_model: int,
    d_ff_expert: int,
    n_experts: int,
    n_shared: int = 0,
    d_ff_shared: Optional[int] = None,
) -> Params:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d_model)
    p: Params = {
        "router": jax.random.normal(k1, (d_model, n_experts), jnp.float32) * s,
        "w_gate": jax.random.normal(
            k2, (n_experts, d_model, d_ff_expert), jnp.float32
        ) * s,
        "w_up": jax.random.normal(
            k3, (n_experts, d_model, d_ff_expert), jnp.float32
        ) * s,
        "w_down": jax.random.normal(
            k4, (n_experts, d_ff_expert, d_model), jnp.float32
        ) / math.sqrt(d_ff_expert),
    }
    if n_shared > 0:
        dfs = d_ff_shared or d_ff_expert * n_shared
        ks1, ks2, ks3 = jax.random.split(k5, 3)
        p["shared_gate"] = jax.random.normal(ks1, (d_model, dfs), jnp.float32) * s
        p["shared_up"] = jax.random.normal(ks2, (d_model, dfs), jnp.float32) * s
        p["shared_down"] = jax.random.normal(ks3, (dfs, d_model), jnp.float32) / math.sqrt(dfs)
    return p


def moe_forward(
    p: Params,
    x: jnp.ndarray,  # [B, S, d]
    top_k: int,
    capacity_factor: float = 1.25,
    dtype=jnp.bfloat16,
    n_active: Optional[int] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Returns (output, aux) where aux carries router stats: ``expert_load``
    (the GeoLayer heat signal) and ``aux_loss`` (load-balance loss).

    ``n_active < E`` marks trailing experts as padding (EP-divisibility
    padding, e.g. granite's 40 experts padded to 48 on a 16-way axis): the
    router never selects them; their buffer rows stay zero."""
    b, s, d = x.shape
    e = p["router"].shape[1]
    t = b * s
    xt = x.reshape(t, d).astype(dtype)

    logits = (xt.astype(jnp.float32)) @ p["router"]
    if n_active is not None and n_active < e:
        pad_mask = jnp.arange(e) >= n_active
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # [T, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- group-local dispatch (GShard grouping) ---------------------------
    # A *global* argsort over the T*k assignments forces the partitioner to
    # all-gather the sorted token gather in every layer (measured: the
    # dominant collective term for MoE prefill/train, EXPERIMENTS §Perf it.6).
    # Tokens are instead split into dp-aligned groups; each group sorts and
    # capacity-clamps locally (vmap), so the only cross-device traffic left
    # is the unavoidable token->expert all-to-all at the buffer boundary.
    n_groups = _pick_groups(t)
    tg = t // n_groups
    capacity = max(int(capacity_factor * tg * top_k / e), 4)
    gi = gate_idx.reshape(n_groups, tg, top_k)
    gv = gate_vals.reshape(n_groups, tg, top_k)
    xg = constrain(xt.reshape(n_groups, tg, d), ("pod", "data"), None, None)

    def dispatch(gi_g, gv_g, x_g):
        flat_e = gi_g.reshape(-1)  # [tg*k]
        flat_t = jnp.repeat(jnp.arange(tg), top_k)
        flat_w = gv_g.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        cum = jnp.cumsum(jnp.ones_like(se)) - 1
        first = jnp.full((e,), tg * top_k, cum.dtype).at[se].min(cum)
        pos = cum - first[se]
        keep = pos < capacity
        pos_c = jnp.where(keep, pos, capacity - 1)
        buf_g = jnp.zeros((e, capacity, d), dtype)
        buf_g = buf_g.at[se, pos_c].add(jnp.where(keep[:, None], x_g[st], 0.0))
        return buf_g, (se, st, sw, keep, pos_c)

    buf_g, (se, st, sw, keep, pos_c) = jax.vmap(dispatch)(gi, gv, xg)
    # [G, E, C, d] -> [E, G*C, d]: the all-to-all point (EP over `model`)
    buf = constrain(
        jnp.moveaxis(buf_g, 0, 1).reshape(e, n_groups * capacity, d),
        "model", ("pod", "data"), None,
    )

    # expert SwiGLU over the E axis (EP-sharded einsums)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dtype)))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dtype))
    y = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(dtype))  # [E,GC,d]
    y = constrain(y, "model", ("pod", "data"), None)
    y_g = jnp.moveaxis(y.reshape(e, n_groups, capacity, d), 1, 0)  # [G,E,C,d]

    def combine(y_gg, se_g, st_g, sw_g, keep_g, pos_g):
        gathered = y_gg[se_g, pos_g]  # [tg*k, d]
        contrib = jnp.where(
            keep_g[:, None], gathered * sw_g[:, None].astype(dtype), 0.0
        )
        return jnp.zeros((tg, d), dtype).at[st_g].add(contrib)

    out = jax.vmap(combine)(y_g, se, st, sw, keep, pos_c)
    out = constrain(out, ("pod", "data"), None, None).reshape(t, d)
    flat_e = gate_idx.reshape(-1)  # for load stats below

    if "shared_gate" in p:
        sg = jax.nn.silu(xt @ p["shared_gate"].astype(dtype))
        su = xt @ p["shared_up"].astype(dtype)
        out = out + (sg * su) @ p["shared_down"].astype(dtype)

    # load-balance aux loss (Switch): e * sum(f_i * P_i)
    load = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * top_k)
    imp = probs.mean(axis=0)
    aux_loss = e * jnp.sum(load * imp)
    return out.reshape(b, s, d), {"expert_load": load, "aux_loss": aux_loss}
