"""Decoder-only LM assembled from the attention/MoE/FFN blocks.

Layer stack runs under ``jax.lax.scan`` over stacked per-layer params so the
HLO stays O(1) in depth (62-layer gemma3 compiles fast) and activation remat
applies per scan step.  Heterogeneous per-layer attention (gemma3's 5:1
local:global) is encoded as a per-layer window array consumed inside the
scan via masking — one code path, no cond branching.

Entry points (pure functions, pjit-ready):
  * ``init_params(key, cfg)``      — concrete params (smoke tests)
  * ``train_step_fn(cfg)``         — (params, opt, batch) -> loss/step
  * ``prefill_fn(cfg)``            — forward, emits KV caches + last logits
  * ``decode_fn(cfg)``             — one-token serve step over KV caches
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import gqa_init, mla_decode, mla_forward, mla_init
from .layers import Params, cross_entropy, embedding_init, rmsnorm, rmsnorm_init, swiglu, swiglu_init
from .moe import moe_forward, moe_init

__all__ = ["LMConfig", "init_params", "forward", "train_loss", "prefill", "decode"]

_GLOBAL_WINDOW = 1 << 30  # "window" that never masks = global attention


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # MoE
    moe: bool = False
    n_experts: int = 0
    n_experts_active: Optional[int] = None  # < n_experts when padded for EP
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # MLA
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # attention pattern
    sliding_window: Optional[int] = None  # window for local layers
    local_global_ratio: int = 0  # N local : 1 global; 0 = all global
    qk_norm: bool = False
    rope_base: float = 10000.0
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # scan_layers=True keeps HLO depth-independent (training default);
    # False unrolls the stack so XLA cost_analysis counts every layer
    # (dry-run/roofline default — scan bodies are costed only once).
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_windows(self) -> jnp.ndarray:
        """Per-layer attention window (gemma3 5:1 pattern; global = huge)."""
        if self.local_global_ratio <= 0 or self.sliding_window is None:
            w = self.sliding_window or _GLOBAL_WINDOW
            return jnp.full((self.n_layers,), w, jnp.int32)
        r = self.local_global_ratio
        pat = [
            self.sliding_window if (i % (r + 1)) != r else _GLOBAL_WINDOW
            for i in range(self.n_layers)
        ]
        return jnp.asarray(pat, jnp.int32)

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        if self.mla:
            attn = d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
            attn += d * self.kv_lora_rank + d * self.qk_rope_dim
            attn += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            attn += self.n_heads * self.v_head_dim * d
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
            attn += self.n_heads * hd * d
        if self.moe:
            ffn = 3 * d * self.d_ff_expert * self.n_experts + d * self.n_experts
            ffn += 3 * d * (self.d_ff_expert * self.n_shared_experts)
        else:
            ffn = 3 * d * self.d_ff
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn + 2 * d) + emb + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        routed_all = 3 * d * self.d_ff_expert * self.n_experts
        routed_active = 3 * d * self.d_ff_expert * self.top_k
        return self.param_count() - self.n_layers * (routed_all - routed_active)


# ---------------------------------------------------------------- parameters
def _layer_init(key, cfg: LMConfig) -> Params:
    k_attn, k_ffn = jax.random.split(key)
    if cfg.mla:
        attn = mla_init(
            k_attn, cfg.d_model, cfg.n_heads, cfg.kv_lora_rank,
            cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
        )
    else:
        attn = gqa_init(
            k_attn, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.qk_norm
        )
    if cfg.moe:
        ffn = moe_init(
            k_ffn, cfg.d_model, cfg.d_ff_expert, cfg.n_experts,
            cfg.n_shared_experts,
        )
    else:
        ffn = swiglu_init(k_ffn, cfg.d_model, cfg.d_ff)
    return {
        "attn": attn,
        "ffn": ffn,
        "ln1": rmsnorm_init(cfg.d_model),
        "ln2": rmsnorm_init(cfg.d_model),
    }


def init_params(key, cfg: LMConfig) -> Params:
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    p: Params = {
        "embed": embedding_init(k_emb, cfg.vocab_size, cfg.d_model),
        "layers": stacked,
        "ln_f": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = embedding_init(k_out, cfg.vocab_size, cfg.d_model)
    return p


# ------------------------------------------------------------------- forward
def _block(
    lp: Params,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    window: jnp.ndarray,  # scalar int32 (per-layer)
    cfg: LMConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray], jnp.ndarray]:
    from ..distributed.constraints import constrain
    from ..distributed.sharding import constrain_lm_layer

    lp = constrain_lm_layer(lp)  # keep FSDP gathers inside the layer loop
    # sequence parallelism: the residual stream (and thus every remat-saved
    # layer input) shards seq over `model`; attention/ffn re-gather locally.
    x = constrain(x, ("pod", "data"), "model", None)
    h = rmsnorm(lp["ln1"], x)
    if cfg.mla:
        a, cache = mla_forward(
            lp["attn"], h, positions, cfg.n_heads,
            cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, dtype=cfg.dtype,
        )
    else:
        # window as data: masking path supports per-layer traced windows
        a, cache = _gqa_forward_window(lp["attn"], h, positions, window, cfg)
    x = x + a
    h = rmsnorm(lp["ln2"], x)
    aux_loss = jnp.asarray(0.0, jnp.float32)
    if cfg.moe:
        f, aux = moe_forward(
            lp["ffn"], h, cfg.top_k, cfg.capacity_factor, cfg.dtype,
            n_active=cfg.n_experts_active,
        )
        aux_loss = aux["aux_loss"]
    else:
        f = swiglu(lp["ffn"], h, cfg.dtype)
    from ..distributed.constraints import constrain as _c

    return _c(x + f, ("pod", "data"), "model", None), cache, aux_loss


def _gqa_forward_window(p, h, positions, window, cfg: LMConfig):
    """GQA forward where the sliding window is a traced scalar: uses the
    chunked/masked path with dynamic window masking."""
    from .attention import _split_heads, _merge_heads
    from .layers import rope
    from ..distributed.constraints import constrain

    dtype = cfg.dtype
    dp = ("pod", "data")
    hd_ = h.astype(dtype)
    q = _split_heads(hd_ @ p["wq"].astype(dtype), cfg.n_heads)
    k = _split_heads(hd_ @ p["wk"].astype(dtype), cfg.n_kv_heads)
    v = _split_heads(hd_ @ p["wv"].astype(dtype), cfg.n_kv_heads)
    # pin head sharding: SPMD loses it through reshape+scan and would
    # replicate the S x S attention buffers (mesh-size memory blowup)
    q = constrain(q, dp, "model", None, None)
    k = constrain(k, dp, "model", None, None)
    v = constrain(v, dp, "model", None, None)
    if "q_norm" in p:
        q = rmsnorm({"g": p["q_norm"]}, q)
        k = rmsnorm({"g": p["k_norm"]}, k)
    q = rope(q, positions, cfg.rope_base)
    k = rope(k, positions, cfg.rope_base)
    o = _window_attention(q, k, v, window)
    o = constrain(o, dp, "model", None, None)
    out = _merge_heads(o).astype(dtype) @ p["wo"].astype(dtype)
    return out, {"k": k, "v": v}


def _window_attention(q, k, v, window, chunk_kv: int = 1024, chunk_q: int = 2048):
    """Causal attention with a *traced* window scalar (mask-based chunked)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = d ** -0.5
    if sq <= 2048 and skv <= 2048:
        kr = jnp.repeat(k, group, axis=1)
        vr = jnp.repeat(v, group, axis=1)
        s = jnp.einsum(
            "bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
        ) * scale
        q_pos = jnp.arange(sq)[:, None] + (skv - sq)
        k_pos = jnp.arange(skv)[None, :]
        mask = (k_pos <= q_pos) & (k_pos > q_pos - window)
        s = jnp.where(mask[None, None], s, -1e30)
        pbs = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", pbs, vr.astype(jnp.float32)).astype(
            q.dtype
        )
    # long path: chunked scan with dynamic window mask

    # chunked_attention accepts static window only; emulate dynamic window by
    # two-mask composition: causal chunked with kv_valid=None, window folded
    # into the mask via the wrapper below.
    return _chunked_dyn_window(q, k, v, window, chunk_kv, chunk_q, scale, group)


def _chunked_dyn_window(q, k, v, window, chunk_kv, chunk_q, scale, group):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    chunk_kv = min(chunk_kv, skv)
    chunk_q = min(chunk_q, sq)
    n_kv = skv // chunk_kv
    assert skv % chunk_kv == 0 and sq % chunk_q == 0
    kc = jnp.moveaxis(k.reshape(b, hkv, n_kv, chunk_kv, d), 2, 0)
    vc = jnp.moveaxis(v.reshape(b, hkv, n_kv, chunk_kv, d), 2, 0)

    def q_block(args):
        qb, iq = args
        cq = qb.shape[2]

        def kv_step(carry, inp):
            m, l, acc = carry
            kb, vb, ikv = inp
            kbr = jnp.repeat(kb, group, axis=1)
            vbr = jnp.repeat(vb, group, axis=1)
            s = jnp.einsum(
                "bhqd,bhkd->bhqk", qb.astype(jnp.float32), kbr.astype(jnp.float32)
            ) * scale
            q_pos = iq * chunk_q + jnp.arange(cq)[:, None] + (skv - sq)
            k_pos = ikv * chunk_kv + jnp.arange(chunk_kv)[None, :]
            mask = (k_pos <= q_pos) & (k_pos > q_pos - window)
            s = jnp.where(mask[None, None], s, -1e30)
            m_cur = s.max(-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            p_ = jnp.exp(s - m_new)
            corr = jnp.exp(m - m_new)
            return (
                m_new,
                l * corr + p_.sum(-1, keepdims=True),
                acc * corr + jnp.einsum("bhqk,bhkd->bhqd", p_, vbr.astype(jnp.float32)),
            ), None

        m0 = jnp.full((b, hq, cq, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hq, cq, 1), jnp.float32)
        a0 = jnp.zeros((b, hq, cq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kc, vc, jnp.arange(n_kv)))
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

    if sq == chunk_q:
        return q_block((q, jnp.asarray(0)))
    n_q = sq // chunk_q
    qs = jnp.moveaxis(q.reshape(b, hq, n_q, chunk_q, d), 2, 0)
    outs = jax.lax.map(q_block, (qs, jnp.arange(n_q)))
    return jnp.moveaxis(outs, 0, 2).reshape(b, hq, sq, d)


def chunked_ce_loss(
    x: jnp.ndarray,  # [B, S, d] final hidden states
    unemb: jnp.ndarray,  # [V, d]
    labels: jnp.ndarray,  # [B, S]
    n_chunks: int = 16,
) -> jnp.ndarray:
    """Cross-entropy without materializing [B, S, V] fp32 logits: scans
    sequence chunks, computing logits -> logsumexp -> gold per chunk.  Cuts
    the CE temp footprint by ~n_chunks (the dominant blob for 150k-vocab
    models); the same trick Megatron/MaxText use for the softmax layer."""
    from ..distributed.constraints import constrain

    b, s, d = x.shape
    while s % n_chunks != 0:
        n_chunks //= 2
    cs = s // n_chunks
    xc = jnp.moveaxis(x.reshape(b, n_chunks, cs, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n_chunks, cs), 1, 0)

    def chunk(tot, inp):
        xx, ll = inp
        logits = xx @ unemb.T  # [b, cs, V]
        logits = constrain(logits, ("pod", "data"), None, "model")
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), ll[..., None], axis=-1
        )[..., 0]
        return tot + (lse - gold).sum(), None

    tot, _ = jax.lax.scan(chunk, jnp.zeros((), jnp.float32), (xc, lc))
    return tot / (b * s)


def forward(
    params: Params,
    tokens: jnp.ndarray,  # [B, S]
    cfg: LMConfig,
    collect_cache: bool = False,
    skip_unembed: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (logits — or hidden states if skip_unembed, caches, aux)."""
    b, s = tokens.shape
    x = params["embed"]["table"].astype(cfg.dtype)[tokens]
    positions = jnp.arange(s)
    windows = cfg.layer_windows()

    fn = _block
    if cfg.remat:
        fn = jax.checkpoint(_block, static_argnums=(4,))

    if cfg.scan_layers:

        def step(x, inp):
            lp, w = inp
            x, cache, aux = fn(lp, x, positions, w, cfg)
            out = (cache if collect_cache else 0, aux)
            return x, out

        x, (caches, auxes) = jax.lax.scan(step, x, (params["layers"], windows))
    else:  # unrolled: roofline-accurate HLO (scan bodies are costed once)
        cache_list, aux_list = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, cache, aux = fn(lp, x, positions, windows[i], cfg)
            if collect_cache:
                cache_list.append(cache)
            aux_list.append(aux)
        auxes = jnp.stack(aux_list)
        caches = (
            jax.tree_util.tree_map(lambda *c: jnp.stack(c), *cache_list)
            if collect_cache
            else None
        )
    x = rmsnorm(params["ln_f"], x)
    if skip_unembed:
        return x, (caches if collect_cache else None), jnp.sum(auxes)
    unemb = params.get("unembed", params["embed"])["table"].astype(cfg.dtype)
    logits = x @ unemb.T
    return logits, (caches if collect_cache else None), jnp.sum(auxes)


def hidden_forward(params: Params, tokens: jnp.ndarray, cfg: LMConfig):
    """Forward up to the final norm (no unembed); returns ([B,S,d], aux)."""
    logits, _, aux = forward(params, tokens, cfg, collect_cache=False, skip_unembed=True)
    return logits, aux


def train_loss(
    params: Params, batch: Dict[str, jnp.ndarray], cfg: LMConfig,
    ce_chunks: int = 16,
):
    if ce_chunks > 1:
        x, aux = hidden_forward(params, batch["tokens"], cfg)
        unemb = params.get("unembed", params["embed"])["table"].astype(cfg.dtype)
        loss = chunked_ce_loss(x, unemb, batch["labels"], ce_chunks)
    else:
        logits, _, aux = forward(params, batch["tokens"], cfg)
        loss = cross_entropy(logits, batch["labels"])
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


def prefill(params: Params, tokens: jnp.ndarray, cfg: LMConfig):
    """Serving prefill: forward + stacked KV caches + last-position logits."""
    logits, caches, _ = forward(params, tokens, cfg, collect_cache=True)
    return logits[:, -1], caches


def decode(
    params: Params,
    token: jnp.ndarray,  # [B] current token ids
    caches: Dict[str, jnp.ndarray],  # stacked over layers (scan layout)
    position: jnp.ndarray,  # [B]
    cfg: LMConfig,
):
    """One-token serve step over stacked caches.  Returns (logits, caches)."""
    x = params["embed"]["table"].astype(cfg.dtype)[token][:, None]  # [B,1,d]
    windows = cfg.layer_windows()

    def step(x, inp):
        lp, cache, w = inp
        from ..distributed.sharding import constrain_lm_layer

        lp = constrain_lm_layer(lp)
        h = rmsnorm(lp["ln1"], x)
        if cfg.mla:
            a, new_cache = mla_decode(
                lp["attn"], h, cache, position, cfg.n_heads,
                cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, dtype=cfg.dtype,
            )
        else:
            a, new_cache = _gqa_decode_window(lp["attn"], h, cache, position, w, cfg)
        x = x + a
        h = rmsnorm(lp["ln2"], x)
        if cfg.moe:
            f, _ = moe_forward(lp["ffn"], h, cfg.top_k, cfg.capacity_factor, cfg.dtype)
        else:
            f = swiglu(lp["ffn"], h, cfg.dtype)
        return x + f, new_cache

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(step, x, (params["layers"], caches, windows))
    else:
        new_cache_list = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            ci = jax.tree_util.tree_map(lambda a: a[i], caches)
            x, nc = step(x, (lp, ci, windows[i]))
            new_cache_list.append(nc)
        new_caches = jax.tree_util.tree_map(lambda *c: jnp.stack(c), *new_cache_list)
    x = rmsnorm(params["ln_f"], x)
    unemb = params.get("unembed", params["embed"])["table"].astype(cfg.dtype)
    logits = (x @ unemb.T)[:, 0]
    return logits, new_caches


def _gqa_decode_window(p, h, cache, position, window, cfg: LMConfig):
    from .attention import _merge_heads, _split_heads
    from .layers import rope
    from ..distributed.constraints import constrain

    dtype = cfg.dtype
    dp = ("pod", "data")
    hd_ = h.astype(dtype)
    q = constrain(_split_heads(hd_ @ p["wq"].astype(dtype), cfg.n_heads), dp, "model", None, None)
    k_new = constrain(_split_heads(hd_ @ p["wk"].astype(dtype), cfg.n_kv_heads), dp, "model", None, None)
    v_new = constrain(_split_heads(hd_ @ p["wv"].astype(dtype), cfg.n_kv_heads), dp, "model", None, None)
    if "q_norm" in p:
        q = rmsnorm({"g": p["q_norm"]}, q)
        k_new = rmsnorm({"g": p["k_norm"]}, k_new)
    q = rope(q, position[:, None], cfg.rope_base)
    k_new = rope(k_new, position[:, None], cfg.rope_base)
    kc = jax.vmap(lambda c, n, pos: jax.lax.dynamic_update_slice(c, n, (0, pos, 0)))(
        cache["k"], k_new, position
    )
    vc = jax.vmap(lambda c, n, pos: jax.lax.dynamic_update_slice(c, n, (0, pos, 0)))(
        cache["v"], v_new, position
    )
    group = cfg.n_heads // cfg.n_kv_heads
    scale = cfg.hd ** -0.5
    # decode attention: one query against the cache, window+valid masked;
    # chunked over KV to bound the f32 logits buffer at long context
    o = _decode_attend(q, kc, vc, position, window, group, scale)
    return _merge_heads(o).astype(dtype) @ p["wo"].astype(dtype), {"k": kc, "v": vc}


def _decode_attend(q, kc, vc, position, window, group, scale, chunk: int = 8192):
    b, hq, _, d = q.shape
    skv = kc.shape[2]
    if skv <= chunk:
        kr = jnp.repeat(kc, group, axis=1)
        vr = jnp.repeat(vc, group, axis=1)
        s = jnp.einsum(
            "bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
        ) * scale
        k_pos = jnp.arange(skv)[None, None, None, :]
        pos = position[:, None, None, None]
        mask = (k_pos <= pos) & (k_pos > pos - window)
        s = jnp.where(mask, s, -1e30)
        p_ = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p_, vr.astype(jnp.float32)).astype(q.dtype)
    n_c = skv // chunk
    assert skv % chunk == 0
    kcs = jnp.moveaxis(kc.reshape(b, -1, n_c, chunk, d), 2, 0)
    vcs = jnp.moveaxis(vc.reshape(b, -1, n_c, chunk, d), 2, 0)

    def kv_step(carry, inp):
        m, l, acc = carry
        kb, vb, ic = inp
        kbr = jnp.repeat(kb, group, axis=1)
        vbr = jnp.repeat(vb, group, axis=1)
        s = jnp.einsum(
            "bhqd,bhkd->bhqk", q.astype(jnp.float32), kbr.astype(jnp.float32)
        ) * scale
        k_pos = (ic * chunk + jnp.arange(chunk))[None, None, None, :]
        pos = position[:, None, None, None]
        mask = (k_pos <= pos) & (k_pos > pos - window)
        s = jnp.where(mask, s, -1e30)
        m_cur = s.max(-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p_ = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        return (
            m_new,
            l * corr + p_.sum(-1, keepdims=True),
            acc * corr + jnp.einsum("bhqk,bhkd->bhqd", p_, vbr.astype(jnp.float32)),
        ), None

    m0 = jnp.full((b, hq, 1, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, 1, 1), jnp.float32)
    a0 = jnp.zeros((b, hq, 1, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kcs, vcs, jnp.arange(n_c)))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
