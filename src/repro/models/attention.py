"""Attention variants for the LM zoo: GQA (+qk-norm), sliding-window/global
mix (gemma3), and MLA (DeepSeek-V2 latent KV compression).

Execution paths:
  * ``ops.attention``     — Pallas flash kernel on TPU, dense ref on CPU.
  * ``chunked_attention`` — pure-XLA online-softmax over KV chunks (``lax.scan``):
    the distribution-grade path used for long sequences in the dry-run, with
    flash-like memory (never materializes S x S logits).

KV caches are plain dicts of arrays; decode steps update them functionally.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.constraints import constrain
from ..kernels import ops
from .layers import Params, dense_init, rmsnorm, rmsnorm_init, rope

__all__ = [
    "chunked_attention",
    "gqa_init",
    "gqa_forward",
    "gqa_decode",
    "mla_init",
    "mla_forward",
    "mla_decode",
]


# ------------------------------------------------------- chunked (XLA flash)
def chunked_attention(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Skv, D]
    v: jnp.ndarray,  # [B, Hkv, Skv, D]
    causal: bool = True,
    window: Optional[int] = None,
    chunk_kv: int = 1024,
    chunk_q: int = 2048,
    kv_valid: Optional[jnp.ndarray] = None,  # [B] #valid kv positions
) -> jnp.ndarray:
    """Online-softmax attention scanning KV (and Q) chunks — O(Sq*Ckv) peak.

    Equivalent to ``ref.attention_ref``; used where the Pallas kernel is not
    available and S^2 logits would blow HBM (32k prefill, 500k decode).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    dv = v.shape[-1]  # may differ from qk dim (MLA)
    group = hq // hkv
    scale = d ** -0.5
    chunk_kv = min(chunk_kv, skv)
    chunk_q = min(chunk_q, sq)
    pad_kv = (-skv) % chunk_kv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    n_kv = k.shape[2] // chunk_kv
    kc = k.reshape(b, hkv, n_kv, chunk_kv, d)
    vc = v.reshape(b, hkv, n_kv, chunk_kv, dv)

    def q_block(qb: jnp.ndarray, q0: jnp.ndarray) -> jnp.ndarray:
        # qb: [B, Hq, cq, D]; q0: scalar absolute offset of this q block
        cq = qb.shape[2]

        def kv_step(carry, inp):
            m, l, acc = carry
            kb, vb, ikv = inp  # [B, Hkv, ckv, D]
            kb = jnp.repeat(kb, group, axis=1)
            vb = jnp.repeat(vb, group, axis=1)
            s = jnp.einsum(
                "bhqd,bhkd->bhqk", qb.astype(jnp.float32), kb.astype(jnp.float32)
            ) * scale
            q_pos = q0 + jnp.arange(cq)[:, None] + (skv - sq)
            k_pos = ikv * chunk_kv + jnp.arange(chunk_kv)[None, :]
            mask = k_pos < skv  # padding
            if causal:
                mask = mask & (k_pos <= q_pos)
            if window is not None:
                mask = mask & (k_pos > q_pos - window)
            if kv_valid is not None:
                mask = mask[None] & (k_pos[None] < kv_valid[:, None, None])
                mask = mask[:, None]
            else:
                mask = mask[None, None]
            s = jnp.where(mask, s, -1e30)
            m_cur = s.max(axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1, keepdims=True)
            acc_new = acc * corr + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, cq, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hq, cq, 1), jnp.float32)
        a0 = jnp.zeros((b, hq, cq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (
                jnp.moveaxis(kc, 2, 0),
                jnp.moveaxis(vc, 2, 0),
                jnp.arange(n_kv),
            ),
        )
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

    if sq <= chunk_q:
        return q_block(q, jnp.asarray(0))
    n_q = sq // chunk_q
    assert sq % chunk_q == 0
    qs = jnp.moveaxis(q.reshape(b, hq, n_q, chunk_q, d), 2, 0)
    outs = jax.lax.map(
        lambda args: q_block(args[0], args[1] * chunk_q), (qs, jnp.arange(n_q))
    )
    return jnp.moveaxis(outs, 0, 2).reshape(b, hq, sq, dv)


def _attend(
    q, k, v, causal: bool, window: Optional[int], kv_valid=None, prefer_kernel=True
) -> jnp.ndarray:
    """Dispatch: Pallas kernel (TPU) -> chunked XLA (long) -> dense ref."""
    skv = k.shape[2]
    if kv_valid is None and ops.on_tpu() and prefer_kernel:
        return ops.attention(q, k, v, causal=causal, window=window)
    if skv > 2048 or kv_valid is not None:
        return chunked_attention(q, k, v, causal=causal, window=window, kv_valid=kv_valid)
    from ..kernels.ref import attention_ref

    return attention_ref(q, k, v, causal=causal, window=window)


# ------------------------------------------------------------------- GQA
def gqa_init(
    key,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    qk_norm: bool = False,
) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(k1, d_model, n_heads * head_dim)["w"],
        "wk": dense_init(k2, d_model, n_kv_heads * head_dim)["w"],
        "wv": dense_init(k3, d_model, n_kv_heads * head_dim)["w"],
        "wo": dense_init(k4, n_heads * head_dim, d_model)["w"],
    }
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim)["g"]
        p["k_norm"] = rmsnorm_init(head_dim)["g"]
    return p


def _split_heads(x: jnp.ndarray, n: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1).transpose(0, 2, 1, 3)  # [B, H, S, D]


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def gqa_forward(
    p: Params,
    x: jnp.ndarray,  # [B, S, d_model]
    positions: jnp.ndarray,  # [S] or [B, S]
    n_heads: int,
    n_kv_heads: int,
    causal: bool = True,
    window: Optional[int] = None,
    rope_base: float = 10000.0,
    dtype=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence attention (train / prefill).  Returns (out, kv_cache)."""
    xd = x.astype(dtype)
    dp = ("pod", "data")
    q = constrain(_split_heads(xd @ p["wq"].astype(dtype), n_heads), dp, "model", None, None)
    k = constrain(_split_heads(xd @ p["wk"].astype(dtype), n_kv_heads), dp, "model", None, None)
    v = constrain(_split_heads(xd @ p["wv"].astype(dtype), n_kv_heads), dp, "model", None, None)
    if "q_norm" in p:
        q = rmsnorm({"g": p["q_norm"]}, q)
        k = rmsnorm({"g": p["k_norm"]}, k)
    q = rope(q, positions, rope_base)
    k = rope(k, positions, rope_base)
    o = constrain(_attend(q, k, v, causal, window), dp, "model", None, None)
    out = _merge_heads(o).astype(dtype) @ p["wo"].astype(dtype)
    return out, {"k": k, "v": v}


def gqa_decode(
    p: Params,
    x: jnp.ndarray,  # [B, 1, d_model]
    cache: Dict[str, jnp.ndarray],  # k/v: [B, Hkv, Smax, D]
    position: jnp.ndarray,  # [B] current absolute position
    n_heads: int,
    n_kv_heads: int,
    window: Optional[int] = None,
    rope_base: float = 10000.0,
    dtype=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token decode with in-place functional KV-cache update."""
    xd = x.astype(dtype)
    dp = ("pod", "data")
    q = constrain(_split_heads(xd @ p["wq"].astype(dtype), n_heads), dp, "model", None, None)
    k_new = constrain(_split_heads(xd @ p["wk"].astype(dtype), n_kv_heads), dp, "model", None, None)
    v_new = constrain(_split_heads(xd @ p["wv"].astype(dtype), n_kv_heads), dp, "model", None, None)
    if "q_norm" in p:
        q = rmsnorm({"g": p["q_norm"]}, q)
        k_new = rmsnorm({"g": p["k_norm"]}, k_new)
    q = rope(q, position[:, None], rope_base)
    k_new = rope(k_new, position[:, None], rope_base)
    kc = jax.vmap(
        lambda c, n, pos: jax.lax.dynamic_update_slice(c, n, (0, pos, 0))
    )(cache["k"], k_new, position)
    vc = jax.vmap(
        lambda c, n, pos: jax.lax.dynamic_update_slice(c, n, (0, pos, 0))
    )(cache["v"], v_new, position)
    kv_valid = position + 1
    o = _attend(q, kc, vc, causal=False, window=window, kv_valid=kv_valid)
    out = _merge_heads(o).astype(dtype) @ p["wo"].astype(dtype)
    return out, {"k": kc, "v": vc}


# ------------------------------------------------------------------- MLA
def mla_init(
    key,
    d_model: int,
    n_heads: int,
    kv_lora_rank: int,
    qk_nope_dim: int = 128,
    qk_rope_dim: int = 64,
    v_head_dim: int = 128,
) -> Params:
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d_model)
    sl = 1.0 / math.sqrt(kv_lora_rank)
    return {
        "wq": jax.random.normal(
            ks[0], (d_model, n_heads * (qk_nope_dim + qk_rope_dim)), jnp.float32
        ) * s,
        "w_dkv": jax.random.normal(ks[1], (d_model, kv_lora_rank), jnp.float32) * s,
        "w_krope": jax.random.normal(ks[2], (d_model, qk_rope_dim), jnp.float32) * s,
        "w_uk": jax.random.normal(
            ks[3], (kv_lora_rank, n_heads * qk_nope_dim), jnp.float32
        ) * sl,
        "w_uv": jax.random.normal(
            ks[4], (kv_lora_rank, n_heads * v_head_dim), jnp.float32
        ) * sl,
        "wo": jax.random.normal(
            ks[5], (n_heads * v_head_dim, d_model), jnp.float32
        ) / math.sqrt(n_heads * v_head_dim),
        "kv_norm": rmsnorm_init(kv_lora_rank)["g"],
    }


def mla_forward(
    p: Params,
    x: jnp.ndarray,  # [B, S, d]
    positions: jnp.ndarray,
    n_heads: int,
    qk_nope_dim: int = 128,
    qk_rope_dim: int = 64,
    v_head_dim: int = 128,
    causal: bool = True,
    dtype=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """MLA (DeepSeek-V2): latent-compressed KV + decoupled RoPE head.

    The cache stores only (c_kv [B,S,r], k_rope [B,S,dr]) — the paper's
    memory saving; here we up-project per step (no absorbed-weight trick)."""
    b, s, _ = x.shape
    xd = x.astype(dtype)
    q = xd @ p["wq"].astype(dtype)
    q = q.reshape(b, s, n_heads, qk_nope_dim + qk_rope_dim).transpose(0, 2, 1, 3)
    q = constrain(q, ("pod", "data"), "model", None, None)
    q_nope, q_rope = q[..., :qk_nope_dim], q[..., qk_nope_dim:]
    q_rope = rope(q_rope, positions)
    c_kv = rmsnorm({"g": p["kv_norm"]}, xd @ p["w_dkv"].astype(dtype))  # [B,S,r]
    k_rope = rope(
        (xd @ p["w_krope"].astype(dtype))[:, None], positions
    )  # [B,1,S,dr] shared head
    k_nope = constrain((c_kv @ p["w_uk"].astype(dtype)).reshape(
        b, s, n_heads, qk_nope_dim
    ).transpose(0, 2, 1, 3), ("pod", "data"), "model", None, None)
    v = constrain((c_kv @ p["w_uv"].astype(dtype)).reshape(
        b, s, n_heads, v_head_dim
    ).transpose(0, 2, 1, 3), ("pod", "data"), "model", None, None)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, n_heads, s, qk_rope_dim))], axis=-1
    )
    o = constrain(_attend(q_full, k_full, v, causal, None), ("pod", "data"), "model", None, None)
    out = _merge_heads(o).astype(dtype) @ p["wo"].astype(dtype)
    return out, {"c_kv": c_kv, "k_rope": k_rope[:, 0]}


def mla_decode(
    p: Params,
    x: jnp.ndarray,  # [B, 1, d]
    cache: Dict[str, jnp.ndarray],  # c_kv [B, Smax, r], k_rope [B, Smax, dr]
    position: jnp.ndarray,  # [B]
    n_heads: int,
    qk_nope_dim: int = 128,
    qk_rope_dim: int = 64,
    v_head_dim: int = 128,
    dtype=jnp.bfloat16,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    b = x.shape[0]
    xd = x.astype(dtype)
    q = xd @ p["wq"].astype(dtype)
    q = q.reshape(b, 1, n_heads, qk_nope_dim + qk_rope_dim).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :qk_nope_dim], q[..., qk_nope_dim:]
    q_rope = rope(q_rope, position[:, None])
    c_new = rmsnorm({"g": p["kv_norm"]}, xd @ p["w_dkv"].astype(dtype))  # [B,1,r]
    kr_new = rope((xd @ p["w_krope"].astype(dtype))[:, None], position[:, None])[
        :, 0
    ]  # [B,1,dr]
    c_kv = jax.vmap(lambda c, n, pos: jax.lax.dynamic_update_slice(c, n, (pos, 0)))(
        cache["c_kv"], c_new, position
    )
    k_rope = jax.vmap(lambda c, n, pos: jax.lax.dynamic_update_slice(c, n, (pos, 0)))(
        cache["k_rope"], kr_new, position
    )
    s_max = c_kv.shape[1]
    k_nope = constrain((c_kv @ p["w_uk"].astype(dtype)).reshape(
        b, s_max, n_heads, qk_nope_dim
    ).transpose(0, 2, 1, 3), ("pod", "data"), "model", None, None)
    v = constrain((c_kv @ p["w_uv"].astype(dtype)).reshape(
        b, s_max, n_heads, v_head_dim
    ).transpose(0, 2, 1, 3), ("pod", "data"), "model", None, None)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [
            k_nope,
            jnp.broadcast_to(
                k_rope[:, None], (b, n_heads, s_max, qk_rope_dim)
            ),
        ],
        axis=-1,
    )
    kv_valid = position + 1
    o = _attend(q_full, k_full, v, causal=False, window=None, kv_valid=kv_valid)
    out = _merge_heads(o).astype(dtype) @ p["wo"].astype(dtype)
    return out, {"c_kv": c_kv, "k_rope": k_rope}
