"""BST — Behavior Sequence Transformer for CTR (Alibaba, arXiv:1905.06874).

Assigned config: embed_dim=32, seq_len=20, 1 transformer block with 8 heads,
MLP 1024-512-256, sigmoid CTR head.  The user's behavior sequence (item +
category embeddings + learned position) and the target item pass through the
transformer; outputs concat into the MLP.

``retrieval_score`` scores one user state against N candidates as a single
batched dot product (``retrieval_cand`` shape; no loop).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..attention import _merge_heads, _split_heads
from ..layers import Params, layernorm, layernorm_init, mlp, mlp_init
from .embedding import lookup, table_init

__all__ = ["BSTSpec", "bst_init", "bst_forward", "bst_user_state", "retrieval_score"]


@dataclasses.dataclass(frozen=True)
class BSTSpec:
    n_items: int = 4_000_000
    n_cats: int = 10_000
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    mlp_dims: Tuple[int, ...] = (1024, 512, 256)
    dropout: float = 0.0  # inference default

    @property
    def d_tok(self) -> int:
        return 2 * self.embed_dim  # item ++ category


def bst_init(key, spec: BSTSpec) -> Params:
    d = spec.d_tok
    ks = jax.random.split(key, 6 + 4 * spec.n_blocks)
    p: Params = {
        "item_table": table_init(ks[0], spec.n_items, spec.embed_dim),
        "cat_table": table_init(ks[1], spec.n_cats, spec.embed_dim),
        "pos_embed": jax.random.normal(
            ks[2], (spec.seq_len + 1, d), jnp.float32
        ) * 0.02,
    }
    for i in range(spec.n_blocks):
        k_q, k_o, k_f, k_l = ks[3 + 4 * i : 7 + 4 * i]
        s = 1.0 / math.sqrt(d)
        p[f"blk{i}"] = {
            "wqkv": jax.random.normal(k_q, (d, 3 * d), jnp.float32) * s,
            "wo": jax.random.normal(k_o, (d, d), jnp.float32) * s,
            "ffn": mlp_init(k_f, (d, 4 * d, d)),
            "ln1": layernorm_init(d),
            "ln2": layernorm_init(d),
        }
    p["head"] = mlp_init(ks[-1], ((spec.seq_len + 1) * d,) + spec.mlp_dims + (1,))
    return p


def _encode_seq(p: Params, batch: Dict[str, jnp.ndarray], spec: BSTSpec, dtype):
    """[B, L+1, 2*embed] token matrix: history ++ target, with positions."""
    hi = lookup(p["item_table"], batch["hist_items"], dtype)  # [B, L, e]
    hc = lookup(p["cat_table"], batch["hist_cats"], dtype)
    ti = lookup(p["item_table"], batch["target_item"], dtype)  # [B, e]
    tc = lookup(p["cat_table"], batch["target_cat"], dtype)
    hist = jnp.concatenate([hi, hc], axis=-1)  # [B, L, d]
    targ = jnp.concatenate([ti, tc], axis=-1)[:, None]  # [B, 1, d]
    x = jnp.concatenate([hist, targ], axis=1)  # [B, L+1, d]
    return x + p["pos_embed"].astype(dtype)[None]


def _transformer(p: Params, x: jnp.ndarray, spec: BSTSpec, dtype) -> jnp.ndarray:
    for i in range(spec.n_blocks):
        blk = p[f"blk{i}"]
        h = layernorm(blk["ln1"], x)
        qkv = h.astype(dtype) @ blk["wqkv"].astype(dtype)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = _split_heads(q, spec.n_heads)
        k = _split_heads(k, spec.n_heads)
        v = _split_heads(v, spec.n_heads)
        s = jnp.einsum(
            "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
        ) * (q.shape[-1] ** -0.5)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", a, v.astype(jnp.float32)).astype(dtype)
        x = x + _merge_heads(o) @ blk["wo"].astype(dtype)
        h = layernorm(blk["ln2"], x)
        x = x + mlp(blk["ffn"], h, act=jax.nn.gelu, dtype=dtype)
    return x


def bst_forward(
    p: Params, batch: Dict[str, jnp.ndarray], spec: BSTSpec, dtype=jnp.bfloat16
) -> jnp.ndarray:
    """CTR logits [B]."""
    x = _encode_seq(p, batch, spec, dtype)
    x = _transformer(p, x, spec, dtype)
    flat = x.reshape(x.shape[0], -1)
    return mlp(p["head"], flat, act=jax.nn.relu, dtype=dtype)[:, 0].astype(jnp.float32)


def bst_user_state(
    p: Params, batch: Dict[str, jnp.ndarray], spec: BSTSpec, dtype=jnp.bfloat16
) -> jnp.ndarray:
    """User embedding for retrieval: mean-pooled transformer output over the
    history tokens, projected to embed_dim via the item table geometry."""
    hi = lookup(p["item_table"], batch["hist_items"], dtype)
    hc = lookup(p["cat_table"], batch["hist_cats"], dtype)
    hist = jnp.concatenate([hi, hc], axis=-1) + p["pos_embed"].astype(dtype)[None, :-1]
    x = _transformer(p, hist, spec, dtype)
    u = x.mean(axis=1)  # [B, d_tok]
    return u[..., : spec.embed_dim]  # align with item embedding space


def retrieval_score(
    p: Params,
    user: jnp.ndarray,  # [B, embed_dim]
    cand_ids: jnp.ndarray,  # [B, N] candidate item ids
    dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Batched dot-product scoring of N candidates per user (no loop)."""
    cand = lookup(p["item_table"], cand_ids, dtype)  # [B, N, e]
    return jnp.einsum("be,bne->bn", user.astype(jnp.float32), cand.astype(jnp.float32))
