"""Sparse embedding substrate for recsys: big tables + bag lookups.

JAX has no ``nn.EmbeddingBag`` — lookups are ``jnp.take`` and bag reduces are
``segment_sum``-style ops; the TPU hot path is ``kernels.ops.bag_lookup``
(vocab-tiled Pallas kernel).  Tables shard row-wise over the ``model`` mesh
axis; GeoLayer's DHD heat over row access frequencies decides which hot rows
get replicated (distributed/geo_sharding.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...kernels import ops

__all__ = ["table_init", "lookup", "bag_lookup"]


def table_init(key, vocab: int, dim: int, scale: float = 0.05) -> jnp.ndarray:
    return jax.random.normal(key, (vocab, dim), jnp.float32) * scale


def lookup(table: jnp.ndarray, ids: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Plain row gather (single-id fields)."""
    return table.astype(dtype)[ids]


def bag_lookup(
    table: jnp.ndarray,
    ids: jnp.ndarray,  # [B, L] multi-hot bags
    weights: Optional[jnp.ndarray] = None,
    mode: str = "sum",
    dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """EmbeddingBag via the kernel dispatcher (ref path on CPU)."""
    out = ops.bag_lookup(table, ids, weights, mode=mode)
    return out.astype(dtype)
