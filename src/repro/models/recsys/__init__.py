from . import bst, embedding  # noqa: F401
