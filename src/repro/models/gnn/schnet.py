"""SchNet — continuous-filter convolutions (arXiv:1706.08566).

Interaction block:  x_i += W_post( sum_j  W_pre(x_j) * F(rbf(||r_ij||)) )
with a 300-Gaussian radial basis over a 10 A cutoff and shifted-softplus
activations (assigned config: 3 interactions, d_hidden=64).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..layers import Params, mlp, mlp_init
from .common import masked_segment_sum, shard_ragged

__all__ = ["schnet_init", "schnet_forward", "gaussian_rbf"]


def ssp(x):  # shifted softplus (SchNet's activation)
    return jax.nn.softplus(x) - jnp.log(2.0)


def gaussian_rbf(d: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """[E] distances -> [E, n_rbf] Gaussian expansion on [0, cutoff]."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * (d[:, None] - centers[None, :]) ** 2)


def cosine_cutoff(d: jnp.ndarray, cutoff: float) -> jnp.ndarray:
    return jnp.where(d < cutoff, 0.5 * (jnp.cos(jnp.pi * d / cutoff) + 1.0), 0.0)


def schnet_init(
    key, n_species: int, d_hidden: int, n_interactions: int, n_rbf: int
) -> Params:
    keys = jax.random.split(key, 3 * n_interactions + 2)
    p: Params = {
        "embed": jax.random.normal(keys[0], (n_species, d_hidden), jnp.float32) * 0.1
    }
    for i in range(n_interactions):
        k_f, k_pre, k_post = keys[1 + 3 * i : 4 + 3 * i]
        p[f"filter{i}"] = mlp_init(k_f, (n_rbf, d_hidden, d_hidden))
        p[f"pre{i}"] = mlp_init(k_pre, (d_hidden, d_hidden))
        p[f"post{i}"] = mlp_init(k_post, (d_hidden, d_hidden, d_hidden))
    p["out"] = mlp_init(keys[-1], (d_hidden, d_hidden // 2, 1))
    return p


def schnet_forward(
    p: Params,
    batch: Dict[str, jnp.ndarray],
    n_interactions: int,
    n_rbf: int,
    cutoff: float,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """Returns per-node scalar contributions [N, 1] (sum-readout = energy)."""
    z = batch["x"].astype(jnp.int32)
    if z.ndim == 2:  # one-hot species given
        h = batch["x"].astype(dtype) @ p["embed"].astype(dtype)
    else:
        h = p["embed"].astype(dtype)[z]
    pos = batch["pos"].astype(dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch.get("edge_mask")
    n = h.shape[0]
    d = jnp.sqrt(((pos[dst] - pos[src]) ** 2).sum(-1) + 1e-12)
    rbf = gaussian_rbf(d, n_rbf, cutoff)
    env = cosine_cutoff(d, cutoff)[:, None]
    for i in range(n_interactions):
        w = mlp(p[f"filter{i}"], rbf, act=ssp, final_act=True, dtype=dtype) * env
        msg = shard_ragged(mlp(p[f"pre{i}"], h, act=ssp, dtype=dtype)[src] * w)
        agg = masked_segment_sum(msg, dst, n, emask)
        h = h + mlp(p[f"post{i}"], agg, act=ssp, dtype=dtype)
    return mlp(p["out"], h, act=ssp, dtype=dtype)
