"""Real Wigner-D rotations + real spherical harmonics for eSCN/EquiformerV2.

The eSCN trick (arXiv:2302.03655, used by EquiformerV2 arXiv:2306.12059):
rotate each edge's source irreps into a frame where the edge direction is
the z-axis; there the SO(3) tensor-product convolution reduces to per-|m|
SO(2) linear maps (O(L^3) instead of O(L^6)); rotate back after mixing.

We build the rotation D_real^l(R) for R = Rz(phi) @ Ry(theta) (which maps
z-hat onto the edge direction r-hat) from static coefficient tensors so the
per-edge evaluation is a handful of einsums over data-dependent angles:

  * small-d:  d^l(beta) = sum_p  A_l[..., p] * cos(beta/2)^(2l-p) sin(beta/2)^p
  * z-rot:    Dz^l(alpha) = sum_m cos(m alpha) Zc_l[m] + sin(m alpha) Zs_l[m]
  * D_real^l = Dz^l(phi) @ Dy^l(theta),   block-diagonal over l.

All coefficient tensors are computed once in NumPy (complex Wigner formula +
complex->real basis change U) and verified against the defining property
  sh_real(R v) = D_real(R) @ sh_real(v)
in tests/test_wigner.py.  Real SH here use the same U convention.
"""
from __future__ import annotations

import functools
import math
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "real_wigner_coeffs",
    "wigner_d_blocks",
    "rotate_irreps",
    "sh_real",
    "dir_to_angles",
    "irreps_dim",
]


def irreps_dim(l_max: int) -> int:
    return (l_max + 1) ** 2


@functools.lru_cache(maxsize=None)
def _u_matrix(l: int) -> np.ndarray:
    """Complex->real change of basis: sh_real = U @ sh_complex.

    Index order m = -l..l.  Convention: Y^r_{l,m>0} = sqrt2*(-1)^m Re Y_l^m,
    Y^r_{l,-m} = sqrt2*(-1)^m Im Y_l^m, Y^r_{l,0} = Y_l^0."""
    n = 2 * l + 1
    u = np.zeros((n, n), dtype=np.complex128)
    u[l, l] = 1.0
    for m in range(1, l + 1):
        cs = (-1.0) ** m
        u[l + m, l + m] = cs / math.sqrt(2)  # coeff of Y_l^{+m}
        u[l + m, l - m] = 1.0 / math.sqrt(2)  # coeff of Y_l^{-m}
        u[l - m, l + m] = cs / (1j * math.sqrt(2))
        u[l - m, l - m] = -1.0 / (1j * math.sqrt(2))
    return u


@functools.lru_cache(maxsize=None)
def _small_d_monomials(l: int) -> np.ndarray:
    """Complex small-d coefficients: d^l_{m'm}(b) = sum_p C[m'+l, m+l, p]
    cos(b/2)^(2l-p) sin(b/2)^p  (Wigner's formula)."""
    n = 2 * l + 1
    c = np.zeros((n, n, 2 * l + 1), dtype=np.float64)
    f = [math.factorial(i) for i in range(2 * l + 1)]
    for mp in range(-l, l + 1):
        for m in range(-l, l + 1):
            pref = math.sqrt(
                f[l + m] * f[l - m] * f[l + mp] * f[l - mp]
            )
            for k in range(0, 2 * l + 1):
                a1 = l + m - k
                a2 = k
                a3 = l - k - mp
                a4 = k - m + mp
                if min(a1, a2, a3, a4) < 0:
                    continue
                p = 2 * k - m + mp  # sin exponent
                coeff = (-1.0) ** k * pref / (f[a1] * f[a2] * f[a3] * f[a4])
                c[mp + l, m + l, p] += coeff
    return c


@functools.lru_cache(maxsize=None)
def real_wigner_coeffs(l: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(A, Zc, Zs) static tensors for degree l:

    A  [2l+1, 2l+1, 2l+1] — real small-d monomial coefficients
    Zc [l+1, 2l+1, 2l+1]  — cos(m*alpha) terms of the real z-rotation
    Zs [l+1, 2l+1, 2l+1]  — sin(m*alpha) terms
    """
    u = _u_matrix(l)
    uh = u.conj().T
    cmono = _small_d_monomials(l)
    n = 2 * l + 1
    a = np.zeros_like(cmono)
    for p in range(2 * l + 1):
        m = u @ cmono[:, :, p] @ uh
        assert np.abs(m.imag).max() < 1e-10
        a[:, :, p] = m.real
    zc = np.zeros((l + 1, n, n))
    zs = np.zeros((l + 1, n, n))
    ms = np.arange(-l, l + 1)
    for m0 in range(l + 1):
        cdiag = np.diag((np.abs(ms) == m0).astype(np.complex128))
        sdiag = np.diag(np.where(np.abs(ms) == m0, np.sign(ms), 0).astype(np.complex128))
        zc_m = u @ cdiag @ uh
        zs_m = -1j * (u @ sdiag @ uh)
        assert np.abs(zc_m.imag).max() < 1e-10
        assert np.abs(zs_m.imag).max() < 1e-10
        zc[m0] = zc_m.real
        zs[m0] = zs_m.real
    return a, zc, zs


def dir_to_angles(vec: jnp.ndarray, eps: float = 1e-9) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unit-ish vectors [..., 3] -> (theta polar-from-z, phi azimuth)."""
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    r = jnp.sqrt(x * x + y * y + z * z + eps)
    theta = jnp.arccos(jnp.clip(z / r, -1.0 + 1e-7, 1.0 - 1e-7))
    phi = jnp.arctan2(y, x)
    return theta, phi


def wigner_d_blocks(
    l_max: int, theta: jnp.ndarray, phi: jnp.ndarray
) -> List[jnp.ndarray]:
    """Per-l real rotation matrices D_real^l(Rz(phi) Ry(theta)), each
    [..., 2l+1, 2l+1].  The rotation maps z-hat to the (theta, phi) direction;
    apply the transpose to bring features *into* the edge frame."""
    c = jnp.cos(theta / 2.0)
    s = jnp.sin(theta / 2.0)
    blocks = []
    for l in range(l_max + 1):
        a_np, zc_np, zs_np = real_wigner_coeffs(l)
        a = jnp.asarray(a_np, jnp.float32)
        zc = jnp.asarray(zc_np, jnp.float32)
        zs = jnp.asarray(zs_np, jnp.float32)
        p = jnp.arange(2 * l + 1)
        mono = c[..., None] ** (2 * l - p) * s[..., None] ** p  # [..., 2l+1]
        # the raw U-conjugated factors come out as D(R^-1) = D(R)^T in this
        # convention (verified against l=1 3x3 rotations) -> transpose each.
        dy = jnp.einsum("...p,nmp->...mn", mono, a)
        m0 = jnp.arange(l + 1, dtype=jnp.float32)
        cosm = jnp.cos(m0 * phi[..., None])  # [..., l+1]
        sinm = jnp.sin(m0 * phi[..., None])
        dz = jnp.einsum("...m,mji->...ij", cosm, zc) + jnp.einsum(
            "...m,mji->...ij", sinm, zs
        )
        blocks.append(jnp.einsum("...ij,...jk->...ik", dz, dy))
    return blocks


def rotate_irreps(
    feats: jnp.ndarray,  # [..., (l_max+1)^2, C]
    blocks: List[jnp.ndarray],  # per-l [..., 2l+1, 2l+1]
    transpose: bool = False,
) -> jnp.ndarray:
    """Apply the block-diagonal rotation to irreps features."""
    out = []
    off = 0
    for l, d in enumerate(blocks):
        n = 2 * l + 1
        seg = feats[..., off : off + n, :]
        eq = "...ji,...jc->...ic" if transpose else "...ij,...jc->...ic"
        out.append(jnp.einsum(eq, d, seg))
        off += n
    return jnp.concatenate(out, axis=-2)


# ----------------------------------------------------- real SH (same basis)
def sh_real(l_max: int, vec: jnp.ndarray) -> jnp.ndarray:
    """Real spherical harmonics [..., (l_max+1)^2] in the U-matrix basis
    (m = -l..l per l), evaluated via associated-Legendre recursion."""
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    r = jnp.sqrt(x * x + y * y + z * z + 1e-12)
    ct = z / r
    st = jnp.sqrt(jnp.clip(1.0 - ct * ct, 0.0, 1.0))
    phi = jnp.arctan2(y, x)
    # P_l^m with Condon-Shortley, m >= 0
    plm = {}
    plm[(0, 0)] = jnp.ones_like(ct)
    for m in range(1, l_max + 1):
        plm[(m, m)] = (
            (-1.0) ** m
            * float(np.prod(np.arange(1, 2 * m, 2)))
            * st ** m
        )
    for m in range(0, l_max):
        plm[(m + 1, m)] = (2 * m + 1) * ct * plm[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            plm[(l, m)] = (
                (2 * l - 1) * ct * plm[(l - 1, m)] - (l + m - 1) * plm[(l - 2, m)]
            ) / (l - m)
    comps = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            nlm = math.sqrt(
                (2 * l + 1)
                / (4 * math.pi)
                * math.factorial(l - am)
                / math.factorial(l + am)
            )
            # complex Y_l^m = N P_l^m e^{imphi}; real basis via U:
            # m>0: sqrt2*(-1)^m Re Y = sqrt2*(-1)^m N P cos(m phi)
            # m<0: sqrt2*(-1)^m Im Y_l^{|m|} = sqrt2*(-1)^m N P sin(|m| phi)
            if m == 0:
                comps.append(nlm * plm[(l, 0)])
            elif m > 0:
                comps.append(
                    math.sqrt(2) * (-1.0) ** m * nlm * plm[(l, m)] * jnp.cos(m * phi)
                )
            else:
                comps.append(
                    math.sqrt(2) * (-1.0) ** am * nlm * plm[(l, am)] * jnp.sin(am * phi)
                )
    return jnp.stack(comps, axis=-1)
