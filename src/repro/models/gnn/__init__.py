from . import common, egnn, equiformer_v2, meshgraphnet, schnet, wigner  # noqa: F401
