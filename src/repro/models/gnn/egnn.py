"""EGNN — E(n)-equivariant GNN (Satorras et al., arXiv:2102.09844).

Per layer:
    m_ij  = phi_e(h_i, h_j, ||x_i - x_j||^2, a_ij)
    x_i'  = x_i + C * sum_j (x_i - x_j) * phi_x(m_ij)
    h_i'  = phi_h(h_i, sum_j m_ij)
Scalar-distance conditioning keeps full E(n) equivariance without spherical
harmonics.  4 layers, d_hidden=64 (assigned config).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..layers import Params, mlp, mlp_init
from .common import masked_segment_mean, masked_segment_sum, shard_ragged

__all__ = ["egnn_init", "egnn_forward"]


def egnn_init(key, d_in: int, d_hidden: int, n_layers: int, d_edge: int = 0) -> Params:
    keys = jax.random.split(key, n_layers * 3 + 2)
    p: Params = {"enc": mlp_init(keys[0], (d_in, d_hidden))}
    for i in range(n_layers):
        k_e, k_x, k_h = keys[1 + 3 * i : 4 + 3 * i]
        p[f"phi_e{i}"] = mlp_init(k_e, (2 * d_hidden + 1 + d_edge, d_hidden, d_hidden))
        p[f"phi_x{i}"] = mlp_init(k_x, (d_hidden, d_hidden, 1))
        p[f"phi_h{i}"] = mlp_init(k_h, (2 * d_hidden, d_hidden, d_hidden))
    p["dec"] = mlp_init(keys[-1], (d_hidden, d_hidden, 1))
    return p


def egnn_forward(
    p: Params,
    batch: Dict[str, jnp.ndarray],
    n_layers: int,
    dtype=jnp.float32,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (node embeddings [N, d], updated coords [N, 3])."""
    x = batch["pos"].astype(dtype)
    h = mlp(p["enc"], batch["x"].astype(dtype), dtype=dtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch.get("edge_mask")
    n = h.shape[0]
    for i in range(n_layers):
        xi, xj = x[dst], x[src]
        diff = xi - xj
        d2 = (diff * diff).sum(-1, keepdims=True)
        feats = [h[dst], h[src], d2]
        if "edge_attr" in batch:
            feats.append(batch["edge_attr"].astype(dtype))
        m = shard_ragged(mlp(p[f"phi_e{i}"], jnp.concatenate(feats, -1), dtype=dtype))
        w = mlp(p[f"phi_x{i}"], m, dtype=dtype)  # [E, 1]
        # mean-normalized coordinate update (C = 1/deg), E(n)-equivariant
        x = x + masked_segment_mean(diff * w, dst, n, emask)
        agg = masked_segment_sum(m, dst, n, emask)
        h = h + mlp(p[f"phi_h{i}"], jnp.concatenate([h, agg], -1), dtype=dtype)
    return h, x
