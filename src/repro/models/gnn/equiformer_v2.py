"""EquiformerV2 — equivariant graph attention via eSCN SO(2) convolutions
(arXiv:2306.12059; eSCN trick arXiv:2302.03655).

Per attention layer, for each edge (src -> dst):
  1. rotate source-node irreps [dim(l_max), C] into the edge frame with the
     real Wigner-D transpose (``wigner.py``, validated to l_max=6);
  2. truncate to |m| <= m_max coefficients (the eSCN O(L^3) reduction);
  3. SO(2) linear maps per |m| — joint (l, channel) mixing; for m>0 the
     (+m, -m) pair mixes with the rotation-structured (W1, W2) pair;
     radially-conditioned channel gates (RBF -> MLP) modulate the message;
  4. per-head attention logits from the invariant (m=0) block,
     segment-softmax over each destination's incoming edges;
  5. rotate messages back to the global frame and aggregate.
FFN is the gated equivariant MLP (l=0 scalars gate all l).  Layers run under
``lax.scan`` over stacked params.

Deviation noted (DESIGN §9): radial conditioning multiplies per-channel
gates rather than modulating the full SO(2) weight matrices (memory-lean,
same dataflow class).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from ..layers import Params, mlp, mlp_init
from .common import masked_segment_sum, shard_ragged
from .schnet import gaussian_rbf
from .wigner import dir_to_angles, irreps_dim, rotate_irreps, wigner_d_blocks

__all__ = ["EqV2Spec", "eqv2_init", "eqv2_forward"]


@dataclasses.dataclass(frozen=True)
class EqV2Spec:
    n_layers: int = 12
    channels: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 32
    cutoff: float = 8.0
    n_species: int = 32

    @property
    def dim(self) -> int:
        return irreps_dim(self.l_max)

    def m_indices(self) -> Dict[int, Dict[str, np.ndarray]]:
        """Static index maps: for each |m| <= m_max the irreps positions of
        the +m and -m components across l (edge-frame truncated set)."""
        out = {}
        for m in range(self.m_max + 1):
            plus, minus = [], []
            for l in range(m, self.l_max + 1):
                base = l * l  # start of degree-l block
                plus.append(base + l + m)
                minus.append(base + l - m)
            out[m] = {
                "plus": np.asarray(plus, np.int32),
                "minus": np.asarray(minus, np.int32),
            }
        return out


def _so2_init(key, spec: EqV2Spec) -> Params:
    p: Params = {}
    c = spec.channels
    ks = jax.random.split(key, 2 * (spec.m_max + 1))
    for m in range(spec.m_max + 1):
        n_l = spec.l_max + 1 - m
        dim = n_l * c
        s = 1.0 / math.sqrt(dim)
        p[f"w1_{m}"] = jax.random.normal(ks[2 * m], (dim, dim), jnp.float32) * s
        if m > 0:
            p[f"w2_{m}"] = jax.random.normal(ks[2 * m + 1], (dim, dim), jnp.float32) * s
    return p


def _layer_init(key, spec: EqV2Spec) -> Params:
    k_so2, k_rad, k_attn, k_out, k_ffn_g, k_ffn_m = jax.random.split(key, 6)
    c = spec.channels
    return {
        "so2": _so2_init(k_so2, spec),
        "radial": mlp_init(k_rad, (spec.n_rbf, c, c)),
        "attn": mlp_init(k_attn, (c, c, spec.n_heads)),
        "out": jax.random.normal(k_out, (spec.l_max + 1, c, c), jnp.float32)
        / math.sqrt(c),
        "ffn_gate": mlp_init(k_ffn_g, (c, 2 * c, (spec.l_max + 1) * c)),
        "ffn_mix": jax.random.normal(k_ffn_m, (spec.l_max + 1, c, c), jnp.float32)
        / math.sqrt(c),
        "ln_scale": jnp.ones((spec.l_max + 1, c), jnp.float32),
    }


def eqv2_init(key, spec: EqV2Spec, d_out: int = 1) -> Params:
    k_emb, k_layers, k_dec = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, spec.n_layers)
    return {
        "embed": jax.random.normal(
            k_emb, (spec.n_species, spec.channels), jnp.float32
        ) * 0.1,
        "layers": jax.vmap(lambda k: _layer_init(k, spec))(layer_keys),
        "dec": mlp_init(k_dec, (spec.channels, spec.channels, d_out)),
    }


def _equiv_layernorm(x: jnp.ndarray, scale: jnp.ndarray, spec: EqV2Spec) -> jnp.ndarray:
    """Norm over each degree-l block (rotation-invariant RMS), per-channel scale."""
    out = []
    for l in range(spec.l_max + 1):
        seg = x[:, l * l : (l + 1) * (l + 1), :]
        rms = jnp.sqrt(jnp.mean(seg * seg, axis=(1, 2), keepdims=True) + 1e-6)
        out.append(seg / rms * scale[l][None, None, :])
    return jnp.concatenate(out, axis=1)


def _so2_conv(
    msg_tr: jnp.ndarray,  # [E, dim_tr, C] edge-frame truncated features
    so2: Params,
    spec: EqV2Spec,
    tr_index: Dict[int, Dict[str, np.ndarray]],
    tr_pos: Dict[int, Dict[str, np.ndarray]],
) -> jnp.ndarray:
    """Per-|m| SO(2) linear maps in the edge frame (joint l-channel mixing)."""
    e = msg_tr.shape[0]
    c = spec.channels
    out = jnp.zeros_like(msg_tr)
    for m in range(spec.m_max + 1):
        pp = tr_pos[m]["plus"]
        mm = tr_pos[m]["minus"]
        n_l = len(pp)
        xp = msg_tr[:, pp, :].reshape(e, n_l * c)
        w1 = so2[f"w1_{m}"]
        if m == 0:
            yp = xp @ w1
            out = out.at[:, pp, :].set(yp.reshape(e, n_l, c))
        else:
            xm = msg_tr[:, mm, :].reshape(e, n_l * c)
            w2 = so2[f"w2_{m}"]
            yp = xp @ w1 - xm @ w2
            ym = xp @ w2 + xm @ w1
            out = out.at[:, pp, :].set(yp.reshape(e, n_l, c))
            out = out.at[:, mm, :].set(ym.reshape(e, n_l, c))
    return out


def prepare_geometry(batch: Dict[str, jnp.ndarray], spec: EqV2Spec, dtype=jnp.float32):
    """Edge frames, radial features, truncation index maps (static per graph)."""
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch.get("edge_mask")
    pos = batch["pos"].astype(dtype)
    vec = pos[dst] - pos[src]
    d2 = (vec * vec).sum(-1)
    dist = jnp.sqrt(d2 + 1e-9)
    # zero-length edges (self-loops, padding) have no direction -> no frame;
    # they MUST be masked or equivariance breaks (frame fixed, features rotate).
    # Mask on the raw squared distance (the eps floor in `dist` would leak).
    directed = d2 > 1e-8
    emask = directed if emask is None else (emask & directed)
    theta, phi = dir_to_angles(vec)
    blocks = wigner_d_blocks(spec.l_max, theta, phi)  # per-l [E, 2l+1, 2l+1]
    rbf = gaussian_rbf(dist, spec.n_rbf, spec.cutoff)

    # truncated-index bookkeeping: positions of each (l, +-m) in the full
    # irreps vector and in the truncated edge-frame vector
    m_idx = spec.m_indices()
    tr_list: List[int] = []
    tr_pos: Dict[int, Dict[str, np.ndarray]] = {}
    for m in range(spec.m_max + 1):
        d_ = {}
        for sgn in ("plus", "minus"):
            ids = m_idx[m][sgn]
            posn = []
            for i in ids:
                if int(i) not in tr_list:
                    tr_list.append(int(i))
                posn.append(tr_list.index(int(i)))
            d_[sgn] = np.asarray(posn, np.int32)
        tr_pos[m] = d_
    tr_arr = jnp.asarray(np.asarray(tr_list, np.int32))
    return dict(
        src=src, dst=dst, emask=emask, blocks=blocks, rbf=rbf,
        m_idx=m_idx, tr_pos=tr_pos, tr_arr=tr_arr,
    )


def layer_apply(
    x: jnp.ndarray,  # [N, dim, C]
    lp: Params,
    geom: Dict,
    spec: EqV2Spec,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """One EquiformerV2 block (eSCN attention + gated FFN)."""
    src, dst, emask = geom["src"], geom["dst"], geom["emask"]
    blocks, rbf = geom["blocks"], geom["rbf"]
    m_idx, tr_pos, tr_arr = geom["m_idx"], geom["tr_pos"], geom["tr_arr"]
    n, _, c = x.shape
    h = _equiv_layernorm(x, lp["ln_scale"], spec)
    # --- eSCN attention ---
    feat_e = shard_ragged(h[src] + h[dst])  # [E, dim, C]
    feat_rot = rotate_irreps(feat_e, blocks, transpose=True)  # edge frame
    feat_tr = shard_ragged(feat_rot[:, tr_arr, :])  # truncate |m| <= m_max
    msg = shard_ragged(_so2_conv(feat_tr, lp["so2"], spec, m_idx, tr_pos))
    gate = mlp(lp["radial"], rbf, dtype=dtype)  # [E, C]
    msg = msg * jax.nn.sigmoid(gate)[:, None, :]
    # attention logits from invariant (l=0) block
    inv = msg[:, tr_pos[0]["plus"][0], :]  # [E, C] (l=0, m=0)
    logits = mlp(lp["attn"], inv, dtype=dtype)  # [E, H]
    logits = jnp.where(emask[:, None], logits, -1e30)
    lmax_ = jax.ops.segment_max(logits, dst, num_segments=n)
    expd = jnp.exp(logits - jnp.maximum(lmax_, -1e29)[dst])
    expd = jnp.where(emask[:, None], expd, 0.0)
    denom = jax.ops.segment_sum(expd, dst, num_segments=n)
    alpha = expd / jnp.maximum(denom[dst], 1e-9)  # [E, H]
    # back to full irreps + global frame
    full = jnp.zeros((msg.shape[0], spec.dim, c), dtype)
    full = full.at[:, tr_arr, :].set(msg)
    full = shard_ragged(rotate_irreps(full, blocks))  # rotate back
    # heads act on channel groups
    hc = c // spec.n_heads
    full = full.reshape(-1, spec.dim, spec.n_heads, hc)
    weighted = full * alpha[:, None, :, None]
    weighted = weighted.reshape(-1, spec.dim, c)
    agg = masked_segment_sum(weighted, dst, n, emask)  # [N, dim, C]
    # per-l output projection
    outs = []
    for l in range(spec.l_max + 1):
        seg = agg[:, l * l : (l + 1) * (l + 1), :]
        outs.append(jnp.einsum("nmc,cd->nmd", seg, lp["out"][l]))
    x = x + jnp.concatenate(outs, axis=1)
    # --- gated equivariant FFN ---
    h = _equiv_layernorm(x, lp["ln_scale"], spec)
    scal = h[:, 0, :]
    gates = mlp(lp["ffn_gate"], scal, dtype=dtype).reshape(n, spec.l_max + 1, c)
    outs = []
    for l in range(spec.l_max + 1):
        seg = h[:, l * l : (l + 1) * (l + 1), :]
        mixed = jnp.einsum("nmc,cd->nmd", seg, lp["ffn_mix"][l])
        g = jax.nn.sigmoid(gates[:, l])[:, None, :]
        outs.append(mixed * g)
    return x + jnp.concatenate(outs, axis=1)


def layer_apply_chunked(
    x: jnp.ndarray,
    lp: Params,
    batch: Dict[str, jnp.ndarray],
    spec: EqV2Spec,
    n_chunks: int,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """Edge-chunked eSCN attention: ``lax.scan`` over edge chunks with an
    online softmax per (node, head) — flash-attention over segments.  Peak
    memory is O(E/n_chunks * dim_tr * C) instead of O(E * dim * C), which is
    what makes 62M-edge graphs (ogb_products) lower within HBM.

    NB: XLA costs scan bodies once; the dry-run corrects flops by n_chunks.
    """
    src_all, dst_all = batch["edge_src"], batch["edge_dst"]
    emask_all = batch.get("edge_mask")
    pos = batch["pos"].astype(dtype)
    n, _, c = x.shape
    e_total = src_all.shape[0]
    ec = e_total // n_chunks
    assert e_total % n_chunks == 0
    m_idx = spec.m_indices()
    tr_list: List[int] = []
    tr_pos: Dict[int, Dict[str, np.ndarray]] = {}
    for m in range(spec.m_max + 1):
        d_ = {}
        for sgn in ("plus", "minus"):
            ids = m_idx[m][sgn]
            posn = []
            for i in ids:
                if int(i) not in tr_list:
                    tr_list.append(int(i))
                posn.append(tr_list.index(int(i)))
            d_[sgn] = np.asarray(posn, np.int32)
        tr_pos[m] = d_
    tr_arr = jnp.asarray(np.asarray(tr_list, np.int32))
    h_in = _equiv_layernorm(x, lp["ln_scale"], spec)
    hc = c // spec.n_heads

    def chunk(carry, ic):
        m_run, d_run, acc = carry  # [N,H], [N,H], [N,dim,C]
        sl = lambda a: shard_ragged(jax.lax.dynamic_slice_in_dim(a, ic * ec, ec, 0))
        src, dst = sl(src_all), sl(dst_all)
        emask = sl(emask_all) if emask_all is not None else None
        vec = shard_ragged(pos[dst] - pos[src])
        d2 = (vec * vec).sum(-1)
        dist = jnp.sqrt(d2 + 1e-9)
        directed = d2 > 1e-8
        emask = directed if emask is None else (emask & directed)
        theta, phi = dir_to_angles(vec)
        blocks = wigner_d_blocks(spec.l_max, theta, phi)
        rbf = gaussian_rbf(dist, spec.n_rbf, spec.cutoff)
        feat_e = shard_ragged(h_in[src] + h_in[dst])
        feat_tr = shard_ragged(rotate_irreps(feat_e, blocks, transpose=True)[:, tr_arr, :])
        msg = shard_ragged(_so2_conv(feat_tr, lp["so2"], spec, m_idx, tr_pos))
        gate = mlp(lp["radial"], rbf, dtype=dtype)
        msg = msg * jax.nn.sigmoid(gate)[:, None, :]
        inv = msg[:, tr_pos[0]["plus"][0], :]
        logits = mlp(lp["attn"], inv, dtype=dtype)  # [Ec, H]
        logits = jnp.where(emask[:, None], logits, -1e30)
        full = jnp.zeros((ec, spec.dim, c), dtype).at[:, tr_arr, :].set(msg)
        full = shard_ragged(rotate_irreps(full, blocks))
        # online softmax update per (dst node, head)
        m_chunk = jax.ops.segment_max(logits, dst, num_segments=n)
        m_new = jnp.maximum(m_run, jnp.maximum(m_chunk, -1e30))
        corr = jnp.exp(jnp.clip(m_run - m_new, -60.0, 0.0))  # [N,H]
        w = jnp.exp(jnp.clip(logits - m_new[dst], -60.0, 0.0))
        w = jnp.where(emask[:, None], w, 0.0)
        d_new = d_run * corr + jax.ops.segment_sum(w, dst, num_segments=n)
        fullh = full.reshape(ec, spec.dim, spec.n_heads, hc)
        contrib = jax.ops.segment_sum(
            fullh * w[:, None, :, None], dst, num_segments=n
        )
        acc_new = (
            acc.reshape(n, spec.dim, spec.n_heads, hc) * corr[:, None, :, None]
            + contrib
        ).reshape(n, spec.dim, c)
        return (m_new, d_new, acc_new), None

    m0 = jnp.full((n, spec.n_heads), -1e30, dtype)
    d0 = jnp.zeros((n, spec.n_heads), dtype)
    a0 = jnp.zeros((n, spec.dim, c), dtype)
    (m_f, d_f, acc), _ = jax.lax.scan(chunk, (m0, d0, a0), jnp.arange(n_chunks))
    denom = jnp.maximum(d_f, 1e-9)[:, None, :, None]
    agg = (acc.reshape(n, spec.dim, spec.n_heads, hc) / denom).reshape(n, spec.dim, c)
    outs = []
    for l in range(spec.l_max + 1):
        seg = agg[:, l * l : (l + 1) * (l + 1), :]
        outs.append(jnp.einsum("nmc,cd->nmd", seg, lp["out"][l]))
    x = x + jnp.concatenate(outs, axis=1)
    # gated FFN (same as layer_apply)
    h = _equiv_layernorm(x, lp["ln_scale"], spec)
    scal = h[:, 0, :]
    gates = mlp(lp["ffn_gate"], scal, dtype=dtype).reshape(n, spec.l_max + 1, c)
    outs = []
    for l in range(spec.l_max + 1):
        seg = h[:, l * l : (l + 1) * (l + 1), :]
        mixed = jnp.einsum("nmc,cd->nmd", seg, lp["ffn_mix"][l])
        g = jax.nn.sigmoid(gates[:, l])[:, None, :]
        outs.append(mixed * g)
    return x + jnp.concatenate(outs, axis=1)


def eqv2_forward(
    p: Params,
    batch: Dict[str, jnp.ndarray],
    spec: EqV2Spec,
    dtype=jnp.float32,
    edge_chunks: int = 1,
    unroll_layers: bool = False,
) -> jnp.ndarray:
    """Returns per-node invariant outputs [N, d_out]."""
    z = batch["x"]
    if z.ndim == 2:
        s0 = batch["x"].astype(dtype) @ p["embed"].astype(dtype)
    else:
        s0 = p["embed"].astype(dtype)[z.astype(jnp.int32)]
    n = s0.shape[0]
    x = jnp.zeros((n, spec.dim, spec.channels), dtype).at[:, 0, :].set(s0)
    if edge_chunks > 1:
        for i in range(spec.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], p["layers"])
            x = layer_apply_chunked(x, lp, batch, spec, edge_chunks, dtype)
    elif unroll_layers:
        geom = prepare_geometry(batch, spec, dtype)
        for i in range(spec.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], p["layers"])
            x = layer_apply(x, lp, geom, spec, dtype)
    else:
        geom = prepare_geometry(batch, spec, dtype)

        def layer(x, lp):
            return layer_apply(x, lp, geom, spec, dtype), None

        x, _ = jax.lax.scan(layer, x, p["layers"])
    return mlp(p["dec"], x[:, 0, :], dtype=dtype)
