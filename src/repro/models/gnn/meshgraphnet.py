"""MeshGraphNet — encode/process/decode mesh simulator (arXiv:2010.03409).

15 processor steps (assigned config), d_hidden=128, 2-layer MLPs with
LayerNorm, sum aggregation, residual node+edge updates.  The processor loop
runs under ``lax.scan`` over stacked per-step params (depth-independent HLO).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..layers import Params, layernorm, layernorm_init, mlp, mlp_init
from .common import masked_segment_sum, shard_ragged

__all__ = ["mgn_init", "mgn_forward"]


def _block_init(key, dims):
    k1 = jax.random.split(key, 1)[0]
    return {"mlp": mlp_init(k1, dims), "ln": layernorm_init(dims[-1])}


def _block(p, x, dtype):
    return layernorm(p["ln"], mlp(p["mlp"], x, dtype=dtype))


def mgn_init(
    key,
    d_node_in: int,
    d_edge_in: int,
    d_hidden: int,
    n_steps: int,
    d_out: int,
    mlp_layers: int = 2,
) -> Params:
    hid = tuple([d_hidden] * mlp_layers)
    k_ne, k_ee, k_proc, k_dec = jax.random.split(key, 4)
    step_keys = jax.random.split(k_proc, n_steps)

    def step_init(k):
        k_e, k_n = jax.random.split(k)
        return {
            "edge": _block_init(k_e, (3 * d_hidden,) + hid),
            "node": _block_init(k_n, (2 * d_hidden,) + hid),
        }

    return {
        "enc_node": _block_init(k_ne, (d_node_in,) + hid),
        "enc_edge": _block_init(k_ee, (d_edge_in,) + hid),
        "steps": jax.vmap(step_init)(step_keys),
        "dec": mlp_init(k_dec, (d_hidden,) + hid[:-1] + (d_out,)),
    }


def mgn_forward(
    p: Params, batch: Dict[str, jnp.ndarray], dtype=jnp.float32
) -> jnp.ndarray:
    """Returns per-node outputs [N, d_out]."""
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask = batch.get("edge_mask")
    n = batch["x"].shape[0]
    h = _block(p["enc_node"], batch["x"].astype(dtype), dtype)
    e = _block(p["enc_edge"], batch["edge_attr"].astype(dtype), dtype)

    def step(carry, sp):
        h, e = carry
        e_new = shard_ragged(e + _block(sp["edge"], jnp.concatenate([e, h[src], h[dst]], -1), dtype))
        agg = masked_segment_sum(e_new, dst, n, emask)
        h_new = h + _block(sp["node"], jnp.concatenate([h, agg], -1), dtype)
        return (h_new, e_new), None

    (h, e), _ = jax.lax.scan(step, (h, e), p["steps"])
    return mlp(p["dec"], h, dtype=dtype)
