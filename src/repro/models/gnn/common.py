"""Shared GNN machinery: masked segment ops over edge lists + batch format.

JAX sparse is BCOO-only, so message passing is implemented as
gather (``x[edge_src]``) -> edge compute -> ``segment_sum``/``segment_max``
scatter back to nodes — this IS the system's sparse substrate
(kernel_taxonomy §GNN).  All shapes static; padding controlled by masks.

Canonical batch (flat disjoint-union layout, works for single large graphs
and batched molecules alike):
    x          [N, F]   node features        node_mask  [N]
    pos        [N, 3]   (geometric models)   edge_mask  [E]
    edge_src   [E]      edge_dst [E]         edge_attr  [E, Fe] (optional)
    graph_id   [N]      graph membership for readout (zeros if one graph)
    labels     [N] or [G] target
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "masked_segment_sum",
    "masked_segment_mean",
    "masked_segment_max",
    "gather_src_dst",
    "graph_readout",
    "shard_ragged",
]


def shard_ragged(x: jnp.ndarray) -> jnp.ndarray:
    """Pin the leading (node/edge) axis to the full mesh — SPMD loses the
    sharding through gathers/slices and would otherwise replicate per-edge
    message tensors (mesh-size memory blowup on 60M-edge graphs)."""
    from ...distributed.constraints import constrain

    return constrain(x, ("pod", "data", "model"), *([None] * (x.ndim - 1)))


def masked_segment_sum(
    data: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    if mask is not None:
        data = jnp.where(mask.reshape(mask.shape + (1,) * (data.ndim - 1)), data, 0)
    data = shard_ragged(data)
    return shard_ragged(jax.ops.segment_sum(data, segment_ids, num_segments=num_segments))


def masked_segment_mean(
    data: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    s = masked_segment_sum(data, segment_ids, num_segments, mask)
    ones = jnp.ones(data.shape[0], data.dtype) if mask is None else mask.astype(data.dtype)
    cnt = jax.ops.segment_sum(ones, segment_ids, num_segments=num_segments)
    return s / jnp.maximum(cnt, 1.0).reshape(cnt.shape + (1,) * (data.ndim - 1))


def masked_segment_max(
    data: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int,
    mask: Optional[jnp.ndarray] = None, neg: float = -1e30,
) -> jnp.ndarray:
    if mask is not None:
        data = jnp.where(mask.reshape(mask.shape + (1,) * (data.ndim - 1)), data, neg)
    out = jax.ops.segment_max(data, segment_ids, num_segments=num_segments)
    return jnp.maximum(out, neg)  # empty segments -> neg floor


def gather_src_dst(x: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray):
    return x[src], x[dst]


def graph_readout(
    h: jnp.ndarray,  # [N, F]
    graph_id: jnp.ndarray,  # [N]
    n_graphs: int,
    node_mask: Optional[jnp.ndarray] = None,
    mode: str = "sum",
) -> jnp.ndarray:
    if mode == "sum":
        return masked_segment_sum(h, graph_id, n_graphs, node_mask)
    if mode == "mean":
        return masked_segment_mean(h, graph_id, n_graphs, node_mask)
    raise ValueError(mode)
