from . import attention, layers, moe, transformer  # noqa: F401
from . import gnn, recsys  # noqa: F401
