"""ShardedGeoGraphStore — the multi-shard data plane over a jax device mesh.

One :class:`~repro.core.store.GeoGraphStore` becomes per-DC **store shards**
laid over a jax device mesh (the mesh-as-geo mapping of
:mod:`repro.distributed.geo_sharding`: shards = DCs, ICI/DCN = WAN tiers).
Tests and CI force an N-device CPU mesh with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``; with fewer devices
than shards the mapping cycles (single-process fallback — identical results,
no parallel payload plane).

Three planes, split by what must stay authoritative where:

* **metadata / control plane** — placement, mutation, compaction and
  migration *planning* stay on an inner ``GeoGraphStore`` coordinator, so
  replica sets are identical to the single-process store by construction.
  The full store kernel API (``serve_batch`` / ``apply_updates`` /
  ``flush_migrations`` / ``begin_flush`` / ``maintain`` / ``compact``)
  is preserved, so ``serve/`` and ``streaming/`` callers work unchanged.
* **routing plane** — each shard owns a :class:`~repro.core.route_index.
  RoutePartition` per origin DC, kept in sync by the coordinator
  :class:`~repro.core.route_index.RouteIndex`'s change events.  Partitions
  re-derive their rows independently from the replicated placement map, so
  shard/coordinator divergence is detectable (``verify_partitions``), and
  ``serve_batch`` dispatches per-origin sub-batches to the owning shard —
  which makes every sub-batch single-origin and lands it on
  ``route_online_batch``'s specialized expansion path.
* **payload plane** — each shard holds a device-resident ``[I, width]``
  float32 block for the items replicated at its DCs.  Row content is a pure
  function of the item's content-stable uid (:func:`payload_for_uids`), so
  shards materialize rows locally at placement time, and migration waves
  ship rows as explicit device-to-device transfers
  (:func:`~repro.distributed.collectives.transfer_rows`, optionally int8)
  whose wire bytes land in per-shard ``MatrixCounter`` grids.

Per-shard :class:`~repro.obs.MetricsRegistry` snapshots fold into one view
via :meth:`~repro.obs.MetricsRegistry.merge` (``merged_metrics``), and each
shard's measured serve wall time feeds a
:class:`~repro.distributed.fault.StragglerDetector` the admission controller
reads for per-shard miss attribution.
"""
from __future__ import annotations

import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.patterns import Pattern
from ..core.route_index import RoutePartition
from ..core.routing import RouteResult, route_online_batch
from ..core.store import GeoGraphStore
from ..obs import MetricsRegistry
from .collectives import transfer_rows
from .fault import StragglerDetector
from .geo_sharding import mesh_devices

__all__ = ["ShardedGeoGraphStore", "StoreShard", "payload_for_uids"]

PAYLOAD_WIDTH = 8


def payload_for_uids(uids: np.ndarray, width: int = PAYLOAD_WIDTH) -> np.ndarray:
    """Deterministic ``[len(uids), width]`` float32 payload rows.

    Row content is a pure function of the item's content-stable uid (a
    Knuth-style multiplicative mix), so any shard can materialize or verify
    a row without consulting a central copy, and rows survive compaction
    (uids are row-selected, never renumbered).  Values lie in ``[0, 1)``,
    which keeps the int8 transfer path's quantization error bounded by
    ``~1/254``.
    """
    uids = np.asarray(uids, dtype=np.int64)
    cols = np.arange(1, width + 1, dtype=np.int64)
    mix = (uids[:, None] * 2654435761 + cols[None, :] * 40503) & 0xFFFF
    return (mix / 65536.0).astype(np.float32)


class StoreShard:
    """One shard of the data plane: a set of origin DCs, their route
    partitions, a device-resident payload block, and a private registry."""

    __slots__ = ("sid", "dcs", "device", "registry", "partitions", "payload")

    def __init__(self, sid: int, dcs: Sequence[int], device, registry) -> None:
        self.sid = int(sid)
        self.dcs = [int(d) for d in dcs]
        self.device = device
        self.registry = registry
        self.partitions: Dict[int, RoutePartition] = {}
        self.payload = None  # [I, width] float32 on self.device

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"StoreShard(sid={self.sid}, dcs={self.dcs}, device={self.device})"


class _ShardedWaveApplier:
    """:class:`~repro.streaming.migration.WaveApplier` proxy that lands each
    wave's payload as device-to-device transfers *before* the metadata
    (placement + route-index) patch applies — data first, routes flip after.

    Staleness is checked up front (``check_valid``) so no payload ships for
    a wave whose item rows were renumbered under the flush."""

    def __init__(self, owner: "ShardedGeoGraphStore", applier) -> None:
        self._owner = owner
        self._applier = applier

    @property
    def plan(self):
        return self._applier.plan

    @property
    def schedule(self):
        return self._applier.schedule

    @property
    def n_remaining(self) -> int:
        return self._applier.n_remaining

    @property
    def done(self) -> bool:
        return self._applier.done

    def peek(self):
        return self._applier.peek()

    def apply_next(self):
        self._applier.check_valid()
        wave = self._applier.peek()
        if wave is not None:
            self._owner._execute_wave(wave)
        return self._applier.apply_next()

    def finish(self):
        out = self._applier.finish()
        # drops (including any constraint-guard rollback) are final now:
        # zero the payload rows each shard no longer holds
        self._owner._apply_drops_payload()
        return out


class ShardedGeoGraphStore:
    """Per-DC store shards over a jax device mesh, behind the store kernel API.

    ``n_shards`` defaults to one shard per DC; fewer shards group DCs
    round-robin (``dc % n_shards``), so the same environment can be served
    at 1/2/4/8 shards with identical replica sets and routes — the
    differential invariant ``tests/test_sharded_store.py`` pins down.

    Unknown attributes delegate to the inner coordinator store, so existing
    control-plane code (:class:`~repro.serve.AdmissionController`,
    :class:`~repro.serve.MaintenancePolicy`) drives a sharded store
    unmodified.

    Parameters beyond the ``GeoGraphStore`` ones:

    * ``n_shards`` / ``devices`` — mesh layout (devices default to
      :func:`~repro.distributed.geo_sharding.mesh_devices`).
    * ``parallel`` — dispatch per-shard sub-batches on a thread pool
      (default: only when the host has >1 CPU and >1 shard).
    * ``payload_width`` / ``compress`` — payload row width and the optional
      ``"int8"`` wire compression for migration transfers.
    * ``telemetry`` — start the per-shard registries enabled.
    * ``fetch_payload`` — have ``serve_batch`` also gather the served rows
      from the owning shard's device payload (end-to-end read path).
    """

    def __init__(
        self,
        g,
        env,
        workload,
        config=None,
        n_shards: Optional[int] = None,
        devices: Optional[Sequence] = None,
        parallel: Optional[bool] = None,
        payload_width: int = PAYLOAD_WIDTH,
        compress: Optional[str] = None,
        telemetry: bool = False,
        straggler_threshold: float = 1.8,
        fetch_payload: bool = False,
        **store_kw,
    ) -> None:
        routing = store_kw.setdefault("routing", "stepwise")
        if routing != "stepwise":
            raise ValueError(
                "ShardedGeoGraphStore partitions the nearest-replica route "
                f"index; routing={routing!r} has no per-origin partition"
            )
        if compress not in (None, "int8"):
            raise ValueError(f"unknown compression {compress!r} (None or 'int8')")
        self._store = GeoGraphStore(g, env, workload, config=config, **store_kw)
        D = env.n_dcs
        self.n_shards = D if n_shards is None else int(n_shards)
        if not 1 <= self.n_shards <= D:
            raise ValueError(f"n_shards must be in [1, {D}], got {self.n_shards}")
        self.payload_width = int(payload_width)
        self.compress = compress
        self.fetch_payload = bool(fetch_payload)
        devices = mesh_devices(self.n_shards) if devices is None else list(devices)
        self.origin_shard: Dict[int, int] = {
            d: d % self.n_shards for d in range(D)
        }
        self.registry = MetricsRegistry(enabled=telemetry)
        self.shards: List[StoreShard] = []
        self.partitions: Dict[int, RoutePartition] = {}
        delta_fn = lambda: self._store.state.delta  # noqa: E731 - live provider
        for sid in range(self.n_shards):
            shard = StoreShard(
                sid,
                [d for d in range(D) if d % self.n_shards == sid],
                devices[sid % len(devices)],
                MetricsRegistry(enabled=telemetry),
            )
            for d in shard.dcs:
                part = RoutePartition(env, d, delta_fn)
                shard.partitions[d] = part
                self.partitions[d] = part
            self.shards.append(shard)
        self._bound_index = None
        self._rebind_index()
        self.straggler = StragglerDetector(
            self.n_shards, threshold=straggler_threshold
        )
        self.last_shard_seconds: Dict[int, float] = {}
        # makespan of the last serve_batch (slowest shard's busy seconds):
        # shards are independent hosts, so this — not the coordinator's wall
        # time — is what the "measured" admission service model charges.
        # Owned by the facade (declared pre-_init_done) so it shadows the
        # inner store's per-sub-batch wall clock.
        self.last_serve_seconds = 0.0
        if parallel is None:
            parallel = self.n_shards > 1 and (os.cpu_count() or 1) > 1
        self._pool = (
            ThreadPoolExecutor(max_workers=self.n_shards) if parallel else None
        )
        self._init_done = True

    # any attribute the sharded facade does not own itself comes from (and
    # goes to) the coordinator — state, lg, _delta_graph, cost(), ... — so
    # code written against GeoGraphStore reads *and writes* through cleanly
    def __getattr__(self, name: str):
        store = self.__dict__.get("_store")
        if store is None:
            raise AttributeError(name)
        return getattr(store, name)

    def __setattr__(self, name: str, value) -> None:
        if "_init_done" in self.__dict__ and name not in self.__dict__:
            setattr(self._store, name, value)
        else:
            object.__setattr__(self, name, value)

    # -------------------------------------------------------- routing plane
    def _rebind_index(self) -> None:
        """(Re-)attach the partitions to the coordinator's RouteIndex.

        ``insert_patterns`` re-places from scratch and builds a *new* index,
        which knows nothing of our listeners — detect the swap, re-subscribe,
        and re-derive every partition and payload block."""
        idx = self._store.route_index
        if idx is None:  # pragma: no cover - guarded by the ctor routing check
            raise RuntimeError("sharded store requires a RouteIndex")
        if idx is not self._bound_index:
            idx.subscribe(self._on_route_event)
            self._bound_index = idx
            for part in self.partitions.values():
                part.derive_all()
            self._sync_payloads()

    def _on_route_event(self, kind: str, payload: object) -> None:
        for part in self.partitions.values():
            part.on_event(kind, payload)

    def route_table(self) -> np.ndarray:
        """``[I, D]`` serving table column-stacked from the shard partitions
        (must equal the coordinator's ``state.route`` — the differential
        invariant)."""
        D = self._store.env.n_dcs
        return np.stack([self.partitions[d].nearest for d in range(D)], axis=1)

    def verify_partitions(self) -> bool:
        """True iff every shard partition equals its coordinator column."""
        idx = self._store.route_index
        return all(p.verify_against(idx) for p in self.partitions.values())

    # -------------------------------------------------------- payload plane
    def _base_payload(self) -> np.ndarray:
        return payload_for_uids(self._store._item_uid, self.payload_width)

    def _sync_payloads(self) -> None:
        """Rebuild every shard's device payload from the placement map (id
        space moved: mutation growth, compaction, full re-place)."""
        base = self._base_payload()
        delta = self._store.state.delta
        for shard in self.shards:
            mask = delta[:, shard.dcs].any(axis=1)
            shard.payload = jax.device_put(base * mask[:, None], shard.device)

    def _apply_drops_payload(self) -> None:
        """Zero payload rows a shard no longer holds (drops/evictions —
        same id space, narrower replica sets)."""
        delta = self._store.state.delta
        for shard in self.shards:
            mask = delta[:, shard.dcs].any(axis=1)
            if shard.payload is None or shard.payload.shape[0] != len(mask):
                return self._sync_payloads()
            keep = jax.device_put(
                mask[:, None].astype(np.float32), shard.device
            )
            shard.payload = shard.payload * keep

    def _execute_wave(self, wave) -> None:
        """Run one migration wave's transfers device-to-device, accounting
        wire bytes per link into the *source* shard's registry."""
        D = self._store.env.n_dcs
        t0 = time.perf_counter()
        touched: List[StoreShard] = []
        for b in wave.links:
            src_sh = self.shards[self.origin_shard[b.src]]
            dst_sh = self.shards[self.origin_shard[b.dst]]
            rows = np.asarray(b.items, dtype=np.int32)
            block, wire = transfer_rows(
                src_sh.payload, rows, dst_sh.device, compress=self.compress
            )
            dst_sh.payload = dst_sh.payload.at[rows].set(block)
            touched.append(dst_sh)
            if src_sh.registry.enabled:
                mat = np.zeros((D, D))
                mat[b.src, b.dst] = wire
                src_sh.registry.counter_grid(
                    "migration.device_bytes_link", ("src", "dst")
                ).add(mat)
        for sh in touched:
            sh.payload.block_until_ready()
        if self.registry.enabled:
            self.registry.histogram("migration.device_wave_s").observe(
                time.perf_counter() - t0
            )
            self.registry.counter("migration.device_waves").inc()

    def verify_payloads(self) -> float:
        """Max abs deviation of any *held* payload row from its uid-derived
        content, across shards (0.0 exact; <~1/127 under int8 transfers)."""
        base = self._base_payload()
        delta = self._store.state.delta
        worst = 0.0
        for shard in self.shards:
            mask = delta[:, shard.dcs].any(axis=1)
            if not mask.any():
                continue
            got = np.asarray(shard.payload)[mask]
            err = np.abs(got - base[mask]).max()
            worst = max(worst, float(err))
        return worst

    # -------------------------------------------------------------- serving
    def serve_online(self, pattern, origin: int) -> RouteResult:
        """Serve one online pattern request through the owning shard."""
        return self.serve_batch([(pattern, origin)])[0]

    def serve_batch(
        self,
        requests: Sequence[Tuple[object, int]],
        observe: bool = True,
    ) -> List[RouteResult]:
        """Serve a batch by dispatching per-origin sub-batches to the owning
        shards and merging results back in input order.

        Requests are independent in the batch router, so the grouped
        dispatch is request-for-request identical to the single-process
        ``serve_batch`` on the same inputs.  Single-origin sub-batches land
        on ``route_online_batch``'s specialized expansion path.  Each
        shard's busy time per call (summed over its origin sub-batches)
        feeds the straggler detector and ``last_shard_seconds`` — the
        quantity ``bench_sharded`` uses for deployment-aggregate
        throughput, where shards are independent hosts and the makespan is
        the slowest shard.  With ``fetch_payload`` the served rows are also
        gathered from the owning shard's device block."""
        norm: List[Tuple[np.ndarray, int]] = []
        for req, origin in requests:
            items = req.items if isinstance(req, Pattern) else np.asarray(req)
            norm.append((items, int(origin)))
        R = len(norm)
        results: List[Optional[RouteResult]] = [None] * R
        by_origin: Dict[int, List[int]] = {}
        for pos, (_, o) in enumerate(norm):
            by_origin.setdefault(o, []).append(pos)
        jobs = sorted(by_origin.items())
        if self._pool is not None and len(jobs) > 1:
            futs = [
                (o, pos, self._pool.submit(
                    self._serve_origin, o, [norm[p] for p in pos]
                ))
                for o, pos in jobs
            ]
            outs = [(o, pos, f.result()) for o, pos, f in futs]
        else:
            outs = [
                (o, pos, self._serve_origin(o, [norm[p] for p in pos]))
                for o, pos in jobs
            ]
        busy: Dict[int, float] = {}
        for o, pos_list, (res, dt) in outs:
            busy[self.origin_shard[o]] = busy.get(self.origin_shard[o], 0.0) + dt
            for p, r in zip(pos_list, res):
                results[p] = r
        for sid in sorted(busy):
            self.straggler.observe(sid, busy[sid])
        self.last_shard_seconds = busy
        self.last_serve_seconds = max(busy.values(), default=0.0)
        if self.fetch_payload:
            self._fetch_rows(jobs, norm)
        if observe and norm:
            # heat injection grouped per origin into the shared demand plane,
            # exactly like the inner store
            for o, pos_list in by_origin.items():
                self._store.demand.observe(
                    np.concatenate([norm[p][0] for p in pos_list]), origin=o
                )
        return results

    def _serve_origin(
        self, origin: int, sub: List[Tuple[np.ndarray, int]]
    ) -> Tuple[List[RouteResult], float]:
        """Route one origin's sub-batch on its owning shard, telemetry into
        that shard's registry; returns results + measured busy seconds."""
        shard = self.shards[self.origin_shard[origin]]
        t0 = time.perf_counter()
        res = route_online_batch(
            self._store.lg, self._store.state, sub, registry=shard.registry
        )
        return res, time.perf_counter() - t0

    def _fetch_rows(
        self, jobs: List[Tuple[int, List[int]]], norm: List[Tuple[np.ndarray, int]]
    ) -> None:
        """Gather each sub-batch's rows from the owning shard's device
        payload (async dispatch, one barrier at the end)."""
        sums = []
        for o, pos_list in jobs:
            idx = np.concatenate([norm[p][0] for p in pos_list])
            if len(idx) == 0:
                continue
            payload = self.shards[self.origin_shard[o]].payload
            sums.append(jnp.take(payload, idx.astype(np.int32), axis=0).sum())
        for s in sums:
            s.block_until_ready()

    # ---------------------------------------------------------- maintenance
    def apply_updates(self, batch):
        report = self._store.apply_updates(batch)
        # partitions followed the index events; the id space moved, so the
        # payload blocks re-materialize from the new uid/placement rows
        self._sync_payloads()
        return report

    def maintain(self, evict: bool = True, diffusion_steps: int = 4):
        out = self._store.maintain(evict=evict, diffusion_steps=diffusion_steps)
        self._apply_drops_payload()
        return out

    def delete_items(self, item_ids: np.ndarray) -> None:
        self._store.delete_items(item_ids)
        self._apply_drops_payload()

    def compact(self) -> bool:
        fired = self._store.compact()
        if fired:
            self._sync_payloads()
        return fired

    def insert_patterns(self, new_patterns) -> None:
        self._store.insert_patterns(new_patterns)
        self._rebind_index()

    def insert_patterns_incremental(self, new_patterns):
        out = self._store.insert_patterns_incremental(new_patterns)
        self._rebind_index()
        return out

    # ------------------------------------------------------------ migration
    def begin_flush(
        self,
        budget_bytes: Optional[float] = None,
        window_s: float = 60.0,
        schedule: str = "ff",
        **kw,
    ):
        """Like the coordinator's ``begin_flush``, but the returned applier
        ships each wave's payload device-to-device before its metadata
        lands."""
        plan, applier = self._store.begin_flush(
            budget_bytes, window_s, schedule=schedule, **kw
        )
        return plan, _ShardedWaveApplier(self, applier)

    def flush_migrations(
        self,
        budget_bytes: Optional[float] = None,
        window_s: Optional[float] = 60.0,
        on_wave=None,
        schedule: str = "ff",
        **kw,
    ):
        if window_s is None:
            # legacy single-shot path: no wave structure to ship, so the
            # payload re-materializes from the final placement instead
            plan = self._store.flush_migrations(
                budget_bytes, window_s, on_wave=on_wave, schedule=schedule, **kw
            )
            self._sync_payloads()
            return plan
        plan, applier = self.begin_flush(
            budget_bytes, window_s, schedule=schedule, **kw
        )
        while applier.n_remaining:
            wave = applier.apply_next()
            if on_wave is not None:
                on_wave(wave)
        applier.finish()
        return plan

    # -------------------------------------------------------------- metrics
    def enable_telemetry(self) -> "ShardedGeoGraphStore":
        self.registry.enable()
        for shard in self.shards:
            shard.registry.enable()
        return self

    def merged_metrics(self) -> dict:
        """One exportable snapshot: coordinator + every shard registry,
        folded by :meth:`~repro.obs.MetricsRegistry.merge`."""
        snaps = [self.registry.snapshot()]
        snaps += [shard.registry.snapshot() for shard in self.shards]
        return MetricsRegistry.merge(snaps)
