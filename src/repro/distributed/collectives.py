"""Small collective helpers used by shard_map'd regions, plus the explicit
device-to-device transfer primitive the sharded store's migration waves run
through."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .compression import compress_int8, decompress_int8

__all__ = ["pmean_tree", "all_to_all_tokens", "transfer_rows"]


def pmean_tree(tree: Any, axis_name: str) -> Any:
    return jax.tree_util.tree_map(lambda x: jax.lax.pmean(x, axis_name), tree)


def all_to_all_tokens(x: jnp.ndarray, axis_name: str, split_axis: int = 0,
                      concat_axis: int = 0) -> jnp.ndarray:
    """Expert-parallel token exchange (inside shard_map)."""
    return jax.lax.all_to_all(x, axis_name, split_axis, concat_axis, tiled=True)


def transfer_rows(
    payload: jnp.ndarray,
    rows: np.ndarray,
    dst_device,
    compress: Optional[str] = None,
) -> Tuple[jnp.ndarray, float]:
    """Ship ``payload[rows]`` to ``dst_device`` as an explicit
    device-to-device copy; returns ``(block on dst, wire bytes)``.

    The gather runs on the source device (where ``payload`` lives); only the
    gathered block crosses the link.  ``compress="int8"`` quantizes the block
    per-tensor symmetric before the hop and dequantizes on the destination —
    the wire then carries 1 byte/element plus the fp32 scale, the migration
    analogue of the DCN gradient compression in
    :mod:`repro.distributed.compression`.
    """
    rows = np.asarray(rows, dtype=np.int32)
    block = jnp.take(payload, rows, axis=0)
    if compress is None:
        out = jax.device_put(block, dst_device)
        wire = int(out.size) * out.dtype.itemsize
    elif compress == "int8":
        q, scale = compress_int8(block)
        q = jax.device_put(q, dst_device)
        scale = jax.device_put(scale, dst_device)
        out = decompress_int8(q, scale)
        wire = int(q.size) * q.dtype.itemsize + int(scale.size) * 4
    else:
        raise ValueError(f"unknown compression {compress!r} (None or 'int8')")
    return out, float(wire)
