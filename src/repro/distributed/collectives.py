"""Small collective helpers used by shard_map'd regions."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["pmean_tree", "all_to_all_tokens"]


def pmean_tree(tree: Any, axis_name: str) -> Any:
    return jax.tree_util.tree_map(lambda x: jax.lax.pmean(x, axis_name), tree)


def all_to_all_tokens(x: jnp.ndarray, axis_name: str, split_axis: int = 0,
                      concat_axis: int = 0) -> jnp.ndarray:
    """Expert-parallel token exchange (inside shard_map)."""
    n = jax.lax.psum(1, axis_name)
    return jax.lax.all_to_all(x, axis_name, split_axis, concat_axis, tiled=True)
