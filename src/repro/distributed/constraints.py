"""Activation sharding constraints (MaxText-style logical annotations).

Inside large jitted programs, SPMD propagation through reshapes/transposes/
scans is conservative — attention heads or token axes silently replicate,
inflating activation memory by the mesh size.  ``constrain`` pins the
intended PartitionSpec when a mesh context is active (``with use_mesh(m):``
around trace/lower) and is a no-op in plain CPU tests.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["use_mesh", "current_mesh", "constrain", "mesh_axes"]

_STATE = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Activate ``mesh`` for activation constraints during tracing/lowering."""
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def mesh_axes() -> Tuple[str, ...]:
    m = current_mesh()
    return tuple(m.axis_names) if m is not None else ()


def constrain(x, *axes):
    """with_sharding_constraint(x, P(*axes)) against the active mesh.

    Axis entries may be None, a name, or a tuple of names; names missing
    from the mesh or not dividing the dim are dropped (no-op per-dim)."""
    m = current_mesh()
    if m is None or len(axes) != x.ndim:
        return x
    present = set(m.axis_names)
    sizes = dict(zip(m.axis_names, m.devices.shape))

    def fit(a, dim):
        if a is None:
            return None
        names = tuple(
            n for n in (a if isinstance(a, tuple) else (a,)) if n in present
        )
        if not names:
            return None
        f = 1
        for n in names:
            f *= sizes[n]
        if dim % f != 0:
            return None
        return names if len(names) > 1 else names[0]

    spec = tuple(fit(a, d) for a, d in zip(axes, x.shape))
    if all(s is None for s in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(m, P(*spec)))
    except Exception:  # pragma: no cover
        return x
