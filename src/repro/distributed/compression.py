"""Gradient compression for the cross-pod (DCN) reduction.

Two standard schemes, both with error feedback (residual carried in the
compression state so the bias vanishes over steps):

  * ``int8``  — per-tensor symmetric quantization; all-reduce runs on int8
                payload (8x less DCN traffic), dequantized after the sum.
  * ``topk``  — magnitude top-k sparsification (indices+values), k as a
                fraction of the tensor; the dense residual is fed back.

``compressed_psum`` composes quantize -> lax.psum -> dequantize inside a
``shard_map``ped region over the ``pod`` axis; the trainer enables it when
the mesh has a pod axis (DESIGN §7).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "init_compression_state",
    "compress_int8",
    "decompress_int8",
    "compress_topk",
    "apply_error_feedback",
    "compressed_psum",
]


def init_compression_state(grads: Any) -> Any:
    """Per-leaf error-feedback residual (same dtype as grads, fp32)."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads
    )


# ------------------------------------------------------------------ int8
def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# ------------------------------------------------------------------ top-k
def compress_topk(x: jnp.ndarray, frac: float = 0.05) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (dense sparsified tensor, kept mask).  Dense layout keeps the
    all-reduce shape static; the WAN saving is modeled by the mask ratio."""
    flat = x.reshape(-1)
    k = max(1, int(frac * flat.shape[0]))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(x) >= thresh
    return jnp.where(mask, x, 0.0), mask


def apply_error_feedback(
    g: jnp.ndarray, residual: jnp.ndarray, method: str = "int8", topk_frac: float = 0.05
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(compressed-then-decompressed gradient, new residual)."""
    x = g.astype(jnp.float32) + residual
    if method == "int8":
        q, s = compress_int8(x)
        out = decompress_int8(q, s)
    elif method == "topk":
        out, _ = compress_topk(x, topk_frac)
    else:
        raise ValueError(method)
    return out.astype(g.dtype), x - out


# ---------------------------------------------------- shard_map'd reduction
def compressed_psum(
    grads: Any,
    residuals: Any,
    axis_name: str = "pod",
    method: str = "int8",
    topk_frac: float = 0.05,
) -> Tuple[Any, Any]:
    """Per-leaf: error-feedback compress, psum over ``axis_name``, average.

    Must be called inside shard_map with ``axis_name`` bound.  Returns
    (averaged decompressed grads, new residuals)."""
    n = jax.lax.psum(1, axis_name)

    def leaf(g, r):
        c, new_r = apply_error_feedback(g, r, method, topk_frac)
        if method == "int8":
            # re-quantize so the wire payload is int8; sum in int32
            q, s = compress_int8(c.astype(jnp.float32))
            qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
            ssum = jax.lax.psum(s, axis_name)  # shared scale approx: mean
            out = qsum.astype(jnp.float32) * (ssum / n) / n
        else:
            out = jax.lax.psum(c.astype(jnp.float32), axis_name) / n
        return out.astype(g.dtype), new_r

    pairs = jax.tree_util.tree_map(leaf, grads, residuals)
    outs = jax.tree_util.tree_map(
        lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_res = jax.tree_util.tree_map(
        lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    return outs, new_res
