"""Fault tolerance + elasticity: failure simulation, elastic remesh,
straggler mitigation (DESIGN §7).

On a real cluster, failures surface as missing heartbeats; here the
``FailureSimulator`` injects them deterministically so the recovery path
(checkpoint restore -> elastic remesh -> reshard -> resume) is exercised by
tests and the quickstart example end-to-end.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

__all__ = [
    "FailureSimulator",
    "elastic_mesh_shape",
    "reshard_tree",
    "StragglerDetector",
    "StragglerMitigator",
]


@dataclasses.dataclass
class FailureEvent:
    step: int
    n_failed: int  # devices lost


class FailureSimulator:
    """Deterministic failure schedule: at listed steps, N devices die."""

    def __init__(self, events: Sequence[Tuple[int, int]] = ()) -> None:
        self.events = [FailureEvent(s, n) for s, n in events]
        self.failed_devices = 0

    def check(self, step: int) -> Optional[FailureEvent]:
        for e in self.events:
            if e.step == step:
                self.failed_devices += e.n_failed
                return e
        return None


def elastic_mesh_shape(
    n_devices: int, prefer_model: int = 16, multi_pod: bool = False
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest usable mesh after losing devices: keep the model axis if it
    divides, shrink data parallelism (elastic DP is loss-free; elastic TP
    would need weight resharding beyond DP)."""
    model = prefer_model
    while model > 1 and n_devices % model != 0:
        model //= 2
    rest = n_devices // model
    if multi_pod and rest % 2 == 0 and rest >= 2:
        return (2, rest // 2, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")


def reshard_tree(tree: Any, mesh, spec_tree) -> Any:
    """Place a host-resident (numpy) pytree onto a (new) mesh with the given
    PartitionSpecs — the elastic-restart path: checkpoints are stored
    unsharded, so any surviving mesh shape can load them."""
    from jax.sharding import NamedSharding

    def put(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, tree, spec_tree)


class StragglerDetector:
    """EWMA per-shard latency tracker with a median-relative lag flag.

    The detection core shared by the data-pipeline mitigator below and the
    sharded serving path: :class:`~repro.distributed.ShardedGeoGraphStore`
    feeds each shard's measured ``serve_batch`` wall time through
    :meth:`observe`, and the admission controller reads :meth:`is_straggler`
    to attribute a deadline miss to a lagging shard instead of the WAN fetch.
    """

    def __init__(self, n_shards: int, threshold: float = 1.8, alpha: float = 0.3):
        self.lat = np.zeros(n_shards)
        self.threshold = threshold
        self.alpha = alpha

    @property
    def n_shards(self) -> int:
        return len(self.lat)

    def observe(self, shard: int, seconds: float) -> None:
        if self.lat[shard] == 0:
            self.lat[shard] = seconds
        else:
            self.lat[shard] = (1 - self.alpha) * self.lat[shard] + self.alpha * seconds

    def ewma(self, shard: int) -> float:
        return float(self.lat[shard])

    def median(self) -> float:
        """Median EWMA over shards with at least one observation (0 if none)."""
        active = self.lat > 0
        return float(np.median(self.lat[active])) if active.any() else 0.0

    def is_straggler(self, shard: int) -> bool:
        """True when ``shard`` lags the active-shard median by ``threshold``x.

        Needs >= 2 observed shards (one shard has no fleet to lag behind)."""
        active = self.lat > 0
        if not (0 <= shard < len(self.lat)) or active.sum() < 2:
            return False
        return bool(self.lat[shard] > self.threshold * np.median(self.lat[active]))

    def flagged(self) -> List[int]:
        """Shard ids currently flagged as stragglers."""
        return [s for s in range(len(self.lat)) if self.is_straggler(s)]

    def snapshot(self) -> Dict[str, object]:
        return {
            "ewma_s": self.lat.tolist(),
            "median_s": self.median(),
            "threshold": self.threshold,
            "flagged": self.flagged(),
        }


class StragglerMitigator(StragglerDetector):
    """Host-side straggler mitigation for the data pipeline.

    Tracks per-shard step latencies (EWMA); when one feeder lags the median
    by ``threshold``x, its next batches are re-dispatched to the fastest
    feeder (bounded work stealing).  On-TPU stragglers are handled by the
    compiler's static schedule; the pipeline is where host jitter bites."""

    def __init__(self, n_shards: int, threshold: float = 1.8, alpha: float = 0.3):
        super().__init__(n_shards, threshold=threshold, alpha=alpha)
        self.reassigned: Dict[int, int] = {}

    def plan(self) -> Dict[int, int]:
        """shard -> substitute feeder for shards flagged as stragglers."""
        active = self.lat > 0
        if active.sum() < 2:
            return {}
        med = float(np.median(self.lat[active]))
        fastest = int(np.argmin(np.where(active, self.lat, np.inf)))
        out = {}
        for s in np.where(active)[0]:
            if self.lat[s] > self.threshold * med and s != fastest:
                out[int(s)] = fastest
        self.reassigned = out
        return out
