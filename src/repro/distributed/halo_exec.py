"""Halo-exchange message passing under shard_map — the *measured*
realization of the GeoLayer placement win for distributed GNNs
(EXPERIMENTS §Perf iteration 7/8).

Baseline distributed message passing all-gathers the full feature matrix
every layer: wire = (P-1)/P * N * d * bytes per layer.  The halo executor
instead exchanges only the rows other shards actually need, with *static*
send lists planned from the graph cut (and prioritized by GeoLayer heat —
``plan_gnn_halo`` picks which remote rows are worth keeping resident):

    per layer:  send_rows = feats[send_idx]        # [P, S_max, d]
                recv_rows = all_to_all(send_rows)  # the halo exchange
                ext = concat([feats_local, recv_rows.reshape(-1, d)])
                msgs -> segment_sum over local edges

wire = P * S_max * d * bytes per layer, with S_max = max rows any shard
exports ≈ boundary size.  The wire ratio vs baseline is measured by
:func:`exchange_stats` (exact byte accounting, no model).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..core.graph import Graph

__all__ = ["HaloProgram", "build_halo_program", "run_message_passing", "exchange_stats"]


def _resolve_shard_map():
    """shard_map moved from jax.experimental to the jax namespace (and the
    replication-check kwarg was renamed check_rep -> check_vma) across JAX
    releases; resolve whichever this install provides."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm, "check_vma"
    from jax.experimental.shard_map import shard_map as sm_exp

    return sm_exp, "check_rep"


@dataclasses.dataclass
class HaloProgram:
    """Static plan for shard_map halo message passing over a partition.

    All arrays have a leading shard axis [P, ...] (padded, masked):
      send_idx  [P, P, s_max]  rows of shard p to ship to shard q (local ids)
      send_mask [P, P, s_max]
      edge_src  [P, e_max]     index into [local n_max ++ recv (P*s_max)]
      edge_dst  [P, e_max]     local destination index
      edge_mask [P, e_max]
      feats     [P, n_max, d]  built by ``scatter_features``
    """

    n_shards: int
    n_max: int
    s_max: int
    e_max: int
    send_idx: np.ndarray
    send_mask: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    local_ids: List[np.ndarray]  # global vertex ids per shard (unpadded)

    def scatter_features(self, feats_global: np.ndarray) -> np.ndarray:
        d = feats_global.shape[1]
        out = np.zeros((self.n_shards, self.n_max, d), feats_global.dtype)
        for p, ids in enumerate(self.local_ids):
            out[p, : len(ids)] = feats_global[ids]
        return out

    def gather_outputs(self, out_sharded: np.ndarray, n_global: int) -> np.ndarray:
        d = out_sharded.shape[-1]
        out = np.zeros((n_global, d), out_sharded.dtype)
        for p, ids in enumerate(self.local_ids):
            out[ids] = out_sharded[p, : len(ids)]
        return out


def build_halo_program(g: Graph, n_shards: int) -> HaloProgram:
    """Plan send lists + local edge index from a partitioned graph.

    Edges are owned by their dst's shard; src rows on other shards enter the
    shard's receive buffer at a deterministic slot (q * s_max + position in
    q's send list to us)."""
    part = g.partition
    local_ids = [np.where(part == p)[0] for p in range(n_shards)]
    g2l = {}
    for p, ids in enumerate(local_ids):
        for i, v in enumerate(ids.tolist()):
            g2l[v] = (p, i)
    n_max = max(len(i) for i in local_ids)

    # who needs what: shard q needs src rows owned by p for q's edges
    need: Dict[Tuple[int, int], List[int]] = {}
    for s, t in zip(g.src.tolist(), g.dst.tolist()):
        ps, _ = g2l[s]
        pq, _ = g2l[t]
        if ps != pq:
            need.setdefault((ps, pq), [])
            if s not in need[(ps, pq)]:
                need[(ps, pq)].append(s)
    s_max = max((len(v) for v in need.values()), default=1)

    send_idx = np.zeros((n_shards, n_shards, s_max), np.int32)
    send_mask = np.zeros((n_shards, n_shards, s_max), bool)
    recv_slot: Dict[Tuple[int, int], int] = {}  # (dst shard, global id) -> slot
    for (ps, pq), verts in need.items():
        for j, v in enumerate(verts):
            send_idx[ps, pq, j] = g2l[v][1]
            send_mask[ps, pq, j] = True
            # receive buffer on q is [P, s_max] flattened: sender-major
            recv_slot[(pq, v)] = ps * s_max + j

    counts = np.bincount([g2l[t][0] for t in g.dst.tolist()], minlength=n_shards)
    e_max = int(counts.max()) if len(counts) else 1
    edge_src = np.zeros((n_shards, e_max), np.int32)
    edge_dst = np.zeros((n_shards, e_max), np.int32)
    edge_mask = np.zeros((n_shards, e_max), bool)
    fill = np.zeros(n_shards, np.int64)
    for s, t in zip(g.src.tolist(), g.dst.tolist()):
        pq, lt = g2l[t]
        ps, ls = g2l[s]
        j = fill[pq]
        edge_dst[pq, j] = lt
        if ps == pq:
            edge_src[pq, j] = ls
        else:  # halo row: offset past the local block
            edge_src[pq, j] = n_max + recv_slot[(pq, s)]
        edge_mask[pq, j] = True
        fill[pq] += 1
    return HaloProgram(
        n_shards=n_shards, n_max=n_max, s_max=s_max, e_max=e_max,
        send_idx=send_idx, send_mask=send_mask,
        edge_src=edge_src, edge_dst=edge_dst, edge_mask=edge_mask,
        local_ids=local_ids,
    )


def run_message_passing(
    prog: HaloProgram,
    mesh: Mesh,
    feats: jnp.ndarray,  # [P, n_max, d] (scatter_features layout)
    weights: jnp.ndarray,  # [d, d] shared message transform (demo layer)
    n_layers: int = 2,
    mode: str = "halo",  # halo | allgather
) -> jnp.ndarray:
    """n_layers of mean-aggregated message passing, halo vs all-gather.

    Both modes compute identical results (tested); they differ only in the
    exchange primitive, i.e. the collective wire bytes."""
    axis = mesh.axis_names[0]
    p_ = prog

    def layer(x, send_idx, send_mask, e_src, e_dst, e_mask):
        # x: [n_max, d] local block (inside shard_map)
        if mode == "halo":
            send = jnp.where(send_mask[..., None], x[send_idx], 0.0)  # [P,s,d]
            recv = jax.lax.all_to_all(send, axis, 0, 0, tiled=False)
            recv = recv.reshape(p_.n_shards * p_.s_max, x.shape[-1])
        else:
            allf = jax.lax.all_gather(x, axis)  # [P, n_max, d]
            # emulate the recv layout from the gathered matrix
            idx_all = jax.lax.all_gather(send_idx, axis)  # [P(src), P(dst), s]
            me = jax.lax.axis_index(axis)
            rows = idx_all[:, me]  # [P, s] rows each sender ships to me
            recv = allf[jnp.arange(p_.n_shards)[:, None], rows].reshape(
                p_.n_shards * p_.s_max, x.shape[-1]
            )
        ext = jnp.concatenate([x, recv], axis=0)
        msg = ext[e_src] @ weights
        msg = jnp.where(e_mask[:, None], msg, 0.0)
        agg = jax.ops.segment_sum(msg, e_dst, num_segments=p_.n_max)
        deg = jax.ops.segment_sum(
            e_mask.astype(x.dtype), e_dst, num_segments=p_.n_max
        )
        return x + jnp.tanh(agg / jnp.maximum(deg, 1.0)[:, None])

    shard_map_fn, check_kw = _resolve_shard_map()

    @partial(
        shard_map_fn,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(axis),
        **{check_kw: False},
    )
    def run(x, send_idx, send_mask, e_src, e_dst, e_mask):
        x, send_idx = x[0], send_idx[0]
        send_mask, e_src = send_mask[0], e_src[0]
        e_dst, e_mask = e_dst[0], e_mask[0]
        for _ in range(n_layers):
            x = layer(x, send_idx, send_mask, e_src, e_dst, e_mask)
        return x[None]

    return run(
        feats,
        jnp.asarray(prog.send_idx),
        jnp.asarray(prog.send_mask),
        jnp.asarray(prog.edge_src),
        jnp.asarray(prog.edge_dst),
        jnp.asarray(prog.edge_mask),
    )


def exchange_stats(prog: HaloProgram, d: int, n_layers: int, bytes_per: int = 4):
    """Exact wire bytes per device per step for both modes."""
    halo = n_layers * prog.n_shards * prog.s_max * d * bytes_per
    allgather = (
        n_layers * (prog.n_shards - 1) * prog.n_max * d * bytes_per
    )
    return {
        "halo_bytes_per_device": halo,
        "allgather_bytes_per_device": allgather,
        "reduction": allgather / max(halo, 1),
    }
