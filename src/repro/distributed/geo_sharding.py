"""GeoLayer applied at mesh scale — the paper's technique as a first-class
framework feature (DESIGN §4).

A TPU mesh is a geo topology in miniature: device shards are "DCs", ICI is
the intra-region WAN, DCN (pod axis) is the cross-region WAN.  Three
integration points:

  * ``mesh_env``        — GeoEnvironment over mesh shards (2-level latency:
                          intra-pod ICI vs cross-pod DCN).
  * ``plan_gnn_halo``   — Eq. 13 replication gain per (boundary vertex,
                          remote shard): heat (access frequency x degree) vs
                          storage+sync cost decides which remote vertices are
                          replicated into each shard's halo.  Cuts per-layer
                          cross-shard gathers to one pre-gather per step.
  * ``plan_expert_replicas`` / ``plan_row_replicas`` — DHD-style heat over
                          router/row access stats -> replication factors for
                          hot MoE experts / embedding rows.

The layered-graph machinery itself runs unchanged on ``mesh_env`` — tests
verify a mesh-level layered graph has exactly 2 bridge layers (ICI, DCN).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from ..core.graph import Graph
from ..core.latency import GeoEnvironment

__all__ = [
    "mesh_env",
    "mesh_devices",
    "HaloPlan",
    "plan_gnn_halo",
    "plan_expert_replicas",
    "plan_row_replicas",
]

# v5e-ish fabric constants (also used by launch/roofline.py)
ICI_RTT_S = 2e-6
ICI_BW_BPS = 5e10  # ~50 GB/s per link
DCN_RTT_S = 1e-4
DCN_BW_BPS = 2.5e9  # ~2.5 GB/s per host pair across pods


def mesh_env(n_shards: int, shards_per_pod: Optional[int] = None) -> GeoEnvironment:
    """Two-level GeoEnvironment over mesh shards (devices or device groups)."""
    spp = shards_per_pod or n_shards
    pod = np.arange(n_shards) // spp
    same = pod[:, None] == pod[None, :]
    rtt = np.where(same, ICI_RTT_S, DCN_RTT_S)
    bw = np.where(same, ICI_BW_BPS, DCN_BW_BPS)
    np.fill_diagonal(rtt, 0.0)
    bw = bw.astype(np.float64)
    np.fill_diagonal(bw, np.inf)
    # cost model: relative units (no $ pricing inside a cluster); transfer
    # "cost" ~ 1/bandwidth so Eq. 13 trades bytes moved for bytes stored.
    return GeoEnvironment(
        names=[f"shard{i}" for i in range(n_shards)],
        rtt_s=rtt,
        bw_Bps=bw,
        c_store=np.full(n_shards, 1e-12),
        c_read=np.full(n_shards, 0.0),
        c_write=np.full(n_shards, 0.0),
        c_net=1.0 / bw,
    )


def mesh_devices(n_shards: int) -> List:
    """One jax device per store shard, cycling when the runtime exposes
    fewer than ``n_shards``.

    Tests/CI force an N-device CPU mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
    initializes).  Without it every shard lands on device 0 — the
    single-process fallback: functionally identical, payload transfers
    degenerate to same-device copies, nothing runs in parallel.

    jax imports lazily so the placement/routing planners in this module stay
    importable without an accelerator runtime.
    """
    import jax

    devs = jax.devices()
    return [devs[i % len(devs)] for i in range(n_shards)]


@dataclasses.dataclass
class HaloPlan:
    """Per-shard halo: remote vertex ids replicated into the shard."""

    halo: List[np.ndarray]  # shard -> remote vertex ids
    replicated_bytes: float
    cut_edges_before: int
    cut_edges_resolved: int  # cross-shard edges whose remote endpoint is now local

    @property
    def resolve_frac(self) -> float:
        return self.cut_edges_resolved / max(self.cut_edges_before, 1)


def plan_gnn_halo(
    g: Graph,
    n_shards: int,
    vertex_heat: Optional[np.ndarray] = None,
    n_layers: int = 4,
    write_rate: float = 1.0,
    budget_frac: float = 0.25,
    bytes_per_vertex: float = 512.0,
) -> HaloPlan:
    """Eq. 13 specialized to mesh halos (uniform intra-cluster latency, so
    the layered decomposition collapses to per-shard, per-vertex gains):

      gain(v, s) = n_layers * reads(v->s) * bytes_v / BW        (saved gathers)
                   - bytes_v * c_store - write_rate * bytes_v / BW (sync)

    reads(v->s) = edges from v into shard s x per-step access (heat).  Every
    positive-gain (v, s) pair is replicated, best-gain first, bounded by
    ``budget_frac`` x local vertices per shard (HBM budget)."""
    part = g.partition
    heat = vertex_heat if vertex_heat is not None else np.ones(g.n_nodes)
    cross = part[g.src] != part[g.dst]
    # edge count from remote vertex u into shard s, both directions
    pairs_a = np.stack([g.src[cross], part[g.dst[cross]]], 1)
    pairs_b = np.stack([g.dst[cross], part[g.src[cross]]], 1)
    pairs = np.concatenate([pairs_a, pairs_b], 0)
    uniq, counts = np.unique(pairs, axis=0, return_counts=True)
    v_ids, s_ids = uniq[:, 0], uniq[:, 1]
    reads = counts.astype(np.float64) * heat[v_ids]
    # relative cost units: gather saving ~ n_layers reads; sync ~ write_rate
    gain = n_layers * reads - write_rate - 0.01  # store cost epsilon
    order = np.argsort(-gain)
    budget = int(budget_frac * g.n_nodes / max(n_shards, 1))
    halo: List[List[int]] = [[] for _ in range(n_shards)]
    fill = np.zeros(n_shards, dtype=np.int64)
    resolved_pairs = set()
    for i in order:
        if gain[i] <= 0:
            break
        s = int(s_ids[i])
        if fill[s] >= budget:
            continue
        halo[s].append(int(v_ids[i]))
        fill[s] += 1
        resolved_pairs.add((int(v_ids[i]), s))
    # how many cut edges now have their remote endpoint local?
    resolved = 0
    for (u, sp), (vv, sq) in zip(
        zip(g.src[cross].tolist(), part[g.dst[cross]].tolist()),
        zip(g.dst[cross].tolist(), part[g.src[cross]].tolist()),
    ):
        if (u, sp) in resolved_pairs or (vv, sq) in resolved_pairs:
            resolved += 1
    halos = [np.asarray(sorted(h), dtype=np.int64) for h in halo]
    return HaloPlan(
        halo=halos,
        replicated_bytes=float(sum(len(h) for h in halos)) * bytes_per_vertex,
        cut_edges_before=int(cross.sum()),
        cut_edges_resolved=resolved,
    )


def plan_expert_replicas(
    expert_load: np.ndarray,  # [E] router load fractions (DHD heat signal)
    n_shards: int,
    max_replicas: int = 4,
) -> np.ndarray:
    """Replication factor per expert ~ proportional to load (hot experts get
    more replicas, capped).  Returns [E] ints >= 1."""
    e = len(expert_load)
    mean = 1.0 / max(e, 1)
    factor = np.clip(np.round(expert_load / max(mean, 1e-9)), 1, max_replicas)
    return factor.astype(np.int64)


def plan_row_replicas(
    row_freq: np.ndarray,  # [V] access counts
    quantile: float = 0.999,
) -> np.ndarray:
    """Hot embedding rows (above the heat quantile) to replicate across the
    model axis instead of row-sharding (GeoLayer pre-caching at mesh scale)."""
    if row_freq.max() <= 0:
        return np.zeros(0, dtype=np.int64)
    theta = np.quantile(row_freq[row_freq > 0], quantile)
    return np.where(row_freq >= theta)[0]
