from . import (  # noqa: F401
    collectives,
    compression,
    fault,
    geo_sharding,
    sharded_store,
    sharding,
)
from .fault import StragglerDetector, StragglerMitigator  # noqa: F401
from .sharded_store import (  # noqa: F401
    ShardedGeoGraphStore,
    StoreShard,
    payload_for_uids,
)
