from . import collectives, compression, fault, geo_sharding, sharding  # noqa: F401
