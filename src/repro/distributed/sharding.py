"""Sharding rules: logical param/batch axes -> mesh axes, per arch family.

Follows the MaxText "logical axis rules" pattern: a path-based rule table
maps each parameter leaf to a PartitionSpec.  Mesh axes:
  * ``pod``   — data parallelism across pods (DCN; slow, compressed grads)
  * ``data``  — data parallelism within a pod (ICI)
  * ``model`` — tensor/expert/vocab/row parallelism (ICI)
Sequence sharding (long-context KV) reuses ``data``.

``param_spec_lm`` handles the stacked-scan layout (leading L axis unsharded).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "dp_axes",
    "param_spec_lm",
    "param_spec_gnn",
    "param_spec_bst",
    "batch_spec_lm",
    "named",
    "tree_shardings",
]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes present in this mesh (('pod','data') or ('data',))."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _lm_rule(path: str, rank: int, ep_divisible: bool = True) -> P:
    """PartitionSpec for one LM param leaf, *excluding* the stacked-L axis.

    ``rank`` is the per-layer rank (disambiguates dense [dff,d] vs MoE
    [E,dff,d] weights sharing path suffixes).  ``ep_divisible``: experts
    shard over ``model`` when E % model == 0, else the expert hidden dim
    shards (TP-within-expert, e.g. granite's 40 experts on 16 shards)."""
    # attention
    if path.endswith("attn.wq") or path.endswith("attn.wk") or path.endswith("attn.wv"):
        return P(None, "model")
    if path.endswith("attn.wo"):
        return P("model", None)
    if path.endswith("attn.w_uk") or path.endswith("attn.w_uv"):
        return P(None, "model")  # MLA up-projections: heads sharded
    if path.endswith("attn.w_dkv") or path.endswith("attn.w_krope"):
        return P(None, None)  # small latent projections: replicated
    # MoE expert weights are 3D per layer: [E, d, f] / [E, f, d]
    if rank == 3 and (
        path.endswith("ffn.w_gate") or path.endswith("ffn.w_up")
    ):
        return P("model", None, None) if ep_divisible else P(None, None, "model")
    if rank == 3 and path.endswith("ffn.w_down"):
        return P("model", None, None) if ep_divisible else P(None, "model", None)
    if path.endswith("ffn.router"):
        return P(None, None)
    if "shared_gate" in path or "shared_up" in path:
        return P(None, "model")
    if "shared_down" in path:
        return P("model", None)
    # dense FFN (2D per layer)
    if path.endswith("ffn.w_gate") or path.endswith("ffn.w_up"):
        return P(None, "model")
    if path.endswith("ffn.w_down"):
        return P("model", None)
    # embeddings: vocab-sharded
    if path.endswith("embed.table") or path.endswith("unembed.table"):
        return P("model", None)
    return P()  # norms, gains, biases: replicated


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def param_spec_lm(
    params_tree: Any, ep_divisible: bool = True, fsdp: bool = False
) -> Any:
    """PartitionSpec pytree for LM params (stacked-scan layout aware).

    ``fsdp=True`` additionally shards the non-``model`` dim of every 2D+
    weight over ``data`` (ZeRO-3 style) — required for params+opt of 27B-
    class models to fit 16GB/chip; XLA inserts the per-layer all-gathers."""

    def rule(path, leaf):
        s = _path_str(path)
        stacked = s.startswith("layers.")
        rank = leaf.ndim - 1 if stacked else leaf.ndim
        base = _lm_rule(s, rank, ep_divisible)
        if fsdp and rank >= 2:
            axes = list(base) + [None] * (rank - len(base))
            if "data" not in axes:
                # shard the largest un-sharded dim over data (prefer dim 0)
                for i in range(rank):
                    if axes[i] is None and leaf.shape[i + (1 if stacked else 0)] % 16 == 0:
                        axes[i] = "data"
                        break
            base = P(*axes)
        if stacked and len(base) < leaf.ndim:  # prepend None for the L axis
            return P(*((None,) * (leaf.ndim - len(base)) + tuple(base)))
        if len(base) > leaf.ndim:
            return P(*base[: leaf.ndim])
        return base

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def param_spec_gnn(params_tree: Any) -> Any:
    """GNN params are small (<= ~35M); replicate everywhere."""
    return jax.tree_util.tree_map(lambda leaf: P(), params_tree)


def param_spec_bst(params_tree: Any) -> Any:
    """BST: embedding tables row-sharded over ``model``; the rest replicated."""

    def rule(path, leaf):
        s = _path_str(path)
        if s.endswith("item_table") or s.endswith("cat_table"):
            return P("model", None)
        return P()

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def batch_spec_lm(mesh: Mesh, kind: str) -> Dict[str, P]:
    """Input PartitionSpecs per shape kind."""
    dp = dp_axes(mesh)
    if kind == "train":
        return {"tokens": P(dp, None), "labels": P(dp, None)}
    if kind == "prefill":
        return {"tokens": P(dp, None)}
    if kind == "decode":
        # caches handled separately (see configs.input_specs)
        return {"token": P(dp), "position": P(dp)}
    raise ValueError(kind)


def tree_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def constrain_lm_layer(lp, ep_divisible: bool = True, fsdp: bool = True):
    """Re-pin per-layer weight shardings *inside* the scan body.

    Without this, the SPMD partitioner hoists the FSDP all-gather of the
    whole stacked [L, ...] parameter array out of the layer loop — the
    entire model materializes unsharded (27B fp32 = 108 GB/device).  With
    the in-body constraint the gather happens per layer slice."""
    from .constraints import constrain, current_mesh

    if current_mesh() is None:
        return lp

    def pin(path, leaf):
        if leaf.ndim < 2:
            return leaf
        s = _path_str(path)
        base = _lm_rule(s, leaf.ndim, ep_divisible)
        axes = list(base) + [None] * (leaf.ndim - len(base))
        if fsdp and "data" not in axes:
            for i in range(leaf.ndim):
                if axes[i] is None and leaf.shape[i] % 16 == 0:
                    axes[i] = "data"
                    break
        return constrain(leaf, *axes[: leaf.ndim])

    return jax.tree_util.tree_map_with_path(pin, lp)
