"""Origin-destination demand layer: single owner of per-DC request heat.

One ``ODDemandLayer`` instance backs every :class:`~repro.core.placement.
HeatCache` of a store: ``heat[d]`` is DC *d*'s Alg. 3 eviction field (the
caches expose it as a shared-storage row view — accumulate, diffuse, decay,
evict all operate in place on this one table, nothing is double-booked).

On top of the raw field the layer keeps the windowed demand model the
control plane plans against:

  * ``od``       — monotone cumulative per-(origin, item) request weight
                   (never diffused or decayed: the ground truth a pre-stage
                   hit/wasted verdict is settled against);
  * ``rate``     — EWMA of per-window od rates (request weight / second);
  * ``profile``  — per-origin item mix (rows sum to 1 once an origin has
                   traffic): what the origin reads, independent of volume;
  * ``history``  — per-window origin intensity vectors, the series the
                   :class:`~repro.demand.Forecaster`s consume.

``measured()`` and ``forecast()`` return the same :class:`DemandView` shape
(item heat ``[I]`` + read-rate table ``[I, D]``), so migration planning and
pre-caching consume measured and predicted demand through one code path.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["DemandView", "ODDemandLayer"]


@dataclasses.dataclass
class DemandView:
    """One demand snapshot in planner coordinates.

    ``horizon == 0`` means measured (current EWMA rates); ``horizon >= 1``
    means a forecast that many windows ahead.  ``read_rates`` aligns with the
    ``r_xy`` table :func:`~repro.streaming.migration.plan_migrations` takes,
    ``item_heat`` with its ``item_heat`` ranking input.
    """

    intensity: np.ndarray  # [D] per-origin request weight per second
    item_heat: np.ndarray  # [I] aggregate per-item demand
    read_rates: np.ndarray  # [I, D] per-(item, origin) demand rates
    horizon: int = 0

    @property
    def total(self) -> float:
        return float(self.intensity.sum())


class ODDemandLayer:
    """Accumulates per-(origin DC, item) request heat from the serving path.

    ``observe``/``observe_requests`` are the only write entry points for
    online heat — stores and caches delegate here, which is what makes the
    single-ownership invariant checkable (``tests/test_demand.py``).
    Windowing is driven by the caller's clock (simulated or wall) through
    ``advance_to(now)``; with no clock the layer degenerates to one open
    window and the raw heat field still behaves exactly like the legacy
    per-DC arrays.
    """

    def __init__(
        self,
        n_items: int,
        n_dcs: int,
        window_s: float = 60.0,
        t0: float = 0.0,
        max_windows: int = 512,
        rate_alpha: float = 0.35,
        profile_alpha: float = 0.35,
        rate_floor: float = 0.0,
        registry=None,
    ) -> None:
        if n_dcs < 1:
            raise ValueError(f"need at least one DC, got {n_dcs}")
        if window_s <= 0:
            raise ValueError(f"window_s must be positive, got {window_s}")
        self.n_items = int(n_items)
        self.n_dcs = int(n_dcs)
        self.window_s = float(window_s)
        self.rate_alpha = float(rate_alpha)
        self.profile_alpha = float(profile_alpha)
        # sparsification: a pure EWMA never reaches exactly zero, so a
        # replica once read would look "serving" forever and could never be
        # dropped by planners keying on ``rate > 0``.  Entries below
        # ``rate_floor`` x the table max are clamped to zero at window close
        # (0.0 = off, exact EWMA semantics).
        self.rate_floor = float(rate_floor)
        self._registry = registry
        # the one [D, I] online heat table (C-contiguous so heat[d] is a
        # contiguous row view the HeatCaches mutate in place)
        self.heat = np.zeros((self.n_dcs, self.n_items), dtype=np.float32)
        # monotone cumulative od weight + its snapshot at the open window's
        # start (current-window mass = od - _od_win_start, one copy/window)
        self.od = np.zeros((self.n_dcs, self.n_items), dtype=np.float32)
        self._od_win_start = self.od.copy()
        self.rate = np.zeros((self.n_dcs, self.n_items), dtype=np.float32)
        self.profile = np.zeros((self.n_dcs, self.n_items), dtype=np.float32)
        self.window_index = 0
        self._win_t0 = float(t0)
        self.history: Deque[np.ndarray] = deque(maxlen=int(max_windows))
        # window_index -> predicted intensity, settled when that window closes
        self._pending_forecasts: Dict[int, np.ndarray] = {}
        self.last_forecast_abs_err: Optional[np.ndarray] = None
        self.total_observed = 0.0

    # ------------------------------------------------------------- telemetry
    def _reg(self):
        if self._registry is not None:
            return self._registry
        from ..obs import get_registry

        return get_registry()

    # ------------------------------------------------------------ observation
    def observe(self, item_ids: np.ndarray, origin: int = 0, freq: float = 1.0) -> None:
        """Deposit one access-event batch from ``origin`` (Alg. 3 lines 3-5).

        Duplicate ids accumulate (``np.add.at``), matching the legacy
        per-cache scatter exactly — fancy-index ``+=`` would collapse them.
        """
        ids = np.asarray(item_ids)
        np.add.at(self.heat[origin], ids, freq)
        np.add.at(self.od[origin], ids, freq)
        self.total_observed += float(freq) * len(ids)

    def observe_requests(self, requests: Sequence[Tuple[np.ndarray, int]]) -> None:
        """Deposit a served batch: ``(items, origin)`` pairs, grouped so each
        touched DC pays one scatter (the ``serve_batch`` hot path)."""
        by_origin: Dict[int, List[np.ndarray]] = {}
        for items, o in requests:
            by_origin.setdefault(int(o), []).append(items)
        for o, groups in by_origin.items():
            self.observe(np.concatenate(groups), origin=o)

    # -------------------------------------------------------------- windowing
    def advance_to(self, now: float) -> int:
        """Close every demand window that ended at or before ``now``; returns
        the number closed.  Idle stretches close as empty (zero-intensity)
        windows — real signal for the forecasters, but bulk-skipped past the
        first so a huge clock jump costs O(history), not O(elapsed/window)."""
        if not math.isfinite(now):
            return 0
        n_due = int((now - self._win_t0) // self.window_s)
        if n_due <= 0:
            return 0
        self._close_window()  # the one window that may carry data
        skip = n_due - 1
        if skip > 0:
            # the remaining windows are provably empty (observe() cannot have
            # run between clock reads): decay the rate model once, record a
            # bounded number of zero-intensity windows for the forecasters
            self.rate *= (1.0 - self.rate_alpha) ** skip
            zeros = np.zeros(self.n_dcs, dtype=np.float64)
            for _ in range(min(skip, self.history.maxlen or skip)):
                self.history.append(zeros.copy())
            self.window_index += skip
            self._win_t0 += skip * self.window_s
            self._pending_forecasts = {
                k: v for k, v in self._pending_forecasts.items()
                if k >= self.window_index
            }
        return n_due

    def _close_window(self) -> None:
        win = self.od - self._od_win_start  # [D, I] mass of the closing window
        inv_w = 1.0 / self.window_s
        intensity = (win.sum(axis=1) * inv_w).astype(np.float64)
        a = self.rate_alpha
        self.rate *= 1.0 - a
        self.rate += (a * inv_w) * win
        if self.rate_floor > 0.0:
            m = float(self.rate.max())
            if m > 0.0:
                self.rate[self.rate < self.rate_floor * m] = 0.0
        mass = win.sum(axis=1)
        pa = self.profile_alpha
        for d in np.where(mass > 0)[0]:
            self.profile[d] *= 1.0 - pa
            self.profile[d] += (pa / mass[d]) * win[d]
        self.history.append(intensity)
        hat = self._pending_forecasts.pop(self.window_index, None)
        if hat is not None:
            err = np.abs(hat - intensity)
            self.last_forecast_abs_err = err
            reg = self._reg()
            if reg.enabled:
                for d in range(self.n_dcs):
                    reg.gauge("demand.forecast_abs_err", origin=d).set(float(err[d]))
                reg.histogram("demand.forecast_mae").observe(float(err.mean()))
        reg = self._reg()
        if reg.enabled:
            reg.counter("demand.windows").inc()
            reg.gauge("demand.intensity").set(float(intensity.sum()))
        self._od_win_start = self.od.copy()
        self.window_index += 1
        self._win_t0 += self.window_s

    # ------------------------------------------------------------------ views
    def measured(self) -> DemandView:
        """The EWMA-rate demand view (what a reactive planner should chase)."""
        rates = np.ascontiguousarray(self.rate.T)
        return DemandView(
            intensity=self.rate.sum(axis=1).astype(np.float64),
            item_heat=self.rate.sum(axis=0).astype(np.float64),
            read_rates=rates,
            horizon=0,
        )

    def forecast(self, forecaster, horizon: int = 1) -> DemandView:
        """Predict demand ``horizon`` windows ahead.

        Per-origin intensity comes from the forecaster over this layer's
        history; it is spread over items through each origin's learned
        profile, so the view has the same planner coordinates as
        :meth:`measured`.  The prediction is recorded and settled against the
        realized intensity when the target window closes (forecast-error
        gauges)."""
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        D = self.n_dcs
        if self.history:
            series = np.stack(self.history)  # [W, D]
        else:
            series = np.zeros((0, D), dtype=np.float64)
        hat = np.array(
            [
                max(0.0, float(forecaster.forecast(series[:, d], horizon)))
                for d in range(D)
            ],
            dtype=np.float64,
        )
        rates_od = self.profile.astype(np.float64) * hat[:, None]  # [D, I]
        self._pending_forecasts[self.window_index + int(horizon) - 1] = hat
        return DemandView(
            intensity=hat,
            item_heat=rates_od.sum(axis=0),
            read_rates=np.ascontiguousarray(rates_od.T),
            horizon=int(horizon),
        )

    def apply_diffusion(
        self, row: int, vertex_heat: np.ndarray, tail_decay: float
    ) -> None:
        """Write one DC's diffused heat field back into the owned table.

        The DHD step (``step_heat_caches``) reads heat *views*, diffuses the
        vertex block and decays the edge tail — but the ``[D, I]`` table is
        single-owned here, so the result comes back through this method
        rather than through a write to the ``HeatCache.heat`` view (the
        exactly-once-deposit invariant geolint GL003 enforces)."""
        n = len(vertex_heat)
        self.heat[row, :n] = vertex_heat
        self.heat[row, n:] *= tail_decay

    # ----------------------------------------------------- id-space remapping
    def grow_items(self, old_n_nodes: int, n_new_vertices: int, n_new_edges: int) -> None:
        """Grow every item-indexed table for a mutation batch, preserving the
        ``vertex v -> v, edge e -> n_nodes + e`` layout (the one shared
        encoding in :func:`repro.core.graph.grow_item_rows`).  HeatCache row
        views re-read through the property, so they follow automatically."""
        from ..core.graph import grow_item_rows

        def grow(a: np.ndarray) -> np.ndarray:
            return np.stack(
                [grow_item_rows(row, old_n_nodes, n_new_vertices, n_new_edges, 0.0)
                 for row in a]
            )

        self.heat = grow(self.heat)
        self.od = grow(self.od)
        self._od_win_start = grow(self._od_win_start)
        self.rate = grow(self.rate)
        self.profile = grow(self.profile)
        self.n_items = self.heat.shape[1]

    def take_rows(self, keep: np.ndarray) -> None:
        """Row-select every item-indexed table onto a compacted id space."""
        keep = np.asarray(keep)
        self.heat = np.ascontiguousarray(self.heat[:, keep])
        self.od = np.ascontiguousarray(self.od[:, keep])
        self._od_win_start = np.ascontiguousarray(self._od_win_start[:, keep])
        self.rate = np.ascontiguousarray(self.rate[:, keep])
        self.profile = np.ascontiguousarray(self.profile[:, keep])
        self.n_items = self.heat.shape[1]

    # ------------------------------------------------------------------ stats
    def stats(self) -> Dict[str, object]:
        return {
            "n_items": self.n_items,
            "n_dcs": self.n_dcs,
            "window_s": self.window_s,
            "window_index": self.window_index,
            "windows_recorded": len(self.history),
            "total_observed": self.total_observed,
            "pending_forecasts": len(self._pending_forecasts),
        }
