"""Pluggable per-origin intensity forecasters for the demand plane.

A forecaster maps one origin DC's *intensity history* — the per-window
request-weight rates recorded by :class:`~repro.demand.ODDemandLayer` — to a
predicted intensity ``horizon`` windows ahead.  Forecasters are stateless
over the series (the layer owns the history), so one instance serves every
origin and re-forecasting after a resume is deterministic.

``SeasonalForecaster`` is the follow-the-sun workhorse: diurnal demand is a
level times a repeating phase shape, so it decomposes the series into an
EWMA level and multiplicative per-phase seasonal indices and recomposes at
the target phase — it anticipates a handoff the EWMA level alone can only
lag behind.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "Forecaster",
    "ZeroForecaster",
    "PersistenceForecaster",
    "EWMAForecaster",
    "SeasonalForecaster",
]

_EPS = 1e-12


class Forecaster:
    """Interface: predict one origin's intensity ``horizon`` windows ahead.

    ``series`` is the chronological per-window intensity history of a single
    origin (``[W]`` floats, oldest first; possibly empty).  Implementations
    must be pure functions of ``(series, horizon)``.
    """

    name = "base"

    def forecast(self, series: np.ndarray, horizon: int = 1) -> float:
        raise NotImplementedError


class ZeroForecaster(Forecaster):
    """Predicts zero demand everywhere — the null forecast.

    A predictive policy driven by this forecaster plans empty pre-stage
    move-sets, so it must be replica-set- and route-identical to the
    reactive policy (the behavior-preservation differential in
    ``tests/test_demand.py``)."""

    name = "zero"

    def forecast(self, series: np.ndarray, horizon: int = 1) -> float:
        return 0.0


class PersistenceForecaster(Forecaster):
    """Identity / persistence forecast: tomorrow looks like the last window."""

    name = "persistence"

    def forecast(self, series: np.ndarray, horizon: int = 1) -> float:
        return float(series[-1]) if len(series) else 0.0


class EWMAForecaster(Forecaster):
    """Exponentially-weighted level; horizon-independent (flat) forecast."""

    name = "ewma"

    def __init__(self, alpha: float = 0.4) -> None:
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)

    def forecast(self, series: np.ndarray, horizon: int = 1) -> float:
        if not len(series):
            return 0.0
        level = float(series[0])
        for x in series[1:]:
            level = (1.0 - self.alpha) * level + self.alpha * float(x)
        return max(0.0, level)


class SeasonalForecaster(Forecaster):
    """Multiplicative diurnal decomposition: EWMA level x per-phase index.

    ``period`` is the cycle length in demand windows (e.g. 8 windows per
    simulated day).  Each observation updates the level and the seasonal
    index of its phase bin; the forecast recomposes ``level * season[phase]``
    at the target phase — so a demand peak that visits the same phase every
    cycle is predicted *before* it arrives, which is exactly what pre-staging
    needs during follow-the-sun handoffs.
    """

    name = "seasonal"

    def __init__(
        self, period: int, alpha: float = 0.3, season_alpha: float = 0.5
    ) -> None:
        if period < 1:
            raise ValueError(f"period must be >= 1, got {period}")
        self.period = int(period)
        self.alpha = float(alpha)
        self.season_alpha = float(season_alpha)

    def forecast(self, series: np.ndarray, horizon: int = 1) -> float:
        W = len(series)
        if W == 0:
            return 0.0
        level = max(float(series[0]), _EPS)
        season = np.ones(self.period, dtype=np.float64)
        sa = self.season_alpha
        for t in range(W):
            x = float(series[t])
            if t > 0:
                level = (1.0 - self.alpha) * level + self.alpha * x
            ph = t % self.period
            season[ph] = (1.0 - sa) * season[ph] + sa * (x / max(level, _EPS))
        phase = (W + int(horizon) - 1) % self.period
        return max(0.0, level * float(season[phase]))
