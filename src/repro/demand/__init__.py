"""Demand plane: the single owner of online request heat and its forecasts.

Before this package, per-(origin DC, region) request heat was bookkept three
times over — ``GeoGraphStore`` scattered observations into per-DC
``HeatCache`` arrays, ``core.placement`` kept its own copies for eviction,
and ``serve.policy`` triggered maintenance off yet another view.  The
:class:`ODDemandLayer` (origin-destination demand, after MnMS's
``OriginDestinationLayer``) now owns the one ``[D, n_items]`` heat table:

  * the serving path (``serve_online`` / ``serve_batch``) deposits request
    heat here, and every :class:`~repro.core.placement.HeatCache` reads its
    per-DC row as a shared-storage view (Alg. 3 eviction semantics intact);
  * windowed origin-destination statistics (per-window intensity history,
    EWMA read rates, per-origin item profiles) feed both the *measured*
    demand view the reactive policy plans against and the *forecast* view a
    predictive :class:`~repro.serve.MaintenancePolicy` pre-stages against;
  * a pluggable :class:`Forecaster` (EWMA / seasonal diurnal-decomposition)
    predicts per-origin intensity one window ahead; forecast error is
    settled against realized intensity through the obs registry.
"""
from .forecast import (  # noqa: F401
    EWMAForecaster,
    Forecaster,
    PersistenceForecaster,
    SeasonalForecaster,
    ZeroForecaster,
)
from .od_layer import DemandView, ODDemandLayer  # noqa: F401

__all__ = [
    "ODDemandLayer",
    "DemandView",
    "Forecaster",
    "EWMAForecaster",
    "SeasonalForecaster",
    "PersistenceForecaster",
    "ZeroForecaster",
]
