"""Nested span tracing with an injectable clock.

A :class:`Tracer` produces :class:`SpanRecord` rows under any monotonic
clock — ``time.perf_counter`` for wall-clock store work, or the serving
scheduler's :class:`~repro.serve.scheduler.SimClock` so control-plane
traces are fully deterministic (same seed → byte-identical export).

Two ways to produce spans:

* ``with tracer.span("route", track="store", layer=2): ...`` — live
  context-manager spans; parenting follows the nesting stack.
* ``tracer.record("request", t0, t1, track="requests", parent=sid, ...)``
  — explicit-timestamp spans for events whose start/end were computed by
  a simulator rather than observed live.

Records are held in a bounded deque so a forgotten tracer can never grow
without limit.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from .metrics import get_registry

__all__ = ["Span", "SpanRecord", "Tracer"]


@dataclass
class SpanRecord:
    """One finished span. Times are in the tracer's clock domain (seconds)."""

    sid: int
    name: str
    t0: float
    t1: float
    track: str = "main"
    parent: Optional[int] = None
    tags: Dict[str, object] = field(default_factory=dict)

    @property
    def dur_s(self) -> float:
        return self.t1 - self.t0


class Span:
    """A live span; ``end()`` is idempotent and happens automatically when
    used as a context manager."""

    __slots__ = ("_tracer", "sid", "name", "t0", "t1", "track", "parent", "tags")

    def __init__(self, tracer: "Tracer", sid: int, name: str, t0: float,
                 track: str, parent: Optional[int], tags: Dict[str, object]):
        self._tracer = tracer
        self.sid = sid
        self.name = name
        self.t0 = t0
        self.t1: Optional[float] = None
        self.track = track
        self.parent = parent
        self.tags = tags

    def elapsed_s(self) -> float:
        """Seconds since the span started (final duration once ended)."""
        if self.t1 is not None:
            return self.t1 - self.t0
        return self._tracer.clock() - self.t0

    def end(self) -> float:
        if self.t1 is None:
            self.t1 = self._tracer.clock()
            self._tracer._finish(self)
        return self.t1 - self.t0

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()


class _NoopSpan:
    """Stand-in returned by a disabled tracer; still measures elapsed time
    so report fields (``apply_time_s`` etc.) stay correct when telemetry
    is off."""

    __slots__ = ("_clock", "t0", "t1")
    sid = None
    parent = None

    def __init__(self, clock: Callable[[], float]):
        self._clock = clock
        self.t0 = clock()
        self.t1: Optional[float] = None

    def elapsed_s(self) -> float:
        if self.t1 is not None:
            return self.t1 - self.t0
        return self._clock() - self.t0

    def end(self) -> float:
        if self.t1 is None:
            self.t1 = self._clock()
        return self.t1 - self.t0

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        self.end()


class Tracer:
    """Span collector.

    Parameters
    ----------
    clock:
        Zero-arg callable returning seconds.  Defaults to
        ``time.perf_counter``; pass ``SimClock.now`` (bound method) for
        deterministic simulated-time traces.
    enabled:
        ``True``/``False`` force the state; ``None`` (default) follows the
        process-default metrics registry, so flipping telemetry on in one
        place lights up both metrics and traces.
    max_spans:
        Bound on retained finished spans (oldest evicted first).
    """

    def __init__(
        self,
        clock: Callable[[], float] = time.perf_counter,
        enabled: Optional[bool] = None,
        max_spans: int = 1_000_000,
    ):
        self.clock = clock
        self._enabled = enabled
        self.records: deque = deque(maxlen=max_spans)
        self._next_sid = 0
        self._stack: list = []  # sids of open context-manager spans

    @property
    def enabled(self) -> bool:
        if self._enabled is None:
            return get_registry().enabled
        return self._enabled

    # -- span production ---------------------------------------------------
    def span(self, name: str, track: str = "main", **tags):
        """Open a live span; use as a context manager or call ``end()``."""
        if not self.enabled:
            return _NoopSpan(self.clock)
        sid = self._next_sid
        self._next_sid += 1
        parent = self._stack[-1] if self._stack else None
        self._stack.append(sid)
        return Span(self, sid, name, self.clock(), track, parent, tags)

    def record(
        self,
        name: str,
        t0: float,
        t1: float,
        track: str = "main",
        parent: Optional[int] = None,
        **tags,
    ) -> Optional[int]:
        """Record a span with explicit timestamps; returns its sid (or
        ``None`` when disabled) so callers can parent children onto it."""
        if not self.enabled:
            return None
        sid = self._next_sid
        self._next_sid += 1
        self.records.append(
            SpanRecord(sid, name, t0, t1, track=track, parent=parent, tags=tags)
        )
        return sid

    def _finish(self, span: Span) -> None:
        # context-manager spans may end out of LIFO order under odd control
        # flow; remove this sid wherever it sits in the stack
        try:
            self._stack.remove(span.sid)
        except ValueError:
            pass
        self.records.append(
            SpanRecord(
                span.sid, span.name, span.t0, span.t1,
                track=span.track, parent=span.parent, tags=span.tags,
            )
        )

    # -- lifecycle ---------------------------------------------------------
    def reset(self) -> None:
        self.records.clear()
        self._stack.clear()
        self._next_sid = 0

    def __len__(self) -> int:
        return len(self.records)
