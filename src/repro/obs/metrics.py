"""Metrics registry (stdlib + numpy only): counters, gauges, and streaming
quantile histograms.

Everything here is bounded-memory by construction.  Histograms use the
P-squared (P²) streaming-quantile sketch of Jain & Chlamtac (1985): five
markers per tracked quantile, adjusted with a parabolic (fallback linear)
update on every observation.  No sample list is ever kept, so a histogram
costs O(1) memory no matter how many values it absorbs.

The process-default registry starts *disabled*: every instrument handed
out by a disabled registry is a shared no-op singleton, so instrumented
hot paths cost one attribute load and a branch.  Components that want
telemetry either flip the default registry on (``get_registry().enable()``)
or install their own via :func:`set_default_registry`.
"""

from __future__ import annotations

import json
import math
import threading
from typing import Dict, Iterable, Mapping, Optional, Tuple

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MatrixCounter",
    "MetricsRegistry",
    "P2Quantile",
    "get_registry",
    "set_default_registry",
]

TagKey = Tuple[Tuple[str, str], ...]


def _tag_key(tags: Mapping[str, object]) -> TagKey:
    return tuple(sorted((k, str(v)) for k, v in tags.items()))


class P2Quantile:
    """P² streaming estimator for a single quantile ``q`` (0 < q < 1).

    Keeps 5 marker heights/positions; after 5 observations each ``add``
    is O(1).  Estimates are exact until the 5th sample, then converge to
    the true quantile as the stream grows.
    """

    __slots__ = ("q", "n", "_heights", "_pos", "_want", "_dwant")

    # max settle passes per add_many batch (see the comment there)
    SETTLE_PASSES = 2

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = q
        self.n = 0
        self._heights: list = []
        self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
        self._want = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0]
        self._dwant = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]

    def add(self, x: float) -> None:
        self.n += 1
        h = self._heights
        if len(h) < 5:
            h.append(x)
            h.sort()
            return
        # locate the cell containing x, clamping the extreme markers
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while x >= h[k + 1]:
                k += 1
        pos = self._pos
        for i in range(k + 1, 5):
            pos[i] += 1.0
        want = self._want
        for i in range(5):
            want[i] += self._dwant[i]
        # nudge interior markers toward their desired positions
        self._nudge(1)
        self._nudge(2)
        self._nudge(3)

    def add_many(self, sorted_values) -> None:
        """Absorb a pre-sorted batch in one pass (batch-P²).

        Marker positions advance by per-batch rank counts (one searchsorted
        across the markers) instead of once per observation, then the
        interior heights are nudged toward their desired positions with the
        usual parabolic/linear steps, iterated until the markers settle.
        Statistically this matches scalar P² — both are O(1)-memory
        approximations whose error vanishes as the stream grows — at a
        per-batch cost that no longer scales with the batch size.
        """
        m = len(sorted_values)
        if m == 0:
            return
        h = self._heights
        if len(h) < 5:
            if self.n == 0 and m >= 5:
                # markers placed straight at their desired ranks — feeding
                # the 5 *smallest* values instead (the batch is sorted!)
                # would pin the low markers at the distribution floor with
                # unit position gaps, deadlocking every later adjustment
                self._init_from_sorted(sorted_values)
            else:
                for v in sorted_values:
                    self.add(float(v))
            return
        vals = sorted_values
        self.n += m
        lo, hi = float(vals[0]), float(vals[-1])
        if lo < h[0]:
            h[0] = lo
        if hi >= h[4]:
            h[4] = hi
        # interior markers advance by their batch rank (#values strictly
        # below, matching the scalar cell search); the max marker absorbs
        # every observation
        below = np.searchsorted(vals, h[1:4], side="left")
        pos = self._pos
        pos[1] += float(below[0])
        pos[2] += float(below[1])
        pos[3] += float(below[2])
        pos[4] += float(m)
        want = self._want
        dwant = self._dwant
        for i in range(1, 5):
            want[i] += m * dwant[i]
        # settle: each pass moves an out-of-place marker one position.  The
        # pass count is capped — heavily tied streams (discrete latency
        # values) otherwise make markers chase their desired rank for ~m
        # passes per batch.  Residual want-pos deviation is zero-mean and
        # carries over, so later batches absorb it; the height estimate
        # oscillates inside the tie neighbourhood, which is the correct
        # quantile there anyway.
        # pass budget scales with the batch so pooled (buffered) batches get
        # proportionally more settle opportunities — a flat cap starves the
        # markers when thousands of values arrive in one flush
        for _ in range(min(m, self.SETTLE_PASSES + m // 256)):
            moved = self._nudge(1)
            moved |= self._nudge(2)
            moved |= self._nudge(3)
            if not moved:
                break

    def _init_from_sorted(self, vals) -> None:
        """Seed all five markers from one sorted batch: heights at the
        desired rank positions, which is the fixed point scalar P² converges
        toward for a stream with this empirical distribution."""
        m = len(vals)
        q = self.q
        self.n = m
        pos = [
            1.0,
            1.0 + (m - 1) * q / 2.0,
            1.0 + (m - 1) * q,
            1.0 + (m - 1) * (1.0 + q) / 2.0,
            float(m),
        ]
        self._pos = list(pos)
        self._want = list(pos)
        self._heights = [float(vals[int(round(p)) - 1]) for p in pos]

    def _nudge(self, i: int) -> bool:
        h, pos, want = self._heights, self._pos, self._want
        d = want[i] - pos[i]
        if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or (
            d <= -1.0 and pos[i - 1] - pos[i] < -1.0
        ):
            d = 1.0 if d > 0 else -1.0
            hp = self._parabolic(i, d)
            if h[i - 1] < hp < h[i + 1]:
                h[i] = hp
            else:  # parabolic step would cross a neighbour: go linear
                j = i + int(d)
                h[i] = h[i] + d * (h[j] - h[i]) / (pos[j] - pos[i])
            pos[i] += d
            return True
        return False

    def _parabolic(self, i: int, d: float) -> float:
        h, pos = self._heights, self._pos
        return h[i] + d / (pos[i + 1] - pos[i - 1]) * (
            (pos[i] - pos[i - 1] + d) * (h[i + 1] - h[i]) / (pos[i + 1] - pos[i])
            + (pos[i + 1] - pos[i] - d) * (h[i] - h[i - 1]) / (pos[i] - pos[i - 1])
        )

    def value(self) -> float:
        h = self._heights
        if not h:
            return math.nan
        if len(h) < 5 or self.n <= 5:
            # exact small-sample quantile (nearest-rank interpolation)
            idx = self.q * (len(h) - 1)
            lo = int(idx)
            hi = min(lo + 1, len(h) - 1)
            return h[lo] + (idx - lo) * (h[hi] - h[lo])
        return h[2]


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "tags", "value")

    def __init__(self, name: str, tags: TagKey = ()):
        self.name = name
        self.tags = tags
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}

    def reset(self) -> None:
        self.value = 0.0


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "tags", "value")

    def __init__(self, name: str, tags: TagKey = ()):
        self.name = name
        self.tags = tags
        self.value = math.nan

    def set(self, value: float) -> None:
        self.value = float(value)

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}

    def reset(self) -> None:
        self.value = math.nan


class Histogram:
    """Streaming histogram: count/sum/min/max plus P² quantile sketches."""

    __slots__ = (
        "name", "tags", "quantiles", "count", "sum", "min", "max",
        "_sketches", "_buf", "_buf_n",
    )

    DEFAULT_QUANTILES = (0.5, 0.9, 0.99)

    # batches accumulate here before the P² sketches see them: marker math
    # costs ~50-100us of cold-cache Python per batch, which the 5% serving
    # telemetry budget cannot pay at every serve_batch.  count/sum/min/max
    # stay exact per batch; sketches are fed the pooled sorted buffer once
    # it crosses this many values (or on any quantile read)
    FLUSH_AT = 8192

    def __init__(
        self,
        name: str,
        tags: TagKey = (),
        quantiles: Iterable[float] = DEFAULT_QUANTILES,
    ):
        self.name = name
        self.tags = tags
        self.quantiles = tuple(quantiles)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._sketches = [P2Quantile(q) for q in self.quantiles]
        self._buf: list = []
        self._buf_n = 0

    def observe(self, value: float) -> None:
        if self._buf_n:
            self._flush()
        value = float(value)
        self.count += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        for s in self._sketches:
            s.add(value)

    def observe_many(self, values) -> None:
        """Vectorized :meth:`observe` for a whole batch.

        count/sum/min/max update immediately (exact at every read); the
        values are buffered and fed to the P² sketches — one shared sort,
        batch-P² per sketch — only when :attr:`FLUSH_AT` values have pooled
        or a quantile is read, amortizing the marker math across batches."""
        vals = np.asarray(values, dtype=float)
        m = int(vals.size)
        if m == 0:
            return
        if m == 1:
            self.observe(float(vals[0]))
            return
        self.count += m
        self.sum += float(vals.sum())
        lo = float(vals.min())
        hi = float(vals.max())
        if lo < self.min:
            self.min = lo
        if hi > self.max:
            self.max = hi
        self._buf.append(vals)
        self._buf_n += m
        if self._buf_n >= self.FLUSH_AT:
            self._flush()

    def _flush(self) -> None:
        buf = self._buf
        if not buf:
            return
        vals = buf[0] if len(buf) == 1 else np.concatenate(buf)
        vals = np.sort(vals, axis=None)
        self._buf = []
        self._buf_n = 0
        for s in self._sketches:
            s.add_many(vals)

    def quantile(self, q: float) -> float:
        if self._buf_n:
            self._flush()
        for s in self._sketches:
            if s.q == q:
                return s.value()
        raise KeyError(f"quantile {q} not tracked by {self.name}")

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def snapshot(self) -> dict:
        if self._buf_n:
            self._flush()
        return {
            "type": "histogram",
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min if self.count else math.nan,
            "max": self.max if self.count else math.nan,
            "quantiles": {f"p{q * 100:g}": s.value() for q, s in zip(self.quantiles, self._sketches)},
        }

    def reset(self) -> None:
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._sketches = [P2Quantile(q) for q in self.quantiles]
        self._buf = []
        self._buf_n = 0


class MatrixCounter:
    """2-D grid of counters addressed by integer tag pairs.

    Hot paths that account a whole ``[n, m]`` matrix per batch (per-link WAN
    bytes keyed ``(src DC, dst DC)``) pay one numpy add instead of one
    registry lookup per cell.  :meth:`MetricsRegistry.snapshot` expands the
    nonzero cells into ordinary per-cell counter entries, so consumers see
    the same shape as individually tagged counters.
    """

    __slots__ = ("name", "tags", "axes", "value")

    def __init__(self, name: str, tags: TagKey = (), axes: Tuple[str, str] = ("i", "j")):
        self.name = name
        self.tags = tags
        self.axes = axes
        self.value = np.zeros((0, 0))

    def add(self, mat) -> None:
        mat = np.asarray(mat, dtype=float)
        if mat.shape != self.value.shape:
            grown = np.zeros(
                (
                    max(mat.shape[0], self.value.shape[0]),
                    max(mat.shape[1], self.value.shape[1]),
                )
            )
            grown[: self.value.shape[0], : self.value.shape[1]] = self.value
            self.value = grown
        self.value[: mat.shape[0], : mat.shape[1]] += mat

    def cells(self):
        """Yield ``(tag_repr, counter_snapshot)`` for every nonzero cell."""
        ai, aj = self.axes
        for i, j in zip(*(a.tolist() for a in np.nonzero(self.value))):
            yield f"{ai}={i},{aj}={j}", {
                "type": "counter",
                "value": float(self.value[i, j]),
            }

    def snapshot(self) -> dict:
        return {"type": "counter_grid", "cells": dict(self.cells())}

    def reset(self) -> None:
        self.value = np.zeros((0, 0))


class _NoopInstrument:
    """Shared do-nothing stand-in handed out by a disabled registry."""

    __slots__ = ()

    def inc(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def observe_many(self, values) -> None:
        pass

    def add(self, mat) -> None:
        pass

    value = math.nan
    count = 0
    sum = 0.0

    def quantile(self, q: float) -> float:
        return math.nan


_NOOP = _NoopInstrument()


class MetricsRegistry:
    """Keyed store of instruments.

    Instruments are keyed on ``(name, sorted tags)``; asking twice for the
    same key returns the same object.  A disabled registry hands out a
    shared no-op singleton instead, so call sites never branch themselves.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._instruments: Dict[Tuple[str, TagKey], object] = {}
        # hot callers park pre-resolved instrument handles here (keyed by
        # caller-chosen name) so a serve-path batch pays one dict get
        # instead of one keyed lookup per instrument; cleared with the
        # instruments so handles can never outlive them
        self._handle_cache: Dict[str, object] = {}

    # -- lifecycle ---------------------------------------------------------
    def enable(self) -> "MetricsRegistry":
        self.enabled = True
        return self

    def disable(self) -> "MetricsRegistry":
        self.enabled = False
        return self

    def reset(self) -> None:
        with self._lock:
            for inst in self._instruments.values():
                inst.reset()

    def clear(self) -> None:
        with self._lock:
            self._instruments.clear()
            self._handle_cache.clear()

    # -- instrument accessors ---------------------------------------------
    def _get_keyed(self, cls, name: str, key: TagKey, **kw):
        if not self.enabled:
            return _NOOP
        k = (name, key)
        inst = self._instruments.get(k)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(k)
                if inst is None:
                    inst = cls(name, key, **kw)
                    self._instruments[k] = inst
        return inst

    def _get(self, cls, name: str, tags: Mapping[str, object], **kw):
        return self._get_keyed(cls, name, _tag_key(tags), **kw)

    def counter(self, name: str, **tags) -> Counter:
        return self._get(Counter, name, tags)

    def counter_keyed(self, name: str, key: TagKey) -> Counter:
        """Hot-path :meth:`counter`: takes the already-normalized tag key
        (the ``tuple(sorted((k, str(v))))`` form), skipping per-call tag
        sorting/stringification — for call sites that cache their keys."""
        return self._get_keyed(Counter, name, key)

    def counter_grid(self, name: str, axes: Tuple[str, str]) -> MatrixCounter:
        """Grid of counters over two integer-valued tag axes; one
        :meth:`MatrixCounter.add` accounts a whole matrix per batch."""
        return self._get_keyed(MatrixCounter, name, (), axes=axes)

    def gauge(self, name: str, **tags) -> Gauge:
        return self._get(Gauge, name, tags)

    def histogram(
        self,
        name: str,
        quantiles: Iterable[float] = Histogram.DEFAULT_QUANTILES,
        **tags,
    ) -> Histogram:
        return self._get(Histogram, name, tags, quantiles=quantiles)

    # -- aggregation -------------------------------------------------------
    @staticmethod
    def merge(snapshots: Iterable[Mapping[str, Mapping[str, dict]]]) -> dict:
        """Merge per-shard :meth:`snapshot` dicts into one aggregate view.

        The sharded store's per-shard registries export independently; this
        folds them into a single dashboard/trace-exportable snapshot:

          * counters sum (matrix-counter cells already export as per-cell
            counters, so per-link byte grids add element-wise);
          * gauges keep the last non-NaN write (snapshot order);
          * histograms merge exactly on count/sum/min/max (mean recomputed)
            and approximately on quantiles — a count-weighted average of the
            per-shard P² estimates, the standard sketch-merge compromise.

        Returns a plain dict in :meth:`snapshot` shape.
        """
        out: Dict[str, Dict[str, dict]] = {}
        for snap in snapshots:
            for name, by_tag in snap.items():
                dst_by = out.setdefault(name, {})
                for tag, inst in by_tag.items():
                    cur = dst_by.get(tag)
                    if cur is None:
                        dst_by[tag] = {
                            k: (dict(v) if isinstance(v, dict) else v)
                            for k, v in inst.items()
                        }
                        if inst.get("type") == "histogram":
                            # stash the weights quantile-averaging needs
                            dst_by[tag]["_qweight"] = {
                                q: inst["count"]
                                for q, v in inst.get("quantiles", {}).items()
                                if not math.isnan(v)
                            }
                        continue
                    if cur["type"] != inst["type"]:
                        raise ValueError(
                            f"{name}/{tag}: cannot merge {inst['type']} "
                            f"into {cur['type']}"
                        )
                    if cur["type"] == "counter":
                        cur["value"] += inst["value"]
                    elif cur["type"] == "gauge":
                        if not math.isnan(inst["value"]):
                            cur["value"] = inst["value"]
                    elif cur["type"] == "histogram":
                        _merge_histogram_snapshots(cur, inst)
                    else:
                        raise ValueError(
                            f"{name}/{tag}: unmergeable type {cur['type']!r}"
                        )
        for by_tag in out.values():
            for inst in by_tag.values():
                inst.pop("_qweight", None)
        return out

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """Nested-dict view: ``{name: {tag_repr: instrument_snapshot}}``."""
        out: Dict[str, dict] = {}
        with self._lock:
            items = sorted(self._instruments.items())
        for (name, tags), inst in items:
            if isinstance(inst, MatrixCounter):
                out.setdefault(name, {}).update(inst.cells())
                continue
            tag_repr = ",".join(f"{k}={v}" for k, v in tags) or "-"
            out.setdefault(name, {})[tag_repr] = inst.snapshot()
        return out

    def to_json(self, path: Optional[str] = None, indent: int = 2) -> str:
        text = json.dumps(self.snapshot(), indent=indent, sort_keys=True, default=str)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text


def _merge_histogram_snapshots(cur: dict, inst: dict) -> None:
    """Fold histogram snapshot ``inst`` into ``cur`` (in place).

    count/sum/min/max merge exactly; each tracked quantile becomes the
    count-weighted average of the shard estimates (``_qweight`` carries the
    accumulated weight per quantile so later folds stay correctly weighted).
    """
    n_new = inst["count"]
    cur["count"] += n_new
    cur["sum"] += inst["sum"]
    cur["mean"] = cur["sum"] / cur["count"] if cur["count"] else math.nan
    for key, pick in (("min", min), ("max", max)):
        v = inst[key]
        if not math.isnan(v):
            cur[key] = v if math.isnan(cur[key]) else pick(cur[key], v)
    weights = cur.setdefault("_qweight", {})
    quant = cur.setdefault("quantiles", {})
    for q, v in inst.get("quantiles", {}).items():
        if math.isnan(v) or n_new == 0:
            continue
        w_old = weights.get(q, 0)
        old = quant.get(q, math.nan)
        if w_old == 0 or math.isnan(old):
            quant[q] = v
        else:
            quant[q] = (old * w_old + v * n_new) / (w_old + n_new)
        weights[q] = w_old + n_new


_default_registry = MetricsRegistry(enabled=False)  # geolint: allow[GL001]


def get_registry() -> MetricsRegistry:
    """The process-default registry (starts disabled)."""
    return _default_registry


def set_default_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Install ``registry`` as the process default; returns the previous one."""
    global _default_registry
    old = _default_registry
    _default_registry = registry
    return old
