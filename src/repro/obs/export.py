"""Exporters: Chrome trace-event JSON (Perfetto / chrome://tracing) and a
plain-text dashboard.

The Chrome exporter is deterministic by construction: tracks are mapped
to pids in sorted-name order, spans are emitted sorted by ``(t0, sid)``,
and the JSON is dumped with sorted keys — so two runs that produced
identical span streams serialize to byte-identical files.  Overlapping
root spans within a track are spread across lanes (tids) greedily;
children always render in their root's lane so nesting stays visually
intact.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

from .metrics import MetricsRegistry
from .trace import SpanRecord, Tracer

__all__ = ["export_chrome_trace", "text_dashboard"]

_US = 1e6  # trace-event timestamps are microseconds


def _lane_assignment(spans: List[SpanRecord]) -> Dict[int, int]:
    """Map sid → lane so overlapping roots get distinct lanes and every
    child inherits its root's lane."""
    by_sid = {s.sid: s for s in spans}

    def root_of(s: SpanRecord) -> SpanRecord:
        while s.parent is not None and s.parent in by_sid:
            s = by_sid[s.parent]
        return s

    roots = sorted(
        {root_of(s).sid for s in spans},
        key=lambda sid: (by_sid[sid].t0, sid),
    )
    lane_free: List[float] = []  # per-lane time the lane frees up
    root_lane: Dict[int, int] = {}
    for sid in roots:
        s = by_sid[sid]
        for i, free in enumerate(lane_free):
            if s.t0 >= free:
                root_lane[sid] = i
                lane_free[i] = s.t1
                break
        else:
            root_lane[sid] = len(lane_free)
            lane_free.append(s.t1)
    return {s.sid: root_lane[root_of(s).sid] for s in spans}


def export_chrome_trace(tracer: Tracer, path: Optional[str] = None) -> str:
    """Serialize the tracer's spans as Chrome trace-event JSON.

    Returns the JSON string; also writes it to ``path`` when given.  Load
    the file in https://ui.perfetto.dev or chrome://tracing.
    """
    spans = sorted(tracer.records, key=lambda s: (s.t0, s.sid))
    tracks = sorted({s.track for s in spans})
    pid_of = {track: i + 1 for i, track in enumerate(tracks)}

    events: List[dict] = []
    for track in tracks:
        events.append(
            {
                "ph": "M",
                "pid": pid_of[track],
                "tid": 0,
                "name": "process_name",
                "args": {"name": track},
            }
        )
    for track in tracks:
        track_spans = [s for s in spans if s.track == track]
        lanes = _lane_assignment(track_spans)
        for s in track_spans:
            events.append(
                {
                    "ph": "X",
                    "pid": pid_of[track],
                    "tid": lanes[s.sid],
                    "name": s.name,
                    "ts": round(s.t0 * _US, 3),
                    "dur": round(max(s.t1 - s.t0, 0.0) * _US, 3),
                    "args": {k: str(v) for k, v in sorted(s.tags.items())},
                }
            )
    text = json.dumps({"traceEvents": events}, sort_keys=True, separators=(",", ":"))
    if path is not None:
        with open(path, "w") as f:
            f.write(text + "\n")
    return text


def text_dashboard(registry: MetricsRegistry, tracer: Optional[Tracer] = None) -> str:
    """Human-readable one-screen summary of a registry (and optionally the
    span counts of a tracer)."""
    lines: List[str] = ["== metrics =="]
    snap = registry.snapshot()
    if not snap:
        lines.append("(no instruments recorded)")
    for name in sorted(snap):
        for tag_repr in sorted(snap[name]):
            row = snap[name][tag_repr]
            label = name if tag_repr == "-" else f"{name}{{{tag_repr}}}"
            if row["type"] == "histogram":
                q = row["quantiles"]
                qtxt = " ".join(f"{k}={v:.6g}" for k, v in sorted(q.items()))
                lines.append(
                    f"{label:58s} n={row['count']:<8d} mean={row['mean']:.6g} {qtxt}"
                )
            else:
                lines.append(f"{label:58s} {row['type']}={row['value']:.6g}")
    if tracer is not None:
        lines.append("== spans ==")
        counts: Dict[str, int] = {}
        for s in tracer.records:
            counts[f"{s.track}/{s.name}"] = counts.get(f"{s.track}/{s.name}", 0) + 1
        if not counts:
            lines.append("(no spans recorded)")
        for key in sorted(counts):
            lines.append(f"{key:58s} n={counts[key]}")
    return "\n".join(lines)
