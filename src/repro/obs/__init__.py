"""Telemetry subsystem: metrics registry, span tracer, exporters.

No dependencies beyond the stdlib and numpy (already required by every
plane — serving, placement, migration, kernels), so importing it never
touches jax import paths.

Quick start::

    from repro.obs import get_registry, Tracer, export_chrome_trace

    get_registry().enable()
    store = GeoGraphStore(g, env, workload)   # picks up default registry
    ... run work ...
    print(text_dashboard(get_registry(), store.tracer))
    export_chrome_trace(store.tracer, "store.trace.json")
"""

from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MatrixCounter,
    MetricsRegistry,
    P2Quantile,
    get_registry,
    set_default_registry,
)
from .trace import Span, SpanRecord, Tracer
from .export import export_chrome_trace, text_dashboard

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MatrixCounter",
    "MetricsRegistry",
    "P2Quantile",
    "Span",
    "SpanRecord",
    "Tracer",
    "export_chrome_trace",
    "get_registry",
    "set_default_registry",
    "text_dashboard",
]
