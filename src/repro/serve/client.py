"""Client-facing read-path API of the serving control plane.

The store surface splits three ways (paper §VI serving, run as an online
control problem):

  * :class:`StoreClient` (this module) — what application code holds.
    ``submit()`` takes the request payload *plus its serving contract*
    (origin DC, latency deadline, priority class) and returns a
    futures-style :class:`RequestHandle` immediately; routing happens when
    the :class:`~repro.serve.AdmissionController` drains.
  * ``AdmissionController`` (:mod:`repro.serve.scheduler`) — forms batches
    adaptively and owns the simulated clock.
  * ``MaintenancePolicy`` (:mod:`repro.serve.policy`) — background work in
    the idle gaps.

Handles replace the integer request ids of the retired FIFO frontend: the
result, dispatch/completion timestamps and deadline-miss verdict live on
the handle itself, so no side-table lookup survives the drain.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from ..core.routing import RouteResult

__all__ = ["RequestHandle", "StoreClient", "INTERACTIVE", "BULK"]

# priority classes: lower value drains first.  Deadlines default per class
# (see AdmissionConfig.default_deadlines); callers can pass any int.
INTERACTIVE = 0
BULK = 1


@dataclasses.dataclass
class RequestHandle:
    """Futures-style handle for one submitted pattern request.

    Timestamps are controller-clock seconds (simulated, deterministic).
    ``result`` is set exactly once, when the batch containing the request
    lands; until then the handle is pending.
    """

    rid: int
    items: np.ndarray
    origin: int
    # keyword-only from here: the legacy GraphRequest dataclass had `result`
    # as the 4th positional field, so a positional `priority` would let old
    # call sites silently stuff a RouteResult into it — force a TypeError
    _: dataclasses.KW_ONLY
    priority: int = INTERACTIVE
    deadline_s: float = math.inf  # latency budget relative to submission
    t_submit: float = 0.0
    t_dispatch: float = math.nan  # batch formation instant
    t_done: float = math.nan  # completion (router busy end + WAN straggler)
    result: Optional[RouteResult] = None

    @property
    def done(self) -> bool:
        return self.result is not None

    @property
    def latency_s(self) -> float:
        """Submission-to-completion latency (NaN while pending)."""
        return self.t_done - self.t_submit

    @property
    def wait_s(self) -> float:
        """Queueing delay before the batch was formed (NaN while pending)."""
        return self.t_dispatch - self.t_submit

    @property
    def deadline_missed(self) -> bool:
        return self.done and self.latency_s > self.deadline_s

    def value(self) -> RouteResult:
        """The routing outcome; raises while the request is still queued."""
        if self.result is None:
            raise RuntimeError(f"request {self.rid} is still pending")
        return self.result


class StoreClient:
    """Read-path API bound to one :class:`~repro.serve.AdmissionController`.

    ``submit`` is non-blocking: it registers the request (optionally at a
    future clock time ``at``, for replaying arrival traces) and returns the
    handle.  ``result`` drains the controller until the handle resolves.
    """

    def __init__(self, controller) -> None:
        self.controller = controller

    def submit(
        self,
        items: np.ndarray,
        origin: int,
        deadline_s: Optional[float] = None,
        priority: int = INTERACTIVE,
        at: Optional[float] = None,
    ) -> RequestHandle:
        return self.controller.submit(
            items, origin, deadline_s=deadline_s, priority=priority, at=at
        )

    def submit_pattern(self, pattern, origin: int, **kw) -> RequestHandle:
        return self.submit(pattern.items, origin, **kw)

    def result(self, handle: RequestHandle) -> RouteResult:
        """Resolve ``handle``, draining the controller if needed."""
        if not handle.done:
            self.controller.run_until_idle()
        return handle.value()
