"""Budgeted background maintenance interleaved into serving idle gaps.

The third leg of the control plane: the :class:`AdmissionController` owns
the clock and offers every idle gap (router quiescent, next arrival in the
future) to a :class:`MaintenancePolicy`, which spends it on background work
in priority order:

  1. **Migration transfer waves** — an in-flight flush
     (``store.begin_flush`` → :class:`~repro.streaming.migration.WaveApplier`)
     lands one :class:`~repro.streaming.migration.TransferWave` at a time;
     serving between waves always sees a placement-consistent route table
     (the PR 4 invariant, now scheduled instead of inline).
  2. **Delta compaction** — proactive ``store.compact()`` below the store's
     reactive tombstone trigger, charged at ``compact_cost_s``.
  3. **Heat maintenance** — periodic ``store.maintain()`` (Alg. 3 diffusion
     + eviction + residual paydown), charged at ``maintain_cost_s``.

**Closing the window loop** (the second ROADMAP gap): every applied wave
reports a *measured* transfer time (via the ``measure_wave`` hook; defaults
to the Eq. 1 estimate when no measurement exists).  The policy tracks the
EWMA of ``estimated / measured`` in :attr:`window_gain` and plans the next
flush with ``effective_window() = window_s * window_gain`` — links that ship
slower than Table I says shrink the byte budget per wave until estimates and
measurements agree, links that ship faster widen it.

**Predictive mode** (``predictive=True``): every time the store's demand
plane closes a window, the policy forecasts per-origin demand one window
ahead (:class:`~repro.demand.Forecaster` over the
:class:`~repro.demand.ODDemandLayer` history) and *pre-stages* replicas
against the forecast heat through the same ``begin_flush`` → wave machinery
— adds only (``theta_drop=0``), landed in idle gaps before the demand
arrives, epoch guards unchanged.  Each pre-staged replica is held in a
ledger and settled one window later against the demand plane's cumulative
od table: ``placement.prestage_hit`` if the destination DC actually read it,
``placement.prestage_wasted`` otherwise.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Deque, Dict, Optional, Tuple

import numpy as np

from ..streaming.migration import StaleFlushError

__all__ = ["MaintenanceConfig", "MaintenancePolicy"]


@dataclasses.dataclass
class MaintenanceConfig:
    window_s: float = 60.0  # target transfer window (pre-correction)
    budget_frac: Optional[float] = None  # WAN byte budget (None = store default)
    flush_every_s: Optional[float] = None  # periodic flush cadence (None = explicit)
    maintain_every_s: Optional[float] = None  # periodic maintain cadence
    maintain_cost_s: float = 0.050  # simulated cost of one maintain()
    compact_cost_s: float = 0.250  # simulated cost of one compact()
    compact_ratio: float = 0.15  # proactive threshold (< store's reactive 0.30)
    diffusion_steps: int = 4
    packing: str = "ff"  # wave packing ("ff" | "lpt")
    ewma_alpha: float = 0.5  # weight of the newest estimate/measured ratio
    min_window_gain: float = 0.05
    max_window_gain: float = 4.0
    plan_kw: Dict[str, object] = dataclasses.field(default_factory=dict)
    # ---- demand-plane planning ------------------------------------------
    # "store": periodic flushes plan against the store's warm-DHD
    # equilibrium over the static workload (the legacy reactive source).
    # "measured": they plan against the demand plane's measured EWMA view —
    # reacting to the traffic actually served.
    heat_source: str = "store"
    # ---- predictive pre-staging -----------------------------------------
    predictive: bool = False  # forecast-driven pre-stage flushes
    forecaster: Optional[object] = None  # demand.Forecaster (default: EWMA)
    prestage_horizon: int = 1  # demand windows ahead to forecast
    prestage_budget_frac: Optional[float] = None  # None = budget_frac/store default
    prestage_theta_add: float = 0.5  # add quantile for pre-stage plans


class MaintenancePolicy:
    """Spends idle gaps on migration waves, compaction and heat maintenance.

    ``measure_wave(wave) -> seconds`` injects the observed transfer time of
    an applied wave (a real deployment times the bulk RPC; tests and
    benchmarks model degraded links).  Liveness: if the next wave cannot fit
    even the offered gap, one wave is applied anyway — a flush never stalls
    forever behind short gaps (the controller clamps the clock advance to
    the gap, so serving is not pushed back by the overrun).
    """

    def __init__(
        self,
        store,
        config: Optional[MaintenanceConfig] = None,
        measure_wave: Optional[Callable[[object], float]] = None,
        tracer=None,
        registry=None,
    ) -> None:
        self.store = store
        self.cfg = config or MaintenanceConfig()
        self.measure_wave = measure_wave
        # an AdmissionController adopting this policy shares its sim-clock
        # tracer (so wave spans land on the serving timeline); standalone
        # users may inject their own
        self.tracer = tracer
        self._registry = registry
        self.window_gain = 1.0  # EWMA of estimated / measured wave makespan
        # ring-buffered like the controller's telemetry: the policy is
        # long-lived and periodic flushes would grow these without bound
        self.wave_log: Deque[Tuple[float, float]] = deque(maxlen=4096)
        self._applier = None
        self._flush_requested = False
        self._flush_kw: Dict[str, object] = {}
        self._last_flush: Optional[float] = None
        self._last_maintain: Optional[float] = None
        self.plans: Deque[object] = deque(maxlen=64)  # most recent flush plans
        self.n_flushes = 0
        self.n_waves = 0
        self.n_maintains = 0
        self.n_compactions = 0
        self.n_stale_flushes = 0  # appliers abandoned to an id-space change
        self.last_maintain_report: Optional[Dict[str, float]] = None
        # predictive pre-staging state
        self.forecaster = self.cfg.forecaster
        if self.cfg.predictive and self.forecaster is None:
            from ..demand import EWMAForecaster

            self.forecaster = EWMAForecaster()
        self._applier_prestage = False  # current applier is a pre-stage flush
        self._last_prestage_window = -1
        # planner-scaled (item_heat, read_rates) of the newest forecast,
        # folded into measured flushes so they don't undo fresh pre-stages
        self._last_forecast: Optional[Tuple[np.ndarray, np.ndarray]] = None
        # (id epoch, demand window, dst DC, items, od snapshot) per landed
        # pre-stage transfer; settled one full demand window later
        self._prestage_ledger: Deque[Tuple] = deque(maxlen=4096)
        self.n_prestage_flushes = 0
        self.prestage_hits = 0
        self.prestage_wasted = 0

    # ------------------------------------------------------------- triggers
    def request_flush(self, **plan_kw) -> None:
        """Arm a migration flush; it begins in the next idle gap."""
        self._flush_requested = True
        self._flush_kw = dict(plan_kw)

    @property
    def flush_in_progress(self) -> bool:
        return self._applier is not None

    def effective_window(self) -> float:
        """Measurement-corrected transfer window for the *next* schedule."""
        return self.cfg.window_s * self.window_gain

    def _reg(self):
        from ..obs import get_registry

        return self._registry if self._registry is not None else get_registry()

    def _trace_wave(self, t0: float, wave, measured_s: float) -> None:
        """Span + per-link byte telemetry for one applied transfer wave.

        ``t0`` is the simulated start (the idle-gap cursor), so wave spans
        interleave correctly with the controller's request spans when both
        share the sim-clock tracer."""
        tr = self.tracer
        traced = tr is not None and tr.enabled
        reg = self._reg()
        if not traced and not reg.enabled:
            return
        env = self.store.env
        t1 = t0 + measured_s
        root = None
        if traced:
            root = tr.record(
                "migration_wave", t0, t1, track="maintenance",
                wave=wave.index, nbytes=int(wave.nbytes),
                n_links=len(wave.links),
                est_makespan_s=round(wave.makespan_s, 6),
            )
        if reg.enabled:
            # one grid update per wave — the per-link loop must not pay a
            # string-keyed instrument lookup per link (GL004); grid cells
            # export per-(src,dst) exactly like the old tagged counters
            mat = np.zeros((env.n_dcs, env.n_dcs))
            for b in wave.links:
                mat[b.src, b.dst] += b.nbytes
            reg.counter_grid("migration.wan_bytes", axes=("src", "dst")).add(mat)
        if traced:
            for b in wave.links:
                est = b.nbytes / env.bw_Bps[b.src, b.dst] + env.rtt_s[b.src, b.dst]
                tr.record(
                    "link_transfer", t0, min(t0 + est, t1), track="maintenance",
                    parent=root, src=b.src, dst=b.dst, nbytes=int(b.nbytes),
                )
        if reg.enabled:
            reg.histogram("migration.wave_makespan_s").observe(measured_s)
            reg.gauge("maintenance.window_gain").set(self.window_gain)

    def _record_wave(self, estimated_s: float, measured_s: float) -> None:
        self.wave_log.append((float(estimated_s), float(measured_s)))
        if estimated_s > 0 and measured_s > 0:
            ratio = estimated_s / measured_s
            a = self.cfg.ewma_alpha
            self.window_gain = min(
                self.cfg.max_window_gain,
                max(self.cfg.min_window_gain,
                    (1.0 - a) * self.window_gain + a * ratio),
            )

    def _flush_due(self, now: float) -> bool:
        if self._applier is not None:
            return False
        if self._flush_requested:
            return True
        if self.cfg.flush_every_s is None:
            return False
        return self._last_flush is None or now - self._last_flush >= self.cfg.flush_every_s

    def _maintain_due(self, now: float) -> bool:
        if self.cfg.maintain_every_s is None:
            return False
        return (
            self._last_maintain is None
            or now - self._last_maintain >= self.cfg.maintain_every_s
        )

    # ------------------------------------------------------------ idle hook
    def on_idle(self, now: float, gap_s: float, quiescent: bool = True) -> float:
        """Fill up to ``gap_s`` seconds of router idle time; returns the
        simulated seconds actually consumed.

        ``quiescent=False`` withholds **compaction**: compacting renumbers
        item rows, which would invalidate raw item arrays held outside the
        store.  The controller passes True only when it is subscribed to the
        store's remap hook (its in-flight handles re-key automatically);
        callers without such protection pass False while requests are
        outstanding.  Waves and ``maintain()`` only change replica sets,
        never item ids, so they run regardless."""
        used = 0.0
        demand = getattr(self.store, "demand", None)
        if demand is not None:
            demand.advance_to(now)
            if self._prestage_ledger:
                self._settle_prestaged(demand)
        if self._flush_due(now):
            budget = (
                None if self.cfg.budget_frac is None
                else self.cfg.budget_frac * float(self.store.g.item_size().sum())
            )
            kw = dict(self.cfg.plan_kw)
            kw.update(self._flush_kw)
            if self.cfg.heat_source == "measured" and demand is not None:
                # plan against the traffic actually served (demand plane).
                # In predictive mode, fold the latest forecast in elementwise
                # (max): dropping a replica the policy *just* pre-staged for
                # the next window, because the measured view hasn't seen its
                # demand yet, would be incoherent.
                heat, rates = self._planner_scale(demand.measured())
                if self._last_forecast is not None:
                    f_heat, f_rates = self._last_forecast
                    if f_heat.shape == heat.shape:
                        heat = np.maximum(heat, f_heat)
                        rates = np.maximum(rates, f_rates)
                kw.setdefault("item_heat", heat)
                kw.setdefault("read_rates", rates)
            plan, self._applier = self.store.begin_flush(
                budget_bytes=budget,
                window_s=self.effective_window(),
                schedule=self.cfg.packing,
                **kw,
            )
            self._applier_prestage = False
            self.plans.append(plan)
            self._flush_requested = False
            self._flush_kw = {}
            self._last_flush = now
            self.n_flushes += 1
        elif (
            self._applier is None
            and self.cfg.predictive
            and demand is not None
            and len(demand.history)
            and demand.window_index > self._last_prestage_window
        ):
            # pre-stage flush: plan adds against *forecast* demand one window
            # ahead; waves land through the shared idle-gap loop below with
            # the epoch guards unchanged.  Never drops — the forecast earns
            # replicas, evicting on it is the measured paths' job.
            self._last_prestage_window = demand.window_index
            view = demand.forecast(
                self.forecaster, horizon=self.cfg.prestage_horizon
            )
            frac = (
                self.cfg.prestage_budget_frac
                if self.cfg.prestage_budget_frac is not None
                else self.cfg.budget_frac
            )
            budget = (
                None if frac is None
                else frac * float(self.store.g.item_size().sum())
            )
            heat, rates = self._planner_scale(view)
            self._last_forecast = (heat, rates)
            kw = dict(self.cfg.plan_kw)
            kw["item_heat"] = heat
            kw["read_rates"] = rates
            kw.setdefault("theta_add", self.cfg.prestage_theta_add)
            kw["theta_drop"] = 0.0
            plan, self._applier = self.store.begin_flush(
                budget_bytes=budget,
                window_s=self.effective_window(),
                schedule=self.cfg.packing,
                **kw,
            )
            self._applier_prestage = True
            self.plans.append(plan)
            self.n_prestage_flushes += 1
            if plan.schedule is not None:
                self._ledger_moves(demand, plan.schedule.local)
        # 1. land transfer waves while they fit (always at least one: a wave
        # wider than every gap must not stall the flush forever).  A
        # StaleFlushError (mutation/compaction renumbered ids mid-flight)
        # abandons the applier — already-landed adds are safe, drops never
        # released — and re-arms the flush for a fresh plan next gap.
        while self._applier is not None:
            wave = self._applier.peek()
            try:
                if wave is None:
                    self._applier.finish()  # drops release + constraint guard
                    self._applier = None
                    self._applier_prestage = False
                    break
                expected = wave.makespan_s / max(self.window_gain, 1e-9)
                if used + expected > gap_s and not (used == 0.0 and expected > gap_s):
                    break
                wave = self._applier.apply_next()
            except StaleFlushError:
                self._applier = None
                self.n_stale_flushes += 1
                if not self._applier_prestage:
                    self._flush_requested = True  # re-plan against the new ids
                self._applier_prestage = False
                break
            if self._applier_prestage and demand is not None:
                self._ledger_wave(demand, wave)
            measured = (
                self.measure_wave(wave) if self.measure_wave is not None
                else wave.makespan_s
            )
            self._record_wave(wave.makespan_s, measured)
            self._trace_wave(now + used, wave, measured)
            self.n_waves += 1
            used += measured
            if used >= gap_s:
                break
        if self._applier is not None:
            return used  # gap exhausted mid-flush; waves resume next gap
        # 2. proactive delta compaction (only with no requests in flight)
        if (
            quiescent
            and self.store.tombstone_ratio() >= self.cfg.compact_ratio
            and used + self.cfg.compact_cost_s <= gap_s
        ):
            if self.store.compact():
                self.n_compactions += 1
                self._trace_simple("compact", now + used, self.cfg.compact_cost_s)
                used += self.cfg.compact_cost_s
        # 3. periodic heat maintenance (diffusion + eviction + residual)
        if self._maintain_due(now) and used + self.cfg.maintain_cost_s <= gap_s:
            self.last_maintain_report = self.store.maintain(
                diffusion_steps=self.cfg.diffusion_steps
            )
            self._last_maintain = now
            self.n_maintains += 1
            self._trace_simple("maintain", now + used, self.cfg.maintain_cost_s)
            used += self.cfg.maintain_cost_s
        return used

    def _planner_scale(self, view) -> Tuple[np.ndarray, np.ndarray]:
        """Rescale a demand view to the workload's planner units.

        The demand plane reports true per-second rates; the migration
        planner's cost model (Eq. 14) was calibrated against the offline
        workload's ``r_xy``/``w_xy`` magnitudes, so per-second rates next to
        workload-scale write costs would price every add out.  Treating the
        view as a *redistribution* of the workload's total read volume keeps
        the read/write economics consistent.  An all-zero view passes
        through untouched (the zero-forecast differential relies on it
        producing an empty plan)."""
        wl = getattr(self.store, "workload", None)
        total = float(view.read_rates.sum())
        if wl is None or total <= 0.0:
            return view.item_heat, view.read_rates
        scale = float(wl.r_xy.sum()) / total
        return view.item_heat * scale, view.read_rates * scale

    # ------------------------------------------------------- prestage ledger
    def _ledger_wave(self, demand, wave) -> None:
        """Record one landed pre-stage wave: per destination DC, the shipped
        items and the demand plane's cumulative od weight at landing time."""
        epoch = getattr(self.store, "_id_epoch", 0)
        for b in wave.links:
            items = np.asarray(b.items)
            self._prestage_ledger.append((
                epoch, demand.window_index, int(b.dst), items.copy(),
                demand.od[b.dst, items].copy(),
            ))

    def _ledger_moves(self, demand, moves) -> None:
        """Record zero-byte local pre-stage adds (src == dst moves)."""
        if not moves:
            return
        epoch = getattr(self.store, "_id_epoch", 0)
        by_dc: Dict[int, list] = {}
        for m in moves:
            by_dc.setdefault(int(m.dc), []).append(int(m.item))
        for dc, its in by_dc.items():
            items = np.asarray(its, dtype=np.int64)
            self._prestage_ledger.append((
                epoch, demand.window_index, dc, items,
                demand.od[dc, items].copy(),
            ))

    def _settle_prestaged(self, demand) -> None:
        """Settle ledger entries at least one full demand window old: a
        pre-staged replica *hit* if its destination DC accumulated new od
        weight on the item since landing (the monotone od table is immune to
        diffusion/decay), else it was *wasted* WAN + storage.  Entries from a
        renumbered id space are unverifiable and dropped silently."""
        epoch = getattr(self.store, "_id_epoch", 0)
        reg = self._reg()
        keep: Deque[Tuple] = deque(maxlen=self._prestage_ledger.maxlen)
        hit_total = wasted_total = 0
        for entry in self._prestage_ledger:
            e_epoch, e_win, dc, items, od0 = entry
            if e_epoch != epoch:
                continue
            if demand.window_index <= e_win:
                keep.append(entry)  # target window still open
                continue
            hits = int((demand.od[dc, items] > od0).sum())
            wasted = int(len(items) - hits)
            self.prestage_hits += hits
            self.prestage_wasted += wasted
            hit_total += hits
            wasted_total += wasted
        # settle the counters once per drain, not per ledger entry (GL004)
        if reg.enabled:
            if hit_total:
                reg.counter("placement.prestage_hit").inc(hit_total)
            if wasted_total:
                reg.counter("placement.prestage_wasted").inc(wasted_total)
        self._prestage_ledger = keep

    def _trace_simple(self, name: str, t0: float, cost_s: float) -> None:
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.record(name, t0, t0 + cost_s, track="maintenance")

    def drain(self, now: float = 0.0) -> float:
        """Run all armed/outstanding maintenance to completion (unbounded
        gap) — the synchronous escape hatch for tests and shutdown paths."""
        return self.on_idle(now, math.inf)

    def stats(self) -> Dict[str, object]:
        return {
            "n_flushes": self.n_flushes,
            "n_waves": self.n_waves,
            "n_maintains": self.n_maintains,
            "n_compactions": self.n_compactions,
            "n_stale_flushes": self.n_stale_flushes,
            "window_gain": self.window_gain,
            "effective_window_s": self.effective_window(),
            "flush_in_progress": self.flush_in_progress,
            "n_prestage_flushes": self.n_prestage_flushes,
            "prestage_hits": self.prestage_hits,
            "prestage_wasted": self.prestage_wasted,
        }
