"""Batched serving engine with continuous batching (slot-based).

A fixed pool of B decode slots shares stacked KV caches; new requests are
prefilled into free slots while other slots keep decoding (one engine step =
at most one prefill + one batched decode).  Retired slots return their
tokens.  This is the serving counterpart of the paper's online mode: the
request router (GeoGraphStore) picks the serving site; this engine is what
runs inside each site.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer as tf

__all__ = ["Request", "ServeConfig", "Engine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [len] token ids
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    n_slots: int = 4
    max_len: int = 128
    eos_id: int = -1  # -1: never stop early
    greedy: bool = True


class Engine:
    def __init__(self, params: Any, cfg: tf.LMConfig, scfg: ServeConfig) -> None:
        self.params = params
        self.cfg = cfg
        self.scfg = scfg
        self.slots: List[Optional[Request]] = [None] * scfg.n_slots
        self.pos = np.zeros(scfg.n_slots, dtype=np.int32)
        self.budget = np.zeros(scfg.n_slots, dtype=np.int32)
        self.caches = self._empty_caches()
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, t, c, pos: tf.decode(p, t, c, pos, cfg)
        )
        self._prefill = jax.jit(lambda p, t: tf.prefill(p, t, cfg))

    def _empty_caches(self):
        c = self.cfg
        b, s = self.scfg.n_slots, self.scfg.max_len
        if c.mla:
            return {
                "c_kv": jnp.zeros((c.n_layers, b, s, c.kv_lora_rank), c.dtype),
                "k_rope": jnp.zeros((c.n_layers, b, s, c.qk_rope_dim), c.dtype),
            }
        return {
            "k": jnp.zeros((c.n_layers, b, c.n_kv_heads, s, c.hd), c.dtype),
            "v": jnp.zeros((c.n_layers, b, c.n_kv_heads, s, c.hd), c.dtype),
        }

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------ step
    def step(self) -> List[Request]:
        """One engine iteration; returns requests completed this step."""
        self._admit()
        finished: List[Request] = []
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if active:
            tokens = np.zeros(self.scfg.n_slots, dtype=np.int32)
            for i in active:
                r = self.slots[i]
                tokens[i] = (
                    r.out_tokens[-1] if r.out_tokens else int(r.prompt[-1])
                )
            logits, self.caches = self._decode(
                self.params,
                jnp.asarray(tokens),
                self.caches,
                jnp.asarray(self.pos),
            )
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for i in active:
                r = self.slots[i]
                tok = int(nxt[i])
                r.out_tokens.append(tok)
                self.pos[i] += 1
                self.budget[i] -= 1
                if (
                    self.budget[i] <= 0
                    or tok == self.scfg.eos_id
                    or self.pos[i] >= self.scfg.max_len - 1
                ):
                    r.done = True
                    finished.append(r)
                    self.slots[i] = None
        return finished

    def _admit(self) -> None:
        """Prefill queued requests into free slots (one per step per slot)."""
        for i in range(self.scfg.n_slots):
            if self.slots[i] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            plen = len(req.prompt)
            _, pc = self._prefill(self.params, jnp.asarray(req.prompt)[None])
            # write the prefilled cache into slot i (pad to max_len)
            def write(c_all, c_new):
                pad = self.scfg.max_len - c_new.shape[-2]
                widths = [(0, 0)] * c_new.ndim
                widths[-2] = (0, pad)
                padded = jnp.pad(c_new, widths)[:, 0]  # drop batch dim
                return c_all.at[:, i].set(padded)

            self.caches = jax.tree_util.tree_map(write, self.caches, pc)
            self.slots[i] = req
            self.pos[i] = plen
            self.budget[i] = req.max_new_tokens

    def run_to_completion(self, max_steps: int = 1000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.queue and all(s is None for s in self.slots):
                break
        return done
