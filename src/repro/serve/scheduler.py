"""Event-driven admission control with latency-aware adaptive batching.

The :class:`AdmissionController` replaces the old synchronous FIFO drain
loop with an event-loop scheduler on a **simulated clock** (deterministic,
no threads):

  * requests arrive (immediately or on a replayed trace via ``at=``), are
    queued per ``(priority class, origin DC)``, and drain in batches through
    the data plane's vectorized ``store.serve_batch``;
  * the **batch size closes the loop on measured routing latency**: every
    drain observes its requests' ``RouteResult.latency_s`` (the Eq. 1 WAN
    straggler) and the controller grows the batch target while the marginal
    p99 stays inside the deadline slack, shrinking multiplicatively on a
    deadline miss (AIMD) — the ROADMAP's "latency-aware batch sizing" loop;
  * **per-origin fairness**: batches are formed round-robin across origin
    queues (``quantum`` requests per origin per pass, priority classes
    first), so one hot DC cannot starve the others — with ``fairness="fifo"``
    the controller degrades to the old global-FIFO order.

Timing model (all simulated seconds): dispatching a batch of R requests
occupies the router for ``dispatch_overhead_s + R * per_request_s``; the
batch's results return together when its straggler WAN fetch lands, so every
request in it completes at ``dispatch + compute + max(latency_s)``.  The
router is free to form the next batch once the compute window ends (fetches
overlap the next drain).  Batching therefore couples a local request's
completion to the slowest remote fetch in its batch — exactly the tension
the adaptive policy trades against per-dispatch overhead.

Routing is untouched policy-free data-plane work: the controller hands the
formed batch to ``serve_batch`` verbatim, so results are request-for-request
identical to calling the store directly on the same batches (asserted in
``tests/test_control_plane.py``).

Idle gaps (router quiescent, next arrival in the future) are offered to an
attached :class:`~repro.serve.MaintenancePolicy` before the clock jumps
forward — migration waves, compaction and heat maintenance run "between
drains" without a second event loop.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from ..obs import Tracer
from .client import RequestHandle

__all__ = ["SimClock", "AdmissionConfig", "BatchRecord", "AdmissionController"]


class SimClock:
    """Deterministic simulated clock (seconds); monotone, never wall time."""

    def __init__(self, t0: float = 0.0) -> None:
        self.t = float(t0)

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock cannot go backwards (dt={dt})")
        self.t += dt

    def jump_to(self, t: float) -> None:
        self.t = max(self.t, float(t))


@dataclasses.dataclass
class AdmissionConfig:
    """Scheduler knobs.  ``policy`` selects the batching discipline:

    * ``"adaptive"`` (default) — AIMD batch target driven by measured
      latency vs deadline slack; dispatches whenever the router is free.
    * ``"greedy"`` — dispatch whenever free, fixed cap ``max_batch``
      (work-conserving fixed batching).
    * ``"fixed"`` — wait until ``max_batch`` requests are pending before
      dispatching (trailing partial drain once arrivals end): the
      fixed-batch FIFO frontend the benchmarks baseline against.
    """

    policy: str = "adaptive"
    fairness: str = "round_robin"  # or "fifo"
    # one AIMD batch target per store shard (sharded stores expose
    # ``origin_shard``): each drain serves a single shard, round-robin
    # across shards with pending work, so a lagging shard shrinks its own
    # target without throttling the healthy ones
    per_shard_aimd: bool = False
    min_batch: int = 1
    max_batch: int = 256
    initial_batch: int = 8
    quantum: int = 8  # per-origin requests taken per round-robin pass
    # router occupancy charged per drain.  "occupancy" (default) keeps the
    # deterministic linear model below; "measured" charges the store's
    # actual serving time instead — ``store.last_serve_seconds`` (the
    # sharded store reports its slowest shard's busy seconds) with the
    # drain's own wall clock as fallback — so the AIMD loop reacts to the
    # real router (e.g. the kernels fast path making big batches cheap).
    # Measured mode injects wall time into the simulated clock: runs are
    # no longer replay-deterministic, which is the point.
    service_model: str = "occupancy"
    # simulated router occupancy per drain ("occupancy" model constants)
    dispatch_overhead_s: float = 2e-3
    per_request_s: float = 2e-5
    # AIMD loop
    growth: float = 1.5
    shrink: float = 0.5
    slack_frac: float = 0.25  # grow only while slack > frac of the deadline
    latency_window: int = 256  # sliding window backing the p99 estimate
    # telemetry bounds: the controller is long-lived, so per-request latency
    # samples and per-drain records are ring-buffered (quantiles read the
    # most recent window; counts/means stay exact via running aggregates)
    metrics_window: int = 65536
    history_window: int = 4096
    # per-priority-class default deadlines (index clamped to the last entry)
    default_deadlines: Tuple[float, ...] = (0.25, 2.0)

    def __post_init__(self) -> None:
        if self.policy not in ("adaptive", "greedy", "fixed"):
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.fairness not in ("round_robin", "fifo"):
            raise ValueError(f"unknown fairness {self.fairness!r}")
        if self.service_model not in ("occupancy", "measured"):
            raise ValueError(f"unknown service_model {self.service_model!r}")
        if self.per_shard_aimd and (
            self.policy != "adaptive" or self.fairness != "round_robin"
        ):
            raise ValueError(
                "per_shard_aimd needs policy='adaptive' and "
                "fairness='round_robin' (per-shard targets are AIMD state "
                "over per-origin queues)"
            )

    def deadline_for(self, priority: int) -> float:
        # clamp both ways: negative (more-urgent-than-interactive) classes
        # take the tightest default, not a Python negative index
        idx = min(max(priority, 0), len(self.default_deadlines) - 1)
        return float(self.default_deadlines[idx])


@dataclasses.dataclass
class BatchRecord:
    """Telemetry for one drain (the adaptive loop's observable)."""

    t_dispatch: float
    size: int
    target: int  # batch target when the batch was formed
    compute_s: float  # router occupancy charged
    straggler_s: float  # max measured RouteResult.latency_s in the batch
    misses: int  # deadline misses produced by this drain


class AdmissionController:
    """Event-loop scheduler between :class:`StoreClient` and the store.

    Only ``store.serve_batch`` is required of the data plane.  All state is
    deterministic under the simulated clock; ``run_until_idle`` is the
    drive-to-completion entry (the old ``flush()``), ``step()`` the
    single-event one.
    """

    def __init__(self, store, config: Optional[AdmissionConfig] = None,
                 clock: Optional[SimClock] = None, policy=None,
                 tracer: Optional[Tracer] = None, registry=None,
                 wall_clock: Optional[Callable[[], float]] = None) -> None:
        self.store = store
        self.cfg = config or AdmissionConfig()
        self.clock = clock or SimClock()
        # fallback duration source for service_model="measured" when the
        # store reports no serve time.  Injected (sim-clock purity, GL002):
        # the default is a *reference* to the monotonic clock — tests pass a
        # fake to keep measured-mode runs deterministic.
        self._wall_clock = wall_clock if wall_clock is not None else time.perf_counter
        self.policy = policy  # optional MaintenancePolicy
        # control-plane spans run on the *simulated* clock: two identical
        # runs produce byte-identical trace exports.  An attached policy
        # without its own tracer shares this one, so migration waves land on
        # the same timeline as the request spans they interleave with.
        self.tracer = tracer if tracer is not None else Tracer(clock=self.clock.now)
        self._registry = registry
        if policy is not None and getattr(policy, "tracer", None) is None:
            policy.tracer = self.tracer
        self.batch_target = int(
            min(max(self.cfg.initial_batch, self.cfg.min_batch), self.cfg.max_batch)
        )
        # sharded data plane hooks (both optional; a plain GeoGraphStore has
        # neither): origin->shard mapping routes per-shard batch formation,
        # and the store's straggler detector feeds miss-cause attribution
        self._origin_shard: Optional[Dict[int, int]] = getattr(
            store, "origin_shard", None
        )
        self._straggler_det = getattr(store, "straggler", None)
        self._targets: Dict[int, int] = {}  # shard -> AIMD target
        self._lat_windows: Dict[int, Deque[float]] = {}  # shard -> p99 window
        self._shard_rr = 0
        self.straggler_misses_by_shard: Dict[int, int] = {}
        self._next_rid = 0
        self._arrival_seq = 0
        self._arrivals: List[Tuple[float, int, RequestHandle]] = []  # heap
        self._fifo: Deque[RequestHandle] = deque()
        self._queues: Dict[Tuple[int, int], Deque[RequestHandle]] = {}
        self._rr_pos: Dict[object, int] = {}
        self._n_pending = 0
        self._lat_window: Deque[float] = deque(maxlen=self.cfg.latency_window)
        self._latencies: Deque[float] = deque(maxlen=self.cfg.metrics_window)
        self._lat_sum = 0.0
        self._t_first_submit = math.inf
        self._t_last_done = 0.0
        self.completed = 0
        self.deadline_misses = 0
        # every miss is attributed to exactly one cause (the first stage
        # whose cumulative time blew the deadline), so the three counts
        # always sum to ``deadline_misses``
        self.misses_by_cause: Dict[str, int] = {
            "queue": 0, "service": 0, "straggler": 0
        }
        self.served_by_origin: Dict[int, int] = {}
        self._lat_by_origin: Dict[int, Deque[float]] = {}
        self.history: Deque[BatchRecord] = deque(maxlen=self.cfg.history_window)
        self._n_batches = 0
        self._batch_size_sum = 0
        # compaction renumbers item rows; subscribing to the store's remap
        # hook keeps in-flight handles valid, which in turn makes it safe to
        # let the maintenance policy compact during idle gaps
        self._remap_registered = False
        register = getattr(store, "add_remap_listener", None)
        if callable(register):
            register(self._remap_pending_items)
            self._remap_registered = True
        # the store's demand plane windows on this scheduler's clock; total
        # idle time is what pre-staging can hide migration work inside
        self._demand = getattr(store, "demand", None)
        self.idle_s = 0.0

    def _remap_pending_items(self, imap: np.ndarray) -> None:
        """Re-key every unserved handle's item rows after a compaction
        (dropped rows vanish from the request, like they do from patterns)."""
        pending = list(self._fifo)
        pending += [h for q in self._queues.values() for h in q]
        pending += [h for _, _, h in self._arrivals]
        for h in pending:
            it = imap[h.items]
            h.items = it[it >= 0]

    # ------------------------------------------------------------ admission
    def submit(
        self,
        items: np.ndarray,
        origin: int,
        deadline_s: Optional[float] = None,
        priority: int = 0,
        at: Optional[float] = None,
    ) -> RequestHandle:
        """Register one request; ``at`` schedules a future arrival (trace
        replay), otherwise the request arrives now."""
        t = self.clock.now() if at is None else float(at)
        h = RequestHandle(
            rid=self._next_rid,
            items=np.asarray(items),
            origin=int(origin),
            priority=int(priority),
            deadline_s=(
                self.cfg.deadline_for(int(priority)) if deadline_s is None
                else float(deadline_s)
            ),
            t_submit=t,
        )
        self._next_rid += 1
        self._t_first_submit = min(self._t_first_submit, t)
        if t <= self.clock.now():
            self._enqueue(h)
        else:
            self._arrival_seq += 1
            heapq.heappush(self._arrivals, (t, self._arrival_seq, h))
        return h

    def _enqueue(self, h: RequestHandle) -> None:
        if self.cfg.fairness == "fifo":
            self._fifo.append(h)
        else:
            self._queues.setdefault((h.priority, h.origin), deque()).append(h)
        self._n_pending += 1

    def _admit_due(self) -> int:
        n = 0
        while self._arrivals and self._arrivals[0][0] <= self.clock.now():
            _, _, h = heapq.heappop(self._arrivals)
            self._enqueue(h)
            n += 1
        return n

    @property
    def pending(self) -> int:
        """Admitted-but-unserved requests (future arrivals excluded)."""
        return self._n_pending

    @property
    def n_scheduled(self) -> int:
        """Future arrivals not yet admitted."""
        return len(self._arrivals)

    def pending_handles(self) -> List[RequestHandle]:
        """Admitted pending requests in drain order (FIFO) / rid order."""
        if self.cfg.fairness == "fifo":
            return list(self._fifo)
        out = [h for q in self._queues.values() for h in q]
        out.sort(key=lambda h: h.rid)
        return out

    # ------------------------------------------------------ batch formation
    def _target_size(self) -> int:
        if self.cfg.policy == "adaptive":
            return self.batch_target
        return self.cfg.max_batch

    def _shard_of(self, origin: int) -> int:
        """Shard owning an origin DC; without a sharded store every origin
        is its own 'shard' (degenerates to per-origin AIMD)."""
        if self._origin_shard is None:
            return origin
        return self._origin_shard.get(origin, origin)

    def _next_shard_key(self) -> Optional[int]:
        """Round-robin over shards that currently have pending requests."""
        keys = sorted(
            {self._shard_of(o) for (_, o), q in self._queues.items() if q}
        )
        if not keys:
            return None
        key = keys[self._shard_rr % len(keys)]
        self._shard_rr += 1
        return key

    def _form_batch(
        self, cap: int, shard_key: Optional[int] = None
    ) -> List[RequestHandle]:
        batch: List[RequestHandle] = []
        if self.cfg.fairness == "fifo":
            while self._fifo and len(batch) < cap:
                batch.append(self._fifo.popleft())
        else:
            prios = sorted({
                p for (p, o), q in self._queues.items()
                if q and (shard_key is None or self._shard_of(o) == shard_key)
            })
            for prio in prios:
                if len(batch) >= cap:
                    break
                origins = sorted({
                    o for (p, o), q in self._queues.items()
                    if p == prio and q
                    and (shard_key is None or self._shard_of(o) == shard_key)
                })
                if not origins:
                    continue
                cursor = prio if shard_key is None else (prio, shard_key)
                start = self._rr_pos.get(cursor, 0) % len(origins)
                while len(batch) < cap:
                    progressed = False
                    for i in range(len(origins)):
                        o = origins[(start + i) % len(origins)]
                        q = self._queues.get((prio, o))
                        take = min(self.cfg.quantum, cap - len(batch), len(q) if q else 0)
                        for _ in range(take):
                            batch.append(q.popleft())
                        progressed = progressed or take > 0
                        if len(batch) >= cap:
                            break
                    if not progressed:
                        break
                # rotate the cursor so the next batch starts one origin over
                self._rr_pos[cursor] = start + 1
        self._n_pending -= len(batch)
        return batch

    def _requeue(self, batch: List[RequestHandle]) -> None:
        """Put an unserved batch back at the queue fronts, order intact."""
        if self.cfg.fairness == "fifo":
            self._fifo.extendleft(reversed(batch))
        else:
            for h in reversed(batch):
                self._queues.setdefault((h.priority, h.origin), deque()).appendleft(h)
        self._n_pending += len(batch)

    # ------------------------------------------------------------ event loop
    def step(self) -> List[RequestHandle]:
        """One scheduler event; returns the requests completed by it.

        Guaranteed progress: either a batch is served, or the clock jumps to
        the next scheduled arrival (idle gaps are first offered to the
        attached maintenance policy).  Returns ``[]`` with nothing pending
        and nothing scheduled."""
        self._admit_due()
        if self._demand is not None:
            self._demand.advance_to(self.clock.now())
        shard_key: Optional[int] = None
        if self.cfg.per_shard_aimd and self._n_pending:
            shard_key = self._next_shard_key()
        if shard_key is not None:
            target = self._targets.get(shard_key, self.batch_target)
        else:
            target = self._target_size()
        waiting_to_fill = (
            self.cfg.policy == "fixed"
            and self._n_pending < target
            and self._arrivals
        )
        if self._n_pending == 0 or waiting_to_fill:
            if not self._arrivals:
                if self._n_pending == 0:
                    return []
            else:
                t_next = self._arrivals[0][0]
                gap = t_next - self.clock.now()
                if self.policy is not None and gap > 0 and self._n_pending == 0:
                    # maintenance runs inside the gap; any overrun is
                    # absorbed (the jump below caps the clock at t_next, so
                    # serving is never pushed back).  Compaction is allowed
                    # only when the remap hook keeps the scheduled handles'
                    # item rows valid across the renumbering.
                    self.policy.on_idle(
                        self.clock.now(), gap, quiescent=self._remap_registered
                    )
                if gap > 0:
                    self.idle_s += gap
                self.clock.jump_to(t_next)
                self._admit_due()
                return []
        batch = self._form_batch(target, shard_key=shard_key)
        t0 = self.clock.now()
        t_wall = self._wall_clock()
        try:
            results = self.store.serve_batch([(h.items, h.origin) for h in batch])
        except BaseException:
            # nothing served, nothing lost: the whole batch returns to the
            # queue fronts and the next step retries it
            self._requeue(batch)
            raise
        if self.cfg.service_model == "measured":
            measured = getattr(self.store, "last_serve_seconds", None)
            compute_s = (
                float(measured)
                if measured is not None
                else self._wall_clock() - t_wall
            )
        else:
            compute_s = (
                self.cfg.dispatch_overhead_s
                + len(batch) * self.cfg.per_request_s
            )
        straggler = max((r.latency_s for r in results), default=0.0)
        t_done = t0 + compute_s + straggler
        bid = self._n_batches
        traced = self.tracer.enabled
        if traced:
            self.tracer.record(
                "drain", t0, t0 + compute_s, track="scheduler",
                batch=bid, size=len(batch), target=target,
            )
        misses = 0
        for h, r in zip(batch, results):
            h.result = r
            h.t_dispatch = t0
            h.t_done = t_done
            self._lat_window.append(h.latency_s)
            self._latencies.append(h.latency_s)
            self._lat_sum += h.latency_s
            self._lat_by_origin.setdefault(
                h.origin, deque(maxlen=self.cfg.metrics_window)
            ).append(h.latency_s)
            if h.deadline_missed:
                misses += 1
                self.misses_by_cause[self._miss_cause(h, t0, compute_s)] += 1
            self.served_by_origin[h.origin] = self.served_by_origin.get(h.origin, 0) + 1
            if traced:
                root = self.tracer.record(
                    "request", h.t_submit, t_done, track="requests",
                    rid=h.rid, origin=h.origin, priority=h.priority, batch=bid,
                )
                self.tracer.record(
                    "queue", h.t_submit, t0, track="requests", parent=root,
                    origin=h.origin,
                )
                self.tracer.record(
                    "route", t0, t0 + compute_s, track="requests", parent=root,
                    origin=h.origin,
                )
                self.tracer.record(
                    "wan_fetch", t0 + compute_s, t_done, track="requests",
                    parent=root, origin=h.origin,
                    layers=r.layers_used, dcs=len(r.dcs),
                )
        self.completed += len(batch)
        self.deadline_misses += misses
        self._t_last_done = max(self._t_last_done, t_done)
        self.history.append(BatchRecord(
            t_dispatch=t0, size=len(batch), target=target,
            compute_s=compute_s, straggler_s=straggler, misses=misses,
        ))
        self._n_batches += 1
        self._batch_size_sum += len(batch)
        self.clock.advance(compute_s)  # fetches overlap the next drain
        self._update_target(batch)
        return batch

    def _miss_cause(self, h: RequestHandle, t0: float, compute_s: float) -> str:
        """Attribute a deadline miss to the first stage that overran.

        ``queue``: the request was already late when dispatched;
        ``service``: dispatch + router occupancy alone blew the deadline;
        ``straggler``: only the batch's slowest WAN fetch pushed it over.
        The stages partition every miss, so cause counts sum exactly to
        ``deadline_misses``.

        With a sharded store, a service-stage overrun whose owning shard is
        flagged by the store's :class:`StragglerDetector` is attributed as a
        ``straggler`` too — the router wasn't slow in general, that shard
        was — and either way a flagged shard's misses are tallied per shard
        in ``straggler_misses_by_shard``."""
        if t0 - h.t_submit > h.deadline_s:
            return "queue"
        det = self._straggler_det
        shard = self._shard_of(h.origin)
        lagging = det is not None and det.is_straggler(shard)
        if (t0 + compute_s) - h.t_submit > h.deadline_s and not lagging:
            return "service"
        if lagging:
            self.straggler_misses_by_shard[shard] = (
                self.straggler_misses_by_shard.get(shard, 0) + 1
            )
        return "straggler"

    def _update_target(self, batch: List[RequestHandle]) -> None:
        """AIMD on measured latency vs deadline slack (adaptive policy).

        With ``per_shard_aimd`` every drain is single-shard, so the update
        lands on that shard's own target (seeded from the global one)."""
        if self.cfg.policy != "adaptive" or not batch:
            return
        if self.cfg.per_shard_aimd:
            key = self._shard_of(batch[0].origin)
            # the p99 growth gate reads this shard's own window: a slow
            # shard's tail must not freeze the healthy shards' growth
            win = self._lat_windows.setdefault(
                key, deque(maxlen=self.cfg.latency_window)
            )
            win.extend(h.latency_s for h in batch)
            cur = self._targets.get(key, self.batch_target)
            self._targets[key] = self._aimd_next(cur, batch, win)
        else:
            self.batch_target = self._aimd_next(
                self.batch_target, batch, self._lat_window
            )

    def _aimd_next(
        self, cur: int, batch: List[RequestHandle], window: Deque[float]
    ) -> int:
        cfg = self.cfg
        if any(h.deadline_missed for h in batch):
            return max(cfg.min_batch, int(cur * cfg.shrink))
        grow = min(cfg.max_batch, max(cur + 1, int(cur * cfg.growth)))
        bounded = [h for h in batch if math.isfinite(h.deadline_s)]
        if not bounded:
            # no deadline pressure: amortize overhead as hard as allowed
            return grow
        tightest = min(h.deadline_s for h in bounded)
        slack = min(h.deadline_s - h.latency_s for h in bounded)
        p99 = float(np.quantile(np.asarray(window), 0.99))
        # grow while the marginal p99 stays inside the deadline slack band
        if slack > cfg.slack_frac * tightest and p99 <= (1.0 - cfg.slack_frac) * tightest:
            return grow
        return cur

    def run_until_idle(self, max_steps: int = 1_000_000) -> List[RequestHandle]:
        """Drain every pending and scheduled request; returns completions in
        completion order (the retired frontend's ``flush`` contract)."""
        done: List[RequestHandle] = []
        for _ in range(max_steps):
            if self._n_pending == 0 and not self._arrivals:
                return done
            done.extend(self.step())
        raise RuntimeError(f"run_until_idle did not converge in {max_steps} steps")

    # -------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, object]:
        lat = np.asarray(self._latencies, dtype=np.float64)
        span = self._t_last_done - (
            self._t_first_submit if math.isfinite(self._t_first_submit) else 0.0
        )
        out = {
            "completed": self.completed,
            "deadline_misses": self.deadline_misses,
            "misses_by_cause": dict(self.misses_by_cause),
            # quantiles over the (ring-buffered) most recent metrics_window
            "p50_s": float(np.quantile(lat, 0.50)) if len(lat) else 0.0,
            "p99_s": float(np.quantile(lat, 0.99)) if len(lat) else 0.0,
            "p99_by_origin": {
                o: float(np.quantile(np.asarray(w, dtype=np.float64), 0.99))
                for o, w in sorted(self._lat_by_origin.items())
            },
            "mean_s": self._lat_sum / self.completed if self.completed else 0.0,
            "throughput_rps": self.completed / span if span > 0 else 0.0,
            "n_batches": self._n_batches,
            "mean_batch": (
                self._batch_size_sum / self._n_batches if self._n_batches else 0.0
            ),
            "batch_target": self.batch_target,
            "served_by_origin": dict(sorted(self.served_by_origin.items())),
            "sim_time_s": self.clock.now(),
            "idle_s": self.idle_s,
        }
        if self.cfg.per_shard_aimd:
            out["batch_target_by_shard"] = dict(sorted(self._targets.items()))
        if self._straggler_det is not None:
            out["straggler_shards"] = self._straggler_det.flagged()
            out["straggler_misses_by_shard"] = dict(
                sorted(self.straggler_misses_by_shard.items())
            )
        return out
