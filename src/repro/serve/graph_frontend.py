"""Deprecated FIFO frontend — a thin shim over the serving control plane.

``GraphFrontend`` predates the Client / AdmissionController / Policy split:
it exposed a synchronous queue that drained everything in fixed ``max_batch``
chunks.  It now delegates to a :class:`~repro.serve.StoreClient` +
:class:`~repro.serve.AdmissionController` configured to reproduce the old
behaviour exactly (``policy="greedy"``, ``fairness="fifo"``, no deadlines),
and emits a :class:`DeprecationWarning` at construction.  Migration path:

    fe = GraphFrontend(store, max_batch=256)      # old
    rid = fe.submit(items, origin); fe.flush()[rid]

    controller = AdmissionController(store)       # new
    client = StoreClient(controller)
    handle = client.submit(items, origin)         # + deadline / priority
    client.result(handle)

``GraphRequest`` is kept as an alias of :class:`~repro.serve.RequestHandle`
(same ``rid`` / ``items`` / ``origin`` / ``result`` / ``done`` surface).
"""
from __future__ import annotations

import math
import warnings
from typing import Dict, List

import numpy as np

from ..core.routing import RouteResult
from .client import RequestHandle, StoreClient
from .scheduler import AdmissionConfig, AdmissionController

__all__ = ["GraphRequest", "GraphFrontend"]

# legacy name: the futures-style handle is a strict superset of the old
# GraphRequest dataclass (rid / items / origin / result / done)
GraphRequest = RequestHandle


class GraphFrontend:
    """Deprecated FIFO request queue; use the control-plane stack instead.

    ``max_batch`` bounds one drain chunk; ``flush()`` serves everything
    pending and returns ``{rid: RouteResult}``.  A mid-drain exception still
    loses nothing: the controller requeues the failing chunk.
    """

    def __init__(self, store, max_batch: int = 256) -> None:
        warnings.warn(
            "GraphFrontend is deprecated; use repro.serve.StoreClient with "
            "an AdmissionController (and a MaintenancePolicy for background "
            "work) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        self.store = store
        self.max_batch = int(max_batch)
        self.controller = AdmissionController(
            store,
            AdmissionConfig(
                policy="greedy", fairness="fifo", max_batch=int(max_batch)
            ),
        )
        self.client = StoreClient(self.controller)
        self.n_served = 0

    # ------------------------------------------------------------ admission
    def submit(self, items: np.ndarray, origin: int) -> int:
        """Enqueue one pattern request; returns its request id."""
        return self.client.submit(items, origin, deadline_s=math.inf).rid

    def submit_pattern(self, pattern, origin: int) -> int:
        return self.submit(pattern.items, origin)

    @property
    def pending(self) -> int:
        return self.controller.pending

    @property
    def queue(self) -> List[RequestHandle]:
        """Pending requests in FIFO order (legacy surface).

        A **snapshot**, not the live list the pre-shim frontend exposed:
        mutating it (``fe.queue.clear()`` etc.) does not cancel anything —
        the requests live in the controller's queues and will still drain.
        Cancellation was never part of the tested contract; callers that
        need it should migrate to the controller API."""
        return self.controller.pending_handles()

    # -------------------------------------------------------------- serving
    def flush(self) -> Dict[int, RouteResult]:
        """Drain the queue in FIFO batches of ``max_batch``."""
        done = self.controller.run_until_idle()
        self.n_served += len(done)
        return {h.rid: h.result for h in done}
