"""Request-queue frontend over ``GeoGraphStore.serve_batch`` (paper §VI).

The graph-store counterpart of :mod:`repro.serve.engine`'s slot engine: online
pattern requests arrive one at a time (per-origin client streams), are queued,
and drain in batches through the vectorized stepwise router.  The frontend is
deliberately thin — admission and batching policy only; all routing decisions
live in the store.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.routing import RouteResult

__all__ = ["GraphRequest", "GraphFrontend"]


@dataclasses.dataclass
class GraphRequest:
    rid: int
    items: np.ndarray
    origin: int
    result: Optional[RouteResult] = None

    @property
    def done(self) -> bool:
        return self.result is not None


class GraphFrontend:
    """FIFO request queue draining through ``store.serve_batch``.

    ``max_batch`` bounds one drain chunk (router work stays cache-sized);
    ``flush()`` serves everything pending and returns ``{rid: RouteResult}``.
    """

    def __init__(self, store, max_batch: int = 256) -> None:
        self.store = store
        self.max_batch = int(max_batch)
        self.queue: List[GraphRequest] = []
        self._next_rid = 0
        self.n_served = 0

    # ------------------------------------------------------------ admission
    def submit(self, items: np.ndarray, origin: int) -> int:
        """Enqueue one pattern request; returns its request id."""
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(
            GraphRequest(rid=rid, items=np.asarray(items), origin=int(origin))
        )
        return rid

    def submit_pattern(self, pattern, origin: int) -> int:
        return self.submit(pattern.items, origin)

    @property
    def pending(self) -> int:
        return len(self.queue)

    # -------------------------------------------------------------- serving
    def flush(self) -> Dict[int, RouteResult]:
        """Drain the queue in FIFO batches of ``max_batch``.

        A chunk is popped from the queue only *after* its results are
        assigned: if ``serve_batch`` raises mid-drain, every unserved request
        (the failing chunk included) stays queued for the next flush instead
        of being lost.  Size-1 chunks take the scalar ``route_online`` fast
        path inside ``serve_batch``."""
        out: Dict[int, RouteResult] = {}
        while self.queue:
            chunk = self.queue[: self.max_batch]
            results = self.store.serve_batch(
                [(r.items, r.origin) for r in chunk]
            )
            for req, res in zip(chunk, results):
                req.result = res
                out[req.rid] = res
            del self.queue[: len(chunk)]
            self.n_served += len(chunk)
        return out
