"""Serving control plane: Client → AdmissionController → store → Policy.

``StoreClient`` is the read-path API (futures-style handles with origin,
deadline and priority class), ``AdmissionController`` the event-loop
scheduler with latency-aware adaptive batching and per-origin fairness,
``MaintenancePolicy`` the budgeted background scheduler that interleaves
migration waves / compaction / heat maintenance into idle gaps and feeds
measured wave transfer times back into the window estimate.

:mod:`repro.serve.engine` is the per-site LM slot engine (unrelated to the
graph-store path) and is imported lazily to keep the control plane jax-free.
"""
from .client import BULK, INTERACTIVE, RequestHandle, StoreClient  # noqa: F401
from .policy import MaintenanceConfig, MaintenancePolicy  # noqa: F401
from .scheduler import (  # noqa: F401
    AdmissionConfig,
    AdmissionController,
    BatchRecord,
    SimClock,
)

__all__ = [
    "RequestHandle",
    "StoreClient",
    "INTERACTIVE",
    "BULK",
    "AdmissionConfig",
    "AdmissionController",
    "BatchRecord",
    "SimClock",
    "MaintenanceConfig",
    "MaintenancePolicy",
]


def __getattr__(name):
    # lazy: repro.serve.engine pulls in jax + the transformer zoo, which the
    # graph-store control plane never needs
    if name == "engine":
        import importlib

        module = importlib.import_module(".engine", __name__)
        globals()["engine"] = module
        return module
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
