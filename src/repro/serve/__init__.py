from . import engine  # noqa: F401
from .graph_frontend import GraphFrontend, GraphRequest  # noqa: F401

__all__ = ["engine", "GraphFrontend", "GraphRequest"]
