"""Mutation batching and the delta-CSR overlay (streaming tentpole, part 1).

Design: the base graph's arrays are append-only with *stable ids* — new
vertices and edges take fresh ids at the end of their ranges, deletes are
tombstones (``node_alive`` / ``edge_alive`` masks).  The control plane keeps
operating on the overlay without rewriting the base CSR; ``DeltaGraph.compact``
produces a dense re-numbered :class:`~repro.core.graph.Graph` (plus the id
maps) when a full rebuild or a from-scratch validation is wanted.

Item-id convention (unchanged from ``core.graph``): vertex v -> v, edge e ->
``n_nodes + e``.  Because vertex appends grow ``n_nodes``, every *edge* item
id shifts by the number of new vertices per batch; :func:`ApplyResult.remap_items`
is the single place that encodes this shift for placement/workload arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.graph import Graph
from ..core.patterns import Pattern, Workload

__all__ = [
    "MutationBatch",
    "MutationLog",
    "DeltaCSR",
    "ApplyResult",
    "DeltaGraph",
    "random_churn_batch",
    "compact_workload",
]


@dataclasses.dataclass
class MutationBatch:
    """One sealed batch of topology mutations (arrays, not per-op objects).

    ``add_edge_src/dst`` may reference provisional vertex ids
    ``old_n_nodes + j`` for the j-th vertex added in the same batch.
    """

    add_vertex_size: np.ndarray  # [nv] float32
    add_vertex_partition: np.ndarray  # [nv] int32
    del_vertex_ids: np.ndarray  # [dv] int64
    add_edge_src: np.ndarray  # [ne] int64
    add_edge_dst: np.ndarray  # [ne] int64
    add_edge_size: np.ndarray  # [ne] float32
    del_edge_ids: np.ndarray  # [de] int64

    @staticmethod
    def empty() -> "MutationBatch":
        return MutationBatch(
            add_vertex_size=np.zeros(0, np.float32),
            add_vertex_partition=np.zeros(0, np.int32),
            del_vertex_ids=np.zeros(0, np.int64),
            add_edge_src=np.zeros(0, np.int64),
            add_edge_dst=np.zeros(0, np.int64),
            add_edge_size=np.zeros(0, np.float32),
            del_edge_ids=np.zeros(0, np.int64),
        )

    @property
    def n_ops(self) -> int:
        return (
            len(self.add_vertex_size) + len(self.del_vertex_ids)
            + len(self.add_edge_src) + len(self.del_edge_ids)
        )


class MutationLog:
    """Accumulates single mutations; ``seal()`` emits a :class:`MutationBatch`.

    ``add_vertex`` returns the provisional id the vertex will take once the
    batch is applied, so callers can wire new edges to new vertices within
    one batch.
    """

    def __init__(self, n_nodes: int) -> None:
        self._n_base = n_nodes
        self._reset()

    def _reset(self) -> None:
        self._av_size: List[float] = []
        self._av_part: List[int] = []
        self._dv: List[int] = []
        self._ae: List[Tuple[int, int, float]] = []
        self._de: List[int] = []

    def __len__(self) -> int:
        return len(self._av_size) + len(self._dv) + len(self._ae) + len(self._de)

    def add_vertex(self, partition: int, size: float = 1.0) -> int:
        vid = self._n_base + len(self._av_size)
        self._av_size.append(float(size))
        self._av_part.append(int(partition))
        return vid

    def delete_vertex(self, vid: int) -> None:
        self._dv.append(int(vid))

    def add_edge(self, src: int, dst: int, size: float = 1.0) -> None:
        self._ae.append((int(src), int(dst), float(size)))

    def delete_edge(self, eid: int) -> None:
        self._de.append(int(eid))

    def seal(self) -> MutationBatch:
        batch = MutationBatch(
            add_vertex_size=np.asarray(self._av_size, np.float32),
            add_vertex_partition=np.asarray(self._av_part, np.int32),
            del_vertex_ids=np.asarray(sorted(set(self._dv)), np.int64),
            add_edge_src=np.asarray([e[0] for e in self._ae], np.int64),
            add_edge_dst=np.asarray([e[1] for e in self._ae], np.int64),
            add_edge_size=np.asarray([e[2] for e in self._ae], np.float32),
            del_edge_ids=np.asarray(sorted(set(self._de)), np.int64),
        )
        self._n_base += len(self._av_size)
        self._reset()
        return batch


# ------------------------------------------------------------------ DeltaCSR
class DeltaCSR:
    """CSR + append/tombstone overlay; adjacency queries without CSR rewrite.

    The base is CSR-shaped (indptr/indices) with a parallel exact int64
    edge-id column, so deletions resolve against the live mask.  Added edges
    live in per-vertex Python lists — O(1) amortized append — and ``merge()``
    folds everything into a fresh base when the overlay grows past
    ``merge_threshold`` of the base size.
    """

    def __init__(
        self,
        n_nodes: int,
        src: np.ndarray,
        dst: np.ndarray,
        edge_ids: Optional[np.ndarray] = None,
        merge_threshold: float = 0.5,
    ) -> None:
        if edge_ids is None:
            edge_ids = np.arange(len(src), dtype=np.int64)
        self.n_nodes = int(n_nodes)
        self._build_base(src, dst, edge_ids)
        self.merge_threshold = merge_threshold
        self._extra_dst: Dict[int, List[int]] = {}
        self._extra_eid: Dict[int, List[int]] = {}
        self._n_extra_edges = 0

    def _build_base(self, src: np.ndarray, dst: np.ndarray, edge_ids: np.ndarray) -> None:
        """CSR-shaped base with an exact int64 edge-id column (CSR.weights is
        float32, which would corrupt edge ids beyond 2^24)."""
        src = np.asarray(src, np.int64)
        order = np.argsort(src, kind="stable")
        counts = np.bincount(src[order], minlength=self.n_nodes)
        self._base_indptr = np.zeros(self.n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=self._base_indptr[1:])
        self._base_indices = np.asarray(dst, np.int64)[order]
        self._base_eids = np.asarray(edge_ids, np.int64)[order]
        self._base_n_nodes = self.n_nodes

    def add_node(self) -> int:
        self.n_nodes += 1
        return self.n_nodes - 1

    def add_edge(self, u: int, v: int, eid: int) -> None:
        self._extra_dst.setdefault(int(u), []).append(int(v))
        self._extra_eid.setdefault(int(u), []).append(int(eid))
        self._n_extra_edges += 1

    def out_edges(self, u: int, edge_alive: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(neighbor ids, edge ids) of u's alive out-edges (base + overlay)."""
        if u < self._base_n_nodes:
            lo, hi = int(self._base_indptr[u]), int(self._base_indptr[u + 1])
            nbr = self._base_indices[lo:hi]
            eid = self._base_eids[lo:hi]
        else:  # vertex appended after the base was built
            nbr = np.zeros(0, np.int64)
            eid = np.zeros(0, np.int64)
        if u in self._extra_dst:
            nbr = np.concatenate([nbr, np.asarray(self._extra_dst[u], np.int64)])
            eid = np.concatenate([eid, np.asarray(self._extra_eid[u], np.int64)])
        keep = edge_alive[eid]
        return nbr[keep], eid[keep]

    def needs_merge(self) -> bool:
        return self._n_extra_edges > self.merge_threshold * max(len(self._base_indices), 1)

    def merge(self, src: np.ndarray, dst: np.ndarray, edge_alive: np.ndarray) -> None:
        """Fold the overlay into a fresh base CSR over the alive edges."""
        eids = np.where(edge_alive)[0]
        self._build_base(src[eids], dst[eids], eids)
        self._extra_dst.clear()
        self._extra_eid.clear()
        self._n_extra_edges = 0


# ---------------------------------------------------------------- DeltaGraph
@dataclasses.dataclass
class ApplyResult:
    """Everything downstream consumers need to absorb one batch."""

    old_n_nodes: int
    old_n_edges: int
    n_new_vertices: int
    new_vertex_ids: np.ndarray  # ids in the *new* numbering
    new_edge_ids: np.ndarray  # edge indices (stable)
    dead_vertex_ids: np.ndarray  # vertices tombstoned by this batch
    dead_edge_ids: np.ndarray  # edges tombstoned (incl. vertex cascades)
    touched_vertices: np.ndarray  # alive endpoints of all mutated edges + new

    def remap_items(self, item_ids: np.ndarray) -> np.ndarray:
        """Old item ids -> new item ids (edge block shifts by new vertices)."""
        item_ids = np.asarray(item_ids)
        return np.where(
            item_ids < self.old_n_nodes, item_ids, item_ids + self.n_new_vertices
        )

    def dead_item_ids(self, new_n_nodes: int) -> np.ndarray:
        """Tombstoned item ids in the new numbering."""
        return np.concatenate(
            [self.dead_vertex_ids, new_n_nodes + self.dead_edge_ids]
        ).astype(np.int64)

    def new_item_ids(self, new_n_nodes: int) -> np.ndarray:
        return np.concatenate(
            [self.new_vertex_ids, new_n_nodes + self.new_edge_ids]
        ).astype(np.int64)


class DeltaGraph:
    """Stable-id mutable view over a :class:`~repro.core.graph.Graph`.

    ``g`` always reflects the latest applied batch (arrays re-concatenated per
    batch — O(n + m) numpy copies, no Python loops); ``node_alive`` /
    ``edge_alive`` carry the tombstones; ``adj`` is the delta-CSR overlay used
    for adjacency queries without rebuilding.
    """

    def __init__(self, g: Graph) -> None:
        self.g = g
        self.node_alive = np.ones(g.n_nodes, dtype=bool)
        self.edge_alive = np.ones(g.n_edges, dtype=bool)
        self.adj = DeltaCSR(g.n_nodes, g.src, g.dst)
        # reverse overlay for undirected incidence queries
        self.radj = DeltaCSR(g.n_nodes, g.dst, g.src)

    @staticmethod
    def from_graph(g: Graph) -> "DeltaGraph":
        return DeltaGraph(g)

    # ------------------------------------------------------------- queries
    def incident_edges(self, u: int) -> np.ndarray:
        """Alive edge ids touching ``u`` (either direction)."""
        _, out_e = self.adj.out_edges(u, self.edge_alive)
        _, in_e = self.radj.out_edges(u, self.edge_alive)
        return np.unique(np.concatenate([out_e, in_e]))

    def undirected_neighbors(self, u: int) -> np.ndarray:
        out_n, _ = self.adj.out_edges(u, self.edge_alive)
        in_n, _ = self.radj.out_edges(u, self.edge_alive)
        return np.unique(np.concatenate([out_n, in_n]))

    @property
    def n_alive_edges(self) -> int:
        return int(self.edge_alive.sum())

    @property
    def n_alive_nodes(self) -> int:
        return int(self.node_alive.sum())

    # --------------------------------------------------------------- apply
    def apply(self, batch: MutationBatch) -> ApplyResult:
        g = self.g
        old_n, old_m = g.n_nodes, g.n_edges
        nv = len(batch.add_vertex_size)
        ne = len(batch.add_edge_src)

        # --- grow vertex arrays ------------------------------------------
        n2 = old_n + nv
        node_size = np.concatenate([g.node_size, batch.add_vertex_size])
        partition = np.concatenate([g.partition, batch.add_vertex_partition])
        node_alive = np.concatenate([self.node_alive, np.ones(nv, bool)])

        # --- append edges (endpoints may reference provisional ids) ------
        if ne:
            if (batch.add_edge_src >= n2).any() or (batch.add_edge_dst >= n2).any():
                raise ValueError("add_edge references an unknown vertex id")
            alive_before = np.concatenate([self.node_alive, np.ones(nv, bool)])
            if (~alive_before[batch.add_edge_src]).any() or (
                ~alive_before[batch.add_edge_dst]
            ).any():
                raise ValueError("add_edge references a deleted vertex")
        src = np.concatenate([g.src, batch.add_edge_src.astype(np.int32)])
        dst = np.concatenate([g.dst, batch.add_edge_dst.astype(np.int32)])
        edge_size = np.concatenate([g.edge_size, batch.add_edge_size])
        edge_alive = np.concatenate([self.edge_alive, np.ones(ne, bool)])
        new_edge_ids = old_m + np.arange(ne, dtype=np.int64)

        # --- tombstones ---------------------------------------------------
        del_e = batch.del_edge_ids
        if len(del_e):
            if (del_e >= old_m).any():
                raise ValueError("delete_edge references an unknown edge id")
            edge_alive[del_e] = False
        dead_v = batch.del_vertex_ids
        if len(dead_v):
            # provisional ids (vertices added in this same batch) are legal
            # delete targets; only ids beyond the post-batch range are unknown
            if (dead_v >= n2).any():
                raise ValueError("delete_vertex references an unknown vertex id")
            node_alive[dead_v] = False
            dead_v_mask = np.zeros(n2, dtype=bool)
            dead_v_mask[dead_v] = True
            cascade = edge_alive & (dead_v_mask[src] | dead_v_mask[dst])
        else:
            cascade = np.zeros(len(src), dtype=bool)
        edge_alive &= ~cascade
        dead_edges = np.unique(
            np.concatenate([del_e, np.where(cascade)[0]])
        ).astype(np.int64)
        # an edge both added and cascade-killed in one batch stays dead
        dead_edges = dead_edges[dead_edges < old_m + ne]

        # --- commit -------------------------------------------------------
        self.g = Graph(
            n_nodes=n2,
            src=src,
            dst=dst,
            node_size=node_size,
            edge_size=edge_size,
            partition=partition,
        )
        self.node_alive = node_alive
        self.edge_alive = edge_alive
        for _ in range(nv):
            self.adj.add_node()
            self.radj.add_node()
        for j in range(ne):
            u, v = int(batch.add_edge_src[j]), int(batch.add_edge_dst[j])
            eid = int(old_m + j)
            self.adj.add_edge(u, v, eid)
            self.radj.add_edge(v, u, eid)
        if self.adj.needs_merge():
            self.adj.merge(src, dst, edge_alive)
            self.radj.merge(dst, src, edge_alive)

        # --- touched frontier --------------------------------------------
        mut_e = np.concatenate([new_edge_ids, dead_edges]).astype(np.int64)
        endpoints = np.concatenate([src[mut_e], dst[mut_e]]) if len(mut_e) else np.zeros(0, np.int64)
        # dead vertices stay in the touched set: downstream consumers (e.g.
        # the warm DHD ELL) must clear their rows, not skip them
        new_vids = old_n + np.arange(nv, dtype=np.int64)
        touched = np.unique(np.concatenate([endpoints, new_vids, dead_v]))

        return ApplyResult(
            old_n_nodes=old_n,
            old_n_edges=old_m,
            n_new_vertices=nv,
            new_vertex_ids=new_vids,
            new_edge_ids=new_edge_ids,
            dead_vertex_ids=np.asarray(dead_v, np.int64),
            dead_edge_ids=dead_edges,
            touched_vertices=touched.astype(np.int64),
        )

    # ------------------------------------------------------------- compact
    def compact(self) -> Tuple[Graph, np.ndarray, np.ndarray]:
        """Dense re-numbered graph over alive vertices/edges.

        Returns (graph, vmap, emap): ``vmap[old_vertex] -> new id or -1``,
        ``emap[old_edge] -> new id or -1``.
        """
        vkeep = np.where(self.node_alive)[0]
        vmap = np.full(self.g.n_nodes, -1, dtype=np.int64)
        vmap[vkeep] = np.arange(len(vkeep))
        ekeep = np.where(self.edge_alive)[0]
        emap = np.full(self.g.n_edges, -1, dtype=np.int64)
        emap[ekeep] = np.arange(len(ekeep))
        g = Graph(
            n_nodes=len(vkeep),
            src=vmap[self.g.src[ekeep]].astype(np.int32),
            dst=vmap[self.g.dst[ekeep]].astype(np.int32),
            node_size=self.g.node_size[vkeep],
            edge_size=self.g.edge_size[ekeep],
            partition=self.g.partition[vkeep],
        )
        return g, vmap, emap


def compact_workload(
    wl: Workload, old_n_nodes: int, gc: Graph, vmap: np.ndarray, emap: np.ndarray
) -> Workload:
    """Re-key a workload onto a :meth:`DeltaGraph.compact` graph.

    Dead items are dropped from every pattern; frequencies are re-aggregated.
    This is what a from-scratch rebuild consumes, so incremental-vs-rebuild
    comparisons evaluate the same logical workload.
    """
    pats: List[Pattern] = []
    for p in wl.patterns:
        vi = p.items[p.items < old_n_nodes]
        ei = p.items[p.items >= old_n_nodes] - old_n_nodes
        v2 = vmap[vi]
        e2 = emap[ei]
        items = np.concatenate([v2[v2 >= 0], gc.n_nodes + e2[e2 >= 0]])
        pats.append(
            Pattern(pid=p.pid, items=np.sort(items), r_py=p.r_py, w_py=p.w_py, eta=p.eta)
        )
    return Workload.from_patterns(pats, gc.n_items, wl.n_dcs)


# ----------------------------------------------------------------- churn gen
def random_churn_batch(
    dg: DeltaGraph,
    rate: float,
    rng: np.random.Generator,
    vertex_fraction: float = 0.1,
) -> MutationBatch:
    """A mixed mutation batch touching ~``rate`` of the alive edges.

    Composition mirrors social-graph churn: mostly edge births/deaths between
    existing vertices, a thin stream of vertex arrivals (wired to random
    alive vertices) and departures (cascading their incident edges).
    """
    g = dg.g
    alive_v = np.where(dg.node_alive)[0]
    alive_e = np.where(dg.edge_alive)[0]
    n_e = max(1, int(rate * len(alive_e)))
    n_v = max(1, int(vertex_fraction * n_e))
    log = MutationLog(g.n_nodes)

    # vertex arrivals, each wired with 1-3 edges
    for _ in range(n_v):
        dc = int(rng.integers(0, int(g.partition.max()) + 1))
        vid = log.add_vertex(partition=dc, size=1.0)
        for _ in range(int(rng.integers(1, 4))):
            peer = int(rng.choice(alive_v))
            if rng.random() < 0.5:
                log.add_edge(vid, peer)
            else:
                log.add_edge(peer, vid)

    # edge births between existing vertices
    for _ in range(n_e):
        u, v = rng.choice(alive_v, size=2, replace=False)
        log.add_edge(int(u), int(v))

    # edge deaths
    for eid in rng.choice(alive_e, size=min(n_e, len(alive_e)), replace=False):
        log.delete_edge(int(eid))

    # vertex departures
    if len(alive_v) > 8 * n_v:
        for vid in rng.choice(alive_v, size=n_v, replace=False):
            log.delete_vertex(int(vid))

    return log.seal()
