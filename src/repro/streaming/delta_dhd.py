"""Warm-started DHD steady state for streaming updates (tentpole, part 3).

The store keeps the previous equilibrium heat field; a mutation batch only
perturbs the field near the touched vertices, so the new equilibrium is
reached in far fewer sweeps than a cold solve:

  1. *frontier pre-solve* — extract the touched frontier plus a one-ring halo,
     clamp the halo to its current (globally-correct) heat, and relax the
     frontier on the small sub-ELL;
  2. *global sweeps* — run full-graph DHD steps from the pre-solved field
     until the residual drops below tolerance.

Both phases go through :func:`repro.kernels.ops.dhd_step`, i.e. the Pallas
ELL kernel on TPU and the vectorized jnp reference on CPU.  The ELL adjacency
is patched row-wise per batch (only touched rows are recomputed) rather than
rebuilt.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.dhd import DHDParams, steady_state
from ..kernels import ops

__all__ = ["StreamingHeat", "WarmStats", "STREAMING_DHD_PARAMS"]

# Constant-source fixed-point iteration needs the Theorem-1 contraction
# regime; the paper's alpha=0.5 placement default is tuned for the *decaying*
# source runs and overshoots ||L_dir||_inf here.  alpha below is only an
# upper cap — ``StreamingHeat._effective_alpha`` clamps it per graph so the
# update map is a contraction with a unique equilibrium.
STREAMING_DHD_PARAMS = DHDParams(alpha=0.05, gamma=0.1, beta=0.3)


@dataclasses.dataclass
class WarmStats:
    frontier_size: int
    halo_size: int
    local_iters: int
    global_iters: int
    residual: float  # sup-norm step size at exit: carried-over staleness


def _round8(k: int) -> int:
    return max(8, int(np.ceil(k / 8.0)) * 8)


# Rows are padded to a multiple of this: shapes stay stable across growth
# batches (no per-batch recompiles) and satisfy the Pallas kernel's block
# divisibility, keeping the TPU hot path eligible.  Pad rows are isolated
# self-loops with zero weight and zero source, so they hold heat 0 forever.
_ROW_PAD = 256


def _padded(n: int) -> int:
    return max(_ROW_PAD, int(np.ceil(n / _ROW_PAD)) * _ROW_PAD)


def _sym_halves(
    src: np.ndarray, dst: np.ndarray, w: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Each undirected edge as two directed halves (u->v and v->u)."""
    uu = np.concatenate([src, dst]).astype(np.int64)
    vv = np.concatenate([dst, src]).astype(np.int64)
    ww = np.concatenate([w, w]).astype(np.float32)
    return uu, vv, ww


def _fill_rows(
    cols: np.ndarray,
    vals: np.ndarray,
    rows: np.ndarray,
    uu: np.ndarray,
    vv: np.ndarray,
    ww: np.ndarray,
) -> bool:
    """Recompute the ELL rows in ``rows`` from directed halves (uu -> vv).

    Returns False when some row overflows kmax (caller must rebuild)."""
    kmax = cols.shape[1]
    sel = np.isin(uu, rows)
    uu, vv, ww = uu[sel], vv[sel], ww[sel]
    order = np.argsort(uu, kind="stable")
    uu, vv, ww = uu[order], vv[order], ww[order]
    counts = np.bincount(uu, minlength=cols.shape[0])
    if counts[rows].max(initial=0) > kmax:
        return False
    # reset to self-pad, then scatter each row's neighbor run
    cols[rows] = rows[:, None]
    vals[rows] = 0.0
    starts = np.zeros(cols.shape[0] + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for u in rows.tolist():
        lo, hi = int(starts[u]), int(starts[u + 1])
        k = hi - lo
        if k:
            cols[u, :k] = vv[lo:hi]
            vals[u, :k] = ww[lo:hi]
    return True


class StreamingHeat:
    """Persistent DHD equilibrium over the alive graph, warm-updated per batch.

    ``rebuild`` performs the cold construction (and is the overflow fallback);
    ``update`` patches the touched ELL rows and re-solves warm.
    """

    def __init__(
        self,
        params: DHDParams = STREAMING_DHD_PARAMS,
        max_iters: int = 300,
        tol: float = 1e-6,
    ) -> None:
        self.params = params
        self.alpha = params.alpha  # clamped per-graph by _effective_alpha
        self.max_iters = max_iters
        self.tol = tol
        self.n_nodes = 0
        self.cols: Optional[np.ndarray] = None  # [n, kmax] int32
        self.vals: Optional[np.ndarray] = None  # [n, kmax] float32
        self.heat: Optional[np.ndarray] = None  # [n] float32
        self.q: Optional[np.ndarray] = None  # [n] float32
        # staleness metric: sup-norm change of one more sweep from the field
        # the last solve() exited with (0 at equilibrium, >0 when the sweep
        # budget ran out first).  Surfaced via WarmStats / UpdateReport.
        self.residual: float = 0.0
        # device-resident adjacency; refreshed by row scatter on warm updates
        self._cols_j: Optional[jnp.ndarray] = None
        self._vals_j: Optional[jnp.ndarray] = None

    def _sync_device(self, rows: Optional[np.ndarray] = None) -> None:
        """Mirror cols/vals to device — full upload, or a row scatter when
        only ``rows`` changed (saves the [n, kmax] host->device copy that
        otherwise dominates small warm updates)."""
        if rows is None or self._cols_j is None or self._cols_j.shape != self.cols.shape:
            self._cols_j = jnp.asarray(self.cols)
            self._vals_j = jnp.asarray(self.vals)
        elif len(rows):
            self._cols_j = self._cols_j.at[rows].set(jnp.asarray(self.cols[rows]))
            self._vals_j = self._vals_j.at[rows].set(jnp.asarray(self.vals[rows]))

    @property
    def vertex_heat(self) -> Optional[np.ndarray]:
        """Equilibrium heat for the real vertices (pad rows stripped)."""
        return None if self.heat is None else self.heat[: self.n_nodes]

    def _effective_alpha(self) -> float:
        """Clamp alpha into the Theorem-1 contraction regime.

        ||L_dir||_inf <= max_e A_e + max_v weighted_deg(v) for any heat
        ordering (out-flows average over |N^out|, in-flows are bounded by the
        incident weight sum), so alpha <= 0.5 * gamma / ((1-gamma) * bound)
        makes the update map a contraction.  That is what guarantees a
        *unique* steady state — without it the ReLU-gated flow has multiple
        equilibria and warm vs cold solves can land on different ones.
        Recomputed after every topology patch so warm updates and cold
        rebuilds of the same graph always iterate the same map.
        """
        p = self.params
        wdeg = float(self.vals.sum(axis=1).max(initial=0.0))
        wmax = float(self.vals.max(initial=0.0))
        bound = wmax + wdeg
        if bound <= 0.0:
            return p.alpha
        safe = 0.5 * p.gamma / ((1.0 - p.gamma) * bound)
        return min(p.alpha, safe)

    # ----------------------------------------------------------- cold path
    def rebuild(
        self,
        n_nodes: int,
        src: np.ndarray,
        dst: np.ndarray,
        weights: np.ndarray,
        q: np.ndarray,
        heat0: Optional[np.ndarray] = None,
    ) -> int:
        """Cold build of the symmetric ELL + full solve.  Returns iterations.

        ``heat0`` warm-seeds the solve from a prior field of length
        ``n_nodes`` — the compaction re-key path, where the topology arrays
        are renumbered but the equilibrium is (row-permuted) unchanged."""
        uu, vv, ww = _sym_halves(src, dst, weights)
        deg = np.bincount(uu, minlength=n_nodes) if len(uu) else np.zeros(n_nodes, np.int64)
        # one extra octet of headroom so streaming edge growth rarely
        # overflows a row (overflow forces a cold rebuild + recompile)
        kmax = _round8(int(deg.max(initial=1)) + 8)
        n_pad = _padded(n_nodes)
        self.n_nodes = n_nodes
        self.cols = np.repeat(np.arange(n_pad, dtype=np.int32)[:, None], kmax, axis=1)
        self.vals = np.zeros((n_pad, kmax), np.float32)
        if len(uu):
            _fill_rows(self.cols, self.vals, np.arange(n_nodes), uu, vv, ww)
        self.q = np.zeros(n_pad, np.float32)
        self.q[:n_nodes] = np.asarray(q, np.float32)
        self.heat = self.q.copy()
        if heat0 is not None:
            self.heat[:n_nodes] = np.asarray(heat0, np.float32)
        self.alpha = self._effective_alpha()
        self._sync_device()
        return self.solve()

    # --------------------------------------------------------------- solve
    def _sweep(
        self, heat: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray, q: jnp.ndarray
    ) -> jnp.ndarray:
        p = self.params
        return ops.dhd_step(
            heat, cols, vals, q, alpha=self.alpha, gamma=p.gamma, beta=p.beta
        )

    def solve(self, max_iters: Optional[int] = None, tol: Optional[float] = None) -> int:
        """Full-graph sweeps from the current field until the residual < tol.

        Runs through :func:`repro.core.dhd.steady_state` (``lax.while_loop``)
        so the whole fixed-point iteration stays on device."""
        max_iters = max_iters or self.max_iters
        tol = tol or self.tol
        if self._cols_j is None:
            self._sync_device()
        cols = self._cols_j
        vals = self._vals_j
        q = jnp.asarray(self.q)
        h, it = steady_state(
            jnp.asarray(self.heat),
            lambda hh, qq: self._sweep(hh, cols, vals, qq),
            lambda k: q,
            max_iters=max_iters,
            tol=tol,
        )
        self.heat = np.array(h)  # np.array: jax buffers are read-only views
        # one probe sweep prices the carried-over staleness: how far one more
        # iteration would still move the field (0 when converged within tol)
        self.residual = float(jnp.max(jnp.abs(self._sweep(h, cols, vals, q) - h)))
        return int(it)

    # ---------------------------------------------------------- warm path
    def _neighbors_of(self, mask: np.ndarray) -> np.ndarray:
        """Vertices adjacent to the masked set (via the current ELL rows)."""
        rows = np.where(mask)[0]
        if len(rows) == 0:
            return np.zeros(0, np.int64)
        nb = self.cols[rows][self.vals[rows] > 0]
        return np.unique(nb.astype(np.int64))

    def update(
        self,
        n_nodes: int,
        src: np.ndarray,
        dst: np.ndarray,
        weights: np.ndarray,
        q: np.ndarray,
        touched: np.ndarray,
        halo_hops: int = 1,
        local_iters: int = 16,
        max_frontier_frac: float = 0.2,
    ) -> WarmStats:
        """Absorb a topology/source delta and re-solve warm.

        ``src/dst/weights`` describe the *alive* undirected edges of the new
        graph; ``touched`` are the vertices whose incident edges or sources
        changed (new vertices included, ids at the end of the range).
        """
        if self.cols is None:
            it = self.rebuild(n_nodes, src, dst, weights, q)
            return WarmStats(n_nodes, 0, 0, it, self.residual)
        n_pad_old = self.cols.shape[0]
        if n_nodes > n_pad_old:
            n_pad = _padded(n_nodes)
            kmax = self.cols.shape[1]
            extra = n_pad - n_pad_old
            pad_cols = np.repeat(
                np.arange(n_pad_old, n_pad, dtype=np.int32)[:, None], kmax, axis=1
            )
            self.cols = np.concatenate([self.cols, pad_cols])
            self.vals = np.concatenate([self.vals, np.zeros((extra, kmax), np.float32)])
            self.heat = np.concatenate([self.heat, np.zeros(extra, np.float32)])
        self.n_nodes = n_nodes
        self.q = np.zeros(self.cols.shape[0], np.float32)
        self.q[:n_nodes] = np.asarray(q, np.float32)

        touched = np.unique(np.asarray(touched, np.int64))
        uu, vv, ww = _sym_halves(src, dst, weights)
        if not _fill_rows(self.cols, self.vals, touched, uu, vv, ww):
            # a touched row outgrew kmax — cold rebuild fallback
            it = self.rebuild(n_nodes, src, dst, weights, q)
            return WarmStats(len(touched), 0, 0, it, self.residual)
        self.alpha = self._effective_alpha()
        self._sync_device(rows=touched)

        # --- frontier pre-solve over F + clamped halo ---------------------
        # Only worth it when the frontier stays a small fraction of the
        # graph; at high churn the expansion covers nearly every vertex and
        # the local phase would just duplicate the global sweeps.
        n_pad = self.cols.shape[0]
        local_done = 0
        frontier = touched
        bmask = cmask = None
        if len(touched) and len(touched) <= max_frontier_frac * n_nodes:
            fmask = np.zeros(n_pad, dtype=bool)
            fmask[touched] = True
            for _ in range(halo_hops):
                fmask[self._neighbors_of(fmask)] = True
            frontier = np.where(fmask)[0]
            bmask = np.zeros(n_pad, dtype=bool)
            bmask[self._neighbors_of(fmask)] = True
            bmask &= ~fmask
            # ghost ring: halo rows are kept complete so their |N^out| is
            # exact, which needs their out-of-halo neighbors present too
            cmask = np.zeros(n_pad, dtype=bool)
            cmask[self._neighbors_of(bmask)] = True
            cmask &= ~(fmask | bmask)
        if (
            bmask is not None
            and len(frontier) <= max_frontier_frac * n_nodes
            and len(frontier)
        ):
            sub = np.concatenate([frontier, np.where(bmask)[0], np.where(cmask)[0]])
            # pad the subproblem coarsely (1024-row quantum): sub sizes vary
            # per batch, and every new shape is a fresh while_loop compile —
            # coarse buckets make consecutive batches reuse the same one
            # (pad rows = isolated, clamped to 0)
            n_sub = max(1024, int(np.ceil(len(sub) / 1024.0)) * 1024)
            lmap = np.full(n_pad, -1, dtype=np.int64)
            lmap[sub] = np.arange(len(sub))
            rows_fb = sub[: len(frontier) + int(bmask.sum())]
            cols_l = np.repeat(
                np.arange(n_sub, dtype=np.int32)[:, None], self.cols.shape[1], axis=1
            )
            vals_l = np.zeros((n_sub, self.cols.shape[1]), np.float32)
            cols_l[: len(rows_fb)] = lmap[self.cols[rows_fb]].astype(np.int32)
            vals_l[: len(rows_fb)] = self.vals[rows_fb]
            clamp = jnp.arange(len(frontier), n_sub)
            clamp_np = np.zeros(n_sub - len(frontier), np.float32)
            clamp_np[: len(sub) - len(frontier)] = self.heat[sub[len(frontier):]]
            clamp_vals = jnp.asarray(clamp_np)
            q_np = np.zeros(n_sub, np.float32)
            q_np[: len(sub)] = self.q[sub]
            q_sub = jnp.asarray(q_np)
            h_np = np.zeros(n_sub, np.float32)
            h_np[: len(sub)] = self.heat[sub]
            cols_j, vals_j = jnp.asarray(cols_l), jnp.asarray(vals_l)
            h_sub, k_local = steady_state(
                jnp.asarray(h_np),
                lambda hh, qq: self._sweep(hh, cols_j, vals_j, qq)
                .at[clamp].set(clamp_vals),
                lambda k: q_sub,
                max_iters=local_iters,
                tol=self.tol,
            )
            local_done = int(k_local)
            self.heat[frontier] = np.asarray(h_sub)[: len(frontier)]

        # --- global mop-up sweeps ----------------------------------------
        it = self.solve()
        return WarmStats(
            frontier_size=len(frontier),
            halo_size=0 if bmask is None else int(bmask.sum() + cmask.sum()),
            local_iters=local_done,
            global_iters=it,
            residual=self.residual,
        )
