"""Streaming-update subsystem: incremental maintenance of the GeoLayer store
under topology churn (paper §V "Update Maintenance", made structural).

Pipeline per mutation batch:

  1. :mod:`mutation_log`  — batch vertex/edge inserts+deletes into a
     delta-CSR overlay; stable item ids, tombstoned deletes, periodic compact.
  2. :mod:`repro.core.layered_graph.repair_layered_graph` — re-level only the
     layers whose DC-pair presence a batch invalidated.
  3. :mod:`delta_dhd`     — warm-start the DHD steady state from the previous
     equilibrium; frontier-local pre-solve through the ELL hot path.
  4. :mod:`migration`     — turn heat deltas into a cost-bounded replica
     move-set (vectorized planner), pack its adds into per-(src, dst)
     transfer waves under the Table I link bandwidth budgets, and apply them
     wave by wave, validated against the Eq. 6 constraints.

The public store entry points are ``GeoGraphStore.apply_updates()`` and
``GeoGraphStore.flush_migrations()``.
"""
from .mutation_log import (  # noqa: F401
    ApplyResult,
    DeltaCSR,
    DeltaGraph,
    MutationBatch,
    MutationLog,
    compact_workload,
    random_churn_batch,
)
from .delta_dhd import StreamingHeat, WarmStats  # noqa: F401
from .migration import (  # noqa: F401
    MigrationPlan,
    MigrationSchedule,
    Move,
    TransferBatch,
    TransferWave,
    apply_plan,
    plan_migrations,
    schedule_transfers,
)

__all__ = [
    "MutationLog",
    "MutationBatch",
    "DeltaCSR",
    "DeltaGraph",
    "ApplyResult",
    "random_churn_batch",
    "compact_workload",
    "StreamingHeat",
    "WarmStats",
    "Move",
    "MigrationPlan",
    "MigrationSchedule",
    "TransferBatch",
    "TransferWave",
    "plan_migrations",
    "schedule_transfers",
    "apply_plan",
]
