"""Cost-bounded replica migration from heat deltas (tentpole, part 4).

After a churn batch shifts the DHD equilibrium, the placement is stale in two
directions: newly-hot items are missing replicas near their readers, and
previously-hot replicas have gone cold.  The planner turns the heat field
into a move-set:

  * **adds** — hot items (heat >= the ``theta_add`` quantile) gain a replica
    at requesting DCs where the per-window read saving beats the added
    storage + write-sync cost (the Eq. 13 surrogate at item granularity);
    each add ships ``size`` bytes over the WAN.
  * **drops** — cold replicas (heat < ``theta_drop`` of the max) that are
    neither the primary copy, nor the sole replica, nor read locally, are
    released for free.

Adds are taken greedily by benefit-per-WAN-byte under ``budget_bytes``
(the paper's migration condition ξ, Eq. 14, as a byte budget).  Application
re-routes exactly the touched items and is guarded by
:func:`repro.core.cost.check_constraints`: a plan never turns a previously
satisfied constraint into a violation — offending drops are rolled back.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cost import PlacementState, check_constraints
from ..core.latency import GeoEnvironment
from ..core.route_index import RouteIndex

__all__ = ["Move", "MigrationPlan", "plan_migrations", "apply_plan"]


@dataclasses.dataclass
class Move:
    item: int
    dc: int
    kind: str  # "add" | "drop"
    benefit: float  # $/window cost saving (surrogate)
    wan_bytes: float  # bytes shipped to realize the move


@dataclasses.dataclass
class MigrationPlan:
    moves: List[Move]
    wan_bytes: float
    est_benefit: float
    n_candidates: int
    skipped_budget: int  # adds skipped (byte budget exhausted or move cap)
    rolled_back: int = 0  # drops reverted by the constraint guard

    @property
    def n_adds(self) -> int:
        return sum(1 for m in self.moves if m.kind == "add")

    @property
    def n_drops(self) -> int:
        return sum(1 for m in self.moves if m.kind == "drop")


def _primary_dcs(g) -> np.ndarray:
    return np.concatenate([g.partition, g.partition[g.src]]).astype(np.int64)


def plan_migrations(
    g,
    env: GeoEnvironment,
    state: PlacementState,
    r_xy: np.ndarray,
    w_xy: np.ndarray,
    item_heat: np.ndarray,
    budget_bytes: float,
    theta_add: float = 0.80,
    theta_drop: float = 0.05,
    max_moves: int = 1024,
    item_alive: Optional[np.ndarray] = None,
) -> MigrationPlan:
    """Propose a move-set; pure planning, no state mutation."""
    sizes = g.item_size()
    I, D = r_xy.shape
    alive = (
        np.ones(I, dtype=bool) if item_alive is None else np.asarray(item_alive, bool)
    )
    primary = _primary_dcs(g)
    heat = np.asarray(item_heat, np.float64)
    hmax = float(heat[alive].max(initial=0.0))
    moves: List[Move] = []
    n_cand = 0

    # ------------------------------------------------------------- drops
    if hmax > 0:
        cold = alive & (heat < theta_drop * hmax)
    else:
        cold = np.zeros(I, dtype=bool)
    n_replicas = state.delta.sum(axis=1)
    drop_cands: List[Move] = []
    for x in np.where(cold & (n_replicas > 1))[0]:
        # only replicas no origin currently reads from are free to drop —
        # a replica serving remote origins would push their reads to a
        # farther DC, a read-cost increase the drop benefit doesn't model
        serving = np.unique(state.route[x][r_xy[x] > 0])
        for d in np.where(state.delta[x])[0]:
            d = int(d)
            if d == primary[x] or d in serving:
                continue
            n_cand += 1
            benefit = float(sizes[x]) * float(env.c_store[d]) + float(
                (w_xy[x] * (env.c_write[d] + sizes[x] * env.c_net[:, d])).sum()
            )
            drop_cands.append(Move(int(x), d, "drop", benefit, 0.0))
    # keep the move-set minimal: highest-value drops first, at most half the
    # cap so adds keep room in the move-set
    drop_cands.sort(key=lambda m: m.benefit, reverse=True)
    moves.extend(drop_cands[: max_moves // 2])

    # -------------------------------------------------------------- adds
    pos = heat[alive & (heat > 0)]
    theta = float(np.quantile(pos, theta_add)) if len(pos) else np.inf
    hot = alive & (heat >= theta) & (heat > 0)
    add_cands: List[Move] = []
    for x in np.where(hot)[0]:
        sx = float(sizes[x])
        w_sync = w_xy[x]
        for d in np.where((r_xy[x] > 0) & ~state.delta[x])[0]:
            d = int(d)
            cur = int(state.route[x, d])
            if cur < 0:
                cur = int(primary[x])
            n_cand += 1
            read_save = float(r_xy[x, d]) * sx * float(env.c_net[cur, d])
            store_add = sx * float(env.c_store[d])
            write_add = float(
                (w_sync * (env.c_write[d] + sx * env.c_net[:, d])).sum()
            )
            benefit = read_save - store_add - write_add
            if benefit > 0:
                add_cands.append(Move(int(x), d, "add", benefit, sx))

    # greedy knapsack by benefit density under the WAN byte budget
    add_cands.sort(key=lambda m: m.benefit / max(m.wan_bytes, 1e-9), reverse=True)
    wan = 0.0
    skipped = 0
    for m in add_cands:
        if len(moves) >= max_moves:
            skipped += 1
            continue
        if wan + m.wan_bytes > budget_bytes:
            skipped += 1
            continue
        wan += m.wan_bytes
        moves.append(m)

    return MigrationPlan(
        moves=moves,
        wan_bytes=wan,
        est_benefit=float(sum(m.benefit for m in moves)),
        n_candidates=n_cand,
        skipped_budget=skipped,
    )


def _reroute_items(
    state: PlacementState, env: GeoEnvironment, rows: np.ndarray
) -> None:
    """Partial Eq. 1 nearest-replica refresh for just ``rows``."""
    state.route_nearest(env, rows=np.asarray(rows))


def apply_plan(
    plan: MigrationPlan,
    state: PlacementState,
    env: GeoEnvironment,
    patterns: Sequence,
    r_xy: np.ndarray,
    sizes: np.ndarray,
    gamma_max_s: float,
    route_index: Optional["RouteIndex"] = None,
) -> Dict[str, bool]:
    """Apply the plan with a constraint guard; returns the final check flags.

    Invariant: no constraint that held before application is violated after —
    adds only widen the replica sets, and drops are rolled back wholesale if
    the post-check regresses.

    With a :class:`~repro.core.route_index.RouteIndex` the routing refresh is
    the move-set delta patch (``apply_moves``); otherwise the touched rows are
    re-derived with a partial ``route_nearest``.
    """

    def _refresh(rows: np.ndarray, moves=None) -> None:
        if route_index is None:
            _reroute_items(state, env, rows)
        elif moves is not None:
            route_index.apply_moves(state.delta, moves)
        else:  # rollback: replica sets changed outside the move-set shape
            route_index.patch_rows(state.delta, rows)
        if route_index is not None:
            state.route = route_index.nearest

    before = check_constraints(patterns, state, r_xy, sizes, env, gamma_max_s)
    touched = np.unique([m.item for m in plan.moves]).astype(np.int64)
    for m in plan.moves:
        state.delta[m.item, m.dc] = m.kind == "add"
    _refresh(touched, moves=plan.moves)
    after = check_constraints(patterns, state, r_xy, sizes, env, gamma_max_s)
    if any(before[k] and not after[k] for k in before):
        drops = [m for m in plan.moves if m.kind == "drop"]
        for m in drops:
            state.delta[m.item, m.dc] = True
        _refresh(touched)
        plan.rolled_back = len(drops)
        plan.moves = [m for m in plan.moves if m.kind == "add"]
        plan.est_benefit = float(sum(m.benefit for m in plan.moves))
        after = check_constraints(patterns, state, r_xy, sizes, env, gamma_max_s)
    return after
