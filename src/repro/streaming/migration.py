"""Bandwidth-aware replica migration: vectorized planning, link-granular
transfer scheduling, wave-ordered application.

After a churn batch shifts the DHD equilibrium, the placement is stale in two
directions: newly-hot items are missing replicas near their readers, and
previously-hot replicas have gone cold.  The subsystem turns the heat field
into a move-set and the move-set into a WAN transfer pipeline:

  1. **Planning** (:func:`plan_migrations`) — drop and add benefits are
     masked ``[K, D]`` matrix reductions (the Eq. 13 surrogate at item
     granularity):

       * **adds** — hot items (heat >= the ``theta_add`` quantile) gain a
         replica at requesting DCs where the per-window read saving beats the
         added storage + write-sync cost; each add ships ``size`` bytes over
         the WAN from its nearest current replica.
       * **drops** — cold replicas (heat < ``theta_drop`` of the max) that
         are neither the primary copy, nor the sole replica, nor read
         locally, are released for free.

     Adds are taken greedily by benefit-per-WAN-byte under ``budget_bytes``
     (the paper's migration condition ξ, Eq. 14, as a global byte budget).
     The original per-item Python loops survive as ``vectorized=False`` —
     the differential reference the matrix path is held to, move for move
     (``tests/test_migration_pipeline.py``).
  2. **Scheduling** (:func:`schedule_transfers`) — accepted adds become
     per-``(src, dst)`` :class:`TransferBatch`es; the source is the nearest
     current replica (the ``route[x, dst]`` entry the read saving was priced
     against, falling back to the primary).  Batches are packed into
     :class:`TransferWave`s under **per-link** byte budgets
     ``env.bw_Bps * window_s`` (Table I): within a wave each link carries at
     most one migration window's worth of bytes, links run concurrently, and
     the pipelined makespan estimate is
     ``sum over waves of max over active links (bytes / bw + rtt)``.
  3. **Application** (:func:`apply_plan` with a schedule) — waves land in
     order, each patching ``state.delta`` and the :class:`RouteIndex` before
     the next begins, so the route table is wave-boundary consistent and a
     frontend can serve between waves (``on_wave``).  Drops are released only
     after every transfer lands (readers keep their replica until the
     replacement exists) and are rolled back wholesale if the Eq. 6
     constraint check regresses — a plan never turns a previously satisfied
     constraint into a violation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cost import PlacementState, check_constraints
from ..core.latency import GeoEnvironment
from ..core.route_index import RouteIndex

__all__ = [
    "Move",
    "MigrationPlan",
    "TransferBatch",
    "TransferWave",
    "MigrationSchedule",
    "StaleFlushError",
    "plan_migrations",
    "schedule_transfers",
    "apply_plan",
    "WaveApplier",
]


class StaleFlushError(RuntimeError):
    """The item id space changed under an in-flight flush (mutation batch or
    compaction since ``begin_flush``); the remaining waves reference stale
    rows and must be re-planned.  Adds already applied are safe — they only
    widened replica sets in the pre-change id space and were remapped with
    everything else — and drops were never released."""


@dataclasses.dataclass
class Move:
    item: int
    dc: int
    kind: str  # "add" | "drop"
    benefit: float  # $/window cost saving (surrogate)
    wan_bytes: float  # bytes shipped to realize the move
    src: int = -1  # adds: nearest current replica the bytes ship from


@dataclasses.dataclass
class MigrationPlan:
    moves: List[Move]
    wan_bytes: float
    est_benefit: float
    n_candidates: int
    skipped_budget: int  # adds skipped (byte budget exhausted or move cap)
    rolled_back: int = 0  # drops reverted by the constraint guard
    schedule: Optional["MigrationSchedule"] = None  # set by flush_migrations

    @property
    def n_adds(self) -> int:
        return sum(1 for m in self.moves if m.kind == "add")

    @property
    def n_drops(self) -> int:
        return sum(1 for m in self.moves if m.kind == "drop")


def _primary_dcs(g) -> np.ndarray:
    return np.concatenate([g.partition, g.partition[g.src]]).astype(np.int64)


# ---------------------------------------------------------------- planning
def plan_migrations(
    g,
    env: GeoEnvironment,
    state: PlacementState,
    r_xy: np.ndarray,
    w_xy: np.ndarray,
    item_heat: np.ndarray,
    budget_bytes: float,
    theta_add: float = 0.80,
    theta_drop: float = 0.05,
    max_moves: int = 1024,
    item_alive: Optional[np.ndarray] = None,
    vectorized: bool = True,
) -> MigrationPlan:
    """Propose a move-set; pure planning, no state mutation.

    ``vectorized=False`` runs the per-item reference implementation; the
    default matrix path produces the identical move-set (same candidates,
    same benefits, same greedy order) at ~array speed.
    """
    if not vectorized:
        return _plan_migrations_legacy(
            g, env, state, r_xy, w_xy, item_heat, budget_bytes,
            theta_add, theta_drop, max_moves, item_alive,
        )
    sizes = g.item_size()
    I, D = r_xy.shape
    alive = (
        np.ones(I, dtype=bool) if item_alive is None else np.asarray(item_alive, bool)
    )
    primary = _primary_dcs(g)
    heat = np.asarray(item_heat, np.float64)
    hmax = float(heat[alive].max(initial=0.0))
    moves: List[Move] = []
    n_cand = 0

    # ------------------------------------------------------------- drops
    if hmax > 0:
        cold = alive & (heat < theta_drop * hmax)
    else:
        cold = np.zeros(I, dtype=bool)
    n_replicas = state.delta.sum(axis=1)
    cold_items = np.where(cold & (n_replicas > 1))[0]
    if len(cold_items):
        K = len(cold_items)
        # only replicas no origin currently reads from are free to drop — a
        # replica serving remote origins would push their reads to a farther
        # DC, a read-cost increase the drop benefit doesn't model.
        # serving[k, d] <=> exists y with r_xy[x, y] > 0 and route[x, y] == d
        routes = state.route[cold_items]  # [K, D]
        kk, yy = np.nonzero(r_xy[cold_items] > 0)
        rt = routes[kk, yy]
        ok = rt >= 0
        serving = np.zeros((K, D), dtype=bool)
        serving[kk[ok], rt[ok]] = True
        elig = state.delta[cold_items].copy()
        elig[np.arange(K), primary[cold_items]] = False
        elig &= ~serving
        kd, dd = np.nonzero(elig)  # (k asc, d asc) == reference loop order
        n_cand += len(kd)
        if len(kd):
            xc = cold_items[kd]
            # benefit[x, d] = s_x * c_store_d + sum_y w_xy * (c_put_d +
            # s_x * c_net[y, d]) — associated exactly like the reference so
            # the float64 results (and thus sort order) are bit-identical
            inner = env.c_write[dd][:, None] + sizes[xc][:, None] * env.c_net.T[dd]
            ben = sizes[xc] * env.c_store[dd] + (w_xy[xc] * inner).sum(axis=1)
            order = np.argsort(-ben, kind="stable")  # stable desc == reference
            for i in order[: max_moves // 2]:
                moves.append(Move(int(xc[i]), int(dd[i]), "drop", float(ben[i]), 0.0))

    # -------------------------------------------------------------- adds
    pos = heat[alive & (heat > 0)]
    theta = float(np.quantile(pos, theta_add)) if len(pos) else np.inf
    hot_items = np.where(alive & (heat >= theta) & (heat > 0))[0]
    wan = 0.0
    skipped = 0
    if len(hot_items):
        elig = (r_xy[hot_items] > 0) & ~state.delta[hot_items]
        hk, hd = np.nonzero(elig)
        n_cand += len(hk)
        if len(hk):
            xa = hot_items[hk]
            cur = state.route[xa, hd].astype(np.int64)
            cur = np.where(cur >= 0, cur, primary[xa])  # nearest replica / primary
            read_save = r_xy[xa, hd] * sizes[xa] * env.c_net[cur, hd]
            store_add = sizes[xa] * env.c_store[hd]
            inner = env.c_write[hd][:, None] + sizes[xa][:, None] * env.c_net.T[hd]
            write_add = (w_xy[xa] * inner).sum(axis=1)
            ben = read_save - store_add - write_add
            keep = ben > 0
            xa, hd, cur, ben = xa[keep], hd[keep], cur[keep], ben[keep]
            wb = sizes[xa].astype(np.float64)
            # greedy knapsack by benefit density under the WAN byte budget;
            # stable descending argsort == the reference's stable sort
            order = np.argsort(-(ben / np.maximum(wb, 1e-9)), kind="stable")
            slots = max_moves - len(moves)
            n_acc = 0
            for i in order:
                if n_acc >= slots:
                    skipped += 1
                    continue
                if wan + wb[i] > budget_bytes:
                    skipped += 1
                    continue
                wan += float(wb[i])
                n_acc += 1
                moves.append(
                    Move(int(xa[i]), int(hd[i]), "add", float(ben[i]),
                         float(wb[i]), src=int(cur[i]))
                )

    return MigrationPlan(
        moves=moves,
        wan_bytes=wan,
        est_benefit=float(sum(m.benefit for m in moves)),
        n_candidates=n_cand,
        skipped_budget=skipped,
    )


def _plan_migrations_legacy(
    g,
    env: GeoEnvironment,
    state: PlacementState,
    r_xy: np.ndarray,
    w_xy: np.ndarray,
    item_heat: np.ndarray,
    budget_bytes: float,
    theta_add: float = 0.80,
    theta_drop: float = 0.05,
    max_moves: int = 1024,
    item_alive: Optional[np.ndarray] = None,
) -> MigrationPlan:
    """Per-item reference planner (the pre-pipeline implementation)."""
    sizes = g.item_size()
    I, D = r_xy.shape
    alive = (
        np.ones(I, dtype=bool) if item_alive is None else np.asarray(item_alive, bool)
    )
    primary = _primary_dcs(g)
    heat = np.asarray(item_heat, np.float64)
    hmax = float(heat[alive].max(initial=0.0))
    moves: List[Move] = []
    n_cand = 0

    # ------------------------------------------------------------- drops
    if hmax > 0:
        cold = alive & (heat < theta_drop * hmax)
    else:
        cold = np.zeros(I, dtype=bool)
    n_replicas = state.delta.sum(axis=1)
    drop_cands: List[Move] = []
    for x in np.where(cold & (n_replicas > 1))[0]:
        serving = np.unique(state.route[x][r_xy[x] > 0])
        for d in np.where(state.delta[x])[0]:
            d = int(d)
            if d == primary[x] or d in serving:
                continue
            n_cand += 1
            benefit = float(sizes[x]) * float(env.c_store[d]) + float(
                (w_xy[x] * (env.c_write[d] + sizes[x] * env.c_net[:, d])).sum()
            )
            drop_cands.append(Move(int(x), d, "drop", benefit, 0.0))
    # keep the move-set minimal: highest-value drops first, at most half the
    # cap so adds keep room in the move-set
    drop_cands.sort(key=lambda m: m.benefit, reverse=True)
    moves.extend(drop_cands[: max_moves // 2])

    # -------------------------------------------------------------- adds
    pos = heat[alive & (heat > 0)]
    theta = float(np.quantile(pos, theta_add)) if len(pos) else np.inf
    hot = alive & (heat >= theta) & (heat > 0)
    add_cands: List[Move] = []
    for x in np.where(hot)[0]:
        sx = float(sizes[x])
        w_sync = w_xy[x]
        for d in np.where((r_xy[x] > 0) & ~state.delta[x])[0]:
            d = int(d)
            cur = int(state.route[x, d])
            if cur < 0:
                cur = int(primary[x])
            n_cand += 1
            read_save = float(r_xy[x, d]) * sx * float(env.c_net[cur, d])
            store_add = sx * float(env.c_store[d])
            write_add = float(
                (w_sync * (env.c_write[d] + sx * env.c_net[:, d])).sum()
            )
            benefit = read_save - store_add - write_add
            if benefit > 0:
                add_cands.append(Move(int(x), d, "add", benefit, sx, src=cur))

    # greedy knapsack by benefit density under the WAN byte budget
    add_cands.sort(key=lambda m: m.benefit / max(m.wan_bytes, 1e-9), reverse=True)
    wan = 0.0
    skipped = 0
    for m in add_cands:
        if len(moves) >= max_moves:
            skipped += 1
            continue
        if wan + m.wan_bytes > budget_bytes:
            skipped += 1
            continue
        wan += m.wan_bytes
        moves.append(m)

    return MigrationPlan(
        moves=moves,
        wan_bytes=wan,
        est_benefit=float(sum(m.benefit for m in moves)),
        n_candidates=n_cand,
        skipped_budget=skipped,
    )


# -------------------------------------------------------------- scheduling
@dataclasses.dataclass
class TransferBatch:
    """One link's payload inside one wave: items shipped ``src -> dst``."""

    src: int
    dst: int
    items: np.ndarray  # item ids, plan-priority order
    nbytes: float
    moves: List[Move]

    @property
    def n_transfers(self) -> int:
        return len(self.moves)


@dataclasses.dataclass
class TransferWave:
    """Concurrent link payloads; the wave ends when its slowest link does."""

    index: int
    links: List[TransferBatch]
    makespan_s: float  # max over links: nbytes / bw + rtt

    @property
    def nbytes(self) -> float:
        return float(sum(b.nbytes for b in self.links))

    @property
    def n_transfers(self) -> int:
        return sum(b.n_transfers for b in self.links)

    @property
    def moves(self) -> List[Move]:
        return [m for b in self.links for m in b.moves]


@dataclasses.dataclass
class MigrationSchedule:
    """Per-link packing of a plan's adds into bandwidth-bounded waves."""

    waves: List[TransferWave]
    window_s: float
    link_budget: np.ndarray  # [D, D] bytes one wave may ship per link
    local: List[Move]  # src == dst adds: nothing crosses the WAN
    makespan_s: float  # pipelined estimate: sum of wave makespans
    oversized: int = 0  # single transfers larger than their link budget
    packing: str = "ff"  # packing discipline that produced the waves

    @property
    def n_waves(self) -> int:
        return len(self.waves)

    @property
    def n_transfers(self) -> int:
        return sum(w.n_transfers for w in self.waves) + len(self.local)

    def link_loads(self) -> Dict[Tuple[int, int, int], float]:
        """(wave, src, dst) -> bytes; the budget-compliance surface under test."""
        return {
            (w.index, b.src, b.dst): b.nbytes for w in self.waves for b in w.links
        }


def _pack_link_ff(ms: List[Move], cap: float) -> Tuple[List[List[Move]], int]:
    """Sequential (next-fit) packing in plan-priority order: the current wave
    is closed as soon as a transfer does not fit, so within a link the highest
    benefit-density transfers always ship first."""
    bins: List[List[Move]] = []
    oversized = 0
    cur: List[Move] = []
    cur_bytes = 0.0
    for m in ms:
        if cur and cur_bytes + m.wan_bytes > cap:
            bins.append(cur)
            cur, cur_bytes = [], 0.0
        cur.append(m)
        cur_bytes += m.wan_bytes
        if cur_bytes > cap:  # lone transfer larger than the link budget
            oversized += 1
            bins.append(cur)
            cur, cur_bytes = [], 0.0
    if cur:
        bins.append(cur)
    return bins, oversized


def _pack_link_lpt(ms: List[Move], cap: float) -> Tuple[List[List[Move]], int]:
    """LPT / first-fit-decreasing packing: transfers sorted by bytes
    descending, each placed into the first wave with room.  Fewer, fuller
    waves -> fewer straggler roundtrips per link."""
    bins: List[List[Move]] = []
    loads: List[float] = []
    oversized = 0
    order = sorted(range(len(ms)), key=lambda i: (-ms[i].wan_bytes, i))
    for i in order:
        m = ms[i]
        if m.wan_bytes > cap:  # ships alone, flagged, like the ff path
            oversized += 1
            bins.append([m])
            loads.append(m.wan_bytes)
            continue
        for j in range(len(bins)):
            if loads[j] + m.wan_bytes <= cap and loads[j] <= cap:
                bins[j].append(m)
                loads[j] += m.wan_bytes
                break
        else:
            bins.append([m])
            loads.append(m.wan_bytes)
    return bins, oversized


def _assemble(
    plan_links: Dict[Tuple[int, int], List[List[Move]]],
    env: GeoEnvironment,
) -> Tuple[List[TransferWave], float]:
    """Zip per-link wave slots into global :class:`TransferWave`s."""
    waves_links: Dict[int, List[TransferBatch]] = {}
    for (s, d), bins in sorted(plan_links.items()):
        for wave_i, cur in enumerate(bins):
            waves_links.setdefault(wave_i, []).append(
                TransferBatch(
                    src=s, dst=d,
                    items=np.asarray([m.item for m in cur], dtype=np.int64),
                    nbytes=float(sum(m.wan_bytes for m in cur)),
                    moves=list(cur),
                )
            )
    waves: List[TransferWave] = []
    makespan = 0.0
    for w in sorted(waves_links):
        links = waves_links[w]
        span = max(
            b.nbytes / float(env.bw_Bps[b.src, b.dst]) + float(env.rtt_s[b.src, b.dst])
            for b in links
        )
        waves.append(TransferWave(index=len(waves), links=links, makespan_s=span))
        makespan += span
    return waves, makespan


def schedule_transfers(
    plan: MigrationPlan,
    env: GeoEnvironment,
    window_s: float,
    schedule: str = "ff",
) -> MigrationSchedule:
    """Pack a plan's adds into per-link :class:`TransferWave`s.

    Each accepted add ships ``wan_bytes`` over the WAN link
    ``(move.src, move.dc)``.  Per link, transfers are packed under the
    per-link byte budget ``env.link_budget_bytes(window_s)`` — a wave never
    carries more than one migration window's worth of bytes on any link,
    except for a single transfer that alone exceeds its link budget (shipped
    as its own, flagged-oversized wave rather than starving forever).  Links
    transfer concurrently within a wave; the makespan estimate per wave is
    the straggler link's ``nbytes / bw + rtt`` (Eq. 1 applied to the bulk
    payload), and the schedule's total is the sum over waves.

    ``schedule`` selects the packing discipline:

      * ``"ff"`` (default) — sequential first-fit in plan-priority order;
        the highest benefit-density transfers ship in the earliest waves.
      * ``"lpt"`` — makespan-aware longest-processing-time packing
        (first-fit-decreasing by bytes per link).  Fuller waves shave the
        straggler roundtrips first-fit leaves behind; the ff schedule is
        kept as a floor, so LPT is **never worse** than first-fit on the
        pipelined makespan estimate (the better of the two is returned).
    """
    if schedule not in ("ff", "lpt"):
        raise ValueError(f"unknown packing {schedule!r} (want 'ff' or 'lpt')")
    budget = env.link_budget_bytes(window_s)
    per_link: Dict[Tuple[int, int], List[Move]] = {}
    local: List[Move] = []
    for m in plan.moves:
        if m.kind != "add":
            continue
        src = int(m.src) if m.src >= 0 else int(m.dc)
        if src == m.dc:
            local.append(m)  # replica materializes from a co-located copy
            continue
        per_link.setdefault((src, int(m.dc)), []).append(m)

    def _build(packer, name: str) -> MigrationSchedule:
        plan_links: Dict[Tuple[int, int], List[List[Move]]] = {}
        oversized = 0
        for (s, d), ms in sorted(per_link.items()):
            bins, over = packer(ms, float(budget[s, d]))
            plan_links[(s, d)] = bins
            oversized += over
        waves, makespan = _assemble(plan_links, env)
        return MigrationSchedule(
            waves=waves,
            window_s=float(window_s),
            link_budget=budget,
            local=local,
            makespan_s=makespan,
            oversized=oversized,
            packing=name,
        )

    ff = _build(_pack_link_ff, "ff")
    if schedule == "ff":
        return ff
    lpt = _build(_pack_link_lpt, "lpt")
    # never worse than first-fit: ties keep ff (priority order preserved)
    return lpt if lpt.makespan_s < ff.makespan_s else ff


# ------------------------------------------------------------- application
def _reroute_items(
    state: PlacementState, env: GeoEnvironment, rows: np.ndarray
) -> None:
    """Partial Eq. 1 nearest-replica refresh for just ``rows``."""
    state.route_nearest(env, rows=np.asarray(rows))


def _refresh_routes(
    state: PlacementState,
    env: GeoEnvironment,
    route_index: Optional["RouteIndex"],
    rows: np.ndarray,
    moves=None,
) -> None:
    """Routing refresh after a replica-set delta — the one shared path for
    the single-shot, wave-by-wave and rollback cases."""
    if route_index is None:
        _reroute_items(state, env, rows)
    elif moves is not None:
        route_index.apply_moves(state.delta, moves)
    else:  # rollback: replica sets changed outside the move-set shape
        route_index.patch_rows(state.delta, rows)
    if route_index is not None:
        state.route = route_index.nearest


class WaveApplier:
    """Resumable wave-by-wave application of a scheduled plan.

    The one-shot :func:`apply_plan` drives this internally; the maintenance
    control plane (:class:`repro.serve.MaintenancePolicy`) holds one across
    serving drains and applies waves into idle gaps one at a time.  The
    invariants are the same as the inline path: after every completed wave
    the placement and :class:`~repro.core.route_index.RouteIndex` are
    mutually consistent, drops release only in :meth:`finish` (after the
    last transfer lands), and the Eq. 6 constraint guard rolls drops back
    wholesale if any previously-satisfied constraint regresses.

    Zero-byte local adds (co-located source) land at construction time —
    they cross no WAN link, so they never wait for a window.
    """

    def __init__(
        self,
        plan: MigrationPlan,
        state: PlacementState,
        env: GeoEnvironment,
        patterns: Sequence,
        r_xy: np.ndarray,
        sizes: np.ndarray,
        gamma_max_s: float,
        route_index: Optional["RouteIndex"] = None,
        valid_check: Optional[Callable[[], bool]] = None,
    ) -> None:
        if plan.schedule is None:
            raise ValueError("WaveApplier needs a scheduled plan (plan.schedule)")
        self.plan = plan
        self.schedule = plan.schedule
        self.state = state
        self.env = env
        self.patterns = patterns
        self.r_xy = r_xy
        self.sizes = sizes
        self.gamma_max_s = gamma_max_s
        self.route_index = route_index
        # id-space guard: begin_flush wires this to the store's epoch so a
        # mutation batch / compaction between waves raises StaleFlushError
        # instead of applying renumbered rows
        self.valid_check = valid_check
        self._before = check_constraints(
            patterns, state, r_xy, sizes, env, gamma_max_s
        )
        self._wave_i = 0
        self._finished = False
        if self.schedule.local:
            for m in self.schedule.local:
                state.delta[m.item, m.dc] = True
            self._refresh(
                np.unique([m.item for m in self.schedule.local]),
                moves=self.schedule.local,
            )

    def _refresh(self, rows: np.ndarray, moves=None) -> None:
        _refresh_routes(self.state, self.env, self.route_index, rows, moves)

    def _ensure_valid(self) -> None:
        if self.valid_check is not None and not self.valid_check():
            raise StaleFlushError(
                "item id space changed under this flush; re-plan the "
                f"remaining {self.n_remaining} waves"
            )

    def check_valid(self) -> None:
        """Raise :class:`StaleFlushError` if the id space moved under this
        flush — for wrappers (the sharded store's transfer proxy) that must
        refuse to ship payload for a wave whose rows are already stale."""
        self._ensure_valid()

    @property
    def n_remaining(self) -> int:
        return len(self.schedule.waves) - self._wave_i

    @property
    def done(self) -> bool:
        return self._finished

    def peek(self) -> Optional[TransferWave]:
        """The next wave to apply (None when all waves have landed)."""
        if self.n_remaining == 0:
            return None
        return self.schedule.waves[self._wave_i]

    def apply_next(self) -> TransferWave:
        """Land one wave: placement rows + route-index patch, in order."""
        self._ensure_valid()
        wave = self.schedule.waves[self._wave_i]
        self._wave_i += 1
        for b in wave.links:
            self.state.delta[b.items, b.dst] = True
        if self.route_index is not None:
            self.route_index.apply_grouped(
                self.state.delta, [(b.dst, "add", b.items) for b in wave.links]
            )
            self.state.route = self.route_index.nearest
        else:
            _reroute_items(
                self.state, self.env,
                np.unique(np.concatenate([b.items for b in wave.links])),
            )
        return wave

    def finish(self) -> Dict[str, bool]:
        """Release drops (every transfer has landed) + run the guard."""
        self._ensure_valid()
        if self.n_remaining:
            raise RuntimeError(f"{self.n_remaining} waves still pending")
        if self._finished:
            raise RuntimeError("finish() already ran")
        self._finished = True
        plan, state = self.plan, self.state
        drops = [m for m in plan.moves if m.kind == "drop"]
        if drops:
            for m in drops:
                state.delta[m.item, m.dc] = False
            self._refresh(np.unique([m.item for m in drops]), moves=drops)
        after = check_constraints(
            self.patterns, state, self.r_xy, self.sizes, self.env, self.gamma_max_s
        )
        if any(self._before[k] and not after[k] for k in self._before):
            touched = np.unique([m.item for m in plan.moves]).astype(np.int64)
            for m in drops:
                state.delta[m.item, m.dc] = True
            self._refresh(touched)
            plan.rolled_back = len(drops)
            plan.moves = [m for m in plan.moves if m.kind == "add"]
            plan.est_benefit = float(sum(m.benefit for m in plan.moves))
            after = check_constraints(
                self.patterns, state, self.r_xy, self.sizes, self.env,
                self.gamma_max_s,
            )
        return after


def apply_plan(
    plan: MigrationPlan,
    state: PlacementState,
    env: GeoEnvironment,
    patterns: Sequence,
    r_xy: np.ndarray,
    sizes: np.ndarray,
    gamma_max_s: float,
    route_index: Optional["RouteIndex"] = None,
    schedule: Optional[MigrationSchedule] = None,
    on_wave: Optional[Callable[[TransferWave], None]] = None,
) -> Dict[str, bool]:
    """Apply the plan with a constraint guard; returns the final check flags.

    Without a ``schedule`` the whole move-set lands at once (the legacy
    single-shot path).  With one, adds land **wave by wave** in schedule
    order through a :class:`WaveApplier`: each wave mutates ``state.delta``
    and patches the :class:`~repro.core.route_index.RouteIndex` (or partially
    reroutes) before ``on_wave(wave)`` fires, so callers can serve requests
    between waves against a route table that is always consistent with the
    placement.  Drops are released only after the last transfer wave.

    Invariant: no constraint that held before application is violated after —
    adds only widen the replica sets, and drops are rolled back wholesale if
    the post-check regresses.
    """
    if schedule is not None:
        if plan.schedule is not schedule:
            plan.schedule = schedule
        wa = WaveApplier(
            plan, state, env, patterns, r_xy, sizes, gamma_max_s,
            route_index=route_index,
        )
        while wa.n_remaining:
            wave = wa.apply_next()
            if on_wave is not None:
                on_wave(wave)
        return wa.finish()

    before = check_constraints(patterns, state, r_xy, sizes, env, gamma_max_s)
    touched = np.unique([m.item for m in plan.moves]).astype(np.int64)
    for m in plan.moves:
        state.delta[m.item, m.dc] = m.kind == "add"
    _refresh_routes(state, env, route_index, touched, moves=plan.moves)
    after = check_constraints(patterns, state, r_xy, sizes, env, gamma_max_s)
    if any(before[k] and not after[k] for k in before):
        drops = [m for m in plan.moves if m.kind == "drop"]
        for m in drops:
            state.delta[m.item, m.dc] = True
        _refresh_routes(state, env, route_index, touched)
        plan.rolled_back = len(drops)
        plan.moves = [m for m in plan.moves if m.kind == "add"]
        plan.est_benefit = float(sum(m.benefit for m in plan.moves))
        after = check_constraints(patterns, state, r_xy, sizes, env, gamma_max_s)
    return after
