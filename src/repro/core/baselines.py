"""Competitor replica-placement / routing / layout strategies (paper §VII-A).

Online-mode competitors (vs overlap-centric placement + stepwise routing):
  * Random-3 — replicas at 3 random DCs, random routing.
  * Top-3    — replicas at the 3 highest-read-frequency DCs, random routing.
  * ADP      — hypergraph-partitioning placement (Yu & Pan [28]): patterns are
               hyperedges; greedy balanced min-cut assignment of items to DCs.
  * DCD      — overlapping-community placement (Liu et al. [27]): communities
               of the co-access graph replicated to their top requesting DCs.
ADP/DCD route with greedy set cover (their papers' routing).

Offline-mode competitors (vs stepwise offline routing):
  * RAGraph  — primary partition in place (no migration).
  * RAGraph+ — contribution-driven edge migration.
  * GrapH    — heterogeneity-aware adaptive edge migration (vertex traffic).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .cost import PlacementState
from .graph import Graph
from .latency import GeoEnvironment
from .patterns import Workload

__all__ = [
    "place_random_k",
    "place_top_k",
    "place_adp",
    "place_dcd",
    "route_random",
    "route_greedy_set_cover",
    "layout_ragraph",
    "layout_ragraph_plus",
    "layout_graph_h",
]


def _primary_state(g: Graph, n_dcs: int) -> PlacementState:
    state = PlacementState.empty(g.n_items, n_dcs)
    state.delta[np.arange(g.n_nodes), g.partition] = True
    state.delta[g.n_nodes + np.arange(g.n_edges), g.partition[g.src]] = True
    return state


# ----------------------------------------------------------------- placement
def place_random_k(
    g: Graph, workload: Workload, env: GeoEnvironment, k: int = 3, seed: int = 0
) -> PlacementState:
    rng = np.random.default_rng(seed)
    state = _primary_state(g, env.n_dcs)
    accessed = np.where(workload.r_xy.sum(axis=1) > 0)[0]
    for x in accessed:
        for d in rng.choice(env.n_dcs, size=min(k, env.n_dcs), replace=False):
            state.delta[x, d] = True
    return state


def place_top_k(
    g: Graph, workload: Workload, env: GeoEnvironment, k: int = 3
) -> PlacementState:
    state = _primary_state(g, env.n_dcs)
    accessed = np.where(workload.r_xy.sum(axis=1) > 0)[0]
    order = np.argsort(-workload.r_xy[accessed], axis=1)[:, :k]
    for row, x in enumerate(accessed):
        for d in order[row]:
            if workload.r_xy[x, d] > 0:
                state.delta[x, d] = True
    return state


def place_adp(
    g: Graph, workload: Workload, env: GeoEnvironment, n_rounds: int = 3
) -> PlacementState:
    """Hypergraph-partitioning placement.  Items = vertices, patterns =
    hyperedges; greedy FM-style passes move items between DCs to reduce the
    number of DCs spanned per hyperedge, weighted by pattern frequency,
    under a soft balance constraint.  Each item's part = its replica site.
    """
    D = env.n_dcs
    state = _primary_state(g, env.n_dcs)
    # initial part = DC with max read frequency (frequency-aware seeding)
    accessed = np.where(workload.r_xy.sum(axis=1) > 0)[0]
    part = np.full(g.n_items, -1, dtype=np.int64)
    part[accessed] = np.argmax(workload.r_xy[accessed], axis=1)
    item_patterns: Dict[int, List[int]] = {}
    for pi, p in enumerate(workload.patterns):
        for x in p.items.tolist():
            item_patterns.setdefault(x, []).append(pi)
    cap = max(1, int(1.2 * len(accessed) / D))
    loads = np.bincount(part[accessed], minlength=D)
    for _ in range(n_rounds):
        moved = 0
        for x in accessed.tolist():
            pis = item_patterns.get(x, [])
            if not pis:
                continue
            # score each DC by co-located pattern mass
            score = np.zeros(D)
            for pi in pis:
                p = workload.patterns[pi]
                counts = np.bincount(
                    part[p.items][part[p.items] >= 0], minlength=D
                ).astype(np.float64)
                score += p.read_rate * counts
            score[loads >= cap] = -np.inf
            best = int(score.argmax())
            if best != part[x] and np.isfinite(score[best]):
                loads[part[x]] -= 1
                loads[best] += 1
                part[x] = best
                moved += 1
        if moved == 0:
            break
    for x in accessed:
        state.delta[x, part[x]] = True
    return state


def place_dcd(
    g: Graph, workload: Workload, env: GeoEnvironment, k_rep: int = 2
) -> PlacementState:
    """Overlapping-community placement: communities = pattern item sets merged
    by Jaccard overlap; each community replicated at its top-k requesting DCs.
    """
    state = _primary_state(g, env.n_dcs)
    pats = workload.patterns
    n = len(pats)
    parent = list(range(n))

    def find(a: int) -> int:
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    sets = [set(p.items.tolist()) for p in pats]
    for i in range(n):
        for j in range(i + 1, min(i + 30, n)):  # windowed pairing for scale
            inter = len(sets[i] & sets[j])
            if inter == 0:
                continue
            jac = inter / len(sets[i] | sets[j])
            if jac > 0.2:
                ri, rj = find(i), find(j)
                if ri != rj:
                    parent[max(ri, rj)] = min(ri, rj)
    comms: Dict[int, List[int]] = {}
    for i in range(n):
        comms.setdefault(find(i), []).append(i)
    for members in comms.values():
        items = np.unique(np.concatenate([pats[i].items for i in members]))
        r = np.sum([pats[i].r_py for i in members], axis=0)
        top = np.argsort(-r)[:k_rep]
        for d in top:
            if r[d] > 0:
                state.delta[items, int(d)] = True
    return state


# ------------------------------------------------------------------- routing
def route_random(
    state: PlacementState, workload: Workload, env: GeoEnvironment, seed: int = 0
) -> None:
    """Random routing: each (item, origin) picks a uniform random replica."""
    rng = np.random.default_rng(seed)
    I, D = state.delta.shape
    state.route = np.full((I, D), -1, dtype=np.int32)
    holders = [np.where(state.delta[x])[0] for x in range(I)]
    for x in range(I):
        h = holders[x]
        if len(h) == 0:
            continue
        state.route[x] = h[rng.integers(0, len(h), size=D)]


def route_greedy_set_cover(
    state: PlacementState, workload: Workload, env: GeoEnvironment
) -> None:
    """ADP/DCD routing: per (pattern, origin) greedy set cover over DCs,
    preferring DCs that serve the most still-missing items (min #DCs)."""
    I, D = state.delta.shape
    state.route = np.full((I, D), -1, dtype=np.int32)
    # default: nearest replica for items not covered by pattern routing
    lat = env.rtt_s.copy()
    np.fill_diagonal(lat, 0.0)
    big = np.where(state.delta[:, :, None], lat[None, :, :], np.inf)
    nearest = np.argmin(big, axis=1).astype(np.int32)
    placed = state.delta.any(axis=1)
    state.route[placed] = nearest[placed]
    for p in workload.patterns:
        for y in np.where(p.r_py > 0)[0]:
            served = np.zeros(len(p.items), dtype=bool)
            while not served.all():
                cover = state.delta[p.items[~served]].sum(axis=0)
                d = int(cover.argmax())
                if cover[d] == 0:
                    break
                hit = ~served & state.delta[p.items, d]
                state.route[p.items[hit], y] = d
                served |= hit


# ----------------------------------------------------------- offline layouts
def layout_ragraph(g: Graph, env: GeoEnvironment) -> np.ndarray:
    """RAGraph default: vertices execute at their primary partition."""
    return g.partition.astype(np.int64).copy()


def layout_ragraph_plus(
    g: Graph,
    env: GeoEnvironment,
    traffic: Optional[np.ndarray] = None,
    budget_frac: float = 0.15,
) -> np.ndarray:
    """Contribution-driven edge migration: move the highest-traffic boundary
    vertices to the neighbor DC that removes the most cut edges."""
    site = g.partition.astype(np.int64).copy()
    t = traffic if traffic is not None else np.ones(g.n_nodes)
    budget = int(budget_frac * g.n_nodes)
    cross = site[g.src] != site[g.dst]
    cand = np.unique(np.concatenate([g.src[cross], g.dst[cross]]))
    cand = cand[np.argsort(-t[cand])][:budget]
    # neighbor DC histogram per candidate
    for v in cand.tolist():
        m_out = g.src == v
        m_in = g.dst == v
        nb_dc = np.concatenate([site[g.dst[m_out]], site[g.src[m_in]]])
        if len(nb_dc) == 0:
            continue
        counts = np.bincount(nb_dc, minlength=env.n_dcs)
        best = int(counts.argmax())
        if counts[best] > counts[site[v]]:
            site[v] = best
    return site


def layout_graph_h(
    g: Graph,
    env: GeoEnvironment,
    traffic: Optional[np.ndarray] = None,
    budget_frac: float = 0.15,
) -> np.ndarray:
    """GrapH-style: migration gain weighs vertex traffic by link $/byte —
    prefers moving hot vertices off expensive heterogeneous paths."""
    site = g.partition.astype(np.int64).copy()
    t = traffic if traffic is not None else np.ones(g.n_nodes)
    budget = int(budget_frac * g.n_nodes)
    cross = site[g.src] != site[g.dst]
    cand = np.unique(np.concatenate([g.src[cross], g.dst[cross]]))
    # expensive-path traffic first
    def path_cost(v: int) -> float:
        m_out = g.src == v
        m_in = g.dst == v
        nb = np.concatenate([site[g.dst[m_out]], site[g.src[m_in]]])
        if len(nb) == 0:
            return 0.0
        return float(t[v] * env.c_net[site[v], nb].sum())

    scores = np.array([path_cost(int(v)) for v in cand])
    cand = cand[np.argsort(-scores)][:budget]
    for v in cand.tolist():
        m_out = g.src == v
        m_in = g.dst == v
        nb_dc = np.concatenate([site[g.dst[m_out]], site[g.src[m_in]]])
        if len(nb_dc) == 0:
            continue
        gains = np.zeros(env.n_dcs)
        for d in range(env.n_dcs):
            gains[d] = -env.c_net[d, nb_dc].sum() * t[v]
        best = int(gains.argmax())
        if gains[best] > gains[site[v]]:
            site[v] = best
    return site
