"""Stepwise layered routing (paper §VI).

Online mode — bottom-up expanding retrieval: serve locally, then per layer
(ascending latency) greedily pick the cluster DC covering the most missing
items (minimizing participating DCs), escalating until the pattern is fully
resolved.

Offline mode — top-down localization (map required items to candidate
replica holders) then bottom-up assembly: each DC is tested with the
migration condition (Eq. 14); excluded DCs' data is redistributed by hashing
to retained DCs within the same cluster, escalating upward when a cluster
retains nobody.  The result is an execution layout for geo-distributed
analytics (few sites, minimal WAN).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import get_registry
from .cost import PlacementState
from .graph import Graph
from .latency import GeoEnvironment
from .layered_graph import LayeredGraph

__all__ = [
    "RouteResult",
    "RouteFastConfig",
    "get_route_fast_config",
    "set_route_fast_config",
    "route_online",
    "route_online_batch",
    "OfflineLayout",
    "route_offline",
]

# precomputed per-layer tag keys: the 5% telemetry budget on the batch
# serving path leaves no room for per-call tag normalization
_LAYER_TAGS: Dict[int, Tuple[Tuple[str, str], ...]] = {}  # geolint: allow[GL001]


def _layer_tags(layer: int) -> Tuple[Tuple[str, str], ...]:
    key = _LAYER_TAGS.get(layer)
    if key is None:
        key = (("layer", str(layer)),)
        _LAYER_TAGS[layer] = key
    return key


class _ObsHandles:
    """Pre-resolved serving/routing instruments for one registry.

    The batch serve path books ~a dozen instruments per call; resolving
    each through the registry's keyed lookup costs more than the increment
    itself.  Handles are memoized in the registry's ``_handle_cache`` (so
    ``clear()`` drops them with the instruments; ``reset()`` keeps the
    instrument objects, so handles survive it)."""

    __slots__ = (
        "requests", "wan", "lat", "grid", "kernel_time", "unresolved",
        "layer_hits", "layer_time", "_reg",
    )

    def __init__(self, reg):
        self._reg = reg
        self.requests = reg.counter_keyed("serving.requests", ())
        self.wan = reg.counter_keyed("serving.wan_bytes", ())
        self.lat = reg.histogram(
            "serving.request_latency_s", quantiles=(0.5, 0.99)
        )
        self.grid = reg.counter_grid("serving.wan_bytes_link", ("src", "dst"))
        self.kernel_time = reg.counter_keyed("routing.kernel_time_s", ())
        self.unresolved = reg.counter_keyed("routing.unresolved_items", ())
        self.layer_hits: dict = {}
        self.layer_time: dict = {}

    def hits(self, layer: int):
        c = self.layer_hits.get(layer)
        if c is None:
            c = self._reg.counter_keyed("routing.layer_hits", _layer_tags(layer))
            self.layer_hits[layer] = c
        return c

    def layer_s(self, layer: int):
        c = self.layer_time.get(layer)
        if c is None:
            c = self._reg.counter_keyed(
                "routing.layer_time_s", _layer_tags(layer)
            )
            self.layer_time[layer] = c
        return c


def _obs_handles(reg) -> _ObsHandles:
    h = reg._handle_cache.get("routing")
    if h is None:
        h = _ObsHandles(reg)
        reg._handle_cache["routing"] = h
    return h


# --------------------------------------------------------- fast-path config
@dataclasses.dataclass
class RouteFastConfig:
    """Eligibility gates for the fused jax/Pallas batch expansion.

    The fast path pays fixed per-call costs (host->device transfer of the
    packed batch, jit dispatch), so small batches stay on the numpy path;
    the size gates also bound the padded ``[R, Kmax]`` buffers the packing
    allocates.  ``max_dcs`` is the int32 replica-bitmask budget (bit 31 is
    the sign bit)."""

    enabled: bool = True
    min_requests: int = 64  # below this the numpy lockstep loop wins
    max_kmax: int = 8192  # widest request (items) eligible for packing
    max_cells: int = 1 << 23  # padded R * Kmax budget (~32 MB of int32)
    max_dcs: int = 31


_FAST_CONFIG = RouteFastConfig()  # geolint: allow[GL001]


def get_route_fast_config() -> RouteFastConfig:
    return _FAST_CONFIG


def set_route_fast_config(config: RouteFastConfig) -> RouteFastConfig:
    global _FAST_CONFIG
    _FAST_CONFIG = config
    return config


# ------------------------------------------------------------------- online
class RouteResult:
    """Routing outcome for one request.

    A ``__slots__`` class rather than a dataclass: the batch path
    materializes one of these per request per serve call, and
    ``per_dc_latency`` — only read by diagnostics and tests — builds its
    dict lazily from the packed ``(dcs, pair_latency)`` columns.
    """

    __slots__ = (
        "served_by",
        "dcs",
        "latency_s",
        "layers_used",
        "n_missing",
        "wan_bytes",
        "_per_dc",
        "_pair_lat",
    )

    def __init__(
        self,
        served_by: np.ndarray,  # [len(items)] serving DC per item (-1 open)
        dcs: np.ndarray,  # distinct participating DCs
        latency_s: float,  # straggler latency (max over DCs, Eq. 1)
        per_dc_latency: Optional[Dict[int, float]] = None,
        layers_used: int = 0,
        n_missing: int = 0,
        wan_bytes: float = 0.0,  # bytes served by non-origin DCs (WAN)
        pair_latency: Optional[List[float]] = None,  # aligned with dcs
    ) -> None:
        self.served_by = served_by
        self.dcs = dcs
        self.latency_s = latency_s
        self.layers_used = layers_used
        self.n_missing = n_missing
        self.wan_bytes = wan_bytes
        self._per_dc = per_dc_latency
        self._pair_lat = pair_latency

    @property
    def per_dc_latency(self) -> Dict[int, float]:
        if self._per_dc is None:
            lats = self._pair_lat if self._pair_lat is not None else ()
            self._per_dc = dict(zip([int(d) for d in self.dcs], lats))
        return self._per_dc

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"RouteResult(dcs={list(map(int, self.dcs))}, "
            f"latency_s={self.latency_s:.6g}, layers_used={self.layers_used}, "
            f"n_missing={self.n_missing}, wan_bytes={self.wan_bytes:.6g})"
        )


def route_online(
    lg: LayeredGraph,
    state: PlacementState,
    items: np.ndarray,
    origin: int,
    sizes: Optional[np.ndarray] = None,
) -> RouteResult:
    """Bottom-up expanding retrieval for one pattern request (paper Fig. 5)."""
    env = lg.env
    if sizes is None:
        sizes = lg.g.item_size()
    items = np.asarray(items)
    served = np.full(len(items), -1, dtype=np.int64)

    # Layer_0: local items first
    local = state.delta[items, origin]
    served[local] = origin
    layers_used = 0

    for layer in range(1, lg.n_layers + 1):
        if (served >= 0).all():
            break
        comp = lg.comp_of_dc[layer, origin]
        cluster = np.where(lg.comp_of_dc[layer] == comp)[0]
        cluster = cluster[cluster != origin]
        if len(cluster) == 0:
            continue
        layers_used = layer
        # greedy max-coverage within the latency-homogeneous cluster
        while True:
            missing = np.where(served < 0)[0]
            if len(missing) == 0:
                break
            cover = state.delta[items[missing]][:, cluster].sum(axis=0)
            best = int(cover.argmax())
            if cover[best] == 0:
                break  # escalate to the next layer
            dc = int(cluster[best])
            hit = missing[state.delta[items[missing], dc]]
            served[hit] = dc
    # resolved latency per participating DC (Eq. 1 with S_d = served bytes)
    per_dc: Dict[int, float] = {}
    wan = 0.0
    for dc in np.unique(served[served >= 0]):
        s_d = float(sizes[items[served == dc]].sum())
        per_dc[int(dc)] = env.request_latency(int(dc), origin, s_d)
        if int(dc) != origin:
            wan += s_d
    lat = max(per_dc.values()) if per_dc else 0.0
    return RouteResult(
        served_by=served,
        dcs=np.unique(served[served >= 0]),
        latency_s=lat,
        per_dc_latency=per_dc,
        layers_used=layers_used,
        n_missing=int((served < 0).sum()),
        wan_bytes=wan,
    )


def _expand_single_origin(
    lg: LayeredGraph,
    delta_all: np.ndarray,
    req_id: np.ndarray,
    R: int,
    o: int,
    served: np.ndarray,
    layers_used: np.ndarray,
    reg,
    obs: bool,
) -> None:
    """Greedy layered expansion for a batch that shares one origin DC.

    Request-identical to the mixed-origin lockstep loop (same greedy
    max-coverage, same lowest-DC-id tie-break), but the shared origin means
    every request sees the *same* cluster per layer — so layer-0 is a column
    slice instead of a per-row gather, coverage bincounts run over only the
    cluster's columns, and every greedy pass touches only the still-missing
    rows.  This is the per-shard serving path: the sharded store dispatches
    per-origin sub-batches, which land here.
    """
    K = delta_all.shape[0]
    local = delta_all[:, o]
    served[local] = o
    idx = np.where(~local)[0]  # flat positions still missing
    if obs:
        unresolved = len(idx)
        _obs_handles(reg).hits(0).inc(K - unresolved)
    for layer in range(1, lg.n_layers + 1):
        if len(idx) == 0:
            break
        if obs:
            t_layer = time.perf_counter()
        comp = lg.comp_of_dc[layer]
        cluster = np.where(comp == comp[o])[0]
        cluster = cluster[cluster != o]
        if len(cluster):
            layers_used[np.unique(req_id[idx])] = layer
            ar_R = np.arange(R)
            while len(idx):
                rid = req_id[idx]
                sub = delta_all[np.ix_(idx, cluster)]  # [missing, |cluster|]
                cover = np.stack(
                    [
                        np.bincount(rid, weights=sub[:, j], minlength=R)
                        for j in range(len(cluster))
                    ],
                    axis=1,
                )
                best_j = np.argmax(cover, axis=1)  # lowest-id tie-break
                gain = cover[ar_R, best_j]
                if not (gain > 0).any():
                    break  # escalate to the next layer
                hit = (gain[rid] > 0) & sub[np.arange(len(idx)), best_j[rid]]
                served[idx[hit]] = cluster[best_j[rid[hit]]]
                idx = idx[~hit]
        if obs:
            h = _obs_handles(reg)
            h.layer_s(layer).inc(time.perf_counter() - t_layer)
            h.hits(layer).inc(unresolved - len(idx))
            unresolved = len(idx)
    if obs:
        _obs_handles(reg).unresolved.inc(len(idx))


def _observe_scalar(
    reg,
    lg: LayeredGraph,
    res: RouteResult,
    items: np.ndarray,
    origin: int,
    sizes: np.ndarray,
    elapsed_s: float,
) -> None:
    """Book the batch path's serving/routing instruments for one scalar
    :func:`route_online` result, so size-1 batches can take the (faster)
    scalar router without losing accounting parity.

    The serving layer of each assignment is recovered instead of re-walking
    the expansion: greedy passes only break when *no* cluster DC covers any
    missing item, so an item is always served at the first layer whose
    cluster holds a replica — i.e. the first layer where its assigned DC
    shares a component with the origin.  Expansion time is charged to the
    deepest layer used (the scalar router doesn't time layers separately).
    """
    h = _obs_handles(reg)
    h.requests.inc(1)
    served = res.served_by
    hits0 = int((served == origin).sum())
    if hits0:
        h.hits(0).inc(hits0)
    wan_link = None
    for dc in res.dcs.tolist():
        dc = int(dc)
        if dc == origin:
            continue
        shared = lg.comp_of_dc[1:, dc] == lg.comp_of_dc[1:, origin]
        layer = int(np.argmax(shared)) + 1
        h.hits(layer).inc(int((served == dc).sum()))
        if wan_link is None:
            wan_link = np.zeros((lg.env.n_dcs, lg.env.n_dcs))
        wan_link[dc, origin] += float(sizes[items[served == dc]].sum())
    if res.layers_used > 0:
        h.layer_s(res.layers_used).inc(elapsed_s)
    h.unresolved.inc(res.n_missing)
    h.lat.observe(res.latency_s)
    h.wan.inc(res.wan_bytes)
    if wan_link is not None:
        h.grid.add(wan_link)


# jax + kernels are imported lazily on the first fast-path call: the numpy
# router must keep working (and importing fast) when jax is unavailable
_KOPS = None  # geolint: allow[GL001]
_KOPS_FAILED = False  # geolint: allow[GL001]


def _get_kops():
    global _KOPS, _KOPS_FAILED
    if _KOPS is None and not _KOPS_FAILED:
        try:
            from ..kernels import autotune, ops

            _KOPS = (ops, autotune)
        except Exception:  # pragma: no cover - jax-less deployment
            _KOPS_FAILED = True
    return _KOPS


def _fast_eligible(
    fast: Optional[bool], config: RouteFastConfig, R: int, D: int, kmax: int,
    n_layers: int,
) -> bool:
    if fast is False or not config.enabled or kmax == 0:
        return False
    if D > config.max_dcs or n_layers > 64:
        return False  # int32 bitmask / stats-lane budget
    if fast is not True:  # default: size heuristics decide
        if R < config.min_requests:
            return False
        if kmax > config.max_kmax or R * kmax > config.max_cells:
            return False
    return _get_kops() is not None


# per-LayeredGraph device copies of the expansion constants (layer
# components, RTT, 1/bandwidth): host->device conversion has a fixed ~70us
# cost per array, which the per-batch fast path cannot afford for arrays
# that never change.  Keyed on id(lg) with the lg kept referenced, so a
# live entry's key cannot be recycled; one entry suffices (one store per
# process; shards share the lg).
_FAST_ENV_CACHE: Dict[int, Tuple[LayeredGraph, tuple]] = {}  # geolint: allow[GL001]


def reset_routing_caches() -> None:
    """Reset every module-level routing cache/singleton: the per-layer tag
    intern table, the fast-path config, the lazy kernels import memo and the
    per-graph device-array cache.  Test isolation hook — everything here
    rebuilds lazily on next use."""
    global _FAST_CONFIG, _KOPS, _KOPS_FAILED
    _LAYER_TAGS.clear()
    _FAST_ENV_CACHE.clear()
    _FAST_CONFIG = RouteFastConfig()
    _KOPS = None
    _KOPS_FAILED = False


def _fast_env_arrays(lg: LayeredGraph) -> tuple:
    hit = _FAST_ENV_CACHE.get(id(lg))
    if hit is not None:
        return hit[1]
    import jax.numpy as jnp

    arrs = (
        jnp.asarray(lg.comp_of_dc, jnp.int32),
        jnp.asarray(lg.env.rtt_s, jnp.float32),
        jnp.asarray(1.0 / lg.env.bw_Bps_safe(), jnp.float32),
    )
    _FAST_ENV_CACHE.clear()
    _FAST_ENV_CACHE[id(lg)] = (lg, arrs)
    return arrs


def _route_batch_fast(
    lg: LayeredGraph,
    delta_all: np.ndarray,  # [K, D] replica rows for the flat item stream
    sizes_all: np.ndarray,  # [K] item bytes, flat
    req_id: np.ndarray,  # [K] request id per flat item
    bounds: np.ndarray,  # [R + 1] request offsets into the flat stream
    lens: np.ndarray,  # [R]
    origin: np.ndarray,  # [R]
    reg,
    obs: bool,
) -> Tuple[np.ndarray, np.ndarray]:
    """Fused expansion for the whole batch on the kernels fast path.

    Bit-packs the batch's replica rows (bit d = replica at DC d) and
    dispatches the autotuned winner for ``(r_pad, k_pad, D, L)``: the
    subset-histogram router (``kernels.ops.route_expand_subsets`` — CPU
    default for small DC counts, per-pass work independent of the item
    count), or a ``[R, Kmax]`` int32 tile through
    ``kernels.ops.route_expand_batch`` (Pallas kernel on TPU, jitted oracle
    otherwise).  Every impl produces the numpy router's exact greedy picks.
    Tile rows and item slots are padded to power-of-two buckets so the jit
    cache is keyed on a handful of shapes across the batch mix.  Returns
    ``(served [K], layers_used [R])``; all byte/latency folds are recomputed
    exactly on the host by the shared epilogue, so results are bit-identical
    to the numpy path.
    """
    ops, autotune = _get_kops()
    R = len(lens)
    K = delta_all.shape[0]
    D = delta_all.shape[1]
    t0 = time.perf_counter() if obs else 0.0
    kmax = int(lens.max())
    k_pad = autotune.shape_bucket(kmax, floor=8)
    r_pad = autotune.shape_bucket(R, floor=8)
    if D <= 23:
        # BLAS bit-pack: bool @ f32 powers of two; every bitmask value is an
        # exact f32 integer below 2^24
        bits_flat = (
            delta_all @ (1 << np.arange(D)).astype(np.float32)
        ).astype(np.int32)
    else:
        bits_flat = (
            delta_all.astype(np.int64) @ (1 << np.arange(D, dtype=np.int64))
        ).astype(np.int32)
    cfg = autotune.get_autotuner().lookup(
        "route_expand", (r_pad, k_pad, D, lg.n_layers)
    ) or {}
    impl = cfg.get("impl")
    if impl is None:
        on_tpu = ops.on_tpu()
        impl = (
            "kernel" if on_tpu
            else "subsets" if D <= ops.SUBSET_MAX_DCS
            else "ref"
        )
    if impl == "subsets" and D <= ops.SUBSET_MAX_DCS:
        served, layers_used, miss_after = ops.route_expand_subsets(
            bits_flat, req_id, R, origin, lg.comp_of_dc
        )
    else:
        pos = np.arange(K, dtype=np.int64) - bounds[req_id]
        bits = np.zeros((r_pad, k_pad), np.int32)
        bits[req_id, pos] = bits_flat
        szp = np.zeros((r_pad, k_pad), np.float32)
        szp[req_id, pos] = sizes_all
        lens_p = np.zeros(r_pad, np.int32)
        lens_p[:R] = lens
        origin_p = np.zeros(r_pad, np.int32)
        origin_p[:R] = origin
        comp, rtt, ibw = _fast_env_arrays(lg)
        served_p, _, layers_used, miss_after, _, _ = ops.route_expand_batch(
            bits, szp, lens_p, origin_p, comp, rtt, ibw,
            use_kernel=impl == "kernel",
            block_r=int(cfg.get("block_r", 128)),
        )
        served = served_p[req_id, pos].astype(np.int64)
    if obs:
        h = _obs_handles(reg)
        h.kernel_time.inc(time.perf_counter() - t0)
        # per-layer resolved counts from the kernel's missing-after-layer
        # columns (early-exited layers report 0 missing, which telescopes
        # to zero extra hits)
        miss_tot = miss_after[:R].sum(axis=0).tolist()
        h.hits(0).inc(K - int(miss_tot[0]))
        for layer in range(1, len(miss_tot)):
            hits = int(miss_tot[layer - 1]) - int(miss_tot[layer])
            if hits:
                h.hits(layer).inc(hits)
        h.unresolved.inc(int(miss_tot[-1]))
    return served, layers_used[:R].astype(np.int64)


def route_online_batch(
    lg: LayeredGraph,
    state: PlacementState,
    requests: Sequence[Tuple[np.ndarray, int]],
    sizes: Optional[np.ndarray] = None,
    registry=None,
    fast: Optional[bool] = None,
) -> List[RouteResult]:
    """Bottom-up expanding retrieval for a whole request batch at once.

    ``requests`` is a sequence of ``(items, origin)`` pairs.  Per request the
    outcome is identical to :func:`route_online` (same greedy max-coverage,
    same lowest-DC-id tie-break), but the batch is resolved with flat array
    ops: per layer, coverage counts for *all* requests are one segment-sum
    ``[R, D]`` and the per-request greedy pick is one masked argmax — the
    per-pattern Python loops collapse into a handful of numpy passes whose
    count is bounded by the layer's cluster width, not the batch size.

    A batch whose requests all share one origin (the sharded store's
    per-shard sub-batches) takes :func:`_expand_single_origin` instead of
    the lockstep loop — same results, less work per pass.

    ``fast`` pins the fused jax/Pallas expansion (:mod:`repro.kernels`):
    ``True`` forces it, ``False`` forbids it, ``None`` (default) lets
    :class:`RouteFastConfig` size gates decide.  The fast path computes the
    same greedy picks on device and re-folds bytes/latency on the host in
    f64, so its results are bit-identical to the numpy path.

    ``registry`` routes serving/routing telemetry into an explicit
    :class:`~repro.obs.MetricsRegistry` (a shard's private registry);
    ``None`` falls back to the process default.
    """
    env = lg.env
    R = len(requests)
    if R == 0:
        return []
    reg = registry if registry is not None else get_registry()
    if R == 1:
        # size-1 fast path: the flat batch machinery (request-id bookkeeping,
        # [R, D] coverage stacks) costs ~2x the scalar router at R == 1 and
        # the scalar path is definitionally request-identical.  With
        # telemetry enabled, _observe_scalar books the batch path's exact
        # instruments from the scalar result (the sharded store's per-shard
        # registries must account every request).
        items, origin_0 = requests[0]
        items = np.asarray(items)
        if sizes is None:
            sizes = lg.g.item_size()
        t0 = time.perf_counter() if reg.enabled else 0.0
        res = route_online(lg, state, items, int(origin_0), sizes=sizes)
        if reg.enabled:
            _observe_scalar(
                reg, lg, res, items, int(origin_0), sizes,
                time.perf_counter() - t0,
            )
        return [res]
    if sizes is None:
        sizes = lg.g.item_size()
    arrs = [np.asarray(it) for it, _ in requests]
    lens = np.fromiter((a.shape[0] for a in arrs), dtype=np.int64, count=R)
    origin = np.fromiter((o for _, o in requests), dtype=np.int64, count=R)
    items_all = (
        np.concatenate(arrs).astype(np.int64, copy=False)
        if lens.sum()
        else np.zeros(0, dtype=np.int64)
    )
    req_id = np.repeat(np.arange(R, dtype=np.int64), lens)
    K = len(items_all)
    D = env.n_dcs
    bounds = np.concatenate([[0], np.cumsum(lens)])
    # one gather each of the batch's replica rows and item bytes; every
    # greedy pass and the shared epilogue reuse them
    delta_all = state.delta[items_all]  # [K, D]
    sz_all = sizes[items_all]  # [K] f64

    # coverage telemetry: per-layer resolved-item counters + expansion
    # timing, all gated so the disabled path costs one attribute load
    obs = reg.enabled
    if obs:
        _obs_handles(reg).requests.inc(R)

    kmax = int(lens.max()) if R else 0
    if _fast_eligible(fast, _FAST_CONFIG, R, D, kmax, lg.n_layers):
        served, layers_used = _route_batch_fast(
            lg, delta_all, sz_all, req_id, bounds, lens, origin, reg, obs,
        )
        return _materialize_results(
            env, sz_all, req_id, bounds, origin, served,
            layers_used, R, D, reg, obs,
        )

    ar_K = np.arange(K)
    ar_R = np.arange(R)
    served = np.full(K, -1, dtype=np.int64)
    layers_used = np.zeros(R, dtype=np.int64)
    org_all = origin[req_id]
    if (origin == origin[0]).all():
        _expand_single_origin(
            lg, delta_all, req_id, R, int(origin[0]), served, layers_used, reg, obs
        )
    else:
        # Layer_0: local items first
        local = delta_all[ar_K, org_all]
        served[local] = org_all[local]

        missing_per_req = np.bincount(req_id[served < 0], minlength=R)
        if obs:
            unresolved = int(missing_per_req.sum())
            _obs_handles(reg).hits(0).inc(K - unresolved)
        for layer in range(1, lg.n_layers + 1):
            active = missing_per_req > 0
            if not active.any():
                break
            if obs:
                t_layer = time.perf_counter()
            comp = lg.comp_of_dc[layer]  # [D]
            allowed = comp[origin][:, None] == comp[None, :]  # [R, D]
            allowed[ar_R, origin] = False
            # route_online marks a layer "used" whenever its cluster is
            # non-empty for a still-unresolved request, even if nothing is
            # found there
            has_cluster = allowed.any(axis=1)
            layers_used[active & has_cluster] = layer
            # greedy max-coverage, all active requests in lockstep: each pass
            # computes every request's best cluster DC and assigns its hits —
            # requests are independent, so lockstep == per-request greedy
            while True:
                miss = served < 0
                if not miss.any():
                    break
                # segment-sum coverage per request: D bincounts beat a slow
                # ufunc.at scatter (D is a handful, the batch is the long axis)
                cover = np.stack(
                    [
                        np.bincount(req_id, weights=delta_all[:, d] * miss, minlength=R)
                        for d in range(D)
                    ],
                    axis=1,
                )
                cover[~allowed] = 0.0
                best = np.argmax(cover, axis=1)  # lowest-id tie-break
                gain = cover[ar_R, best]
                progress = gain > 0
                if not progress.any():
                    break
                hit = miss & progress[req_id] & delta_all[ar_K, best[req_id]]
                served[hit] = best[req_id[hit]]
            missing_per_req = np.bincount(req_id[served < 0], minlength=R)
            if obs:
                # cumulative seconds as a counter (count comes from
                # layer_hits' batch count): a scalar histogram observe costs
                # ~10us in P² marker maths, which the 5% serving budget
                # cannot spare
                h = _obs_handles(reg)
                h.layer_s(layer).inc(time.perf_counter() - t_layer)
                now_unresolved = int(missing_per_req.sum())
                h.hits(layer).inc(unresolved - now_unresolved)
                unresolved = now_unresolved

        if obs:
            _obs_handles(reg).unresolved.inc(unresolved)

    return _materialize_results(
        env, sz_all, req_id, bounds, origin, served, layers_used,
        R, D, reg, obs,
    )


def _materialize_results(
    env: GeoEnvironment,
    sz_all: np.ndarray,  # [K] item bytes for the flat stream, f64
    req_id: np.ndarray,  # [K]
    bounds: np.ndarray,  # [R + 1] request offsets into the flat stream
    origin: np.ndarray,  # [R]
    served: np.ndarray,  # [K] serving DC per flat item (-1 unresolved)
    layers_used: np.ndarray,  # [R]
    R: int,
    D: int,
    reg,
    obs: bool,
) -> List[RouteResult]:
    """Shared exact epilogue: fold served assignments into Eq. 1 latency,
    WAN bytes and per-request :class:`RouteResult`\\ s, entirely in host
    f64.  Both the numpy expansion and the jax fast path feed this from
    their (integer, identical) ``served`` picks, which is what makes the
    fast path bit-identical — f32 device byte sums never leak into results.
    """
    ar_R = np.arange(R)
    srv = served >= 0
    if srv.all():
        # fully-resolved batch (the common case): skip the three boolean-
        # indexed copies of the flat stream
        flat = req_id * D + served
        weights = sz_all
        n_miss = np.zeros(R, np.int64)
    else:
        flat = req_id[srv] * D + served[srv]  # (request, serving DC) pair
        weights = sz_all[srv]
        n_miss = np.bincount(req_id[~srv], minlength=R)
    bytes_rd = np.bincount(flat, weights=weights, minlength=R * D).reshape(R, D)
    served_mask = np.zeros(R * D, dtype=bool)
    served_mask[flat] = True
    served_mask = served_mask.reshape(R, D)
    lat_rd = env.rtt_s[:, origin].T + bytes_rd / env.bw_Bps_safe()[:, origin].T
    lat_rd[ar_R, origin] = 0.0  # local serving is free (Eq. 1)
    straggler = np.where(served_mask, lat_rd, -np.inf).max(axis=1)
    straggler[~served_mask.any(axis=1)] = 0.0
    wan_r = bytes_rd.sum(axis=1) - bytes_rd[ar_R, origin]

    if obs:
        # serving-path telemetry, batch-granular: one sketch update for the
        # whole latency vector and one [D, D] reduction for per-link WAN
        # bytes (bytes_rd grouped by origin DC) — per-request Python here
        # would blow the 5% overhead budget of BENCH_obs
        # p50/p99 only: every tracked quantile is one more P² sketch fed per
        # batch, and the p90 sketch does not earn its ~20us here
        h = _obs_handles(reg)
        h.lat.observe_many(straggler)
        wan_total = float(wan_r.sum())
        h.wan.inc(wan_total)
        if wan_total > 0.0:
            # [serving DC, origin DC] bytes as one bincount over the R*D
            # cells — no [R, D] onehot/matmul temporaries on the hot path
            cell = (np.arange(D) * D)[None, :] + origin[:, None]  # [R, D]
            link = np.bincount(
                cell.ravel(), weights=bytes_rd.ravel(), minlength=D * D
            ).reshape(D, D)
            np.fill_diagonal(link, 0.0)  # local serving is not WAN traffic
            h.grid.add(link)

    # per-request materialization: all (r, dc) pairs at once, no np.unique;
    # per_dc_latency dicts build lazily inside RouteResult on first access.
    # Scalars are pre-extracted to python (tolist) and RouteResult is built
    # positionally — at batch 1024 this loop is the epilogue's hot half.
    rr, dd = np.nonzero(served_mask)  # row-major: grouped by request
    pair_lat = lat_rd[rr, dd].tolist()
    pair_bounds = np.cumsum(np.bincount(rr, minlength=R)).tolist()
    results: List[RouteResult] = []
    append = results.append
    straggler_l = straggler.tolist()
    layers_l = layers_used.tolist()
    n_miss_l = n_miss.tolist()
    wan_l = wan_r.tolist()
    bounds_l = bounds.tolist()
    lo = 0
    for r in range(R):
        hi = pair_bounds[r]
        append(
            RouteResult(
                served[bounds_l[r] : bounds_l[r + 1]],
                dd[lo:hi],
                straggler_l[r],
                None,
                layers_l[r],
                n_miss_l[r],
                wan_l[r],
                pair_lat[lo:hi],
            )
        )
        lo = hi
    return results


# ------------------------------------------------------------------ offline
@dataclasses.dataclass
class OfflineLayout:
    sites: np.ndarray  # retained execution DCs
    item_site: np.ndarray  # [I] executing DC per required item (-1 = n/a)
    migrated: np.ndarray  # item ids moved off their primary DC
    wan_bytes: float  # assembly traffic
    excluded: np.ndarray  # DCs ruled out by Eq. 14


def _boundary_vertices(g: Graph, dc: int) -> int:
    src_dc = g.partition[g.src]
    dst_dc = g.partition[g.dst]
    cross = src_dc != dst_dc
    b = np.unique(
        np.concatenate([g.src[cross & (src_dc == dc)], g.dst[cross & (dst_dc == dc)]])
    )
    return int(len(b))


def route_offline(
    lg: LayeredGraph,
    state: PlacementState,
    required_items: np.ndarray,
    n_iters: int = 15,
    msg_bytes: float = 16.0,
    xi_frac: float = 0.2,
) -> OfflineLayout:
    """Top-down localization + bottom-up assembly (paper Fig. 6, Eq. 14)."""
    g, env = lg.g, lg.env
    D = env.n_dcs
    sizes = g.item_size()
    required_items = np.asarray(required_items)
    req_mask = np.zeros(g.n_items, dtype=bool)
    req_mask[required_items] = True

    # --- top-down localization: candidate holders per required item -------
    # (delta already encodes all replicas; localization = restricting to it.)
    primary = np.zeros(g.n_items, dtype=np.int64)
    primary[: g.n_nodes] = g.partition
    primary[g.n_nodes :] = g.partition[g.src]

    # --- Eq. 14 migration test per DC --------------------------------------
    total_boundary = sum(_boundary_vertices(g, d) for d in range(D))
    xi = xi_frac * n_iters * msg_bytes * max(total_boundary, 1)
    eta_l = lg.eta_L(1)
    retained: List[int] = []
    excluded: List[int] = []
    for d in range(D):
        local_req = required_items[primary[required_items] == d]
        if len(local_req) == 0:
            excluded.append(d)
            continue
        vert_req = local_req[local_req < g.n_nodes]
        replicas_at_d = int(
            (state.delta[vert_req, d] & (g.partition[vert_req] != d)).sum()
        )
        n_bs = _boundary_vertices(g, d)
        comm_proxy = n_iters * msg_bytes * (replicas_at_d + n_bs)
        local_size = float(sizes[local_req].sum())
        if comm_proxy - local_size > (1.0 - eta_l) * xi:
            excluded.append(d)
        else:
            retained.append(d)
    if not retained:  # degenerate: keep the DC with the most local data
        vols = [
            float(sizes[required_items[primary[required_items] == d]].sum())
            for d in range(D)
        ]
        retained = [int(np.argmax(vols))]
        excluded = [d for d in range(D) if d != retained[0]]

    retained_arr = np.asarray(sorted(retained))
    # --- bottom-up assembly -------------------------------------------------
    item_site = np.full(g.n_items, -1, dtype=np.int64)
    load = {int(d): 0.0 for d in retained}
    wan_bytes = 0.0
    migrated: List[np.ndarray] = []

    own = primary[required_items]
    keep = np.isin(own, retained_arr)
    # in-place: items whose primary DC is retained execute there
    item_site[required_items[keep]] = own[keep]
    for d in retained:
        load[d] += float(sizes[required_items[keep][own[keep] == d]].sum())

    # replica reuse: a displaced item already replicated at a retained DC
    pending = required_items[~keep]
    if len(pending):
        rep = state.delta[pending][:, retained_arr]
        has_rep = rep.any(axis=1)
        choice = retained_arr[np.argmax(rep, axis=1)]
        reuse = pending[has_rep]
        item_site[reuse] = choice[has_rep]
        pending = pending[~has_rep]

    # remaining items migrate: hash to retained DCs within the smallest
    # enclosing cluster, escalating per layer (Fig. 6 bottom-up)
    if len(pending):
        for x in pending.tolist():
            home = int(primary[x])
            dest = -1
            for layer in range(1, lg.n_layers + 1):
                comp = lg.comp_of_dc[layer, home]
                cluster = np.where(lg.comp_of_dc[layer] == comp)[0]
                cands = [int(d) for d in cluster if d in load]
                if cands:
                    # minimize comm cost, tie-break on current load balance
                    costs = [
                        (env.c_net[home, d] * sizes[x] + 1e-12 * load[d], d)
                        for d in cands
                    ]
                    dest = min(costs)[1]
                    break
            if dest < 0:
                dest = int(retained_arr[0])
            item_site[x] = dest
            load[dest] += float(sizes[x])
            wan_bytes += float(sizes[x])
        migrated.append(pending)

    migrated_arr = (
        np.concatenate(migrated) if migrated else np.zeros(0, dtype=np.int64)
    )
    return OfflineLayout(
        sites=retained_arr,
        item_site=item_site,
        migrated=migrated_arr,
        wan_bytes=wan_bytes,
        excluded=np.asarray(sorted(excluded)),
    )
