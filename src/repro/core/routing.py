"""Stepwise layered routing (paper §VI).

Online mode — bottom-up expanding retrieval: serve locally, then per layer
(ascending latency) greedily pick the cluster DC covering the most missing
items (minimizing participating DCs), escalating until the pattern is fully
resolved.

Offline mode — top-down localization (map required items to candidate
replica holders) then bottom-up assembly: each DC is tested with the
migration condition (Eq. 14); excluded DCs' data is redistributed by hashing
to retained DCs within the same cluster, escalating upward when a cluster
retains nobody.  The result is an execution layout for geo-distributed
analytics (few sites, minimal WAN).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import get_registry
from .cost import PlacementState
from .graph import Graph
from .latency import GeoEnvironment
from .layered_graph import LayeredGraph

__all__ = [
    "RouteResult",
    "route_online",
    "route_online_batch",
    "OfflineLayout",
    "route_offline",
]

# precomputed per-layer tag keys: the 5% telemetry budget on the batch
# serving path leaves no room for per-call tag normalization
_LAYER_TAGS: Dict[int, Tuple[Tuple[str, str], ...]] = {}


def _layer_tags(layer: int) -> Tuple[Tuple[str, str], ...]:
    key = _LAYER_TAGS.get(layer)
    if key is None:
        key = (("layer", str(layer)),)
        _LAYER_TAGS[layer] = key
    return key


# ------------------------------------------------------------------- online
@dataclasses.dataclass
class RouteResult:
    served_by: np.ndarray  # [len(items)] serving DC per item (-1 unresolved)
    dcs: np.ndarray  # distinct participating DCs
    latency_s: float  # straggler latency (max over DCs, Eq. 1)
    per_dc_latency: Dict[int, float]
    layers_used: int
    n_missing: int
    wan_bytes: float = 0.0  # bytes served by non-origin DCs (WAN traffic)


def route_online(
    lg: LayeredGraph,
    state: PlacementState,
    items: np.ndarray,
    origin: int,
    sizes: Optional[np.ndarray] = None,
) -> RouteResult:
    """Bottom-up expanding retrieval for one pattern request (paper Fig. 5)."""
    env = lg.env
    if sizes is None:
        sizes = lg.g.item_size()
    items = np.asarray(items)
    served = np.full(len(items), -1, dtype=np.int64)

    # Layer_0: local items first
    local = state.delta[items, origin]
    served[local] = origin
    layers_used = 0

    for layer in range(1, lg.n_layers + 1):
        if (served >= 0).all():
            break
        comp = lg.comp_of_dc[layer, origin]
        cluster = np.where(lg.comp_of_dc[layer] == comp)[0]
        cluster = cluster[cluster != origin]
        if len(cluster) == 0:
            continue
        layers_used = layer
        # greedy max-coverage within the latency-homogeneous cluster
        while True:
            missing = np.where(served < 0)[0]
            if len(missing) == 0:
                break
            cover = state.delta[items[missing]][:, cluster].sum(axis=0)
            best = int(cover.argmax())
            if cover[best] == 0:
                break  # escalate to the next layer
            dc = int(cluster[best])
            hit = missing[state.delta[items[missing], dc]]
            served[hit] = dc
    # resolved latency per participating DC (Eq. 1 with S_d = served bytes)
    per_dc: Dict[int, float] = {}
    wan = 0.0
    for dc in np.unique(served[served >= 0]):
        s_d = float(sizes[items[served == dc]].sum())
        per_dc[int(dc)] = env.request_latency(int(dc), origin, s_d)
        if int(dc) != origin:
            wan += s_d
    lat = max(per_dc.values()) if per_dc else 0.0
    return RouteResult(
        served_by=served,
        dcs=np.unique(served[served >= 0]),
        latency_s=lat,
        per_dc_latency=per_dc,
        layers_used=layers_used,
        n_missing=int((served < 0).sum()),
        wan_bytes=wan,
    )


def _expand_single_origin(
    lg: LayeredGraph,
    delta_all: np.ndarray,
    req_id: np.ndarray,
    R: int,
    o: int,
    served: np.ndarray,
    layers_used: np.ndarray,
    reg,
    obs: bool,
) -> None:
    """Greedy layered expansion for a batch that shares one origin DC.

    Request-identical to the mixed-origin lockstep loop (same greedy
    max-coverage, same lowest-DC-id tie-break), but the shared origin means
    every request sees the *same* cluster per layer — so layer-0 is a column
    slice instead of a per-row gather, coverage bincounts run over only the
    cluster's columns, and every greedy pass touches only the still-missing
    rows.  This is the per-shard serving path: the sharded store dispatches
    per-origin sub-batches, which land here.
    """
    K = delta_all.shape[0]
    local = delta_all[:, o]
    served[local] = o
    idx = np.where(~local)[0]  # flat positions still missing
    if obs:
        unresolved = len(idx)
        reg.counter_keyed("routing.layer_hits", _layer_tags(0)).inc(K - unresolved)
    for layer in range(1, lg.n_layers + 1):
        if len(idx) == 0:
            break
        if obs:
            t_layer = time.perf_counter()
        comp = lg.comp_of_dc[layer]
        cluster = np.where(comp == comp[o])[0]
        cluster = cluster[cluster != o]
        if len(cluster):
            layers_used[np.unique(req_id[idx])] = layer
            ar_R = np.arange(R)
            while len(idx):
                rid = req_id[idx]
                sub = delta_all[np.ix_(idx, cluster)]  # [missing, |cluster|]
                cover = np.stack(
                    [
                        np.bincount(rid, weights=sub[:, j], minlength=R)
                        for j in range(len(cluster))
                    ],
                    axis=1,
                )
                best_j = np.argmax(cover, axis=1)  # lowest-id tie-break
                gain = cover[ar_R, best_j]
                if not (gain > 0).any():
                    break  # escalate to the next layer
                hit = (gain[rid] > 0) & sub[np.arange(len(idx)), best_j[rid]]
                served[idx[hit]] = cluster[best_j[rid[hit]]]
                idx = idx[~hit]
        if obs:
            reg.counter_keyed("routing.layer_time_s", _layer_tags(layer)).inc(
                time.perf_counter() - t_layer
            )
            reg.counter_keyed("routing.layer_hits", _layer_tags(layer)).inc(
                unresolved - len(idx)
            )
            unresolved = len(idx)
    if obs:
        reg.counter_keyed("routing.unresolved_items", ()).inc(len(idx))


def route_online_batch(
    lg: LayeredGraph,
    state: PlacementState,
    requests: Sequence[Tuple[np.ndarray, int]],
    sizes: Optional[np.ndarray] = None,
    registry=None,
) -> List[RouteResult]:
    """Bottom-up expanding retrieval for a whole request batch at once.

    ``requests`` is a sequence of ``(items, origin)`` pairs.  Per request the
    outcome is identical to :func:`route_online` (same greedy max-coverage,
    same lowest-DC-id tie-break), but the batch is resolved with flat array
    ops: per layer, coverage counts for *all* requests are one segment-sum
    ``[R, D]`` and the per-request greedy pick is one masked argmax — the
    per-pattern Python loops collapse into a handful of numpy passes whose
    count is bounded by the layer's cluster width, not the batch size.

    A batch whose requests all share one origin (the sharded store's
    per-shard sub-batches) takes :func:`_expand_single_origin` instead of
    the lockstep loop — same results, less work per pass.

    ``registry`` routes serving/routing telemetry into an explicit
    :class:`~repro.obs.MetricsRegistry` (a shard's private registry);
    ``None`` falls back to the process default.
    """
    env = lg.env
    R = len(requests)
    if R == 0:
        return []
    reg = registry if registry is not None else get_registry()
    if R == 1 and not reg.enabled:
        # size-1 fast path: the flat batch machinery (request-id bookkeeping,
        # [R, D] coverage stacks) costs ~2x the scalar router at R == 1
        # (BENCH_serving batch-1 speedup was 0.48) and the scalar path is
        # definitionally request-identical.  With telemetry enabled the
        # batch path runs even at R == 1 so every served request is counted
        # (the sharded store's per-shard registries must account exactly).
        items, origin = requests[0]
        return [route_online(lg, state, np.asarray(items), int(origin), sizes=sizes)]
    if sizes is None:
        sizes = lg.g.item_size()
    lens = np.asarray([len(np.asarray(it)) for it, _ in requests], dtype=np.int64)
    origin = np.asarray([int(o) for _, o in requests], dtype=np.int64)
    items_all = (
        np.concatenate([np.asarray(it, dtype=np.int64) for it, _ in requests])
        if lens.sum()
        else np.zeros(0, dtype=np.int64)
    )
    req_id = np.repeat(np.arange(R, dtype=np.int64), lens)
    K = len(items_all)
    ar_K = np.arange(K)
    ar_R = np.arange(R)
    served = np.full(K, -1, dtype=np.int64)
    layers_used = np.zeros(R, dtype=np.int64)
    D = env.n_dcs
    # one gather of the batch's replica rows; every greedy pass reuses it
    delta_all = state.delta[items_all]  # [K, D]
    org_all = origin[req_id]

    # coverage telemetry: per-layer resolved-item counters + expansion
    # timing, all gated so the disabled path costs one attribute load
    obs = reg.enabled
    if obs:
        reg.counter_keyed("serving.requests", ()).inc(R)

    if (origin == origin[0]).all():
        _expand_single_origin(
            lg, delta_all, req_id, R, int(origin[0]), served, layers_used, reg, obs
        )
    else:
        # Layer_0: local items first
        local = delta_all[ar_K, org_all]
        served[local] = org_all[local]

        missing_per_req = np.bincount(req_id[served < 0], minlength=R)
        if obs:
            unresolved = int(missing_per_req.sum())
            reg.counter_keyed("routing.layer_hits", _layer_tags(0)).inc(K - unresolved)
        for layer in range(1, lg.n_layers + 1):
            active = missing_per_req > 0
            if not active.any():
                break
            if obs:
                t_layer = time.perf_counter()
            comp = lg.comp_of_dc[layer]  # [D]
            allowed = comp[origin][:, None] == comp[None, :]  # [R, D]
            allowed[ar_R, origin] = False
            # route_online marks a layer "used" whenever its cluster is
            # non-empty for a still-unresolved request, even if nothing is
            # found there
            has_cluster = allowed.any(axis=1)
            layers_used[active & has_cluster] = layer
            # greedy max-coverage, all active requests in lockstep: each pass
            # computes every request's best cluster DC and assigns its hits —
            # requests are independent, so lockstep == per-request greedy
            while True:
                miss = served < 0
                if not miss.any():
                    break
                # segment-sum coverage per request: D bincounts beat a slow
                # ufunc.at scatter (D is a handful, the batch is the long axis)
                cover = np.stack(
                    [
                        np.bincount(req_id, weights=delta_all[:, d] * miss, minlength=R)
                        for d in range(D)
                    ],
                    axis=1,
                )
                cover[~allowed] = 0.0
                best = np.argmax(cover, axis=1)  # lowest-id tie-break
                gain = cover[ar_R, best]
                progress = gain > 0
                if not progress.any():
                    break
                hit = miss & progress[req_id] & delta_all[ar_K, best[req_id]]
                served[hit] = best[req_id[hit]]
            missing_per_req = np.bincount(req_id[served < 0], minlength=R)
            if obs:
                # cumulative seconds as a counter (count comes from
                # layer_hits' batch count): a scalar histogram observe costs
                # ~10us in P² marker maths, which the 5% serving budget
                # cannot spare
                reg.counter_keyed("routing.layer_time_s", _layer_tags(layer)).inc(
                    time.perf_counter() - t_layer
                )
                now_unresolved = int(missing_per_req.sum())
                reg.counter_keyed("routing.layer_hits", _layer_tags(layer)).inc(
                    unresolved - now_unresolved
                )
                unresolved = now_unresolved

        if obs:
            reg.counter_keyed("routing.unresolved_items", ()).inc(unresolved)

    # resolved latency per (request, DC): served bytes -> Eq. 1, vectorized
    srv = served >= 0
    flat = req_id[srv] * D + served[srv]  # (request, serving DC) pair key
    bytes_rd = np.bincount(
        flat, weights=sizes[items_all[srv]], minlength=R * D
    ).reshape(R, D)
    served_mask = np.zeros(R * D, dtype=bool)
    served_mask[flat] = True
    served_mask = served_mask.reshape(R, D)
    lat_rd = env.rtt_s[:, origin].T + bytes_rd / env.bw_Bps_safe()[:, origin].T
    lat_rd[ar_R, origin] = 0.0  # local serving is free (Eq. 1)
    straggler = np.where(served_mask, lat_rd, -np.inf).max(axis=1)
    straggler[~served_mask.any(axis=1)] = 0.0
    wan_r = bytes_rd.sum(axis=1) - bytes_rd[ar_R, origin]
    n_miss = np.bincount(req_id[~srv], minlength=R) if (~srv).any() else np.zeros(R, np.int64)

    if obs:
        # serving-path telemetry, batch-granular: one sketch update for the
        # whole latency vector and one [D, D] reduction for per-link WAN
        # bytes (bytes_rd grouped by origin DC) — per-request Python here
        # would blow the 5% overhead budget of BENCH_obs
        # p50/p99 only: every tracked quantile is one more P² sketch fed per
        # batch, and the p90 sketch does not earn its ~20us here
        reg.histogram(
            "serving.request_latency_s", quantiles=(0.5, 0.99)
        ).observe_many(straggler)
        wan_total = float(wan_r.sum())
        reg.counter_keyed("serving.wan_bytes", ()).inc(wan_total)
        if wan_total > 0.0:
            onehot = np.zeros((R, D))
            onehot[ar_R, origin] = 1.0
            link = bytes_rd.T @ onehot  # [serving DC, origin DC] bytes
            np.fill_diagonal(link, 0.0)  # local serving is not WAN traffic
            reg.counter_grid("serving.wan_bytes_link", ("src", "dst")).add(link)

    # per-request materialization: all (r, dc) pairs at once, no np.unique
    rr, dd = np.nonzero(served_mask)  # row-major: grouped by request
    pair_lat = lat_rd[rr, dd]
    pair_bounds = np.concatenate([[0], np.cumsum(np.bincount(rr, minlength=R))])
    results: List[RouteResult] = []
    bounds = np.concatenate([[0], np.cumsum(lens)])
    for r in range(R):
        lo, hi = pair_bounds[r], pair_bounds[r + 1]
        results.append(
            RouteResult(
                served_by=served[bounds[r] : bounds[r + 1]],
                dcs=dd[lo:hi],
                latency_s=float(straggler[r]),
                per_dc_latency=dict(
                    zip(dd[lo:hi].tolist(), pair_lat[lo:hi].tolist())
                ),
                layers_used=int(layers_used[r]),
                n_missing=int(n_miss[r]),
                wan_bytes=float(wan_r[r]),
            )
        )
    return results


# ------------------------------------------------------------------ offline
@dataclasses.dataclass
class OfflineLayout:
    sites: np.ndarray  # retained execution DCs
    item_site: np.ndarray  # [I] executing DC per required item (-1 = n/a)
    migrated: np.ndarray  # item ids moved off their primary DC
    wan_bytes: float  # assembly traffic
    excluded: np.ndarray  # DCs ruled out by Eq. 14


def _boundary_vertices(g: Graph, dc: int) -> int:
    src_dc = g.partition[g.src]
    dst_dc = g.partition[g.dst]
    cross = src_dc != dst_dc
    b = np.unique(
        np.concatenate([g.src[cross & (src_dc == dc)], g.dst[cross & (dst_dc == dc)]])
    )
    return int(len(b))


def route_offline(
    lg: LayeredGraph,
    state: PlacementState,
    required_items: np.ndarray,
    n_iters: int = 15,
    msg_bytes: float = 16.0,
    xi_frac: float = 0.2,
) -> OfflineLayout:
    """Top-down localization + bottom-up assembly (paper Fig. 6, Eq. 14)."""
    g, env = lg.g, lg.env
    D = env.n_dcs
    sizes = g.item_size()
    required_items = np.asarray(required_items)
    req_mask = np.zeros(g.n_items, dtype=bool)
    req_mask[required_items] = True

    # --- top-down localization: candidate holders per required item -------
    # (delta already encodes all replicas; localization = restricting to it.)
    primary = np.zeros(g.n_items, dtype=np.int64)
    primary[: g.n_nodes] = g.partition
    primary[g.n_nodes :] = g.partition[g.src]

    # --- Eq. 14 migration test per DC --------------------------------------
    total_boundary = sum(_boundary_vertices(g, d) for d in range(D))
    xi = xi_frac * n_iters * msg_bytes * max(total_boundary, 1)
    eta_l = lg.eta_L(1)
    retained: List[int] = []
    excluded: List[int] = []
    for d in range(D):
        local_req = required_items[primary[required_items] == d]
        if len(local_req) == 0:
            excluded.append(d)
            continue
        vert_req = local_req[local_req < g.n_nodes]
        replicas_at_d = int(
            (state.delta[vert_req, d] & (g.partition[vert_req] != d)).sum()
        )
        n_bs = _boundary_vertices(g, d)
        comm_proxy = n_iters * msg_bytes * (replicas_at_d + n_bs)
        local_size = float(sizes[local_req].sum())
        if comm_proxy - local_size > (1.0 - eta_l) * xi:
            excluded.append(d)
        else:
            retained.append(d)
    if not retained:  # degenerate: keep the DC with the most local data
        vols = [
            float(sizes[required_items[primary[required_items] == d]].sum())
            for d in range(D)
        ]
        retained = [int(np.argmax(vols))]
        excluded = [d for d in range(D) if d != retained[0]]

    retained_arr = np.asarray(sorted(retained))
    # --- bottom-up assembly -------------------------------------------------
    item_site = np.full(g.n_items, -1, dtype=np.int64)
    load = {int(d): 0.0 for d in retained}
    wan_bytes = 0.0
    migrated: List[np.ndarray] = []

    own = primary[required_items]
    keep = np.isin(own, retained_arr)
    # in-place: items whose primary DC is retained execute there
    item_site[required_items[keep]] = own[keep]
    for d in retained:
        load[d] += float(sizes[required_items[keep][own[keep] == d]].sum())

    # replica reuse: a displaced item already replicated at a retained DC
    pending = required_items[~keep]
    if len(pending):
        rep = state.delta[pending][:, retained_arr]
        has_rep = rep.any(axis=1)
        choice = retained_arr[np.argmax(rep, axis=1)]
        reuse = pending[has_rep]
        item_site[reuse] = choice[has_rep]
        pending = pending[~has_rep]

    # remaining items migrate: hash to retained DCs within the smallest
    # enclosing cluster, escalating per layer (Fig. 6 bottom-up)
    if len(pending):
        for x in pending.tolist():
            home = int(primary[x])
            dest = -1
            for layer in range(1, lg.n_layers + 1):
                comp = lg.comp_of_dc[layer, home]
                cluster = np.where(lg.comp_of_dc[layer] == comp)[0]
                cands = [int(d) for d in cluster if d in load]
                if cands:
                    # minimize comm cost, tie-break on current load balance
                    costs = [
                        (env.c_net[home, d] * sizes[x] + 1e-12 * load[d], d)
                        for d in cands
                    ]
                    dest = min(costs)[1]
                    break
            if dest < 0:
                dest = int(retained_arr[0])
            item_site[x] = dest
            load[dest] += float(sizes[x])
            wan_bytes += float(sizes[x])
        migrated.append(pending)

    migrated_arr = (
        np.concatenate(migrated) if migrated else np.zeros(0, dtype=np.int64)
    )
    return OfflineLayout(
        sites=retained_arr,
        item_site=item_site,
        migrated=migrated_arr,
        wan_bytes=wan_bytes,
        excluded=np.asarray(sorted(excluded)),
    )
