"""Stepwise layered routing (paper §VI).

Online mode — bottom-up expanding retrieval: serve locally, then per layer
(ascending latency) greedily pick the cluster DC covering the most missing
items (minimizing participating DCs), escalating until the pattern is fully
resolved.

Offline mode — top-down localization (map required items to candidate
replica holders) then bottom-up assembly: each DC is tested with the
migration condition (Eq. 14); excluded DCs' data is redistributed by hashing
to retained DCs within the same cluster, escalating upward when a cluster
retains nobody.  The result is an execution layout for geo-distributed
analytics (few sites, minimal WAN).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .cost import PlacementState
from .graph import Graph
from .latency import GeoEnvironment
from .layered_graph import LayeredGraph

__all__ = ["RouteResult", "route_online", "OfflineLayout", "route_offline"]


# ------------------------------------------------------------------- online
@dataclasses.dataclass
class RouteResult:
    served_by: np.ndarray  # [len(items)] serving DC per item (-1 unresolved)
    dcs: np.ndarray  # distinct participating DCs
    latency_s: float  # straggler latency (max over DCs, Eq. 1)
    per_dc_latency: Dict[int, float]
    layers_used: int
    n_missing: int


def route_online(
    lg: LayeredGraph,
    state: PlacementState,
    items: np.ndarray,
    origin: int,
    sizes: Optional[np.ndarray] = None,
) -> RouteResult:
    """Bottom-up expanding retrieval for one pattern request (paper Fig. 5)."""
    env = lg.env
    if sizes is None:
        sizes = lg.g.item_size()
    items = np.asarray(items)
    served = np.full(len(items), -1, dtype=np.int64)

    # Layer_0: local items first
    local = state.delta[items, origin]
    served[local] = origin
    layers_used = 0

    for layer in range(1, lg.n_layers + 1):
        if (served >= 0).all():
            break
        comp = lg.comp_of_dc[layer, origin]
        cluster = np.where(lg.comp_of_dc[layer] == comp)[0]
        cluster = cluster[cluster != origin]
        if len(cluster) == 0:
            continue
        layers_used = layer
        # greedy max-coverage within the latency-homogeneous cluster
        while True:
            missing = np.where(served < 0)[0]
            if len(missing) == 0:
                break
            cover = state.delta[items[missing]][:, cluster].sum(axis=0)
            best = int(cover.argmax())
            if cover[best] == 0:
                break  # escalate to the next layer
            dc = int(cluster[best])
            hit = missing[state.delta[items[missing], dc]]
            served[hit] = dc
    # resolved latency per participating DC (Eq. 1 with S_d = served bytes)
    per_dc: Dict[int, float] = {}
    for dc in np.unique(served[served >= 0]):
        s_d = float(sizes[items[served == dc]].sum())
        per_dc[int(dc)] = env.request_latency(int(dc), origin, s_d)
    lat = max(per_dc.values()) if per_dc else 0.0
    return RouteResult(
        served_by=served,
        dcs=np.unique(served[served >= 0]),
        latency_s=lat,
        per_dc_latency=per_dc,
        layers_used=layers_used,
        n_missing=int((served < 0).sum()),
    )


# ------------------------------------------------------------------ offline
@dataclasses.dataclass
class OfflineLayout:
    sites: np.ndarray  # retained execution DCs
    item_site: np.ndarray  # [I] executing DC per required item (-1 = n/a)
    migrated: np.ndarray  # item ids moved off their primary DC
    wan_bytes: float  # assembly traffic
    excluded: np.ndarray  # DCs ruled out by Eq. 14


def _boundary_vertices(g: Graph, dc: int) -> int:
    src_dc = g.partition[g.src]
    dst_dc = g.partition[g.dst]
    cross = src_dc != dst_dc
    b = np.unique(
        np.concatenate([g.src[cross & (src_dc == dc)], g.dst[cross & (dst_dc == dc)]])
    )
    return int(len(b))


def route_offline(
    lg: LayeredGraph,
    state: PlacementState,
    required_items: np.ndarray,
    n_iters: int = 15,
    msg_bytes: float = 16.0,
    xi_frac: float = 0.2,
) -> OfflineLayout:
    """Top-down localization + bottom-up assembly (paper Fig. 6, Eq. 14)."""
    g, env = lg.g, lg.env
    D = env.n_dcs
    sizes = g.item_size()
    required_items = np.asarray(required_items)
    req_mask = np.zeros(g.n_items, dtype=bool)
    req_mask[required_items] = True

    # --- top-down localization: candidate holders per required item -------
    # (delta already encodes all replicas; localization = restricting to it.)
    primary = np.zeros(g.n_items, dtype=np.int64)
    primary[: g.n_nodes] = g.partition
    primary[g.n_nodes :] = g.partition[g.src]

    # --- Eq. 14 migration test per DC --------------------------------------
    total_boundary = sum(_boundary_vertices(g, d) for d in range(D))
    xi = xi_frac * n_iters * msg_bytes * max(total_boundary, 1)
    eta_l = lg.eta_L(1)
    retained: List[int] = []
    excluded: List[int] = []
    for d in range(D):
        local_req = required_items[primary[required_items] == d]
        if len(local_req) == 0:
            excluded.append(d)
            continue
        vert_req = local_req[local_req < g.n_nodes]
        replicas_at_d = int(
            (state.delta[vert_req, d] & (g.partition[vert_req] != d)).sum()
        )
        n_bs = _boundary_vertices(g, d)
        comm_proxy = n_iters * msg_bytes * (replicas_at_d + n_bs)
        local_size = float(sizes[local_req].sum())
        if comm_proxy - local_size > (1.0 - eta_l) * xi:
            excluded.append(d)
        else:
            retained.append(d)
    if not retained:  # degenerate: keep the DC with the most local data
        vols = [
            float(sizes[required_items[primary[required_items] == d]].sum())
            for d in range(D)
        ]
        retained = [int(np.argmax(vols))]
        excluded = [d for d in range(D) if d != retained[0]]

    retained_arr = np.asarray(sorted(retained))
    # --- bottom-up assembly -------------------------------------------------
    item_site = np.full(g.n_items, -1, dtype=np.int64)
    load = {int(d): 0.0 for d in retained}
    wan_bytes = 0.0
    migrated: List[np.ndarray] = []

    own = primary[required_items]
    keep = np.isin(own, retained_arr)
    # in-place: items whose primary DC is retained execute there
    item_site[required_items[keep]] = own[keep]
    for d in retained:
        load[d] += float(sizes[required_items[keep][own[keep] == d]].sum())

    # replica reuse: a displaced item already replicated at a retained DC
    pending = required_items[~keep]
    if len(pending):
        rep = state.delta[pending][:, retained_arr]
        has_rep = rep.any(axis=1)
        choice = retained_arr[np.argmax(rep, axis=1)]
        reuse = pending[has_rep]
        item_site[reuse] = choice[has_rep]
        pending = pending[~has_rep]

    # remaining items migrate: hash to retained DCs within the smallest
    # enclosing cluster, escalating per layer (Fig. 6 bottom-up)
    if len(pending):
        for x in pending.tolist():
            home = int(primary[x])
            dest = -1
            for layer in range(1, lg.n_layers + 1):
                comp = lg.comp_of_dc[layer, home]
                cluster = np.where(lg.comp_of_dc[layer] == comp)[0]
                cands = [int(d) for d in cluster if d in load]
                if cands:
                    # minimize comm cost, tie-break on current load balance
                    costs = [
                        (env.c_net[home, d] * sizes[x] + 1e-12 * load[d], d)
                        for d in cands
                    ]
                    dest = min(costs)[1]
                    break
            if dest < 0:
                dest = int(retained_arr[0])
            item_site[x] = dest
            load[dest] += float(sizes[x])
            wan_bytes += float(sizes[x])
        migrated.append(pending)

    migrated_arr = (
        np.concatenate(migrated) if migrated else np.zeros(0, dtype=np.int64)
    )
    return OfflineLayout(
        sites=retained_arr,
        item_site=item_site,
        migrated=migrated_arr,
        wan_bytes=wan_bytes,
        excluded=np.asarray(sorted(excluded)),
    )
