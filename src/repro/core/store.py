"""GeoGraphStore — the public facade of the GeoLayer system.

Ties together: layered-graph construction (§IV), overlap-centric replica
placement (§V), stepwise routing (§VI), cost accounting (§III) and the
update-maintenance strategy (§V "Update Maintenance"): periodic refresh from
access logs + incremental delete cleanup + heat-based eviction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import baselines
from .cost import CostBreakdown, PlacementState, check_constraints, total_cost
from .graph import Graph, build_csr
from .latency import GeoEnvironment
from .layered_graph import LayeredGraph, build_layered_graph
from .patterns import Pattern, Workload
from .placement import HeatCache, PlacementConfig, overlap_centric_placement
from .routing import OfflineLayout, RouteResult, route_offline, route_online

__all__ = ["GeoGraphStore", "StoreStats"]


@dataclasses.dataclass
class StoreStats:
    placement_stats: Dict[str, object]
    build_time_s: float
    placement_time_s: float


class GeoGraphStore:
    """Geo-distributed graph store with GeoLayer placement + routing.

    Strategy knobs allow the ablation grid of paper Fig. 16:
      placement in {"geolayer", "random", "top", "adp", "dcd"},
      routing   in {"stepwise", "random", "greedy"}.
    """

    def __init__(
        self,
        g: Graph,
        env: GeoEnvironment,
        workload: Workload,
        config: Optional[PlacementConfig] = None,
        placement: str = "geolayer",
        routing: str = "stepwise",
        latency_interval_s: float = 0.100,
        seed: int = 0,
    ) -> None:
        self.g = g
        self.env = env
        self.workload = workload
        self.config = config or PlacementConfig()
        self.placement_name = placement
        self.routing_name = routing
        t0 = time.perf_counter()
        self.lg: LayeredGraph = build_layered_graph(
            g, env, latency_interval_s=latency_interval_s
        )
        t1 = time.perf_counter()
        self.state, pstats = self._place(placement, seed)
        t2 = time.perf_counter()
        self._apply_routing(routing, seed)
        self.caches = {
            d: HeatCache(g, d, self.state, self.config.dhd) for d in range(env.n_dcs)
        }
        self.stats = StoreStats(
            placement_stats=pstats,
            build_time_s=t1 - t0,
            placement_time_s=t2 - t1,
        )

    # ------------------------------------------------------------ strategies
    def _place(self, name: str, seed: int) -> Tuple[PlacementState, Dict]:
        if name == "geolayer":
            return overlap_centric_placement(self.lg, self.workload, self.config)
        if name == "random":
            return (
                baselines.place_random_k(self.g, self.workload, self.env, seed=seed),
                {"baseline": "random-3"},
            )
        if name == "top":
            return (
                baselines.place_top_k(self.g, self.workload, self.env),
                {"baseline": "top-3"},
            )
        if name == "adp":
            return (
                baselines.place_adp(self.g, self.workload, self.env),
                {"baseline": "adp"},
            )
        if name == "dcd":
            return (
                baselines.place_dcd(self.g, self.workload, self.env),
                {"baseline": "dcd"},
            )
        raise ValueError(f"unknown placement {name!r}")

    def _apply_routing(self, name: str, seed: int) -> None:
        if name == "stepwise":
            # per-item table seeded nearest; pattern requests use route_online
            self.state.route_nearest(self.env, self.g.item_size())
        elif name == "random":
            baselines.route_random(self.state, self.workload, self.env, seed=seed)
        elif name == "greedy":
            baselines.route_greedy_set_cover(self.state, self.workload, self.env)
        else:
            raise ValueError(f"unknown routing {name!r}")

    # -------------------------------------------------------------- serving
    def serve_online(self, pattern: Pattern, origin: int) -> RouteResult:
        """Serve one online pattern request; returns the routing outcome."""
        if self.routing_name == "stepwise":
            res = route_online(self.lg, self.state, pattern.items, origin)
        else:
            res = self._route_by_table(pattern.items, origin)
        # record accesses into the origin's heat cache (Alg. 3 injection)
        self.caches[origin].observe(pattern.items, freq=1.0)
        return res

    def _route_by_table(self, items: np.ndarray, origin: int) -> RouteResult:
        sizes = self.g.item_size()
        served = self.state.route[items, origin].astype(np.int64)
        per_dc: Dict[int, float] = {}
        for dc in np.unique(served[served >= 0]):
            s_d = float(sizes[items[served == dc]].sum())
            per_dc[int(dc)] = self.env.request_latency(int(dc), origin, s_d)
        return RouteResult(
            served_by=served,
            dcs=np.unique(served[served >= 0]),
            latency_s=max(per_dc.values()) if per_dc else 0.0,
            per_dc_latency=per_dc,
            layers_used=0,
            n_missing=int((served < 0).sum()),
        )

    def plan_offline(
        self, required_items: np.ndarray, n_iters: int = 15, msg_bytes: float = 16.0
    ) -> OfflineLayout:
        return route_offline(
            self.lg, self.state, required_items, n_iters=n_iters, msg_bytes=msg_bytes
        )

    # ---------------------------------------------------------- maintenance
    def maintain(self, evict: bool = True, diffusion_steps: int = 4) -> Dict[str, int]:
        """Periodic maintenance: heat diffusion + cold-replica eviction
        (Alg. 3) and routing-table refresh."""
        evicted = 0
        for cache in self.caches.values():
            cache.step(n_steps=diffusion_steps)
            if evict:
                evicted += len(cache.evict())
        self.state.route_nearest(self.env, self.g.item_size())
        return {"evicted": evicted}

    def delete_items(self, item_ids: np.ndarray) -> None:
        """Bottom-up delete cleanup: drop all replicas everywhere (§V)."""
        self.state.delta[np.asarray(item_ids)] = False
        self.state.route[np.asarray(item_ids)] = -1

    def insert_patterns(self, new_patterns: Sequence[Pattern]) -> None:
        """Incremental update: materialize new access patterns and re-run
        placement for them (periodic refresh path of §V)."""
        self.workload = Workload.from_patterns(
            list(self.workload.patterns) + list(new_patterns),
            self.workload.n_items,
            self.workload.n_dcs,
        )
        self.state, pstats = self._place(self.placement_name, seed=0)
        self._apply_routing(self.routing_name, seed=0)
        self.stats.placement_stats = pstats

    # -------------------------------------------------------------- costing
    def cost(self) -> CostBreakdown:
        return total_cost(
            self.workload.patterns,
            self.state,
            self.workload.r_xy,
            self.workload.w_xy,
            self.g.item_size(),
            self.env,
            self.config.lambda1,
            self.config.lambda2,
        )

    def constraints(self, gamma_max_s: Optional[float] = None) -> Dict[str, bool]:
        return check_constraints(
            self.workload.patterns,
            self.state,
            self.workload.r_xy,
            self.g.item_size(),
            self.env,
            gamma_max_s or self.config.gamma_max_s,
        )
