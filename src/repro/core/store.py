"""GeoGraphStore — the public facade of the GeoLayer system.

Ties together: layered-graph construction (§IV), overlap-centric replica
placement (§V), stepwise routing (§VI), cost accounting (§III) and the
update-maintenance strategy (§V "Update Maintenance"): periodic refresh from
access logs + incremental delete cleanup + heat-based eviction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import baselines
from .cost import CostBreakdown, PlacementState, check_constraints, total_cost
from .graph import Graph, build_csr
from .latency import GeoEnvironment
from .layered_graph import LayeredGraph, build_layered_graph, repair_layered_graph
from .patterns import Pattern, Workload
from .placement import HeatCache, PlacementConfig, overlap_centric_placement
from .routing import OfflineLayout, RouteResult, route_offline, route_online

__all__ = ["GeoGraphStore", "StoreStats", "UpdateReport"]


@dataclasses.dataclass
class StoreStats:
    placement_stats: Dict[str, object]
    build_time_s: float
    placement_time_s: float


@dataclasses.dataclass
class UpdateReport:
    """Outcome of one ``apply_updates`` batch."""

    n_add_vertices: int
    n_del_vertices: int
    n_add_edges: int
    n_del_edges: int
    n_touched_vertices: int
    repair: object  # core.layered_graph.RepairStats
    heat: object  # streaming.delta_dhd.WarmStats
    apply_time_s: float


class GeoGraphStore:
    """Geo-distributed graph store with GeoLayer placement + routing.

    Strategy knobs allow the ablation grid of paper Fig. 16:
      placement in {"geolayer", "random", "top", "adp", "dcd"},
      routing   in {"stepwise", "random", "greedy"}.
    """

    def __init__(
        self,
        g: Graph,
        env: GeoEnvironment,
        workload: Workload,
        config: Optional[PlacementConfig] = None,
        placement: str = "geolayer",
        routing: str = "stepwise",
        latency_interval_s: float = 0.100,
        seed: int = 0,
    ) -> None:
        self.g = g
        self.env = env
        self.workload = workload
        self.config = config or PlacementConfig()
        self.placement_name = placement
        self.routing_name = routing
        t0 = time.perf_counter()
        self.lg: LayeredGraph = build_layered_graph(
            g, env, latency_interval_s=latency_interval_s
        )
        t1 = time.perf_counter()
        self.state, pstats = self._place(placement, seed)
        t2 = time.perf_counter()
        self._apply_routing(routing, seed)
        self.caches = {
            d: HeatCache(g, d, self.state, self.config.dhd) for d in range(env.n_dcs)
        }
        self.stats = StoreStats(
            placement_stats=pstats,
            build_time_s=t1 - t0,
            placement_time_s=t2 - t1,
        )
        # streaming-update state (lazily materialized on first apply_updates)
        self._delta_graph = None
        self._heat = None
        self._heat_scale = None

    # ------------------------------------------------------------ strategies
    def _place(self, name: str, seed: int) -> Tuple[PlacementState, Dict]:
        if name == "geolayer":
            return overlap_centric_placement(self.lg, self.workload, self.config)
        if name == "random":
            return (
                baselines.place_random_k(self.g, self.workload, self.env, seed=seed),
                {"baseline": "random-3"},
            )
        if name == "top":
            return (
                baselines.place_top_k(self.g, self.workload, self.env),
                {"baseline": "top-3"},
            )
        if name == "adp":
            return (
                baselines.place_adp(self.g, self.workload, self.env),
                {"baseline": "adp"},
            )
        if name == "dcd":
            return (
                baselines.place_dcd(self.g, self.workload, self.env),
                {"baseline": "dcd"},
            )
        raise ValueError(f"unknown placement {name!r}")

    def _apply_routing(self, name: str, seed: int) -> None:
        if name == "stepwise":
            # per-item table seeded nearest; pattern requests use route_online
            self.state.route_nearest(self.env)
        elif name == "random":
            baselines.route_random(self.state, self.workload, self.env, seed=seed)
        elif name == "greedy":
            baselines.route_greedy_set_cover(self.state, self.workload, self.env)
        else:
            raise ValueError(f"unknown routing {name!r}")

    # -------------------------------------------------------------- serving
    def serve_online(self, pattern: Pattern, origin: int) -> RouteResult:
        """Serve one online pattern request; returns the routing outcome."""
        if self.routing_name == "stepwise":
            res = route_online(self.lg, self.state, pattern.items, origin)
        else:
            res = self._route_by_table(pattern.items, origin)
        # record accesses into the origin's heat cache (Alg. 3 injection)
        self.caches[origin].observe(pattern.items, freq=1.0)
        return res

    def _route_by_table(self, items: np.ndarray, origin: int) -> RouteResult:
        sizes = self.g.item_size()
        served = self.state.route[items, origin].astype(np.int64)
        per_dc: Dict[int, float] = {}
        for dc in np.unique(served[served >= 0]):
            s_d = float(sizes[items[served == dc]].sum())
            per_dc[int(dc)] = self.env.request_latency(int(dc), origin, s_d)
        return RouteResult(
            served_by=served,
            dcs=np.unique(served[served >= 0]),
            latency_s=max(per_dc.values()) if per_dc else 0.0,
            per_dc_latency=per_dc,
            layers_used=0,
            n_missing=int((served < 0).sum()),
        )

    def plan_offline(
        self, required_items: np.ndarray, n_iters: int = 15, msg_bytes: float = 16.0
    ) -> OfflineLayout:
        return route_offline(
            self.lg, self.state, required_items, n_iters=n_iters, msg_bytes=msg_bytes
        )

    # ---------------------------------------------------------- maintenance
    def maintain(self, evict: bool = True, diffusion_steps: int = 4) -> Dict[str, int]:
        """Periodic maintenance: heat diffusion + cold-replica eviction
        (Alg. 3) and routing-table refresh."""
        evicted = 0
        for cache in self.caches.values():
            cache.step(n_steps=diffusion_steps)
            if evict:
                evicted += len(cache.evict())
        self.state.route_nearest(self.env)
        return {"evicted": evicted}

    def delete_items(self, item_ids: np.ndarray) -> None:
        """Bottom-up delete cleanup: drop all replicas everywhere (§V)."""
        self.state.delta[np.asarray(item_ids)] = False
        self.state.route[np.asarray(item_ids)] = -1

    def insert_patterns(self, new_patterns: Sequence[Pattern]) -> None:
        """Incremental update: materialize new access patterns and re-run
        placement for them (periodic refresh path of §V)."""
        self.workload = Workload.from_patterns(
            list(self.workload.patterns) + list(new_patterns),
            self.workload.n_items,
            self.workload.n_dcs,
        )
        self.state, pstats = self._place(self.placement_name, seed=0)
        self._apply_routing(self.routing_name, seed=0)
        self.stats.placement_stats = pstats

    # ---------------------------------------------------- streaming updates
    def _heat_inputs(self):
        """(alive edge ids, edge weights, vertex sources) for streaming DHD.

        Normalization scales are frozen at first use: the warm path only
        rewrites *touched* ELL rows, so renormalizing by the current max each
        batch would leave untouched rows on a stale scale and the field would
        drift from any cold rebuild."""
        g = self.g
        alive_e = (
            np.where(self._delta_graph.edge_alive)[0]
            if self._delta_graph is not None
            else np.arange(g.n_edges)
        )
        w_e = self.workload.r_xy[g.n_nodes:].sum(axis=1)[alive_e].astype(np.float32)
        r_v = self.workload.r_xy[: g.n_nodes].sum(axis=1).astype(np.float32)
        if self._heat_scale is None:
            self._heat_scale = (
                max(float(w_e.max()) if len(w_e) else 1.0, 1.0),
                max(float(r_v.max()), 1e-12),
            )
        w_scale, q_scale = self._heat_scale
        return alive_e, w_e / w_scale + 1e-3, r_v / q_scale

    def _grow_item_rows(self, a: np.ndarray, old_n: int, nv: int, ne: int, fill) -> np.ndarray:
        """Insert rows for new vertices (mid) and new edges (end) into an
        item-indexed [I, D] array, preserving the v | e id layout."""
        mid = np.full((nv, a.shape[1]), fill, dtype=a.dtype)
        end = np.full((ne, a.shape[1]), fill, dtype=a.dtype)
        return np.concatenate([a[:old_n], mid, a[old_n:], end])

    def apply_updates(self, batch) -> UpdateReport:
        """Absorb one :class:`~repro.streaming.MutationBatch` incrementally.

        Instead of the full rebuild path (``build_layered_graph`` +
        ``overlap_centric_placement`` + global reroute) this: grows the
        delta-CSR overlay, repairs only the invalidated latency layers,
        deposits primary replicas for new items / purges dead ones, reroutes
        exactly the touched rows, and warm-starts DHD from the previous
        equilibrium.  Replica migration is deferred to
        :meth:`flush_migrations` so bursts of batches amortize one move-set.
        """
        from ..streaming.delta_dhd import StreamingHeat
        from ..streaming.migration import _reroute_items
        from ..streaming.mutation_log import DeltaGraph

        t0 = time.perf_counter()
        if self._delta_graph is None:
            self._delta_graph = DeltaGraph(self.g)
        dg = self._delta_graph
        if batch.n_ops == 0:  # no-op batch: skip repair/heat entirely
            return UpdateReport(0, 0, 0, 0, 0, None, None, time.perf_counter() - t0)
        res = dg.apply(batch)
        g2 = dg.g
        old_n = res.old_n_nodes
        nv, ne = res.n_new_vertices, len(res.new_edge_ids)

        # --- remap item-indexed state to the shifted id space -------------
        self.state.delta = self._grow_item_rows(self.state.delta, old_n, nv, ne, False)
        self.state.route = self._grow_item_rows(self.state.route, old_n, nv, ne, -1)
        wl = self.workload
        r2 = self._grow_item_rows(wl.r_xy, old_n, nv, ne, 0.0)
        w2 = self._grow_item_rows(wl.w_xy, old_n, nv, ne, 0.0)
        dead_items = res.dead_item_ids(g2.n_nodes)
        dead_mask = np.zeros(g2.n_items, dtype=bool)
        dead_mask[dead_items] = True
        pats = []
        for p in wl.patterns:
            items = res.remap_items(p.items)
            items = items[~dead_mask[items]]
            pats.append(Pattern(pid=p.pid, items=items, r_py=p.r_py, w_py=p.w_py, eta=p.eta))
        self.workload = Workload(
            patterns=pats, n_items=g2.n_items, n_dcs=wl.n_dcs, r_xy=r2, w_xy=w2
        )
        for cache in self.caches.values():
            cache.g = g2
            cache.edge_mask = dg.edge_alive
            cache.heat = np.concatenate(
                [cache.heat[:old_n], np.zeros(nv, np.float32),
                 cache.heat[old_n:], np.zeros(ne, np.float32)]
            )
        self.g = g2

        # --- incremental layered-graph repair ----------------------------
        self.lg, rstats = repair_layered_graph(self.lg, g2, dg.edge_alive)

        # --- primaries for new items, bottom-up delete cleanup -----------
        if nv:
            self.state.delta[res.new_vertex_ids, g2.partition[res.new_vertex_ids]] = True
        if ne:
            e = res.new_edge_ids
            self.state.delta[g2.n_nodes + e, g2.partition[g2.src[e]]] = True
        self.state.delta[dead_items] = False
        self.state.route[dead_items] = -1
        r2[dead_items] = 0.0
        w2[dead_items] = 0.0

        # --- reroute only the rows whose replica sets changed -------------
        changed = np.unique(np.concatenate([res.new_item_ids(g2.n_nodes), dead_items]))
        _reroute_items(self.state, self.env, changed)

        # --- warm-start DHD over the alive topology -----------------------
        # Migration planning only *ranks* items by heat, so the store runs a
        # bounded relaxation budget per batch instead of iterating to full
        # tolerance: the field stays continuously near-equilibrium across the
        # batch stream (any leftover residual is worked off by later batches).
        # The StreamingHeat defaults remain exact for standalone users.
        if self._heat is None:
            self._heat = StreamingHeat(tol=1e-5, max_iters=32)
        alive_e, w_e, q = self._heat_inputs()
        hstats = self._heat.update(
            g2.n_nodes, g2.src[alive_e], g2.dst[alive_e], w_e, q,
            touched=res.touched_vertices,
        )
        return UpdateReport(
            n_add_vertices=nv,
            n_del_vertices=len(res.dead_vertex_ids),
            n_add_edges=ne,
            n_del_edges=len(res.dead_edge_ids),
            n_touched_vertices=len(res.touched_vertices),
            repair=rstats,
            heat=hstats,
            apply_time_s=time.perf_counter() - t0,
        )

    def flush_migrations(self, budget_bytes: Optional[float] = None, **kw):
        """Plan + apply the cost-bounded replica move-set for the heat drift
        accumulated since the last flush.  Returns the
        :class:`~repro.streaming.MigrationPlan` (with ``rolled_back`` set if
        the constraint guard reverted drops)."""
        from ..streaming.delta_dhd import StreamingHeat
        from ..streaming.migration import apply_plan, plan_migrations

        sizes = self.g.item_size()
        if budget_bytes is None:
            budget_bytes = 0.05 * float(sizes.sum())
        if self._heat is None or self._heat.heat is None:
            # never churned: cold-solve the equilibrium once
            self._heat = StreamingHeat()
            alive_e, w_e, q = self._heat_inputs()
            self._heat.rebuild(self.g.n_nodes, self.g.src[alive_e], self.g.dst[alive_e], w_e, q)
        vheat = self._heat.vertex_heat
        eheat = 0.5 * (vheat[self.g.src] + vheat[self.g.dst])
        if self._delta_graph is not None:
            item_alive = np.concatenate(
                [self._delta_graph.node_alive, self._delta_graph.edge_alive]
            )
        else:
            item_alive = np.ones(self.g.n_items, dtype=bool)
        item_heat = np.concatenate([vheat, eheat]) * item_alive
        plan = plan_migrations(
            self.g, self.env, self.state, self.workload.r_xy, self.workload.w_xy,
            item_heat, budget_bytes, item_alive=item_alive, **kw,
        )
        apply_plan(
            plan, self.state, self.env, self.workload.patterns,
            self.workload.r_xy, sizes, self.config.gamma_max_s,
        )
        return plan

    # -------------------------------------------------------------- costing
    def cost(self) -> CostBreakdown:
        return total_cost(
            self.workload.patterns,
            self.state,
            self.workload.r_xy,
            self.workload.w_xy,
            self.g.item_size(),
            self.env,
            self.config.lambda1,
            self.config.lambda2,
        )

    def constraints(self, gamma_max_s: Optional[float] = None) -> Dict[str, bool]:
        return check_constraints(
            self.workload.patterns,
            self.state,
            self.workload.r_xy,
            self.g.item_size(),
            self.env,
            gamma_max_s or self.config.gamma_max_s,
        )
