"""GeoGraphStore — the public facade of the GeoLayer system.

Ties together: layered-graph construction (§IV), overlap-centric replica
placement (§V), stepwise routing (§VI), cost accounting (§III) and the
update-maintenance strategy (§V "Update Maintenance"): periodic refresh from
access logs + incremental delete cleanup + heat-based eviction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import baselines
from ..demand import ODDemandLayer
from ..obs import Tracer, get_registry
from .cost import CostBreakdown, PlacementState, check_constraints, total_cost
from .graph import Graph, grow_item_rows
from .latency import GeoEnvironment
from .layered_graph import LayeredGraph, build_layered_graph, repair_layered_graph
from .patterns import Pattern, Workload
from .placement import (
    HeatCache,
    PlacementConfig,
    PlacementJournal,
    overlap_centric_placement,
    step_heat_caches,
)
from .route_index import RouteIndex
from .routing import (
    OfflineLayout,
    RouteResult,
    route_offline,
    route_online,
    route_online_batch,
)

__all__ = ["GeoGraphStore", "StoreStats", "UpdateReport"]


@dataclasses.dataclass
class StoreStats:
    placement_stats: Dict[str, object]
    build_time_s: float
    placement_time_s: float


@dataclasses.dataclass
class UpdateReport:
    """Outcome of one ``apply_updates`` batch."""

    n_add_vertices: int
    n_del_vertices: int
    n_add_edges: int
    n_del_edges: int
    n_touched_vertices: int
    repair: object  # core.layered_graph.RepairStats
    heat: object  # streaming.delta_dhd.WarmStats
    apply_time_s: float
    compacted: bool = False  # tombstone-ratio compaction fired this batch

    @property
    def heat_residual(self) -> float:
        """Staleness carried over by the budgeted warm DHD solve: the sup-norm
        change one more sweep would make.  ~0 means the field is at its
        equilibrium; larger values mean later batches / ``maintain()`` still
        owe relaxation work (the operator-visible drift metric)."""
        return float(getattr(self.heat, "residual", 0.0) or 0.0)


class GeoGraphStore:
    """Geo-distributed graph store with GeoLayer placement + routing.

    The **data-plane kernel** of the system: placement state, routing
    tables, heat fields and their incremental maintenance primitives
    (``serve_batch`` / ``apply_updates`` / ``plan_flush`` + ``begin_flush``
    / ``maintain`` / ``compact``).  *Policy* — when to drain, how large a
    batch, when to run maintenance, how wide a migration window — lives in
    the serving control plane (:mod:`repro.serve`: ``StoreClient`` →
    ``AdmissionController`` → this store → ``MaintenancePolicy``).

    Strategy knobs allow the ablation grid of paper Fig. 16:
      placement in {"geolayer", "random", "top", "adp", "dcd"},
      routing   in {"stepwise", "random", "greedy"}.
    """

    def __init__(
        self,
        g: Graph,
        env: GeoEnvironment,
        workload: Workload,
        config: Optional[PlacementConfig] = None,
        placement: str = "geolayer",
        routing: str = "stepwise",
        latency_interval_s: float = 0.100,
        seed: int = 0,
        compact_ratio: float = 0.30,
        tracer: Optional[Tracer] = None,
        registry=None,
        demand_window_s: float = 60.0,
    ) -> None:
        self.g = g
        self.env = env
        self.workload = workload
        self.config = config or PlacementConfig()
        self.placement_name = placement
        self.routing_name = routing
        self.compact_ratio = compact_ratio
        # telemetry: wall-clock spans for data-plane work (the control plane
        # runs its own sim-clock tracer — the two clock domains never mix in
        # one export).  Default tracer/registry follow the process default:
        # both short-circuit to no-ops until telemetry is enabled.
        self.tracer = tracer if tracer is not None else Tracer(clock=time.perf_counter)
        self._registry = registry
        # wall-clock seconds of the last serve_batch routing pass: the
        # admission controller's "measured" service model charges this as
        # router occupancy instead of the linear Eq. 1 occupancy constants
        self.last_serve_seconds = 0.0
        self.route_index: Optional[RouteIndex] = None
        # content-stable uid per item row: assigned monotonically at birth,
        # row-selected (never renumbered) on compaction.  Placement-journal
        # fingerprints digest uids instead of raw rows, so memo keys survive
        # the compaction renumbering.
        self._item_uid = np.arange(g.n_items, dtype=np.int64)
        self._next_uid = int(g.n_items)
        # bumped on every id-space change (mutation batch, compaction);
        # begin_flush captures it so a WaveApplier outlives neither
        self._id_epoch = 0
        # compaction listeners: called with imap (old row -> new row, -1 =
        # dropped) after the store has fully re-keyed itself, so holders of
        # raw item rows (e.g. an AdmissionController's in-flight request
        # handles) can remap instead of dangling
        self._remap_listeners: List = []
        # memo of placement intermediates; populated by every geolayer
        # placement run, replayed by insert_patterns_incremental, remapped
        # in place across compaction, discarded on topology mutations
        self._placement_journal = self._fresh_journal()
        with self.tracer.span("store.build_layered_graph", track="store") as sp_build:
            self.lg: LayeredGraph = build_layered_graph(
                g, env, latency_interval_s=latency_interval_s
            )
        with self.tracer.span("store.place", track="store", strategy=placement) as sp_place:
            self.state, pstats = self._place(placement, seed)
        with self.tracer.span("store.route", track="store", strategy=routing):
            self._apply_routing(routing, seed)
        # demand plane: single owner of online request heat.  Every per-DC
        # HeatCache reads its row of the [D, I] table as a view — the serving
        # path deposits heat exactly once, there is no per-cache copy to
        # double-book (ISSUE 9 single-ownership invariant).
        self.demand = ODDemandLayer(
            g.n_items, env.n_dcs, window_s=demand_window_s, registry=registry
        )
        self.caches = {
            d: HeatCache(g, d, self.state, self.config.dhd, demand=self.demand)
            for d in range(env.n_dcs)
        }
        self.stats = StoreStats(
            placement_stats=pstats,
            build_time_s=sp_build.elapsed_s(),
            placement_time_s=sp_place.elapsed_s(),
        )
        # streaming-update state (lazily materialized on first apply_updates)
        self._delta_graph = None
        self._heat = None
        self._heat_scale = None

    # ------------------------------------------------------------- telemetry
    def _reg(self):
        """Explicit registry if one was injected, else the process default."""
        return self._registry if self._registry is not None else get_registry()

    # ------------------------------------------------------------ strategies
    def _fresh_journal(self) -> PlacementJournal:
        j = PlacementJournal()
        j.item_uid = self._item_uid
        return j

    def _place(self, name: str, seed: int, route: bool = True) -> Tuple[PlacementState, Dict]:
        if name == "geolayer":
            return overlap_centric_placement(
                self.lg, self.workload, self.config,
                journal=self._placement_journal, route=route,
            )
        if name == "random":
            return (
                baselines.place_random_k(self.g, self.workload, self.env, seed=seed),
                {"baseline": "random-3"},
            )
        if name == "top":
            return (
                baselines.place_top_k(self.g, self.workload, self.env),
                {"baseline": "top-3"},
            )
        if name == "adp":
            return (
                baselines.place_adp(self.g, self.workload, self.env),
                {"baseline": "adp"},
            )
        if name == "dcd":
            return (
                baselines.place_dcd(self.g, self.workload, self.env),
                {"baseline": "dcd"},
            )
        raise ValueError(f"unknown placement {name!r}")

    def _apply_routing(self, name: str, seed: int) -> None:
        self.route_index = None
        if name == "stepwise":
            # per-item table seeded nearest; pattern requests use route_online.
            # The RouteIndex owns the table from here on: ``state.route``
            # aliases ``index.nearest`` so incremental patches are visible to
            # every consumer without copies.
            self.route_index = RouteIndex.build(self.state.delta, self.env)
            self.state.route = self.route_index.nearest
        elif name == "random":
            baselines.route_random(self.state, self.workload, self.env, seed=seed)
        elif name == "greedy":
            baselines.route_greedy_set_cover(self.state, self.workload, self.env)
        else:
            raise ValueError(f"unknown routing {name!r}")

    # -------------------------------------------------------------- serving
    def serve_online(self, pattern: Pattern, origin: int) -> RouteResult:
        """Serve one online pattern request; returns the routing outcome."""
        if self.routing_name == "stepwise":
            res = route_online(self.lg, self.state, pattern.items, origin)
        else:
            res = self._route_by_table(pattern.items, origin)
        # record the access into the demand plane (Alg. 3 injection: the
        # origin's heat-cache row is a view of the same table)
        self.demand.observe(pattern.items, origin=origin, freq=1.0)
        return res

    def serve_batch(
        self,
        requests: Sequence[Tuple[object, int]],
        observe: bool = True,
    ) -> List[RouteResult]:
        """Serve a whole batch of online requests in one vectorized pass.

        ``requests`` is a sequence of ``(pattern_or_items, origin)`` pairs;
        results align with the input order and match ``serve_online``
        request-for-request.  Stepwise routing resolves the batch through
        :func:`route_online_batch` (flat ``[R, I]`` array ops per layer);
        table-driven strategies fall back to per-request table lookups.
        """
        norm: List[Tuple[np.ndarray, int]] = []
        for req, origin in requests:
            items = req.items if isinstance(req, Pattern) else np.asarray(req)
            norm.append((items, int(origin)))
        t_serve = time.perf_counter()
        with self.tracer.span("store.serve_batch", track="store", size=len(norm)):
            if self.routing_name == "stepwise":
                # serving.* counters/histograms are emitted batch-granular
                # inside route_online_batch, where the flat arrays live
                results = route_online_batch(
                    self.lg, self.state, norm, registry=self._registry
                )
            else:
                results = [self._route_by_table(it, o) for it, o in norm]
                reg = self._reg()
                if reg.enabled and results:
                    self._observe_serving(reg, norm, results)
        self.last_serve_seconds = time.perf_counter() - t_serve
        if observe and norm:
            # heat injection grouped per origin inside the demand plane: one
            # scatter per DC touched, accumulated exactly once
            self.demand.observe_requests(norm)
        return results

    def _observe_serving(self, reg, norm, results: List[RouteResult]) -> None:
        """Serving-path counters for the table-driven fallback strategies
        (the stepwise hot path emits these vectorized inside
        :func:`route_online_batch`).  Per-link bytes are reconstructed from
        Eq. 1 — the route result already paid for ``per_dc_latency``, so
        ``(lat - rtt) * bw`` recovers each serving DC's byte volume with
        scalar math (no re-aggregation of the batch)."""
        reg.counter("serving.requests").inc(len(results))
        env = self.env
        wan_total = 0.0
        by_link: Dict[Tuple[int, int], float] = {}
        for (_, origin), r in zip(norm, results):
            wan_total += r.wan_bytes
            if r.wan_bytes <= 0.0:
                continue
            for dc, lat in r.per_dc_latency.items():
                if dc == origin:
                    continue
                nbytes = (lat - env.rtt_s[dc, origin]) * env.bw_Bps[dc, origin]
                key = (dc, origin)
                by_link[key] = by_link.get(key, 0.0) + nbytes
        reg.counter("serving.wan_bytes").inc(wan_total)
        for (src, dst), nbytes in by_link.items():
            reg.counter("serving.wan_bytes_link", src=src, dst=dst).inc(nbytes)
        lat_h = reg.histogram("serving.request_latency_s")
        for r in results:
            lat_h.observe(r.latency_s)

    def _route_by_table(self, items: np.ndarray, origin: int) -> RouteResult:
        sizes = self.g.item_size()
        served = self.state.route[items, origin].astype(np.int64)
        per_dc: Dict[int, float] = {}
        wan = 0.0
        for dc in np.unique(served[served >= 0]):
            s_d = float(sizes[items[served == dc]].sum())
            per_dc[int(dc)] = self.env.request_latency(int(dc), origin, s_d)
            if int(dc) != origin:
                wan += s_d
        return RouteResult(
            served_by=served,
            dcs=np.unique(served[served >= 0]),
            latency_s=max(per_dc.values()) if per_dc else 0.0,
            per_dc_latency=per_dc,
            layers_used=0,
            n_missing=int((served < 0).sum()),
            wan_bytes=wan,
        )

    def plan_offline(
        self, required_items: np.ndarray, n_iters: int = 15, msg_bytes: float = 16.0
    ) -> OfflineLayout:
        return route_offline(
            self.lg, self.state, required_items, n_iters=n_iters, msg_bytes=msg_bytes
        )

    # ---------------------------------------------------------- maintenance
    def _resync_route_index(self) -> None:
        """Re-adopt the routing table if external code orphaned the alias.

        A direct full ``state.route_nearest(env)`` *replaces* ``state.route``
        with a fresh array, silently detaching it from ``route_index.nearest``.
        Stepwise routing's invariant is nearest-replica routing, so the index
        re-derives from the placement and takes ownership back."""
        if self.route_index is not None and self.state.route is not self.route_index.nearest:
            self.route_index.rebuild(self.state.delta)
            self.state.route = self.route_index.nearest

    def maintain(self, evict: bool = True, diffusion_steps: int = 4) -> Dict[str, float]:
        """Periodic maintenance: heat diffusion + cold-replica eviction
        (Alg. 3), routing refresh, and working off any warm-DHD residual.

        With a :class:`RouteIndex` the eviction refresh patches only the rows
        whose replica sets actually shrank; the legacy path re-derives the
        whole table."""
        with self.tracer.span("store.maintain", track="store"):
            self._resync_route_index()
            evicted = 0
            # all per-DC caches share one topology -> ONE batched diffusion
            step_heat_caches(list(self.caches.values()), n_steps=diffusion_steps)
            for dc, cache in self.caches.items():
                if evict:
                    ids = cache.evict()
                    evicted += len(ids)
                    if self.route_index is not None:
                        self.route_index.drop_replicas(self.state.delta, ids, dc)
            if self.route_index is None:
                self.state.route_nearest(self.env)
            residual = 0.0
            if self._heat is not None and self._heat.heat is not None:
                # budgeted apply_updates sweeps may leave the heat field short
                # of equilibrium; the maintenance window pays that debt down
                self._heat.solve()
                residual = self._heat.residual
            return {"evicted": evicted, "heat_residual": residual}

    def demand_view(self):
        """Measured demand-plane view (:class:`~repro.demand.DemandView`) —
        the same planner coordinates ``ODDemandLayer.forecast()`` produces,
        so measured and predicted demand flow through one code path."""
        return self.demand.measured()

    def precache(
        self,
        item_heat: Optional[np.ndarray] = None,
        theta_quantile: Optional[float] = None,
        max_per_dc: Optional[int] = None,
    ) -> np.ndarray:
        """Demand-driven DHD pre-caching (§V), online flavor.

        Seeds :func:`~repro.core.placement.precache_hot_regions` from the
        demand plane: an injected ``item_heat`` (e.g. a forecast view's) if
        given, else the measured demand view, else — before any traffic —
        the static workload tables (the placement-time default).  Newly
        added replicas are patched into the route index; returns the item
        rows whose replica sets changed."""
        from .placement import precache_hot_regions

        self._resync_route_index()
        intensity = item_heat
        if intensity is None:
            measured = self.demand.measured().item_heat
            if float(measured.max(initial=0.0)) > 0.0:
                intensity = measured
        before = self.state.delta.copy()
        precache_hot_regions(
            self.g, self.workload, self.state,
            self.config.theta_quantile if theta_quantile is None else theta_quantile,
            self.config.dhd,
            max_per_dc=(
                self.config.precache_max_per_dc if max_per_dc is None else max_per_dc
            ),
            read_intensity=intensity,
        )
        changed = np.where((self.state.delta != before).any(axis=1))[0]
        if len(changed):
            if self.route_index is not None:
                self.route_index.patch_rows(self.state.delta, changed)
            else:
                from ..streaming.migration import _reroute_items

                _reroute_items(self.state, self.env, changed)
        return changed

    def delete_items(self, item_ids: np.ndarray) -> None:
        """Bottom-up delete cleanup: drop all replicas everywhere (§V)."""
        self._resync_route_index()
        ids = np.asarray(item_ids)
        self.state.delta[ids] = False
        if self.route_index is not None:
            self.route_index.clear_rows(ids)
        else:
            self.state.route[ids] = -1

    def insert_patterns(self, new_patterns: Sequence[Pattern]) -> None:
        """Full refresh: materialize new access patterns and re-run placement
        and routing from scratch (periodic refresh path of §V).

        The journal is reset first so this really is a cold re-place (and is
        freshly populated for later incremental inserts).  Heat caches are
        re-pointed at the new :class:`PlacementState`."""
        self.workload = Workload.from_patterns(
            list(self.workload.patterns) + list(new_patterns),
            self.workload.n_items,
            self.workload.n_dcs,
        )
        self._placement_journal = self._fresh_journal()
        self.state, pstats = self._place(self.placement_name, seed=0)
        self._apply_routing(self.routing_name, seed=0)
        for cache in self.caches.values():
            cache.state = self.state
        self.stats.placement_stats = pstats

    def insert_patterns_incremental(
        self, new_patterns: Sequence[Pattern]
    ) -> Dict[str, object]:
        """Absorb new access patterns without the full re-place.

        Replays Algorithms 1+2 over the extended workload *through the
        placement journal*: pools the new patterns never touch are journal
        hits (their decomposition, region adjacency and batched DHD heat
        tables are replayed, not recomputed), so only the affected BSs/pools
        pay compute.  The resulting replica sets are identical to
        :meth:`insert_patterns` by construction — same deterministic control
        flow, memoized intermediates keyed on exact inputs.  The deltas are
        then patched **in place**: ``state.delta`` rows are updated (the
        :class:`PlacementState` object and its aliases survive) and only the
        changed rows of the :class:`RouteIndex` are re-derived.

        Returns a report dict (changed rows, journal hit/miss counters,
        wall time).  Non-geolayer placements have no incremental structure
        to exploit, and non-stepwise routing policies (random/greedy) derive
        their whole table from the final placement — both fall back to
        :meth:`insert_patterns` so the routing policy is never silently
        mixed with nearest-replica patches.
        """
        if self.placement_name != "geolayer" or self.routing_name != "stepwise":
            self.insert_patterns(new_patterns)
            return {"fallback": "full", "n_new": len(new_patterns)}
        with self.tracer.span(
            "store.insert_patterns_incremental", track="store",
            n_new=len(new_patterns),
        ) as root:
            self.workload = Workload.from_patterns(
                list(self.workload.patterns) + list(new_patterns),
                self.workload.n_items,
                self.workload.n_dcs,
            )
            j = self._placement_journal
            hits0, miss0 = j.hits, j.misses
            with self.tracer.span("store.replay_placement", track="store"):
                new_state, pstats = self._place(
                    self.placement_name, seed=0, route=False
                )
            changed = np.where((new_state.delta != self.state.delta).any(axis=1))[0]
            self.state.delta[changed] = new_state.delta[changed]
            with self.tracer.span(
                "store.patch_routes", track="store", rows=int(len(changed))
            ):
                if self.route_index is not None:
                    self._resync_route_index()
                    self.route_index.patch_rows(self.state.delta, changed)
                else:
                    from ..streaming.migration import _reroute_items

                    _reroute_items(self.state, self.env, changed)
            self.stats.placement_stats = pstats
            return {
                "n_new": len(new_patterns),
                "rows_changed": int(len(changed)),
                "journal_hits": j.hits - hits0,
                "journal_misses": j.misses - miss0,
                "apply_time_s": root.elapsed_s(),
            }

    # ---------------------------------------------------- streaming updates
    def _heat_inputs(self):
        """(alive edge ids, edge weights, vertex sources) for streaming DHD.

        Normalization scales are frozen at first use: the warm path only
        rewrites *touched* ELL rows, so renormalizing by the current max each
        batch would leave untouched rows on a stale scale and the field would
        drift from any cold rebuild."""
        g = self.g
        alive_e = (
            np.where(self._delta_graph.edge_alive)[0]
            if self._delta_graph is not None
            else np.arange(g.n_edges)
        )
        w_e = self.workload.r_xy[g.n_nodes:].sum(axis=1)[alive_e].astype(np.float32)
        r_v = self.workload.r_xy[: g.n_nodes].sum(axis=1).astype(np.float32)
        if self._heat_scale is None:
            self._heat_scale = (
                max(float(w_e.max()) if len(w_e) else 1.0, 1.0),
                max(float(r_v.max()), 1e-12),
            )
        w_scale, q_scale = self._heat_scale
        return alive_e, w_e / w_scale + 1e-3, r_v / q_scale

    def _grow_item_rows(self, a: np.ndarray, old_n: int, nv: int, ne: int, fill) -> np.ndarray:
        """Item-indexed row growth through the one shared id-layout encoding
        (:func:`repro.core.graph.grow_item_rows`)."""
        return grow_item_rows(a, old_n, nv, ne, fill)

    def apply_updates(self, batch) -> UpdateReport:
        """Absorb one :class:`~repro.streaming.MutationBatch` incrementally.

        Instead of the full rebuild path (``build_layered_graph`` +
        ``overlap_centric_placement`` + global reroute) this: grows the
        delta-CSR overlay, repairs only the invalidated latency layers,
        deposits primary replicas for new items / purges dead ones, reroutes
        exactly the touched rows, and warm-starts DHD from the previous
        equilibrium.  Replica migration is deferred to
        :meth:`flush_migrations` so bursts of batches amortize one move-set.
        """
        root = self.tracer.span(
            "store.apply_updates", track="store", n_ops=int(batch.n_ops)
        )
        try:
            return self._apply_updates_traced(batch, root)
        finally:
            root.end()

    def _apply_updates_traced(self, batch, root) -> UpdateReport:
        from ..streaming.delta_dhd import StreamingHeat
        from ..streaming.migration import _reroute_items
        from ..streaming.mutation_log import DeltaGraph

        self._resync_route_index()
        if self._delta_graph is None:
            self._delta_graph = DeltaGraph(self.g)
        dg = self._delta_graph
        if batch.n_ops == 0:  # no-op batch: skip repair/heat entirely
            return UpdateReport(0, 0, 0, 0, 0, None, None, root.elapsed_s())
        # mutations change the edge topology -> journaled region adjacency
        # and heat tables die (the id shift alone would be survivable now
        # that fingerprints run over uids, but the topology change is not)
        self._id_epoch += 1  # id space shifts; in-flight flushes go stale
        res = dg.apply(batch)
        g2 = dg.g
        old_n = res.old_n_nodes
        nv, ne = res.n_new_vertices, len(res.new_edge_ids)

        # --- remap item-indexed state to the shifted id space -------------
        self._item_uid = self._grow_item_rows(self._item_uid, old_n, nv, ne, -1)
        born = np.where(self._item_uid < 0)[0]
        self._item_uid[born] = np.arange(
            self._next_uid, self._next_uid + len(born), dtype=np.int64
        )
        self._next_uid += len(born)
        self._placement_journal = self._fresh_journal()
        self.state.delta = self._grow_item_rows(self.state.delta, old_n, nv, ne, False)
        if self.route_index is None:
            self.state.route = self._grow_item_rows(self.state.route, old_n, nv, ne, -1)
        wl = self.workload
        r2 = self._grow_item_rows(wl.r_xy, old_n, nv, ne, 0.0)
        w2 = self._grow_item_rows(wl.w_xy, old_n, nv, ne, 0.0)
        dead_items = res.dead_item_ids(g2.n_nodes)
        dead_mask = np.zeros(g2.n_items, dtype=bool)
        dead_mask[dead_items] = True
        pats = []
        for p in wl.patterns:
            items = res.remap_items(p.items)
            items = items[~dead_mask[items]]
            pats.append(Pattern(pid=p.pid, items=items, r_py=p.r_py, w_py=p.w_py, eta=p.eta))
        self.workload = Workload(
            patterns=pats, n_items=g2.n_items, n_dcs=wl.n_dcs, r_xy=r2, w_xy=w2
        )
        # the demand plane grows all its item-indexed tables once; the
        # caches' heat rows are views and follow automatically
        self.demand.grow_items(old_n, nv, ne)
        for cache in self.caches.values():
            cache.g = g2
            cache.edge_mask = dg.edge_alive
        self.g = g2

        # --- incremental layered-graph repair ----------------------------
        with self.tracer.span("store.repair_layers", track="store"):
            self.lg, rstats = repair_layered_graph(self.lg, g2, dg.edge_alive)

        # --- primaries for new items, bottom-up delete cleanup -----------
        if nv:
            self.state.delta[res.new_vertex_ids, g2.partition[res.new_vertex_ids]] = True
        if ne:
            e = res.new_edge_ids
            self.state.delta[g2.n_nodes + e, g2.partition[g2.src[e]]] = True
        self.state.delta[dead_items] = False
        if self.route_index is None:
            self.state.route[dead_items] = -1
        r2[dead_items] = 0.0
        w2[dead_items] = 0.0

        # --- reroute only the rows whose replica sets changed -------------
        changed = np.unique(np.concatenate([res.new_item_ids(g2.n_nodes), dead_items]))
        with self.tracer.span(
            "store.reroute", track="store", rows=int(len(changed))
        ):
            if self.route_index is not None:
                # the index grows its own rows (edge block shifts by nv),
                # clears the tombstoned ones and derives exactly the changed
                # rows
                self.route_index.apply_batch(
                    self.state.delta, old_n, nv, ne, changed, dead_items
                )
                self.state.route = self.route_index.nearest
            else:
                _reroute_items(self.state, self.env, changed)

        # --- warm-start DHD over the alive topology -----------------------
        # Migration planning only *ranks* items by heat, so the store runs a
        # bounded relaxation budget per batch instead of iterating to full
        # tolerance: the field stays continuously near-equilibrium across the
        # batch stream (any leftover residual is worked off by later batches).
        # The StreamingHeat defaults remain exact for standalone users.
        if self._heat is None:
            self._heat = StreamingHeat(tol=1e-5, max_iters=32)
        alive_e, w_e, q = self._heat_inputs()
        with self.tracer.span("store.warm_heat", track="store"):
            hstats = self._heat.update(
                g2.n_nodes, g2.src[alive_e], g2.dst[alive_e], w_e, q,
                touched=res.touched_vertices,
            )

        # --- notify raw-row holders of the id-space shift -----------------
        # Vertex inserts shift every edge-item row by nv; queued request
        # handles (and any other subscriber) re-key through the same growth
        # map the store's own state grew through, with tombstoned rows
        # dropped.  Fired before the compaction trigger below so a
        # same-batch compaction sees subscribers already in the post-growth
        # id space and its own imap composes cleanly.
        if self._remap_listeners:
            old_n_items = old_n + (g2.n_edges - ne)
            imap_g = np.empty(old_n_items, dtype=np.int64)
            imap_g[:old_n] = np.arange(old_n)
            imap_g[old_n:] = old_n + nv + np.arange(old_n_items - old_n)
            imap_g[dead_mask[imap_g]] = -1
            self._fire_remap_listeners(imap_g)

        # --- tombstone-ratio compaction trigger ---------------------------
        # The delta overlay grows without bound otherwise: tombstoned rows
        # keep occupying every [I, D] array and every ELL row forever.
        compacted = False
        if self.tombstone_ratio() >= self.compact_ratio:
            self._compact_in_place()
            compacted = True
        return UpdateReport(
            n_add_vertices=nv,
            n_del_vertices=len(res.dead_vertex_ids),
            n_add_edges=ne,
            n_del_edges=len(res.dead_edge_ids),
            n_touched_vertices=len(res.touched_vertices),
            repair=rstats,
            heat=hstats,
            apply_time_s=root.elapsed_s(),
            compacted=compacted,
        )

    def tombstone_ratio(self) -> float:
        """Fraction of item rows that are tombstones (dead vertices+edges)."""
        dg = self._delta_graph
        if dg is None:
            return 0.0
        total = dg.g.n_items
        alive = dg.n_alive_nodes + dg.n_alive_edges
        return 1.0 - alive / max(total, 1)

    def compact(self) -> bool:
        """Fold the delta overlay eagerly (maintenance-window compaction).

        ``apply_updates`` compacts reactively at ``compact_ratio``; a
        :class:`~repro.serve.MaintenancePolicy` calls this proactively when
        an idle gap can absorb the cost.  No-op (False) when there is no
        overlay or no tombstone to reclaim."""
        if self._delta_graph is None or self.tombstone_ratio() <= 0.0:
            return False
        self._compact_in_place()
        return True

    def add_remap_listener(self, fn) -> None:
        """Register ``fn(imap)`` to fire after every id-space re-keying —
        mutation-batch growth (vertex inserts shift the edge block) as well
        as compaction (``imap[old_row] -> new_row``, -1 = dropped) — with
        the store already fully consistent in the new id space.  Holders of
        raw item rows — queued request handles, external caches — remap
        through it instead of dangling across the renumbering.

        Bound methods are held weakly: when the subscriber (e.g. a retired
        ``AdmissionController``) is garbage-collected, its entry is pruned on
        the next compaction instead of pinning it alive forever."""
        import weakref

        try:
            self._remap_listeners.append(weakref.WeakMethod(fn))
        except TypeError:  # plain function/lambda: hold strongly
            self._remap_listeners.append(lambda _fn=fn: _fn)

    def _fire_remap_listeners(self, imap: np.ndarray) -> None:
        live = []
        for ref in self._remap_listeners:
            fn = ref()
            if fn is not None:
                fn(imap)
                live.append(ref)
        self._remap_listeners = live

    def _compact_in_place(self) -> None:
        """Re-key every item-indexed structure onto the dense compacted graph.

        Invoked by the tombstone-ratio trigger in :meth:`apply_updates`.
        Placement rows, the route index, workload frequencies, heat caches
        and the warm DHD field are all row-selected/remapped in place; the
        layered graph is rebuilt from the compact graph (compaction renumbers
        ids, so the stable-id repair path does not apply) and a fresh
        :class:`~repro.streaming.DeltaGraph` takes over with zero tombstones.
        """
        sp = self.tracer.span(
            "store.compact", track="store",
            tombstone_ratio=round(self.tombstone_ratio(), 4),
        )
        with sp:
            self._compact_in_place_traced()

    def _compact_in_place_traced(self) -> None:
        dg = self._delta_graph
        old_n = self.g.n_nodes
        gc, vmap, emap = dg.compact()
        vkeep = np.where(dg.node_alive)[0]
        ekeep = np.where(dg.edge_alive)[0]
        # new row order: alive vertices (old order), then alive edges
        keep = np.concatenate([vkeep, old_n + ekeep])
        self._item_uid = self._item_uid[keep]

        # placement rows + route index
        self.state.delta = self.state.delta[keep]
        if self.route_index is not None:
            self.route_index.take_rows(keep)
            self.state.route = self.route_index.nearest
        else:
            self.state.route = self.state.route[keep]

        # workload: remap pattern items, row-select aggregated frequencies
        imap = np.full(old_n + len(emap), -1, dtype=np.int64)
        imap[:old_n] = vmap
        imap[old_n:] = np.where(emap >= 0, gc.n_nodes + emap, -1)
        # journal keys digest uids (compaction-stable); only the row-indexed
        # memo values need rewriting onto the renumbered id space
        self._placement_journal.remap(imap, self._item_uid)
        pats = []
        for p in self.workload.patterns:
            it = imap[p.items]
            pats.append(
                Pattern(pid=p.pid, items=it[it >= 0], r_py=p.r_py, w_py=p.w_py, eta=p.eta)
            )
        self.workload = Workload(
            patterns=pats,
            n_items=gc.n_items,
            n_dcs=self.workload.n_dcs,
            r_xy=self.workload.r_xy[keep],
            w_xy=self.workload.w_xy[keep],
        )

        # demand plane: row-select every item-indexed table; the caches'
        # heat rows are views and follow.  Drop the (now all-True) edge mask.
        self.demand.take_rows(keep)
        for cache in self.caches.values():
            cache.g = gc
            cache.edge_mask = None

        # layered graph: rebuild on the renumbered graph, same thresholds
        self.lg = build_layered_graph(
            gc, self.env, thresholds_s=self.lg.thresholds_s
        )

        # warm DHD: re-key the equilibrium field, rebuild the ELL warm
        self.g = gc
        from ..streaming.mutation_log import DeltaGraph

        self._delta_graph = DeltaGraph(gc)
        if self._heat is not None and self._heat.heat is not None:
            h0 = self._heat.vertex_heat[vkeep].copy()
            alive_e, w_e, q = self._heat_inputs()
            self._heat.rebuild(
                gc.n_nodes, gc.src[alive_e], gc.dst[alive_e], w_e, q, heat0=h0
            )

        # the store is consistent in the new id space: stale-flush guards
        # trip from here on, and raw-row holders get their remap shot
        self._id_epoch += 1
        self._fire_remap_listeners(imap)

    def plan_flush(
        self,
        budget_bytes: Optional[float] = None,
        window_s: Optional[float] = 60.0,
        schedule: str = "ff",
        **kw,
    ):
        """Plan (but do not apply) the cost-bounded replica move-set for the
        heat drift accumulated since the last flush.

        Returns a :class:`~repro.streaming.MigrationPlan`; with a
        ``window_s`` its ``.schedule`` holds the per-link transfer waves
        (``schedule`` picks the packing: ``"ff"`` priority-order first-fit,
        ``"lpt"`` makespan-aware).  Pure planning: the placement, route
        index and heat state are read, never written.

        ``item_heat=`` / ``read_rates=`` (forwarded through ``**kw``) inject
        the demand tables the planner optimizes against — a measured or
        *forecast* :class:`~repro.demand.DemandView` — instead of the default
        warm-DHD equilibrium over the static workload.  The default path is
        unchanged, so reactive planning stays bit-identical."""
        if schedule not in ("ff", "lpt"):
            # validated here too: with window_s=None schedule_transfers (the
            # authority on packing names) never runs, and a typo'd packing
            # request must not silently single-shot instead
            raise ValueError(f"unknown packing {schedule!r} (want 'ff' or 'lpt')")
        with self.tracer.span("store.plan_flush", track="store"):
            return self._plan_flush_traced(budget_bytes, window_s, schedule, **kw)

    def _plan_flush_traced(
        self, budget_bytes, window_s, schedule,
        item_heat=None, read_rates=None, **kw,
    ):
        from ..streaming.delta_dhd import StreamingHeat
        from ..streaming.migration import plan_migrations, schedule_transfers

        self._resync_route_index()
        sizes = self.g.item_size()
        if budget_bytes is None:
            budget_bytes = 0.05 * float(sizes.sum())
        if self._delta_graph is not None:
            item_alive = np.concatenate(
                [self._delta_graph.node_alive, self._delta_graph.edge_alive]
            )
        else:
            item_alive = np.ones(self.g.n_items, dtype=bool)
        if item_heat is None:
            # reactive default: warm-DHD equilibrium over the workload tables
            if self._heat is None or self._heat.heat is None:
                # never churned: cold-solve the equilibrium once
                self._heat = StreamingHeat()
                alive_e, w_e, q = self._heat_inputs()
                self._heat.rebuild(self.g.n_nodes, self.g.src[alive_e], self.g.dst[alive_e], w_e, q)
            vheat = self._heat.vertex_heat
            eheat = 0.5 * (vheat[self.g.src] + vheat[self.g.dst])
            item_heat = np.concatenate([vheat, eheat]) * item_alive
        else:
            # injected demand-plane view (measured or forecast): no DHD solve
            item_heat = np.asarray(item_heat, dtype=np.float64) * item_alive
        r_xy = self.workload.r_xy if read_rates is None else np.asarray(read_rates)
        plan = plan_migrations(
            self.g, self.env, self.state, r_xy, self.workload.w_xy,
            item_heat, budget_bytes, item_alive=item_alive, **kw,
        )
        if window_s is not None:
            plan.schedule = schedule_transfers(
                plan, self.env, window_s, schedule=schedule
            )
        return plan

    def begin_flush(
        self,
        budget_bytes: Optional[float] = None,
        window_s: float = 60.0,
        schedule: str = "ff",
        **kw,
    ):
        """Plan a scheduled flush and hand back ``(plan, WaveApplier)``.

        The control-plane entry: the caller (typically a
        :class:`~repro.serve.MaintenancePolicy`) lands waves one at a time
        into idle gaps via ``applier.apply_next()`` and releases drops with
        ``applier.finish()``.  Zero-byte local adds land immediately.

        The applier is epoch-guarded: if a mutation batch or compaction
        renumbers the item id space while waves are still pending, the next
        ``apply_next()``/``finish()`` raises
        :class:`~repro.streaming.migration.StaleFlushError` instead of
        applying stale rows — re-plan with a fresh ``begin_flush``."""
        from ..streaming.migration import WaveApplier

        if window_s is None:
            raise ValueError("begin_flush needs a window_s (waves to step)")
        plan = self.plan_flush(budget_bytes, window_s, schedule=schedule, **kw)
        epoch = self._id_epoch
        applier = WaveApplier(
            plan, self.state, self.env, self.workload.patterns,
            self._guard_rates(kw), self.g.item_size(), self.config.gamma_max_s,
            route_index=self.route_index,
            valid_check=lambda: self._id_epoch == epoch,
        )
        return plan, applier

    def _guard_rates(self, plan_kw) -> np.ndarray:
        """The demand table the Eq. 6 constraint guard holds the flush to.

        Plan and guard must judge the same demand: a plan made against an
        injected measured/forecast ``read_rates`` view, but guarded against
        the offline workload's ``r_xy``, would see every demand-cold drop as
        an SLO regression on synthetic reads nobody issues any more — and
        the guard would roll back all drops, forever."""
        rates = plan_kw.get("read_rates")
        if rates is None:
            return self.workload.r_xy
        return np.asarray(rates, dtype=np.float64)

    def flush_migrations(
        self,
        budget_bytes: Optional[float] = None,
        window_s: Optional[float] = 60.0,
        on_wave=None,
        schedule: str = "ff",
        **kw,
    ):
        """Plan + apply the cost-bounded replica move-set for the heat drift
        accumulated since the last flush.

        With a ``window_s`` (the default) accepted adds are scheduled into
        per-(src, dst) transfer waves under the per-link byte budgets
        ``env.link_budget_bytes(window_s)`` and applied **wave by wave**:
        after each wave the placement and :class:`RouteIndex` are mutually
        consistent, ``on_wave(wave)`` fires (e.g. to drain an
        :class:`~repro.serve.AdmissionController` between waves), and drops
        are released only once every transfer has landed.  ``window_s=None``
        keeps the legacy single-shot application.

        Returns the :class:`~repro.streaming.MigrationPlan` with
        ``plan.schedule`` attached (wave layout, per-link budgets, pipelined
        makespan estimate) and ``rolled_back`` set if the constraint guard
        reverted drops."""
        from ..streaming.migration import apply_plan

        plan = self.plan_flush(budget_bytes, window_s, schedule=schedule, **kw)
        apply_plan(
            plan, self.state, self.env, self.workload.patterns,
            self._guard_rates(kw), self.g.item_size(), self.config.gamma_max_s,
            route_index=self.route_index,
            schedule=plan.schedule,
            on_wave=on_wave,
        )
        return plan

    # -------------------------------------------------------------- costing
    def cost(self) -> CostBreakdown:
        return total_cost(
            self.workload.patterns,
            self.state,
            self.workload.r_xy,
            self.workload.w_xy,
            self.g.item_size(),
            self.env,
            self.config.lambda1,
            self.config.lambda2,
        )

    def constraints(self, gamma_max_s: Optional[float] = None) -> Dict[str, bool]:
        return check_constraints(
            self.workload.patterns,
            self.state,
            self.workload.r_xy,
            self.g.item_size(),
            self.env,
            gamma_max_s or self.config.gamma_max_s,
        )
