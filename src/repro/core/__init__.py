"""GeoLayer core: the paper's contribution (§III-§VI + appendix)."""
from . import (  # noqa: F401
    analytics,
    baselines,
    cost,
    dhd,
    graph,
    latency,
    layered_graph,
    optimal,
    patterns,
    placement,
    route_index,
    routing,
    store,
)
