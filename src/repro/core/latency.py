"""Geo-distributed environment model: DCs, WAN latency/bandwidth, pricing.

Defaults reproduce the paper's measurements:
  * Table I  — available bandwidth + RTT among five Alibaba Cloud DCs.
  * Table II — cloud storage / GET / PUT / transfer prices (Alibaba row).
Request latency follows Eq. (1):  l = RTT + size / BW.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

__all__ = ["GeoEnvironment", "PAPER_TABLE1_DCS", "make_paper_env", "make_synthetic_env"]

# --- Table I (paper §II).  RTT in ms (lower triangle), BW in Mbps (upper). ---
PAPER_TABLE1_DCS = ["us_east", "us_west", "london", "singapore", "beijing"]

_T1_RTT_MS = np.array(
    [
        [0.0, 69.0, 80.0, 225.0, 226.0],
        [69.0, 0.0, 136.0, 178.0, 145.0],
        [80.0, 136.0, 0.0, 213.0, 256.0],
        [225.0, 178.0, 213.0, 0.0, 75.0],
        [226.0, 145.0, 256.0, 75.0, 0.0],
    ]
)
_T1_BW_MBPS = np.array(
    [
        [0.0, 96.0, 92.0, 66.0, 68.0],
        [96.0, 0.0, 93.0, 80.0, 77.0],
        [92.0, 93.0, 0.0, 74.0, 42.0],
        [66.0, 80.0, 74.0, 0.0, 96.0],
        [68.0, 77.0, 42.0, 96.0, 0.0],
    ]
)

# --- Table II, Alibaba row: storage $/GB/month, GET $/M, PUT $/M, net $/GB ---
_ALIBABA_PRICES = dict(store=0.016, get=0.10, put=1.40, net=0.043)


@dataclasses.dataclass
class GeoEnvironment:
    """Latency / bandwidth / pricing model for a set of DCs.

    Units: latency seconds, bandwidth bytes/sec, sizes bytes, costs $.
    """

    names: Sequence[str]
    rtt_s: np.ndarray  # [D, D] round-trip seconds
    bw_Bps: np.ndarray  # [D, D] bytes/sec
    c_store: np.ndarray  # [D] $/byte/window
    c_read: np.ndarray  # [D] $/GET
    c_write: np.ndarray  # [D] $/PUT
    c_net: np.ndarray  # [D, D] $/byte  (src -> dst)

    @property
    def n_dcs(self) -> int:
        return len(self.names)

    def request_latency(self, d: int, y: int, size_bytes: float) -> float:
        """Eq. (1): latency of DC ``d`` serving ``size_bytes`` to DC ``y``."""
        if d == y:
            return 0.0
        return float(self.rtt_s[d, y] + size_bytes / self.bw_Bps[d, y])

    def request_latency_matrix(self, sizes: np.ndarray) -> np.ndarray:
        """Vectorized Eq. (1): [D_serve, D_origin] latency for per-pair sizes.

        ``sizes`` broadcastable to [D, D]; diagonal forced to 0 (local)."""
        lat = self.rtt_s + np.asarray(sizes) / self.bw_Bps_safe()
        np.fill_diagonal(lat, 0.0)
        return lat

    def bw_Bps_safe(self) -> np.ndarray:
        bw = self.bw_Bps.copy()
        np.fill_diagonal(bw, np.inf)
        return bw

    def link_budget_bytes(self, window_s: float) -> np.ndarray:
        """[src, dst] WAN bytes one migration window can ship per link.

        The link-granular form of the paper's migration condition ξ (Eq. 14):
        a transfer wave may load each (src, dst) link with at most
        ``bw_Bps * window_s`` bytes.  The diagonal is +inf — co-located
        copies never cross the WAN."""
        return self.bw_Bps_safe() * float(window_s)

    def edge_latency(self, d: int, dprime: int, size_bytes: float = 0.0) -> float:
        """Latency level assigned to a cross-partition edge (Def. 1 delta)."""
        return self.request_latency(d, dprime, size_bytes)

    def pairwise_rtt_levels(self, thresholds_s: Sequence[float]) -> np.ndarray:
        """Map each DC pair to a 1-based latency layer via threshold buckets."""
        t = np.asarray(list(thresholds_s) + [np.inf])
        lvl = np.searchsorted(t, self.rtt_s, side="right")
        np.fill_diagonal(lvl, 0)
        return lvl.astype(np.int32)


def make_paper_env(scale_rtt: float = 1.0, scale_bw: float = 1.0) -> GeoEnvironment:
    """The five-DC environment of Table I with Alibaba pricing."""
    d = len(PAPER_TABLE1_DCS)
    rtt = _T1_RTT_MS / 1e3 * scale_rtt
    bw = _T1_BW_MBPS * 1e6 / 8.0 * scale_bw  # Mbps -> bytes/s
    bw[bw == 0] = np.inf
    p = _ALIBABA_PRICES
    gb = 1 << 30
    return GeoEnvironment(
        names=list(PAPER_TABLE1_DCS),
        rtt_s=rtt,
        bw_Bps=bw,
        c_store=np.full(d, p["store"] / gb),
        c_read=np.full(d, p["get"] / 1e6),
        c_write=np.full(d, p["put"] / 1e6),
        c_net=np.full((d, d), p["net"] / gb),
    )


def make_synthetic_env(
    n_dcs: int,
    heterogeneity: str = "high",
    seed: int = 0,
    prices: Optional[Dict[str, float]] = None,
) -> GeoEnvironment:
    """Random WAN with controllable heterogeneity (paper §VII-B sensitivity).

    ``low``    — intra-country cluster: RTT ~ U[10, 40] ms
    ``medium`` — continental: RTT ~ U[30, 120] ms
    ``high``   — global: RTT ~ U[60, 260] ms (Table I-like spread)
    """
    rng = np.random.default_rng(seed)
    lo, hi = {"low": (10, 40), "medium": (30, 120), "high": (60, 260)}[heterogeneity]
    rtt_ms = rng.uniform(lo, hi, size=(n_dcs, n_dcs))
    rtt_ms = (rtt_ms + rtt_ms.T) / 2.0
    np.fill_diagonal(rtt_ms, 0.0)
    # Bandwidth anti-correlates with RTT (paper Table I trend), 40-100 Mbps.
    bw_mbps = 100.0 - 55.0 * (rtt_ms - lo) / max(hi - lo, 1)
    bw_mbps = np.clip((bw_mbps + bw_mbps.T) / 2.0, 40.0, 100.0)
    bw = bw_mbps * 1e6 / 8.0
    np.fill_diagonal(bw, np.inf)
    p = dict(_ALIBABA_PRICES)
    if prices:
        p.update(prices)
    gb = 1 << 30
    return GeoEnvironment(
        names=[f"dc{i}" for i in range(n_dcs)],
        rtt_s=rtt_ms / 1e3,
        bw_Bps=bw,
        c_store=np.full(n_dcs, p["store"] / gb),
        c_read=np.full(n_dcs, p["get"] / 1e6),
        c_write=np.full(n_dcs, p["put"] / 1e6),
        c_net=np.full((n_dcs, n_dcs), p["net"] / gb),
    )
