"""Directed Heat Diffusion (DHD) model — paper §V Eqs. (7)-(12), Theorem 1.

Vertices are thermal masses; access frequency is heat.  Per step, heat flows
along each undirected edge from the hotter to the colder endpoint:

    dH_uv = alpha * A_uv / |N_u^out| * ReLU(H_u - H_v)          (Eq. 7)
    H_v'  = (1-gamma) * [H_v + sum_in dH - sum_out dH] + beta*Q (Eqs. 8/10)

``|N_u^out|`` is the number of *lower-heat* neighbors of the hotter endpoint
(data-dependent).  Sources (Eq. 9) inject exponentially-decaying external
heat.  The steady state solves  gamma*H - alpha*(1-gamma)*L_dir*H = beta*Q
(Eq. 12); Theorem 1 gives the contraction bound
``alpha < gamma / ((1-gamma) * ||L_dir||_inf)``.

Two data-plane implementations:
  * edge-list (``segment_sum``) — used for arbitrary graphs, autodiff-safe;
  * dense Laplacian — used for small per-cluster solves and for validating
    the steady state against a direct linear solve (Theorem 1).
The TPU hot-path lives in ``repro.kernels.dhd_spmv`` (ELL-blocked Pallas);
``repro.kernels.ops.dhd_step`` dispatches kernel vs this reference.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "DHDParams",
    "dhd_step_edges",
    "dhd_step_edges_batch",
    "dhd_step_dense",
    "build_l_dir",
    "steady_state",
    "linear_steady_state",
    "convergence_alpha_bound",
    "source_heat",
    "diffuse_affinity",
    "diffuse_affinity_batch",
]


class DHDParams(NamedTuple):
    """Paper defaults: alpha=0.5, gamma=0.1, beta=0.3 (§V-B)."""

    alpha: float = 0.5
    gamma: float = 0.1
    beta: float = 0.3


# ----------------------------------------------------------------- edge form
@functools.partial(jax.jit, static_argnames=("n_nodes",))
def dhd_step_edges(
    heat: jnp.ndarray,  # [n]
    src: jnp.ndarray,  # [m] undirected edge endpoints
    dst: jnp.ndarray,  # [m]
    weight: jnp.ndarray,  # [m] A_uv  (edge initial heat / frequency)
    q: jnp.ndarray,  # [n] external source heat this step
    n_nodes: int,
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
) -> jnp.ndarray:
    """One DHD update (Eqs. 7-8) over an undirected edge list."""
    hs = heat[src]
    hd = heat[dst]
    hot_is_src = hs > hd
    hot = jnp.where(hot_is_src, src, dst)
    cold = jnp.where(hot_is_src, dst, src)
    # ReLU gate (equal heat -> no flow) AND weight gate: a zero-weight edge
    # is *absent* — it must not enter |N_u^out| either, matching the ELL
    # reference's ``vals > 0`` masking.  This is what lets batched callers
    # share one edge list across seeds and switch edges off per seed.
    active = (hs != hd) & (weight > 0)
    ones = jnp.where(active, 1.0, 0.0)
    # |N_u^out| = number of strictly-lower-heat neighbors of the hot endpoint
    n_out = jax.ops.segment_sum(ones, hot, num_segments=n_nodes)
    n_out_safe = jnp.maximum(n_out, 1.0)
    dh = alpha * weight / n_out_safe[hot] * (heat[hot] - heat[cold])
    dh = jnp.where(active, dh, 0.0)
    delta = jax.ops.segment_sum(dh, cold, num_segments=n_nodes) - jax.ops.segment_sum(
        dh, hot, num_segments=n_nodes
    )
    return (1.0 - gamma) * (heat + delta) + beta * q


@functools.partial(jax.jit, static_argnames=("n_nodes",))
def dhd_step_edges_batch(
    heat: jnp.ndarray,  # [B, n]
    src: jnp.ndarray,  # [m] shared undirected edge endpoints
    dst: jnp.ndarray,  # [m]
    weight: jnp.ndarray,  # [m] shared or [B, m] per-seed A_uv
    q: jnp.ndarray,  # [B, n]
    n_nodes: int,
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
) -> jnp.ndarray:
    """Batched DHD update: B independent heat fields over one edge list.

    With 2-D ``weight`` each row carries its own edge weights (0 = edge
    absent for that row, thanks to the weight gate in
    :func:`dhd_step_edges`).  Row ``b`` equals ``dhd_step_edges(heat[b],
    src, dst, weight[b], q[b], n_nodes)``.
    """
    w_axis = 0 if weight.ndim == 2 else None
    return jax.vmap(
        lambda h, w, qq: dhd_step_edges(
            h, src, dst, w, qq, n_nodes, alpha=alpha, gamma=gamma, beta=beta
        ),
        in_axes=(0, w_axis, 0),
    )(heat, weight, q)


# ---------------------------------------------------------------- dense form
def build_l_dir(heat: jnp.ndarray, adj: jnp.ndarray) -> jnp.ndarray:
    """Directional Laplacian (Eq. 11) for the current heat field.

    ``(L)_vw = -A_vw/|N_v^out|`` if H_v > H_w (out-flow from v),
    ``(L)_vw = +A_wv/|N_w^out|`` if H_w > H_v (in-flow to v), else 0.
    Then the dense update is  H' = (1-g)(H + a*L@H) ... with the convention
    that ``L @ H`` realizes sum_in dH - sum_out dH when flows use the
    temperature *difference*; we therefore apply L to the difference form
    directly in :func:`dhd_step_dense` and keep this builder for Theorem-1
    style analysis (fixed L at equilibrium).
    """
    h = heat[:, None]
    hotter = h > h.T  # [v, w] True if H_v > H_w
    active = adj > 0
    out_mask = hotter & active  # v -> w flow (v loses)
    n_out = jnp.maximum(out_mask.sum(axis=1, keepdims=True), 1.0)
    out_part = jnp.where(out_mask, -adj / n_out, 0.0)
    in_mask = (~hotter) & (h.T > h) & active  # w -> v flow (v gains)
    n_out_w = jnp.maximum(out_mask.sum(axis=1), 1.0)  # |N_w^out| per row w
    in_part = jnp.where(in_mask, (adj / n_out_w[None, :]), 0.0)
    return out_part + in_part


@jax.jit
def dhd_step_dense(
    heat: jnp.ndarray,  # [n]
    adj: jnp.ndarray,  # [n, n] symmetric nonneg weights (A_uv)
    q: jnp.ndarray,  # [n]
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
) -> jnp.ndarray:
    """One DHD update in dense form — mathematically equal to the edge form."""
    h = heat
    diff = h[:, None] - h[None, :]  # diff[u,v] = H_u - H_v
    flow_mask = (diff > 0) & (adj > 0)  # u hotter than v
    n_out = jnp.maximum(flow_mask.sum(axis=1), 1.0)  # |N_u^out|
    dh = alpha * adj / n_out[:, None] * jnp.where(flow_mask, diff, 0.0)
    # dh[u, v]: heat leaving u toward v
    delta = dh.sum(axis=0) - dh.sum(axis=1)  # gains - losses per vertex
    return (1.0 - gamma) * (h + delta) + beta * q


# ------------------------------------------------------------- steady state
def steady_state(
    heat0: jnp.ndarray,
    step_fn,
    q_fn,
    max_iters: int = 200,
    tol: float = 1e-6,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Iterate ``heat <- step_fn(heat, q_fn(k))`` to a fixed point.

    Returns (H*, iterations-used).  Uses ``lax.while_loop`` so it stays on
    device; ``q_fn`` must be jax-traceable in ``k``.
    """

    def cond(state):
        k, h, prev, done = state
        return jnp.logical_and(k < max_iters, jnp.logical_not(done))

    def body(state):
        k, h, prev, _ = state
        nh = step_fn(h, q_fn(k))
        done = jnp.max(jnp.abs(nh - h)) < tol
        return k + 1, nh, h, done

    k, h, _, _ = jax.lax.while_loop(
        cond, body, (jnp.asarray(0), heat0, heat0 + jnp.inf, jnp.asarray(False))
    )
    return h, k


def linear_steady_state(
    l_dir: jnp.ndarray,
    q: jnp.ndarray,
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
) -> jnp.ndarray:
    """Direct solve of Eq. (12): H* = beta (gamma*I - alpha(1-gamma)L)^-1 Q*.

    Valid (unique, nonneg for M-matrix L) under the Theorem-1 bound."""
    n = l_dir.shape[0]
    a = gamma * jnp.eye(n) - alpha * (1.0 - gamma) * l_dir
    return beta * jnp.linalg.solve(a, q)


def convergence_alpha_bound(l_dir: jnp.ndarray, gamma: float = 0.1) -> float:
    """Theorem 1: alpha < gamma / ((1-gamma) ||L||_inf) guarantees contraction."""
    norm = float(jnp.max(jnp.sum(jnp.abs(l_dir), axis=1)))
    if norm == 0.0:
        return float("inf")
    return gamma / ((1.0 - gamma) * norm)


# ------------------------------------------------------------------- sources
def source_heat(
    q0: jnp.ndarray,  # [n] initial source heat (1/|O| on sources, else 0)
    k: jnp.ndarray,  # step index
    half_life: float = 8.0,
    extra: Optional[jnp.ndarray] = None,  # dQ * sum(sigma_v) access term
) -> jnp.ndarray:
    """Source dynamics (Eq. 9): q0 * exp(-pi*k) + extra, pi = ln2/T_hl."""
    pi = np.log(2.0) / half_life
    q = q0 * jnp.exp(-pi * k)
    if extra is not None:
        q = q + extra
    return q


# --------------------------------------------------- placement-affinity runs
def diffuse_affinity(
    n_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    weight: np.ndarray,
    seed_heat: np.ndarray,  # [n] heat injected at the BS's held regions
    base_heat: Optional[np.ndarray] = None,
    params: DHDParams = DHDParams(),
    n_steps: int = 32,
) -> np.ndarray:
    """Heat reaching each node when ``seed_heat`` diffuses over the region
    graph (paper Fig. 4 competition).  Sources decay with half-life
    ``n_steps/4`` so the run terminates with a stable field.  Returns np.
    """
    if len(src) == 0:
        return np.asarray(seed_heat, dtype=np.float32)
    src_j = jnp.asarray(src, dtype=jnp.int32)
    dst_j = jnp.asarray(dst, dtype=jnp.int32)
    w_j = jnp.asarray(weight, dtype=jnp.float32)
    h = jnp.asarray(
        seed_heat if base_heat is None else seed_heat + base_heat, dtype=jnp.float32
    )
    q0 = jnp.asarray(seed_heat, dtype=jnp.float32)
    half_life = max(n_steps / 4.0, 1.0)

    def body(k, h):
        q = source_heat(q0, k, half_life=half_life)
        return dhd_step_edges(
            h, src_j, dst_j, w_j, q, n_nodes,
            alpha=params.alpha, gamma=params.gamma, beta=params.beta,
        )

    h = jax.lax.fori_loop(0, n_steps, body, h)
    return np.asarray(h)


def diffuse_affinity_batch(
    n_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    weight: np.ndarray,  # [m] shared or [B, m] per-seed weights
    seeds: np.ndarray,  # [B, n] heat injected per seed vector
    base_heat: Optional[np.ndarray] = None,  # [n] or [B, n]
    params: DHDParams = DHDParams(),
    n_steps: int = 32,
    use_kernel: Optional[bool] = None,
) -> np.ndarray:
    """Batched :func:`diffuse_affinity`: B seed vectors, ONE diffusion run.

    Row ``b`` equals ``diffuse_affinity(n_nodes, src, dst, weight[b], ...,
    seeds[b])`` — per-seed weights let callers share an edge-list union and
    deactivate edges per seed with zero weight (the placement arena's
    per-candidate super-node topologies).  Dispatch lives in
    :func:`repro.kernels.ops.diffuse_batch`: the batched Pallas ELL kernel
    when kernel-eligible, the vmapped edge form otherwise.
    """
    seeds = np.atleast_2d(np.asarray(seeds, dtype=np.float32))
    if len(src) == 0:
        return seeds.copy()
    from ..kernels import ops  # local: kernels.ops lazily imports this module

    return ops.diffuse_batch(
        n_nodes, src, dst, weight, seeds, base_heat=base_heat,
        params=params, n_steps=n_steps, use_kernel=use_kernel,
    )
