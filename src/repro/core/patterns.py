"""Graph patterns and overlap-region decomposition (paper §II, §V Fig. 4).

A *pattern* is the set of data items (vertices + edges) matched by a graph
query — generated here as k-hop random-walk neighborhoods, mirroring the
paper's 3-hop walk workloads on UK/TW.  Patterns carry per-origin read/write
frequencies and a latency-SLO coefficient ``eta`` (constraint (d) of Eq. 6).

*Overlap regions* are the Venn cells of a pattern set: every item is keyed by
the bitmask of patterns containing it, and each distinct bitmask forms one
disjoint region (paper Fig. 4a's {r1..r7}).  Regions are the placement
granularity of Algorithm 2.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import CSR, Graph, build_csr

__all__ = [
    "Pattern",
    "Workload",
    "generate_khop_patterns",
    "aggregate_item_frequencies",
    "OverlapRegion",
    "decompose_overlap_regions",
    "region_adjacency",
]


@dataclasses.dataclass
class Pattern:
    pid: int
    items: np.ndarray  # item ids (vertex v -> v; edge e -> n_nodes + e)
    r_py: np.ndarray  # [D] read frequency per origin DC
    w_py: np.ndarray  # [D] write frequency per origin DC
    eta: float = 1.0  # latency requirement coefficient, (0, 1]

    @property
    def read_rate(self) -> float:
        return float(self.r_py.sum())

    @property
    def write_rate(self) -> float:
        return float(self.w_py.sum())


@dataclasses.dataclass
class Workload:
    patterns: List[Pattern]
    n_items: int
    n_dcs: int
    r_xy: np.ndarray  # [I, D] aggregated per-item read frequencies
    w_xy: np.ndarray  # [I, D]

    @staticmethod
    def from_patterns(patterns: List[Pattern], n_items: int, n_dcs: int) -> "Workload":
        r, w = aggregate_item_frequencies(patterns, n_items, n_dcs)
        return Workload(patterns=patterns, n_items=n_items, n_dcs=n_dcs, r_xy=r, w_xy=w)


def generate_khop_patterns(
    g: Graph,
    csr: CSR,
    n_patterns: int,
    hops: int = 3,
    branch: int = 2,
    seed: int = 0,
    write_fraction: float = 0.3,
    freq_zipf_a: float = 1.4,
    eta_choices: Sequence[float] = (0.25, 0.5, 0.75, 1.0),
    n_dcs: Optional[int] = None,
    n_hot_sources: Optional[int] = None,
) -> List[Pattern]:
    """K-hop random-walk patterns with Zipf-skewed source popularity.

    Each pattern expands ``branch`` random neighbors per frontier vertex for
    ``hops`` steps; visited vertices and traversed edges become the pattern's
    items.  Source vertices are drawn Zipf-skewed so hot regions emerge (the
    precondition for the paper's conduction/superposition observations).
    ``eta`` is drawn uniformly from ``eta_choices`` (paper: random latency
    requirement mapped to one layer's interval).
    """
    rng = np.random.default_rng(seed)
    D = n_dcs if n_dcs is not None else int(g.partition.max()) + 1
    # Zipf-ish popularity over vertices (rank-based to avoid huge tails).
    # ``n_hot_sources`` restricts sources to a fixed hot core — the paper's
    # observed access pattern (celebrity regions attract most queries), and
    # what makes historical placement predictive for test patterns.
    ranks = rng.permutation(g.n_nodes) + 1
    popularity = 1.0 / ranks.astype(np.float64) ** freq_zipf_a
    if n_hot_sources is not None and n_hot_sources < g.n_nodes:
        hot = np.argsort(ranks)[:n_hot_sources]
        mask = np.zeros(g.n_nodes)
        mask[hot] = 1.0
        popularity = popularity * mask
    popularity /= popularity.sum()

    # CSR edge lookup: map (u, slot) -> edge item id needs original edge index;
    # build a parallel CSR of edge ids.
    eid_csr = build_csr(
        g.n_nodes, g.src, g.dst, weights=np.arange(g.n_edges, dtype=np.float32)
    )

    patterns: List[Pattern] = []
    for pid in range(n_patterns):
        v0 = int(rng.choice(g.n_nodes, p=popularity))
        verts = {v0}
        edges: set = set()
        frontier = [v0]
        for _ in range(hops):
            nxt: List[int] = []
            for u in frontier:
                lo, hi = int(eid_csr.indptr[u]), int(eid_csr.indptr[u + 1])
                deg = hi - lo
                if deg == 0:
                    continue
                k = min(branch, deg)
                sel = rng.choice(deg, size=k, replace=False)
                for s in sel:
                    v = int(eid_csr.indices[lo + s])
                    e = int(eid_csr.weights[lo + s])
                    edges.add(e)
                    if v not in verts:
                        verts.add(v)
                        nxt.append(v)
            frontier = nxt
            if not frontier:
                break
        items = np.concatenate(
            [
                np.fromiter(verts, dtype=np.int64, count=len(verts)),
                g.n_nodes + np.fromiter(edges, dtype=np.int64, count=len(edges)),
            ]
        )
        origin = int(g.partition[v0])
        r_py = np.zeros(D)
        base = float(1 + rng.poisson(4) + 40 * popularity[v0] * g.n_nodes / 10)
        r_py[origin] = base
        # some patterns are requested from a second, remote origin
        if rng.random() < 0.35 and D > 1:
            other = int(rng.choice([d for d in range(D) if d != origin]))
            r_py[other] = max(1.0, base * rng.uniform(0.2, 0.8))
        w_py = np.zeros(D)
        if rng.random() < write_fraction:
            w_py[origin] = base * rng.uniform(0.05, 0.3)
        eta = float(rng.choice(np.asarray(eta_choices)))
        patterns.append(Pattern(pid=pid, items=np.unique(items), r_py=r_py, w_py=w_py, eta=eta))
    return patterns


def aggregate_item_frequencies(
    patterns: Sequence[Pattern], n_items: int, n_dcs: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-item R_xy / W_xy from pattern-level frequencies (access logs)."""
    r = np.zeros((n_items, n_dcs), dtype=np.float64)
    w = np.zeros((n_items, n_dcs), dtype=np.float64)
    for p in patterns:
        r[p.items] += p.r_py[None, :]
        w[p.items] += p.w_py[None, :]
    return r, w


# ------------------------------------------------------------ overlap regions
@dataclasses.dataclass
class OverlapRegion:
    rid: int
    key: Tuple[int, ...]  # sorted pids whose intersection cell this is
    items: np.ndarray
    degree: int  # |key| — overlap multiplicity (superposition weight)


def decompose_overlap_regions(
    patterns: Sequence[Pattern], n_items: int, vectorized: bool = True
) -> List[OverlapRegion]:
    """Split a pattern set into disjoint Venn regions (paper Fig. 4a).

    Items sharing the same membership bitmask form one region.  Scales to
    many patterns because only realized bitmasks are materialized.

    The default path stacks every (item, pattern) incidence pair, builds the
    bit-packed membership matrix, and groups identical rows with one
    ``np.unique(axis=0)`` pass — no per-item Python loop (this was the next
    placement hot spot once pool decompositions became journal-cached).
    ``vectorized=False`` keeps the per-item dict reference it is
    oracle-tested against in ``tests/test_patterns.py``; the two agree
    whenever pattern ids are distinct and each pattern's items are unique —
    invariants every generator in this repo upholds (the reference would
    key duplicate incidences as repeated pids).
    """
    if not vectorized:
        return _decompose_overlap_regions_py(patterns, n_items)
    pats = sorted((p for p in patterns if len(p.items)), key=lambda p: p.pid)
    if not pats:
        return []
    P = len(pats)
    counts = [len(p.items) for p in pats]
    items_all = np.concatenate([np.asarray(p.items, dtype=np.int64) for p in pats])
    col = np.repeat(np.arange(P, dtype=np.int64), counts)
    touched, inv = np.unique(items_all, return_inverse=True)
    member = np.zeros((len(touched), P), dtype=bool)
    member[inv, col] = True
    # columns are in ascending-pid order, so a row's set bits read out as the
    # sorted key tuple; packing keeps np.unique's row compare at P/8 bytes
    packed = np.packbits(member, axis=1)
    rows, region_of = np.unique(packed, axis=0, return_inverse=True)
    order = np.argsort(region_of, kind="stable")  # items ascending per region
    bounds = np.concatenate([[0], np.cumsum(np.bincount(region_of, minlength=len(rows)))])
    pid_arr = np.asarray([p.pid for p in pats], dtype=np.int64)
    keyed: List[Tuple[Tuple[int, ...], np.ndarray]] = []
    for r in range(len(rows)):
        bits = np.unpackbits(rows[r])[:P].astype(bool)
        key = tuple(int(q) for q in pid_arr[bits])
        keyed.append((key, touched[order[bounds[r] : bounds[r + 1]]]))
    keyed.sort(key=lambda kv: kv[0])  # the reference orders cells by key
    return [
        OverlapRegion(rid=rid, key=key, items=items, degree=len(key))
        for rid, (key, items) in enumerate(keyed)
    ]


def _decompose_overlap_regions_py(
    patterns: Sequence[Pattern], n_items: int
) -> List[OverlapRegion]:
    """Per-item membership-dict reference (the pre-vectorization path)."""
    membership: Dict[int, List[int]] = {}
    for p in patterns:
        for x in p.items.tolist():
            membership.setdefault(x, []).append(p.pid)
    cells: Dict[Tuple[int, ...], List[int]] = {}
    for x, pids in membership.items():
        cells.setdefault(tuple(sorted(pids)), []).append(x)
    regions = []
    for rid, (key, items) in enumerate(sorted(cells.items())):
        regions.append(
            OverlapRegion(
                rid=rid,
                key=key,
                items=np.asarray(sorted(items), dtype=np.int64),
                degree=len(key),
            )
        )
    return regions


def region_adjacency(
    regions: Sequence[OverlapRegion], g: Graph
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Region-graph edges for the DHD competition (paper Fig. 4b).

    Two regions are adjacent when the graph has an edge whose endpoint
    vertices (or the edge item itself vs its endpoints) fall in different
    regions; the weight counts such connections.  Returns (src, dst, w).
    """
    n_regions = len(regions)
    item_region = np.full(g.n_items, -1, dtype=np.int64)
    for r in regions:
        item_region[r.items] = r.rid
    er = item_region[g.n_nodes + np.arange(g.n_edges)]
    sr = item_region[g.src]
    dr = item_region[g.dst]
    # canonical (min, max) pair keys over the three incidence kinds, counted
    # with one vectorized np.unique pass (this runs once per decomposition
    # pool — the per-edge Python-dict version was a placement hot spot)
    keys = []
    for a, b in ((sr, dr), (sr, er), (er, dr)):
        valid = (a >= 0) & (b >= 0) & (a != b)
        lo = np.minimum(a[valid], b[valid])
        hi = np.maximum(a[valid], b[valid])
        keys.append(lo * n_regions + hi)
    flat = np.concatenate(keys) if keys else np.zeros(0, dtype=np.int64)
    if len(flat) == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z, np.zeros(0, dtype=np.float32)
    uniq, counts = np.unique(flat, return_counts=True)
    return uniq // n_regions, uniq % n_regions, counts.astype(np.float32)
