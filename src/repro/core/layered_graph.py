"""Latency-aware layered graph (paper §IV, Definitions 1-2).

* ``Layer_0``      : per-DC local subgraphs (disjoint partition of G).
* ``Layer_i`` i>=1 : bridge graphs of cross-partition edges whose inter-DC
                     latency falls in the bucket [t_{i-1}, t_i).
* Bridge subgraph  : the subset of a layer's edges that merges a set of
                     weakly-connected components of everything below into one
                     component; the merged lower components form its *cluster*.

The hierarchy is a tree over (layer, component) nodes; placement and routing
decisions are confined to branches of this tree (paper App. C(i)).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .graph import Graph, weakly_connected_components
from .latency import GeoEnvironment

__all__ = [
    "BridgeSubgraph",
    "LayeredGraph",
    "build_layered_graph",
    "RepairStats",
    "repair_layered_graph",
]


@dataclasses.dataclass
class BridgeSubgraph:
    """One bridge subgraph (Def. 2): intra-layer edge set merging a cluster."""

    layer: int
    bs_id: int  # globally unique
    comp: int  # component id at ``layer`` this BS produced
    edge_ids: np.ndarray  # indices into Graph.src/dst
    children: List[int]  # component ids at layer-1 merged by this BS
    dcs: np.ndarray  # all DCs covered by the merged component

    @property
    def n_dcs(self) -> int:
        return int(len(self.dcs))


@dataclasses.dataclass
class LayeredGraph:
    g: Graph
    env: GeoEnvironment
    thresholds_s: List[float]  # t_1 .. t_{h-1}  (t_0 = 0, t_h = +inf)
    n_layers: int  # h  (bridge layers are 1..h)
    edge_layer: np.ndarray  # [m] int32: 0 intra-DC else 1..h
    comp_of_dc: np.ndarray  # [h+1, D] component label of each DC per layer
    layers: List[List[BridgeSubgraph]]  # layers[i] -> BSs at layer i (i>=1)
    mean_layer_latency: np.ndarray  # [h+1] mean RTT of edges in each layer
    _bs_by_id: Dict[int, BridgeSubgraph] = dataclasses.field(default_factory=dict)

    # ---------------------------------------------------------------- lookup
    def bs(self, bs_id: int) -> BridgeSubgraph:
        return self._bs_by_id[bs_id]

    def all_bs(self) -> List[BridgeSubgraph]:
        return [b for layer in self.layers for b in layer]

    def bs_for_dc(self, layer: int, dc: int) -> Optional[BridgeSubgraph]:
        """The BS at ``layer`` whose merged component contains ``dc``."""
        comp = self.comp_of_dc[layer, dc]
        for b in self.layers[layer]:
            if b.comp == comp:
                return b
        return None

    def cluster_dcs(self, layer: int, comp: int) -> np.ndarray:
        return np.where(self.comp_of_dc[layer] == comp)[0]

    def bs_children(self, b: BridgeSubgraph) -> List[BridgeSubgraph]:
        """Child BSs one layer below, within b's cluster (may be empty at L1)."""
        if b.layer <= 1:
            return []
        lower = []
        for child_comp in b.children:
            for cand in self.layers[b.layer - 1]:
                if cand.comp == child_comp:
                    lower.append(cand)
        return lower

    def layer_for_latency(self, latency_s: float) -> int:
        """Layer k s.t. latency in [t_{k-1}, t_k): the sink target (Alg. 1)."""
        t = [0.0] + list(self.thresholds_s)
        for k in range(len(t) - 1, 0, -1):
            if latency_s >= t[k]:
                return min(k + 1, self.n_layers)
        return 1

    def eta_L(self, layer: int) -> float:
        """Ratio of a layer's mean latency to the topmost layer's (Eq. 14)."""
        top = self.mean_layer_latency[self.n_layers]
        if top <= 0:
            return 1.0
        return float(self.mean_layer_latency[layer] / top)

    def summary(self) -> str:
        lines = [
            f"LayeredGraph: {self.env.n_dcs} DCs, {self.g.n_edges} edges, "
            f"h={self.n_layers} bridge layers, thresholds={self.thresholds_s}"
        ]
        for i in range(1, self.n_layers + 1):
            n_edges = int((self.edge_layer == i).sum())
            lines.append(
                f"  Layer_{i}: {len(self.layers[i])} bridge subgraphs, "
                f"{n_edges} edges, comps={len(np.unique(self.comp_of_dc[i]))}"
            )
        return "\n".join(lines)


def _default_thresholds(env: GeoEnvironment, interval_s: float) -> List[float]:
    """Fixed-interval bucketing (paper §VII-A uses 100 ms buckets)."""
    max_rtt = float(env.rtt_s.max())
    h = max(1, int(np.ceil(max_rtt / interval_s + 1e-9)))
    return [interval_s * k for k in range(1, h)]


def _assign_edge_layers(
    src_dc: np.ndarray,
    dst_dc: np.ndarray,
    env: GeoEnvironment,
    thresholds_s: Sequence[float],
) -> Tuple[np.ndarray, np.ndarray]:
    """Layer index per edge (0 intra-DC, else 1..h) + its RTT (Def. 1)."""
    h = len(thresholds_s) + 1
    cross = src_dc != dst_dc
    edge_rtt = env.rtt_s[src_dc, dst_dc]
    t = np.asarray([0.0] + list(thresholds_s) + [np.inf])
    # f(e)=i  <=>  delta(e) in [t_{i-1}, t_i)
    edge_layer = np.searchsorted(t, edge_rtt, side="right").astype(np.int32)
    edge_layer = np.clip(edge_layer, 1, h)
    edge_layer[~cross] = 0
    return edge_layer, edge_rtt


def _mean_layer_latency(
    edge_layer: np.ndarray,
    edge_rtt: np.ndarray,
    thresholds_s: Sequence[float],
    latency_interval_s: float,
) -> np.ndarray:
    h = len(thresholds_s) + 1
    t = np.asarray([0.0] + list(thresholds_s) + [np.inf])
    mean_lat = np.zeros(h + 1)
    for i in range(1, h + 1):
        m = edge_layer == i
        mean_lat[i] = float(edge_rtt[m].mean()) if m.any() else (
            float((t[i - 1] + min(t[i], t[i - 1] + latency_interval_s)) / 2.0)
        )
    return mean_lat


def _grow_layers(
    src_dc: np.ndarray,
    dst_dc: np.ndarray,
    edge_layer: np.ndarray,
    comp_of_dc: np.ndarray,
    layers: List[List[BridgeSubgraph]],
    bs_by_id: Dict[int, BridgeSubgraph],
    start_layer: int,
    h: int,
    next_bs: int,
    n_dcs: int,
) -> int:
    """Iterative component merging for layers ``start_layer..h``.

    Fills ``comp_of_dc[i]`` / ``layers[i]`` / ``bs_by_id`` in place from the
    components already recorded at ``start_layer - 1``.  The union-find labels
    are canonical (component root = smallest member, renumbered by sorted
    root), so the result is a pure function of the *edge set* per layer —
    which is what makes incremental repair produce rebuild-identical output.
    Returns the next free bs_id.
    """
    for i in range(start_layer, h + 1):
        prev = comp_of_dc[i - 1]
        eids = np.where(edge_layer == i)[0]
        # project layer-i edges onto previous components (DC granularity)
        e_src_c = prev[src_dc[eids]]
        e_dst_c = prev[dst_dc[eids]]
        n_prev = int(prev.max()) + 1 if n_dcs else 0
        labels = weakly_connected_components(n_prev, e_src_c, e_dst_c)
        comp_of_dc[i] = labels[prev]
        # one BS per new component that actually merged something / has edges
        for new_c in np.unique(labels):
            members_prev = np.where(labels == new_c)[0]  # prev comp ids
            bs_edges = eids[(labels[e_src_c] == new_c)]
            if len(bs_edges) == 0:
                continue  # pass-through component, no bridge subgraph
            dcs = np.where(comp_of_dc[i] == new_c)[0]
            b = BridgeSubgraph(
                layer=i,
                bs_id=next_bs,
                comp=int(new_c),
                edge_ids=bs_edges,
                children=[int(c) for c in members_prev],
                dcs=dcs,
            )
            layers[i].append(b)
            bs_by_id[next_bs] = b
            next_bs += 1
    return next_bs


def build_layered_graph(
    g: Graph,
    env: GeoEnvironment,
    thresholds_s: Optional[Sequence[float]] = None,
    latency_interval_s: float = 0.100,
) -> LayeredGraph:
    """Construct the layered graph from a geo-partitioned graph.

    Edge latency (Def. 1 ``delta``) = RTT between the owning DCs; thresholds
    default to fixed ``latency_interval_s`` buckets spanning the env's RTTs.
    """
    if thresholds_s is None:
        thresholds_s = _default_thresholds(env, latency_interval_s)
    thresholds_s = list(thresholds_s)
    h = len(thresholds_s) + 1
    D = env.n_dcs

    src_dc, dst_dc = g.edge_dc_pair()
    edge_layer, edge_rtt = _assign_edge_layers(src_dc, dst_dc, env, thresholds_s)
    mean_lat = _mean_layer_latency(edge_layer, edge_rtt, thresholds_s, latency_interval_s)

    comp_of_dc = np.zeros((h + 1, D), dtype=np.int32)
    comp_of_dc[0] = np.arange(D)  # Layer_0: each DC is its own component
    layers: List[List[BridgeSubgraph]] = [[] for _ in range(h + 1)]
    bs_by_id: Dict[int, BridgeSubgraph] = {}
    _grow_layers(
        src_dc, dst_dc, edge_layer, comp_of_dc, layers, bs_by_id,
        start_layer=1, h=h, next_bs=0, n_dcs=D,
    )

    lg = LayeredGraph(
        g=g,
        env=env,
        thresholds_s=thresholds_s,
        n_layers=h,
        edge_layer=edge_layer,
        comp_of_dc=comp_of_dc,
        layers=layers,
        mean_layer_latency=mean_lat,
        _bs_by_id=bs_by_id,
    )
    return lg


# ------------------------------------------------------- incremental repair
@dataclasses.dataclass
class RepairStats:
    touched_layers: List[int]  # layers whose edge membership changed
    first_dirty: Optional[int]  # lowest layer whose DC-components changed
    relabeled_layers: int  # layers recomputed from scratch (>= first_dirty)
    patched_layers: int  # clean layers whose BS edge lists were patched
    n_new_bs: int


def _layer_pair_keys(
    edge_layer: np.ndarray,
    src_dc: np.ndarray,
    dst_dc: np.ndarray,
    n_dcs: int,
    layer: int,
) -> np.ndarray:
    """Canonical (min, max) DC-pair keys of the alive edges in ``layer``."""
    e = np.where(edge_layer == layer)[0]
    a = src_dc[e].astype(np.int64)
    b = dst_dc[e].astype(np.int64)
    return np.unique(np.minimum(a, b) * n_dcs + np.maximum(a, b))


def repair_layered_graph(
    lg: LayeredGraph,
    g2: Graph,
    edge_alive: np.ndarray,
    latency_interval_s: float = 0.100,
) -> Tuple[LayeredGraph, RepairStats]:
    """Incrementally repair ``lg`` after a mutation batch (paper §V update
    maintenance, layered-graph side).

    ``g2`` extends ``lg.g`` with appended vertices/edges (stable ids); dead
    edges are flagged ``~edge_alive`` and get ``edge_layer = -1``.  The DC
    components of layer ``i`` depend only on which *DC pairs* carry alive
    edges at each layer ``<= i``, so:

      * layers whose pair-presence set is unchanged keep their components and
        bridge subgraphs — only the BS edge-id lists are patched where edge
        membership changed;
      * from the lowest pair-dirty layer upward, components and BSs are
        recomputed with the exact build code path (``_grow_layers``), which
        yields output identical to a from-scratch rebuild.

    Vertex mutations never dirty components directly (components live at DC
    granularity); only cross-DC edge births/deaths in new pairs do.
    """
    env = lg.env
    thresholds_s = lg.thresholds_s
    h = lg.n_layers
    D = env.n_dcs
    m_old = lg.edge_layer.shape[0]
    m_new = g2.n_edges

    src_dc, dst_dc = g2.edge_dc_pair()

    # --- extend the layer assignment to new edges, tombstone dead ones ----
    old_alive = lg.edge_layer >= 0
    new_layer_tail, _ = _assign_edge_layers(
        src_dc[m_old:], dst_dc[m_old:], env, thresholds_s
    )
    edge_layer = np.concatenate([lg.edge_layer, new_layer_tail])
    newly_dead = np.zeros(m_new, dtype=bool)
    newly_dead[:m_old] = old_alive & ~edge_alive[:m_old]
    newly_dead[m_old:] = ~edge_alive[m_old:]
    born = np.zeros(m_new, dtype=bool)
    born[m_old:] = edge_alive[m_old:]

    touched = np.unique(
        np.concatenate([edge_layer[newly_dead], edge_layer[born]])
    ).astype(int)
    touched = [int(i) for i in touched if i >= 1]  # layer 0 has no BSs/comps

    # old pair sets must be read before tombstoning
    old_pairs = {
        i: _layer_pair_keys(
            np.where(old_alive, lg.edge_layer, -1),
            src_dc[:m_old], dst_dc[:m_old], D, i,
        )
        for i in touched
    }
    edge_layer[~edge_alive] = -1

    first_dirty: Optional[int] = None
    for i in sorted(touched):
        new_pairs = _layer_pair_keys(edge_layer, src_dc, dst_dc, D, i)
        if not np.array_equal(old_pairs[i], new_pairs):
            first_dirty = i
            break

    # --- rebuild structures: copy clean layers, regrow dirty ones ---------
    comp_of_dc = lg.comp_of_dc.copy()
    layers: List[List[BridgeSubgraph]] = [[] for _ in range(h + 1)]
    bs_by_id: Dict[int, BridgeSubgraph] = {}
    clean_top = h if first_dirty is None else first_dirty - 1
    patched = 0
    for i in range(1, clean_top + 1):
        patch = i in touched
        if patch:
            eids = np.where(edge_layer == i)[0]
            e_comp = comp_of_dc[i][src_dc[eids]]
            patched += 1
        for b in lg.layers[i]:
            if patch:
                b = dataclasses.replace(b, edge_ids=eids[e_comp == b.comp])
            layers[i].append(b)
            bs_by_id[b.bs_id] = b

    n_new_bs = 0
    if first_dirty is not None:
        next_bs = max(lg._bs_by_id.keys(), default=-1) + 1
        end_bs = _grow_layers(
            src_dc, dst_dc, edge_layer, comp_of_dc, layers, bs_by_id,
            start_layer=first_dirty, h=h, next_bs=next_bs, n_dcs=D,
        )
        n_new_bs = end_bs - next_bs

    edge_rtt = env.rtt_s[src_dc, dst_dc]
    mean_lat = _mean_layer_latency(edge_layer, edge_rtt, thresholds_s, latency_interval_s)

    lg2 = LayeredGraph(
        g=g2,
        env=env,
        thresholds_s=list(thresholds_s),
        n_layers=h,
        edge_layer=edge_layer,
        comp_of_dc=comp_of_dc,
        layers=layers,
        mean_layer_latency=mean_lat,
        _bs_by_id=bs_by_id,
    )
    stats = RepairStats(
        touched_layers=sorted(touched),
        first_dirty=first_dirty,
        relabeled_layers=0 if first_dirty is None else h - first_dirty + 1,
        patched_layers=patched,
        n_new_bs=n_new_bs,
    )
    return lg2, stats
