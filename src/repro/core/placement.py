"""Overlap-centric replica placement (paper §V, Algorithms 1-3, Eq. 13).

Flow (level-synchronous rendering of Algorithms 1+2):

1. **Sinking** (Alg. 1): each pattern enters the layer whose latency interval
   contains its SLO ``eta_p * Gamma_max`` — edges above that layer are too slow
   to cross at serve time, so the pattern is held independently by every
   requesting bridge subgraph (BS) of its target layer.
2. **Per layer k = h..1** (Alg. 2):
   * Phase 1 — every unit held by a BS is tested with the replication gain
     (Eq. 13): gain >= 0 -> full replication into all requesting child BSs
     (one layer down); gain < 0 -> deferred to the cluster's decomposition
     pool.
   * Phase 2 — each pool is split into disjoint overlap regions (Venn cells);
     per region: gain > 0 -> replicate across the cluster's requesting BSs,
     else a **DHD competition** (paper Fig. 4b): each candidate BS seeds heat
     at its current holdings, diffuses over the region graph, and the region
     goes to the BS whose heat reaches it strongest (frequency fallback).
   * Units that reach layer 0 are deposited as replicas in the DCs.
3. **Pre-caching** (§V) — steady-state DHD over the whole graph identifies
   high-heat vertices (>= theta quantile) cached at every non-owning DC.
4. **Eviction** (Alg. 3) — online heat tracking; items whose diffused heat
   falls below ``theta_c`` are evicted.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import dhd
from ..obs import get_registry
from .cost import PlacementState
from .graph import Graph
from .latency import GeoEnvironment
from .layered_graph import LayeredGraph
from .patterns import (
    OverlapRegion,
    Pattern,
    Workload,
    decompose_overlap_regions,
    region_adjacency,
)

__all__ = [
    "PlacedUnit",
    "PlacementConfig",
    "replication_gain",
    "CompetitionArena",
    "PlacementJournal",
    "overlap_centric_placement",
    "precache_hot_regions",
    "HeatCache",
    "step_heat_caches",
]


@dataclasses.dataclass
class PlacedUnit:
    """A pattern or overlap region flowing down the layered graph."""

    items: np.ndarray
    r_py: np.ndarray  # [D]
    w_py: np.ndarray  # [D]
    eta: float
    key: Tuple[int, ...]  # source pattern ids (region identity)

    @staticmethod
    def from_pattern(p: Pattern) -> "PlacedUnit":
        return PlacedUnit(
            items=p.items, r_py=p.r_py, w_py=p.w_py, eta=p.eta, key=(p.pid,)
        )


@dataclasses.dataclass
class PlacementConfig:
    gamma_max_s: float = 0.5  # latency SLO upper bound (paper: 500 ms fraud)
    lambda1: float = 0.5
    lambda2: float = 0.5
    dhd: dhd.DHDParams = dataclasses.field(default_factory=dhd.DHDParams)
    dhd_steps: int = 32
    # one batched diffusion per pool (CompetitionArena) instead of one
    # diffusion per (candidate, region); winner-identical to the sequential
    # path (differentially tested), False keeps the per-call reference
    dhd_batch: bool = True
    precache: bool = True
    theta_quantile: float = 0.55  # paper Fig. 12: 50-60% is near-optimal
    precache_max_per_dc: int = 4096


# ------------------------------------------------------------------ Eq. (13)
def replication_gain(
    unit: PlacedUnit,
    holder_dcs: np.ndarray,
    children_dcs: List[np.ndarray],
    sizes: np.ndarray,
    env: GeoEnvironment,
    lambda1: float = 0.5,
    primary: Optional[np.ndarray] = None,
) -> float:
    """Surrogate replication gain (Eq. 13) of fully replicating ``unit``
    into each requesting child region.

    gain = dC^R (cross-reads become local) + dC^A (lambda1 * eliminated
    cross-BS routings) - dC^S (added storage) - dC^W (added sync).
    Prices are averaged over the concrete DC pairs involved, so the surrogate
    tracks the real cost model's geometry (cluster-local, Appendix D).
    """
    items = unit.items
    item_sizes = sizes[items]
    size_sum = float(item_sizes.sum())
    n_items = len(items)
    holder = np.unique(np.asarray(holder_dcs, dtype=np.int64))
    w_total = float(unit.w_py.sum())
    primary_items = primary[items] if primary is not None else None
    gain = 0.0
    for child in children_dcs:
        child_arr = np.asarray(child, dtype=np.int64)
        r_c = float(unit.r_py[child_arr].sum())
        if r_c <= 0:
            continue
        # reads of items whose primary already sits in the child region are
        # local without a replica — only *remote* bytes produce savings
        # (without this the surrogate over-replicates write-heavy patterns;
        # measured: Fig. 9 optimality gap 20.7% -> see bench_output)
        if primary_items is not None:
            size_remote = float(item_sizes[~np.isin(primary_items, child_arr)].sum())
        else:
            size_remote = size_sum
        outside = holder[~np.isin(holder, child_arr)]
        if len(outside) == 0:
            outside = holder
        # mean $/byte of the cross-cluster paths this replication removes
        net_mean = float(env.c_net[np.ix_(outside, child_arr)].mean())
        store_mean = float(env.c_store[child_arr].mean())
        put_mean = float(env.c_write[child_arr].mean())
        read_save = r_c * size_remote * net_mean
        assoc_save = lambda1 * r_c * n_items * 1e-6  # assoc unit ~ per-M GETs
        store_add = size_sum * store_mean
        write_add = w_total * (put_mean * n_items + size_remote * net_mean)
        gain += read_save + assoc_save - store_add - write_add
    return gain


# ----------------------------------------------------------- DHD competition
def _dhd_competition(
    region: OverlapRegion,
    candidates: List[Tuple[int, np.ndarray, List[np.ndarray]]],
    all_regions: Sequence[OverlapRegion],
    g: Graph,
    params: dhd.DHDParams,
    n_steps: int,
    unit_r: np.ndarray,
) -> int:
    """Pick the winning candidate (index into ``candidates``) for ``region``.

    ``candidates`` entries are (bs_index, dcs, held_item_arrays).  Each
    candidate seeds heat at a super-node representing its current holdings
    connected to the candidate regions by graph-edge counts (Fig. 4b); the
    region goes to the candidate whose diffused heat at it is largest.
    Fallback: total access frequency of the candidate's DCs for the region.
    """
    n_regions = len(all_regions)
    rsrc, rdst, rw = region_adjacency(all_regions, g)
    item_region = np.full(g.n_items, -1, dtype=np.int64)
    for r in all_regions:
        item_region[r.items] = r.rid
    scores = []
    for (_, dcs, held_items) in candidates:
        if held_items:
            held = np.unique(np.concatenate(held_items))
        else:
            held = np.zeros(0, dtype=np.int64)
        if len(held) == 0 or len(rsrc) == 0:
            scores.append(-1.0)
            continue
        # connect the holdings super-node (id = n_regions) to regions that
        # share graph edges with the held items
        held_mask = np.zeros(g.n_items, dtype=bool)
        held_mask[held] = True
        touch_src = held_mask[g.src] & (item_region[g.dst] >= 0)
        touch_dst = held_mask[g.dst] & (item_region[g.src] >= 0)
        extra: Dict[int, float] = {}
        for rid in item_region[g.dst[touch_src]]:
            extra[int(rid)] = extra.get(int(rid), 0.0) + 1.0
        for rid in item_region[g.src[touch_dst]]:
            extra[int(rid)] = extra.get(int(rid), 0.0) + 1.0
        if not extra:
            scores.append(-1.0)
            continue
        esrc = np.array([n_regions] * len(extra), dtype=np.int64)
        edst = np.array(list(extra.keys()), dtype=np.int64)
        ew = np.array(list(extra.values()), dtype=np.float32)
        seed = np.zeros(n_regions + 1, dtype=np.float32)
        seed[n_regions] = 1.0
        heat = dhd.diffuse_affinity(
            n_regions + 1,
            np.concatenate([rsrc, esrc]),
            np.concatenate([rdst, edst]),
            np.concatenate([rw, ew]),
            seed,
            params=params,
            n_steps=n_steps,
        )
        scores.append(float(heat[region.rid]))
    scores_arr = np.asarray(scores)
    if scores_arr.max() > 0:
        return int(scores_arr.argmax())
    # unreachable by heat -> frequency of the candidate DCs for this region
    freq = [float(unit_r[dcs].sum()) for (_, dcs, _) in candidates]
    return int(np.asarray(freq).argmax())


# --------------------------------------------------- batched DHD competition
class CompetitionArena:
    """Per-pool batched DHD competition (one diffusion for every candidate).

    A candidate's diffused heat field depends only on the region graph, its
    own super-node edges and the (shared) seed — *not* on which region is
    being contested.  So a pool with R regions and C candidates needs C
    diffusions, not R x C: the arena hoists ``region_adjacency`` once, builds
    every candidate's super-node edge weights with ``np.add.at`` over a
    shared edge-list union (weight 0 = edge absent for that candidate, see
    the weight gate in :func:`repro.core.dhd.dhd_step_edges`), and runs ONE
    batched diffusion producing a ``[C, R+1]`` heat table.  Per-region
    winners read from the table with exactly the scoring/fallback rules of
    :func:`_dhd_competition`.
    """

    def __init__(
        self,
        regions: Sequence[OverlapRegion],
        g: Graph,
        candidates: List[Tuple[int, np.ndarray, List[np.ndarray]]],
        params: dhd.DHDParams,
        n_steps: int,
        heat_valid: Optional[Tuple[Optional[np.ndarray], np.ndarray]] = None,
    ) -> None:
        self.candidates = candidates
        self.n_regions = len(regions)
        if heat_valid is None:
            heat_valid = self._build(regions, g, candidates, params, n_steps)
        self.heat, self.valid = heat_valid

    @staticmethod
    def _build(
        regions: Sequence[OverlapRegion],
        g: Graph,
        candidates: List[Tuple[int, np.ndarray, List[np.ndarray]]],
        params: dhd.DHDParams,
        n_steps: int,
    ) -> Tuple[Optional[np.ndarray], np.ndarray]:
        reg = get_registry()
        if not reg.enabled:
            return CompetitionArena._build_impl(
                regions, g, candidates, params, n_steps
            )
        t0 = time.perf_counter()
        out = CompetitionArena._build_impl(regions, g, candidates, params, n_steps)
        reg.histogram("placement.arena_build_s").observe(time.perf_counter() - t0)
        reg.counter("placement.arena_builds").inc()
        reg.counter("placement.diffusion_candidates").inc(len(candidates))
        return out

    @staticmethod
    def _build_impl(
        regions: Sequence[OverlapRegion],
        g: Graph,
        candidates: List[Tuple[int, np.ndarray, List[np.ndarray]]],
        params: dhd.DHDParams,
        n_steps: int,
    ) -> Tuple[Optional[np.ndarray], np.ndarray]:
        n_regions = len(regions)
        n_cand = len(candidates)
        valid = np.zeros(n_cand, dtype=bool)
        rsrc, rdst, rw = region_adjacency(regions, g)
        if len(rsrc) == 0:  # heat cannot reach anything -> frequency fallback
            return None, valid
        item_region = np.full(g.n_items, -1, dtype=np.int64)
        for r in regions:
            item_region[r.items] = r.rid
        src_reg = item_region[g.src]
        dst_reg = item_region[g.dst]
        # super-node edge weights per candidate: graph-edge counts between
        # the candidate's holdings and each region (Fig. 4b), segment-summed
        cnt = np.zeros((n_cand, n_regions), dtype=np.float32)
        held_mask = np.zeros(g.n_items, dtype=bool)
        for ci, (_, _, held_items) in enumerate(candidates):
            if not held_items:
                continue
            held = np.concatenate(held_items)
            if len(held) == 0:
                continue
            held_mask[:] = False
            held_mask[held] = True
            touch_src = held_mask[g.src] & (dst_reg >= 0)
            touch_dst = held_mask[g.dst] & (src_reg >= 0)
            np.add.at(cnt[ci], dst_reg[touch_src], 1.0)
            np.add.at(cnt[ci], src_reg[touch_dst], 1.0)
            valid[ci] = bool(cnt[ci].any())
        if not valid.any():
            return None, valid
        # shared edge-list union: region edges + every super edge any
        # candidate uses; per-candidate weights switch its own super edges on
        touched = np.where(cnt.any(axis=0))[0]
        usrc = np.concatenate([rsrc, np.full(len(touched), n_regions, dtype=np.int64)])
        udst = np.concatenate([rdst, touched])
        weights = np.empty((n_cand, len(usrc)), dtype=np.float32)
        weights[:, : len(rw)] = rw[None, :]
        weights[:, len(rw):] = cnt[:, touched]
        seeds = np.zeros((n_cand, n_regions + 1), dtype=np.float32)
        seeds[:, n_regions] = 1.0
        heat = dhd.diffuse_affinity_batch(
            n_regions + 1, usrc, udst, weights, seeds,
            params=params, n_steps=n_steps,
        )
        return heat, valid

    def winner(self, rid: int, req: Sequence[int], unit_r: np.ndarray) -> int:
        """Winning position within ``req`` (candidate indices contesting
        region ``rid``) — same scoring and frequency fallback as
        :func:`_dhd_competition` over the same candidate order."""
        if self.heat is not None:
            scores = np.asarray(
                [self.heat[i, rid] if self.valid[i] else -1.0 for i in req]
            )
            if scores.max() > 0:
                return int(scores.argmax())
        freq = [float(unit_r[self.candidates[i][1]].sum()) for i in req]
        return int(np.asarray(freq).argmax())


# ------------------------------------------------------- placement journal
def _digest(*arrays: np.ndarray) -> bytes:
    h = hashlib.blake2b(digest_size=16)
    for a in arrays:
        b = np.ascontiguousarray(a)
        h.update(str(b.dtype).encode())
        h.update(str(b.shape).encode())
        h.update(b.tobytes())
    return h.digest()


def _unit_fp(u: PlacedUnit, uid: Optional[np.ndarray] = None) -> Tuple:
    items = uid[u.items] if uid is not None else u.items
    return (u.key, float(u.eta), _digest(items, u.r_py, u.w_py))


def _cand_fp(
    cand: List[Tuple[int, np.ndarray, List[np.ndarray]]],
    uid: Optional[np.ndarray] = None,
) -> Tuple:
    return tuple(
        (cid, _digest(dcs),
         tuple(_digest(uid[h] if uid is not None else h) for h in held))
        for (cid, dcs, held) in cand
    )


class PlacementJournal:
    """Memo of placement intermediates keyed on their *exact* inputs.

    Algorithms 1+2 are deterministic, so any intermediate whose inputs are
    unchanged between two runs can be replayed from the journal instead of
    recomputed.  :meth:`GeoGraphStore.insert_patterns_incremental` exploits
    this: re-running placement over the extended workload only pays for the
    pools the new patterns actually touch (decomposition, region adjacency
    and the batched DHD heat table are all journal hits elsewhere), which is
    what makes the result provably identical to a full re-place.

    Keys fingerprint unit items/frequencies and candidate holdings with
    BLAKE2 digests.  When ``item_uid`` is set (the store maintains one
    monotonically-assigned uid per item row), digests run over *uids* rather
    than raw row indices — raw rows renumber on compaction, uids never do —
    which makes every key **fingerprint-stable across**
    ``GeoGraphStore._compact_in_place``: the store calls :meth:`remap` to
    rewrite the row-indexed memo *values* (region item arrays) onto the
    compacted id space and every key keeps matching.  Topology changes
    (mutation batches) still discard the journal: region adjacency and heat
    tables depend on the edge set itself, not just the pool's items.  Each
    memo table is FIFO-bounded (``max_entries``) so repeated incremental
    inserts — which retire old fingerprints every round — cannot grow it
    without bound; evicted entries simply recompute on next use.
    """

    def __init__(self, max_entries: int = 4096) -> None:
        self.max_entries = max_entries
        self.regions: Dict[Tuple, List[OverlapRegion]] = {}
        self.heat: Dict[Tuple, Tuple[Optional[np.ndarray], np.ndarray]] = {}
        self.gain: Dict[Tuple, float] = {}
        self.hits = 0
        self.misses = 0
        # [n_items] content-stable uid per item row; owned by the store
        self.item_uid: Optional[np.ndarray] = None

    def stats(self) -> Dict[str, int]:
        return dict(hits=self.hits, misses=self.misses,
                    pools=len(self.regions), heats=len(self.heat))

    def unit_fp(self, u: PlacedUnit) -> Tuple:
        return _unit_fp(u, self.item_uid)

    def cand_fp(self, cand: List[Tuple[int, np.ndarray, List[np.ndarray]]]) -> Tuple:
        return _cand_fp(cand, self.item_uid)

    def remap(self, imap: np.ndarray, item_uid: np.ndarray) -> None:
        """Re-key row-indexed memo values onto a compacted id space.

        ``imap[old_row] -> new_row`` (-1 = dropped).  Keys are uid-digests
        and survive untouched; only region item arrays store raw rows
        (compaction renumbers monotonically, so remapped arrays stay sorted
        — the decompose invariant).  Gains are scalars over sizes/prices
        that compaction preserves and survive too.  Heat tables do NOT:
        ``region_adjacency`` runs over the raw edge arrays, which before
        compaction still contain tombstoned edges — a post-compaction
        recompute would exclude them, so memoized tables are cleared rather
        than replayed stale."""
        for regions in self.regions.values():
            for r in regions:
                it = imap[r.items]
                r.items = it[it >= 0]
        self.heat.clear()
        self.item_uid = item_uid

    def memo(self, cache: Dict, key: Tuple, compute):
        hit = cache.get(key)
        if hit is not None:
            self.hits += 1
            return hit
        self.misses += 1
        out = compute()
        cache[key] = out
        while len(cache) > self.max_entries:  # FIFO: dicts keep insert order
            cache.pop(next(iter(cache)))
        return out


# ------------------------------------------------------- main placement flow
def overlap_centric_placement(
    lg: LayeredGraph,
    workload: Workload,
    config: Optional[PlacementConfig] = None,
    journal: Optional[PlacementJournal] = None,
    route: bool = True,
) -> Tuple[PlacementState, Dict[str, object]]:
    """Algorithms 1 + 2 end-to-end.  Returns (placement state, stats).

    ``journal`` memoizes pool decompositions, replication gains and DHD heat
    tables across runs (see :class:`PlacementJournal`); ``route=False`` skips
    the final nearest-replica table derivation for callers that patch an
    existing :class:`~repro.core.route_index.RouteIndex` instead."""
    cfg = config or PlacementConfig()
    g, env = lg.g, lg.env
    sizes = g.item_size()
    D = env.n_dcs
    state = PlacementState.empty(g.n_items, D)
    # journal counters persist across placements; track this run's delta
    j_hits0 = journal.hits if journal is not None else 0
    j_miss0 = journal.misses if journal is not None else 0

    # primary copies: each vertex at its partition DC, each edge at src's DC
    state.delta[np.arange(g.n_nodes), g.partition] = True
    state.delta[g.n_nodes + np.arange(g.n_edges), g.partition[g.src]] = True
    primary = np.concatenate([g.partition, g.partition[g.src]]).astype(np.int64)

    # holdings[k][id] -> list of units.  At k>0 id = bs_id; at k=0 id = dc.
    h = lg.n_layers
    holdings: List[Dict[int, List[PlacedUnit]]] = [dict() for _ in range(h + 1)]
    pools: List[Dict[int, List[Tuple[int, PlacedUnit]]]] = [dict() for _ in range(h + 1)]
    stats = dict(replicated=0, decomposed=0, regions=0, competitions=0, skipped_w=0)

    def requesting_dcs(unit: PlacedUnit, dcs: np.ndarray) -> np.ndarray:
        return dcs[unit.r_py[dcs] > 0]

    # ---- Alg. 1: sink each pattern to its target layer -------------------
    for p in workload.patterns:
        if p.read_rate <= p.write_rate:  # Alg. 2 precondition R > W
            stats["skipped_w"] += 1
            continue
        unit = PlacedUnit.from_pattern(p)
        k_star = lg.layer_for_latency(p.eta * cfg.gamma_max_s)
        placed = False
        for b in lg.layers[k_star]:
            if len(requesting_dcs(unit, b.dcs)):
                holdings[k_star].setdefault(b.bs_id, []).append(unit)
                placed = True
        if not placed:  # requesting DC isolated at this layer -> direct deposit
            for dc in np.where(p.r_py > 0)[0]:
                holdings[0].setdefault(int(dc), []).append(unit)

    # ---- Alg. 2: layer-by-layer placement --------------------------------
    for k in range(h, 0, -1):
        # Phase 1: replication-vs-decomposition per held unit
        for bs_id, units in list(holdings[k].items()):
            b = lg.bs(bs_id)
            children = lg.bs_children(b)
            for unit in units:
                if k == 1 or not children:
                    # children are the DCs of this BS's cluster
                    child_dcs = [np.asarray([int(d)]) for d in b.dcs
                                 if unit.r_py[int(d)] > 0]
                    child_ids = [int(d) for d in b.dcs if unit.r_py[int(d)] > 0]
                    to_layer = 0
                else:
                    kids = [c for c in children if len(requesting_dcs(unit, c.dcs))]
                    child_dcs = [c.dcs for c in kids]
                    child_ids = [c.bs_id for c in kids]
                    to_layer = k - 1
                if not child_ids:
                    continue
                if journal is not None:
                    gkey = (journal.unit_fp(unit), bs_id, tuple(child_ids), to_layer)
                    gain = journal.memo(
                        journal.gain, gkey,
                        lambda: replication_gain(
                            unit, b.dcs, child_dcs, sizes, env, cfg.lambda1, primary
                        ),
                    )
                else:
                    gain = replication_gain(
                        unit, b.dcs, child_dcs, sizes, env, cfg.lambda1, primary
                    )
                if gain >= 0:
                    stats["replicated"] += 1
                    for cid in child_ids:
                        holdings[to_layer].setdefault(cid, []).append(unit)
                else:
                    stats["decomposed"] += 1
                    pools[k].setdefault(b.comp, []).append((bs_id, unit))
        holdings[k].clear()

        # Phase 2: overlap-region allocation within each cluster
        for comp, entries in list(pools[k].items()):
            units = [u for (_, u) in entries]
            pool_fp = (
                (k, comp, tuple((bs, journal.unit_fp(u)) for (bs, u) in entries))
                if journal is not None else None
            )
            def _decompose():
                pseudo = [
                    Pattern(pid=i, items=u.items, r_py=u.r_py, w_py=u.w_py, eta=u.eta)
                    for i, u in enumerate(units)
                ]
                return decompose_overlap_regions(pseudo, g.n_items)
            if journal is not None:
                regions = journal.memo(journal.regions, pool_fp, _decompose)
            else:
                regions = _decompose()
            stats["regions"] += len(regions)
            b_holder = next(bb for bb in lg.layers[k] if bb.comp == comp)
            children = lg.bs_children(b_holder)
            if k == 1 or not children:
                cand = [
                    (int(d), np.asarray([int(d)]), [u.items for u in holdings[0].get(int(d), [])])
                    for d in b_holder.dcs
                ]
                to_layer = 0
            else:
                cand = [
                    (c.bs_id, c.dcs, [u.items for u in holdings[k - 1].get(c.bs_id, [])])
                    for c in children
                ]
                to_layer = k - 1
            # one batched diffusion covers every competition in this pool;
            # built lazily so pools that fully replicate never pay for it
            arena: Optional[CompetitionArena] = None

            def _get_arena() -> CompetitionArena:
                nonlocal arena
                if arena is None:
                    if journal is not None:
                        hv = journal.memo(
                            journal.heat, (pool_fp, journal.cand_fp(cand)),
                            lambda: CompetitionArena._build(
                                regions, g, cand, cfg.dhd, cfg.dhd_steps
                            ),
                        )
                        arena = CompetitionArena(
                            regions, g, cand, cfg.dhd, cfg.dhd_steps, heat_valid=hv
                        )
                    else:
                        arena = CompetitionArena(
                            regions, g, cand, cfg.dhd, cfg.dhd_steps
                        )
                return arena

            for region in regions:
                pids = region.key
                r_py = np.sum([units[i].r_py for i in pids], axis=0)
                w_py = np.sum([units[i].w_py for i in pids], axis=0)
                runit = PlacedUnit(
                    items=region.items, r_py=r_py, w_py=w_py,
                    eta=min(units[i].eta for i in pids),
                    key=tuple(sorted(set(sum((units[i].key for i in pids), ())))),
                )
                req_idx = [
                    i for i, (cid, dcs, held) in enumerate(cand)
                    if r_py[dcs].sum() > 0
                ]
                if not req_idx:
                    continue
                req = [cand[i] for i in req_idx]
                if journal is not None:
                    gkey = (
                        journal.unit_fp(runit), b_holder.bs_id,
                        tuple(cand[i][0] for i in req_idx), to_layer,
                    )
                    gain = journal.memo(
                        journal.gain, gkey,
                        lambda: replication_gain(
                            runit, b_holder.dcs, [d for (_, d, _) in req],
                            sizes, env, cfg.lambda1, primary,
                        ),
                    )
                else:
                    gain = replication_gain(
                        runit, b_holder.dcs, [d for (_, d, _) in req], sizes, env,
                        cfg.lambda1, primary,
                    )
                if gain > 0:
                    stats["replicated"] += 1
                    targets = [cid for (cid, _, _) in req]
                else:
                    stats["competitions"] += 1
                    if cfg.dhd_batch:
                        win = _get_arena().winner(region.rid, req_idx, r_py)
                    else:
                        win = _dhd_competition(
                            region, req, regions, g, cfg.dhd, cfg.dhd_steps, r_py
                        )
                    targets = [req[win][0]]
                for cid in targets:
                    holdings[to_layer].setdefault(cid, []).append(runit)
            pools[k].pop(comp)

    # ---- deposit layer-0 holdings as replicas -----------------------------
    for dc, units in holdings[0].items():
        for u in units:
            state.delta[u.items, int(dc)] = True

    # ---- Phase 3: pre-caching (paper §V) ----------------------------------
    if cfg.precache:
        precache_hot_regions(
            g, workload, state, cfg.theta_quantile, cfg.dhd,
            max_per_dc=cfg.precache_max_per_dc,
        )

    if journal is not None:
        stats["journal"] = journal.stats()
        reg = get_registry()
        if reg.enabled:
            reg.counter("placement.journal_hits").inc(journal.hits - j_hits0)
            reg.counter("placement.journal_misses").inc(journal.misses - j_miss0)
    if route:
        state.route_nearest(env)
    return state, stats


# ----------------------------------------------------------------- pre-cache
def precache_hot_regions(
    g: Graph,
    workload: Workload,
    state: PlacementState,
    theta_quantile: float = 0.55,
    params: dhd.DHDParams = dhd.DHDParams(),
    n_steps: int = 48,
    max_per_dc: int = 4096,
    read_intensity: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Steady-state DHD over the whole graph; cache vertices whose equilibrium
    heat is >= the ``theta_quantile`` of the heat distribution at every DC
    that does not own them (bounded by ``max_per_dc``).  Returns hot-vertex ids.

    ``read_intensity`` injects the ``[n_items]`` per-item demand the DHD
    seeds/edge weights derive from — a measured or *forecast* view from the
    demand plane (``ODDemandLayer.measured()/forecast().item_heat``).  The
    default reads the static workload tables, which is bit-identical to the
    pre-demand-plane behavior.
    """
    if read_intensity is None:
        r_v = workload.r_xy[: g.n_nodes].sum(axis=1).astype(np.float32)
        w_raw = workload.r_xy[g.n_nodes :].sum(axis=1).astype(np.float32)
    else:
        ri = np.asarray(read_intensity, dtype=np.float32)
        r_v = ri[: g.n_nodes]
        w_raw = ri[g.n_nodes :]
    if r_v.max() <= 0:
        return np.zeros(0, dtype=np.int64)
    heat0 = r_v / r_v.max()
    theta = float(np.quantile(heat0[heat0 > 0], theta_quantile)) if (heat0 > 0).any() else 0.0
    sources = heat0 >= theta
    q0 = np.where(sources, 1.0 / max(sources.sum(), 1), 0.0).astype(np.float32)
    w_e = w_raw / max(w_raw.max(), 1.0) + 1e-3
    heat = dhd.diffuse_affinity_batch(
        g.n_nodes, g.src, g.dst, w_e, q0[None, :], base_heat=heat0,
        params=params, n_steps=n_steps,
    )[0]
    theta_star = float(np.quantile(heat, theta_quantile))
    hot = np.where(heat >= theta_star)[0]
    if len(hot) > max_per_dc:
        hot = hot[np.argsort(-heat[hot])[:max_per_dc]]
    for d in range(state.delta.shape[1]):
        ext = hot[g.partition[hot] != d]
        state.delta[ext, d] = True
    return hot


# ------------------------------------------------------------------ eviction
class HeatCache:
    """Online replica eviction (Alg. 3): heat-tracked cache per DC.

    The cache does not own its heat array: ``heat`` is a shared-storage row
    view into the store's :class:`~repro.demand.ODDemandLayer` (the single
    owner of online request heat).  Standalone construction (tests, ad-hoc
    use) gets a private single-row demand layer, so the Alg. 3 semantics are
    identical either way — accumulate via ``observe``, diffuse via ``step``,
    evict below ``theta_c``."""

    def __init__(
        self,
        g: Graph,
        dc: int,
        state: PlacementState,
        params: dhd.DHDParams = dhd.DHDParams(),
        theta_c: float = 0.05,
        demand=None,
    ) -> None:
        self.g = g
        self.dc = dc
        self.state = state
        self.params = params
        self.theta_c = theta_c
        if demand is None:
            # standalone cache: private single-row demand layer (row 0)
            from ..demand import ODDemandLayer

            demand = ODDemandLayer(g.n_items, 1)
            self._row = 0
        else:
            self._row = dc
        self.demand = demand
        # streaming stores set this to the alive mask so diffusion never
        # crosses tombstoned edges; None = static graph, all edges live
        self.edge_mask: Optional[np.ndarray] = None

    @property
    def heat(self) -> np.ndarray:
        """This DC's row of the demand plane's ``[D, n_items]`` heat table —
        a view, not a copy: in-place mutation (diffusion, decay) writes
        through, and there is no second array to fall out of sync."""
        return self.demand.heat[self._row]

    def cached_mask(self) -> np.ndarray:
        """Replicas held at this DC beyond the primary partition copy."""
        primary = np.zeros(self.g.n_items, dtype=bool)
        primary[: self.g.n_nodes] = self.g.partition == self.dc
        primary[self.g.n_nodes :] = self.g.partition[self.g.src] == self.dc
        return self.state.delta[:, self.dc] & ~primary

    def observe(self, item_ids: np.ndarray, freq: float = 1.0) -> None:
        """External heat injection: one access event batch (Alg. 3 lines 3-5).

        Delegates to the demand plane — the one place accumulation happens —
        where duplicate ids accumulate (``serve_batch`` concatenates
        per-origin request items), which fancy-index ``+=`` would silently
        collapse."""
        self.demand.observe(item_ids, origin=self._row, freq=freq)

    def step(self, n_steps: int = 4) -> None:
        """Diffuse heat over the cache topology (vertex items only)."""
        step_heat_caches([self], n_steps=n_steps)

    def evict(self) -> np.ndarray:
        """Remove cold replicas; returns evicted item ids (Alg. 3 lines 7-10).

        The caller (``GeoGraphStore.maintain``) refreshes the routing table
        after eviction, matching Alg. 3 line 10."""
        cold = self.cached_mask() & (self.heat < self.theta_c)
        ids = np.where(cold)[0]
        self.state.delta[ids, self.dc] = False
        return ids


def step_heat_caches(caches: Sequence[HeatCache], n_steps: int = 4) -> None:
    """Diffuse every cache's heat field in ONE batched DHD run.

    All per-DC caches of a store share the same graph, edge mask and params,
    so their Alg. 3 diffusions differ only in the seed heat — a ``[D, n]``
    batch through :func:`repro.core.dhd.diffuse_affinity_batch`.  Caches
    with differing topology fall back to individual runs.  Row ``d`` equals
    what ``caches[d].step(n_steps)`` alone would produce."""
    if not caches:
        return
    lead = caches[0]
    shared = all(
        c.g is lead.g and c.edge_mask is lead.edge_mask and c.params == lead.params
        for c in caches[1:]
    )
    if not shared:
        for c in caches:
            step_heat_caches([c], n_steps=n_steps)
        return
    g = lead.g
    if lead.edge_mask is not None:
        src, dst = g.src[lead.edge_mask], g.dst[lead.edge_mask]
    else:
        src, dst = g.src, g.dst
    n = g.n_nodes
    seeds = np.stack([c.heat[:n] for c in caches])
    h = dhd.diffuse_affinity_batch(
        n, src, dst, np.ones(len(src), dtype=np.float32), seeds,
        params=lead.params, n_steps=n_steps,
    )
    decay = (1.0 - lead.params.gamma) ** n_steps
    # heat is single-owned by the demand layer: diffusion results go back
    # through its write-back, never through the HeatCache.heat view (GL003)
    for c, row in zip(caches, h):
        c.demand.apply_diffusion(c._row, row, decay)
