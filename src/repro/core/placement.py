"""Overlap-centric replica placement (paper §V, Algorithms 1-3, Eq. 13).

Flow (level-synchronous rendering of Algorithms 1+2):

1. **Sinking** (Alg. 1): each pattern enters the layer whose latency interval
   contains its SLO ``eta_p * Gamma_max`` — edges above that layer are too slow
   to cross at serve time, so the pattern is held independently by every
   requesting bridge subgraph (BS) of its target layer.
2. **Per layer k = h..1** (Alg. 2):
   * Phase 1 — every unit held by a BS is tested with the replication gain
     (Eq. 13): gain >= 0 -> full replication into all requesting child BSs
     (one layer down); gain < 0 -> deferred to the cluster's decomposition
     pool.
   * Phase 2 — each pool is split into disjoint overlap regions (Venn cells);
     per region: gain > 0 -> replicate across the cluster's requesting BSs,
     else a **DHD competition** (paper Fig. 4b): each candidate BS seeds heat
     at its current holdings, diffuses over the region graph, and the region
     goes to the BS whose heat reaches it strongest (frequency fallback).
   * Units that reach layer 0 are deposited as replicas in the DCs.
3. **Pre-caching** (§V) — steady-state DHD over the whole graph identifies
   high-heat vertices (>= theta quantile) cached at every non-owning DC.
4. **Eviction** (Alg. 3) — online heat tracking; items whose diffused heat
   falls below ``theta_c`` are evicted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import dhd
from .cost import PlacementState
from .graph import Graph
from .latency import GeoEnvironment
from .layered_graph import BridgeSubgraph, LayeredGraph
from .patterns import (
    OverlapRegion,
    Pattern,
    Workload,
    decompose_overlap_regions,
    region_adjacency,
)

__all__ = [
    "PlacedUnit",
    "PlacementConfig",
    "replication_gain",
    "overlap_centric_placement",
    "precache_hot_regions",
    "HeatCache",
]


@dataclasses.dataclass
class PlacedUnit:
    """A pattern or overlap region flowing down the layered graph."""

    items: np.ndarray
    r_py: np.ndarray  # [D]
    w_py: np.ndarray  # [D]
    eta: float
    key: Tuple[int, ...]  # source pattern ids (region identity)

    @staticmethod
    def from_pattern(p: Pattern) -> "PlacedUnit":
        return PlacedUnit(
            items=p.items, r_py=p.r_py, w_py=p.w_py, eta=p.eta, key=(p.pid,)
        )


@dataclasses.dataclass
class PlacementConfig:
    gamma_max_s: float = 0.5  # latency SLO upper bound (paper: 500 ms fraud)
    lambda1: float = 0.5
    lambda2: float = 0.5
    dhd: dhd.DHDParams = dataclasses.field(default_factory=dhd.DHDParams)
    dhd_steps: int = 32
    precache: bool = True
    theta_quantile: float = 0.55  # paper Fig. 12: 50-60% is near-optimal
    precache_max_per_dc: int = 4096


# ------------------------------------------------------------------ Eq. (13)
def replication_gain(
    unit: PlacedUnit,
    holder_dcs: np.ndarray,
    children_dcs: List[np.ndarray],
    sizes: np.ndarray,
    env: GeoEnvironment,
    lambda1: float = 0.5,
    primary: Optional[np.ndarray] = None,
) -> float:
    """Surrogate replication gain (Eq. 13) of fully replicating ``unit``
    into each requesting child region.

    gain = dC^R (cross-reads become local) + dC^A (lambda1 * eliminated
    cross-BS routings) - dC^S (added storage) - dC^W (added sync).
    Prices are averaged over the concrete DC pairs involved, so the surrogate
    tracks the real cost model's geometry (cluster-local, Appendix D).
    """
    items = unit.items
    size_sum = float(sizes[items].sum())
    n_items = len(items)
    holder_set = set(int(d) for d in holder_dcs)
    gain = 0.0
    for child in children_dcs:
        child_list = [int(d) for d in child]
        r_c = float(unit.r_py[child].sum())
        if r_c <= 0:
            continue
        # reads of items whose primary already sits in the child region are
        # local without a replica — only *remote* bytes produce savings
        # (without this the surrogate over-replicates write-heavy patterns;
        # measured: Fig. 9 optimality gap 20.7% -> see bench_output)
        if primary is not None:
            remote = ~np.isin(primary[items], child)
            size_remote = float(sizes[items[remote]].sum())
        else:
            size_remote = size_sum
        w_total = float(unit.w_py.sum())
        outside = [d for d in holder_set if d not in child_list] or list(holder_set)
        # mean $/byte of the cross-cluster paths this replication removes
        net_mean = float(np.mean([[env.c_net[o, c] for o in outside] for c in child_list]))
        store_mean = float(np.mean([env.c_store[c] for c in child_list]))
        put_mean = float(np.mean([env.c_write[c] for c in child_list]))
        read_save = r_c * size_remote * net_mean
        assoc_save = lambda1 * r_c * n_items * 1e-6  # assoc unit ~ per-M GETs
        store_add = size_sum * store_mean
        write_add = w_total * (put_mean * n_items + size_remote * net_mean)
        gain += read_save + assoc_save - store_add - write_add
    return gain


# ----------------------------------------------------------- DHD competition
def _dhd_competition(
    region: OverlapRegion,
    candidates: List[Tuple[int, np.ndarray, List[np.ndarray]]],
    all_regions: Sequence[OverlapRegion],
    g: Graph,
    params: dhd.DHDParams,
    n_steps: int,
    unit_r: np.ndarray,
) -> int:
    """Pick the winning candidate (index into ``candidates``) for ``region``.

    ``candidates`` entries are (bs_index, dcs, held_item_arrays).  Each
    candidate seeds heat at a super-node representing its current holdings
    connected to the candidate regions by graph-edge counts (Fig. 4b); the
    region goes to the candidate whose diffused heat at it is largest.
    Fallback: total access frequency of the candidate's DCs for the region.
    """
    n_regions = len(all_regions)
    rsrc, rdst, rw = region_adjacency(all_regions, g)
    item_region = np.full(g.n_items, -1, dtype=np.int64)
    for r in all_regions:
        item_region[r.items] = r.rid
    scores = []
    for (_, dcs, held_items) in candidates:
        if held_items:
            held = np.unique(np.concatenate(held_items))
        else:
            held = np.zeros(0, dtype=np.int64)
        if len(held) == 0 or len(rsrc) == 0:
            scores.append(-1.0)
            continue
        # connect the holdings super-node (id = n_regions) to regions that
        # share graph edges with the held items
        held_mask = np.zeros(g.n_items, dtype=bool)
        held_mask[held] = True
        touch_src = held_mask[g.src] & (item_region[g.dst] >= 0)
        touch_dst = held_mask[g.dst] & (item_region[g.src] >= 0)
        extra: Dict[int, float] = {}
        for rid in item_region[g.dst[touch_src]]:
            extra[int(rid)] = extra.get(int(rid), 0.0) + 1.0
        for rid in item_region[g.src[touch_dst]]:
            extra[int(rid)] = extra.get(int(rid), 0.0) + 1.0
        if not extra:
            scores.append(-1.0)
            continue
        esrc = np.array([n_regions] * len(extra), dtype=np.int64)
        edst = np.array(list(extra.keys()), dtype=np.int64)
        ew = np.array(list(extra.values()), dtype=np.float32)
        seed = np.zeros(n_regions + 1, dtype=np.float32)
        seed[n_regions] = 1.0
        heat = dhd.diffuse_affinity(
            n_regions + 1,
            np.concatenate([rsrc, esrc]),
            np.concatenate([rdst, edst]),
            np.concatenate([rw, ew]),
            seed,
            params=params,
            n_steps=n_steps,
        )
        scores.append(float(heat[region.rid]))
    scores_arr = np.asarray(scores)
    if scores_arr.max() > 0:
        return int(scores_arr.argmax())
    # unreachable by heat -> frequency of the candidate DCs for this region
    freq = [float(unit_r[dcs].sum()) for (_, dcs, _) in candidates]
    return int(np.asarray(freq).argmax())


# ------------------------------------------------------- main placement flow
def overlap_centric_placement(
    lg: LayeredGraph,
    workload: Workload,
    config: Optional[PlacementConfig] = None,
) -> Tuple[PlacementState, Dict[str, object]]:
    """Algorithms 1 + 2 end-to-end.  Returns (placement state, stats)."""
    cfg = config or PlacementConfig()
    g, env = lg.g, lg.env
    sizes = g.item_size()
    D = env.n_dcs
    state = PlacementState.empty(g.n_items, D)

    # primary copies: each vertex at its partition DC, each edge at src's DC
    state.delta[np.arange(g.n_nodes), g.partition] = True
    state.delta[g.n_nodes + np.arange(g.n_edges), g.partition[g.src]] = True
    primary = np.concatenate([g.partition, g.partition[g.src]]).astype(np.int64)

    # holdings[k][id] -> list of units.  At k>0 id = bs_id; at k=0 id = dc.
    h = lg.n_layers
    holdings: List[Dict[int, List[PlacedUnit]]] = [dict() for _ in range(h + 1)]
    pools: List[Dict[int, List[Tuple[int, PlacedUnit]]]] = [dict() for _ in range(h + 1)]
    stats = dict(replicated=0, decomposed=0, regions=0, competitions=0, skipped_w=0)

    def requesting_dcs(unit: PlacedUnit, dcs: np.ndarray) -> np.ndarray:
        return dcs[unit.r_py[dcs] > 0]

    # ---- Alg. 1: sink each pattern to its target layer -------------------
    for p in workload.patterns:
        if p.read_rate <= p.write_rate:  # Alg. 2 precondition R > W
            stats["skipped_w"] += 1
            continue
        unit = PlacedUnit.from_pattern(p)
        k_star = lg.layer_for_latency(p.eta * cfg.gamma_max_s)
        placed = False
        for b in lg.layers[k_star]:
            if len(requesting_dcs(unit, b.dcs)):
                holdings[k_star].setdefault(b.bs_id, []).append(unit)
                placed = True
        if not placed:  # requesting DC isolated at this layer -> direct deposit
            for dc in np.where(p.r_py > 0)[0]:
                holdings[0].setdefault(int(dc), []).append(unit)

    # ---- Alg. 2: layer-by-layer placement --------------------------------
    for k in range(h, 0, -1):
        # Phase 1: replication-vs-decomposition per held unit
        for bs_id, units in list(holdings[k].items()):
            b = lg.bs(bs_id)
            children = lg.bs_children(b)
            for unit in units:
                if k == 1 or not children:
                    # children are the DCs of this BS's cluster
                    child_dcs = [np.asarray([int(d)]) for d in b.dcs
                                 if unit.r_py[int(d)] > 0]
                    child_ids = [int(d) for d in b.dcs if unit.r_py[int(d)] > 0]
                    to_layer = 0
                else:
                    kids = [c for c in children if len(requesting_dcs(unit, c.dcs))]
                    child_dcs = [c.dcs for c in kids]
                    child_ids = [c.bs_id for c in kids]
                    to_layer = k - 1
                if not child_ids:
                    continue
                gain = replication_gain(
                    unit, b.dcs, child_dcs, sizes, env, cfg.lambda1, primary
                )
                if gain >= 0:
                    stats["replicated"] += 1
                    for cid in child_ids:
                        holdings[to_layer].setdefault(cid, []).append(unit)
                else:
                    stats["decomposed"] += 1
                    pools[k].setdefault(b.comp, []).append((bs_id, unit))
        holdings[k].clear()

        # Phase 2: overlap-region allocation within each cluster
        for comp, entries in list(pools[k].items()):
            units = [u for (_, u) in entries]
            pseudo = [
                Pattern(pid=i, items=u.items, r_py=u.r_py, w_py=u.w_py, eta=u.eta)
                for i, u in enumerate(units)
            ]
            regions = decompose_overlap_regions(pseudo, g.n_items)
            stats["regions"] += len(regions)
            b_holder = next(bb for bb in lg.layers[k] if bb.comp == comp)
            children = lg.bs_children(b_holder)
            if k == 1 or not children:
                cand = [
                    (int(d), np.asarray([int(d)]), [u.items for u in holdings[0].get(int(d), [])])
                    for d in b_holder.dcs
                ]
                to_layer = 0
            else:
                cand = [
                    (c.bs_id, c.dcs, [u.items for u in holdings[k - 1].get(c.bs_id, [])])
                    for c in children
                ]
                to_layer = k - 1
            for region in regions:
                pids = region.key
                r_py = np.sum([units[i].r_py for i in pids], axis=0)
                w_py = np.sum([units[i].w_py for i in pids], axis=0)
                runit = PlacedUnit(
                    items=region.items, r_py=r_py, w_py=w_py,
                    eta=min(units[i].eta for i in pids),
                    key=tuple(sorted(set(sum((units[i].key for i in pids), ())))),
                )
                req = [
                    (cid, dcs, held) for (cid, dcs, held) in cand
                    if r_py[dcs].sum() > 0
                ]
                if not req:
                    continue
                gain = replication_gain(
                    runit, b_holder.dcs, [d for (_, d, _) in req], sizes, env,
                    cfg.lambda1, primary,
                )
                if gain > 0:
                    stats["replicated"] += 1
                    targets = [cid for (cid, _, _) in req]
                else:
                    stats["competitions"] += 1
                    win = _dhd_competition(
                        region, req, regions, g, cfg.dhd, cfg.dhd_steps, r_py
                    )
                    targets = [req[win][0]]
                for cid in targets:
                    holdings[to_layer].setdefault(cid, []).append(runit)
            pools[k].pop(comp)

    # ---- deposit layer-0 holdings as replicas -----------------------------
    for dc, units in holdings[0].items():
        for u in units:
            state.delta[u.items, int(dc)] = True

    # ---- Phase 3: pre-caching (paper §V) ----------------------------------
    if cfg.precache:
        precache_hot_regions(
            g, workload, state, cfg.theta_quantile, cfg.dhd,
            max_per_dc=cfg.precache_max_per_dc,
        )

    state.route_nearest(env)
    return state, stats


# ----------------------------------------------------------------- pre-cache
def precache_hot_regions(
    g: Graph,
    workload: Workload,
    state: PlacementState,
    theta_quantile: float = 0.55,
    params: dhd.DHDParams = dhd.DHDParams(),
    n_steps: int = 48,
    max_per_dc: int = 4096,
) -> np.ndarray:
    """Steady-state DHD over the whole graph; cache vertices whose equilibrium
    heat is >= the ``theta_quantile`` of the heat distribution at every DC
    that does not own them (bounded by ``max_per_dc``).  Returns hot-vertex ids.
    """
    r_v = workload.r_xy[: g.n_nodes].sum(axis=1).astype(np.float32)
    if r_v.max() <= 0:
        return np.zeros(0, dtype=np.int64)
    heat0 = r_v / r_v.max()
    theta = float(np.quantile(heat0[heat0 > 0], theta_quantile)) if (heat0 > 0).any() else 0.0
    sources = heat0 >= theta
    q0 = np.where(sources, 1.0 / max(sources.sum(), 1), 0.0).astype(np.float32)
    w_e = workload.r_xy[g.n_nodes :].sum(axis=1).astype(np.float32)
    w_e = w_e / max(w_e.max(), 1.0) + 1e-3
    heat = dhd.diffuse_affinity(
        g.n_nodes, g.src, g.dst, w_e, q0, base_heat=heat0, params=params, n_steps=n_steps
    )
    theta_star = float(np.quantile(heat, theta_quantile))
    hot = np.where(heat >= theta_star)[0]
    if len(hot) > max_per_dc:
        hot = hot[np.argsort(-heat[hot])[:max_per_dc]]
    for d in range(state.delta.shape[1]):
        ext = hot[g.partition[hot] != d]
        state.delta[ext, d] = True
    return hot


# ------------------------------------------------------------------ eviction
class HeatCache:
    """Online replica eviction (Alg. 3): heat-tracked cache per DC."""

    def __init__(
        self,
        g: Graph,
        dc: int,
        state: PlacementState,
        params: dhd.DHDParams = dhd.DHDParams(),
        theta_c: float = 0.05,
    ) -> None:
        self.g = g
        self.dc = dc
        self.state = state
        self.params = params
        self.theta_c = theta_c
        self.heat = np.zeros(g.n_items, dtype=np.float32)
        # streaming stores set this to the alive mask so diffusion never
        # crosses tombstoned edges; None = static graph, all edges live
        self.edge_mask: Optional[np.ndarray] = None

    def cached_mask(self) -> np.ndarray:
        """Replicas held at this DC beyond the primary partition copy."""
        primary = np.zeros(self.g.n_items, dtype=bool)
        primary[: self.g.n_nodes] = self.g.partition == self.dc
        primary[self.g.n_nodes :] = self.g.partition[self.g.src] == self.dc
        return self.state.delta[:, self.dc] & ~primary

    def observe(self, item_ids: np.ndarray, freq: float = 1.0) -> None:
        """External heat injection: one access event batch (Alg. 3 lines 3-5).

        Duplicate ids accumulate (``serve_batch`` concatenates per-origin
        request items), which fancy-index ``+=`` would silently collapse."""
        np.add.at(self.heat, np.asarray(item_ids), freq)

    def step(self, n_steps: int = 4) -> None:
        """Diffuse heat over the cache topology (vertex items only)."""
        n = self.g.n_nodes
        if self.edge_mask is not None:
            src, dst = self.g.src[self.edge_mask], self.g.dst[self.edge_mask]
        else:
            src, dst = self.g.src, self.g.dst
        h = dhd.diffuse_affinity(
            n,
            src,
            dst,
            np.ones(len(src), dtype=np.float32),
            self.heat[:n],
            params=self.params,
            n_steps=n_steps,
        )
        self.heat[:n] = h
        self.heat[n:] *= (1.0 - self.params.gamma) ** n_steps

    def evict(self) -> np.ndarray:
        """Remove cold replicas; returns evicted item ids (Alg. 3 lines 7-10).

        The caller (``GeoGraphStore.maintain``) refreshes the routing table
        after eviction, matching Alg. 3 line 10."""
        cold = self.cached_mask() & (self.heat < self.theta_c)
        ids = np.where(cold)[0]
        self.state.delta[ids, self.dc] = False
        return ids
