"""Incremental nearest/second-nearest replica route index (paper §VI serving).

``PlacementState.route_nearest`` re-derives the Eq. 1 routing table with a
masked argmin over the full ``[I, D, D]`` latency tensor.  That is the right
tool at build time, but the streaming store changes only a handful of replica
rows per mutation batch or migration flush — rebuilding the whole table per
event made routing the last rebuild-bound subsystem.

:class:`RouteIndex` keeps, per (item, origin DC):

  * ``nearest[x, y]``  — the latency-minimal replica DC (== the Eq. 1 route)
  * ``second[x, y]``   — the runner-up replica DC (-1 when < 2 replicas)

and patches *only affected rows* on replica-set deltas:

  * ``add_replicas``  — O(K·D) compare-and-shift against the cached pair;
    no argmin, no [K, D, D] temporary.
  * ``drop_replicas`` — rows whose nearest was dropped promote their cached
    second in O(1), then only the vacated ``second`` slots are re-derived.
  * ``apply_moves``   — a migration move-set, grouped per (DC, kind).
  * ``apply_batch``   — a mutation batch: grows the id space (vertex block
    inserts shift the edge block), clears tombstoned rows, seeds new ones.

The index *owns* its ``nearest`` array; :class:`~repro.core.store.GeoGraphStore`
aliases ``state.route`` to it so every consumer of the routing table sees
patches immediately.  ``verify`` cross-checks against a from-scratch
``route_nearest`` rebuild (the differential invariant under test in
``tests/test_route_index.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from .graph import grow_item_rows
from .latency import GeoEnvironment

__all__ = ["RouteIndex", "RouteIndexStats", "RoutePartition"]


@dataclasses.dataclass
class RouteIndexStats:
    """Cumulative patch accounting (how much rebuild work the index avoided)."""

    full_rebuilds: int = 0
    rows_patched: int = 0  # rows re-derived by masked argmin
    rows_promoted: int = 0  # drop fixed by promoting the cached second
    rows_shifted: int = 0  # add fixed by compare-and-shift (no argmin)

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class RouteIndex:
    """``[n_items, n_dcs]`` nearest + second-nearest replica index."""

    def __init__(self, env: GeoEnvironment, n_items: int) -> None:
        self.env = env
        lat = env.rtt_s.copy()
        np.fill_diagonal(lat, 0.0)
        self.lat = lat  # [d, y] serving-DC -> origin latency (size-free, Eq. 1)
        self.nearest = np.full((n_items, env.n_dcs), -1, dtype=np.int32)
        self.second = np.full((n_items, env.n_dcs), -1, dtype=np.int32)
        self.stats = RouteIndexStats()
        # change-event subscribers (the sharded store's per-origin partitions
        # mirror the index through these instead of polling): fn(kind, payload)
        # with kinds "rows" (patched row ids), "grow" ((old_n_nodes, n_new_v,
        # n_new_e)), "take" (row permutation), "rebuild" (None)
        self._listeners: List[Callable[[str, object], None]] = []

    # --------------------------------------------------------------- events
    def subscribe(self, fn: Callable[[str, object], None]) -> None:
        """Register a change listener; fired after each index mutation, when
        the placement ``delta`` the mutation derived from is still current."""
        self._listeners.append(fn)

    def _emit(self, kind: str, payload: object = None) -> None:
        for fn in self._listeners:
            fn(kind, payload)

    # ------------------------------------------------------------- building
    @staticmethod
    def build(delta: np.ndarray, env: GeoEnvironment) -> "RouteIndex":
        idx = RouteIndex(env, delta.shape[0])
        idx.rebuild(delta)
        return idx

    @property
    def n_items(self) -> int:
        return self.nearest.shape[0]

    def _argmin2(self, delta_rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Masked (nearest, second) argmin over serving DCs for ``delta_rows``.

        Ties break toward the lower DC id, matching ``route_nearest``."""
        big = np.where(delta_rows[:, :, None], self.lat[None, :, :], np.inf)
        nearest = np.argmin(big, axis=1).astype(np.int32)  # [K, y]
        k = np.arange(big.shape[0])[:, None]
        y = np.arange(big.shape[2])[None, :]
        best = big[k, nearest, y]
        big[k, nearest, y] = np.inf
        second = np.argmin(big, axis=1).astype(np.int32)
        second_ok = np.isfinite(big[k, second, y])
        second = np.where(second_ok, second, -1).astype(np.int32)
        none = ~np.isfinite(best)
        nearest = np.where(none, -1, nearest).astype(np.int32)
        return nearest, second

    def rebuild(self, delta: np.ndarray) -> None:
        """Full from-scratch derivation (init / strategy switch / fallback)."""
        self.nearest, self.second = self._argmin2(delta)
        self.stats.full_rebuilds += 1
        if self._listeners:
            self._emit("rebuild")

    def patch_rows(self, delta: np.ndarray, rows: np.ndarray) -> None:
        """Re-derive exactly ``rows`` (replica sets changed arbitrarily)."""
        rows = np.asarray(rows, dtype=np.int64)
        if len(rows) == 0:
            return
        self.nearest[rows], self.second[rows] = self._argmin2(delta[rows])
        self.stats.rows_patched += len(rows)
        if self._listeners:
            self._emit("rows", rows)

    # ----------------------------------------------------------- delta ops
    def add_replicas(self, delta: np.ndarray, items: np.ndarray, dc: int) -> None:
        """Absorb "replica of ``items`` appeared at ``dc``" without argmin.

        The new candidate either beats the cached nearest (shift nearest into
        second), beats only the second (replace it), or loses to both (no-op).
        Rows that already referenced ``dc`` (re-add after a rollback) fall
        back to a row patch."""
        items = np.asarray(items, dtype=np.int64)
        if len(items) == 0:
            return
        stale = (self.nearest[items] == dc).any(axis=1) | (
            self.second[items] == dc
        ).any(axis=1)
        if stale.any():
            self.patch_rows(delta, items[stale])
            items = items[~stale]
            if len(items) == 0:
                return
        n = self.nearest[items]  # [K, D]
        s = self.second[items]
        cand = self.lat[dc][None, :]  # [1, D] broadcast over rows
        n_lat = np.where(n >= 0, self.lat[np.maximum(n, 0), np.arange(n.shape[1])[None, :]], np.inf)
        s_lat = np.where(s >= 0, self.lat[np.maximum(s, 0), np.arange(s.shape[1])[None, :]], np.inf)
        # strict '<' keeps the lower-DC-id tie-break of the argmin derivation:
        # an equal-latency newcomer with a higher id must not displace the
        # incumbent; with a lower id it must (argmin would have picked it)
        beats_n = (cand < n_lat) | ((cand == n_lat) & (dc < n))
        beats_s = ~beats_n & ((cand < s_lat) | ((cand == s_lat) & (dc < s)))
        s2 = np.where(beats_n, n, np.where(beats_s, dc, s))
        n2 = np.where(beats_n, dc, n)
        self.nearest[items] = n2.astype(np.int32)
        self.second[items] = s2.astype(np.int32)
        self.stats.rows_shifted += len(items)
        if self._listeners:
            self._emit("rows", items)

    def drop_replicas(self, delta: np.ndarray, items: np.ndarray, dc: int) -> None:
        """Absorb "replica of ``items`` vanished from ``dc``".

        Rows not referencing ``dc`` are untouched.  Rows whose nearest was
        ``dc`` promote the cached second in O(1); every row that lost its
        second slot (by promotion or direct hit) re-derives only that slot
        with an argmin restricted to non-nearest replicas."""
        items = np.asarray(items, dtype=np.int64)
        if len(items) == 0:
            return
        n = self.nearest[items]
        s = self.second[items]
        hit_n = n == dc
        hit_s = s == dc
        touched = hit_n.any(axis=1) | hit_s.any(axis=1)
        items = items[touched]
        if len(items) == 0:
            return
        n, s, hit_n, hit_s = n[touched], s[touched], hit_n[touched], hit_s[touched]
        n = np.where(hit_n, s, n)  # promote second into vacated nearest
        vacated = hit_n | hit_s
        self.stats.rows_promoted += int(hit_n.any(axis=1).sum())
        # re-derive the vacated second slots: argmin over replicas != nearest
        big = np.where(delta[items][:, :, None], self.lat[None, :, :], np.inf)
        k = np.arange(len(items))[:, None]
        y = np.arange(n.shape[1])[None, :]
        big[k, np.maximum(n, 0), y] = np.inf  # exclude the (new) nearest
        s_new = np.argmin(big, axis=1).astype(np.int32)
        s_new = np.where(np.isfinite(big[k, s_new, y]), s_new, -1)
        s = np.where(vacated, s_new, s)
        # a row that lost its only replica: nearest promoted to -1 already
        self.nearest[items] = n.astype(np.int32)
        self.second[items] = s.astype(np.int32)
        if self._listeners:
            self._emit("rows", items)

    def apply_moves(self, delta: np.ndarray, moves: Sequence) -> None:
        """Patch the index for an applied migration move-set.

        ``delta`` must already reflect the moves (the caller mutates placement
        first, exactly like ``apply_plan``).  Moves are grouped per (dc, kind)
        so each group is one vectorized patch."""
        groups: Dict[Tuple[int, str], List[int]] = {}
        for m in moves:
            groups.setdefault((int(m.dc), m.kind), []).append(int(m.item))
        self.apply_grouped(
            delta,
            [(dc, kind, np.asarray(its, dtype=np.int64))
             for (dc, kind), its in sorted(groups.items())],
        )

    def apply_grouped(
        self, delta: np.ndarray, groups: Sequence[Tuple[int, str, np.ndarray]]
    ) -> None:
        """Patch pre-grouped replica-set deltas: ``(dc, kind, items)`` triples.

        The array-native entry the migration transfer pipeline uses per wave
        (a :class:`~repro.streaming.migration.TransferBatch` is already one
        ``(dst, "add", items)`` group — no per-move Python loop).  Drops go
        first: the drop path re-derives vacated slots from the final delta,
        so adds resolved afterwards see consistent cached state."""
        for dc, kind, its in sorted(groups, key=lambda t: t[1] != "drop"):
            arr = np.unique(np.asarray(its, dtype=np.int64))
            if kind == "add":
                self.add_replicas(delta, arr, int(dc))
            else:
                self.drop_replicas(delta, arr, int(dc))

    # ------------------------------------------------------ id-space deltas
    def grow(self, old_n_nodes: int, n_new_vertices: int, n_new_edges: int) -> None:
        """Insert rows for new vertices (mid) / edges (end), v|e id layout —
        through the one shared encoding, so index rows can never desync from
        the placement rows grown the same way."""
        self.nearest = grow_item_rows(
            self.nearest, old_n_nodes, n_new_vertices, n_new_edges, -1
        )
        self.second = grow_item_rows(
            self.second, old_n_nodes, n_new_vertices, n_new_edges, -1
        )
        if self._listeners:
            self._emit("grow", (old_n_nodes, n_new_vertices, n_new_edges))

    def clear_rows(self, rows: np.ndarray) -> None:
        rows = np.asarray(rows, dtype=np.int64)
        self.nearest[rows] = -1
        self.second[rows] = -1
        if self._listeners:
            self._emit("rows", rows)

    def apply_batch(
        self,
        delta: np.ndarray,
        old_n_nodes: int,
        n_new_vertices: int,
        n_new_edges: int,
        changed_rows: np.ndarray,
        dead_rows: np.ndarray,
    ) -> None:
        """Absorb one mutation batch: grow the id space (edge block shifts by
        the new-vertex count), tombstone dead rows, derive the changed ones."""
        self.grow(old_n_nodes, n_new_vertices, n_new_edges)
        self.clear_rows(dead_rows)
        live = np.asarray(changed_rows, dtype=np.int64)
        dead_mask = np.zeros(self.n_items, dtype=bool)
        dead_mask[np.asarray(dead_rows, dtype=np.int64)] = True
        self.patch_rows(delta, live[~dead_mask[live]])

    # -------------------------------------------------------- reordering
    def take_rows(self, order: np.ndarray) -> None:
        """Re-key the index onto a compacted id space (row permutation only:
        stored values are DC ids, which compaction never renumbers)."""
        order = np.asarray(order, dtype=np.int64)
        self.nearest = self.nearest[order]
        self.second = self.second[order]
        if self._listeners:
            self._emit("take", order)

    # ------------------------------------------------------------- checking
    def verify(self, delta: np.ndarray) -> bool:
        """True iff the incremental index equals a from-scratch derivation."""
        ref_n, ref_s = self._argmin2(delta)
        return bool(
            np.array_equal(self.nearest, ref_n) and np.array_equal(self.second, ref_s)
        )


class RoutePartition:
    """One origin DC's column of the route index, owned by a store shard.

    The sharded store keeps the coordinator :class:`RouteIndex` authoritative
    and streams its change events (:meth:`RouteIndex.subscribe`) to the shard
    that owns each origin.  A partition does **not** copy the coordinator's
    column: on every event it independently re-derives its rows from the
    replicated placement map (the same masked-argmin math restricted to one
    origin), so shard/coordinator divergence is a detectable bug
    (:meth:`verify_against`) rather than definitionally impossible.

    ``delta_fn`` must return the *current* placement map — the store swaps
    the underlying array on growth and compaction, so the partition holds a
    provider, never the array itself.
    """

    def __init__(
        self,
        env: GeoEnvironment,
        dc: int,
        delta_fn: Callable[[], np.ndarray],
    ) -> None:
        self.dc = int(dc)
        lat = env.rtt_s.copy()
        np.fill_diagonal(lat, 0.0)
        self.lat_col = lat[:, self.dc]  # [D] serving-DC -> this origin
        self._delta_fn = delta_fn
        self.nearest = np.zeros(0, dtype=np.int32)
        self.second = np.zeros(0, dtype=np.int32)
        self.derive_all()

    @property
    def n_items(self) -> int:
        return self.nearest.shape[0]

    def _derive(self, delta_rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(nearest, second) for this origin over ``delta_rows`` — the
        column restriction of :meth:`RouteIndex._argmin2`, same lower-DC-id
        tie-break."""
        big = np.where(delta_rows, self.lat_col[None, :], np.inf)
        nearest = np.argmin(big, axis=1).astype(np.int32)
        k = np.arange(big.shape[0])
        best = big[k, nearest]
        big[k, nearest] = np.inf
        second = np.argmin(big, axis=1).astype(np.int32)
        second = np.where(np.isfinite(big[k, second]), second, -1).astype(np.int32)
        nearest = np.where(np.isfinite(best), nearest, -1).astype(np.int32)
        return nearest, second

    def derive_all(self) -> None:
        self.nearest, self.second = self._derive(self._delta_fn())

    def on_event(self, kind: str, payload: object) -> None:
        """Absorb one :class:`RouteIndex` change event."""
        if kind == "rows":
            rows = np.asarray(payload, dtype=np.int64)
            if len(rows) == 0:
                return
            n, s = self._derive(self._delta_fn()[rows])
            self.nearest[rows] = n
            self.second[rows] = s
        elif kind == "grow":
            old_n_nodes, n_new_vertices, n_new_edges = payload
            self.nearest = grow_item_rows(
                self.nearest, old_n_nodes, n_new_vertices, n_new_edges, -1
            )
            self.second = grow_item_rows(
                self.second, old_n_nodes, n_new_vertices, n_new_edges, -1
            )
        elif kind == "take":
            order = np.asarray(payload, dtype=np.int64)
            self.nearest = self.nearest[order]
            self.second = self.second[order]
        elif kind == "rebuild":
            self.derive_all()
        else:  # pragma: no cover - future event kinds must not silently drop
            raise ValueError(f"unknown route-index event {kind!r}")

    def verify_against(self, index: RouteIndex) -> bool:
        """True iff the partition equals the coordinator's column for this
        origin (the sharded differential invariant)."""
        return bool(
            np.array_equal(self.nearest, index.nearest[:, self.dc])
            and np.array_equal(self.second, index.second[:, self.dc])
        )
