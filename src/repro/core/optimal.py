"""Exact solution of the joint placement/routing BIP (Eq. 6) on tiny instances.

The paper solves Eq. 6 with PuLP/CBC on WIKI-vote to report a 7.8% optimality
gap (Fig. 9).  CBC is not available offline, so we brute-force the same
optimum: enumerate per-item replica sets (delta rows) and route each request
optimally given delta; pattern costs decompose per pattern given routes.

Complexity is O(I * 2^D * D) per candidate assignment sweep with a
coordinate-descent outer loop (items are coupled only through C^(A), which
depends on pattern routing; we iterate item-wise exact improvement until a
fixed point — on the tiny instances used in tests/benchmarks this reaches
the enumerated global optimum, which we verify by full enumeration when
I * D <= 16).
"""
from __future__ import annotations

import itertools
from typing import Optional, Tuple

import numpy as np

from .cost import PlacementState, total_cost
from .latency import GeoEnvironment
from .patterns import Workload

__all__ = ["solve_exact_tiny", "solve_coordinate_descent"]


def _route_optimal(
    state: PlacementState, workload: Workload, env: GeoEnvironment, sizes: np.ndarray
) -> None:
    """Optimal routing given delta: nearest replica minimizes both Eq. 3's
    cross-DC cost and Eq. 1 latency (c_read uniform across DCs here)."""
    state.route_nearest(env)


def solve_exact_tiny(
    workload: Workload,
    env: GeoEnvironment,
    sizes: np.ndarray,
    primary: np.ndarray,  # [I] primary DC per item (fixed, always a replica)
    max_enum_items: int = 8,
    max_extra_replicas: int = 1,
) -> Tuple[PlacementState, float]:
    """Full enumeration over replica sets of the accessed items (bounded to
    ``max_extra_replicas`` extra copies per item to keep the product space
    tractable: (1 + D*extra)^items states)."""
    I = workload.n_items
    D = env.n_dcs
    accessed = np.where(workload.r_xy.sum(axis=1) + workload.w_xy.sum(axis=1) > 0)[0]
    if len(accessed) > max_enum_items:
        raise ValueError(
            f"{len(accessed)} accessed items > {max_enum_items}; use coordinate descent"
        )
    best_cost = np.inf
    best_state: Optional[PlacementState] = None
    # choice per item: subset of extra DCs to add replicas at
    subsets = list(itertools.chain.from_iterable(
        itertools.combinations(range(D), r)
        for r in range(min(max_extra_replicas, D - 1) + 1)
    ))
    for combo in itertools.product(range(len(subsets)), repeat=len(accessed)):
        state = PlacementState.empty(I, D)
        state.delta[np.arange(I), primary] = True
        for xi, ci in zip(accessed, combo):
            for d in subsets[ci]:
                state.delta[xi, d] = True
        _route_optimal(state, workload, env, sizes)
        c = total_cost(
            workload.patterns, state, workload.r_xy, workload.w_xy, sizes, env
        ).total
        if c < best_cost:
            best_cost = c
            best_state = state
    assert best_state is not None
    return best_state, float(best_cost)


def solve_coordinate_descent(
    workload: Workload,
    env: GeoEnvironment,
    sizes: np.ndarray,
    primary: np.ndarray,
    max_rounds: int = 6,
    seed: int = 0,
) -> Tuple[PlacementState, float]:
    """Item-wise exact improvement: for each accessed item enumerate all 2^D
    replica rows (primary forced), keep the row minimizing the exact global
    objective.  Converges to a strong local optimum of Eq. 6; used as the
    reference optimum on small graphs (paper Fig. 9 scale)."""
    I = workload.n_items
    D = env.n_dcs
    accessed = np.where(workload.r_xy.sum(axis=1) + workload.w_xy.sum(axis=1) > 0)[0]
    state = PlacementState.empty(I, D)
    state.delta[np.arange(I), primary] = True
    _route_optimal(state, workload, env, sizes)
    cur = total_cost(
        workload.patterns, state, workload.r_xy, workload.w_xy, sizes, env
    ).total
    rows = [np.array(bits) for bits in itertools.product([False, True], repeat=D)]
    rng = np.random.default_rng(seed)
    for _ in range(max_rounds):
        improved = False
        order = rng.permutation(accessed)
        for x in order.tolist():
            best_row = state.delta[x].copy()
            best_c = cur
            for row in rows:
                r = row.copy()
                r[primary[x]] = True
                if (r == state.delta[x]).all():
                    continue
                state.delta[x] = r
                _route_optimal(state, workload, env, sizes)
                c = total_cost(
                    workload.patterns, state, workload.r_xy, workload.w_xy, sizes, env
                ).total
                if c < best_c - 1e-12:
                    best_c = c
                    best_row = r.copy()
            state.delta[x] = best_row
            _route_optimal(state, workload, env, sizes)
            if best_c < cur - 1e-12:
                cur = best_c
                improved = True
        if not improved:
            break
    return state, float(cur)
