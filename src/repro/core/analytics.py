"""Offline graph analytics engines (paper §VII workloads) + geo cost simulator.

PageRank / SSSP / HITS / LPA are iterative ``segment_sum``/``segment_min``
computations in JAX — the same dataflow a Pregel-style geo engine (RAGraph)
executes, so per-iteration message counts map 1:1 to WAN traffic.  K-core
uses Batagelj-Zaversnik peeling (control-plane NumPy, like the paper's
setup where core iterations = max core number).

``simulate_execution`` prices a layout (vertex -> execution site) under the
paper's BSP model: per iteration, cut edges exchange ``msg_bytes`` messages;
iteration time = straggler compute + straggler link (Eq. 1); WAN volume
accumulates cut bytes — the quantities of Figs. 13-14.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph
from .latency import GeoEnvironment

__all__ = [
    "pagerank",
    "sssp",
    "hits",
    "label_propagation",
    "core_decomposition",
    "ExecStats",
    "simulate_execution",
]


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_iters"))
def pagerank(
    src: jnp.ndarray, dst: jnp.ndarray, n_nodes: int, n_iters: int = 15, damp: float = 0.85
) -> jnp.ndarray:
    deg = jax.ops.segment_sum(jnp.ones_like(src, dtype=jnp.float32), src, n_nodes)
    deg = jnp.maximum(deg, 1.0)
    r0 = jnp.full((n_nodes,), 1.0 / n_nodes, dtype=jnp.float32)

    def body(_, r):
        contrib = r[src] / deg[src]
        agg = jax.ops.segment_sum(contrib, dst, n_nodes)
        return (1.0 - damp) / n_nodes + damp * agg

    return jax.lax.fori_loop(0, n_iters, body, r0)


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_iters"))
def sssp(
    src: jnp.ndarray,
    dst: jnp.ndarray,
    weight: jnp.ndarray,
    source: int,
    n_nodes: int,
    n_iters: int = 10,
) -> jnp.ndarray:
    inf = jnp.asarray(jnp.inf, dtype=jnp.float32)
    dist0 = jnp.full((n_nodes,), jnp.inf, dtype=jnp.float32).at[source].set(0.0)

    def body(_, dist):
        cand = dist[src] + weight
        relax = jax.ops.segment_min(cand, dst, n_nodes)
        return jnp.minimum(dist, jnp.where(jnp.isfinite(relax), relax, inf))

    return jax.lax.fori_loop(0, n_iters, body, dist0)


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_iters"))
def hits(
    src: jnp.ndarray, dst: jnp.ndarray, n_nodes: int, n_iters: int = 20
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h0 = jnp.ones((n_nodes,), dtype=jnp.float32)
    a0 = jnp.ones((n_nodes,), dtype=jnp.float32)

    def body(_, state):
        h, a = state
        a = jax.ops.segment_sum(h[src], dst, n_nodes)
        a = a / jnp.maximum(jnp.linalg.norm(a), 1e-12)
        h = jax.ops.segment_sum(a[dst], src, n_nodes)
        h = h / jnp.maximum(jnp.linalg.norm(h), 1e-12)
        return h, a

    return jax.lax.fori_loop(0, n_iters, body, (h0, a0))


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_iters"))
def label_propagation(
    src: jnp.ndarray, dst: jnp.ndarray, n_nodes: int, n_iters: int = 10
) -> jnp.ndarray:
    """Min-label propagation (monotone LPA variant used by delta-accumulative
    engines like Maiter/RAGraph; identical message pattern to classic LPA)."""
    lab0 = jnp.arange(n_nodes, dtype=jnp.int32)

    def body(_, lab):
        m1 = jax.ops.segment_min(lab[src], dst, n_nodes)
        m2 = jax.ops.segment_min(lab[dst], src, n_nodes)
        return jnp.minimum(lab, jnp.minimum(m1, m2))

    return jax.lax.fori_loop(0, n_iters, body, lab0)


def core_decomposition(n_nodes: int, src: np.ndarray, dst: np.ndarray) -> Tuple[np.ndarray, int]:
    """Batagelj-Zaversnik peeling on the *simple* graph (parallel edges and
    self-loops dropped — the standard k-core definition).
    Returns (core numbers, peel rounds)."""
    a, b = np.minimum(src, dst), np.maximum(src, dst)
    keep = a != b
    key = a[keep].astype(np.int64) * n_nodes + b[keep]
    _, idx = np.unique(key, return_index=True)
    src = a[keep][idx]
    dst = b[keep][idx]
    deg = np.bincount(src, minlength=n_nodes) + np.bincount(dst, minlength=n_nodes)
    core = np.zeros(n_nodes, dtype=np.int32)
    alive = np.ones(n_nodes, dtype=bool)
    cur = deg.astype(np.int64).copy()
    k = 0
    rounds = 0
    while alive.any():
        k_candidates = cur[alive]
        k = max(k, int(k_candidates.min()))
        while True:
            peel = alive & (cur <= k)
            if not peel.any():
                break
            rounds += 1
            core[peel] = k
            alive[peel] = False
            # decrement neighbor degrees
            m = peel[src] & alive[dst]
            np.subtract.at(cur, dst[m], 1)
            m = peel[dst] & alive[src]
            np.subtract.at(cur, src[m], 1)
    return core, rounds


# ------------------------------------------------------------ cost simulator
@dataclasses.dataclass
class ExecStats:
    time_s: float
    wan_bytes: float
    cut_edges: int
    n_sites: int
    per_iter_time_s: float


def simulate_execution(
    env: GeoEnvironment,
    g: Graph,
    vertex_site: np.ndarray,  # [n] execution DC per vertex
    n_iters: int,
    msg_bytes: float = 16.0,
    edge_rate: float = 5e7,  # edges/sec processed per DC (compute model)
    assembly_bytes: float = 0.0,
) -> ExecStats:
    """BSP execution model over a geo layout (used for Figs. 13-15).

    Per superstep: every cut edge ships one ``msg_bytes`` message; link time
    follows Eq. 1 aggregated per DC pair; iteration time = straggler
    (max compute + max link) — the paper's bottleneck model (§III-A).
    """
    site_s = vertex_site[g.src]
    site_d = vertex_site[g.dst]
    cut = site_s != site_d
    cut_edges = int(cut.sum())
    sites = np.unique(vertex_site[vertex_site >= 0])
    # per-pair message volume
    pair_bytes: Dict[Tuple[int, int], float] = {}
    if cut_edges:
        pairs, counts = np.unique(
            np.stack([site_s[cut], site_d[cut]], axis=1), axis=0, return_counts=True
        )
        for (a, b), c in zip(pairs, counts):
            pair_bytes[(int(a), int(b))] = float(c) * msg_bytes
    link_t = 0.0
    for (a, b), v in pair_bytes.items():
        link_t = max(link_t, env.rtt_s[a, b] / 2.0 + v / env.bw_Bps[a, b])
    # straggler compute: max local edges per site
    comp_t = 0.0
    for s in sites:
        local_edges = int(((site_s == s) & (site_d == s)).sum()) + int(
            ((site_s == s) ^ (site_d == s)).sum()
        )
        comp_t = max(comp_t, local_edges / edge_rate)
    per_iter = comp_t + link_t
    wan = n_iters * sum(pair_bytes.values()) + assembly_bytes
    return ExecStats(
        time_s=n_iters * per_iter + assembly_bytes / _min_bw(env, sites),
        wan_bytes=wan,
        cut_edges=cut_edges,
        n_sites=len(sites),
        per_iter_time_s=per_iter,
    )


def _min_bw(env: GeoEnvironment, sites: np.ndarray) -> float:
    if len(sites) < 2:
        return float("inf")
    vals = [
        env.bw_Bps[a, b]
        for a in sites
        for b in sites
        if a != b and np.isfinite(env.bw_Bps[a, b])
    ]
    return float(min(vals)) if vals else float("inf")
