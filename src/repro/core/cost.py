"""GeoLayer cost metrics and the joint optimization objective (paper §III).

Decision variables (Eq. 6):
  * ``delta[x, d]``  — item x has a replica at DC d           (placement)
  * ``route[x, y]``  — DC serving reads of x from origin y    (= sigma_xyd)
  * ``rho[p, y]``    — derived: set of DCs serving pattern p from y

Costs:  C^(S) Eq. 2, C^(R) Eq. 3, C^(W) Eq. 4, C^(A) Eq. 5.
Constraints (a)-(e) are checked by :func:`check_constraints`.
All heavy loops are vectorized NumPy; this is the control-plane oracle that
benchmarks and tests evaluate every strategy against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .latency import GeoEnvironment

__all__ = [
    "PlacementState",
    "CostBreakdown",
    "storage_cost",
    "read_cost",
    "write_cost",
    "association_penalty",
    "pattern_latencies",
    "total_cost",
    "check_constraints",
]

_LAT_FLOOR_S = 1e-3  # guards Eq. 5's ratio when the min-latency DC is local


@dataclasses.dataclass
class PlacementState:
    """Placement + routing decisions for ``n_items`` over ``n_dcs``."""

    delta: np.ndarray  # [I, D] bool — replica map
    route: np.ndarray  # [I, D] int32 — serving DC of item x for origin y

    @staticmethod
    def empty(n_items: int, n_dcs: int) -> "PlacementState":
        return PlacementState(
            delta=np.zeros((n_items, n_dcs), dtype=bool),
            route=np.full((n_items, n_dcs), -1, dtype=np.int32),
        )

    def copy(self) -> "PlacementState":
        return PlacementState(self.delta.copy(), self.route.copy())

    def place(self, items: np.ndarray, dc: int) -> None:
        self.delta[np.asarray(items), dc] = True

    def route_nearest(
        self,
        env: GeoEnvironment,
        rows: Optional[np.ndarray] = None,
    ) -> None:
        """Route every (item, origin) to its latency-minimal replica (Eq. 1).

        The per-item size term is identical across candidate DCs, so RTT
        alone ranks them.  ``rows`` restricts the refresh to a subset of
        items — the streaming partial-reroute path after replica-set
        changes."""
        lat = env.rtt_s.copy()  # [d, y]; size term identical across d per item
        np.fill_diagonal(lat, 0.0)
        delta = self.delta if rows is None else self.delta[rows]
        if delta.shape[0] == 0:
            return
        big = np.where(delta[:, :, None], lat[None, :, :], np.inf)  # [I,d,y]
        route = np.argmin(big, axis=1).astype(np.int32)  # [I, y]
        route[~delta.any(axis=1)] = -1
        if rows is None:
            self.route = route
        else:
            self.route[rows] = route


@dataclasses.dataclass
class CostBreakdown:
    storage: float
    read: float
    write: float
    assoc: float

    @property
    def total(self) -> float:
        return self.storage + self.read + self.write + self.assoc

    def as_dict(self) -> Dict[str, float]:
        return dict(
            storage=self.storage, read=self.read, write=self.write,
            assoc=self.assoc, total=self.total,
        )


# ------------------------------------------------------------------ Eq. (2)
def storage_cost(state: PlacementState, sizes: np.ndarray, env: GeoEnvironment) -> float:
    return float((sizes[:, None] * state.delta * env.c_store[None, :]).sum())


# ------------------------------------------------------------------ Eq. (3)
def read_cost(
    state: PlacementState,
    r_xy: np.ndarray,  # [I, D] read frequency of item x from origin y
    sizes: np.ndarray,
    env: GeoEnvironment,
) -> float:
    I, D = r_xy.shape
    d = state.route  # [I, D]
    valid = d >= 0
    d_safe = np.where(valid, d, 0)
    get = env.c_read[d_safe]  # [I, D]
    ys = np.arange(D)[None, :]
    cross = (d_safe != ys) & valid
    net = np.where(cross, sizes[:, None] * env.c_net[d_safe, ys], 0.0)
    return float((r_xy * np.where(valid, get + net, 0.0)).sum())


# ------------------------------------------------------------------ Eq. (4)
def write_cost(
    state: PlacementState,
    w_xy: np.ndarray,  # [I, D]
    sizes: np.ndarray,
    env: GeoEnvironment,
) -> float:
    I, D = w_xy.shape
    # synchronization to every replica d != y:
    #   sum_d delta_xd * (c_write_d + s_x * c_net[y, d]), excluding d == y
    sync_put = state.delta @ env.c_write  # [I]
    own_put = state.delta * env.c_write[None, :]  # replica at y itself
    net_to = np.einsum("id,yd->iy", state.delta, env.c_net)  # [I, y]
    net_own = state.delta * np.diag(env.c_net)[None, :]
    sync = (sync_put[:, None] - own_put) + sizes[:, None] * (net_to - net_own)
    # Eq. 4: local PUT at the originating DC + replica synchronization
    return float((w_xy * (env.c_write[None, :] + sync)).sum())


# ------------------------------------------------------------------ Eq. (1)
def pattern_latencies(
    items: np.ndarray,
    origin: int,
    state: PlacementState,
    sizes: np.ndarray,
    env: GeoEnvironment,
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-serving-DC latency l_yd^p for a pattern from ``origin``.

    Returns (serving_dcs, latencies).  S_d^p = total bytes of p's items that
    DC d serves for this origin (Eq. 1)."""
    d = state.route[items, origin]
    d = d[d >= 0]
    if len(d) == 0:
        return np.array([], dtype=np.int64), np.array([])
    dcs = np.unique(d)
    s_d = np.zeros(len(dcs))
    sz = sizes[items[state.route[items, origin] >= 0]]
    for i, dc in enumerate(dcs):
        s_d[i] = sz[d == dc].sum()
    lat = np.array(
        [env.request_latency(int(dc), origin, s) for dc, s in zip(dcs, s_d)]
    )
    return dcs, lat


# ------------------------------------------------------------------ Eq. (5)
def association_penalty(
    patterns: Sequence,  # of core.patterns.Pattern
    state: PlacementState,
    sizes: np.ndarray,
    env: GeoEnvironment,
    lambda1: float = 0.5,
    lambda2: float = 0.5,
) -> float:
    total = 0.0
    for p in patterns:
        for y in np.where(p.r_py > 0)[0]:
            dcs, lat = pattern_latencies(p.items, int(y), state, sizes, env)
            if len(dcs) == 0:
                continue
            n_extra = len(dcs) - 1
            # Delta-l over *remote* participants: local self-serving has ~0
            # latency and is not a WAN straggler candidate (deviation from a
            # literal Eq. 5 read, where a partially-local pattern would make
            # the ratio unbounded; documented in DESIGN.md).
            rem = lat[dcs != y]
            if len(rem) >= 2:
                lmin = max(float(rem.min()), _LAT_FLOOR_S)
                dl = (float(rem.max()) - float(rem.min())) / lmin
            else:
                dl = 0.0
            total += float(p.r_py[y]) * (lambda1 * n_extra + lambda2 * dl)
    return total


# ------------------------------------------------------------------ Eq. (6)
def total_cost(
    patterns: Sequence,
    state: PlacementState,
    r_xy: np.ndarray,
    w_xy: np.ndarray,
    sizes: np.ndarray,
    env: GeoEnvironment,
    lambda1: float = 0.5,
    lambda2: float = 0.5,
) -> CostBreakdown:
    return CostBreakdown(
        storage=storage_cost(state, sizes, env),
        read=read_cost(state, r_xy, sizes, env),
        write=write_cost(state, w_xy, sizes, env),
        assoc=association_penalty(patterns, state, sizes, env, lambda1, lambda2),
    )


def check_constraints(
    patterns: Sequence,
    state: PlacementState,
    r_xy: np.ndarray,
    sizes: np.ndarray,
    env: GeoEnvironment,
    gamma_max_s: float,
) -> Dict[str, bool]:
    """Constraints (a)-(e) of Eq. (6).  Returns per-constraint pass flags.

    ``r_xy`` is the demand table the placement is accountable to.  The
    pattern constraints (b) and (d) bind only at origins whose reads of the
    pattern exist in that table: with the offline workload's ``r_xy`` (built
    as the per-item sum of every pattern's ``r_py``) this is exactly the
    ``r_py > 0`` origin set, while an injected measured/forecast demand
    table frees origins with zero live traffic from the SLO — a replica
    nobody reads from must be droppable (Alg. 3), which a constraint pinned
    to retired synthetic reads would forbid forever."""
    I, D = r_xy.shape
    ok: Dict[str, bool] = {}
    routed = state.route >= 0
    # (a) sigma <= delta and exactly one serving DC per requested item
    r_safe = np.where(routed, state.route, 0)
    served_has_replica = np.where(
        routed, state.delta[np.arange(I)[:, None], r_safe], True
    )
    ok["a_route_on_replica"] = bool(served_has_replica.all())
    requested = r_xy > 0
    ok["a_requested_routed"] = bool((routed | ~requested).all())
    # (b) rho only on DCs holding all the referenced items' replicas
    ok_b = True
    for p in patterns:
        for y in np.where(p.r_py > 0)[0]:
            if not requested[p.items, y].any():
                continue  # no live demand for this pattern at y
            d = state.route[p.items, y]
            if (d < 0).any():
                ok_b = False
                break
            if not state.delta[p.items, d].all():
                ok_b = False
                break
    ok["b_pattern_route_on_replica"] = ok_b
    # (c) average read latency <= Gamma_max
    num = 0.0
    den = 0.0
    for y in range(D):
        d = state.route[:, y]
        m = (d >= 0) & requested[:, y]
        if not m.any():
            continue
        l = np.array(
            [env.request_latency(int(dd), y, float(sizes[x])) for x, dd in zip(np.where(m)[0], d[m])]
        )
        num += (r_xy[m, y] * l).sum()
        den += m.sum()
    ok["c_avg_latency"] = bool(den == 0 or num / max(den, 1) <= gamma_max_s)
    # (d) per-pattern straggler <= eta_p * Gamma_max
    ok_d = True
    for p in patterns:
        for y in np.where(p.r_py > 0)[0]:
            if not requested[p.items, y].any():
                continue  # no live demand for this pattern at y
            _, lat = pattern_latencies(p.items, int(y), state, sizes, env)
            if len(lat) and lat.max() > p.eta * gamma_max_s + 1e-12:
                ok_d = False
    ok["d_pattern_slo"] = ok_d
    ok["e_binary"] = True  # by construction of the dtypes
    return ok
