"""Graph containers and format builders for the GeoLayer store.

The control plane (placement / routing decisions) operates on NumPy arrays;
the data plane (heat diffusion, analytics) consumes the CSR/ELL/COO tensors
produced here as jnp arrays.  All structures are immutable-by-convention.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Graph",
    "CSR",
    "ELL",
    "build_csr",
    "build_ell",
    "weakly_connected_components",
    "subgraph_edges",
    "grow_item_rows",
]


@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row adjacency.  indptr[n+1], indices[nnz]."""

    indptr: np.ndarray
    indices: np.ndarray
    weights: Optional[np.ndarray] = None

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u] : self.indptr[u + 1]]


@dataclasses.dataclass(frozen=True)
class ELL:
    """Padded neighbor-list (ELLPACK) adjacency for TPU-friendly SpMV.

    ``cols[n, max_deg]`` padded with ``n`` (self-loop sentinel) and
    ``mask[n, max_deg]`` 1.0 for real edges.  An optional COO tail holds
    overflow edges for nodes whose degree exceeds ``max_deg``.
    """

    cols: np.ndarray  # [n, max_deg] int32
    vals: np.ndarray  # [n, max_deg] float32 (edge weight; 0 where padded)
    tail_src: np.ndarray  # [t] int32 overflow COO
    tail_dst: np.ndarray  # [t] int32
    tail_val: np.ndarray  # [t] float32

    @property
    def n_nodes(self) -> int:
        return int(self.cols.shape[0])

    @property
    def max_degree(self) -> int:
        return int(self.cols.shape[1])


@dataclasses.dataclass
class Graph:
    """A (possibly directed) graph with per-item sizes and a geo partition.

    Vertices and edges are both *data items* in the GeoLayer cost model.
    Item ids: vertex v -> v;  edge e (index into ``src``) -> n_nodes + e.
    """

    n_nodes: int
    src: np.ndarray  # [m] int32
    dst: np.ndarray  # [m] int32
    node_size: np.ndarray  # [n] float32, bytes (or normalized units)
    edge_size: np.ndarray  # [m] float32
    partition: np.ndarray  # [n] int32 -> DC id owning the primary copy

    def __post_init__(self) -> None:
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        self.node_size = np.asarray(self.node_size, dtype=np.float32)
        self.edge_size = np.asarray(self.edge_size, dtype=np.float32)
        self.partition = np.asarray(self.partition, dtype=np.int32)

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_items(self) -> int:
        return self.n_nodes + self.n_edges

    def item_size(self) -> np.ndarray:
        return np.concatenate([self.node_size, self.edge_size])

    def edge_item_id(self, e: np.ndarray) -> np.ndarray:
        return np.asarray(e) + self.n_nodes

    def is_cross_edge(self) -> np.ndarray:
        """Boolean mask of edges whose endpoints live in different DCs."""
        return self.partition[self.src] != self.partition[self.dst]

    def edge_dc_pair(self) -> Tuple[np.ndarray, np.ndarray]:
        return self.partition[self.src], self.partition[self.dst]

    @staticmethod
    def from_edges(
        n_nodes: int,
        src: Sequence[int],
        dst: Sequence[int],
        partition: Sequence[int],
        node_size: Optional[Sequence[float]] = None,
        edge_size: Optional[Sequence[float]] = None,
    ) -> "Graph":
        src = np.asarray(src, dtype=np.int32)
        dst = np.asarray(dst, dtype=np.int32)
        m = src.shape[0]
        if node_size is None:
            node_size = np.ones(n_nodes, dtype=np.float32)
        if edge_size is None:
            edge_size = np.ones(m, dtype=np.float32)
        return Graph(
            n_nodes=n_nodes,
            src=src,
            dst=dst,
            node_size=np.asarray(node_size, dtype=np.float32),
            edge_size=np.asarray(edge_size, dtype=np.float32),
            partition=np.asarray(partition, dtype=np.int32),
        )


def build_csr(
    n_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    weights: Optional[np.ndarray] = None,
    symmetrize: bool = False,
) -> CSR:
    """Build CSR from an edge list; optionally add reverse edges."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        if weights is not None:
            weights = np.concatenate([weights, weights])
    order = np.argsort(src, kind="stable")
    src_s, dst_s = src[order], dst[order]
    counts = np.bincount(src_s, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    w = weights[order].astype(np.float32) if weights is not None else None
    return CSR(indptr=indptr, indices=dst_s.astype(np.int32), weights=w)


def build_ell(
    csr: CSR,
    max_degree: Optional[int] = None,
    degree_quantile: float = 0.98,
) -> ELL:
    """Pack a CSR into ELL with a COO tail for overflow (power-law safe).

    ``max_degree`` defaults to the ``degree_quantile`` of the degree
    distribution, rounded up to a multiple of 8 (VPU lane friendliness).
    """
    n = csr.n_nodes
    deg = csr.degree()
    if max_degree is None:
        q = int(np.quantile(deg, degree_quantile)) if n else 1
        max_degree = max(8, int(np.ceil(max(q, 1) / 8.0)) * 8)
    cols = np.full((n, max_degree), fill_value=np.arange(n)[:, None], dtype=np.int32)
    vals = np.zeros((n, max_degree), dtype=np.float32)
    tail_src: List[int] = []
    tail_dst: List[int] = []
    tail_val: List[float] = []
    w = csr.weights if csr.weights is not None else np.ones(csr.n_edges, np.float32)
    for u in range(n):
        lo, hi = int(csr.indptr[u]), int(csr.indptr[u + 1])
        k = hi - lo
        take = min(k, max_degree)
        cols[u, :take] = csr.indices[lo : lo + take]
        vals[u, :take] = w[lo : lo + take]
        if k > max_degree:
            tail_src.extend([u] * (k - max_degree))
            tail_dst.extend(csr.indices[lo + max_degree : hi].tolist())
            tail_val.extend(w[lo + max_degree : hi].tolist())
    return ELL(
        cols=cols,
        vals=vals,
        tail_src=np.asarray(tail_src, dtype=np.int32),
        tail_dst=np.asarray(tail_dst, dtype=np.int32),
        tail_val=np.asarray(tail_val, dtype=np.float32),
    )


def weakly_connected_components(
    n_nodes: int, src: np.ndarray, dst: np.ndarray
) -> np.ndarray:
    """Label weakly connected components via union-find.  Returns [n] labels
    renumbered to 0..k-1 (order of first appearance)."""
    parent = np.arange(n_nodes, dtype=np.int64)

    def find(a: int) -> int:
        root = a
        while parent[root] != root:
            root = parent[root]
        while parent[a] != root:  # path compression
            parent[a], a = root, parent[a]
        return root

    for u, v in zip(np.asarray(src).tolist(), np.asarray(dst).tolist()):
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    labels = np.fromiter((find(i) for i in range(n_nodes)), dtype=np.int64, count=n_nodes)
    _, renum = np.unique(labels, return_inverse=True)
    return renum.astype(np.int32)


def subgraph_edges(g: Graph, edge_mask: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Return (src, dst) of the edges selected by ``edge_mask``."""
    return g.src[edge_mask], g.dst[edge_mask]


def grow_item_rows(
    a: np.ndarray, old_n_nodes: int, n_new_vertices: int, n_new_edges: int, fill
) -> np.ndarray:
    """Grow an item-indexed array for a mutation batch, preserving the
    ``vertex v -> v, edge e -> n_nodes + e`` id layout: new-vertex rows are
    inserted *mid* (end of the vertex block, shifting every edge item id by
    ``n_new_vertices``) and new-edge rows appended at the end.

    This is the single encoding of the id-space shift — placement rows, the
    route index and heat caches must all grow through it so their rows stay
    aligned.  Works for 1-D ([I] fields) and 2-D ([I, D] tables) arrays.
    """
    tail = a.shape[1:]
    mid = np.full((n_new_vertices, *tail), fill, dtype=a.dtype)
    end = np.full((n_new_edges, *tail), fill, dtype=a.dtype)
    return np.concatenate([a[:old_n_nodes], mid, a[old_n_nodes:], end])
