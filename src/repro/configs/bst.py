"""bst [recsys]: Behavior Sequence Transformer [arXiv:1905.06874]:
embed_dim=32, seq_len=20, 1 block, 8 heads, MLP 1024-512-256.
Item vocab 2^22 (4.2M rows; row-sharded over `model`)."""
from ..models.recsys.bst import BSTSpec
from .base import RecsysArch

ARCH = RecsysArch(
    "bst",
    spec=BSTSpec(
        n_items=1 << 22,
        n_cats=16384,
        embed_dim=32,
        seq_len=20,
        n_blocks=1,
        n_heads=8,
        mlp_dims=(1024, 512, 256),
    ),
    smoke_spec=BSTSpec(
        n_items=1024, n_cats=64, embed_dim=16, seq_len=8, n_blocks=1,
        n_heads=2, mlp_dims=(32, 16),
    ),
)
