"""egnn [gnn]: 4 layers, d_hidden=64, E(n)-equivariant [arXiv:2102.09844]."""
import jax
import jax.numpy as jnp

from ..models.gnn.egnn import egnn_forward, egnn_init
from ..models.layers import mlp, mlp_init
from .base import GNNArch

_FULL = dict(n_layers=4, d_hidden=64)
_SMOKE = dict(n_layers=2, d_hidden=16)


def _init(key, d_in, d_out, full):
    c = _FULL if full else _SMOKE
    k1, k2 = jax.random.split(key)
    return {
        "body": egnn_init(k1, d_in, c["d_hidden"], c["n_layers"]),
        "head": mlp_init(k2, (c["d_hidden"], d_out)),
        "_n_layers": jnp.zeros((c["n_layers"],)),  # static marker
    }


def _forward(params, batch, full, shape_name=None):
    c = _FULL if full else _SMOKE
    h, _ = egnn_forward(params["body"], batch, c["n_layers"])
    return mlp(params["head"], h, dtype=jnp.float32)


ARCH = GNNArch("egnn", _init, _forward)
