"""The paper's own system config: GeoLayer store defaults (§VII setup).

Not one of the 40 arch cells — this is the configuration surface for the
geo-distributed graph store itself (examples/ + benchmarks/ consume it)."""
import dataclasses

from ..core.dhd import DHDParams
from ..core.placement import PlacementConfig


@dataclasses.dataclass(frozen=True)
class GeoLayerSystemConfig:
    n_dcs: int = 5  # Table I environment
    latency_interval_s: float = 0.100  # paper: 100 ms layer buckets
    gamma_max_s: float = 0.5  # fraud-detection SLO (500 ms)
    lambda1: float = 0.5
    lambda2: float = 0.5
    dhd: DHDParams = DHDParams(alpha=0.5, gamma=0.1, beta=0.3)
    theta_quantile: float = 0.55  # pre-cache threshold (Fig. 12 optimum)
    n_history_patterns: int = 1000
    n_test_patterns: int = 100
    write_fraction: float = 0.3

    def placement_config(self) -> PlacementConfig:
        return PlacementConfig(
            gamma_max_s=self.gamma_max_s,
            lambda1=self.lambda1,
            lambda2=self.lambda2,
            dhd=self.dhd,
            theta_quantile=self.theta_quantile,
        )


CONFIG = GeoLayerSystemConfig()
