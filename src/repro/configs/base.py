"""Config system: arch specs, shape cells, abstract inputs, step functions.

Every assigned architecture registers an ``ArchSpec`` subclass instance that
knows how to (a) build full + smoke model configs, (b) enumerate its
(shape x kind) cells with skip rules, (c) produce ShapeDtypeStruct inputs +
PartitionSpecs for the dry-run, and (d) build the jit-able step function.

FLOP accounting note: dry-run configs unroll layer stacks (scan bodies are
costed once by XLA); training uses scan.  The one exception is
equiformer-v2's edge-chunk scan on huge graphs — corrected analytically
(see ``flops_correction``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..distributed.sharding import param_spec_bst, param_spec_gnn, param_spec_lm
from ..models import transformer as tf
from ..models.layers import cross_entropy
from ..models.recsys.bst import (
    BSTSpec,
    bst_forward,
    bst_init,
    bst_user_state,
    retrieval_score,
)
from ..train.optimizer import OptConfig, adamw_init, adamw_update

__all__ = [
    "Cell",
    "ArchSpec",
    "LMArch",
    "GNNArch",
    "RecsysArch",
    "LM_SHAPES",
    "GNN_SHAPES",
    "RECSYS_SHAPES",
    "pad_to",
]

OPT = OptConfig()


def pad_to(n: int, mult: int = 512) -> int:
    return ((n + mult - 1) // mult) * mult


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    kind: str  # train | prefill | decode | serve | retrieval
    skip: Optional[str] = None  # reason, if inapplicable
    flops_correction: float = 1.0  # multiplier for scan-undercounted HLO

    @property
    def key(self) -> str:
        return f"{self.arch}/{self.shape}"


# ---------------------------------------------------------------------------
# Shape tables (assigned)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    kind: str
    seq_len: int
    global_batch: int


LM_SHAPES = [
    LMShape("train_4k", "train", 4096, 256),
    LMShape("prefill_32k", "prefill", 32768, 32),
    LMShape("decode_32k", "decode", 32768, 128),
    LMShape("long_500k", "decode", 524288, 1),
]


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    n_nodes: int
    n_edges: int
    d_feat: int  # feature dim (or n_species for int features)
    n_classes: int
    task: str  # node_class | graph_reg
    n_graphs: int = 1
    resident_nodes: int = 0  # minibatch: resident feature-table rows
    seeds: int = 0  # minibatch: #seed nodes with labels
    int_features: bool = False


GNN_SHAPES = [
    GNNShape("full_graph_sm", pad_to(2708), pad_to(10556), 1433, 7, "node_class"),
    # reddit-scale sampled block: 1024 seeds, fanout 15-10
    GNNShape(
        "minibatch_lg",
        pad_to(1024 + 1024 * 15 + 1024 * 150),
        pad_to(1024 * 15 + 1024 * 150),
        602,
        41,
        "node_class",
        resident_nodes=pad_to(232_965),
        seeds=1024,
    ),
    GNNShape(
        "ogb_products", pad_to(2_449_029), pad_to(61_859_140), 100, 47, "node_class"
    ),
    GNNShape(
        "molecule", pad_to(128 * 30), pad_to(128 * 64), 16, 0, "graph_reg",
        n_graphs=128, int_features=False,
    ),
]


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    kind: str
    batch: int
    n_candidates: int = 0


RECSYS_SHAPES = [
    RecsysShape("train_batch", "train", 65536),
    RecsysShape("serve_p99", "serve", 512),
    RecsysShape("serve_bulk", "serve", 262144),
    RecsysShape("retrieval_cand", "retrieval", 1, pad_to(1_000_000)),
]


# ---------------------------------------------------------------------------
# Base spec
# ---------------------------------------------------------------------------
class ArchSpec:
    name: str = ""
    family: str = ""

    def depth_points(self):
        return None  # no depth scan: HLO costing is exact

    def cells(self) -> List[Cell]:
        raise NotImplementedError

    def abstract_state(self) -> Tuple[Any, Any]:
        """(params ShapeDtypeStruct tree, opt ShapeDtypeStruct tree)."""
        raise NotImplementedError

    def param_partition(self, state_shape) -> Tuple[Any, Any]:
        raise NotImplementedError

    def make_step(self, cell: Cell) -> Callable:
        raise NotImplementedError

    def inputs(self, cell: Cell, mesh: Mesh) -> Tuple[Tuple, Tuple]:
        """(abstract args, PartitionSpec trees), *excluding* params/opt."""
        raise NotImplementedError

    # smoke-test interface
    def smoke_params(self, key):
        raise NotImplementedError

    def smoke_batch(self, key) -> Dict[str, jnp.ndarray]:
        raise NotImplementedError

    def smoke_loss(self, params, batch) -> jnp.ndarray:
        raise NotImplementedError


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def make_train_step(loss_fn: Callable) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, info = adamw_update(grads, opt_state, params, OPT)
        return new_params, new_opt, loss

    return train_step


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------
class LMArch(ArchSpec):
    family = "lm"

    def __init__(
        self,
        name: str,
        cfg: tf.LMConfig,
        smoke_cfg: tf.LMConfig,
        sub_quadratic: bool = False,
        ep_divisible: bool = True,
    ) -> None:
        self.name = name
        self.cfg = cfg  # scan_layers=True: production layout (memory compile)
        self.smoke_cfg = smoke_cfg
        self.sub_quadratic = sub_quadratic
        self.ep_divisible = ep_divisible

    # differential costing: XLA costs scan bodies once, so the dry-run also
    # compiles two shallow *unrolled* variants and extrapolates linearly in
    # depth (launch/dryrun.py).  Returns (L_a, L_b, L_full).
    def depth_points(self) -> Optional[Tuple[int, int, int]]:
        if self.cfg.local_global_ratio > 0:
            period = self.cfg.local_global_ratio + 1
            return (period, 2 * period, self.cfg.n_layers)
        return (1, 2, self.cfg.n_layers)

    def variant(self, depth: int) -> "LMArch":
        v = LMArch(
            name=f"{self.name}@L{depth}",
            cfg=dataclasses.replace(
                self.cfg, n_layers=depth, scan_layers=False
            ),
            smoke_cfg=self.smoke_cfg,
            sub_quadratic=self.sub_quadratic,
            ep_divisible=self.ep_divisible,
        )
        return v

    def cells(self) -> List[Cell]:
        out = []
        for s in LM_SHAPES:
            skip = None
            if s.name == "long_500k" and not self.sub_quadratic:
                skip = (
                    "pure full-attention arch: 500k-context decode requires "
                    "sub-quadratic attention (assignment skip rule; DESIGN §6)"
                )
            out.append(Cell(self.name, s.name, s.kind, skip))
        return out

    def shape(self, name: str) -> LMShape:
        return next(s for s in LM_SHAPES if s.name == name)

    # ------------------------------------------------------------- abstracts
    def abstract_state(self):
        p = jax.eval_shape(lambda: tf.init_params(jax.random.PRNGKey(0), self.cfg))
        o = jax.eval_shape(adamw_init, p)
        return p, o

    def param_partition(self, state_shape):
        p_shape, _ = state_shape
        pspec = param_spec_lm(p_shape, self.ep_divisible, fsdp=True)
        ospec = {"mu": pspec, "nu": pspec, "step": P()}
        return pspec, ospec

    # ----------------------------------------------------------------- steps
    def make_step(self, cell: Cell) -> Callable:
        cfg = self.cfg
        if cell.kind == "train":
            return make_train_step(lambda p, b: tf.train_loss(p, b, cfg))
        if cell.kind == "prefill":
            return lambda params, tokens: tf.prefill(params, tokens, cfg)
        if cell.kind == "decode":
            return lambda params, token, caches, position: tf.decode(
                params, token, caches, position, cfg
            )
        raise ValueError(cell.kind)

    # ---------------------------------------------------------------- inputs
    def _cache_struct(self, B: int, S: int):
        c = self.cfg
        if c.mla:
            return {
                "c_kv": _sds((c.n_layers, B, S, c.kv_lora_rank), c.dtype),
                "k_rope": _sds((c.n_layers, B, S, c.qk_rope_dim), c.dtype),
            }
        return {
            "k": _sds((c.n_layers, B, c.n_kv_heads, S, c.hd), c.dtype),
            "v": _sds((c.n_layers, B, c.n_kv_heads, S, c.hd), c.dtype),
        }

    def _cache_spec(self, mesh: Mesh, batch_sharded: bool, seq_sharded: bool):
        c = self.cfg
        dp = dp_axes(mesh)
        b_ax = dp if batch_sharded else None
        s_ax = "data" if seq_sharded else None
        if seq_sharded:
            b_ax = None  # B=1 long-context
        if c.mla:
            return {
                "c_kv": P(None, b_ax, s_ax, "model"),
                "k_rope": P(None, b_ax, s_ax, None),
            }
        # shard kv-head axis when it divides the model axis, else head_dim
        model_n = mesh.shape["model"]
        if c.n_kv_heads % model_n == 0:
            return {
                "k": P(None, b_ax, "model", s_ax, None),
                "v": P(None, b_ax, "model", s_ax, None),
            }
        return {
            "k": P(None, b_ax, None, s_ax, "model"),
            "v": P(None, b_ax, None, s_ax, "model"),
        }

    def inputs(self, cell: Cell, mesh: Mesh):
        s = self.shape(cell.shape)
        dp = dp_axes(mesh)
        B, S = s.global_batch, s.seq_len
        if cell.kind == "train":
            batch = {
                "tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32),
            }
            spec = {"tokens": P(dp, None), "labels": P(dp, None)}
            return (batch,), (spec,)
        if cell.kind == "prefill":
            return (
                (_sds((B, S), jnp.int32),),
                (P(dp, None),),
            )
        if cell.kind == "decode":
            long_ctx = S > 100_000
            caches = self._cache_struct(B, S)
            cspec = self._cache_spec(
                mesh, batch_sharded=not long_ctx, seq_sharded=long_ctx
            )
            tok = _sds((B,), jnp.int32)
            pos = _sds((B,), jnp.int32)
            tspec = P(dp) if not long_ctx else P()
            return (tok, caches, pos), (tspec, cspec, tspec)
        raise ValueError(cell.kind)

    # ----------------------------------------------------------------- smoke
    def smoke_params(self, key):
        return tf.init_params(key, self.smoke_cfg)

    def smoke_batch(self, key):
        tok = jax.random.randint(key, (2, 16), 0, self.smoke_cfg.vocab_size)
        return {"tokens": tok, "labels": tok}

    def smoke_loss(self, params, batch):
        loss, _ = tf.train_loss(params, batch, self.smoke_cfg)
        return loss


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------
class GNNArch(ArchSpec):
    """GNN arch: supplies ``init_fn(key, d_in, d_out, full)`` and
    ``forward_fn(params, batch, full)`` -> [N, d_out]."""

    family = "gnn"

    def __init__(
        self,
        name: str,
        init_fn: Callable,
        forward_fn: Callable,
        flops_correction: Dict[str, float] = {},
        variant_builder: Optional[Callable] = None,
        depth_full: int = 0,
    ) -> None:
        self.name = name
        self.init_fn = init_fn
        self.forward_fn = forward_fn
        self._fc = dict(flops_correction)
        self.variant_builder = variant_builder
        self.depth_full = depth_full

    def depth_points(self) -> Optional[Tuple[int, int, int]]:
        if self.variant_builder is None:
            return None  # model is fully unrolled already (exact costing)
        return (1, 2, self.depth_full)

    def variant(self, depth: int) -> "GNNArch":
        init_fn, forward_fn = self.variant_builder(depth)
        return GNNArch(f"{self.name}@L{depth}", init_fn, forward_fn, self._fc)

    def cells(self) -> List[Cell]:
        return [
            Cell(self.name, s.name, "train", None, self._fc.get(s.name, 1.0))
            for s in GNN_SHAPES
        ]

    def shape(self, name: str) -> GNNShape:
        return next(s for s in GNN_SHAPES if s.name == name)

    def _d_out(self, s: GNNShape) -> int:
        return s.n_classes if s.task == "node_class" else 1

    def abstract_state_for(self, shape_name: str):
        s = self.shape(shape_name)
        p = jax.eval_shape(
            lambda: self.init_fn(
                jax.random.PRNGKey(0), s.d_feat, self._d_out(s), True
            )
        )
        o = jax.eval_shape(adamw_init, p)
        return p, o

    def abstract_state(self):
        return self.abstract_state_for("full_graph_sm")

    def param_partition(self, state_shape):
        p_shape, _ = state_shape
        pspec = param_spec_gnn(p_shape)
        ospec = {"mu": pspec, "nu": pspec, "step": P()}
        return pspec, ospec

    def loss_fn(self, shape_name: str, full: bool = True) -> Callable:
        s = self.shape(shape_name)
        fwd = self.forward_fn

        def loss(params, batch):
            b = dict(batch)
            if s.resident_nodes:  # gather sampled-block features on device
                b["x"] = batch["feats_resident"][batch["node_ids"]]
            out = fwd(params, b, full, s.name)
            if s.task == "node_class":
                if s.seeds:  # minibatch: loss on seed nodes only
                    logits = out[: s.seeds]
                    ce = cross_entropy(logits, batch["labels"][: s.seeds])
                else:
                    ce = cross_entropy(
                        out, batch["labels"], mask=batch["node_mask"].astype(jnp.float32)
                    )
                return ce, {"ce": ce}
            # graph regression: masked sum-readout per graph
            from ..models.gnn.common import graph_readout

            e = graph_readout(
                out, batch["graph_id"], s.n_graphs, batch["node_mask"]
            )[:, 0]
            mse = jnp.mean((e - batch["energy"]) ** 2)
            return mse, {"mse": mse}

        return loss

    def make_step(self, cell: Cell) -> Callable:
        return make_train_step(self.loss_fn(cell.shape, full=True))

    def inputs(self, cell: Cell, mesh: Mesh):
        s = self.shape(cell.shape)
        ax = all_axes(mesh)
        N, E = s.n_nodes, s.n_edges
        batch: Dict[str, Any] = {
            "pos": _sds((N, 3), jnp.float32),
            "edge_src": _sds((E,), jnp.int32),
            "edge_dst": _sds((E,), jnp.int32),
            "edge_mask": _sds((E,), jnp.bool_),
            "node_mask": _sds((N,), jnp.bool_),
        }
        spec: Dict[str, Any] = {
            "pos": P(ax, None),
            "edge_src": P(ax),
            "edge_dst": P(ax),
            "edge_mask": P(ax),
            "node_mask": P(ax),
        }
        if s.resident_nodes:
            batch["feats_resident"] = _sds((s.resident_nodes, s.d_feat), jnp.float32)
            spec["feats_resident"] = P(ax, None)
            batch["node_ids"] = _sds((N,), jnp.int32)
            spec["node_ids"] = P(ax)
            batch["labels"] = _sds((N,), jnp.int32)
            spec["labels"] = P(ax)
        else:
            batch["x"] = _sds((N, s.d_feat), jnp.float32)
            spec["x"] = P(ax, None)
            if s.task == "node_class":
                batch["labels"] = _sds((N,), jnp.int32)
                spec["labels"] = P(ax)
            else:
                batch["graph_id"] = _sds((N,), jnp.int32)
                spec["graph_id"] = P(ax)
                batch["energy"] = _sds((s.n_graphs,), jnp.float32)
                spec["energy"] = P()
        return (batch,), (spec,)

    # ----------------------------------------------------------------- smoke
    def smoke_params(self, key):
        return self.init_fn(key, 8, 3, False)

    def smoke_batch(self, key):
        rng = np.random.default_rng(0)
        n, e = 24, 48
        return {
            "x": jnp.asarray(rng.standard_normal((n, 8)), jnp.float32),
            "pos": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
            "edge_src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
            "edge_dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
            "edge_mask": jnp.ones((e,), bool),
            "node_mask": jnp.ones((n,), bool),
            "labels": jnp.asarray(rng.integers(0, 3, n), jnp.int32),
        }

    def smoke_loss(self, params, batch):
        out = self.forward_fn(params, batch, False, None)
        return cross_entropy(out, batch["labels"])


# ---------------------------------------------------------------------------
# Recsys family (BST)
# ---------------------------------------------------------------------------
class RecsysArch(ArchSpec):
    family = "recsys"

    def __init__(self, name: str, spec: BSTSpec, smoke_spec: BSTSpec) -> None:
        self.name = name
        self.spec = spec
        self.smoke_spec = smoke_spec

    def cells(self) -> List[Cell]:
        return [Cell(self.name, s.name, s.kind) for s in RECSYS_SHAPES]

    def shape(self, name: str) -> RecsysShape:
        return next(s for s in RECSYS_SHAPES if s.name == name)

    def abstract_state(self):
        p = jax.eval_shape(lambda: bst_init(jax.random.PRNGKey(0), self.spec))
        o = jax.eval_shape(adamw_init, p)
        return p, o

    def param_partition(self, state_shape):
        p_shape, _ = state_shape
        pspec = param_spec_bst(p_shape)
        ospec = {"mu": pspec, "nu": pspec, "step": P()}
        return pspec, ospec

    def loss_fn(self) -> Callable:
        spec = self.spec

        def loss(params, batch):
            logits = bst_forward(params, batch, spec)
            lab = batch["label"]
            bce = jnp.mean(
                jnp.maximum(logits, 0) - logits * lab + jnp.log1p(jnp.exp(-jnp.abs(logits)))
            )
            return bce, {"bce": bce}

        return loss

    def make_step(self, cell: Cell) -> Callable:
        spec = self.spec
        if cell.kind == "train":
            return make_train_step(self.loss_fn())
        if cell.kind == "serve":
            return lambda params, batch: bst_forward(params, batch, spec)
        if cell.kind == "retrieval":
            def retrieve(params, batch):
                u = bst_user_state(params, batch, spec)
                return retrieval_score(params, u, batch["cand_ids"])

            return retrieve
        raise ValueError(cell.kind)

    def inputs(self, cell: Cell, mesh: Mesh):
        s = self.shape(cell.shape)
        dp = dp_axes(mesh)
        B, L = s.batch, self.spec.seq_len
        b_ax = dp if B % int(np.prod([mesh.shape[a] for a in dp])) == 0 else None
        batch = {
            "hist_items": _sds((B, L), jnp.int32),
            "hist_cats": _sds((B, L), jnp.int32),
            "target_item": _sds((B,), jnp.int32),
            "target_cat": _sds((B,), jnp.int32),
        }
        spec = {
            "hist_items": P(b_ax, None),
            "hist_cats": P(b_ax, None),
            "target_item": P(b_ax),
            "target_cat": P(b_ax),
        }
        if cell.kind == "train":
            batch["label"] = _sds((B,), jnp.float32)
            spec["label"] = P(b_ax)
        if cell.kind == "retrieval":
            batch["cand_ids"] = _sds((B, s.n_candidates), jnp.int32)
            spec["cand_ids"] = P(None, all_axes(mesh))
        return (batch,), (spec,)

    # ----------------------------------------------------------------- smoke
    def smoke_params(self, key):
        return bst_init(key, self.smoke_spec)

    def smoke_batch(self, key):
        rng = np.random.default_rng(0)
        B, L = 8, self.smoke_spec.seq_len
        return {
            "hist_items": jnp.asarray(rng.integers(0, self.smoke_spec.n_items, (B, L))),
            "hist_cats": jnp.asarray(rng.integers(0, self.smoke_spec.n_cats, (B, L))),
            "target_item": jnp.asarray(rng.integers(0, self.smoke_spec.n_items, B)),
            "target_cat": jnp.asarray(rng.integers(0, self.smoke_spec.n_cats, B)),
            "label": jnp.asarray(rng.random(B) < 0.3, jnp.float32),
        }

    def smoke_loss(self, params, batch):
        logits = bst_forward(params, batch, self.smoke_spec)
        lab = batch["label"]
        return jnp.mean(
            jnp.maximum(logits, 0) - logits * lab + jnp.log1p(jnp.exp(-jnp.abs(logits)))
        )
