"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H MLA(kv_lora=512)
vocab=102400, MoE: 64 routed experts top-6 + 2 shared, d_ff_expert=1408
[arXiv:2405.04434; hf].  Assignment note lists "160 routed" (full V2);
we follow the inline 64e spec, which matches the hf V2-Lite card."""

from ..models.transformer import LMConfig
from .base import LMArch

ARCH = LMArch(
    name="deepseek-v2-lite-16b",
    cfg=LMConfig(
        name="deepseek-v2-lite-16b",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=0,
        vocab_size=102400,
        moe=True,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        d_ff_expert=1408,
        mla=True,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
    ),
    smoke_cfg=LMConfig(
        name="deepseek-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        moe=True,
        n_experts=8,
        n_shared_experts=1,
        top_k=2,
        d_ff_expert=32,
        mla=True,
        kv_lora_rank=32,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        remat=False,
    ),
    sub_quadratic=False,  # MLA is still full attention
    ep_divisible=True,  # 64 % 16 == 0
)
