"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global attention (sliding window 1024), 128k ctx
[hf:google/gemma-3-*; unverified].  Runs long_500k: the hybrid local:global
pattern is sub-quadratic on local layers and linear per decode step."""
from ..models.transformer import LMConfig
from .base import LMArch

ARCH = LMArch(
    name="gemma3-27b",
    cfg=LMConfig(
        name="gemma3-27b",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_ff=21504,
        vocab_size=262144,
        head_dim=128,
        sliding_window=1024,
        local_global_ratio=5,
    ),
    smoke_cfg=LMConfig(
        name="gemma3-smoke",
        n_layers=6,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        sliding_window=8,
        local_global_ratio=5,
        remat=False,
    ),
    sub_quadratic=True,  # hybrid local:global
)
