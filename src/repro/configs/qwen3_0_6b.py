"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936, qk_norm [hf:Qwen/Qwen3-*; hf]."""
from ..models.transformer import LMConfig
from .base import LMArch

ARCH = LMArch(
    name="qwen3-0.6b",
    cfg=LMConfig(
        name="qwen3-0.6b",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=3072,
        vocab_size=151936,
        head_dim=128,
        qk_norm=True,
    ),
    smoke_cfg=LMConfig(
        name="qwen3-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        qk_norm=True,
        remat=False,
    ),
    sub_quadratic=False,
)
