"""meshgraphnet [gnn]: 15 processor steps, d_hidden=128, sum aggregation,
2-layer MLPs [arXiv:2010.03409].  Edge features derived from pos (rel-pos +
norm), the standard MGN encoding."""
import jax.numpy as jnp

from ..models.gnn.meshgraphnet import mgn_forward, mgn_init
from .base import GNNArch

_FULL = dict(n_steps=15, d_hidden=128, mlp_layers=2)
_SMOKE = dict(n_steps=3, d_hidden=16, mlp_layers=2)


def _init(key, d_in, d_out, full):
    c = _FULL if full else _SMOKE
    return mgn_init(
        key, d_in, 4, c["d_hidden"], c["n_steps"], d_out, c["mlp_layers"]
    )


def _forward(params, batch, full, shape_name=None):
    pos = batch["pos"].astype(jnp.float32)
    rel = pos[batch["edge_dst"]] - pos[batch["edge_src"]]
    norm = jnp.linalg.norm(rel, axis=-1, keepdims=True)
    b = dict(batch, edge_attr=jnp.concatenate([rel, norm], -1))
    # full-scale runs use bf16 messages: halves the cross-shard gather bytes
    # (collective term) at negligible accuracy cost for 2-layer MLP blocks
    return mgn_forward(params, b, dtype=jnp.bfloat16 if full else jnp.float32)


def _variant(depth):
    def init_fn(key, d_in, d_out, full):
        c = _FULL if full else _SMOKE
        return mgn_init(key, d_in, 4, c["d_hidden"], depth, d_out, c["mlp_layers"])

    return init_fn, _forward


ARCH = GNNArch(
    "meshgraphnet", _init, _forward, variant_builder=_variant,
    depth_full=_FULL["n_steps"],
)
