"""equiformer-v2 [gnn]: 12 layers, d_hidden=128, l_max=6, m_max=2, 8 heads,
SO(2)-eSCN convolutions [arXiv:2306.12059].  Huge-edge shapes run the
edge-chunked online-softmax path; those cells carry a flops correction
(= n_chunks) because XLA costs scan bodies once."""

from ..models.gnn.equiformer_v2 import EqV2Spec, eqv2_forward, eqv2_init
from .base import GNNArch

_FULL = EqV2Spec(n_layers=12, channels=128, l_max=6, m_max=2, n_heads=8, n_rbf=32)
_SMOKE = EqV2Spec(n_layers=2, channels=8, l_max=2, m_max=1, n_heads=2, n_rbf=8)

# edge chunking per shape: chunks chosen so each chunk is ~2M edges
_CHUNKS = {"ogb_products": 28, "minibatch_lg": 1, "full_graph_sm": 1, "molecule": 1}


def _init(key, d_in, d_out, full):
    spec = _FULL if full else _SMOKE
    spec = EqV2Spec(**{**spec.__dict__, "n_species": d_in})
    return eqv2_init(key, spec, d_out)


def _forward(params, batch, full, shape_name=None):
    spec = _FULL if full else _SMOKE
    d_in = batch["x"].shape[-1] if batch["x"].ndim == 2 else 32
    spec = EqV2Spec(**{**spec.__dict__, "n_species": d_in})
    chunks = _CHUNKS.get(shape_name or "", 1)
    n_edges = batch["edge_src"].shape[0]
    while chunks > 1 and (n_edges % chunks or (n_edges // chunks) % 512):
        chunks -= 1
    return eqv2_forward(params, batch, spec, edge_chunks=chunks)


def _variant(depth):
    def init_fn(key, d_in, d_out, full):
        spec = _FULL if full else _SMOKE
        spec = EqV2Spec(**{**spec.__dict__, "n_species": d_in, "n_layers": depth})
        return eqv2_init(key, spec, d_out)

    def forward_fn(params, batch, full, shape_name=None):
        spec = _FULL if full else _SMOKE
        d_in = batch["x"].shape[-1] if batch["x"].ndim == 2 else 32
        spec = EqV2Spec(
            **{**spec.__dict__, "n_species": d_in, "n_layers": depth}
        )
        chunks = _CHUNKS.get(shape_name or "", 1)
        n_edges = batch["edge_src"].shape[0]
        while chunks > 1 and (n_edges % chunks or (n_edges // chunks) % 512):
            chunks -= 1
        return eqv2_forward(params, batch, spec, edge_chunks=chunks)

    return init_fn, forward_fn


ARCH = GNNArch(
    "equiformer-v2",
    _init,
    _forward,
    # edge-chunk scan body costed once -> multiply by n_chunks (HLO approx;
    # MODEL_FLOPS for this cell is analytic)
    flops_correction={"ogb_products": 28.0},
    variant_builder=_variant,
    depth_full=_FULL.n_layers,
)
