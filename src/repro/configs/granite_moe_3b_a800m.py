"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) vocab=49155
(padded to 49408 for 16-way vocab sharding), MoE 40 experts top-8,
d_ff_expert=512 [hf:ibm-granite/granite-3.0-*; hf].

40 % 16 != 0: experts are PADDED to 48 (8 masked dummies the router can
never select) so the expert axis shards 3-per-device over ``model`` — the
expert analog of vocab padding.  Non-padded TP-within-expert sharding
compiled >15 min under SPMD (EXPERIMENTS §Dry-run notes)."""
from ..models.transformer import LMConfig
from .base import LMArch

ARCH = LMArch(
    name="granite-moe-3b-a800m",
    cfg=LMConfig(
        name="granite-moe-3b-a800m",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=0,
        vocab_size=49408,  # 49155 padded to /256 (sharding divisibility)
        head_dim=64,
        moe=True,
        n_experts=48,  # padded; 40 active
        n_experts_active=40,
        n_shared_experts=0,
        top_k=8,
        d_ff_expert=512,
    ),
    smoke_cfg=LMConfig(
        name="granite-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        head_dim=16,
        moe=True,
        n_experts=5,
        top_k=2,
        d_ff_expert=32,
        remat=False,
    ),
    sub_quadratic=False,
    ep_divisible=True,  # 48 % 16 == 0 after padding
)
