"""yi-6b [dense]: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA [arXiv:2403.04652; hf]."""
from ..models.transformer import LMConfig
from .base import LMArch

ARCH = LMArch(
    name="yi-6b",
    cfg=LMConfig(
        name="yi-6b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        head_dim=128,
    ),
    smoke_cfg=LMConfig(
        name="yi-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        remat=False,
    ),
    sub_quadratic=False,
)
