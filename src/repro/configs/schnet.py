"""schnet [gnn]: 3 interactions, d_hidden=64, 300 Gaussian RBFs, 10 A cutoff
[arXiv:1706.08566].  Feature graphs use x @ embed (soft species)."""
import jax

from ..models.gnn.schnet import schnet_forward, schnet_init
from ..models.layers import mlp_init
from .base import GNNArch

_FULL = dict(n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0)
_SMOKE = dict(n_interactions=2, d_hidden=16, n_rbf=16, cutoff=5.0)


def _init(key, d_in, d_out, full):
    c = _FULL if full else _SMOKE
    k1, k2 = jax.random.split(key)
    p = schnet_init(k1, d_in, c["d_hidden"], c["n_interactions"], c["n_rbf"])
    p["out"] = mlp_init(k2, (c["d_hidden"], c["d_hidden"] // 2, d_out))
    return p


def _forward(params, batch, full, shape_name=None):
    c = _FULL if full else _SMOKE
    return schnet_forward(
        params, batch, c["n_interactions"], c["n_rbf"], c["cutoff"]
    )


ARCH = GNNArch("schnet", _init, _forward)
