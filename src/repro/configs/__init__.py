"""Arch registry: ``get_arch(name)`` / ``list_archs()`` / ``all_cells()``."""
from __future__ import annotations

from typing import Dict, List

from .base import ArchSpec, Cell

_MODULES = [
    "deepseek_v2_lite_16b",
    "granite_moe_3b_a800m",
    "yi_6b",
    "gemma3_27b",
    "qwen3_0_6b",
    "egnn",
    "meshgraphnet",
    "equiformer_v2",
    "schnet",
    "bst",
]

_REGISTRY: Dict[str, ArchSpec] = {}  # geolint: allow[GL001]


def reset_arch_registry() -> None:
    """Drop the lazily-imported arch table (re-imported on next access)."""
    _REGISTRY.clear()


def _load() -> None:
    if _REGISTRY:
        return
    import importlib

    for m in _MODULES:
        mod = importlib.import_module(f".{m}", __package__)
        arch = mod.ARCH
        _REGISTRY[arch.name] = arch


def get_arch(name: str) -> ArchSpec:
    _load()
    return _REGISTRY[name]


def list_archs() -> List[str]:
    _load()
    return sorted(_REGISTRY)


def all_cells() -> List[Cell]:
    _load()
    out: List[Cell] = []
    for name in sorted(_REGISTRY):
        out.extend(_REGISTRY[name].cells())
    return out
