"""Production mesh construction.

Never touches jax device state at import time — everything is a function.
Single-pod: (data=16, model=16) = 256 chips.  Multi-pod: (pod=2, data=16,
model=16) = 512 chips; the ``pod`` axis maps to DCN (slow links), which is
exactly the latency layer the GeoLayer machinery treats as ``Layer_2``
(see distributed/geo_sharding.mesh_env).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_cpu_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py sets this before importing jax)"
        )
    arr = np.asarray(devices[:n]).reshape(shape)
    return Mesh(arr, axes)


def make_cpu_mesh(shape: Sequence[int] = (1, 1), axes: Sequence[str] = ("data", "model")) -> Mesh:
    """Degenerate mesh for CPU smoke tests (1 device)."""
    n = int(np.prod(shape))
    arr = np.asarray(jax.devices()[:n]).reshape(tuple(shape))
    return Mesh(arr, tuple(axes))
