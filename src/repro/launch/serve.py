"""Serving launcher: continuous-batching engine on an LM arch's smoke config.

``python -m repro.launch.serve --arch qwen3-0.6b --requests 8``
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_arch, list_archs
from ..models import transformer as tf
from ..serve.engine import Engine, Request, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if arch.family != "lm":
        raise SystemExit("serving demo targets LM archs")
    cfg = arch.smoke_cfg
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(n_slots=args.slots, max_len=128))
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        eng.submit(
            Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, plen),
                max_new_tokens=args.max_new,
            )
        )
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(
        f"[{args.arch}] served {len(done)} requests, {toks} tokens in {dt:.2f}s "
        f"({toks/dt:.1f} tok/s, {args.slots} slots, continuous batching)"
    )


if __name__ == "__main__":
    main()
