"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, derives the three roofline terms from the
compiled per-device module:

  compute    = HLO_flops / peak_flops          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = wire_bytes / ICI_bw             (~50 GB/s/link; pod-axis
                                                collectives priced at DCN)

plus MODEL_FLOPS (6*N*D dense / 6*N_active*D MoE; analytic edge/einsum
models for GNN/recsys), the useful-compute ratio, the dominant term, and a
one-line lever.  Reads launch/results/dryrun_*.json; writes a markdown
table + JSON summary consumed by EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List, Optional

# --- hardware constants (TPU v5e target; see assignment) -------------------
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 5e10  # bytes/s/link
DCN_BW = 2.5e9  # bytes/s cross-pod (pod-axis collectives)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

import re

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[\w\-\.]*\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _parse_collectives(hlo: str) -> Dict[str, Dict[str, float]]:
    """Census of collective ops: count + tensor bytes + modeled wire bytes."""
    out: Dict[str, Dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        shape = [int(x) for x in dims.split(",") if x] or [1]
        nbytes = float(np.prod(shape)) * _DTYPE_BYTES[dtype]
        # group size from the op's attributes (look ahead in the same line)
        line_end = hlo.find("\n", m.end())
        line = hlo[m.start() : line_end if line_end > 0 else m.end() + 400]
        g = 2.0
        gm = _GROUPS_RE.search(line)
        if gm:
            g = float(len(gm.group(1).split(",")))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = float(gi.group(2))
        if kind == "all-gather":
            wire = nbytes * (g - 1.0) / g
        elif kind == "all-reduce":
            wire = 2.0 * nbytes * (g - 1.0) / g
        elif kind == "reduce-scatter":
            wire = nbytes * (g - 1.0)  # result bytes are post-scatter
        elif kind == "all-to-all":
            wire = nbytes * (g - 1.0) / g
        else:  # collective-permute
            wire = nbytes
        d = out.setdefault(kind, {"count": 0, "tensor_bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += 1
        d["tensor_bytes"] += nbytes
        d["wire_bytes"] += wire
    return out





# ---------------------------------------------------- analytic MODEL_FLOPS
def _lm_model_flops(arch_name: str, shape: str) -> Optional[float]:
    from ..configs import get_arch

    arch = get_arch(arch_name)
    cfg = arch.cfg
    n_active = cfg.active_param_count()
    s = arch.shape(shape)
    tokens = s.global_batch * s.seq_len
    if shape == "train_4k":
        return 6.0 * n_active * tokens  # fwd 2ND + bwd 4ND
    if shape == "prefill_32k":
        return 2.0 * n_active * tokens
    # decode: one token per sequence; attention reads S_ctx keys
    d_attn = (
        2.0 * cfg.n_layers * s.global_batch * s.seq_len
        * cfg.n_heads * cfg.hd * 2 * 2  # qk + pv, 2 flops/MAC
    )
    return 2.0 * n_active * s.global_batch + d_attn


def _gnn_model_flops(arch_name: str, shape: str) -> Optional[float]:
    from ..configs import get_arch
    from ..configs.base import GNN_SHAPES

    s = next(g for g in GNN_SHAPES if g.name == shape)
    N, E = s.n_nodes, s.n_edges
    # per-arch per-edge/node MAC models (x2 flops, x3 for fwd+bwd)
    if arch_name == "egnn":
        d = 64
        per_edge = (2 * d + 1) * d + d * d + d * d + d  # phi_e + phi_x
        per_node = 2 * d * d + d * d  # phi_h
        fwd = 4 * (E * per_edge + N * per_node) * 2
    elif arch_name == "meshgraphnet":
        d = 128
        per_edge = (3 * d) * d + d * d
        per_node = (2 * d) * d + d * d
        fwd = 15 * (E * per_edge + N * per_node) * 2
    elif arch_name == "schnet":
        d, r = 64, 300
        per_edge = r * d + d * d + d  # filter mlp + pre
        per_node = 2 * d * d
        fwd = 3 * (E * per_edge + N * per_node) * 2
    elif arch_name == "equiformer-v2":
        c, lmax, mmax = 128, 6, 2
        # SO(2) mixes: per |m| joint (l, c) matmul both directions
        so2 = sum(
            (2 if m else 1) * ((lmax + 1 - m) * c) ** 2 * 2
            for m in range(mmax + 1)
        )
        rot = 2 * sum((2 * l + 1) ** 2 for l in range(lmax + 1)) * c * 2
        per_edge = so2 + rot
        per_node = (lmax + 1) * c * c * 2 * 2  # out proj + ffn mix
        fwd = 12 * (E * per_edge + N * per_node)
    else:
        return None
    return 3.0 * fwd  # fwd + bwd


def _recsys_model_flops(shape: str) -> Optional[float]:
    from ..configs.base import RECSYS_SHAPES

    s = next(r for r in RECSYS_SHAPES if r.name == shape)
    d, L = 64, 21  # d_tok, seq+target
    attn = L * L * d * 2 * 3 + L * d * d * 4 * 2
    mlp = (L * d) * 1024 + 1024 * 512 + 512 * 256
    per_ex = (attn + mlp * 2)
    if s.kind == "train":
        return 3.0 * s.batch * per_ex
    if s.kind == "retrieval":
        return s.batch * (per_ex + 2.0 * s.n_candidates * 32)
    return 1.0 * s.batch * per_ex


def model_flops(arch: str, shape: str, family: str) -> Optional[float]:
    if family == "lm":
        return _lm_model_flops(arch, shape)
    if family == "gnn":
        return _gnn_model_flops(arch, shape)
    return _recsys_model_flops(shape)


# ----------------------------------------------------------------- analysis
def analyze(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rec.get("skipped") or not rec.get("ok"):
        return None
    from ..configs import get_arch

    arch = get_arch(rec["arch"])
    n_chips = 1
    for v in rec["mesh_shape"].values():
        n_chips *= v
    corr = rec.get("corrected", {})
    # differential extrapolation can go slightly negative when a term is
    # depth-independent and noisy between the two variants — clamp at 0
    flops_dev = max(corr.get("flops_per_device", 0.0), 0.0)
    bytes_dev = max(corr.get("bytes_accessed_per_device", 0.0), 0.0)
    colls = corr.get("collectives", {})
    wire = max(sum(v["wire_bytes"] for v in colls.values()), 0.0)
    # pod-axis (DCN) share: groups spanning both pods have size >= 2x intra
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = wire / ICI_BW
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_collective,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"], arch.family)
    mf_dev = (mf / n_chips) if mf else None
    useful = (mf_dev / flops_dev) if (mf_dev and flops_dev) else None
    bound = max(terms.values())
    frac = t_compute / bound if bound > 0 else 0.0
    lever = {
        "compute_s": "compute-bound: fuse/kernel-level wins only (good place)",
        "memory_s": "memory-bound: raise arithmetic intensity (fuse, bf16 "
        "activations, bigger per-device batch, flash-style attention)",
        "collective_s": "collective-bound: reshard to cut cross-device traffic "
        "(GeoLayer halo/replica placement, overlap collectives with compute)",
    }[dominant]
    mem = rec.get("production", {}).get("memory", {})
    state = rec.get("production", {}).get("state_bytes_per_device", 0)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "wire_bytes_per_device": wire,
        **terms,
        "dominant": dominant.replace("_s", ""),
        "roofline_fraction": frac,
        "model_flops_per_device": mf_dev,
        "useful_flops_ratio": useful,
        "state_gib_per_device": state / 2**30,
        "temp_gib_per_device": mem.get("temp_bytes", 0) / 2**30,
        "args_gib_per_device": mem.get("argument_bytes", 0) / 2**30,
        "lever": lever,
        "collective_detail": colls,
    }


def load_all(mesh: str = "single") -> List[Dict[str, Any]]:
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"dryrun_{mesh}_*.json"))):
        with open(path) as f:
            rec = json.load(f)
        a = analyze(rec)
        if a:
            out.append(a)
        elif rec.get("skipped"):
            out.append(
                {"arch": rec["arch"], "shape": rec["shape"], "mesh": mesh,
                 "skipped": rec["skipped"]}
            )
    return out


def to_markdown(rows: List[Dict[str, Any]]) -> str:
    hdr = (
        "| cell | compute (s) | memory (s) | collective (s) | dominant | "
        "roofline frac | useful ratio | state GiB | temp GiB |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        cell = f"{r['arch']}/{r['shape']}"
        if r.get("skipped"):
            lines.append(f"| {cell} | — | — | — | SKIP | — | — | — | — |")
            continue
        u = r.get("useful_flops_ratio")
        us = f"{u:.2f}" if u else "n/a"
        lines.append(
            f"| {cell} | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['roofline_fraction']:.2f} | {us} | "
            f"{r['state_gib_per_device']:.2f} | {r['temp_gib_per_device']:.2f} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default=os.path.join(RESULTS_DIR, "roofline.json"))
    args = ap.parse_args()
    rows = load_all(args.mesh)
    print(to_markdown(rows))
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    live = [r for r in rows if not r.get("skipped")]
    if live:
        worst = min(live, key=lambda r: r["roofline_fraction"])
        collb = max(live, key=lambda r: r["collective_s"])
        print(f"worst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline_fraction']:.2f})")
        print(f"most collective-bound:  {collb['arch']}/{collb['shape']} "
              f"({collb['collective_s']:.3e}s)")


if __name__ == "__main__":
    main()
