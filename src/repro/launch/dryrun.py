import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (JSON under launch/results/):
  * memory_analysis of the *production* step (scan layout, FSDP shardings)
  * cost_analysis flops / bytes, **differentially corrected** for depth:
    XLA costs scan bodies once, so two shallow unrolled variants (L_a, L_b)
    are compiled and the per-layer cost is extrapolated linearly —
    exact for homogeneous stacks, ~1% error for gemma3's 5:1 mix.
  * collective op census with modeled wire bytes (ring formulas), taken from
    the depth variants and extrapolated the same way.
  * analytic per-device state bytes (params+opt under the cell's shardings).

Usage:
  python -m repro.launch.dryrun --mesh single --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --mesh both --all
"""
import argparse
import json
import time
from typing import Any, Dict

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import all_cells, get_arch
from ..configs.base import Cell
from ..distributed.constraints import use_mesh
from .mesh import make_production_mesh
from .roofline import _parse_collectives

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

def _shard_factor(spec: P, mesh: Mesh) -> int:
    f = 1
    for ax in spec:
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            f *= mesh.shape[a]
    return f


def _state_bytes_per_device(state_shape, spec_tree, mesh: Mesh) -> float:
    total = 0.0
    leaves = jax.tree_util.tree_leaves(state_shape)
    specs = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
    for leaf, spec in zip(leaves, specs):
        nbytes = float(np.prod(leaf.shape)) * leaf.dtype.itemsize if leaf.shape else leaf.dtype.itemsize
        total += nbytes / _shard_factor(spec, mesh)
    return total


def _ns(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def _compile_cell(arch, cell: Cell, mesh: Mesh, donate: bool):
    """Lower+compile one cell; returns (compiled, analyses dict)."""
    state_shape = (
        arch.abstract_state_for(cell.shape)
        if hasattr(arch, "abstract_state_for")
        else arch.abstract_state()
    )
    pspec, ospec = arch.param_partition(state_shape)
    step = arch.make_step(cell)
    in_args, in_specs = arch.inputs(cell, mesh)
    if cell.kind == "train":
        args = (state_shape[0], state_shape[1]) + tuple(in_args)
        specs = (pspec, ospec) + tuple(in_specs)
        donate_argnums = (0, 1) if donate else ()
    else:
        args = (state_shape[0],) + tuple(in_args)
        specs = (pspec,) + tuple(in_specs)
        donate_argnums = ()
        if cell.kind == "decode":
            donate_argnums = (2,) if donate else ()  # donate KV caches
    # durations use the monotonic clock: time.time() deltas jump under NTP
    t0 = time.perf_counter()
    jitted = jax.jit(
        step, in_shardings=_ns(mesh, specs), donate_argnums=donate_argnums
    )
    with use_mesh(mesh):
        lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    ca = compiled.cost_analysis() or {}
    info = {
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_accessed_per_device": float(ca.get("bytes accessed", 0.0)),
        "state_bytes_per_device": _state_bytes_per_device(
            state_shape, (pspec, ospec), mesh
        ),
    }
    try:
        ma = compiled.memory_analysis()
        info["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        info["memory"] = {"error": str(e)}
    info["collectives"] = _parse_collectives(compiled.as_text())
    return info


def run_cell(cell: Cell, mesh: Mesh, mesh_name: str, skip_variants: bool = False) -> Dict[str, Any]:
    arch = get_arch(cell.arch)
    rec: Dict[str, Any] = {
        "arch": cell.arch,
        "shape": cell.shape,
        "kind": cell.kind,
        "mesh": mesh_name,
        "mesh_shape": dict(mesh.shape),
        "flops_correction": cell.flops_correction,
    }
    if cell.skip:
        rec["skipped"] = cell.skip
        return rec
    # production compile: memory + baseline cost
    rec["production"] = _compile_cell(arch, cell, mesh, donate=True)
    # differential depth variants for exact flops/bytes/collectives
    dp = arch.depth_points()
    if dp is not None and not skip_variants:
        la, lb, lfull = dp
        va = _compile_cell(arch.variant(la), cell, mesh, donate=False)
        vb = _compile_cell(arch.variant(lb), cell, mesh, donate=False)
        scale = (lfull - la) / (lb - la)

        def extrap(a: float, b: float) -> float:
            return a + scale * (b - a)

        rec["depth_points"] = {"la": la, "lb": lb, "lfull": lfull}
        rec["corrected"] = {
            "flops_per_device": extrap(
                va["flops_per_device"], vb["flops_per_device"]
            ),
            "bytes_accessed_per_device": extrap(
                va["bytes_accessed_per_device"], vb["bytes_accessed_per_device"]
            ),
        }
        colls: Dict[str, Dict[str, float]] = {}
        kinds = set(va["collectives"]) | set(vb["collectives"])
        zero = {"count": 0, "tensor_bytes": 0.0, "wire_bytes": 0.0}
        for k in kinds:
            a = va["collectives"].get(k, zero)
            b = vb["collectives"].get(k, zero)
            colls[k] = {
                f: extrap(a[f], b[f]) for f in ("count", "tensor_bytes", "wire_bytes")
            }
        rec["corrected"]["collectives"] = colls
        rec["variants"] = {"la": va, "lb": vb}
    else:
        rec["corrected"] = {
            "flops_per_device": rec["production"]["flops_per_device"]
            * cell.flops_correction,
            "bytes_accessed_per_device": rec["production"][
                "bytes_accessed_per_device"
            ]
            * cell.flops_correction,
            "collectives": rec["production"]["collectives"],
        }
    return rec


def result_path(mesh_name: str, cell: Cell) -> str:
    safe = f"{cell.arch}_{cell.shape}".replace("/", "_").replace(".", "_")
    return os.path.join(RESULTS_DIR, f"dryrun_{mesh_name}_{safe}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-variants", action="store_true")
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    cells = all_cells()
    if args.arch:
        cells = [c for c in cells if c.arch == args.arch]
    if args.shape:
        cells = [c for c in cells if c.shape == args.shape]
    if not (args.all or args.arch or args.shape):
        ap.error("pass --all or filter with --arch/--shape")

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for multi in meshes:
        mesh_name = "multi" if multi else "single"
        mesh = make_production_mesh(multi_pod=multi)
        for cell in cells:
            path = result_path(mesh_name, cell)
            if os.path.exists(path) and not args.force:
                print(f"[skip-existing] {mesh_name} {cell.key}")
                continue
            print(f"[dryrun] {mesh_name} {cell.key} ...", flush=True)
            t0 = time.perf_counter()
            try:
                rec = run_cell(cell, mesh, mesh_name, skip_variants=args.no_variants)
                rec["ok"] = True
            except Exception as e:  # noqa: BLE001
                rec = {
                    "arch": cell.arch, "shape": cell.shape, "mesh": mesh_name,
                    "ok": False, "error": f"{type(e).__name__}: {e}",
                }
                print(f"  FAILED: {rec['error']}")
            rec["wall_s"] = round(time.perf_counter() - t0, 1)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec.get("ok") and "production" in rec:
                p = rec["production"]
                c = rec.get("corrected", {})
                mem = p.get("memory", {})
                print(
                    f"  ok {rec['wall_s']}s  flops/dev={c.get('flops_per_device', 0):.3e}"
                    f"  args={mem.get('argument_bytes', 0)/2**30:.2f}GiB"
                    f"  temp={mem.get('temp_bytes', 0)/2**30:.2f}GiB"
                )
            elif rec.get("skipped"):
                print(f"  SKIP: {rec['skipped'][:80]}")


if __name__ == "__main__":
    main()
