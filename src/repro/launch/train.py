"""End-to-end training launcher: ``python -m repro.launch.train --arch <id>``.

On this CPU container it trains the arch's *smoke* config with the real
Trainer (checkpointing, compression, failure injection all live); on a TPU
cluster the same flags select the full config + production mesh.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_arch, list_archs
from ..data.pipeline import TokenPipeline
from ..distributed.fault import FailureSimulator
from ..train.optimizer import OptConfig
from ..train.trainer import Trainer, TrainerConfig


def make_data(arch, seed: int = 0):
    if arch.family == "lm":
        cfg = arch.smoke_cfg
        return iter(TokenPipeline(cfg.vocab_size, batch=8, seq_len=32, seed=seed))
    if arch.family == "recsys":
        from ..data.pipeline import RecsysPipeline

        sp = arch.smoke_spec
        pipe = RecsysPipeline(sp.n_items, sp.n_cats, batch=8, seq_len=sp.seq_len, seed=seed)

        def gen():
            step = 0
            while True:
                b = pipe.batch_at(step)
                yield {
                    "hist_items": b["hist_items"], "hist_cats": b["hist_cats"],
                    "target_item": b["target_item"], "target_cat": b["target_cat"],
                    "label": b["label"],
                }
                step += 1

        return gen()
    # gnn: fixed random graph batch each step (full-batch training)
    key = jax.random.PRNGKey(seed)
    batch = arch.smoke_batch(key)

    def gen():
        while True:
            yield {k: np.asarray(v) for k, v in batch.items()}

    return gen()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs() + ["all"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--compression", default=None, choices=[None, "int8", "topk"])
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step (recovery demo)")
    args = ap.parse_args()

    names = list_archs() if args.arch == "all" else [args.arch]
    for name in names:
        arch = get_arch(name)
        params = arch.smoke_params(jax.random.PRNGKey(0))
        sim = FailureSimulator([(args.fail_at, 1)]) if args.fail_at else None
        tcfg = TrainerConfig(
            total_steps=args.steps,
            ckpt_every=max(args.steps // 4, 1),
            ckpt_dir=f"{args.ckpt_dir}/{name}",
            grad_compression=args.compression,
            opt=OptConfig(lr=1e-3, warmup_steps=5, total_steps=args.steps),
        )
        tr = Trainer(
            lambda p, b: _wrap(arch.smoke_loss)(p, b), params, tcfg, failure_sim=sim
        )
        metrics = tr.run(make_data(arch))
        losses = metrics["loss"]
        print(
            f"[{name}] {len(losses)} steps  loss {losses[0]:.4f} -> {losses[-1]:.4f}"
            + (f"  recoveries={len(metrics.get('recoveries', []))}" if sim else "")
        )


def _wrap(loss_fn):
    def f(params, batch):
        l = loss_fn(params, batch)
        return l, {}

    return f


if __name__ == "__main__":
    main()
