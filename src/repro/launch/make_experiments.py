"""Append generated §Dry-run + §Roofline tables to EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os

from .roofline import RESULTS_DIR, load_all, to_markdown

EXP = os.path.join(os.path.dirname(__file__), "..", "..", "..", "EXPERIMENTS.md")
MARK = "(appended by `python -m repro.launch.make_experiments` after the dry-run)"


def dryrun_table(mesh: str) -> str:
    rows = [
        "| cell | status | compile (s) | flops/dev | args GiB | temp GiB | collectives |",
        "|---|---|---|---|---|---|---|",
    ]
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, f"dryrun_{mesh}_*.json"))):
        with open(path) as f:
            r = json.load(f)
        cell = f"{r['arch']}/{r['shape']}"
        if r.get("skipped"):
            rows.append(f"| {cell} | SKIP ({r['skipped'][:48]}…) | | | | | |")
            continue
        if not r.get("ok"):
            rows.append(f"| {cell} | **FAIL** {r.get('error', '')[:60]} | | | | | |")
            continue
        p = r["production"]
        c = r.get("corrected", {})
        mem = p.get("memory", {})
        colls = c.get("collectives", p.get("collectives", {}))
        cstr = " ".join(f"{k}:{int(v['count'])}" for k, v in sorted(colls.items()))
        rows.append(
            f"| {cell} | ok | {p['t_compile_s']:.0f} | "
            f"{c.get('flops_per_device', 0):.2e} | "
            f"{mem.get('argument_bytes', 0)/2**30:.2f} | "
            f"{mem.get('temp_bytes', 0)/2**30:.1f} | {cstr} |"
        )
    return "\n".join(rows)


def _wire(rec) -> float:
    # production (uncorrected) numbers on BOTH meshes: the multi run skips
    # depth variants, so corrected-vs-production would be apples/oranges
    c = rec.get("production", {}).get("collectives", {})
    return sum(v["wire_bytes"] for v in c.values())


def crosspod_table() -> str:
    """Pod-axis (DCN) pressure: wire-bytes delta multi vs single, priced at
    DCN bandwidth (2.5 GB/s) vs ICI (50 GB/s).  The delta approximates the
    pod-crossing traffic a step adds when the batch spans two pods (plus
    second-order resharding differences); int8-EF gradient compression
    (distributed/compression.py) divides the gradient share by ~4x."""
    rows = [
        "| cell | wire single | wire multi | Δ (≈DCN) | Δ/DCN bw | note |",
        "|---|---|---|---|---|---|",
    ]
    for ps in sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun_single_*.json"))):
        pm = ps.replace("dryrun_single_", "dryrun_multi_")
        if not os.path.exists(pm):
            continue
        with open(ps) as f:
            rs = json.load(f)
        with open(pm) as f:
            rm = json.load(f)
        if not (rs.get("ok") and rm.get("ok")):
            continue
        if rs.get("kind") != "train":
            continue  # DCN pressure is a training (gradient) story
        ws, wm = _wire(rs), _wire(rm)
        delta = max(wm - ws, 0.0)
        rows.append(
            f"| {rs['arch']}/{rs['shape']} | {ws:.2e} | {wm:.2e} | "
            f"{delta:.2e} | {delta/2.5e9:.3f} s | "
            f"{'DCN-bound step' if delta/2.5e9 > ws/5e10 else 'ICI still dominates'} |"
        )
    return "\n".join(rows)


def main() -> None:
    out = ["\n### Dry-run — single-pod (16,16), 256 chips\n"]
    out.append(dryrun_table("single"))
    if glob.glob(os.path.join(RESULTS_DIR, "dryrun_multi_*.json")):
        out.append("\n### Dry-run — multi-pod (2,16,16), 512 chips\n")
        out.append(dryrun_table("multi"))
        out.append("\n### Multi-pod DCN pressure (train cells)\n")
        out.append(crosspod_table())
    out.append("\n### Roofline — single-pod, per device\n")
    out.append(to_markdown(load_all("single")))
    text = "\n".join(out) + "\n"
    path = os.path.abspath(EXP)
    with open(path) as f:
        doc = f.read()
    base = doc.split(MARK)[0] + MARK + "\n"
    with open(path, "w") as f:
        f.write(base + text)
    print(f"wrote generated tables to {path}")


if __name__ == "__main__":
    main()
