"""Runtime invariant sanitizer for :class:`~repro.core.store.GeoGraphStore`.

The static half of this PR (``tools/geolint``) keeps *code* from breaking
the store invariants; this module checks the invariants hold in the
*running* process, with low-frequency differential checks a production
deployment can afford to leave on:

  * **route-index integrity** — the incremental nearest/second index equals
    a from-scratch masked-argmin rebuild (:meth:`RouteIndex.verify`), the
    PR 2 differential run against live state instead of a test fixture.
  * **heat-view aliasing** — every ``HeatCache.heat`` row is still a
    shared-storage view of the demand plane's one ``[D, I]`` table (PR 9's
    exactly-once deposit depends on it; a silent copy would fork the heat).
  * **placement-journal validity** — the journal digests rows through the
    store's live uid table and its memoized region rows are sorted and
    in-range (the PR 3 replay-identity contract after grow/compact remaps).
  * **merged-metrics coherence** — the registry snapshot merges without a
    type clash (:meth:`MetricsRegistry.merge` raises ``ValueError`` when
    one shard registered a name as a counter and another as a gauge).

Enable with ``REPRO_SANITIZE=1``: :func:`maybe_attach` is a no-op without
it, so call sites (benchmarks, the CI smoke lanes) wire it unconditionally.
Attached, the sanitizer wraps the store's mutating entry points and runs
:meth:`StoreSanitizer.check` every ``every``-th mutation.
"""
from __future__ import annotations

import functools
import os
from typing import List, Optional

import numpy as np

__all__ = [
    "SanitizerError",
    "StoreSanitizer",
    "attach_sanitizer",
    "maybe_attach",
    "sanitize_enabled",
]

# store entry points that mutate placement, id space or heat — each wrapped
# call counts one "op" toward the every-N check cadence
_WRAPPED_METHODS = (
    "apply_updates",
    "flush_migrations",
    "compact",
    "maintain",
    "insert_patterns",
    "insert_patterns_incremental",
    "delete_items",
    "precache",
)


def sanitize_enabled() -> bool:
    """True iff ``REPRO_SANITIZE`` is set to a truthy value."""
    return os.environ.get("REPRO_SANITIZE", "").strip().lower() not in (
        "", "0", "false", "no",
    )


class SanitizerError(AssertionError):
    """A store invariant does not hold at runtime."""


class StoreSanitizer:
    """Differential invariant checks over one attached store."""

    def __init__(self, store, every: int = 4) -> None:
        self.store = store
        self.every = max(1, int(every))
        self.ops_seen = 0
        self.checks_run = 0

    # ------------------------------------------------------------- checks
    def _check_route_index(self, failures: List[str]) -> None:
        idx = getattr(self.store, "route_index", None)
        if idx is None:
            return
        if not idx.verify(self.store.state.delta):
            failures.append(
                "route-index divergence: incremental nearest/second index "
                "!= from-scratch rebuild of the current placement (a patch "
                "path missed a replica-set delta)"
            )

    def _check_heat_aliasing(self, failures: List[str]) -> None:
        demand = getattr(self.store, "demand", None)
        caches = getattr(self.store, "caches", None)
        if demand is None or not caches:
            return
        for d, cache in caches.items():
            if cache.demand is not demand:
                failures.append(
                    f"heat aliasing: cache[{d}] holds a different demand "
                    f"layer than the store (heat deposits would fork)"
                )
                continue
            row = cache.heat
            if row.base is not demand.heat or not np.shares_memory(
                row, demand.heat
            ):
                failures.append(
                    f"heat aliasing: cache[{d}].heat is not a view of the "
                    f"demand plane's [D, I] table (copied row — eviction "
                    f"would run on stale heat)"
                )
            elif row.shape != (demand.n_items,):
                failures.append(
                    f"heat aliasing: cache[{d}].heat shape {row.shape} != "
                    f"({demand.n_items},)"
                )

    def _check_journal(self, failures: List[str]) -> None:
        journal = getattr(self.store, "_placement_journal", None)
        if journal is None:
            return
        uid = getattr(self.store, "_item_uid", None)
        if uid is not None:
            if journal.item_uid is not uid:
                failures.append(
                    "journal digest: journal.item_uid is not the store's "
                    "live uid table (fingerprints would go stale across "
                    "compaction)"
                )
            elif len(np.unique(uid)) != len(uid):
                failures.append("journal digest: store uid table has duplicates")
        n_items = int(self.store.g.n_items)
        for regions in journal.regions.values():
            for r in regions:
                items = np.asarray(r.items)
                if items.size == 0:
                    continue
                if items.min() < 0 or items.max() >= n_items:
                    failures.append(
                        "journal digest: memoized region rows out of range "
                        "after a remap (stale imap application)"
                    )
                    return
                if np.any(np.diff(items) < 0):
                    failures.append(
                        "journal digest: memoized region rows unsorted — "
                        "breaks the decompose invariant on replay"
                    )
                    return

    def _check_metrics_merge(self, failures: List[str]) -> None:
        from ..obs.metrics import MetricsRegistry, get_registry

        snaps = []
        reg_fn = getattr(self.store, "_reg", None)
        if callable(reg_fn):
            reg = reg_fn()
        else:
            reg = getattr(self.store, "registry", None) or get_registry()
        snaps.append(reg.snapshot())
        for shard_reg in getattr(self.store, "shard_registries", []) or []:
            snaps.append(shard_reg.snapshot())
        try:
            MetricsRegistry.merge(snaps * 2)  # self-merge exercises type checks
        except ValueError as e:
            failures.append(f"metrics merge: type clash across snapshots ({e})")

    # -------------------------------------------------------------- driver
    def check(self) -> bool:
        """Run every invariant check; raises :class:`SanitizerError` on the
        first batch of failures, returns True when all hold."""
        failures: List[str] = []
        self._check_route_index(failures)
        self._check_heat_aliasing(failures)
        self._check_journal(failures)
        self._check_metrics_merge(failures)
        if failures:
            raise SanitizerError(
                "store invariant violation(s):\n  - " + "\n  - ".join(failures)
            )
        self.checks_run += 1
        return True

    def maybe_check(self) -> None:
        self.ops_seen += 1
        if self.ops_seen % self.every == 0:
            self.check()


def attach_sanitizer(store, every: int = 4) -> StoreSanitizer:
    """Wrap ``store``'s mutating entry points with every-N invariant checks.

    Idempotent: re-attaching returns the existing sanitizer.  The check runs
    *after* the wrapped mutation, so a violation names the op that caused it.
    """
    existing = getattr(store, "_sanitizer", None)
    if existing is not None:
        return existing
    sanitizer = StoreSanitizer(store, every=every)
    for name in _WRAPPED_METHODS:
        fn = getattr(store, name, None)
        if fn is None:
            continue

        def wrapped(*args, __fn=fn, **kwargs):
            out = __fn(*args, **kwargs)
            sanitizer.maybe_check()
            return out

        functools.update_wrapper(wrapped, fn)
        setattr(store, name, wrapped)
    store._sanitizer = sanitizer
    return sanitizer


def maybe_attach(store, every: int = 4) -> Optional[StoreSanitizer]:
    """:func:`attach_sanitizer` iff ``REPRO_SANITIZE`` is set; else no-op."""
    if not sanitize_enabled():
        return None
    return attach_sanitizer(store, every=every)
