"""Runtime invariant sanitizers (enabled via ``REPRO_SANITIZE=1``)."""
from .sanitize import (
    SanitizerError,
    StoreSanitizer,
    attach_sanitizer,
    maybe_attach,
    sanitize_enabled,
)

__all__ = [
    "SanitizerError",
    "StoreSanitizer",
    "attach_sanitizer",
    "maybe_attach",
    "sanitize_enabled",
]
