"""Blocked online-softmax attention (FlashAttention) as a Pallas TPU kernel.

Grid: (batch, q_heads, q_blocks, kv_blocks) — kv innermost ("arbitrary"
semantics) so the running-softmax scratch (m, l, acc) carries across kv
iterations and the output is finalized at the last kv block.

BlockSpec tiling keeps one (block_q x d) Q tile and one (block_kv x d) K/V
tile in VMEM; the S = Q K^T tile (block_q x block_kv) is MXU-shaped
(multiples of 128 recommended).  Supports causal masking, sliding-window
(local) masking and GQA via an index_map that folds q-head -> kv-head.

Decode (Sq=1..8 with large Skv) runs the same kernel with block_q = Sq.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # [1, 1, bq, d]
    k_ref,  # [1, 1, bkv, d]
    v_ref,  # [1, 1, bkv, d]
    o_ref,  # [1, 1, bq, d]
    m_scr,  # [bq, 1] running max
    l_scr,  # [bq, 1] running denom
    acc_scr,  # [bq, d] running numerator
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_kv: int,
    seq_q: int,
    seq_kv: int,
):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)  # [bkv, d]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [bq, bkv]

    # absolute positions; suffix-aligned when seq_q < seq_kv (decode)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    q_pos = q_pos + (seq_kv - seq_q)
    k_pos = ikv * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1
    )
    mask = jnp.ones((block_q, block_kv), dtype=jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]  # [bq, 1]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # [bq, bkv]
    correction = jnp.exp(m_prev - m_new)  # [bq, 1]
    l_new = l_prev * correction + p.sum(axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_scr[...] = acc_scr[...] * correction + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ikv == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Skv, D]
    v: jnp.ndarray,  # [B, Hkv, Skv, D]
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0
    scale = d ** -0.5
    grid = (b, hq, sq // block_q, skv // block_kv)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_kv=block_kv,
        seq_q=sq,
        seq_kv=skv,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ikv: (b_, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, d), lambda b_, h, iq, ikv: (b_, h // group, ikv, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_kv, d), lambda b_, h, iq, ikv: (b_, h // group, ikv, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h, iq, ikv: (b_, h, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),  # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),  # running numerator acc
        ],
        interpret=interpret,
    )(q, k, v)
