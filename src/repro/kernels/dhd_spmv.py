"""Directed-Heat-Diffusion step over ELL adjacency — Pallas TPU kernel.

This is the paper's compute hot-spot (Eqs. 7-8 iterated to steady state for
placement scoring, pre-caching and eviction).  TPU adaptation (DESIGN §2):
a GPU implementation would scatter per edge; here the adjacency is packed as
**symmetric ELL** (每 row = padded neighbor list) so every row's update is a
dense VPU reduction, tiled ``block_n`` rows at a time in VMEM.

Two passes (both O(n * kmax)):
  1. ``_count_kernel`` — |N_u^out| = # strictly-lower-heat neighbors per row.
  2. ``_flow_kernel``  — inflow - outflow per row given the global n_out.

The full heat / n_out vectors stay resident in VMEM as (n, 1) blocks
(n <= ~2M fp32 fits the 16MB*ish VMEM budget per core; larger graphs are
block-diffused per cluster by the control plane, which is exactly how the
paper confines DHD runs to clusters).  Overflow edges beyond kmax live in a
COO tail handled by ``ops.dhd_step`` with segment ops.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dhd_ell_step"]


def _count_kernel(h_ref, cols_ref, vals_ref, nout_ref):
    i = pl.program_id(0)
    block_n = cols_ref.shape[0]
    heat = h_ref[:, 0]  # [n] full vector in VMEM
    cols = cols_ref[...]  # [block_n, kmax]
    vals = vals_ref[...]
    h_u = jax.lax.dynamic_slice(heat, (i * block_n,), (block_n,))[:, None]
    h_nb = jnp.take(heat, cols, axis=0)  # VMEM gather
    out_mask = (vals > 0) & (h_u > h_nb)
    nout_ref[:, 0] = out_mask.sum(axis=1).astype(jnp.float32)


def _flow_kernel(h_ref, nout_ref, cols_ref, vals_ref, delta_ref, *, alpha: float):
    i = pl.program_id(0)
    block_n = cols_ref.shape[0]
    heat = h_ref[:, 0]
    n_out = nout_ref[:, 0]
    cols = cols_ref[...]
    vals = vals_ref[...]
    h_u = jax.lax.dynamic_slice(heat, (i * block_n,), (block_n,))[:, None]
    nout_u = jnp.maximum(
        jax.lax.dynamic_slice(n_out, (i * block_n,), (block_n,)), 1.0
    )[:, None]
    h_nb = jnp.take(heat, cols, axis=0)
    nout_nb = jnp.maximum(jnp.take(n_out, cols, axis=0), 1.0)
    out_mask = (vals > 0) & (h_u > h_nb)
    in_mask = (vals > 0) & (h_nb > h_u)
    outflow = (alpha / nout_u * vals * jnp.where(out_mask, h_u - h_nb, 0.0)).sum(
        axis=1
    )
    inflow = (alpha / nout_nb * vals * jnp.where(in_mask, h_nb - h_u, 0.0)).sum(
        axis=1
    )
    delta_ref[:, 0] = inflow - outflow


@functools.partial(
    jax.jit, static_argnames=("alpha", "gamma", "beta", "block_n", "interpret")
)
def dhd_ell_step(
    heat: jnp.ndarray,  # [n] float32
    cols: jnp.ndarray,  # [n, kmax] int32 symmetric ELL (pad = self)
    vals: jnp.ndarray,  # [n, kmax] float32 (0 where padded)
    q: jnp.ndarray,  # [n] source heat
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
    block_n: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """One DHD update; ELL part only (COO tail composed in ``ops.dhd_step``)."""
    n, kmax = cols.shape
    block_n = min(block_n, n)
    assert n % block_n == 0, "pad n to a multiple of block_n"
    grid = (n // block_n,)
    h2d = heat[:, None].astype(jnp.float32)  # (n, 1) — VMEM-resident layout

    n_out = pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),  # full heat
            pl.BlockSpec((block_n, kmax), lambda i: (i, 0)),
            pl.BlockSpec((block_n, kmax), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(h2d, cols, vals)

    delta = pl.pallas_call(
        functools.partial(_flow_kernel, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_n, kmax), lambda i: (i, 0)),
            pl.BlockSpec((block_n, kmax), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(h2d, n_out, cols, vals)

    return (1.0 - gamma) * (heat + delta[:, 0]) + beta * q
