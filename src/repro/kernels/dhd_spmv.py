"""Directed-Heat-Diffusion step over ELL adjacency — Pallas TPU kernel.

This is the paper's compute hot-spot (Eqs. 7-8 iterated to steady state for
placement scoring, pre-caching and eviction).  TPU adaptation (DESIGN §2):
a GPU implementation would scatter per edge; here the adjacency is packed as
**symmetric ELL** (每 row = padded neighbor list) so every row's update is a
dense VPU reduction, tiled ``block_n`` rows at a time in VMEM.

Two passes (both O(n * kmax)):
  1. ``_count_kernel`` — |N_u^out| = # strictly-lower-heat neighbors per row.
  2. ``_flow_kernel``  — inflow - outflow per row given the global n_out.

The full heat / n_out vectors stay resident in VMEM as (n, 1) blocks
(n <= ~2M fp32 fits the 16MB*ish VMEM budget per core; larger graphs are
block-diffused per cluster by the control plane, which is exactly how the
paper confines DHD runs to clusters).  Overflow edges beyond kmax live in a
COO tail handled by ``ops.dhd_step`` with segment ops.

Arbitrary row counts are handled by padding inside the wrappers: pad rows
are isolated zero-weight self-loops (no flow in or out, |N^out| = 0), so the
padded result sliced back to ``n`` rows is exact and any cluster size takes
the kernel path.

``dhd_ell_step_batch`` runs B independent heat fields over one shared column
structure with a 2-D grid (batch × row-blocks); ``vals`` may be per-batch
(``[B, n, kmax]``), which is how the placement arena diffuses every
candidate's super-node topology in a single launch.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dhd_ell_step", "dhd_ell_step_batch"]


def _pad_rows(
    heat: jnp.ndarray, cols: jnp.ndarray, vals: jnp.ndarray, block_n: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """Pad to a row-count multiple of ``block_n`` with isolated self-loops.

    ``heat`` may be [n] or [B, n]; ``vals`` [n, kmax] or [B, n, kmax].
    Pad rows get heat 0 and zero-weight self-edges, so they never exchange
    heat with real rows and the sliced result is exact."""
    n = heat.shape[-1]
    kmax = cols.shape[1]
    n_pad = -(-n // block_n) * block_n
    if n_pad == n:
        return heat, cols, vals, n
    pad = n_pad - n
    pad_cols = jnp.broadcast_to(
        jnp.arange(n, n_pad, dtype=cols.dtype)[:, None], (pad, kmax)
    )
    cols = jnp.concatenate([cols, pad_cols], axis=0)
    if vals.ndim == 3:
        vals = jnp.concatenate(
            [vals, jnp.zeros((vals.shape[0], pad, kmax), vals.dtype)], axis=1
        )
    else:
        vals = jnp.concatenate([vals, jnp.zeros((pad, kmax), vals.dtype)], axis=0)
    zpad = jnp.zeros((*heat.shape[:-1], pad), heat.dtype)
    heat = jnp.concatenate([heat, zpad], axis=-1)
    return heat, cols, vals, n


def _count_kernel(h_ref, cols_ref, vals_ref, nout_ref):
    i = pl.program_id(0)
    block_n = cols_ref.shape[0]
    heat = h_ref[:, 0]  # [n] full vector in VMEM
    cols = cols_ref[...]  # [block_n, kmax]
    vals = vals_ref[...]
    h_u = jax.lax.dynamic_slice(heat, (i * block_n,), (block_n,))[:, None]
    h_nb = jnp.take(heat, cols, axis=0)  # VMEM gather
    out_mask = (vals > 0) & (h_u > h_nb)
    nout_ref[:, 0] = out_mask.sum(axis=1).astype(jnp.float32)


def _flow_kernel(h_ref, nout_ref, cols_ref, vals_ref, delta_ref, *, alpha: float):
    i = pl.program_id(0)
    block_n = cols_ref.shape[0]
    heat = h_ref[:, 0]
    n_out = nout_ref[:, 0]
    cols = cols_ref[...]
    vals = vals_ref[...]
    h_u = jax.lax.dynamic_slice(heat, (i * block_n,), (block_n,))[:, None]
    nout_u = jnp.maximum(
        jax.lax.dynamic_slice(n_out, (i * block_n,), (block_n,)), 1.0
    )[:, None]
    h_nb = jnp.take(heat, cols, axis=0)
    nout_nb = jnp.maximum(jnp.take(n_out, cols, axis=0), 1.0)
    out_mask = (vals > 0) & (h_u > h_nb)
    in_mask = (vals > 0) & (h_nb > h_u)
    outflow = (alpha / nout_u * vals * jnp.where(out_mask, h_u - h_nb, 0.0)).sum(
        axis=1
    )
    inflow = (alpha / nout_nb * vals * jnp.where(in_mask, h_nb - h_u, 0.0)).sum(
        axis=1
    )
    delta_ref[:, 0] = inflow - outflow


@functools.partial(
    jax.jit, static_argnames=("alpha", "gamma", "beta", "block_n", "interpret")
)
def dhd_ell_step(
    heat: jnp.ndarray,  # [n] float32
    cols: jnp.ndarray,  # [n, kmax] int32 symmetric ELL (pad = self)
    vals: jnp.ndarray,  # [n, kmax] float32 (0 where padded)
    q: jnp.ndarray,  # [n] source heat
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
    block_n: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """One DHD update; ELL part only (COO tail composed in ``ops.dhd_step``)."""
    n = heat.shape[0]
    block_n = min(block_n, n)
    heat_p, cols, vals, _ = _pad_rows(heat, cols, vals, block_n)
    n_pad, kmax = cols.shape
    grid = (n_pad // block_n,)
    h2d = heat_p[:, None].astype(jnp.float32)  # (n, 1) — VMEM-resident layout

    n_out = pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_pad, 1), lambda i: (0, 0)),  # full heat
            pl.BlockSpec((block_n, kmax), lambda i: (i, 0)),
            pl.BlockSpec((block_n, kmax), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        interpret=interpret,
    )(h2d, cols, vals)

    delta = pl.pallas_call(
        functools.partial(_flow_kernel, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_pad, 1), lambda i: (0, 0)),
            pl.BlockSpec((n_pad, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_n, kmax), lambda i: (i, 0)),
            pl.BlockSpec((block_n, kmax), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        interpret=interpret,
    )(h2d, n_out, cols, vals)

    return (1.0 - gamma) * (heat + delta[:n, 0]) + beta * q


# ----------------------------------------------------------- batched variant
def _count_kernel_batch(h_ref, cols_ref, vals_ref, nout_ref):
    i = pl.program_id(1)
    cols = cols_ref[...]  # [block_n, kmax]
    block_n = cols.shape[0]
    vals = vals_ref[...]
    if vals.ndim == 3:  # per-batch weights arrive as a (1, block_n, kmax) block
        vals = vals[0]
    heat = h_ref[0, :]  # this batch row's full heat vector in VMEM
    h_u = jax.lax.dynamic_slice(heat, (i * block_n,), (block_n,))[:, None]
    h_nb = jnp.take(heat, cols, axis=0)
    out_mask = (vals > 0) & (h_u > h_nb)
    nout_ref[0, :] = out_mask.sum(axis=1).astype(jnp.float32)


def _flow_kernel_batch(h_ref, nout_ref, cols_ref, vals_ref, delta_ref, *, alpha: float):
    i = pl.program_id(1)
    cols = cols_ref[...]
    block_n = cols.shape[0]
    vals = vals_ref[...]
    if vals.ndim == 3:
        vals = vals[0]
    heat = h_ref[0, :]
    n_out = nout_ref[0, :]
    h_u = jax.lax.dynamic_slice(heat, (i * block_n,), (block_n,))[:, None]
    nout_u = jnp.maximum(
        jax.lax.dynamic_slice(n_out, (i * block_n,), (block_n,)), 1.0
    )[:, None]
    h_nb = jnp.take(heat, cols, axis=0)
    nout_nb = jnp.maximum(jnp.take(n_out, cols, axis=0), 1.0)
    out_mask = (vals > 0) & (h_u > h_nb)
    in_mask = (vals > 0) & (h_nb > h_u)
    outflow = (alpha / nout_u * vals * jnp.where(out_mask, h_u - h_nb, 0.0)).sum(
        axis=1
    )
    inflow = (alpha / nout_nb * vals * jnp.where(in_mask, h_nb - h_u, 0.0)).sum(
        axis=1
    )
    delta_ref[0, :] = inflow - outflow


@functools.partial(
    jax.jit, static_argnames=("alpha", "gamma", "beta", "block_n", "interpret")
)
def dhd_ell_step_batch(
    heat: jnp.ndarray,  # [B, n] float32
    cols: jnp.ndarray,  # [n, kmax] int32 shared symmetric ELL (pad = self)
    vals: jnp.ndarray,  # [n, kmax] shared or [B, n, kmax] per-batch weights
    q: jnp.ndarray,  # [B, n] source heat
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
    block_n: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched DHD update: B heat fields, one shared column structure.

    2-D grid over (batch, row-blocks); each program holds its batch row's
    full heat/n_out vector in VMEM (same residency argument as the single
    kernel — B small heat vectors instead of one).  With 3-D ``vals`` each
    batch element diffuses over its own edge weights (zero = edge absent for
    that element), matching ``ref.dhd_ell_ref_batch`` row-for-row.
    """
    b, n = heat.shape
    block_n = min(block_n, n)
    heat_p, cols, vals, _ = _pad_rows(heat, cols, vals, block_n)
    n_pad, kmax = cols.shape
    grid = (b, n_pad // block_n)
    h2 = heat_p.astype(jnp.float32)  # [B, n_pad]
    if vals.ndim == 3:
        vals_spec = pl.BlockSpec((1, block_n, kmax), lambda bb, i: (bb, i, 0))
    else:
        vals_spec = pl.BlockSpec((block_n, kmax), lambda bb, i: (i, 0))

    n_out = pl.pallas_call(
        _count_kernel_batch,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n_pad), lambda bb, i: (bb, 0)),  # full heat row
            pl.BlockSpec((block_n, kmax), lambda bb, i: (i, 0)),
            vals_spec,
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda bb, i: (bb, i)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad), jnp.float32),
        interpret=interpret,
    )(h2, cols, vals)

    delta = pl.pallas_call(
        functools.partial(_flow_kernel_batch, alpha=alpha),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n_pad), lambda bb, i: (bb, 0)),
            pl.BlockSpec((1, n_pad), lambda bb, i: (bb, 0)),
            pl.BlockSpec((block_n, kmax), lambda bb, i: (i, 0)),
            vals_spec,
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda bb, i: (bb, i)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad), jnp.float32),
        interpret=interpret,
    )(h2, n_out, cols, vals)

    return (1.0 - gamma) * (heat + delta[:, :n]) + beta * q
