"""Per-device kernel autotuner: measure every candidate, cache the winner.

The same measure-everything-then-index discipline bitfiltrator applies to
FPGA architectures: for each (device kind, op, shape bucket) every candidate
launch configuration is timed (min-of-repeats to shed scheduler noise), the
winner is cached in an in-process table, and both the sweep timings and the
winners land in the PR 6 metrics registry (``repro.obs``) as first-class
instruments instead of ad-hoc dicts.

Winner tables serialize to **sorted-key JSON under a version stamp** so two
sweeps of the same device produce byte-identical files; ``load`` ignores
stamps from other versions.  A lookup for a device/op/shape that was never
swept (e.g. a winner table shipped from a TPU host loaded on CPU) returns
``None`` — callers fall back to their built-in defaults — and bumps a
``kernels.autotune_miss`` counter so untuned serving is visible.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from ..obs import get_registry

__all__ = [
    "TABLE_VERSION",
    "Autotuner",
    "get_autotuner",
    "set_autotuner",
    "shape_bucket",
    "signature_key",
]

TABLE_VERSION = 1

Signature = Sequence[Union[int, str]]


def shape_bucket(n: int, floor: int = 8) -> int:
    """Next power of two >= max(n, floor): shapes inside one bucket share a
    jit cache entry and a winner, so sweeps amortize across the batch mix."""
    b = max(int(floor), 1)
    n = max(int(n), 1)
    while b < n:
        b <<= 1
    return b


def signature_key(signature: Signature) -> str:
    """Deterministic string key for an op signature (shape-bucket tuple)."""
    return "x".join(str(s) for s in signature)


def _config_key(config: Dict[str, Any]) -> str:
    return json.dumps(config, sort_keys=True)


class Autotuner:
    """In-process winner table keyed on (device kind, op, shape bucket)."""

    def __init__(self, registry=None) -> None:
        self._table: Dict[str, Dict[str, Dict[str, dict]]] = {}
        self._registry = registry

    # ------------------------------------------------------------- plumbing
    def _reg(self):
        return self._registry if self._registry is not None else get_registry()

    @staticmethod
    def device_kind() -> str:
        """``backend:device_kind`` of the default jax device (e.g.
        ``cpu:cpu`` or ``tpu:TPU v5e``); ``unknown`` when jax is absent."""
        try:
            import jax

            dev = jax.devices()[0]
            return f"{jax.default_backend()}:{getattr(dev, 'device_kind', '?')}"
        except Exception:  # pragma: no cover - no backend at all
            return "unknown"

    # -------------------------------------------------------------- lookups
    def lookup(
        self, op: str, signature: Signature, device: Optional[str] = None
    ) -> Optional[Dict[str, Any]]:
        """Winner config for (device, op, signature), or ``None`` (+ a
        ``kernels.autotune_miss`` count) when nothing was swept — the caller
        must fall back to its built-in defaults."""
        dev = device or self.device_kind()
        entry = self._table.get(dev, {}).get(op, {}).get(signature_key(signature))
        reg = self._reg()
        if entry is None:
            if reg.enabled:
                reg.counter("kernels.autotune_miss", op=op).inc()
            return None
        if reg.enabled:
            reg.counter("kernels.autotune_hit", op=op).inc()
        return dict(entry["config"])

    # --------------------------------------------------------------- sweeps
    def sweep(
        self,
        op: str,
        signature: Signature,
        candidates: Sequence[Dict[str, Any]],
        runner: Callable[[Dict[str, Any]], Any],
        repeats: int = 3,
        device: Optional[str] = None,
    ) -> Dict[str, Any]:
        """Time every candidate config (one warm-up call to absorb compiles,
        then min-of-``repeats``), record the sweep into the registry, cache
        and return the winner.  Ties break on the candidate's sorted-key
        JSON, so the winner is deterministic under equal timings."""
        if not candidates:
            raise ValueError("sweep needs at least one candidate config")
        dev = device or self.device_kind()
        sig = signature_key(signature)
        reg = self._reg()
        timings: List[Tuple[float, str, Dict[str, Any]]] = []
        for config in candidates:
            runner(config)  # warm-up: compile + first-touch outside the clock
            best = float("inf")
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                runner(config)
                best = min(best, time.perf_counter() - t0)
            timings.append((best, _config_key(config), dict(config)))
            reg.counter("kernels.autotune_trials", op=op).inc()
            reg.histogram("kernels.autotune_sweep_s", op=op).observe(best)
        timings.sort(key=lambda t: (t[0], t[1]))
        best_s, _, winner = timings[0]
        self._table.setdefault(dev, {}).setdefault(op, {})[sig] = {
            "config": dict(winner),
            "best_s": best_s,
            "timings": [
                {"config": c, "seconds": s} for s, _, c in timings
            ],
        }
        reg.gauge("kernels.autotune_best_s", op=op, sig=sig, device=dev).set(best_s)
        return dict(winner)

    # ---------------------------------------------------------- persistence
    def snapshot(self) -> dict:
        """Serializable winner tables under the version stamp."""
        return {"version": TABLE_VERSION, "tables": self._table}

    def dumps(self) -> str:
        """Deterministic sorted-key JSON of the winner tables."""
        return json.dumps(self.snapshot(), sort_keys=True, indent=2)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())
            f.write("\n")

    def load(self, source: Union[str, dict]) -> bool:
        """Merge winner tables from a path or parsed snapshot.  Tables from
        a different :data:`TABLE_VERSION` are ignored (``False``); entries
        for devices this process never sees just sit idle — lookups for the
        local device still miss and fall back to defaults."""
        if isinstance(source, str):
            with open(source) as f:
                source = json.load(f)
        if source.get("version") != TABLE_VERSION:
            reg = self._reg()
            if reg.enabled:
                reg.counter("kernels.autotune_stale_table").inc()
            return False
        for dev, ops in source.get("tables", {}).items():
            for op, sigs in ops.items():
                self._table.setdefault(dev, {}).setdefault(op, {}).update(
                    {k: dict(v) for k, v in sigs.items()}
                )
        return True

    def reset(self) -> None:
        """Drop the in-process winner cache (sweeps must re-run)."""
        self._table.clear()


_AUTOTUNER = Autotuner()  # geolint: allow[GL001] — singleton with reset()


def get_autotuner() -> Autotuner:
    return _AUTOTUNER


def set_autotuner(tuner: Autotuner) -> Autotuner:
    global _AUTOTUNER
    _AUTOTUNER = tuner
    return tuner
