"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret mode
on CPU, shape/dtype sweeps in tests/test_kernels_*.py) and the fallback used
by ``ops.py`` when running on platforms without Pallas support.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "dhd_ell_ref", "dhd_ell_ref_batch", "embedding_bag_ref"]


def attention_ref(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Skv, D]
    v: jnp.ndarray,  # [B, Hkv, Skv, D]
    causal: bool = True,
    window: Optional[int] = None,  # sliding-window size (local attention)
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Dense softmax attention with GQA head grouping + causal/local masks.

    With Sq < Skv (decode/chunked prefill), query position i is aligned to
    absolute position ``i + Skv - Sq`` (the suffix convention)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kr) * scale
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vr).astype(q.dtype)


def dhd_ell_ref(
    heat: jnp.ndarray,  # [n]
    cols: jnp.ndarray,  # [n, kmax] symmetric ELL neighbor ids (pad = self)
    vals: jnp.ndarray,  # [n, kmax] edge weights (0 where padded)
    q: jnp.ndarray,  # [n] source heat this step
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
) -> jnp.ndarray:
    """DHD step (Eqs. 7-8) over a symmetric ELL adjacency.

    Returns the updated heat.  Matches ``core.dhd.dhd_step_edges`` on the
    corresponding undirected edge list (each edge present in both rows).
    """
    h_nb = heat[cols]  # [n, kmax]
    h_u = heat[:, None]
    active = vals > 0
    out_mask = active & (h_u > h_nb)
    in_mask = active & (h_nb > h_u)
    # |N_u^out| — strictly-lower-heat neighbors of u
    n_out = jnp.maximum(out_mask.sum(axis=1), 1).astype(heat.dtype)
    outflow = (
        alpha / n_out[:, None] * vals * jnp.where(out_mask, h_u - h_nb, 0.0)
    ).sum(axis=1)
    # inflow from each hotter neighbor j uses |N_j^out|
    inflow = (
        alpha / n_out[cols] * vals * jnp.where(in_mask, h_nb - h_u, 0.0)
    ).sum(axis=1)
    return (1.0 - gamma) * (heat + inflow - outflow) + beta * q


def dhd_ell_ref_batch(
    heat: jnp.ndarray,  # [B, n]
    cols: jnp.ndarray,  # [n, kmax] symmetric ELL neighbor ids (shared)
    vals: jnp.ndarray,  # [n, kmax] shared or [B, n, kmax] per-seed weights
    q: jnp.ndarray,  # [B, n] source heat this step
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
) -> jnp.ndarray:
    """Batched DHD step: B independent heat fields over one shared ELL
    column structure.  ``vals`` may carry per-seed edge weights (3-D); a
    zero weight deactivates the edge for that seed only, which is how the
    placement arena runs per-candidate super-node topologies through one
    shared adjacency.  Row ``b`` equals ``dhd_ell_ref(heat[b], cols,
    vals[b], q[b])``.
    """
    h_nb = heat[:, cols]  # [B, n, kmax]
    h_u = heat[:, :, None]
    vals_b = vals if vals.ndim == 3 else vals[None]
    active = vals_b > 0
    out_mask = active & (h_u > h_nb)
    in_mask = active & (h_nb > h_u)
    n_out = jnp.maximum(out_mask.sum(axis=-1), 1).astype(heat.dtype)  # [B, n]
    outflow = (
        alpha / n_out[..., None] * vals_b * jnp.where(out_mask, h_u - h_nb, 0.0)
    ).sum(axis=-1)
    inflow = (
        alpha / n_out[:, cols] * vals_b * jnp.where(in_mask, h_nb - h_u, 0.0)
    ).sum(axis=-1)
    return (1.0 - gamma) * (heat + inflow - outflow) + beta * q


def embedding_bag_ref(
    table: jnp.ndarray,  # [V, D]
    indices: jnp.ndarray,  # [B, L] int32
    weights: Optional[jnp.ndarray] = None,  # [B, L]
    mode: str = "sum",
) -> jnp.ndarray:
    """EmbeddingBag: per-bag weighted gather-reduce (sum or mean).

    JAX has no native ``nn.EmbeddingBag``; this take+reduce *is* the system's
    reference lookup (kernel_taxonomy §B.6)."""
    rows = table[indices]  # [B, L, D]
    if weights is None:
        weights = jnp.ones(indices.shape, dtype=table.dtype)
    out = (rows * weights[..., None]).sum(axis=1)
    if mode == "mean":
        denom = jnp.maximum(weights.sum(axis=1, keepdims=True), 1e-9)
        out = out / denom
    return out
