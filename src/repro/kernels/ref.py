"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret mode
on CPU, shape/dtype sweeps in tests/test_kernels_*.py) and the fallback used
by ``ops.py`` when running on platforms without Pallas support.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "attention_ref",
    "dhd_ell_ref",
    "dhd_ell_ref_batch",
    "embedding_bag_ref",
    "route_expand_ref",
]


def attention_ref(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Skv, D]
    v: jnp.ndarray,  # [B, Hkv, Skv, D]
    causal: bool = True,
    window: Optional[int] = None,  # sliding-window size (local attention)
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Dense softmax attention with GQA head grouping + causal/local masks.

    With Sq < Skv (decode/chunked prefill), query position i is aligned to
    absolute position ``i + Skv - Sq`` (the suffix convention)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kr) * scale
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vr).astype(q.dtype)


def dhd_ell_ref(
    heat: jnp.ndarray,  # [n]
    cols: jnp.ndarray,  # [n, kmax] symmetric ELL neighbor ids (pad = self)
    vals: jnp.ndarray,  # [n, kmax] edge weights (0 where padded)
    q: jnp.ndarray,  # [n] source heat this step
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
) -> jnp.ndarray:
    """DHD step (Eqs. 7-8) over a symmetric ELL adjacency.

    Returns the updated heat.  Matches ``core.dhd.dhd_step_edges`` on the
    corresponding undirected edge list (each edge present in both rows).
    """
    h_nb = heat[cols]  # [n, kmax]
    h_u = heat[:, None]
    active = vals > 0
    out_mask = active & (h_u > h_nb)
    in_mask = active & (h_nb > h_u)
    # |N_u^out| — strictly-lower-heat neighbors of u
    n_out = jnp.maximum(out_mask.sum(axis=1), 1).astype(heat.dtype)
    outflow = (
        alpha / n_out[:, None] * vals * jnp.where(out_mask, h_u - h_nb, 0.0)
    ).sum(axis=1)
    # inflow from each hotter neighbor j uses |N_j^out|
    inflow = (
        alpha / n_out[cols] * vals * jnp.where(in_mask, h_nb - h_u, 0.0)
    ).sum(axis=1)
    return (1.0 - gamma) * (heat + inflow - outflow) + beta * q


def dhd_ell_ref_batch(
    heat: jnp.ndarray,  # [B, n]
    cols: jnp.ndarray,  # [n, kmax] symmetric ELL neighbor ids (shared)
    vals: jnp.ndarray,  # [n, kmax] shared or [B, n, kmax] per-seed weights
    q: jnp.ndarray,  # [B, n] source heat this step
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
) -> jnp.ndarray:
    """Batched DHD step: B independent heat fields over one shared ELL
    column structure.  ``vals`` may carry per-seed edge weights (3-D); a
    zero weight deactivates the edge for that seed only, which is how the
    placement arena runs per-candidate super-node topologies through one
    shared adjacency.  Row ``b`` equals ``dhd_ell_ref(heat[b], cols,
    vals[b], q[b])``.
    """
    h_nb = heat[:, cols]  # [B, n, kmax]
    h_u = heat[:, :, None]
    vals_b = vals if vals.ndim == 3 else vals[None]
    active = vals_b > 0
    out_mask = active & (h_u > h_nb)
    in_mask = active & (h_nb > h_u)
    n_out = jnp.maximum(out_mask.sum(axis=-1), 1).astype(heat.dtype)  # [B, n]
    outflow = (
        alpha / n_out[..., None] * vals_b * jnp.where(out_mask, h_u - h_nb, 0.0)
    ).sum(axis=-1)
    inflow = (
        alpha / n_out[:, cols] * vals_b * jnp.where(in_mask, h_nb - h_u, 0.0)
    ).sum(axis=-1)
    return (1.0 - gamma) * (heat + inflow - outflow) + beta * q


def route_expand_masks(
    bits: jnp.ndarray,  # [R, K] i32 per-item replica bitmask over DCs
    lens: jnp.ndarray,  # [R] i32 real item count per request
    origin: jnp.ndarray,  # [R] i32 origin DC
    comp: jnp.ndarray,  # [hier + 1, D] i32 layer component ids (layer 0 first)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Derived request masks shared by the oracle and the kernel wrapper:
    ``(valid [R, K], local [R, K], missing [R, K], allowed [R, L, D])``.
    ``allowed[r, l, d]`` is True when DC ``d`` sits in the origin's layer
    ``l + 1`` cluster (the origin itself is excluded, as in the greedy)."""
    R, K = bits.shape
    D = comp.shape[1]
    valid = jnp.arange(K, dtype=jnp.int32)[None, :] < lens[:, None]
    local = valid & (((bits >> origin[:, None]) & 1) > 0)
    comp_l = comp[1:]  # [L, D]
    comp_o = jnp.transpose(comp_l[:, origin])  # [R, L]
    allowed = (comp_l[None, :, :] == comp_o[:, :, None]) & (
        jnp.arange(D, dtype=jnp.int32)[None, None, :] != origin[:, None, None]
    )
    return valid, local, valid & ~local, allowed


def route_expand_ref(
    bits: jnp.ndarray,  # [R, K] i32 per-item replica bitmask (bit d = DC d)
    sizes: jnp.ndarray,  # [R, K] f32 item bytes (0 where padded)
    lens: jnp.ndarray,  # [R] i32 real item count per request
    origin: jnp.ndarray,  # [R] i32 origin DC per request
    comp: jnp.ndarray,  # [hier + 1, D] i32 layer component ids
    rtt: jnp.ndarray,  # [D, D] f32 env RTT matrix
    ibw: jnp.ndarray,  # [D, D] f32 elementwise 1 / bandwidth matrix
) -> Tuple[jnp.ndarray, ...]:
    """Fused stepwise layered expansion (paper §VI) + Eq. 1 latency fold.

    Ground truth for the ``route_expand`` Pallas kernel and the jitted CPU
    fast path behind :func:`repro.core.routing.route_online_batch`.  Per
    request the greedy picks match :func:`repro.core.routing.route_online`
    exactly: serve locally first, then per layer repeatedly pick the
    cluster DC covering the most still-missing items (``argmax`` = lowest-
    DC-id tie-break), assign its hits, escalate when no cluster DC covers
    anything.  The batch walks the layers in lockstep behind one early-exit
    ``while_loop``: a pass with zero progress anywhere escalates the shared
    layer pointer — extra passes are idempotent per request, so lockstep
    equals per-request greedy.  Coverage counts are 0/1 sums, exact in f32
    below 2^24 items; the iteration bound L * (D + 1) covers the worst case
    (at most D - 1 productive picks plus one no-progress pass per layer).

    Returns ``(served [R, K] i32 (-1 unresolved), bytes_rd [R, D] f32,
    layers_used [R] i32, miss_after [R, L+1] i32 (missing count after each
    layer, layer 0 first), straggler_s [R] f32, wan_bytes [R] f32)``.
    """
    R, K = bits.shape
    L = comp.shape[0] - 1
    D = comp.shape[1]
    valid, local, missing, allowed = route_expand_masks(bits, lens, origin, comp)
    served = jnp.where(local, origin[:, None].astype(jnp.int32), jnp.int32(-1))
    layers_used = jnp.zeros((R,), jnp.int32)
    miss_after = jnp.zeros((R, L + 1), jnp.int32)
    miss_after = miss_after.at[:, 0].set(missing.sum(axis=1))
    max_iters = L * (D + 1)

    def cond(c):
        _, missing, layer, _, _, it = c
        return (layer < L) & missing.any() & (it < max_iters)

    # Coverage popcounts: for narrow batches (item slots <= 512) the D
    # per-DC shift-and-mask reductions collapse into ceil(D / 3) "field
    # word" reductions — bit d of each item spread into a 10-bit field
    # (3 DCs per int32 word), so one sum per word accumulates 3 exact
    # per-DC counts at once (count <= 512 < 2^10, word sum < 2^31).
    use_fields = K <= 512
    if use_fields:
        words = []
        for w in range((D + 2) // 3):
            acc = jnp.zeros_like(bits)
            for j, d in enumerate(range(w * 3, min(w * 3 + 3, D))):
                acc = acc + (((bits >> d) & 1) << (10 * j))
            words.append(acc)

    def _coverage(missing):
        if use_fields:
            cols = []
            for w, word in enumerate(words):
                s = jnp.where(missing, word, 0).sum(axis=1)  # [R]
                for j in range(min(3, D - w * 3)):
                    cols.append((s >> (10 * j)) & 1023)
            return jnp.stack(cols, axis=1).astype(jnp.float32)
        masked = jnp.where(missing, bits, 0)
        return jnp.stack(
            [((masked >> d) & 1).sum(axis=1) for d in range(D)], axis=1
        ).astype(jnp.float32)

    def body(c):
        served, missing, layer, layers_used, miss_after, it = c
        a_l = jax.lax.dynamic_index_in_dim(allowed, layer, axis=1, keepdims=False)
        layers_used = jnp.where(
            missing.any(axis=1) & a_l.any(axis=1), layer + 1, layers_used
        )
        cover = jnp.where(a_l, _coverage(missing), 0.0)
        best = jnp.argmax(cover, axis=1).astype(jnp.int32)  # lowest-id ties
        gain = jnp.max(cover, axis=1)
        has = ((bits >> best[:, None]) & 1) > 0
        hit = missing & (gain > 0)[:, None] & has
        progressed = hit.any()
        new_missing = missing & ~hit
        miss_after = jnp.where(
            progressed,
            miss_after,
            miss_after.at[:, layer + 1].set(new_missing.sum(axis=1)),
        )
        return (
            jnp.where(hit, best[:, None], served),
            new_missing,
            jnp.where(progressed, layer, layer + 1),
            layers_used,
            miss_after,
            it + 1,
        )

    served, missing, _, layers_used, miss_after, _ = jax.lax.while_loop(
        cond, body, (served, missing, jnp.int32(0), layers_used, miss_after, jnp.int32(0))
    )

    # Eq. 1 fold: per-DC served bytes -> transfer latency, straggler = max
    # over serving DCs, WAN = bytes served away from the origin
    szv = jnp.where(valid, sizes, 0.0)
    bytes_rd = jnp.stack(
        [jnp.where(served == d, szv, 0.0).sum(axis=1) for d in range(D)], axis=1
    )
    served_d = jnp.stack([(served == d).any(axis=1) for d in range(D)], axis=1)
    at_origin = (
        jnp.arange(D, dtype=jnp.int32)[None, :] == origin[:, None]
    )  # [R, D]
    rtt_ro = jnp.transpose(rtt[:, origin])
    ibw_ro = jnp.transpose(ibw[:, origin])
    lat_rd = jnp.where(at_origin, 0.0, rtt_ro + bytes_rd * ibw_ro)
    straggler = jnp.max(jnp.where(served_d, lat_rd, 0.0), axis=1)
    wan = jnp.where(at_origin, 0.0, bytes_rd).sum(axis=1)
    return served, bytes_rd, layers_used, miss_after, straggler, wan


def embedding_bag_ref(
    table: jnp.ndarray,  # [V, D]
    indices: jnp.ndarray,  # [B, L] int32
    weights: Optional[jnp.ndarray] = None,  # [B, L]
    mode: str = "sum",
) -> jnp.ndarray:
    """EmbeddingBag: per-bag weighted gather-reduce (sum or mean).

    JAX has no native ``nn.EmbeddingBag``; this take+reduce *is* the system's
    reference lookup (kernel_taxonomy §B.6)."""
    rows = table[indices]  # [B, L, D]
    if weights is None:
        weights = jnp.ones(indices.shape, dtype=table.dtype)
    out = (rows * weights[..., None]).sum(axis=1)
    if mode == "mean":
        denom = jnp.maximum(weights.sum(axis=1, keepdims=True), 1e-9)
        out = out / denom
    return out
