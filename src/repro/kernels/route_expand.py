"""Fused stepwise layered routing expansion — Pallas TPU kernel.

This is the serving hot-spot (paper §VI): per layer, coverage counts of
still-missing items over the replica map, masked argmax replica pick per
request (lowest-DC-id tie-break), assign hits, repeat until no cluster DC
covers anything, escalate — then fold served bytes into Eq. 1 latency,
straggler and WAN cost.  TPU adaptation: the replica map is **bit-packed**
(one int32 lane per item, bit d = "DC d holds a replica"), so a request
block is a dense ``[block_r, Kp]`` int32 tile in VMEM and per-DC coverage is
a shift-and-mask popcount over the item axis — no ``[R, K, D]`` f32 cube.

The expansion runs one early-exit ``while_loop`` over (layer, greedy pass)
per block: a pass that assigns items anywhere in the block stays in the
layer, a pass with zero progress escalates the shared layer pointer.  Extra
greedy passes are idempotent per request, so the block-lockstep walk equals
per-request greedy exactly (see ``ref.route_expand_ref``); the iteration
bound ``L * (D + 1)`` covers the worst case of D - 1 productive picks plus
one no-progress pass per layer.  Coverage counts are 0/1 sums, exact in f32
below 2^24 items.

Outputs per request block: served DC per item slot (int32, -1 unresolved),
per-DC served bytes, and a stats row (layers used, final missing count,
straggler seconds, WAN bytes, missing-after-each-layer) packed into one
128-lane f32 vector.

Grid: 1-D over request blocks — requests are independent, so any batch size
is eligible via row padding (pad requests have zero valid items; they
resolve to all-unserved with zero cost).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["route_expand", "STATS_LANES", "STAT_MISS_BASE"]

# stats row lane layout (f32): 0 = layers_used, 1 = final missing count,
# 2 = straggler seconds, 3 = WAN bytes, STAT_MISS_BASE + l = missing after
# layer l (l = 0 .. n_layers)
STATS_LANES = 128
STAT_MISS_BASE = 8


def _expand_kernel(
    bits_ref,  # [block_r, Kp] i32 replica bitmask per item slot
    sizes_ref,  # [block_r, Kp] f32 bytes (0 where padded)
    lens_ref,  # [block_r, 1] i32 real item count
    origin_ref,  # [block_r, 1] i32
    allowed_ref,  # [block_r, L, Dp] f32 cluster mask per layer
    origin_oh_ref,  # [block_r, Dp] f32
    rtt_ref,  # [block_r, Dp] f32 RTT d -> origin
    ibw_ref,  # [block_r, Dp] f32 1 / bandwidth d -> origin
    served_ref,  # out [block_r, Kp] i32
    bytes_ref,  # out [block_r, STATS_LANES] f32 (lane d = bytes from DC d)
    stats_ref,  # out [block_r, STATS_LANES] f32
    *,
    n_layers: int,
    n_dc: int,
):
    bits = bits_ref[...]
    sizes = sizes_ref[...]
    lens = lens_ref[...]  # [block_r, 1]
    origin = origin_ref[...]  # [block_r, 1]
    allowed = allowed_ref[...]
    origin_oh = origin_oh_ref[...]
    rtt = rtt_ref[...]
    ibw = ibw_ref[...]
    block_r, k_pad = bits.shape
    d_pad = allowed.shape[2]
    f32 = sizes.dtype

    iota_k = jax.lax.broadcasted_iota(jnp.int32, (block_r, k_pad), 1)
    lane = jax.lax.broadcasted_iota(jnp.int32, (block_r, STATS_LANES), 1)
    d_lane = jax.lax.broadcasted_iota(jnp.int32, (block_r, d_pad), 1)

    valid = iota_k < lens
    local = valid & (((bits >> origin) & 1) > 0)
    missing0 = valid & jnp.logical_not(local)
    # field-word coverage (see ref.route_expand_ref): for item tiles <= 512
    # wide, spread bit d of each item into a 10-bit field, 3 DCs per int32
    # word — one reduction per word yields 3 exact per-DC popcounts
    use_fields = k_pad <= 512
    if use_fields:
        words = []
        for w in range((n_dc + 2) // 3):
            acc = jnp.zeros_like(bits)
            for j, d in enumerate(range(w * 3, min(w * 3 + 3, n_dc))):
                acc = acc + (((bits >> d) & 1) << (10 * j))
            words.append(acc)

    def _coverage(missing):
        cover = jnp.zeros((block_r, d_pad), f32)
        if use_fields:
            for w, word in enumerate(words):
                s = jnp.where(missing, word, 0).sum(axis=1, keepdims=True)
                for j in range(min(3, n_dc - w * 3)):
                    cnt = ((s >> (10 * j)) & 1023).astype(f32)
                    cover = jnp.where(d_lane == w * 3 + j, cnt, cover)
            return cover
        masked = jnp.where(missing, bits, 0)
        for d in range(n_dc):
            cnt = ((masked >> d) & 1).astype(f32).sum(axis=1, keepdims=True)
            cover = jnp.where(d_lane == d, cnt, cover)
        return cover
    served0 = jnp.where(local, origin, jnp.int32(-1))
    miss_stats0 = jnp.where(
        lane == STAT_MISS_BASE,
        missing0.astype(f32).sum(axis=1, keepdims=True),
        jnp.zeros((block_r, STATS_LANES), f32),
    )
    max_iters = n_layers * (n_dc + 1)

    def cond(c):
        _, missing, layer, _, _, it = c
        return (layer < n_layers) & missing.any() & (it < max_iters)

    def body(c):
        served, missing, layer, layers_used, miss_stats, it = c
        a_l = jax.lax.dynamic_index_in_dim(allowed, layer, axis=1, keepdims=False)
        layers_used = jnp.where(
            missing.any(axis=1, keepdims=True)
            & (a_l.max(axis=1, keepdims=True) > 0),
            (layer + 1).astype(f32),
            layers_used,
        )
        cover = jnp.where(a_l > 0, _coverage(missing), f32.type(0.0))
        gain = cover.max(axis=1, keepdims=True)
        # first index achieving the max == argmax == lowest-DC-id tie-break
        best = jnp.where(cover == gain, d_lane, d_pad).min(axis=1, keepdims=True)
        has = ((bits >> best) & 1) > 0
        hit = missing & (gain > 0) & has
        progressed = hit.any()
        new_missing = missing & jnp.logical_not(hit)
        miss_stats = jnp.where(
            progressed,
            miss_stats,
            jnp.where(
                lane == STAT_MISS_BASE + layer + 1,
                new_missing.astype(f32).sum(axis=1, keepdims=True),
                miss_stats,
            ),
        )
        return (
            jnp.where(hit, best, served),
            new_missing,
            jnp.where(progressed, layer, layer + 1),
            layers_used,
            miss_stats,
            it + 1,
        )

    served, missing, _, layers_used, miss_stats, _ = jax.lax.while_loop(
        cond,
        body,
        (
            served0,
            missing0,
            jnp.int32(0),
            jnp.zeros((block_r, 1), f32),
            miss_stats0,
            jnp.int32(0),
        ),
    )
    served_ref[...] = served

    # Eq. 1 fold: per-DC served bytes, straggler latency, WAN bytes.  D is a
    # handful, so static per-DC column folds beat a one-hot matmul here.
    sz = jnp.where(valid, sizes, f32.type(0.0))
    bytes_out = jnp.zeros((block_r, STATS_LANES), f32)
    straggler = jnp.zeros((block_r, 1), f32)
    wan = jnp.zeros((block_r, 1), f32)
    for d in range(n_dc):
        b_d = jnp.where(served == d, sz, f32.type(0.0)).sum(axis=1, keepdims=True)
        bytes_out = jnp.where(lane == d, b_d, bytes_out)
        at_origin = origin == d  # [block_r, 1]
        lat_d = jnp.where(
            at_origin,
            f32.type(0.0),
            rtt[:, d : d + 1] + b_d * ibw[:, d : d + 1],
        )
        served_d = (served == d).astype(f32).sum(axis=1, keepdims=True) > 0
        straggler = jnp.maximum(straggler, jnp.where(served_d, lat_d, 0.0))
        wan = wan + b_d * (1.0 - origin_oh[:, d : d + 1])
    bytes_ref[...] = bytes_out

    stats = miss_stats
    stats = jnp.where(lane == 0, layers_used, stats)
    final_missing = missing.astype(f32).sum(axis=1, keepdims=True)
    stats = jnp.where(lane == 1, final_missing, stats)
    stats = jnp.where(lane == 2, straggler, stats)
    stats = jnp.where(lane == 3, wan, stats)
    stats_ref[...] = stats


def _pad_axis(x: jnp.ndarray, axis: int, to: int) -> jnp.ndarray:
    pad = to - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def route_expand(
    bits: jnp.ndarray,  # [R, K] i32 per-item replica bitmask (bit d = DC d)
    sizes: jnp.ndarray,  # [R, K] f32 item bytes (0 where padded)
    lens: jnp.ndarray,  # [R] i32 real item count per request
    origin: jnp.ndarray,  # [R] i32 origin DC per request
    comp: jnp.ndarray,  # [hier + 1, D] i32 layer component ids
    rtt: jnp.ndarray,  # [D, D] f32 env RTT matrix
    ibw: jnp.ndarray,  # [D, D] f32 elementwise 1 / bandwidth matrix
    *,
    block_r: int = 128,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, ...]:
    """Pallas route-expansion; same contract as ``ref.route_expand_ref``.

    Derives the per-request cluster masks and origin-relative cost columns
    on-device (tiny [L, D] / [D, D] gathers), pads requests to ``block_r``
    multiples, DCs to a sublane multiple of 8 and item slots to a lane
    multiple of 128, runs the fused kernel over a request-block grid, and
    slices back.  The stats row requires ``STAT_MISS_BASE + n_layers + 1 <=
    STATS_LANES`` (plenty for the paper's latency hierarchies) and ``n_dc <=
    STATS_LANES``.
    """
    R, K = bits.shape
    L = comp.shape[0] - 1
    D = comp.shape[1]
    assert STAT_MISS_BASE + L + 1 <= STATS_LANES
    assert D <= STATS_LANES
    block_r = max(8, min(block_r, -(-R // 8) * 8))
    r_pad = -(-R // block_r) * block_r
    k_pad = -(-max(K, 1) // 128) * 128
    d_pad = -(-max(D, 1) // 8) * 8

    origin = origin.astype(jnp.int32)
    comp_l = comp[1:].astype(jnp.int32)  # [L, D]
    comp_o = jnp.transpose(comp_l[:, origin])  # [R, L]
    allowed = (comp_l[None, :, :] == comp_o[:, :, None]) & (
        jnp.arange(D, dtype=jnp.int32)[None, None, :] != origin[:, None, None]
    )
    oh = (
        jnp.arange(D, dtype=jnp.int32)[None, :] == origin[:, None]
    ).astype(jnp.float32)
    rtt_ro = jnp.transpose(rtt[:, origin]).astype(jnp.float32)
    ibw_ro = jnp.transpose(ibw[:, origin]).astype(jnp.float32)

    bits_p = _pad_axis(_pad_axis(bits.astype(jnp.int32), 1, k_pad), 0, r_pad)
    sizes_p = _pad_axis(_pad_axis(sizes.astype(jnp.float32), 1, k_pad), 0, r_pad)
    lens_p = _pad_axis(lens.astype(jnp.int32)[:, None], 0, r_pad)
    origin_p = _pad_axis(origin[:, None], 0, r_pad)
    allowed_p = _pad_axis(
        _pad_axis(allowed.astype(jnp.float32), 2, d_pad), 0, r_pad
    )
    oh_p = _pad_axis(_pad_axis(oh, 1, d_pad), 0, r_pad)
    rtt_p = _pad_axis(_pad_axis(rtt_ro, 1, d_pad), 0, r_pad)
    ibw_p = _pad_axis(_pad_axis(ibw_ro, 1, d_pad), 0, r_pad)

    grid = (r_pad // block_r,)
    served_p, bytes_p, stats_p = pl.pallas_call(
        functools.partial(_expand_kernel, n_layers=L, n_dc=D),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, k_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_r, k_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, L, d_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_r, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_r, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_r, d_pad), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, k_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_r, STATS_LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_r, STATS_LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r_pad, k_pad), jnp.int32),
            jax.ShapeDtypeStruct((r_pad, STATS_LANES), jnp.float32),
            jax.ShapeDtypeStruct((r_pad, STATS_LANES), jnp.float32),
        ],
        interpret=interpret,
    )(bits_p, sizes_p, lens_p, origin_p, allowed_p, oh_p, rtt_p, ibw_p)

    served = served_p[:R, :K]
    bytes_rd = bytes_p[:R, :D]
    layers_used = stats_p[:R, 0].astype(jnp.int32)
    miss_after = stats_p[:R, STAT_MISS_BASE : STAT_MISS_BASE + L + 1].astype(
        jnp.int32
    )
    straggler = stats_p[:R, 2]
    wan = stats_p[:R, 3]
    return served, bytes_rd, layers_used, miss_after, straggler, wan
