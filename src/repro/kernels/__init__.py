"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel module contains a ``pl.pallas_call`` with explicit BlockSpec
VMEM tiling; ``ref.py`` holds the pure-jnp oracles; ``ops.py`` the jit'd
public wrappers with platform dispatch.
"""
from . import ops, ref  # noqa: F401
