"""Public kernel API: jit'd wrappers dispatching Pallas kernel vs jnp oracle.

Policy: on TPU backends the Pallas kernels run compiled; on CPU (this
container) the default is the pure-jnp reference (fast, vectorized) while
``interpret=True`` forces the kernel body through the Pallas interpreter for
validation.  ``use_kernel`` can be pinned explicitly by callers/tests.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .dhd_spmv import dhd_ell_step
from .embedding_bag import embedding_bag as _embedding_bag_kernel
from .flash_attention import flash_attention as _flash_attention_kernel

__all__ = ["attention", "dhd_step", "bag_lookup", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.lru_cache(maxsize=None)
def _attention_with_vjp(causal: bool, window: Optional[int], block_q: int,
                        block_kv: int, interpret: bool):
    """Trainable flash attention: Pallas kernel forward, reference-math
    backward (the standard pattern until a fused bwd kernel lands — the
    bwd recomputes attention from the saved q/k/v, so no S x S residuals
    are stored either way)."""

    @jax.custom_vjp
    def f(q, k, v):
        return _flash_attention_kernel(
            q, k, v, causal=causal, window=window,
            block_q=block_q, block_kv=block_kv, interpret=interpret,
        )

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, pullback = jax.vjp(
            lambda q_, k_, v_: ref.attention_ref(
                q_, k_, v_, causal=causal, window=window
            ),
            q, k, v,
        )
        return pullback(g)

    f.defvjp(fwd, bwd)
    return f


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    use_kernel: Optional[bool] = None,
    block_q: int = 128,
    block_kv: int = 128,
) -> jnp.ndarray:
    """FlashAttention when kernel-eligible, dense reference otherwise.

    Kernel eligibility: TPU backend (or explicit request) and block-divisible
    sequence lengths.  The kernel path is differentiable (custom VJP with a
    recompute backward), so it serves training and serving alike."""
    if use_kernel is None:
        use_kernel = on_tpu()
    sq, skv = q.shape[2], k.shape[2]
    divisible = sq % min(block_q, sq) == 0 and skv % min(block_kv, skv) == 0
    if use_kernel and divisible:
        fn = _attention_with_vjp(
            causal, window, min(block_q, sq), min(block_kv, skv), not on_tpu()
        )
        return fn(q, k, v)
    return ref.attention_ref(q, k, v, causal=causal, window=window)


def dhd_step(
    heat: jnp.ndarray,
    cols: jnp.ndarray,
    vals: jnp.ndarray,
    q: jnp.ndarray,
    tail_src: Optional[jnp.ndarray] = None,
    tail_dst: Optional[jnp.ndarray] = None,
    tail_val: Optional[jnp.ndarray] = None,
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
    use_kernel: Optional[bool] = None,
    block_n: int = 256,
) -> jnp.ndarray:
    """DHD update over ELL (+ optional COO tail for overflow edges).

    The tail contributes to both |N_u^out| and the flows; since the ELL
    kernel computes counts internally, tail edges are folded in by running
    the edge-list reference over the tail *jointly* with per-row ELL flows
    only when a tail exists (rare: >q98 degree).  Placement confines DHD to
    clusters, so the no-tail fast path dominates.
    """
    if use_kernel is None:
        use_kernel = on_tpu()
    has_tail = tail_src is not None and tail_src.size > 0
    if has_tail:
        # Tail edges change |N_u^out| globally, so the blocked kernel cannot
        # be patched additively — reconstruct the exact undirected edge list
        # (host-side) and use the edge-list formulation.  An edge may appear
        # in one endpoint's ELL row while overflowing the other's, so dedupe
        # on the canonical (min,max) key, not on direction.
        import numpy as np

        n = heat.shape[0]
        cols_np, vals_np = np.asarray(cols), np.asarray(vals)
        iu, ik = np.nonzero(vals_np > 0)
        e_src = np.concatenate([iu, np.asarray(tail_src)])
        e_dst = np.concatenate([cols_np[iu, ik], np.asarray(tail_dst)])
        e_w = np.concatenate([vals_np[iu, ik], np.asarray(tail_val)])
        a = np.minimum(e_src, e_dst)
        b = np.maximum(e_src, e_dst)
        _, first = np.unique(a.astype(np.int64) * n + b, return_index=True)
        from ..core.dhd import dhd_step_edges

        return dhd_step_edges(
            heat,
            jnp.asarray(a[first], jnp.int32),
            jnp.asarray(b[first], jnp.int32),
            jnp.asarray(e_w[first], jnp.float32),
            q, n, alpha=alpha, gamma=gamma, beta=beta,
        )
    if use_kernel and heat.shape[0] % min(block_n, heat.shape[0]) == 0:
        return dhd_ell_step(
            heat, cols, vals, q, alpha=alpha, gamma=gamma, beta=beta,
            block_n=min(block_n, heat.shape[0]), interpret=not on_tpu(),
        )
    return ref.dhd_ell_ref(heat, cols, vals, q, alpha=alpha, gamma=gamma, beta=beta)


def bag_lookup(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    weights: Optional[jnp.ndarray] = None,
    mode: str = "sum",
    use_kernel: Optional[bool] = None,
    block_b: int = 128,
    block_v: int = 1024,
) -> jnp.ndarray:
    """EmbeddingBag lookup (sum/mean)."""
    if use_kernel is None:
        use_kernel = on_tpu()
    b, _ = indices.shape
    v, _ = table.shape
    divisible = b % min(block_b, b) == 0 and v % min(block_v, v) == 0
    if use_kernel and divisible:
        return _embedding_bag_kernel(
            table, indices, weights, mode=mode,
            block_b=block_b, block_v=block_v, interpret=not on_tpu(),
        )
    return ref.embedding_bag_ref(table, indices, weights, mode=mode)
