"""Public kernel API: jit'd wrappers dispatching Pallas kernel vs jnp oracle.

Policy: on TPU backends the Pallas kernels run compiled; on CPU (this
container) the default is the pure-jnp reference (fast, vectorized) while
``interpret=True`` forces the kernel body through the Pallas interpreter for
validation.  ``use_kernel`` can be pinned explicitly by callers/tests.
"""
from __future__ import annotations

import functools
import time
from collections import OrderedDict
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from ..obs import get_registry
from .autotune import get_autotuner
from .dhd_spmv import dhd_ell_step, dhd_ell_step_batch
from .embedding_bag import embedding_bag as _embedding_bag_kernel
from .flash_attention import flash_attention as _flash_attention_kernel
from .route_expand import route_expand as _route_expand_kernel

__all__ = [
    "attention",
    "dhd_step",
    "dhd_step_batch",
    "diffuse_batch",
    "bag_lookup",
    "edge_cache_stats",
    "on_tpu",
    "route_expand_batch",
    "route_expand_candidates",
    "route_expand_subsets",
]


# ------------------------------------------------------- dispatch telemetry
def _obs_t0() -> Optional[float]:
    """perf_counter() when telemetry is on, else None (zero-cost gate)."""
    return time.perf_counter() if get_registry().enabled else None


def _obs_dispatch(op: str, path: str, t0: Optional[float]) -> None:
    if t0 is None:
        return
    reg = get_registry()
    reg.counter("kernels.dispatch", op=op, path=path).inc()
    reg.histogram("kernels.op_time_s", op=op).observe(time.perf_counter() - t0)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.lru_cache(maxsize=None)
def _attention_with_vjp(causal: bool, window: Optional[int], block_q: int,
                        block_kv: int, interpret: bool):
    """Trainable flash attention: Pallas kernel forward, reference-math
    backward (the standard pattern until a fused bwd kernel lands — the
    bwd recomputes attention from the saved q/k/v, so no S x S residuals
    are stored either way)."""

    @jax.custom_vjp
    def f(q, k, v):
        return _flash_attention_kernel(
            q, k, v, causal=causal, window=window,
            block_q=block_q, block_kv=block_kv, interpret=interpret,
        )

    def fwd(q, k, v):
        return f(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, pullback = jax.vjp(
            lambda q_, k_, v_: ref.attention_ref(
                q_, k_, v_, causal=causal, window=window
            ),
            q, k, v,
        )
        return pullback(g)

    f.defvjp(fwd, bwd)
    return f


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    use_kernel: Optional[bool] = None,
    block_q: int = 128,
    block_kv: int = 128,
) -> jnp.ndarray:
    """FlashAttention when kernel-eligible, dense reference otherwise.

    Kernel eligibility: TPU backend (or explicit request) and block-divisible
    sequence lengths.  The kernel path is differentiable (custom VJP with a
    recompute backward), so it serves training and serving alike."""
    if use_kernel is None:
        use_kernel = on_tpu()
    sq, skv = q.shape[2], k.shape[2]
    divisible = sq % min(block_q, sq) == 0 and skv % min(block_kv, skv) == 0
    if use_kernel and divisible:
        fn = _attention_with_vjp(
            causal, window, min(block_q, sq), min(block_kv, skv), not on_tpu()
        )
        return fn(q, k, v)
    return ref.attention_ref(q, k, v, causal=causal, window=window)


# --------------------------------------------------- COO-tail edge recovery
# Rebuilding + deduping the full undirected edge list from (ELL, tail) is a
# host-side O(nnz log nnz) pass; streaming stores call dhd_step with the SAME
# adjacency arrays every sweep, so the deduped arrays are cached keyed on the
# *identity* of the inputs.  Entries hold strong references to their keys'
# arrays, so a live cache entry's ids can never be reused by a new object.
# CONTRACT: adjacency arrays passed to dhd_step/dhd_step_batch with a tail
# must not be mutated in place afterwards (jnp arrays — the expected input —
# are immutable; numpy callers must replace, not rewrite, their buffers), or
# the identity key would serve the pre-mutation edge list.
_EDGE_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()  # geolint: allow[GL001]
_EDGE_CACHE_MAX = 8


def reset_kernel_caches() -> None:
    """Drop the identity-keyed edge cache and the subset-mask table
    (test isolation hook; both rebuild lazily on next use)."""
    _EDGE_CACHE.clear()
    _SUBSET_HAS_CACHE.clear()


def edge_cache_stats() -> dict:
    """Edge-cache hit/miss counts from the process-default registry.

    Counts live in the registry (so ``registry.reset()`` clears them
    between benchmark runs); a disabled registry reports zeros."""
    reg = get_registry()
    hits = reg.counter("kernels.edge_cache", event="hit").value
    misses = reg.counter("kernels.edge_cache", event="miss").value
    hits = 0.0 if hits != hits else hits  # NaN from the no-op singleton
    misses = 0.0 if misses != misses else misses
    total = hits + misses
    return {
        "hits": int(hits),
        "misses": int(misses),
        "hit_rate": hits / total if total else 0.0,
    }


def _tail_edges(
    n: int, cols, vals, tail_src, tail_dst, tail_val
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Exact undirected (a, b, w) covering ELL rows + COO tail, deduped on
    the canonical (min, max) key (an edge may sit in one endpoint's ELL row
    while overflowing the other's)."""
    key = (n, id(cols), id(vals), id(tail_src), id(tail_dst), id(tail_val))
    hit = _EDGE_CACHE.get(key)
    if hit is not None:
        _EDGE_CACHE.move_to_end(key)
        get_registry().counter("kernels.edge_cache", event="hit").inc()
        return hit[1]
    cols_np, vals_np = np.asarray(cols), np.asarray(vals)
    iu, ik = np.nonzero(vals_np > 0)
    e_src = np.concatenate([iu, np.asarray(tail_src)])
    e_dst = np.concatenate([cols_np[iu, ik], np.asarray(tail_dst)])
    e_w = np.concatenate([vals_np[iu, ik], np.asarray(tail_val)])
    a = np.minimum(e_src, e_dst)
    b = np.maximum(e_src, e_dst)
    _, first = np.unique(a.astype(np.int64) * n + b, return_index=True)
    out = (
        jnp.asarray(a[first], jnp.int32),
        jnp.asarray(b[first], jnp.int32),
        jnp.asarray(e_w[first], jnp.float32),
    )
    _EDGE_CACHE[key] = ((cols, vals, tail_src, tail_dst, tail_val), out)
    get_registry().counter("kernels.edge_cache", event="miss").inc()
    while len(_EDGE_CACHE) > _EDGE_CACHE_MAX:
        _EDGE_CACHE.popitem(last=False)
    return out


def dhd_step(
    heat: jnp.ndarray,
    cols: jnp.ndarray,
    vals: jnp.ndarray,
    q: jnp.ndarray,
    tail_src: Optional[jnp.ndarray] = None,
    tail_dst: Optional[jnp.ndarray] = None,
    tail_val: Optional[jnp.ndarray] = None,
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
    use_kernel: Optional[bool] = None,
    block_n: int = 256,
) -> jnp.ndarray:
    """DHD update over ELL (+ optional COO tail for overflow edges).

    The tail contributes to both |N_u^out| and the flows; since the ELL
    kernel computes counts internally, tail edges are folded in by running
    the edge-list reference over the tail *jointly* with per-row ELL flows
    only when a tail exists (rare: >q98 degree).  Placement confines DHD to
    clusters, so the no-tail fast path dominates.  The kernel path pads to
    the block size internally, so any row count is eligible.
    """
    if use_kernel is None:
        use_kernel = on_tpu()
    t0 = _obs_t0()
    has_tail = tail_src is not None and tail_src.size > 0
    if has_tail:
        # Tail edges change |N_u^out| globally, so the blocked kernel cannot
        # be patched additively — use the exact edge-list formulation over
        # the (cached) reconstructed undirected edge list.
        n = heat.shape[0]
        a, b, w = _tail_edges(n, cols, vals, tail_src, tail_dst, tail_val)
        from ..core.dhd import dhd_step_edges

        out = dhd_step_edges(
            heat, a, b, w, q, n, alpha=alpha, gamma=gamma, beta=beta
        )
        _obs_dispatch("dhd_step", "tail_edges", t0)
        return out
    if use_kernel:
        out = dhd_ell_step(
            heat, cols, vals, q, alpha=alpha, gamma=gamma, beta=beta,
            block_n=min(block_n, heat.shape[0]), interpret=not on_tpu(),
        )
        _obs_dispatch("dhd_step", "kernel", t0)
        return out
    out = ref.dhd_ell_ref(heat, cols, vals, q, alpha=alpha, gamma=gamma, beta=beta)
    _obs_dispatch("dhd_step", "ref", t0)
    return out


def dhd_step_batch(
    heat: jnp.ndarray,  # [B, n]
    cols: jnp.ndarray,  # [n, kmax]
    vals: jnp.ndarray,  # [n, kmax] shared or [B, n, kmax] per-batch
    q: jnp.ndarray,  # [B, n]
    tail_src: Optional[jnp.ndarray] = None,
    tail_dst: Optional[jnp.ndarray] = None,
    tail_val: Optional[jnp.ndarray] = None,
    alpha: float = 0.5,
    gamma: float = 0.1,
    beta: float = 0.3,
    use_kernel: Optional[bool] = None,
    block_n: int = 256,
) -> jnp.ndarray:
    """Batched :func:`dhd_step`: B heat fields over one shared adjacency.

    Dispatch mirrors the single-seed path: batched Pallas ELL kernel when
    kernel-eligible, batched jnp reference otherwise, exact batched edge
    form when a COO tail exists (shared ``vals`` only — the tail rebuild is
    a per-adjacency operation)."""
    if use_kernel is None:
        use_kernel = on_tpu()
    t0 = _obs_t0()
    has_tail = tail_src is not None and tail_src.size > 0
    if has_tail:
        if vals.ndim == 3:
            raise ValueError("COO-tail batching requires shared [n, kmax] vals")
        n = heat.shape[1]
        a, b, w = _tail_edges(n, cols, vals, tail_src, tail_dst, tail_val)
        from ..core.dhd import dhd_step_edges_batch

        out = dhd_step_edges_batch(
            heat, a, b, w, q, n, alpha=alpha, gamma=gamma, beta=beta
        )
        _obs_dispatch("dhd_step_batch", "tail_edges", t0)
        return out
    if use_kernel:
        out = dhd_ell_step_batch(
            heat, cols, vals, q, alpha=alpha, gamma=gamma, beta=beta,
            block_n=min(block_n, heat.shape[1]), interpret=not on_tpu(),
        )
        _obs_dispatch("dhd_step_batch", "kernel", t0)
        return out
    out = ref.dhd_ell_ref_batch(
        heat, cols, vals, q, alpha=alpha, gamma=gamma, beta=beta
    )
    _obs_dispatch("dhd_step_batch", "ref", t0)
    return out


# --------------------------------------------------- batched diffusion loop
def _ell_pack_batch(
    n: int, src: np.ndarray, dst: np.ndarray, weight: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """Pack an undirected edge list into tail-free symmetric ELL, vectorized.

    ``weight`` may be [m] (shared) or [B, m] (per-seed); the column structure
    is shared so per-seed variants differ only in ``vals``."""
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    uu = np.concatenate([src, dst])
    vv = np.concatenate([dst, src])
    w = np.asarray(weight, np.float32)
    wb = np.concatenate([w, w], axis=-1)  # [..., 2m]
    order = np.argsort(uu, kind="stable")
    uu, vv, wb = uu[order], vv[order], wb[..., order]
    counts = np.bincount(uu, minlength=n)
    kmax = max(int(counts.max(initial=1)), 1)
    starts = np.zeros(n + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    pos = np.arange(len(uu)) - starts[uu]
    cols = np.broadcast_to(np.arange(n, dtype=np.int32)[:, None], (n, kmax)).copy()
    cols[uu, pos] = vv.astype(np.int32)
    if w.ndim == 2:
        vals = np.zeros((w.shape[0], n, kmax), np.float32)
        vals[:, uu, pos] = wb
    else:
        vals = np.zeros((n, kmax), np.float32)
        vals[uu, pos] = wb
    return cols, vals


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "n_steps", "alpha", "gamma", "beta", "half_life"),
)
def _diffuse_edges_loop(
    src, dst, weight, h0, q0, *, n_nodes, n_steps, alpha, gamma, beta, half_life
):
    from ..core.dhd import dhd_step_edges_batch, source_heat

    def body(k, h):
        q = source_heat(q0, k, half_life=half_life)
        return dhd_step_edges_batch(
            h, src, dst, weight, q, n_nodes,
            alpha=alpha, gamma=gamma, beta=beta,
        )

    return jax.lax.fori_loop(0, n_steps, body, h0)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_steps", "alpha", "gamma", "beta", "half_life", "block_n", "interpret"
    ),
)
def _diffuse_ell_loop(
    cols, vals, h0, q0, *,
    n_steps, alpha, gamma, beta, half_life, block_n, interpret
):
    from ..core.dhd import source_heat

    def body(k, h):
        q = source_heat(q0, k, half_life=half_life)
        return dhd_ell_step_batch(
            h, cols, vals, q, alpha=alpha, gamma=gamma, beta=beta,
            block_n=block_n, interpret=interpret,
        )

    return jax.lax.fori_loop(0, n_steps, body, h0)


def diffuse_batch(
    n_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    weight: np.ndarray,  # [m] shared or [B, m] per-seed
    seeds: np.ndarray,  # [B, n]
    base_heat: Optional[np.ndarray] = None,
    params=None,
    n_steps: int = 32,
    use_kernel: Optional[bool] = None,
    block_n: int = 256,
) -> np.ndarray:
    """Backend for :func:`repro.core.dhd.diffuse_affinity_batch`.

    Runs the whole decaying-source loop on device: the batched Pallas ELL
    kernel (edge list packed tail-free once per call) when kernel-eligible,
    the vmapped edge form otherwise."""
    from ..core.dhd import DHDParams

    p = params or DHDParams()
    if use_kernel is None:
        use_kernel = on_tpu()
    seeds_j = jnp.asarray(seeds, jnp.float32)
    if base_heat is None:
        h0 = seeds_j
    else:
        h0 = seeds_j + jnp.asarray(np.atleast_2d(base_heat), jnp.float32)
    half_life = max(n_steps / 4.0, 1.0)
    t0 = _obs_t0()
    if use_kernel:
        cols, vals = _ell_pack_batch(n_nodes, src, dst, weight)
        h = _diffuse_ell_loop(
            jnp.asarray(cols), jnp.asarray(vals), h0, seeds_j,
            n_steps=n_steps, alpha=p.alpha, gamma=p.gamma, beta=p.beta,
            half_life=half_life, block_n=min(block_n, n_nodes),
            interpret=not on_tpu(),
        )
        _obs_dispatch("diffuse_batch", "kernel", t0)
    else:
        w = np.asarray(weight, np.float32)
        h = _diffuse_edges_loop(
            jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
            jnp.asarray(w), h0, seeds_j,
            n_nodes=n_nodes, n_steps=n_steps,
            alpha=p.alpha, gamma=p.gamma, beta=p.beta, half_life=half_life,
        )
        _obs_dispatch("diffuse_batch", "ref", t0)
    return np.asarray(h)


# ------------------------------------------------------ fused route expansion
_route_expand_ref_jit = jax.jit(ref.route_expand_ref)


# precomputed tag keys: the route dispatch sits inside the 5% serving
# telemetry budget, so it books two plain counters (count + cumulative
# seconds) instead of the P² histogram _obs_dispatch feeds
_ROUTE_OBS_KEYS = {
    path: ((("op", "route_expand"), ("path", path)),)
    for path in ("kernel", "ref", "subsets")
}


def _route_obs(path: str, t0: Optional[float]) -> None:
    if t0 is None:
        return
    reg = get_registry()
    # handle pair memoized per registry (dropped with the instruments by
    # MetricsRegistry.clear()): two dict gets instead of two keyed lookups
    cache_key = "kernels.route:" + path
    pair = reg._handle_cache.get(cache_key)
    if pair is None:
        (key,) = _ROUTE_OBS_KEYS[path]
        pair = (
            reg.counter_keyed("kernels.dispatch", key),
            reg.counter_keyed("kernels.route_expand_time_s", key),
        )
        reg._handle_cache[cache_key] = pair
    pair[0].inc()
    pair[1].inc(time.perf_counter() - t0)


def route_expand_candidates(
    backend: Optional[str] = None, n_dcs: Optional[int] = None
) -> list:
    """Autotuner candidate configs for ``route_expand`` on ``backend``.

    TPU sweeps the Pallas kernel's request-block shapes against the compiled
    oracle; CPU pits the jitted oracle against the subset-histogram router
    (the interpreted kernel exists for validation, not speed).  The subset
    candidate is offered only when the DC count keeps its ``2**D`` histogram
    small (``n_dcs`` unknown counts as eligible — dispatch re-checks)."""
    backend = backend or jax.default_backend()
    cands = [{"impl": "ref"}]
    if backend == "tpu":
        cands += [{"impl": "kernel", "block_r": b} for b in (32, 64, 128, 256)]
    elif n_dcs is None or n_dcs <= SUBSET_MAX_DCS:
        cands.append({"impl": "subsets"})
    return cands


def route_expand_batch(
    bits: np.ndarray,  # [R, K] i32 per-item replica bitmask (bit d = DC d)
    sizes: np.ndarray,  # [R, K] f32 item bytes (0 where padded)
    lens: np.ndarray,  # [R] real item count per request
    origin: np.ndarray,  # [R] origin DC per request
    comp: np.ndarray,  # [hier + 1, D] layer component ids
    rtt: np.ndarray,  # [D, D] env RTT matrix
    ibw: np.ndarray,  # [D, D] elementwise 1 / bandwidth matrix
    use_kernel: Optional[bool] = None,
    block_r: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> Tuple[np.ndarray, ...]:
    """Fused stepwise layered expansion + Eq. 1 fold for a packed batch.

    Dispatch: an autotuner winner for ``(R, K, D, L)`` (see
    ``kernels.autotune``) pins impl and block shape; without one, TPU takes
    the Pallas kernel and CPU the jitted oracle — both produce the oracle's
    exact greedy picks (``ref.route_expand_ref``).  Returns numpy
    ``(served, bytes_rd, layers_used, miss_after, straggler_s, wan_bytes)``.
    """
    R, K = bits.shape
    L = comp.shape[0] - 1
    D = comp.shape[1]
    t0 = _obs_t0()
    if use_kernel is None or block_r is None:
        cfg = get_autotuner().lookup("route_expand", (R, K, D, L)) or {}
        if use_kernel is None:
            impl = cfg.get("impl", "kernel" if on_tpu() else "ref")
            use_kernel = impl == "kernel"
        if block_r is None:
            block_r = int(cfg.get("block_r", 128))
    if interpret is None:
        interpret = not on_tpu()
    args = (
        jnp.asarray(bits, jnp.int32),
        jnp.asarray(sizes, jnp.float32),
        jnp.asarray(lens, jnp.int32),
        jnp.asarray(origin, jnp.int32),
        jnp.asarray(comp, jnp.int32),
        jnp.asarray(rtt, jnp.float32),
        jnp.asarray(ibw, jnp.float32),
    )
    if use_kernel:
        out = _route_expand_kernel(
            *args, block_r=int(block_r), interpret=interpret
        )
        out = tuple(np.asarray(o) for o in out)
        _route_obs("kernel", t0)
    else:
        out = _route_expand_ref_jit(*args)
        out = tuple(np.asarray(o) for o in out)
        _route_obs("ref", t0)
    return out


# subset-histogram router: with D data centers an item's routing behaviour is
# fully determined by its replica bitmask, so a batch collapses to at most
# 2**D distinct item classes per request.  Histogramming the flat item stream
# over (request, bitmask) turns every greedy pass into [R, 2**D]-sized work —
# independent of the item count — which on CPU beats both the jitted oracle
# and the (interpreted) kernel by a wide margin for small D.
SUBSET_MAX_DCS = 8

_SUBSET_HAS_CACHE: dict = {}  # geolint: allow[GL001]


def _subset_has(n_dc: int) -> Tuple[np.ndarray, np.ndarray]:
    hit = _SUBSET_HAS_CACHE.get(n_dc)
    if hit is None:
        s = np.arange(1 << n_dc, dtype=np.int64)
        has = ((s[:, None] >> np.arange(n_dc)) & 1).astype(bool)  # [S, D]
        hit = (has, has.astype(np.float64))
        _SUBSET_HAS_CACHE.clear()
        _SUBSET_HAS_CACHE[n_dc] = hit
    return hit


def route_expand_subsets(
    bits_flat: np.ndarray,  # [K] i32/i64 per-item replica bitmask, flat stream
    req_id: np.ndarray,  # [K] request id per flat item (sorted by request)
    n_requests: int,
    origin: np.ndarray,  # [R] origin DC per request
    comp: np.ndarray,  # [hier + 1, D] layer component ids
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stepwise layered expansion over per-request replica-subset histograms.

    Runs the exact greedy of ``route_online`` (same coverage counts — an
    item contributes to a DC's coverage iff its bitmask holds that DC's bit —
    same lowest-DC-id argmax tie-break, same layer escalation) but on
    ``[R, 2**D]`` subset counts, then scatters each subset's serving DC back
    to its items with one gather.  Returns
    ``(served [K] i64, layers_used [R] i64, miss_after [R, hier + 1] i64)``;
    the byte/latency fold is left to the caller's exact host epilogue.
    """
    t0 = _obs_t0()
    R = int(n_requests)
    L = comp.shape[0] - 1
    D = comp.shape[1]
    S = 1 << D
    has, has_f = _subset_has(D)
    # [R, S] item count per (request, replica subset); exact as f64 (< 2^53)
    cnt = np.bincount(
        req_id * S + bits_flat.astype(np.int64), minlength=R * S
    ).reshape(R, S).astype(np.float64)
    origin_in = has[:, origin].T  # [R, S] subset holds the origin's bit
    serve = np.where(origin_in, origin[:, None], -1)  # [R, S] per-subset DC
    missing = ~origin_in
    miss_cnt = (cnt * missing).sum(axis=1)
    miss_after = np.zeros((R, L + 1), dtype=np.int64)
    miss_after[:, 0] = miss_cnt
    ar_R = np.arange(R)
    layers_used = np.zeros(R, dtype=np.int64)
    for layer in range(1, L + 1):
        if not miss_cnt.any():
            break  # untouched miss_after columns stay 0 == fully resolved
        cl = comp[layer]
        allowed = cl[origin][:, None] == cl[None, :]  # [R, D]
        allowed[ar_R, origin] = False
        layers_used = np.where(
            (miss_cnt > 0) & allowed.any(axis=1), layer, layers_used
        )
        while True:
            cover = (cnt * missing) @ has_f  # [R, D] exact integer counts
            cover[~allowed] = 0.0
            best = cover.argmax(axis=1)  # first max == lowest DC id
            progressed = cover[ar_R, best] > 0
            if not progressed.any():
                break
            hit = missing & has[:, best].T & progressed[:, None]
            serve = np.where(hit, best[:, None], serve)
            missing &= ~hit
            miss_cnt = (cnt * missing).sum(axis=1)
        miss_after[:, layer] = miss_cnt
    served = serve[req_id, bits_flat]
    _route_obs("subsets", t0)
    return served, layers_used, miss_after


def bag_lookup(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    weights: Optional[jnp.ndarray] = None,
    mode: str = "sum",
    use_kernel: Optional[bool] = None,
    block_b: int = 128,
    block_v: int = 1024,
) -> jnp.ndarray:
    """EmbeddingBag lookup (sum/mean)."""
    if use_kernel is None:
        use_kernel = on_tpu()
    b, _ = indices.shape
    v, _ = table.shape
    divisible = b % min(block_b, b) == 0 and v % min(block_v, v) == 0
    if use_kernel and divisible:
        return _embedding_bag_kernel(
            table, indices, weights, mode=mode,
            block_b=block_b, block_v=block_v, interpret=not on_tpu(),
        )
    return ref.embedding_bag_ref(table, indices, weights, mode=mode)
