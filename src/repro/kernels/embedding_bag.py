"""EmbeddingBag (gather + bag-reduce) — Pallas TPU kernel.

The recsys hot path (BST's item/category history lookup).  TPU adaptation:
instead of per-index HBM gathers (GPU style), the **vocab axis is tiled
through VMEM**: grid = (bag_blocks, vocab_blocks); each step loads a
(block_v x dim) table tile, resolves the in-range indices against it with a
VMEM take + mask, and accumulates into a VMEM scratch — dense, predictable
DMA traffic, no data-dependent HBM addressing.  For Zipf-distributed indices
the hot vocab tiles hit nearly every bag block (good reuse); GeoLayer's
row-replication (DESIGN §4.3) exploits exactly that skew at mesh scale.

``mode='mean'`` normalizes by bag weight inside the finalize step.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["embedding_bag"]


def _bag_kernel(
    idx_ref,  # [block_b, L]
    w_ref,  # [block_b, L]
    tab_ref,  # [block_v, D]
    o_ref,  # [block_b, D]
    acc_scr,  # [block_b, D] f32
    wsum_scr,  # [block_b, 1] f32
    *,
    block_v: int,
    mode: str,
):
    iv = pl.program_id(1)
    n_v = pl.num_programs(1)

    @pl.when(iv == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        wsum_scr[...] = jnp.zeros_like(wsum_scr)

    idx = idx_ref[...]  # [bb, L] global vocab ids
    w = w_ref[...].astype(jnp.float32)
    tab = tab_ref[...].astype(jnp.float32)  # [bv, D]
    lo = iv * block_v
    local = idx - lo
    in_range = (local >= 0) & (local < block_v)
    local_c = jnp.clip(local, 0, block_v - 1)
    rows = jnp.take(tab, local_c, axis=0)  # [bb, L, D] VMEM gather
    wm = jnp.where(in_range, w, 0.0)
    acc_scr[...] += jnp.einsum("bl,bld->bd", wm, rows)
    wsum_scr[...] += wm.sum(axis=1, keepdims=True)

    @pl.when(iv == n_v - 1)
    def _finalize():
        out = acc_scr[...]
        if mode == "mean":
            out = out / jnp.maximum(wsum_scr[...], 1e-9)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("mode", "block_b", "block_v", "interpret")
)
def embedding_bag(
    table: jnp.ndarray,  # [V, D]
    indices: jnp.ndarray,  # [B, L] int32
    weights: Optional[jnp.ndarray] = None,  # [B, L]
    mode: str = "sum",
    block_b: int = 128,
    block_v: int = 1024,
    interpret: bool = True,
) -> jnp.ndarray:
    v, d = table.shape
    b, l = indices.shape
    block_b = min(block_b, b)
    block_v = min(block_v, v)
    assert b % block_b == 0 and v % block_v == 0
    if weights is None:
        weights = jnp.ones((b, l), dtype=table.dtype)
    grid = (b // block_b, v // block_v)
    kernel = functools.partial(_bag_kernel, block_v=block_v, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, l), lambda ib, iv: (ib, 0)),
            pl.BlockSpec((block_b, l), lambda ib, iv: (ib, 0)),
            pl.BlockSpec((block_v, d), lambda ib, iv: (iv, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda ib, iv: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_b, d), jnp.float32),
            pltpu.VMEM((block_b, 1), jnp.float32),
        ],
        interpret=interpret,
    )(indices, weights, table)
