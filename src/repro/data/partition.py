"""Geo partitioners: assign vertices to DCs / mesh shards.

``hash_partition`` is the throughput default; ``balanced_bfs_partition``
produces locality-preserving partitions (fewer bridge edges), which is what
makes the layered graph's Layer_0 meaningful.
"""
from __future__ import annotations


import numpy as np

from ..core.graph import build_csr

__all__ = ["hash_partition", "balanced_bfs_partition", "edge_cut"]


def hash_partition(n_nodes: int, n_parts: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_parts, size=n_nodes).astype(np.int32)


def balanced_bfs_partition(
    n_nodes: int,
    src: np.ndarray,
    dst: np.ndarray,
    n_parts: int,
    seed: int = 0,
) -> np.ndarray:
    """Multi-seed BFS growth with per-part capacity (LDG-flavored).

    Grows ``n_parts`` regions from random seeds simultaneously; each step the
    least-loaded part claims the next frontier vertex.  Produces contiguous,
    balanced regions with low edge cut — a stand-in for METIS."""
    rng = np.random.default_rng(seed)
    csr = build_csr(n_nodes, src, dst, symmetrize=True)
    part = np.full(n_nodes, -1, dtype=np.int32)
    cap = int(np.ceil(n_nodes / n_parts))
    loads = np.zeros(n_parts, dtype=np.int64)
    frontiers = [list() for _ in range(n_parts)]
    seeds = rng.choice(n_nodes, size=n_parts, replace=False)
    for p, s in enumerate(seeds):
        part[s] = p
        loads[p] += 1
        frontiers[p].extend(csr.neighbors(int(s)).tolist())
    active = True
    while active:
        active = False
        for p in np.argsort(loads):
            if loads[p] >= cap:
                continue
            f = frontiers[p]
            while f:
                v = f.pop()
                if part[v] < 0:
                    part[v] = p
                    loads[p] += 1
                    frontiers[p].extend(csr.neighbors(int(v)).tolist())
                    active = True
                    break
    # unreachable leftovers -> least loaded
    for v in np.where(part < 0)[0]:
        p = int(np.argmin(loads))
        part[v] = p
        loads[p] += 1
    return part


def edge_cut(part: np.ndarray, src: np.ndarray, dst: np.ndarray) -> float:
    if len(src) == 0:
        return 0.0
    return float((part[src] != part[dst]).mean())
