"""Synthetic graph generators with the structural knobs of the paper's
datasets (LDBC-SNB / UK-2005 / Twitter-2010): power-law degrees, community
structure, geo partitions.  Scaled-down but structure-preserving (DESIGN §9).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.graph import Graph

__all__ = [
    "rmat_graph",
    "community_graph",
    "make_benchmark_graph",
    "diurnal_demand_trace",
]


def _geo_partition(n: int, n_dcs: int, rng: np.random.Generator) -> np.ndarray:
    """Contiguous id-range partition with ragged sizes — mimics regional
    ingest (ids are assigned locally, so ranges are geo-coherent)."""
    cuts = np.sort(rng.choice(np.arange(1, n), size=n_dcs - 1, replace=False))
    bounds = np.concatenate([[0], cuts, [n]])
    part = np.zeros(n, dtype=np.int32)
    for d in range(n_dcs):
        part[bounds[d] : bounds[d + 1]] = d
    return part


def rmat_graph(
    scale: int,
    edge_factor: int = 8,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    n_dcs: int = 5,
) -> Graph:
    """R-MAT generator (power-law, Twitter/UK-like).  n = 2^scale nodes."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        src_bit = (r >= a + b) & (r < a + b + c) | (r >= a + b + c)
        dst_bit = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    mask = src != dst
    src, dst = src[mask], dst[mask]
    # dedupe
    key = src * n + dst
    _, idx = np.unique(key, return_index=True)
    src, dst = src[idx], dst[idx]
    partition = _geo_partition(n, n_dcs, rng)
    sizes = rng.lognormal(mean=np.log(256.0), sigma=0.5, size=n).astype(np.float32)
    esizes = rng.lognormal(mean=np.log(64.0), sigma=0.4, size=len(src)).astype(
        np.float32
    )
    return Graph(
        n_nodes=n,
        src=src.astype(np.int32),
        dst=dst.astype(np.int32),
        node_size=sizes,
        edge_size=esizes,
        partition=partition,
    )


def community_graph(
    n_nodes: int,
    n_communities: int = 8,
    p_in: float = 0.05,
    p_out: float = 0.002,
    seed: int = 0,
    n_dcs: int = 5,
    geo_affinity: float = 0.8,
) -> Graph:
    """Planted-partition graph (SNB-like community structure).

    ``geo_affinity`` biases each community's vertices toward one home DC —
    the generative assumption behind geo partitioning (regional data)."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_communities, size=n_nodes)
    order = np.argsort(comm)
    comm = comm[order]
    src_l, dst_l = [], []
    for ci in range(n_communities):
        members = np.where(comm == ci)[0]
        k = len(members)
        if k < 2:
            continue
        m_in = rng.binomial(k * (k - 1) // 2, p_in)
        s = members[rng.integers(0, k, size=m_in)]
        d = members[rng.integers(0, k, size=m_in)]
        src_l.append(s)
        dst_l.append(d)
    m_out = rng.binomial(n_nodes * (n_nodes - 1) // 2, p_out)
    src_l.append(rng.integers(0, n_nodes, size=m_out))
    dst_l.append(rng.integers(0, n_nodes, size=m_out))
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    mask = src != dst
    src, dst = src[mask], dst[mask]
    key = src.astype(np.int64) * n_nodes + dst
    _, idx = np.unique(key, return_index=True)
    src, dst = src[idx], dst[idx]
    home_dc = rng.integers(0, n_dcs, size=n_communities)
    partition = np.where(
        rng.random(n_nodes) < geo_affinity,
        home_dc[comm],
        rng.integers(0, n_dcs, size=n_nodes),
    )
    sizes = rng.lognormal(mean=np.log(256.0), sigma=0.5, size=n_nodes).astype(
        np.float32
    )
    esizes = rng.lognormal(mean=np.log(64.0), sigma=0.4, size=len(src)).astype(
        np.float32
    )
    return Graph(
        n_nodes=n_nodes,
        src=src.astype(np.int32),
        dst=dst.astype(np.int32),
        node_size=sizes,
        edge_size=esizes,
        partition=partition.astype(np.int32),
    )


def diurnal_demand_trace(
    patterns: Sequence,
    n_dcs: int,
    n_requests: int,
    period_s: float,
    n_periods: int = 2,
    kappa: float = 6.0,
    locality: float = 0.9,
    seed: int = 0,
    deadline_s: Optional[float] = None,
    priority: int = 0,
) -> Tuple[List[Tuple[float, np.ndarray, int, int, Optional[float]]], np.ndarray]:
    """Follow-the-sun request trace: the demand peak sweeps across the DCs.

    Per-origin arrival intensity is a von-Mises bump over the diurnal phase,
    centred at phase ``d / n_dcs`` for DC *d* — as simulated time advances
    one ``period_s``, the traffic peak visits every DC once, in order (the
    workload of the paper's geo-distributed setting: each region is busy
    during its local daytime).  Each request draws a pattern *homed* at its
    origin with probability ``locality`` (home = pattern index mod
    ``n_dcs``), so the hot item set rotates with the peak and placement has
    something to chase.

    Returns ``(rows, handoffs)``:

    * ``rows`` — ``(t, items, origin, priority, deadline_s)`` tuples sorted
      by arrival time, feedable straight into ``StoreClient.submit(...,
      at=t)``;
    * ``handoffs`` — the analytic peak-handoff instants ``period_s * (c +
      (d + 0.5) / n_dcs)``, midway between consecutive DC peaks: the moments
      a reactive placement is stalest and a one-window-ahead forecast pays.
    """
    if n_dcs < 1:
        raise ValueError(f"need at least one DC, got {n_dcs}")
    if not patterns:
        raise ValueError("need at least one pattern")
    rng = np.random.default_rng(seed)
    total_s = float(n_periods) * float(period_s)
    t = np.sort(rng.uniform(0.0, total_s, size=int(n_requests)))
    phase = t / float(period_s)
    # von-Mises-shaped origin weights, peak for DC d at phase d/n_dcs
    ang = 2.0 * np.pi * (phase[:, None] - np.arange(n_dcs)[None, :] / n_dcs)
    w = np.exp(kappa * (np.cos(ang) - 1.0))
    w /= w.sum(axis=1, keepdims=True)
    u = rng.random(len(t))
    origins = (w.cumsum(axis=1) < u[:, None]).sum(axis=1)
    home = np.arange(len(patterns)) % n_dcs
    by_home = [np.where(home == d)[0] for d in range(n_dcs)]
    rows: List[Tuple[float, np.ndarray, int, int, Optional[float]]] = []
    for k in range(len(t)):
        d = int(origins[k])
        pool = by_home[d]
        if len(pool) and rng.random() < locality:
            pi = int(pool[rng.integers(0, len(pool))])
        else:
            pi = int(rng.integers(0, len(patterns)))
        rows.append((float(t[k]), patterns[pi].items, d, priority, deadline_s))
    handoffs = np.array(
        [
            period_s * (c + (d + 0.5) / n_dcs)
            for c in range(int(n_periods))
            for d in range(n_dcs)
        ],
        dtype=np.float64,
    )
    return rows, handoffs


def make_benchmark_graph(name: str, seed: int = 0, n_dcs: int = 5) -> Graph:
    """The three benchmark graph families of Table III, scaled to CPU:

    * ``snb`` — community-structured social network (LDBC-SNB analogue)
    * ``uk``  — high-fanout power-law web graph (UK-2005 analogue)
    * ``tw``  — heavy-tailed follower graph (Twitter-2010 analogue)
    * ``wiki`` — small dense vote graph (WIKI-vote analogue, Fig. 9)
    """
    if name == "snb":
        return community_graph(4096, n_communities=12, seed=seed, n_dcs=n_dcs)
    if name == "uk":
        return rmat_graph(12, edge_factor=12, a=0.65, b=0.15, c=0.15, seed=seed, n_dcs=n_dcs)
    if name == "tw":
        return rmat_graph(12, edge_factor=16, a=0.57, b=0.19, c=0.19, seed=seed, n_dcs=n_dcs)
    if name == "wiki":
        return rmat_graph(9, edge_factor=14, seed=seed, n_dcs=min(n_dcs, 4))
    raise ValueError(f"unknown benchmark graph {name!r}")
