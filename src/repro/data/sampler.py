"""Fanout neighbor sampler for sampled GNN training (``minibatch_lg``).

GraphSAGE-style layered sampling over CSR: for each seed node draw up to
``fanout[i]`` neighbors at hop i, emitting a padded block the JAX train step
consumes with static shapes.  Runs host-side (data pipeline), NumPy only.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from ..core.graph import CSR

__all__ = ["SampledBlock", "NeighborSampler"]


@dataclasses.dataclass
class SampledBlock:
    """Padded k-hop block. Shapes are static given (batch, fanouts).

    node_ids:  [n_max] global ids of all sampled nodes (padded with 0)
    node_mask: [n_max] validity
    edge_src/edge_dst: [e_max] indices *into node_ids* (padded self-loops)
    edge_mask: [e_max]
    seeds:     [batch] positions of the seed nodes in node_ids (0..batch-1)
    """

    node_ids: np.ndarray
    node_mask: np.ndarray
    edge_src: np.ndarray
    edge_dst: np.ndarray
    edge_mask: np.ndarray
    seeds: np.ndarray

    @property
    def n_max(self) -> int:
        return int(self.node_ids.shape[0])

    @property
    def e_max(self) -> int:
        return int(self.edge_src.shape[0])


def block_capacity(batch: int, fanouts: Sequence[int]) -> Tuple[int, int]:
    """Static (n_max, e_max) for a given batch + fanout schedule."""
    n_max = batch
    e_max = 0
    frontier = batch
    for f in fanouts:
        e_max += frontier * f
        frontier = frontier * f
        n_max += frontier
    return n_max, e_max


class NeighborSampler:
    def __init__(self, csr: CSR, fanouts: Sequence[int], seed: int = 0) -> None:
        self.csr = csr
        self.fanouts = list(fanouts)
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        batch = len(seeds)
        n_max, e_max = block_capacity(batch, self.fanouts)
        node_ids = np.zeros(n_max, dtype=np.int64)
        node_mask = np.zeros(n_max, dtype=bool)
        edge_src = np.zeros(e_max, dtype=np.int32)
        edge_dst = np.zeros(e_max, dtype=np.int32)
        edge_mask = np.zeros(e_max, dtype=bool)

        node_ids[:batch] = seeds
        node_mask[:batch] = True
        pos = {int(v): i for i, v in enumerate(seeds)}
        n_ptr = batch
        e_ptr = 0
        frontier = list(range(batch))  # positions of current frontier
        for f in self.fanouts:
            nxt: List[int] = []
            for fp in frontier:
                u = int(node_ids[fp])
                lo, hi = int(self.csr.indptr[u]), int(self.csr.indptr[u + 1])
                deg = hi - lo
                if deg == 0:
                    continue
                k = min(f, deg)
                sel = self.rng.choice(deg, size=k, replace=False)
                for s in sel:
                    v = int(self.csr.indices[lo + s])
                    if v not in pos:
                        pos[v] = n_ptr
                        node_ids[n_ptr] = v
                        node_mask[n_ptr] = True
                        nxt.append(n_ptr)
                        n_ptr += 1
                    # message edge: neighbor -> frontier node
                    edge_src[e_ptr] = pos[v]
                    edge_dst[e_ptr] = fp
                    edge_mask[e_ptr] = True
                    e_ptr += 1
            frontier = nxt
            if not frontier:
                break
        return SampledBlock(
            node_ids=node_ids,
            node_mask=node_mask,
            edge_src=edge_src,
            edge_dst=edge_dst,
            edge_mask=edge_mask,
            seeds=np.arange(batch, dtype=np.int32),
        )
