from . import partition, pipeline, sampler, synthetic  # noqa: F401
