"""Host-side data pipelines: synthetic token / recsys / GNN batch streams
with double-buffered prefetch and per-shard feeding for multi-host launches.

Everything is deterministic given (seed, step) so a restarted job resumes the
exact stream position from the checkpointed step — a fault-tolerance
requirement (no data skew/repeat after restart).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np

__all__ = ["TokenPipeline", "RecsysPipeline", "Prefetcher", "shard_batch"]


class TokenPipeline:
    """Synthetic LM token stream (Zipf unigram mix) with stateless indexing:
    batch(step) is a pure function of (seed, step)."""

    def __init__(
        self, vocab_size: int, batch: int, seq_len: int, seed: int = 0
    ) -> None:
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        z = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        tokens = np.minimum(z, self.vocab_size - 1).astype(np.int32)
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class RecsysPipeline:
    """Synthetic behavior-sequence batches for BST: item/category histories
    with Zipf-skewed item popularity (the heat skew GeoLayer exploits)."""

    def __init__(
        self,
        n_items: int,
        n_cats: int,
        batch: int,
        seq_len: int,
        seed: int = 0,
    ) -> None:
        self.n_items = n_items
        self.n_cats = n_cats
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        z = rng.zipf(1.2, size=(self.batch, self.seq_len + 1))
        items = np.minimum(z, self.n_items - 1).astype(np.int32)
        cats = (items % self.n_cats).astype(np.int32)
        clicks = (rng.random(self.batch) < 0.3).astype(np.float32)
        return {
            "hist_items": items[:, :-1],
            "hist_cats": cats[:, :-1],
            "target_item": items[:, -1],
            "target_cat": cats[:, -1],
            "label": clicks,
        }


class Prefetcher:
    """Double-buffered background prefetch of any ``batch_at(step)`` source."""

    def __init__(self, source, start_step: int = 0, depth: int = 2) -> None:
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self) -> None:
        s = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(s)
            self.q.put((s, batch))
            s += 1

    def next(self):
        return self.q.get()

    def stop(self) -> None:
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def shard_batch(
    batch: Dict[str, np.ndarray], shard_index: int, n_shards: int
) -> Dict[str, np.ndarray]:
    """Slice a global batch into this host's shard along axis 0."""
    out = {}
    for k, v in batch.items():
        per = v.shape[0] // n_shards
        out[k] = v[shard_index * per : (shard_index + 1) * per]
    return out
