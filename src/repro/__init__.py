"""repro: GeoLayer (geo-distributed graph store) on JAX/TPU + arch zoo."""
