"""Fault-tolerant checkpointing: atomic sharded saves, async writer,
manifest-driven auto-resume, elastic resharding hooks.

Layout:
    <dir>/step_<N>/shard_<proc>.npz     flattened param+opt leaves
    <dir>/step_<N>/MANIFEST.json        step, leaf paths, config hash, done
A checkpoint is valid iff MANIFEST.json exists and ``done`` is true —
written last after all shards fsync (atomic tmp+rename), so a crash mid-save
never corrupts the restore path.  ``latest_step`` skips incomplete saves.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager", "flatten_tree", "unflatten_tree"]


def flatten_tree(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def unflatten_tree(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        leaves.append(np.asarray(arr).astype(leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        config_hash: str = "",
        keep: int = 3,
        async_save: bool = True,
    ) -> None:
        self.dir = directory
        self.config_hash = config_hash
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, block: bool = False) -> None:
        flat = flatten_tree(state)  # host copy happens here (device-safe)
        if self.async_save and not block:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, flat), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        t0 = time.perf_counter()  # durations: monotonic, never time.time()
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "shard_0.npz"), **flat)
        manifest = {
            "step": step,
            "n_leaves": len(flat),
            "config_hash": self.config_hash,
            "time": time.time(),  # wall timestamp only — NOT a duration
            "save_s": round(time.perf_counter() - t0, 6),
            "done": True,
        }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            mf = os.path.join(self.dir, name, "MANIFEST.json")
            if not os.path.exists(mf):
                continue
            try:
                with open(mf) as f:
                    m = json.load(f)
                if m.get("done"):
                    steps.append(int(m["step"]))
            except (json.JSONDecodeError, KeyError, ValueError):
                continue  # torn manifest -> treat as invalid
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any) -> Any:
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "MANIFEST.json")) as f:
            m = json.load(f)
        if self.config_hash and m.get("config_hash") not in ("", self.config_hash):
            raise ValueError(
                f"checkpoint config hash {m.get('config_hash')!r} != "
                f"current {self.config_hash!r}"
            )
        flat = dict(np.load(os.path.join(path, "shard_0.npz")))
        return unflatten_tree(template, flat)

    def restore_latest(self, template: Any) -> Tuple[Optional[int], Any]:
        step = self.latest_step()
        if step is None:
            return None, template
        return step, self.restore(step, template)


def config_hash(obj: Any) -> str:
    return hashlib.sha1(repr(obj).encode()).hexdigest()[:12]
