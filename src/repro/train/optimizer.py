"""AdamW + schedules as pure pytree transforms (no optax dependency).

State layout mirrors params (mu/nu per leaf), so the same PartitionSpecs
shard the optimizer state — required for the dry-run memory analysis to
reflect real training HBM (params + 2x moments + grads).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "adamw_init", "adamw_update", "cosine_lr", "global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
    return {"mu": zeros(params), "nu": zeros(params), "step": jnp.zeros((), jnp.int32)}


def cosine_lr(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(
    grads: Any, state: Dict[str, Any], params: Any, cfg: OptConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(step, cfg)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32) * scale
        mu2 = b1 * mu + (1 - b1) * g
        nu2 = b2 * nu + (1 - b2) * g * g
        mu_hat = mu2 / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype), mu2, nu2

    out = jax.tree_util.tree_map(upd, grads, state["mu"], state["nu"], params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
