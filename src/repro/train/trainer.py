"""Generic fault-tolerant training loop.

Features (DESIGN §7): microbatch gradient accumulation (compute/comm
overlap: the cross-replica reduction happens once per accumulated step),
optional int8/top-k compressed cross-pod gradient reduction, async atomic
checkpoints with auto-resume, failure injection -> elastic remesh ->
reshard -> continue, straggler-aware pipeline hooks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed import compression, fault
from .checkpoint import CheckpointManager, config_hash
from .optimizer import OptConfig, adamw_init, adamw_update

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    microbatch: int = 1  # gradient-accumulation chunks per step
    grad_compression: Optional[str] = None  # None | "int8" | "topk"
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)


class Trainer:
    def __init__(
        self,
        loss_fn: Callable[[Any, Dict[str, jnp.ndarray]], Tuple[jnp.ndarray, Dict]],
        params: Any,
        cfg: TrainerConfig,
        failure_sim: Optional[fault.FailureSimulator] = None,
    ) -> None:
        self.loss_fn = loss_fn
        self.cfg = cfg
        self.params = params
        self.opt_state = adamw_init(params)
        self.comp_state = (
            compression.init_compression_state(params)
            if cfg.grad_compression
            else None
        )
        self.failure_sim = failure_sim
        # hash covers the state-compatibility surface only (schedule length
        # may legitimately change when extending a run)
        o = cfg.opt
        self.ckpt = CheckpointManager(
            cfg.ckpt_dir,
            config_hash=config_hash(
                (o.lr, o.b1, o.b2, o.eps, o.weight_decay, o.clip_norm, cfg.microbatch)
            ),
        )
        self.metrics: Dict[str, list] = {"loss": [], "step_time": []}
        self._update = jax.jit(self._update_fn)

    # ------------------------------------------------------------- step fns
    def _grads(self, params, batch):
        (loss, aux), grads = jax.value_and_grad(self.loss_fn, has_aux=True)(
            params, batch
        )
        return loss, grads

    def _update_fn(self, params, opt_state, comp_state, batch):
        mb = self.cfg.microbatch
        if mb > 1:
            # split batch into microbatches, accumulate grads (overlap: the
            # optimizer + any cross-pod reduction runs once per step)
            def mb_slice(i, x):
                per = x.shape[0] // mb
                return jax.lax.dynamic_slice_in_dim(x, i * per, per, axis=0)

            def body(carry, i):
                loss_acc, grads_acc = carry
                sub = jax.tree_util.tree_map(lambda x: mb_slice(i, x), batch)
                loss, grads = self._grads(params, sub)
                grads_acc = jax.tree_util.tree_map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zero), jnp.arange(mb)
            )
            loss = loss / mb
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
        else:
            loss, grads = self._grads(params, batch)

        if self.cfg.grad_compression and comp_state is not None:
            # error-feedback compression (the psum itself is implicit in
            # sharded training; the EF quantization models the wire format)
            pairs = jax.tree_util.tree_map(
                lambda g, r: compression.apply_error_feedback(
                    g, r, self.cfg.grad_compression
                ),
                grads,
                comp_state,
            )
            grads = jax.tree_util.tree_map(
                lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
            )
            comp_state = jax.tree_util.tree_map(
                lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
            )
        new_params, new_opt, info = adamw_update(grads, opt_state, params, self.cfg.opt)
        return new_params, new_opt, comp_state, loss, info

    # ---------------------------------------------------------------- loop
    def run(self, data: Iterator[Dict[str, np.ndarray]], resume: bool = True) -> Dict:
        start = 0
        if resume:
            step, restored = self.ckpt.restore_latest(
                {"params": self.params, "opt": self.opt_state}
            )
            if step is not None:
                self.params = restored["params"]
                self.opt_state = restored["opt"]
                start = step
        it = iter(data)
        for step in range(start, self.cfg.total_steps):
            if self.failure_sim is not None:
                ev = self.failure_sim.check(step)
                if ev is not None:
                    # node failure: restore from last checkpoint, remesh
                    self.recover_from_failure(ev)
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            self.params, self.opt_state, self.comp_state, loss, info = self._update(
                self.params, self.opt_state, self.comp_state, batch
            )
            dt = time.perf_counter() - t0
            self.metrics["loss"].append(float(loss))
            self.metrics["step_time"].append(dt)
            if (step + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save(step + 1, {"params": self.params, "opt": self.opt_state})
        self.ckpt.wait()  # drain any in-flight periodic save first
        self.ckpt.save(
            self.cfg.total_steps,
            {"params": self.params, "opt": self.opt_state},
            block=True,
        )
        return self.metrics

    def recover_from_failure(self, ev: fault.FailureEvent) -> None:
        """Checkpoint-restore recovery path.  On a real cluster this runs on
        the surviving hosts with an elastic remesh (fault.elastic_mesh_shape)
        before restoring; with one CPU device the restore path still runs."""
        self.ckpt.wait()  # quiesce in-flight async saves before restoring
        step, restored = self.ckpt.restore_latest(
            {"params": self.params, "opt": self.opt_state}
        )
        if step is not None:
            self.params = restored["params"]
            self.opt_state = restored["opt"]
        n_dev = jax.device_count() - ev.n_failed
        shape, axes = fault.elastic_mesh_shape(max(n_dev, 1))
        self.metrics.setdefault("recoveries", []).append(
            {"at_step": ev.step, "restored_step": step, "new_mesh": (shape, axes)}
        )
