"""Runtime invariant sanitizer: a clean store passes every differential
check, and each deliberately-injected corruption — route-index drift, heat
aliasing break, journal re-key, metrics type clash — raises
:class:`SanitizerError` naming the violated invariant.
"""
import types

import pytest

from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.store import GeoGraphStore
from repro.data.synthetic import community_graph
from repro.debug.sanitize import (
    SanitizerError,
    StoreSanitizer,
    attach_sanitizer,
    maybe_attach,
    sanitize_enabled,
)
from repro.demand import ODDemandLayer
from repro.obs.metrics import MetricsRegistry


def _fresh_store(seed=0, n_vertices=400, n_patterns=24):
    g = community_graph(
        n_vertices, n_communities=8, p_in=0.04, p_out=0.001, seed=seed, n_dcs=5
    )
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, n_patterns, seed=seed + 1, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return GeoGraphStore(
        g,
        env,
        wl,
        config=PlacementConfig(precache=False, dhd_steps=4),
        demand_window_s=6.0,
    )


@pytest.fixture(scope="module")
def store():
    return _fresh_store()


# ----------------------------------------------------------------- clean run
def test_clean_store_passes_all_checks(store):
    s = StoreSanitizer(store)
    assert s.check() is True
    assert s.checks_run == 1


# ------------------------------------------------------ injected corruptions
def test_route_index_corruption_is_caught(store):
    """Acceptance criterion: flip one incremental-index entry and the
    differential rebuild check must refuse it."""
    idx = store.route_index
    assert idx is not None
    old = int(idx.nearest[0, 0])
    idx.nearest[0, 0] = (old + 1) % store.env.n_dcs
    try:
        with pytest.raises(SanitizerError, match="route-index divergence"):
            StoreSanitizer(store).check()
    finally:
        idx.nearest[0, 0] = old
    StoreSanitizer(store).check()  # restored → clean again


def test_heat_aliasing_break_is_caught(store):
    dc = next(iter(store.caches))
    cache = store.caches[dc]
    orig = cache.demand
    cache.demand = ODDemandLayer(store.g.n_items, 1)  # forked heat table
    try:
        with pytest.raises(SanitizerError, match="heat aliasing"):
            StoreSanitizer(store).check()
    finally:
        cache.demand = orig
    StoreSanitizer(store).check()


def test_journal_uid_copy_is_caught(store):
    journal = store._placement_journal
    orig = journal.item_uid
    journal.item_uid = store._item_uid.copy()  # equal values, broken identity
    try:
        with pytest.raises(SanitizerError, match="journal digest"):
            StoreSanitizer(store).check()
    finally:
        journal.item_uid = orig
    StoreSanitizer(store).check()


def test_metrics_type_clash_is_caught(store):
    r1 = MetricsRegistry(enabled=True)
    r2 = MetricsRegistry(enabled=True)
    r1.counter("sanitize.clash").inc()
    r2.histogram("sanitize.clash").observe(1.0)
    store.shard_registries = [r1, r2]
    try:
        with pytest.raises(SanitizerError, match="metrics merge"):
            StoreSanitizer(store).check()
    finally:
        del store.shard_registries
    StoreSanitizer(store).check()


# -------------------------------------------------------- attach & cadence
def _dummy_store():
    calls = []
    store = types.SimpleNamespace(calls=calls)
    store.apply_updates = lambda *a, **k: calls.append(("apply_updates", a))
    store.compact = lambda *a, **k: calls.append(("compact", a))
    return store


def test_attach_wraps_mutators_and_checks_on_cadence():
    store = _dummy_store()
    s = attach_sanitizer(store, every=2)
    store.apply_updates(1)
    assert s.ops_seen == 1 and s.checks_run == 0
    store.compact()
    assert s.ops_seen == 2 and s.checks_run == 1
    assert store.calls == [("apply_updates", (1,)), ("compact", ())]


def test_attach_is_idempotent():
    store = _dummy_store()
    s1 = attach_sanitizer(store)
    wrapped = store.apply_updates
    s2 = attach_sanitizer(store)
    assert s1 is s2
    assert store.apply_updates is wrapped  # not double-wrapped


def test_maybe_attach_respects_env(monkeypatch):
    store = _dummy_store()
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitize_enabled()
    assert maybe_attach(store) is None
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert maybe_attach(store) is None
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_enabled()
    assert maybe_attach(store) is not None


def test_sanitizer_survives_store_ops(store):
    """End-to-end: wrapped real-store mutators run checks that pass."""
    s = attach_sanitizer(store, every=1)
    before = s.checks_run
    pats = generate_khop_patterns(
        store.g, build_csr(store.g.n_nodes, store.g.src, store.g.dst, symmetrize=True),
        4, seed=7, n_dcs=store.env.n_dcs,
    )
    store.serve_batch([(p, 0) for p in pats[:2]])
    store.maintain()
    assert s.checks_run > before
