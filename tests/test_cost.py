import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
    from _hypothesis_stub import given, settings, st

from repro.core.cost import (
    PlacementState,
    association_penalty,
    read_cost,
    storage_cost,
    write_cost,
)
from repro.core.latency import make_paper_env
from repro.core.patterns import Pattern


def _mini(seed=0, n_items=10, D=3):
    rng = np.random.default_rng(seed)
    env = make_paper_env()
    sizes = rng.random(n_items).astype(np.float32) * 100
    r = rng.random((n_items, env.n_dcs)) * (rng.random((n_items, env.n_dcs)) < 0.4)
    w = rng.random((n_items, env.n_dcs)) * 0.2 * (r > 0)
    st_ = PlacementState.empty(n_items, env.n_dcs)
    prim = rng.integers(0, env.n_dcs, n_items)
    st_.delta[np.arange(n_items), prim] = True
    st_.route_nearest(env)
    return env, sizes, r, w, st_


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_costs_nonnegative(seed):
    env, sizes, r, w, state = _mini(seed)
    assert storage_cost(state, sizes, env) >= 0
    assert read_cost(state, r, sizes, env) >= 0
    assert write_cost(state, w, sizes, env) >= 0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_more_replicas_monotone(seed):
    """Adding a replica: storage+write up, read down (nearest routing)."""
    env, sizes, r, w, state = _mini(seed)
    s0 = storage_cost(state, sizes, env)
    r0 = read_cost(state, r, sizes, env)
    w0 = write_cost(state, w, sizes, env)
    state2 = state.copy()
    state2.delta[:, 0] = True  # replicate everything at DC 0
    state2.route_nearest(env)
    assert storage_cost(state2, sizes, env) >= s0
    assert write_cost(state2, w, sizes, env) >= w0
    assert read_cost(state2, r, sizes, env) <= r0 + 1e-12


def test_full_local_pattern_no_assoc_penalty():
    env = make_paper_env()
    n = 4
    sizes = np.ones(n, np.float32)
    state = PlacementState.empty(n, env.n_dcs)
    state.delta[:, 2] = True
    state.route_nearest(env)
    p = Pattern(0, np.arange(n), r_py=np.eye(env.n_dcs)[2] * 5, w_py=np.zeros(env.n_dcs))
    # all items at the requesting DC -> sum(rho)=1 -> zero penalty (Eq. 5)
    assert association_penalty([p], state, sizes, env) == 0.0


def test_assoc_penalty_grows_with_spread():
    env = make_paper_env()
    n = 4
    sizes = np.ones(n, np.float32)
    st1 = PlacementState.empty(n, env.n_dcs)
    st1.delta[:, 1] = True
    st1.route_nearest(env)
    st2 = PlacementState.empty(n, env.n_dcs)
    for i in range(n):
        st2.delta[i, i % env.n_dcs] = True
    st2.route_nearest(env)
    p = Pattern(0, np.arange(n), r_py=np.eye(env.n_dcs)[0] * 5, w_py=np.zeros(env.n_dcs))
    assert association_penalty([p], st2, sizes, env) > association_penalty(
        [p], st1, sizes, env
    )
