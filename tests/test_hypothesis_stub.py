"""The deterministic hypothesis fallback itself (always exercised, even when
real hypothesis is installed — the stub must keep working in environments
that cannot pip install)."""
import pytest

from _hypothesis_stub import given, settings, st


@settings(max_examples=7)
@given(st.integers(0, 10), st.floats(-1.0, 1.0))
def test_stub_draws_in_range(n, x):
    assert 0 <= n <= 10
    assert -1.0 <= x <= 1.0


def test_stub_example_count_and_determinism():
    seen = []

    @settings(max_examples=5)
    @given(st.integers(0, 1000))
    def collect(v):
        seen.append(v)

    collect()
    first = list(seen)
    seen.clear()
    collect()
    assert seen == first  # seeded -> reproducible
    assert len(seen) == 5


@pytest.fixture
def myfix():
    return 42


@settings(max_examples=3)
@given(st.integers(0, 10))
def test_stub_fixture_plus_strategy(myfix, seed):
    """Fixtures (passed by keyword by pytest) must not collide with drawn
    values; like hypothesis, strategies fill the rightmost parameters."""
    assert myfix == 42
    assert 0 <= seed <= 10
