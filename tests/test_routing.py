import numpy as np

from repro.core.routing import route_offline, route_online


def test_online_routing_complete(small_setup, small_store):
    g, env, csr, wl, pats = small_setup
    store = small_store
    for p in pats[:10]:
        origin = int(np.argmax(p.r_py))
        res = route_online(store.lg, store.state, p.items, origin)
        assert res.n_missing == 0  # all items resolved
        # every served item really has a replica at its serving DC
        served = res.served_by
        for x, d in zip(p.items, served):
            assert store.state.delta[x, d]
        assert res.latency_s >= 0


def test_online_prefers_local(small_setup, small_store):
    g, env, csr, wl, pats = small_setup
    store = small_store
    p = pats[0]
    origin = int(np.argmax(p.r_py))
    res = route_online(store.lg, store.state, p.items, origin)
    local_avail = store.state.delta[p.items, origin]
    assert (res.served_by[local_avail] == origin).all()


def test_offline_layout_covers(small_setup, small_store):
    g, env, csr, wl, pats = small_setup
    store = small_store
    req = np.arange(g.n_nodes)
    plan = route_offline(store.lg, store.state, req)
    assert (plan.item_site[req] >= 0).all()
    assert set(np.unique(plan.item_site[req])) <= set(plan.sites.tolist())
    assert 1 <= len(plan.sites) <= env.n_dcs


def test_offline_migration_threshold(small_setup, small_store):
    g, env, csr, wl, pats = small_setup
    store = small_store
    # more iterations -> larger message proxy -> fewer/equal retained sites
    p1 = route_offline(store.lg, store.state, np.arange(g.n_nodes), n_iters=1)
    p2 = route_offline(store.lg, store.state, np.arange(g.n_nodes), n_iters=500)
    assert len(p2.sites) <= len(p1.sites)
