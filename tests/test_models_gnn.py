import jax
import jax.numpy as jnp
import numpy as np

from repro.models.gnn.egnn import egnn_forward, egnn_init
from repro.models.gnn.equiformer_v2 import EqV2Spec, eqv2_forward, eqv2_init
from repro.models.gnn.meshgraphnet import mgn_forward, mgn_init
from repro.models.gnn.schnet import schnet_forward, schnet_init


def _batch(seed=0, n=24, e=64, d=8):
    rng = np.random.default_rng(seed)
    return dict(
        x=jnp.asarray(rng.standard_normal((n, d)), jnp.float32),
        pos=jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        edge_src=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        edge_dst=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        edge_mask=jnp.ones((e,), bool),
        edge_attr=jnp.asarray(rng.standard_normal((e, 4)), jnp.float32),
    )


def _rot(seed=1):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    return q


def test_egnn_equivariance():
    b = _batch()
    p = egnn_init(jax.random.PRNGKey(0), 8, 16, 3, d_edge=4)
    h1, x1 = egnn_forward(p, b, 3)
    r = _rot()
    b2 = dict(b, pos=jnp.asarray(np.asarray(b["pos"]) @ r.T, jnp.float32))
    h2, x2 = egnn_forward(p, b2, 3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=5e-3)
    np.testing.assert_allclose(np.asarray(x1) @ r.T, np.asarray(x2), atol=5e-3)


def test_schnet_invariance():
    b = _batch()
    b["x"] = jnp.asarray(np.random.default_rng(0).integers(0, 8, 24))
    p = schnet_init(jax.random.PRNGKey(0), 8, 16, 2, 16)
    o1 = schnet_forward(p, b, 2, 16, 5.0)
    r = _rot()
    b2 = dict(b, pos=jnp.asarray(np.asarray(b["pos"]) @ r.T, jnp.float32))
    o2 = schnet_forward(p, b2, 2, 16, 5.0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_eqv2_invariance_lmax6():
    rng = np.random.default_rng(0)
    spec = EqV2Spec(n_layers=2, channels=16, l_max=6, m_max=2, n_heads=4,
                    n_rbf=8, n_species=10)
    p = eqv2_init(jax.random.PRNGKey(0), spec)
    n, e = 16, 48
    b = dict(
        x=jnp.asarray(rng.integers(0, 10, n)),
        pos=jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        edge_src=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        edge_dst=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        edge_mask=jnp.ones((e,), bool),
    )
    o1 = eqv2_forward(p, b, spec)
    r = _rot(3)
    b2 = dict(b, pos=jnp.asarray(np.asarray(b["pos"]) @ r.T, jnp.float32))
    o2 = eqv2_forward(p, b2, spec)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_eqv2_chunked_consistency():
    rng = np.random.default_rng(1)
    spec = EqV2Spec(n_layers=2, channels=8, l_max=3, m_max=2, n_heads=2,
                    n_rbf=8, n_species=10)
    p = eqv2_init(jax.random.PRNGKey(0), spec)
    n, e = 16, 64
    b = dict(
        x=jnp.asarray(rng.integers(0, 10, n)),
        pos=jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        edge_src=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        edge_dst=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        edge_mask=jnp.ones((e,), bool),
    )
    o1 = eqv2_forward(p, b, spec)
    o2 = eqv2_forward(p, b, spec, edge_chunks=8)
    o3 = eqv2_forward(p, b, spec, unroll_layers=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=1e-5)


def test_mgn_masking():
    """Masked edges contribute nothing."""
    b = _batch()
    p = mgn_init(jax.random.PRNGKey(0), 8, 4, 16, 3, 2)
    def fwd(batch):
        pos = batch["pos"]
        rel = pos[batch["edge_dst"]] - pos[batch["edge_src"]]
        nrm = jnp.linalg.norm(rel, axis=-1, keepdims=True)
        return mgn_forward(p, dict(batch, edge_attr=jnp.concatenate([rel, nrm], -1)))
    o1 = fwd(b)
    # zero out half the edges via mask vs physically removing them
    e = b["edge_src"].shape[0]
    mask = jnp.asarray(np.arange(e) < e // 2)
    o2 = fwd(dict(b, edge_mask=mask))
    b3 = dict(b, edge_src=b["edge_src"][: e // 2], edge_dst=b["edge_dst"][: e // 2],
              edge_mask=jnp.ones((e // 2,), bool))
    o3 = fwd(b3)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o3), atol=1e-4)
    assert not np.allclose(np.asarray(o1), np.asarray(o2))
