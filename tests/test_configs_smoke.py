"""Per-arch smoke tests (deliverable f): reduced config, one forward/train
step on CPU, asserting output shapes + finite values."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_cells, get_arch, list_archs

ARCHS = list_archs()


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_forward_step(name):
    arch = get_arch(name)
    key = jax.random.PRNGKey(0)
    params = arch.smoke_params(key)
    batch = arch.smoke_batch(jax.random.PRNGKey(1))
    loss = jax.jit(arch.smoke_loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name} produced non-finite loss"


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_train_step_decreases(name):
    """One gradient step strictly reduces loss on the same batch."""
    arch = get_arch(name)
    params = arch.smoke_params(jax.random.PRNGKey(0))
    batch = arch.smoke_batch(jax.random.PRNGKey(1))
    loss_fn = arch.smoke_loss
    g = jax.jit(jax.grad(loss_fn))(params, batch)
    lr = 1e-2
    params2 = jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)
    l0 = float(jax.jit(loss_fn)(params, batch))
    l1 = float(jax.jit(loss_fn)(params2, batch))
    assert np.isfinite(l1)
    assert l1 < l0 + 1e-6, f"{name}: {l0} -> {l1}"


def test_cell_enumeration():
    cells = all_cells()
    assert len(cells) == 40, "assignment: 40 (arch x shape) cells"
    skipped = [c for c in cells if c.skip]
    # long_500k skipped exactly for the 4 pure full-attention LM archs
    assert sorted(c.arch for c in skipped) == [
        "deepseek-v2-lite-16b", "granite-moe-3b-a800m", "qwen3-0.6b", "yi-6b",
    ]
    assert all(c.shape == "long_500k" for c in skipped)


def test_configs_match_assignment():
    a = get_arch("deepseek-v2-lite-16b").cfg
    assert (a.n_layers, a.d_model, a.n_heads, a.vocab_size) == (27, 2048, 16, 102400)
    assert a.moe and a.n_experts == 64 and a.top_k == 6 and a.n_shared_experts == 2
    assert a.mla and a.kv_lora_rank == 512
    g = get_arch("gemma3-27b").cfg
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff) == (62, 5376, 32, 16, 21504)
    assert g.local_global_ratio == 5 and g.sliding_window == 1024
    y = get_arch("yi-6b").cfg
    assert (y.n_layers, y.d_model, y.n_heads, y.n_kv_heads, y.d_ff, y.vocab_size) == (
        32, 4096, 32, 4, 11008, 64000)
    q = get_arch("qwen3-0.6b").cfg
    assert q.qk_norm and (q.n_layers, q.d_model, q.vocab_size) == (28, 1024, 151936)
    gr = get_arch("granite-moe-3b-a800m").cfg
    # 40 active experts, padded to 48 for 16-way EP (DESIGN §9)
    assert gr.moe and gr.n_experts == 48 and gr.n_experts_active == 40
    assert gr.top_k == 8 and gr.d_ff_expert == 512
    from repro.configs.bst import ARCH as BST
    assert BST.spec.embed_dim == 32 and BST.spec.seq_len == 20
    assert BST.spec.n_heads == 8 and BST.spec.mlp_dims == (1024, 512, 256)
