"""Link-granular migration pipeline (bandwidth-aware transfer scheduling).

Differential bars:
  * the vectorized planner == the per-item legacy planner, move for move,
    on seeded churn workloads (same candidates, benefits, greedy order);
  * no scheduled wave loads any (src, dst) link beyond its byte budget
    ``env.link_budget_bytes(window_s)`` (single oversized transfers are
    isolated and flagged);
  * wave-ordered application keeps the RouteIndex row-identical to a full
    ``route_nearest`` re-derivation after *every* wave, so a frontend can
    serve between waves.
"""
import math

import numpy as np
import pytest

from repro.core.graph import Graph, build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.routing import route_online
from repro.core.store import GeoGraphStore
from repro.serve import AdmissionConfig, AdmissionController, StoreClient
from repro.streaming import DeltaGraph, random_churn_batch
from repro.streaming.delta_dhd import StreamingHeat
from repro.streaming.migration import (
    MigrationPlan,
    Move,
    plan_migrations,
    schedule_transfers,
)


def _random_graph(n, m, n_dcs, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    return Graph.from_edges(
        n, src[keep], dst[keep], partition=rng.integers(0, n_dcs, n)
    )


def _churned_store(seed, n_batches=3, rate=0.02):
    g = _random_graph(220, 1400, 4, seed)
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, 24, seed=seed + 1, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    store = GeoGraphStore(
        g, env, wl, config=PlacementConfig(precache=False, dhd_steps=4)
    )
    rng = np.random.default_rng(seed + 100)
    store._delta_graph = DeltaGraph(store.g)
    for _ in range(n_batches):
        store.apply_updates(random_churn_batch(store._delta_graph, rate, rng))
    return store


def _item_heat(store):
    """Mirror of flush_migrations' heat derivation (planning inputs only)."""
    if store._heat is None or store._heat.heat is None:
        store._heat = StreamingHeat()
        alive_e, w_e, q = store._heat_inputs()
        store._heat.rebuild(
            store.g.n_nodes, store.g.src[alive_e], store.g.dst[alive_e], w_e, q
        )
    vheat = store._heat.vertex_heat
    eheat = 0.5 * (vheat[store.g.src] + vheat[store.g.dst])
    if store._delta_graph is not None:
        alive = np.concatenate(
            [store._delta_graph.node_alive, store._delta_graph.edge_alive]
        )
    else:
        alive = np.ones(store.g.n_items, dtype=bool)
    return np.concatenate([vheat, eheat]) * alive, alive


def _plan_pair(store, budget_frac=0.05, **kw):
    heat, alive = _item_heat(store)
    budget = budget_frac * float(store.g.item_size().sum())
    args = (
        store.g, store.env, store.state,
        store.workload.r_xy, store.workload.w_xy, heat, budget,
    )
    return (
        plan_migrations(*args, item_alive=alive, vectorized=True, **kw),
        plan_migrations(*args, item_alive=alive, vectorized=False, **kw),
    )


# ------------------------------------------------------- planner differential
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_vectorized_planner_matches_legacy(seed):
    """Move-for-move identity on seeded churn workloads, including the
    greedy order, benefits, sources, and every counter."""
    store = _churned_store(seed)
    for kw in (
        dict(theta_add=0.5, theta_drop=0.15),
        dict(theta_add=0.8, theta_drop=0.05),
        dict(theta_add=0.3, theta_drop=0.30, max_moves=64),
    ):
        pv, pl = _plan_pair(store, **kw)
        assert pv.n_candidates == pl.n_candidates
        assert pv.skipped_budget == pl.skipped_budget
        assert pv.wan_bytes == pl.wan_bytes
        assert pv.est_benefit == pl.est_benefit
        assert len(pv.moves) == len(pl.moves)
        for a, b in zip(pv.moves, pl.moves):
            assert (a.item, a.dc, a.kind, a.src) == (b.item, b.dc, b.kind, b.src)
            assert a.benefit == b.benefit  # bit-identical association order
            assert a.wan_bytes == b.wan_bytes


def test_planner_budget_and_sources():
    store = _churned_store(3)
    pv, _ = _plan_pair(store, theta_add=0.4, theta_drop=0.15)
    budget = 0.05 * float(store.g.item_size().sum())
    assert pv.wan_bytes <= budget + 1e-9
    primary = np.concatenate(
        [store.g.partition, store.g.partition[store.g.src]]
    ).astype(np.int64)
    for m in pv.moves:
        if m.kind != "add":
            assert m.src == -1
            continue
        # nearest-replica source: the route entry the saving was priced on
        cur = int(store.state.route[m.item, m.dc])
        assert m.src == (cur if cur >= 0 else int(primary[m.item]))
        assert m.src != m.dc
    # zero budget admits no adds on either path
    z_v, z_l = [
        plan_migrations(
            store.g, store.env, store.state, store.workload.r_xy,
            store.workload.w_xy, _item_heat(store)[0], 0.0,
            item_alive=_item_heat(store)[1], vectorized=v,
        )
        for v in (True, False)
    ]
    assert z_v.n_adds == z_l.n_adds == 0
    assert z_v.wan_bytes == z_l.wan_bytes == 0.0


# ------------------------------------------------------------- link budgets
def _tight_window(store, n_items_per_wave=3.0):
    """A window sized so one wave carries only a few median items per link."""
    med = float(np.median(store.g.item_size()))
    bw_min = float(store.env.bw_Bps_safe().min())
    return n_items_per_wave * med / bw_min


def test_schedule_respects_link_budgets():
    store = _churned_store(4)
    pv, _ = _plan_pair(store, theta_add=0.3, theta_drop=0.15)
    assert pv.n_adds > 0
    window = _tight_window(store)
    sched = schedule_transfers(pv, store.env, window)
    assert sched.n_waves >= 2  # tight window actually forces pipelining
    seen = []
    for w in sched.waves:
        assert w.makespan_s > 0
        for b in w.links:
            budget = float(sched.link_budget[b.src, b.dst])
            # the invariant under test: a wave never overloads a link
            # (a lone transfer bigger than the budget is isolated + flagged)
            assert b.nbytes <= budget + 1e-9 or b.n_transfers == 1
            assert b.nbytes == pytest.approx(
                float(sum(m.wan_bytes for m in b.moves))
            )
            seen.extend((m.item, m.dc) for m in b.moves)
        # wave makespan is the straggler link (Eq. 1 on the bulk payload)
        spans = [
            b.nbytes / float(store.env.bw_Bps[b.src, b.dst])
            + float(store.env.rtt_s[b.src, b.dst])
            for b in w.links
        ]
        assert w.makespan_s == pytest.approx(max(spans))
    seen.extend((m.item, m.dc) for m in sched.local)
    planned = [(m.item, m.dc) for m in pv.moves if m.kind == "add"]
    # every accepted add is scheduled exactly once, none invented
    assert sorted(seen) == sorted(planned)
    assert sched.makespan_s == pytest.approx(
        sum(w.makespan_s for w in sched.waves)
    )


def test_schedule_preserves_priority_within_link():
    store = _churned_store(5)
    pv, _ = _plan_pair(store, theta_add=0.3, theta_drop=0.15)
    sched = schedule_transfers(pv, store.env, _tight_window(store))
    prio = {(m.item, m.dc): i
            for i, m in enumerate(m for m in pv.moves if m.kind == "add")}
    per_link = {}
    for w in sched.waves:
        for b in w.links:
            per_link.setdefault((b.src, b.dst), []).extend(
                prio[(m.item, m.dc)] for m in b.moves
            )
    for order in per_link.values():
        assert order == sorted(order)  # highest benefit density ships first


def test_oversized_transfer_isolated():
    env = make_paper_env()
    big, small = 1e9, 8.0
    moves = [
        Move(0, 1, "add", 1.0, small, src=0),
        Move(1, 1, "add", 1.0, big, src=0),  # alone exceeds any tight budget
        Move(2, 1, "add", 1.0, small, src=0),
    ]
    plan = MigrationPlan(moves, big + 2 * small, 3.0, 3, 0)
    window = 32.0 / float(env.bw_Bps[0, 1])  # budget: 32 bytes on link 0->1
    sched = schedule_transfers(plan, env, window)
    assert sched.oversized == 1
    for w in sched.waves:
        for b in w.links:
            if b.nbytes > float(sched.link_budget[b.src, b.dst]):
                assert b.n_transfers == 1  # oversized ships alone
    # order preserved: small, big (own wave), small
    flat = [m.item for w in sched.waves for b in w.links for m in b.moves]
    assert flat == [0, 1, 2]
    assert sched.n_waves == 3


def test_schedule_empty_plan():
    env = make_paper_env()
    sched = schedule_transfers(MigrationPlan([], 0.0, 0.0, 0, 0), env, 1.0)
    assert sched.n_waves == 0 and sched.makespan_s == 0.0
    assert sched.n_transfers == 0


# ---------------------------------------------------------- LPT wave packing
def _random_plan(rng, n_dcs=5, n_moves=60):
    moves = []
    for i in range(n_moves):
        s = int(rng.integers(0, n_dcs))
        d = int(rng.integers(0, n_dcs))
        if s == d:
            d = (d + 1) % n_dcs
        nb = float(rng.lognormal(3.0, 1.2))
        moves.append(Move(i, d, "add", float(rng.random()), nb, src=s))
    wan = float(sum(m.wan_bytes for m in moves))
    return MigrationPlan(moves, wan, 1.0, n_moves, 0)


@pytest.mark.parametrize("seed", range(8))
def test_lpt_never_worse_than_first_fit(seed):
    """``schedule="lpt"`` must dominate the default packing on the pipelined
    makespan estimate for randomized plans (it keeps ff as a floor), while
    scheduling the identical transfer multiset under the same link budgets."""
    env = make_paper_env()
    rng = np.random.default_rng(seed)
    plan = _random_plan(rng)
    # budget ~ a few median transfers per link so packing actually matters
    window = 120.0 / float(env.bw_Bps_safe().min())
    ff = schedule_transfers(plan, env, window, schedule="ff")
    lpt = schedule_transfers(plan, env, window, schedule="lpt")
    assert lpt.makespan_s <= ff.makespan_s + 1e-9
    assert lpt.packing in ("ff", "lpt")

    def flat(s):
        out = [(m.item, m.dc) for w in s.waves for b in w.links for m in b.moves]
        out += [(m.item, m.dc) for m in s.local]
        return sorted(out)

    assert flat(lpt) == flat(ff)  # nothing dropped, nothing invented
    for w in lpt.waves:
        for b in w.links:
            assert (
                b.nbytes <= float(lpt.link_budget[b.src, b.dst]) + 1e-9
                or b.n_transfers == 1
            )


def test_lpt_flush_lands_same_placement():
    """Packing only reorders WAN shipping; the final replica sets and routes
    must be identical to the default schedule."""
    s_ff = _churned_store(9)
    s_lpt = _churned_store(9)
    kw = dict(theta_add=0.3, theta_drop=0.15)
    window = _tight_window(s_ff)
    p_ff = s_ff.flush_migrations(window_s=window, schedule="ff", **kw)
    p_lpt = s_lpt.flush_migrations(window_s=window, schedule="lpt", **kw)
    assert p_lpt.schedule.makespan_s <= p_ff.schedule.makespan_s + 1e-9
    assert np.array_equal(s_ff.state.delta, s_lpt.state.delta)
    assert np.array_equal(s_ff.state.route, s_lpt.state.route)
    assert s_lpt.route_index.verify(s_lpt.state.delta)


def test_schedule_rejects_unknown_packing():
    env = make_paper_env()
    with pytest.raises(ValueError, match="unknown packing"):
        schedule_transfers(MigrationPlan([], 0.0, 0.0, 0, 0), env, 1.0, schedule="best")


# ------------------------------------------------------ wave-ordered apply
def test_wave_application_keeps_route_index_rebuild_identical():
    """After every completed wave the incremental RouteIndex must equal a
    from-scratch ``route_nearest`` derivation of the placement-so-far."""
    store = _churned_store(6)
    checks = []

    def on_wave(wave):
        checks.append(store.route_index.verify(store.state.delta))

    before = store.constraints()
    plan = store.flush_migrations(
        window_s=_tight_window(store), on_wave=on_wave,
        theta_add=0.3, theta_drop=0.15,
    )
    assert plan.schedule is not None
    if plan.n_adds:
        assert len(checks) == plan.schedule.n_waves >= 1
    assert all(checks)
    assert store.route_index.verify(store.state.delta)  # and after drops
    after = store.constraints()
    for k, held in before.items():
        if held:
            assert after[k], f"migration regressed constraint {k}"
    for m in plan.moves:
        assert store.state.delta[m.item, m.dc] == (m.kind == "add")


def test_wave_application_matches_single_shot():
    """Pipelined application converges to the same placement + routing as
    the legacy all-at-once path on an identically-churned store."""
    s_wave = _churned_store(7)
    s_shot = _churned_store(7)
    kw = dict(theta_add=0.3, theta_drop=0.15)
    p_wave = s_wave.flush_migrations(window_s=_tight_window(s_wave), **kw)
    p_shot = s_shot.flush_migrations(window_s=None, **kw)
    assert [(m.item, m.dc, m.kind) for m in p_wave.moves] == [
        (m.item, m.dc, m.kind) for m in p_shot.moves
    ]
    assert np.array_equal(s_wave.state.delta, s_shot.state.delta)
    assert np.array_equal(s_wave.state.route, s_shot.state.route)
    assert p_shot.schedule is None and p_wave.schedule is not None


def test_controller_serves_between_waves():
    """A controller drained inside ``on_wave`` sees a route table that is
    consistent with the placement at that wave boundary."""
    store = _churned_store(8)
    ctl = AdmissionController(
        store, AdmissionConfig(policy="greedy", fairness="fifo", max_batch=4)
    )
    client = StoreClient(ctl)
    pats = [p for p in store.workload.patterns if len(p.items)]
    served = []

    def on_wave(wave):
        p = pats[wave.index % len(pats)]
        origin = int(np.argmax(p.r_py))
        h = client.submit(p.items, origin, deadline_s=math.inf)
        ctl.run_until_idle()
        res = h.result
        ref = route_online(store.lg, store.state, p.items, origin)
        served.append(
            res.n_missing == 0
            and np.array_equal(res.served_by, ref.served_by)
        )

    plan = store.flush_migrations(
        window_s=_tight_window(store), on_wave=on_wave,
        theta_add=0.3, theta_drop=0.15,
    )
    if plan.n_adds:
        assert len(served) >= 1
    assert all(served)
