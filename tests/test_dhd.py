import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
    from _hypothesis_stub import given, settings, st

from repro.core import dhd


def _random_sym_adj(rng, n, p=0.3):
    a = (rng.random((n, n)) < p).astype(np.float32) * rng.random((n, n)).astype(np.float32)
    a = np.triu(a, 1)
    return a + a.T


def test_dense_vs_edges_equivalence():
    rng = np.random.default_rng(0)
    n = 12
    adj = _random_sym_adj(rng, n)
    iu, iv = np.nonzero(np.triu(adj, 1))
    w = adj[iu, iv]
    heat = jnp.asarray(rng.random(n), jnp.float32)
    q = jnp.asarray(rng.random(n) * 0.1, jnp.float32)
    out_d = dhd.dhd_step_dense(heat, jnp.asarray(adj), q)
    out_e = dhd.dhd_step_edges(
        heat, jnp.asarray(iu, jnp.int32), jnp.asarray(iv, jnp.int32),
        jnp.asarray(w), q, n,
    )
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_e), rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_theorem1_convergence_under_bound(seed):
    """Theorem 1: alpha < gamma/((1-gamma)||L||_inf) -> unique fixed point;
    fixed-point iteration matches the direct linear solve."""
    rng = np.random.default_rng(seed)
    n = 8
    adj = _random_sym_adj(rng, n, p=0.5)
    heat0 = jnp.asarray(rng.random(n), jnp.float32)
    gamma, beta = 0.1, 0.3
    l_dir = dhd.build_l_dir(heat0, jnp.asarray(adj))
    alpha_max = dhd.convergence_alpha_bound(l_dir, gamma)
    alpha = min(0.9 * alpha_max, 10.0)
    q = jnp.asarray(rng.random(n) * 0.1, jnp.float32)
    # fixed point of H -> (1-g)(H + a L H) + b q  with L *frozen* (Theorem 1)
    h_lin = dhd.linear_steady_state(l_dir, q, alpha, gamma, beta)
    h = heat0
    for _ in range(3000):
        h_new = (1 - gamma) * (h + alpha * (l_dir @ h)) + beta * q
        if float(jnp.max(jnp.abs(h_new - h))) < 1e-9:
            h = h_new
            break
        h = h_new
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_lin), rtol=1e-3, atol=1e-5)


def test_heat_flows_hot_to_cold():
    # two nodes: all flow from hot to cold, never negative
    heat = jnp.asarray([1.0, 0.0])
    out = dhd.dhd_step_edges(
        heat, jnp.asarray([0]), jnp.asarray([1]), jnp.asarray([1.0]),
        jnp.zeros(2), 2, alpha=0.5, gamma=0.0, beta=0.0,
    )
    assert out[0] < 1.0 and out[1] > 0.0
    # conservation when gamma=0 and no sources
    assert abs(float(out.sum()) - 1.0) < 1e-6


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_no_flow_between_equal_heat(seed):
    rng = np.random.default_rng(seed)
    n = 6
    heat = jnp.full((n,), 0.7, jnp.float32)
    src = jnp.asarray(rng.integers(0, n, 10), jnp.int32)
    dst = jnp.asarray((rng.integers(1, n, 10) + np.asarray(src)) % n, jnp.int32)
    out = dhd.dhd_step_edges(
        heat, src, dst, jnp.ones(10), jnp.zeros(n), n, gamma=0.0, beta=0.0
    )
    np.testing.assert_allclose(np.asarray(out), 0.7, rtol=1e-6)


def test_source_decay():
    q0 = jnp.asarray([1.0, 0.0])
    q1 = dhd.source_heat(q0, jnp.asarray(0), half_life=2.0)
    q2 = dhd.source_heat(q0, jnp.asarray(2), half_life=2.0)
    assert float(q2[0]) == pytest.approx(float(q1[0]) / 2.0, rel=1e-5)
