"""End-to-end system behaviour of the GeoGraphStore."""
import numpy as np

from repro.core.patterns import Pattern


def test_constraints_hold(small_setup, small_store):
    g, env, csr, wl, pats = small_setup
    ok = small_store.constraints()
    assert ok["a_route_on_replica"]
    assert ok["a_requested_routed"]
    assert ok["e_binary"]


def test_geolayer_beats_baselines_cost(small_setup):
    from repro.core.placement import PlacementConfig
    from repro.core.store import GeoGraphStore

    g, env, csr, wl, pats = small_setup
    cfg = PlacementConfig(precache=False, dhd_steps=4)
    c_geo = GeoGraphStore(g, env, wl, config=cfg).cost().total
    c_rand = GeoGraphStore(g, env, wl, config=cfg, placement="random",
                           routing="random").cost().total
    c_top = GeoGraphStore(g, env, wl, config=cfg, placement="top",
                          routing="random").cost().total
    assert c_geo < c_rand
    assert c_geo < c_top


def test_online_latency_beats_random(small_setup, small_store):
    import numpy as np

    from repro.core.placement import PlacementConfig
    from repro.core.store import GeoGraphStore

    g, env, csr, wl, pats = small_setup
    rand = GeoGraphStore(g, env, wl, config=PlacementConfig(precache=False, dhd_steps=4),
                         placement="random", routing="random")
    def mean_lat(store):
        return np.mean([
            store.serve_online(p, int(np.argmax(p.r_py))).latency_s for p in pats[:15]
        ])
    assert mean_lat(small_store) < mean_lat(rand)


def test_delete_and_insert(small_setup):
    from repro.core.placement import PlacementConfig
    from repro.core.store import GeoGraphStore

    g, env, csr, wl, pats = small_setup
    store = GeoGraphStore(g, env, wl, config=PlacementConfig(precache=False, dhd_steps=4))
    victim = pats[0].items[:3]
    store.delete_items(victim)
    assert not store.state.delta[victim].any()
    # incremental insert re-places
    newp = Pattern(999, pats[1].items, r_py=pats[1].r_py * 2, w_py=pats[1].w_py, eta=0.5)
    store.insert_patterns([newp])
    assert (store.state.delta[newp.items].sum(axis=1) >= 1).all()


def test_maintain_refreshes_routing(small_setup, small_store):
    out = small_store.maintain(evict=True)
    assert "evicted" in out
    ok = small_store.constraints()
    assert ok["a_route_on_replica"]
