"""RouteIndex: incremental nearest/second-nearest replica index.

The load-bearing invariant: after ANY sequence of store mutations
(``apply_updates``, ``flush_migrations``, ``maintain`` evictions, compaction)
the incremental index equals a from-scratch ``route_nearest`` rebuild
row-for-row — the differential acceptance criterion of the serving PR.
"""
import numpy as np
import pytest

from repro.core.cost import PlacementState
from repro.core.graph import Graph, build_csr
from repro.core.latency import make_paper_env, make_synthetic_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.route_index import RouteIndex
from repro.core.store import GeoGraphStore
from repro.streaming import DeltaGraph, MutationLog, random_churn_batch


def _random_graph(n, m, n_dcs, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    return Graph.from_edges(
        n, src[keep], dst[keep], partition=rng.integers(0, n_dcs, n)
    ), rng


def _make_store(seed=0, n=250, m=1200, n_patterns=25, **kw):
    g, rng = _random_graph(n, m, 5, seed)
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, n_patterns, seed=seed + 1, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    store = GeoGraphStore(
        g, env, wl, config=PlacementConfig(precache=False, dhd_steps=4), **kw
    )
    return store, rng


def _assert_index_matches_rebuild(store):
    """Row-for-row equality with a from-scratch route_nearest derivation."""
    ref = PlacementState(store.state.delta.copy(), store.state.route.copy())
    ref.route_nearest(store.env)
    assert np.array_equal(store.route_index.nearest, ref.route)
    assert store.route_index.verify(store.state.delta)


# ------------------------------------------------------------ primitives
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_fuzz_add_drop_moves_match_rebuild(seed):
    """Randomized add/drop/move-set patches == full rebuild, every step."""
    rng = np.random.default_rng(seed)
    env = make_synthetic_env(6, "high", seed=seed) if seed % 2 else make_paper_env()
    D, I = env.n_dcs, 80
    delta = rng.random((I, D)) < 0.4
    idx = RouteIndex.build(delta, env)
    assert idx.verify(delta)

    class _Move:
        def __init__(self, item, dc, kind):
            self.item, self.dc, self.kind = item, dc, kind

    for step in range(50):
        op = rng.integers(0, 3)
        dc = int(rng.integers(0, D))
        items = rng.choice(I, size=rng.integers(1, 12), replace=False)
        if op == 0:
            delta[items, dc] = True
            idx.add_replicas(delta, items, dc)
        elif op == 1:
            delta[items, dc] = False
            idx.drop_replicas(delta, items, dc)
        else:
            moves = []
            for x in items:
                kind = "add" if rng.random() < 0.5 else "drop"
                delta[int(x), dc] = kind == "add"
                moves.append(_Move(int(x), dc, kind))
            idx.apply_moves(delta, moves)
        assert idx.verify(delta), f"diverged at step {step} (op {op})"
    # the incremental paths actually ran (not everything fell back to patch)
    assert idx.stats.rows_shifted > 0
    assert idx.stats.rows_promoted > 0


def test_second_nearest_semantics():
    env = make_paper_env()
    delta = np.zeros((3, env.n_dcs), dtype=bool)
    delta[0, [1, 3]] = True  # two replicas
    delta[1, 2] = True  # single replica
    idx = RouteIndex.build(delta, env)
    # single replica: nearest everywhere, no second
    assert (idx.nearest[1] == 2).all()
    assert (idx.second[1] == -1).all()
    # no replica: unroutable
    assert (idx.nearest[2] == -1).all()
    # two replicas: {nearest, second} == {1, 3} for every origin
    for y in range(env.n_dcs):
        assert {int(idx.nearest[0, y]), int(idx.second[0, y])} == {1, 3}
    # dropping one of the two replicas leaves a single-replica row
    dc = int(idx.nearest[0, 0])
    delta[0, dc] = False
    idx.drop_replicas(delta, np.array([0]), dc)
    assert idx.verify(delta)
    assert (idx.second[0] == -1).all()


# ---------------------------------------------------- store differential
def test_differential_updates_and_migrations():
    """Randomized apply_updates + flush_migrations sequence: incremental
    RouteIndex == from-scratch route_nearest rebuild, row-for-row."""
    store, rng = _make_store(seed=11)
    assert store.route_index is not None
    assert store.state.route is store.route_index.nearest
    _assert_index_matches_rebuild(store)
    store._delta_graph = DeltaGraph(store.g)
    for i in range(4):
        store.apply_updates(random_churn_batch(store._delta_graph, 0.03, rng))
        assert store.state.route is store.route_index.nearest
        _assert_index_matches_rebuild(store)
        if i % 2:
            store.flush_migrations()
            _assert_index_matches_rebuild(store)
    store.maintain()
    _assert_index_matches_rebuild(store)


def test_external_route_nearest_resync():
    """A direct full route_nearest() replaces state.route and orphans the
    index alias; the next store entry point must re-adopt the table (the
    staleness bug behind evictions patching a detached array)."""
    store, _ = _make_store(seed=9)
    store.state.route_nearest(store.env)
    assert store.state.route is not store.route_index.nearest
    store.maintain(evict=True)
    assert store.state.route is store.route_index.nearest
    _assert_index_matches_rebuild(store)
    assert store.constraints()["a_route_on_replica"]
    # delete_items after a second orphaning must also resync (it clears
    # index rows, which would otherwise never reach the detached table)
    store.state.route_nearest(store.env)
    victim = store.workload.patterns[0].items[:3]
    store.delete_items(victim)
    assert store.state.route is store.route_index.nearest
    assert (store.state.route[victim] == -1).all()
    _assert_index_matches_rebuild(store)


def test_maintain_eviction_patches_index():
    store, _ = _make_store(seed=3)
    # heat is cold everywhere -> eviction drops every non-primary replica
    out = store.maintain(evict=True)
    assert out["evicted"] > 0
    _assert_index_matches_rebuild(store)


# ------------------------------------------------------------ compaction
def test_compaction_across_delete_serve_boundary():
    """Interleaved deletes + serves across the tombstone-ratio compaction:
    every pattern stays servable, placement/routing invariants hold, and the
    store actually shrinks its id space."""
    store, rng = _make_store(seed=7, compact_ratio=0.25)
    store._delta_graph = DeltaGraph(store.g)
    n_items_before = store.g.n_items
    compacted = False
    for i in range(12):
        alive_v = np.where(store._delta_graph.node_alive)[0]
        log = MutationLog(store.g.n_nodes)
        for vid in rng.choice(alive_v, size=12, replace=False):
            log.delete_vertex(int(vid))
        rep = store.apply_updates(log.seal())
        compacted = compacted or rep.compacted
        reqs = [
            (p.items, int(np.argmax(p.r_py)))
            for p in store.workload.patterns
            if len(p.items)
        ]
        results = store.serve_batch(reqs)
        assert sum(r.n_missing for r in results) == 0
        _assert_index_matches_rebuild(store)
        ok = store.constraints()
        assert ok["a_route_on_replica"] and ok["b_pattern_route_on_replica"]
        if compacted:
            break
    assert compacted, "tombstone-ratio trigger never fired"
    assert store.tombstone_ratio() == 0.0
    assert store.g.n_items < n_items_before
    # post-compaction churn keeps working on the re-keyed state
    for _ in range(2):
        store.apply_updates(random_churn_batch(store._delta_graph, 0.03, rng))
        _assert_index_matches_rebuild(store)
    reqs = [
        (p.items, int(np.argmax(p.r_py)))
        for p in store.workload.patterns
        if len(p.items)
    ]
    assert sum(r.n_missing for r in store.serve_batch(reqs)) == 0


# ------------------------------------------------------- warm-DHD residual
def test_heat_residual_surfaced_and_decays():
    """A starved warm solve reports a positive carried-over residual in
    UpdateReport; repeated maintain() works it off to ~0."""
    from repro.streaming import StreamingHeat

    store, rng = _make_store(seed=5)
    store._delta_graph = DeltaGraph(store.g)
    # starve the per-batch sweep budget so residual is visibly carried
    store._heat = StreamingHeat(tol=1e-7, max_iters=1)
    rep = store.apply_updates(random_churn_batch(store._delta_graph, 0.05, rng))
    assert rep.heat_residual == rep.heat.residual
    assert rep.heat_residual > 1e-6
    residuals = [rep.heat_residual]
    store._heat.max_iters = 16  # each maintenance window pays down 16 sweeps
    for _ in range(40):
        out = store.maintain(evict=False)
        residuals.append(out["heat_residual"])
        if residuals[-1] < 1e-6:
            break
    assert residuals[-1] < 1e-6, f"residual never decayed: {residuals}"
    assert residuals[-1] < residuals[0]
