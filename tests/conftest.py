import pytest


@pytest.fixture(scope="session")
def paper_env():
    from repro.core.latency import make_paper_env

    return make_paper_env()


@pytest.fixture(scope="session")
def small_setup():
    """Shared small graph + workload (session-scoped: placement is costly)."""
    from repro.core.graph import build_csr
    from repro.core.latency import make_paper_env
    from repro.core.patterns import Workload, generate_khop_patterns
    from repro.data.synthetic import make_benchmark_graph

    g = make_benchmark_graph("wiki", n_dcs=4, seed=0)
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, 40, seed=1, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return g, env, csr, wl, pats


@pytest.fixture(scope="session")
def small_store(small_setup):
    from repro.core.placement import PlacementConfig
    from repro.core.store import GeoGraphStore

    g, env, csr, wl, pats = small_setup
    return GeoGraphStore(
        g, env, wl, config=PlacementConfig(precache=True, dhd_steps=8)
    )
