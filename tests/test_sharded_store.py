"""Sharded data plane: ShardedGeoGraphStore differential identity.

Bars under test:
  * **identity** — a sharded store at 2/4/8 shards produces the exact
    replica sets (``state.delta``), serving tables (partition columns ==
    ``state.route``) and ``serve_batch`` results of a single-process
    ``GeoGraphStore`` built from the same seed, through every mutation the
    store supports: churn (``apply_updates``), migration waves
    (``begin_flush``/``flush_migrations``), evictions (``maintain``),
    deletes and compaction;
  * **payload plane** — migration waves land as real device-to-device
    transfers: after every wave each shard's device block holds exactly the
    uid-derived rows for its replicas (bit-exact fp32, bounded error int8),
    and wire bytes hit the per-shard ``MatrixCounter`` grids;
  * **per-shard telemetry** — shard registries fold into one merged view
    whose serving counters account for every request;
  * **per-shard admission** — ``per_shard_aimd`` gives each shard its own
    AIMD target (a slow shard shrinks without throttling healthy ones) and
    a detector-flagged shard's misses are attributed ``straggler``.

CI forces an 8-device CPU mesh via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; without it every
shard cycles onto one device and the same assertions hold (single-process
fallback).
"""

import numpy as np
import pytest

from repro.core.graph import Graph, build_csr
from repro.core.latency import make_paper_env
from repro.core.patterns import Workload, generate_khop_patterns
from repro.core.placement import PlacementConfig
from repro.core.routing import RouteResult
from repro.core.store import GeoGraphStore
from repro.distributed import ShardedGeoGraphStore, payload_for_uids
from repro.distributed.geo_sharding import mesh_devices, mesh_env
from repro.serve import AdmissionConfig, AdmissionController
from repro.streaming import DeltaGraph, random_churn_batch


# --------------------------------------------------------------- scaffolding
def _build(seed, env, part_dcs=None):
    """Graph + workload, independently constructible from a seed (stores
    mutate their graph in place, so differential pairs need two builds)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, 220, 1400)
    dst = rng.integers(0, 220, 1400)
    keep = src != dst
    g = Graph.from_edges(
        220, src[keep], dst[keep],
        partition=rng.integers(0, part_dcs or env.n_dcs, 220),
    )
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, 24, seed=seed + 1, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return g, wl, pats


_CFG = PlacementConfig(precache=False, dhd_steps=4)


def _pair(seed, env, n_shards, part_dcs=None, **sharded_kw):
    g1, wl1, pats = _build(seed, env, part_dcs)
    g2, wl2, _ = _build(seed, env, part_dcs)
    ref = GeoGraphStore(g1, env, wl1, config=_CFG, routing="stepwise")
    sh = ShardedGeoGraphStore(
        g2, env, wl2, config=_CFG, n_shards=n_shards, **sharded_kw
    )
    return ref, sh, pats


def _churn(store, seed, n_batches=3, rate=0.02):
    rng = np.random.default_rng(seed + 100)
    store._delta_graph = DeltaGraph(store.g)
    for _ in range(n_batches):
        store.apply_updates(random_churn_batch(store._delta_graph, rate, rng))


def _requests(pats, env, n, seed):
    """65% home-origin / 35% uniform request mix."""
    rng = np.random.default_rng(seed)
    live = [p for p in pats if len(p.items)]
    out = []
    for _ in range(n):
        p = live[int(rng.integers(0, len(live)))]
        home = int(np.argmax(p.r_py))
        o = home if rng.random() < 0.65 else int(rng.integers(0, env.n_dcs))
        out.append((p.items, o))
    return out


def _assert_results_equal(r1, r2):
    assert len(r1) == len(r2)
    for a, b in zip(r1, r2):
        assert np.array_equal(a.served_by, b.served_by)
        assert a.latency_s == b.latency_s  # float-identical, not approx
        assert a.wan_bytes == b.wan_bytes
        assert a.layers_used == b.layers_used
        assert a.n_missing == b.n_missing
        assert set(a.dcs.tolist()) == set(b.dcs.tolist())


def _assert_state_parity(ref, sh):
    assert np.array_equal(ref.state.delta, sh.state.delta)
    assert np.array_equal(ref.state.route, sh.route_table())
    assert np.array_equal(ref.state.route, sh.state.route)
    assert sh.verify_partitions()


def _tight_window(store, n_items_per_wave=3.0):
    med = float(np.median(store.g.item_size()))
    return n_items_per_wave * med / float(store.env.bw_Bps_safe().min())


# ---------------------------------------------------------- identity: mesh
@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_mesh_identity_across_shard_counts(n_shards):
    """Same env served at 2/4/8 shards == the single-process store."""
    env = mesh_env(8, shards_per_pod=4)
    ref, sh, pats = _pair(20, env, n_shards)
    _assert_state_parity(ref, sh)
    reqs = _requests(pats, env, 96, seed=21)
    _assert_results_equal(ref.serve_batch(reqs), sh.serve_batch(reqs))
    # heat observation paths must match too: both stores plan identically
    _churn(ref, 22), _churn(sh, 22)
    _assert_state_parity(ref, sh)
    _assert_results_equal(ref.serve_batch(reqs), sh.serve_batch(reqs))


def test_mesh_devices_cycle_and_mesh_serving():
    devs = mesh_devices(8)
    assert len(devs) == 8
    # a 3-shard store on an 8-DC mesh groups DCs round-robin
    env = mesh_env(8, shards_per_pod=4)
    _, sh, pats = _pair(30, env, n_shards=3)
    assert sh.origin_shard == {d: d % 3 for d in range(8)}
    assert sorted(d for s in sh.shards for d in s.dcs) == list(range(8))
    r = sh.serve_batch(_requests(pats, env, 32, seed=31))
    assert all(isinstance(x, RouteResult) for x in r)


# ------------------------------------------- identity: full mutation cycle
@pytest.mark.parametrize("n_shards,compress", [(2, "int8"), (5, None)])
def test_identity_through_churn_flush_maintain_compact(n_shards, compress):
    env = make_paper_env()
    # partition over D-1 DCs so migration finds profitable adds
    ref, sh, pats = _pair(
        6, env, n_shards, part_dcs=env.n_dcs - 1,
        telemetry=True, compress=compress,
    )
    _churn(ref, 6), _churn(sh, 6)
    _assert_state_parity(ref, sh)
    assert sh.verify_payloads() == 0.0

    kw = dict(theta_add=0.3, theta_drop=0.15)
    window = _tight_window(ref)
    p1 = ref.flush_migrations(window_s=window, **kw)
    p2 = sh.flush_migrations(window_s=window, **kw)
    assert p1.n_adds == p2.n_adds > 0  # waves actually shipped payload
    assert p1.schedule.n_waves == p2.schedule.n_waves >= 1
    _assert_state_parity(ref, sh)
    tol = 0.0 if compress is None else 1.0 / 127.0
    assert sh.verify_payloads() <= tol

    reqs = _requests(pats, env, 64, seed=61)
    _assert_results_equal(ref.serve_batch(reqs), sh.serve_batch(reqs))

    ref.maintain(), sh.maintain()
    _assert_state_parity(ref, sh)
    assert sh.verify_payloads() <= tol

    ids = np.arange(0, ref.g.n_items, 5)
    ref.delete_items(ids), sh.delete_items(ids)
    fired = (ref.compact(), sh.compact())
    assert fired[0] == fired[1]
    _assert_state_parity(ref, sh)
    # compaction re-materializes payloads from the surviving uids: exact
    assert sh.verify_payloads() == 0.0
    reqs2 = [(np.clip(it, 0, ref.g.n_items - 1), o) for it, o in reqs]
    _assert_results_equal(ref.serve_batch(reqs2), sh.serve_batch(reqs2))

    bytes_moved = sum(
        v["value"]
        for v in sh.merged_metrics()
        .get("migration.device_bytes_link", {})
        .values()
    )
    if compress is None:
        # fp32 wire bytes == adds x row width x 4B, exactly
        assert bytes_moved == p2.n_adds * sh.payload_width * 4
    else:
        assert 0 < bytes_moved < p2.n_adds * sh.payload_width * 4


def test_wavewise_payload_invariant_and_stepwise_applier():
    """After *every* wave the held rows of every shard match their uid
    content — transfers land with the metadata patch, not at finish."""
    env = make_paper_env()
    ref, sh, _ = _pair(7, env, n_shards=3, part_dcs=env.n_dcs - 1,
                       telemetry=True)
    _churn(ref, 7), _churn(sh, 7)
    kw = dict(theta_add=0.3, theta_drop=0.15)
    window = _tight_window(ref)
    p1, a1 = ref.begin_flush(window_s=window, **kw)
    p2, a2 = sh.begin_flush(window_s=window, **kw)
    if a1.n_remaining < 2:
        pytest.skip("plan produced fewer than 2 transfer waves")
    assert a2.n_remaining == a1.n_remaining
    while a2.n_remaining:
        w1, w2 = a1.apply_next(), a2.apply_next()
        assert [(b.src, b.dst, b.items.tolist()) for b in w1.links] == [
            (b.src, b.dst, b.items.tolist()) for b in w2.links
        ]
        assert sh.verify_payloads() == 0.0
        assert np.array_equal(ref.state.route, sh.route_table())
    a1.finish(), a2.finish()
    _assert_state_parity(ref, sh)
    assert sh.verify_payloads() == 0.0
    waves = sh.registry.snapshot()["migration.device_waves"]["-"]["value"]
    assert waves == p2.schedule.n_waves


def test_insert_patterns_rebinds_partitions_and_payload():
    env = mesh_env(4)
    ref, sh, pats = _pair(40, env, n_shards=2)

    def fresh(store):  # same graph content on both sides -> same patterns
        csr = build_csr(store.g.n_nodes, store.g.src, store.g.dst,
                        symmetrize=True)
        return generate_khop_patterns(store.g, csr, 10, seed=41,
                                      n_dcs=env.n_dcs)

    ref_new, sh_new = fresh(ref), fresh(sh)
    # full re-place builds a brand-new RouteIndex: the facade must re-bind
    ref.insert_patterns(ref_new[:6]), sh.insert_patterns(sh_new[:6])
    _assert_state_parity(ref, sh)
    assert sh.verify_payloads() == 0.0
    ref.insert_patterns_incremental(ref_new[6:10])
    sh.insert_patterns_incremental(sh_new[6:10])
    _assert_state_parity(ref, sh)
    reqs = _requests(pats, env, 48, seed=42)
    _assert_results_equal(ref.serve_batch(reqs), sh.serve_batch(reqs))


def test_parallel_dispatch_matches_serial():
    env = mesh_env(8, shards_per_pod=4)
    _, serial, pats = _pair(50, env, n_shards=4, parallel=False)
    _, threaded, _ = _pair(50, env, n_shards=4, parallel=True)
    assert threaded._pool is not None
    reqs = _requests(pats, env, 128, seed=51)
    _assert_results_equal(serial.serve_batch(reqs), threaded.serve_batch(reqs))
    for o in range(env.n_dcs):
        assert np.array_equal(serial.caches[o].heat, threaded.caches[o].heat)


def test_constructor_rejects_bad_configs():
    env = mesh_env(4)
    g, wl, _ = _build(60, env)
    with pytest.raises(ValueError, match="route index"):
        ShardedGeoGraphStore(g, env, wl, config=_CFG, routing="flat")
    with pytest.raises(ValueError, match="n_shards"):
        ShardedGeoGraphStore(g, env, wl, config=_CFG, n_shards=9)
    with pytest.raises(ValueError, match="compression"):
        ShardedGeoGraphStore(g, env, wl, config=_CFG, compress="zstd")


def test_payload_for_uids_stable_and_bounded():
    rows = payload_for_uids(np.array([0, 1, 2**40, 7]), width=4)
    assert rows.shape == (4, 4) and rows.dtype == np.float32
    assert (0 <= rows).all() and (rows < 1).all()
    # pure function of uid: permutation-covariant, no hidden state
    perm = payload_for_uids(np.array([7, 0]), width=4)
    assert np.array_equal(perm[0], rows[3]) and np.array_equal(perm[1], rows[0])


# ------------------------------------------------------------------ metrics
def test_merged_metrics_account_every_request():
    env = mesh_env(8, shards_per_pod=4)
    _, sh, pats = _pair(70, env, n_shards=4, telemetry=True)
    reqs = _requests(pats, env, 80, seed=71)
    sh.serve_batch(reqs)
    sh.serve_batch(reqs[:20])
    merged = sh.merged_metrics()
    assert merged["serving.requests"]["-"]["value"] == 100.0
    # per-shard registries really are per-shard: each holds only its slice
    per_shard = [
        s.registry.snapshot()
        .get("serving.requests", {})
        .get("-", {})
        .get("value", 0.0)
        for s in sh.shards
    ]
    assert sum(per_shard) == 100.0
    assert sum(1 for v in per_shard if v) > 1
    lat = merged["serving.request_latency_s"]["-"]
    assert lat["count"] == 100.0
    # fetch path: serving the same batch with payload reads changes no result
    sh.fetch_payload = True
    r = sh.serve_batch(reqs[:8], observe=False)
    assert len(r) == 8


# --------------------------------------------------- per-shard admission
class _StubShardStore:
    """Two-shard data plane stub with a controllable slow shard: shard 1's
    serve wall time is fed to the detector exactly as the sharded store
    feeds measured times."""

    def __init__(self, slow_factor=10.0):
        from repro.distributed.fault import StragglerDetector

        self.origin_shard = {0: 0, 1: 1}
        self.straggler = StragglerDetector(2, threshold=1.8)
        self.slow_factor = slow_factor

    def serve_batch(self, reqs):
        out = []
        for items, origin in reqs:
            shard = self.origin_shard[origin]
            base = 0.002 if shard == 0 else 0.002 * self.slow_factor
            self.straggler.observe(shard, base)
            out.append(
                RouteResult(
                    served_by=np.zeros(len(items), dtype=np.int64),
                    dcs=np.array([origin]),
                    latency_s=base,
                    per_dc_latency={origin: base},
                    layers_used=0,
                    n_missing=0,
                    wan_bytes=0.0,
                )
            )
        return out


def test_per_shard_aimd_isolates_slow_shard():
    cfg = AdmissionConfig(
        per_shard_aimd=True, initial_batch=4, max_batch=64,
        default_deadlines=(0.012,),
    )
    ctl = AdmissionController(_StubShardStore(slow_factor=20.0), cfg)
    rng = np.random.default_rng(0)
    # arrivals slower than the service rate: the healthy shard must never
    # miss (so its target grows) while the slow shard's straggler always
    # blows the deadline (so its own target shrinks)
    for i in range(200):
        ctl.submit(np.arange(3), origin=int(rng.integers(0, 2)), at=1e-3 * i)
    ctl.run_until_idle()
    m = ctl.metrics()
    assert m["completed"] == 200
    assert sum(m["misses_by_cause"].values()) == m["deadline_misses"]
    targets = m["batch_target_by_shard"]
    assert set(targets) == {0, 1}
    # the slow shard shrank its own target; the healthy shard kept growing
    assert targets[1] < targets[0]
    assert targets[0] > cfg.initial_batch
    # a detector-flagged shard's misses are attributed to the straggler
    assert 1 in m["straggler_shards"]
    assert m["straggler_misses_by_shard"].get(1, 0) > 0
    assert m["misses_by_cause"]["straggler"] >= m[
        "straggler_misses_by_shard"
    ][1]


def test_per_shard_aimd_config_validation():
    with pytest.raises(ValueError, match="per_shard_aimd"):
        AdmissionConfig(per_shard_aimd=True, policy="greedy")
    with pytest.raises(ValueError, match="per_shard_aimd"):
        AdmissionConfig(per_shard_aimd=True, fairness="fifo")


def test_controller_drives_sharded_store_end_to_end():
    """The full loop: controller -> sharded serve -> straggler feed ->
    per-shard targets, against the real data plane."""
    env = mesh_env(8, shards_per_pod=4)
    _, sh, pats = _pair(80, env, n_shards=4, telemetry=True)
    ctl = AdmissionController(
        sh, AdmissionConfig(per_shard_aimd=True, initial_batch=4, max_batch=32)
    )
    reqs = _requests(pats, env, 120, seed=81)
    for i, (items, o) in enumerate(reqs):
        ctl.submit(items, o, at=2e-4 * i)
    done = ctl.run_until_idle()
    assert len(done) == 120
    m = ctl.metrics()
    assert m["completed"] == 120
    assert sum(m["misses_by_cause"].values()) == m["deadline_misses"]
    assert set(m["batch_target_by_shard"]) <= set(range(4))
    # the real store fed the detector one EWMA per serving shard
    assert (sh.straggler.lat > 0).sum() == len(m["batch_target_by_shard"])
    merged = sh.merged_metrics()
    assert merged["serving.requests"]["-"]["value"] == 120.0


# ------------------------------------------------- kernels fast-path parity
@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_fast_path_identity_across_shard_counts(n_shards):
    """The kernels fast path forced through the sharded per-shard dispatch
    must stay float-identical to the numpy single-process store: the shared
    f64 epilogue makes impl choice invisible in results."""
    from repro.core.routing import (
        RouteFastConfig,
        get_route_fast_config,
        set_route_fast_config,
    )

    env = mesh_env(8, shards_per_pod=4)
    ref, sh, pats = _pair(60, env, n_shards)
    reqs = _requests(pats, env, 96, seed=61)
    want = ref.serve_batch(reqs)
    old = get_route_fast_config()
    set_route_fast_config(RouteFastConfig(min_requests=2))
    try:
        got = sh.serve_batch(reqs)
    finally:
        set_route_fast_config(old)
    _assert_results_equal(want, got)
    # the measured-service hook reports the slowest shard's busy seconds
    assert sh.last_serve_seconds == max(sh.last_shard_seconds.values())
