"""Deterministic fallback for the ``hypothesis`` property-testing API.

CI installs real hypothesis (``requirements.txt``); some execution sandboxes
cannot ``pip install`` anything, and a module-level ``importorskip`` silently
skipped every property test there — permanently.  This stub implements the
tiny slice of the API those tests use (``given``/``settings`` +
``strategies.integers/floats/sampled_from/booleans``) with a seeded RNG, so
the properties still execute everywhere: deterministic samples instead of
shrinking search, which is strictly better than not running at all.

Usage in test modules::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
        from _hypothesis_stub import given, settings, st
"""
from __future__ import annotations

import functools
import inspect

import numpy as np

__all__ = ["given", "settings", "st", "strategies"]

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, sampler):
        self.sample = sampler


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(0, len(elements)))])


class st:  # namespace mirror of hypothesis.strategies
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)


strategies = st


def given(*strats, **kw_strats):
    """Run the test body over deterministic samples of each strategy."""

    def deco(fn):
        # like hypothesis, positional strategies fill the *rightmost*
        # parameters; the leading ones stay visible to pytest as fixtures
        params = list(inspect.signature(fn).parameters)
        n_fixtures = max(0, len(params) - len(strats) - len(kw_strats))
        drawn_names = [p for p in params[n_fixtures:] if p not in kw_strats]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(0)
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES)
            for _ in range(n):
                # bind drawn values by NAME so fixtures passed as keywords
                # (pytest's calling convention) never collide positionally
                drawn = {p: s.sample(rng) for p, s in zip(drawn_names, strats)}
                drawn.update({k: s.sample(rng) for k, s in kw_strats.items()})
                fn(*args, **kwargs, **drawn)

        wrapper.__signature__ = inspect.Signature(
            [
                inspect.Parameter(p, inspect.Parameter.POSITIONAL_OR_KEYWORD)
                for p in params[:n_fixtures]
            ]
        )
        return wrapper

    return deco


def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
    """Records ``max_examples``; every other knob is a no-op here."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco
