"""Property tests for the eSCN rotation machinery (validated to l_max=6)."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
    from _hypothesis_stub import given, settings, st

from repro.models.gnn.wigner import (
    dir_to_angles,
    rotate_irreps,
    sh_real,
    wigner_d_blocks,
)


def rotmat(theta, phi):
    cz, sz = np.cos(phi), np.sin(phi)
    cy, sy = np.cos(theta), np.sin(theta)
    return np.array([[cz, -sz, 0], [sz, cz, 0], [0, 0, 1]]) @ np.array(
        [[cy, 0, sy], [0, 1, 0], [-sy, 0, cy]]
    )


@settings(max_examples=25, deadline=None)
@given(
    st.floats(0.05, 3.09), st.floats(-3.1, 3.1),
    st.integers(0, 10_000),
)
def test_wigner_rotation_property(theta, phi, seed):
    """Defining property: sh(R v) == D(R) sh(v) for all l <= 6."""
    l_max = 6
    rng = np.random.default_rng(seed)
    v = rng.standard_normal(3)
    v /= np.linalg.norm(v)
    r = rotmat(theta, phi)
    sh_v = sh_real(l_max, jnp.asarray(v, jnp.float32))
    sh_rv = sh_real(l_max, jnp.asarray(r @ v, jnp.float32))
    blocks = wigner_d_blocks(
        l_max, jnp.asarray(theta, jnp.float32), jnp.asarray(phi, jnp.float32)
    )
    pred = rotate_irreps(jnp.asarray(sh_v)[:, None], blocks)[:, 0]
    np.testing.assert_allclose(np.asarray(pred), np.asarray(sh_rv), atol=5e-5)


def test_orthogonality():
    blocks = wigner_d_blocks(6, jnp.asarray(1.234, jnp.float32), jnp.asarray(-0.77, jnp.float32))
    for l, b in enumerate(blocks):
        b = np.asarray(b)
        np.testing.assert_allclose(b @ b.T, np.eye(2 * l + 1), atol=2e-5)


def test_edge_frame_alignment():
    """D(R)^T sh(r_hat) == sh(z_hat): rotating into the edge frame."""
    theta, phi = 0.8, -1.3
    d = np.array([np.sin(theta) * np.cos(phi), np.sin(theta) * np.sin(phi), np.cos(theta)])
    blocks = wigner_d_blocks(6, jnp.asarray(theta, jnp.float32), jnp.asarray(phi, jnp.float32))
    aligned = rotate_irreps(
        jnp.asarray(sh_real(6, jnp.asarray(d, jnp.float32)))[:, None], blocks,
        transpose=True,
    )[:, 0]
    zref = sh_real(6, jnp.asarray([0.0, 0.0, 1.0], jnp.float32))
    np.testing.assert_allclose(np.asarray(aligned), np.asarray(zref), atol=5e-5)


def test_dir_to_angles_roundtrip():
    rng = np.random.default_rng(0)
    v = rng.standard_normal((10, 3)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    theta, phi = dir_to_angles(jnp.asarray(v))
    rec = np.stack(
        [np.sin(theta) * np.cos(phi), np.sin(theta) * np.sin(phi), np.cos(theta)], 1
    )
    np.testing.assert_allclose(rec, v, atol=2e-3)
