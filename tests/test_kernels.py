"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dhd import dhd_step_edges
from repro.core.graph import build_csr, build_ell
from repro.kernels import ops, ref
from repro.kernels.dhd_spmv import dhd_ell_step, dhd_ell_step_batch
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.flash_attention import flash_attention

ATTN_SWEEP = [
    # b, hq, hkv, sq, skv, d, causal, window, dtype
    (2, 4, 2, 128, 128, 64, True, None, jnp.float32),
    (1, 8, 8, 256, 256, 32, False, None, jnp.float32),
    (1, 4, 1, 128, 512, 64, True, 64, jnp.float32),
    (2, 4, 2, 8, 256, 64, True, None, jnp.float32),
    (1, 2, 2, 64, 64, 128, True, None, jnp.bfloat16),
]


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window,dtype", ATTN_SWEEP)
def test_flash_attention_matches_ref(b, hq, hkv, sq, skv, d, causal, window, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_kv=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("n,kmax,block_n", [(256, 8, 64), (512, 16, 128), (128, 4, 32)])
def test_dhd_kernel_matches_edge_oracle(n, kmax, block_n):
    rng = np.random.default_rng(1)
    m = n * kmax // 4
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    a, b = np.minimum(src, dst)[keep], np.maximum(src, dst)[keep]
    _, i = np.unique(a.astype(np.int64) * n + b, return_index=True)
    a, b = a[i], b[i]
    w = (rng.random(len(a)) + 0.1).astype(np.float32)
    csr = build_csr(n, a, b, weights=w, symmetrize=True)
    ell = build_ell(csr, max_degree=int(csr.degree().max()))
    assert len(ell.tail_src) == 0
    heat = jnp.asarray(rng.random(n), jnp.float32)
    q = jnp.asarray(rng.random(n) * 0.1, jnp.float32)
    out = dhd_ell_step(heat, jnp.asarray(ell.cols), jnp.asarray(ell.vals), q,
                       block_n=block_n, interpret=True)
    want = dhd_step_edges(heat, jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32),
                          jnp.asarray(w), q, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5, rtol=1e-4)


def test_dhd_kernel_pads_arbitrary_n():
    """Non-block-multiple row counts take the kernel path via internal
    self-loop padding instead of crashing (satellite of the batched engine)."""
    rng = np.random.default_rng(5)
    n = 37  # not a multiple of any block size
    src, dst = rng.integers(0, n, 120), rng.integers(0, n, 120)
    keep = src != dst
    a, b = np.minimum(src, dst)[keep], np.maximum(src, dst)[keep]
    _, i = np.unique(a.astype(np.int64) * n + b, return_index=True)
    a, b = a[i], b[i]
    w = (rng.random(len(a)) + 0.1).astype(np.float32)
    csr = build_csr(n, a, b, weights=w, symmetrize=True)
    ell = build_ell(csr, max_degree=int(csr.degree().max()))
    heat = jnp.asarray(rng.random(n), jnp.float32)
    q = jnp.asarray(rng.random(n) * 0.1, jnp.float32)
    out = dhd_ell_step(heat, jnp.asarray(ell.cols), jnp.asarray(ell.vals), q,
                       block_n=16, interpret=True)
    want = ref.dhd_ell_ref(heat, jnp.asarray(ell.cols), jnp.asarray(ell.vals), q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("n,kmax,block_n,B,batched_vals", [
    (64, 8, 32, 4, False),
    (57, 6, 16, 3, True),   # padding path + per-batch weights
    (128, 4, 64, 2, True),
])
def test_dhd_kernel_batch_matches_ref(n, kmax, block_n, B, batched_vals):
    rng = np.random.default_rng(6)
    m = n * kmax // 4
    src, dst = rng.integers(0, n, m), rng.integers(0, n, m)
    keep = src != dst
    a, b = np.minimum(src, dst)[keep], np.maximum(src, dst)[keep]
    _, i = np.unique(a.astype(np.int64) * n + b, return_index=True)
    a, b = a[i], b[i]
    w = (rng.random(len(a)) + 0.1).astype(np.float32)
    csr = build_csr(n, a, b, weights=w, symmetrize=True)
    ell = build_ell(csr, max_degree=int(csr.degree().max()))
    heat = jnp.asarray(rng.random((B, n)), jnp.float32)
    q = jnp.asarray(rng.random((B, n)) * 0.1, jnp.float32)
    if batched_vals:
        vals = np.repeat(ell.vals[None], B, axis=0)
        vals *= (rng.random(vals.shape) > 0.2)  # drop edges per batch element
        vals = jnp.asarray(vals)
    else:
        vals = jnp.asarray(ell.vals)
    cols = jnp.asarray(ell.cols)
    out = dhd_ell_step_batch(heat, cols, vals, q, block_n=block_n, interpret=True)
    want = ref.dhd_ell_ref_batch(heat, cols, vals, q)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5, rtol=1e-4)
    # row b of the batch == the single-seed kernel on (heat[b], vals[b])
    for k in range(B):
        vk = vals[k] if batched_vals else vals
        single = dhd_ell_step(heat[k], cols, vk, q[k], block_n=block_n, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(single), atol=1e-5, rtol=1e-4
        )


def test_dhd_tail_edge_cache_reused():
    """Repeated dhd_step calls with the same adjacency arrays must hit the
    deduped-edge cache instead of rebuilding the edge list host-side.

    Hit/miss counts live in the metrics registry now (no module-global
    leaking across runs), so the test enables a throwaway registry."""
    from repro.obs import MetricsRegistry, set_default_registry

    rng = np.random.default_rng(8)
    n = 48
    a = rng.integers(0, n, 140)
    b = (a + 1 + rng.integers(0, n - 1, 140)) % n
    w = (rng.random(140) + 0.1).astype(np.float32)
    csr = build_csr(n, a, b, weights=w, symmetrize=True)
    ell = build_ell(csr, max_degree=2)  # forces a tail
    assert len(ell.tail_src) > 0
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    ts, td, tv = (jnp.asarray(ell.tail_src), jnp.asarray(ell.tail_dst),
                  jnp.asarray(ell.tail_val))
    heat = jnp.asarray(rng.random(n), jnp.float32)
    q = jnp.asarray(rng.random(n) * 0.1, jnp.float32)
    old = set_default_registry(MetricsRegistry(enabled=True))
    try:
        r1 = ops.dhd_step(heat, cols, vals, q, ts, td, tv)
        hits0 = ops.edge_cache_stats()["hits"]
        r2 = ops.dhd_step(heat, cols, vals, q, ts, td, tv)
        rb = ops.dhd_step_batch(heat[None], cols, vals, q[None], ts, td, tv)
        stats = ops.edge_cache_stats()
        assert stats["hits"] >= hits0 + 2
        assert 0.0 < stats["hit_rate"] <= 1.0
    finally:
        reg = set_default_registry(old)
    # registry reset clears the counts (the old module-global never did)
    reg.reset()
    assert set_default_registry(reg) is old  # install to read, then restore
    assert ops.edge_cache_stats() == {"hits": 0, "misses": 0, "hit_rate": 0.0}
    set_default_registry(old)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=0)
    np.testing.assert_allclose(np.asarray(rb[0]), np.asarray(r1), atol=1e-6)


def test_dhd_tail_path_exact(small_setup):
    rng = np.random.default_rng(3)
    n, m = 64, 300
    src, dst = rng.integers(0, n, m), rng.integers(0, n, m)
    keep = src != dst
    a, b = np.minimum(src, dst)[keep], np.maximum(src, dst)[keep]
    _, i = np.unique(a.astype(np.int64) * n + b, return_index=True)
    a, b = a[i], b[i]
    w = (rng.random(len(a)) + 0.1).astype(np.float32)
    csr = build_csr(n, a, b, weights=w, symmetrize=True)
    ell = build_ell(csr, max_degree=4)  # forces a big tail
    assert len(ell.tail_src) > 0
    heat = jnp.asarray(rng.random(n), jnp.float32)
    q = jnp.asarray(rng.random(n) * 0.1, jnp.float32)
    out = ops.dhd_step(heat, jnp.asarray(ell.cols), jnp.asarray(ell.vals), q,
                       jnp.asarray(ell.tail_src), jnp.asarray(ell.tail_dst),
                       jnp.asarray(ell.tail_val))
    want = dhd_step_edges(heat, jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32),
                          jnp.asarray(w), q, n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


BAG_SWEEP = [
    (2048, 32, 256, 20, "sum", jnp.float32),
    (4096, 64, 128, 8, "mean", jnp.float32),
    (1024, 16, 64, 5, "sum", jnp.float32),
    (512, 8, 32, 3, "sum", jnp.bfloat16),
]


@pytest.mark.parametrize("V,D,B,L,mode,dtype", BAG_SWEEP)
def test_embedding_bag_matches_ref(V, D, B, L, mode, dtype):
    rng = np.random.default_rng(2)
    tab = jnp.asarray(rng.standard_normal((V, D)), dtype)
    idx = jnp.asarray(rng.integers(0, V, (B, L)), jnp.int32)
    w = jnp.asarray(rng.random((B, L)), dtype)
    out = embedding_bag(tab, idx, w, mode=mode, block_b=32, block_v=256, interpret=True)
    want = ref.embedding_bag_ref(tab, idx, w, mode=mode)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_chunked_attention_matches_ref():
    from repro.models.attention import chunked_attention

    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((1, 4, 256, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 32)), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, chunk_kv=64, chunk_q=128)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=1e-4)


def test_kernel_attention_trainable():
    """The Pallas kernel path is differentiable (custom VJP, ref backward)."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    f_kern = lambda q_: ops.attention(
        q_, k, v, causal=True, use_kernel=True, block_q=64, block_kv=64
    ).sum()
    f_ref = lambda q_: ref.attention_ref(q_, k, v, causal=True).sum()
    g1 = jax.grad(f_kern)(q)
    g2 = jax.grad(f_ref)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)
