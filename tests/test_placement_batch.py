"""Differential tests for the batched multi-seed DHD placement engine.

Invariants under test:
  * ``diffuse_affinity_batch`` == per-seed ``diffuse_affinity`` row-for-row
    (shared weights, per-seed weights, and the batched-ELL kernel path);
  * ``CompetitionArena`` picks the same winner as the sequential
    ``_dhd_competition`` for every region of randomized pools;
  * ``overlap_centric_placement`` with ``dhd_batch`` on/off is replica-set
    identical end-to-end;
  * ``insert_patterns_incremental`` == full ``insert_patterns`` re-place on
    churn traces (replica sets AND routes), including after streaming
    mutations invalidate the placement journal;
  * batched heat-cache stepping == per-cache stepping;
  * the vectorized ``replication_gain`` == a straightforward reference.
"""
import numpy as np
import pytest

from repro.core import dhd
from repro.core.graph import build_csr
from repro.core.latency import make_paper_env
from repro.core.layered_graph import build_layered_graph
from repro.core.patterns import OverlapRegion, Pattern, Workload, generate_khop_patterns
from repro.core.placement import (
    CompetitionArena,
    HeatCache,
    PlacedUnit,
    PlacementConfig,
    _dhd_competition,
    replication_gain,
    step_heat_caches,
)
from repro.core.store import GeoGraphStore
from repro.data.synthetic import community_graph


def _random_edges(rng, n, m):
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    a = np.minimum(src, dst)[keep]
    b = np.maximum(src, dst)[keep]
    _, i = np.unique(a.astype(np.int64) * n + b, return_index=True)
    return a[i], b[i]


# ------------------------------------------------------- batched diffusion
def test_diffuse_batch_matches_single_rows():
    rng = np.random.default_rng(0)
    n, B = 50, 6
    a, b = _random_edges(rng, n, 200)
    w = (rng.random(len(a)) + 0.1).astype(np.float32)
    seeds = rng.random((B, n)).astype(np.float32)
    batch = dhd.diffuse_affinity_batch(n, a, b, w, seeds, n_steps=10)
    for k in range(B):
        single = dhd.diffuse_affinity(n, a, b, w, seeds[k], n_steps=10)
        np.testing.assert_allclose(batch[k], single, atol=1e-6, rtol=1e-5)


def test_diffuse_batch_per_seed_weights_equal_edge_removal():
    """Zero weight rows must behave exactly like removing the edge."""
    rng = np.random.default_rng(1)
    n, B = 40, 4
    a, b = _random_edges(rng, n, 160)
    w = (rng.random(len(a)) + 0.1).astype(np.float32)
    wb = np.tile(w, (B, 1))
    wb[rng.random((B, len(a))) < 0.4] = 0.0
    seeds = rng.random((B, n)).astype(np.float32)
    batch = dhd.diffuse_affinity_batch(n, a, b, wb, seeds, n_steps=10)
    for k in range(B):
        live = wb[k] > 0
        single = dhd.diffuse_affinity(n, a[live], b[live], wb[k][live], seeds[k], n_steps=10)
        np.testing.assert_allclose(batch[k], single, atol=1e-6, rtol=1e-5)


def test_diffuse_batch_kernel_path_matches_edge_path():
    rng = np.random.default_rng(2)
    n, B = 37, 3  # deliberately not a block multiple: exercises row padding
    a, b = _random_edges(rng, n, 120)
    wb = (rng.random((B, len(a))) + 0.05).astype(np.float32)
    wb[rng.random((B, len(a))) < 0.3] = 0.0
    seeds = rng.random((B, n)).astype(np.float32)
    edge = dhd.diffuse_affinity_batch(n, a, b, wb, seeds, n_steps=6, use_kernel=False)
    kern = dhd.diffuse_affinity_batch(n, a, b, wb, seeds, n_steps=6, use_kernel=True)
    np.testing.assert_allclose(kern, edge, atol=1e-5, rtol=1e-4)


def test_dhd_step_edges_weight_gate():
    """A zero-weight edge must not count toward |N_u^out| (absent edge)."""
    import jax.numpy as jnp

    heat = jnp.asarray([1.0, 0.0, 0.5])
    src = jnp.asarray([0, 0], jnp.int32)
    dst = jnp.asarray([1, 2], jnp.int32)
    q = jnp.zeros(3)
    with_dead = dhd.dhd_step_edges(
        heat, src, dst, jnp.asarray([1.0, 0.0]), q, 3, gamma=0.0, beta=0.0
    )
    only_live = dhd.dhd_step_edges(
        heat, jnp.asarray([0], jnp.int32), jnp.asarray([1], jnp.int32),
        jnp.asarray([1.0]), q, 3, gamma=0.0, beta=0.0,
    )
    np.testing.assert_allclose(np.asarray(with_dead), np.asarray(only_live), atol=1e-7)


# ----------------------------------------------------- arena vs sequential
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_arena_matches_sequential_competition(seed):
    rng = np.random.default_rng(seed)
    n_regions = int(rng.integers(6, 14))
    n_cand = int(rng.integers(2, 6))
    g = community_graph(320, n_communities=8, p_in=0.04, p_out=0.004,
                        seed=seed, n_dcs=5)
    verts = rng.permutation(g.n_nodes)
    groups = np.array_split(verts[:160], n_regions)
    regions = [
        OverlapRegion(rid=i, key=(i,), items=np.sort(grp.astype(np.int64)), degree=1)
        for i, grp in enumerate(groups)
    ]
    cand = []
    for c in range(n_cand):
        # some candidates hold nothing (exercises the -1 validity path)
        if rng.random() < 0.2:
            held = []
        else:
            held = [np.sort(rng.choice(verts[160:], size=30, replace=False).astype(np.int64))]
        cand.append((c, np.asarray([c % 5]), held))
    unit_r = rng.random(5) + 0.05
    params = dhd.DHDParams()
    arena = CompetitionArena(regions, g, cand, params, n_steps=16)
    req = list(range(n_cand))
    for r in regions:
        want = _dhd_competition(r, cand, regions, g, params, 16, unit_r)
        got = arena.winner(r.rid, req, unit_r)
        assert got == want, f"region {r.rid}: arena={got} sequential={want}"


def test_placement_batch_flag_is_replica_identical(small_setup):
    g, env, csr, wl, pats = small_setup
    from repro.core.placement import overlap_centric_placement

    lg = build_layered_graph(g, env)
    seq, _ = overlap_centric_placement(
        lg, wl, PlacementConfig(precache=False, dhd_steps=8, dhd_batch=False)
    )
    bat, _ = overlap_centric_placement(
        lg, wl, PlacementConfig(precache=False, dhd_steps=8, dhd_batch=True)
    )
    assert np.array_equal(seq.delta, bat.delta)
    assert np.array_equal(seq.route, bat.route)


# ------------------------------------------------------ incremental insert
def _mk_store(seed=0, n_v=700, n_p=60):
    g = community_graph(n_v, n_communities=10, p_in=0.02, p_out=0.001,
                        seed=seed, n_dcs=5)
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, n_p, seed=seed + 1, n_dcs=env.n_dcs,
                                  n_hot_sources=32)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    return GeoGraphStore(
        g, env, wl, config=PlacementConfig(precache=False, dhd_steps=8)
    ), csr


def _new_patterns(g, csr, env, n, seed):
    fresh = generate_khop_patterns(g, csr, n, seed=seed, n_dcs=env.n_dcs,
                                   n_hot_sources=32)
    return [
        Pattern(10_000 + seed * 100 + i, p.items, p.r_py, p.w_py, p.eta)
        for i, p in enumerate(fresh)
    ]


def test_incremental_insert_matches_full_replace():
    full, csr = _mk_store()
    inc, _ = _mk_store()
    for rnd in range(3):
        new = _new_patterns(full.g, csr, full.env, 3, seed=rnd)
        state_obj = inc.state
        full.insert_patterns(new)
        rep = inc.insert_patterns_incremental(new)
        assert inc.state is state_obj  # patched in place, aliases intact
        assert np.array_equal(full.state.delta, inc.state.delta)
        assert np.array_equal(full.state.route, inc.state.route)
        assert rep["journal_hits"] > 0  # untouched pools replayed, not recomputed
        assert inc.route_index.verify(inc.state.delta)


def test_incremental_insert_after_streaming_churn():
    """Mutations shift ids and kill the journal; the next incremental insert
    must still be identical to a full re-place on the mutated store."""
    from repro.streaming import DeltaGraph, random_churn_batch

    full, _ = _mk_store(seed=5)
    inc, _ = _mk_store(seed=5)
    for store, s in ((full, 11), (inc, 11)):
        store._delta_graph = DeltaGraph(store.g)
        store.apply_updates(random_churn_batch(store._delta_graph, 0.02,
                                               np.random.default_rng(s)))
    assert np.array_equal(full.state.delta, inc.state.delta)
    csr = build_csr(full.g.n_nodes, full.g.src, full.g.dst, symmetrize=True)
    new = _new_patterns(full.g, csr, full.env, 3, seed=77)
    full.insert_patterns(new)
    inc.insert_patterns_incremental(new)
    assert np.array_equal(full.state.delta, inc.state.delta)
    assert np.array_equal(full.state.route, inc.state.route)


def test_journal_survives_compaction():
    """Fingerprint-stable ids: journal keys digest per-item uids, so a
    ``_compact_in_place`` renumbering remaps the memo values instead of
    voiding them — the next incremental insert still replays untouched
    pools AND stays identical to a full re-place on the compacted store."""
    from repro.streaming import DeltaGraph, random_churn_batch

    inc, _ = _mk_store(seed=9)
    full, _ = _mk_store(seed=9)
    for store in (inc, full):
        store._delta_graph = DeltaGraph(store.g)
        store.apply_updates(
            random_churn_batch(store._delta_graph, 0.05, np.random.default_rng(3))
        )
    assert inc.tombstone_ratio() > 0  # there is something to compact
    # repopulate the journal post-mutation (the topology change reset it)
    csr = build_csr(inc.g.n_nodes, inc.g.src, inc.g.dst, symmetrize=True)
    new1 = _new_patterns(inc.g, csr, inc.env, 3, seed=21)
    inc.insert_patterns_incremental(new1)
    full.insert_patterns(new1)
    journal = inc._placement_journal
    assert len(journal.regions) > 0
    assert inc.compact() and full.compact()
    assert inc._placement_journal is journal  # survived, not discarded
    assert len(journal.regions) > 0
    # remapped region rows live in the compacted id space
    for regions in journal.regions.values():
        for r in regions:
            assert len(r.items) == 0 or r.items.max() < inc.g.n_items
    csr2 = build_csr(inc.g.n_nodes, inc.g.src, inc.g.dst, symmetrize=True)
    new2 = _new_patterns(inc.g, csr2, inc.env, 3, seed=22)
    rep = inc.insert_patterns_incremental(new2)
    full.insert_patterns(new2)
    assert rep["journal_hits"] > 0  # compaction did not void the memos
    assert np.array_equal(full.state.delta, inc.state.delta)
    assert np.array_equal(full.state.route, inc.state.route)
    assert inc.route_index.verify(inc.state.delta)


def test_incremental_insert_baseline_fallback():
    g = community_graph(200, n_communities=4, seed=0, n_dcs=5)
    env = make_paper_env()
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    pats = generate_khop_patterns(g, csr, 10, seed=1, n_dcs=env.n_dcs)
    wl = Workload.from_patterns(pats, g.n_items, env.n_dcs)
    store = GeoGraphStore(g, env, wl, placement="random", routing="random",
                          config=PlacementConfig(precache=False))
    rep = store.insert_patterns_incremental(_new_patterns(g, csr, env, 2, seed=3))
    assert rep.get("fallback") == "full"
    assert len(store.workload.patterns) == 12
    # geolayer + non-stepwise routing must also re-place fully: patching
    # nearest-replica rows into a greedy table would mix routing policies
    greedy = GeoGraphStore(g, env, wl, placement="geolayer", routing="greedy",
                           config=PlacementConfig(precache=False))
    rep = greedy.insert_patterns_incremental(_new_patterns(g, csr, env, 2, seed=4))
    assert rep.get("fallback") == "full"


# --------------------------------------------------------- batched caches
def test_step_heat_caches_matches_individual(small_setup, small_store):
    """Oracle is the pre-batching per-cache body (direct diffuse_affinity
    over the cache topology + edge-row decay), NOT the shared batched code."""
    g, env, csr, wl, pats = small_setup
    rng = np.random.default_rng(3)
    caches = [HeatCache(g, d, small_store.state) for d in range(3)]
    want = []
    for c in caches:
        c.observe(rng.integers(0, g.n_items, 50))
        n = g.n_nodes
        ref_heat = c.heat.copy()
        ref_heat[:n] = dhd.diffuse_affinity(
            n, g.src, g.dst, np.ones(g.n_edges, dtype=np.float32),
            c.heat[:n], params=c.params, n_steps=4,
        )
        ref_heat[n:] *= (1.0 - c.params.gamma) ** 4
        want.append(ref_heat)
    step_heat_caches(caches, n_steps=4)
    for c, ref_heat in zip(caches, want):
        np.testing.assert_allclose(c.heat, ref_heat, atol=1e-6, rtol=1e-5)


# ------------------------------------------------- vectorized gain oracle
def _gain_reference(unit, holder_dcs, children_dcs, sizes, env, lambda1, primary):
    """The pre-vectorization formula, kept verbatim as the oracle."""
    items = unit.items
    size_sum = float(sizes[items].sum())
    n_items = len(items)
    holder_set = set(int(d) for d in holder_dcs)
    gain = 0.0
    for child in children_dcs:
        child_list = [int(d) for d in child]
        r_c = float(unit.r_py[child].sum())
        if r_c <= 0:
            continue
        if primary is not None:
            remote = ~np.isin(primary[items], child)
            size_remote = float(sizes[items[remote]].sum())
        else:
            size_remote = size_sum
        w_total = float(unit.w_py.sum())
        outside = [d for d in sorted(holder_set) if d not in child_list] or sorted(holder_set)
        net_mean = float(np.mean([[env.c_net[o, c] for o in outside] for c in child_list]))
        store_mean = float(np.mean([env.c_store[c] for c in child_list]))
        put_mean = float(np.mean([env.c_write[c] for c in child_list]))
        read_save = r_c * size_remote * net_mean
        assoc_save = lambda1 * r_c * n_items * 1e-6
        store_add = size_sum * store_mean
        write_add = w_total * (put_mean * n_items + size_remote * net_mean)
        gain += read_save + assoc_save - store_add - write_add
    return gain


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_replication_gain_vectorized_matches_reference(seed, paper_env):
    env = paper_env
    rng = np.random.default_rng(seed)
    n_items_total = 120
    sizes = (rng.random(n_items_total) * 40 + 1).astype(np.float32)
    primary = rng.integers(0, env.n_dcs, n_items_total)
    for _ in range(10):
        items = np.unique(rng.integers(0, n_items_total, 25))
        unit = PlacedUnit(
            items=items,
            r_py=rng.random(env.n_dcs) * rng.integers(0, 30, env.n_dcs),
            w_py=rng.random(env.n_dcs) * (rng.random(env.n_dcs) < 0.4),
            eta=1.0, key=(0,),
        )
        holder = np.unique(rng.integers(0, env.n_dcs, 3))
        children = [
            np.unique(rng.integers(0, env.n_dcs, rng.integers(1, 3)))
            for _ in range(rng.integers(1, 4))
        ]
        want = _gain_reference(unit, holder, children, sizes, env, 0.5, primary)
        got = replication_gain(unit, holder, children, sizes, env, 0.5, primary)
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)
