import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import (
    LMConfig,
    decode,
    forward,
    init_params,
    prefill,
    train_loss,
)

VARIANTS = {
    "dense": LMConfig(name="d", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab_size=256, remat=False),
    "qk_norm": LMConfig(name="q", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab_size=256, qk_norm=True, remat=False),
    "local_global": LMConfig(name="g", n_layers=6, d_model=64, n_heads=4,
                             n_kv_heads=2, d_ff=128, vocab_size=256,
                             sliding_window=8, local_global_ratio=5, remat=False),
    "mla": LMConfig(name="m", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                    d_ff=128, vocab_size=256, mla=True, kv_lora_rank=32,
                    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, remat=False),
    "moe": LMConfig(name="e", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    d_ff=0, vocab_size=256, moe=True, n_experts=8,
                    n_shared_experts=1, top_k=2, d_ff_expert=32, remat=False),
}


@pytest.mark.parametrize("name", list(VARIANTS))
def test_initial_loss_near_uniform(name):
    cfg = VARIANTS[name]
    params = init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    loss, aux = train_loss(params, {"tokens": tok, "labels": tok}, cfg)
    assert abs(float(aux["ce"]) - np.log(cfg.vocab_size)) < 0.6


@pytest.mark.parametrize("name", ["dense", "mla", "local_global"])
def test_decode_matches_forward(name):
    """Prefill + step-by-step decode reproduces the full-forward logits."""
    cfg = VARIANTS[name]
    params = init_params(jax.random.PRNGKey(0), cfg)
    s_total, s_pre = 12, 8
    tok = jax.random.randint(jax.random.PRNGKey(2), (2, s_total), 0, cfg.vocab_size)
    full_logits, _, _ = forward(params, tok, cfg)
    last, caches = prefill(params, tok[:, :s_pre], cfg)
    np.testing.assert_allclose(
        np.asarray(last, np.float32),
        np.asarray(full_logits[:, s_pre - 1], np.float32),
        atol=2e-2, rtol=2e-2,
    )
    # pad caches to s_total and decode the remaining tokens
    def pad(v):
        widths = [(0, 0)] * v.ndim
        widths[-2] = (0, s_total - s_pre)
        return jnp.pad(v, widths)
    caches = jax.tree_util.tree_map(pad, caches)
    for t in range(s_pre, s_total):
        pos = jnp.full((2,), t, jnp.int32)
        logits, caches = decode(params, tok[:, t], caches, pos, cfg)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, t], np.float32),
            atol=2e-2, rtol=2e-2,
        )


def test_scan_equals_unrolled():
    cfg = VARIANTS["dense"]
    cfg_u = cfg.__class__(**{**cfg.__dict__, "scan_layers": False})
    params = init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    l1, _, _ = forward(params, tok, cfg)
    l2, _, _ = forward(params, tok, cfg_u)
    np.testing.assert_allclose(np.asarray(l1, np.float32), np.asarray(l2, np.float32),
                               atol=1e-2, rtol=1e-2)


def test_moe_load_stats():
    from repro.models.moe import moe_forward, moe_init

    key = jax.random.PRNGKey(0)
    p = moe_init(key, 64, 32, 8, 1)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.bfloat16)
    out, aux = moe_forward(p, x, top_k=2)
    assert out.shape == x.shape
    np.testing.assert_allclose(float(aux["expert_load"].sum()), 1.0, rtol=1e-5)
    assert float(aux["aux_loss"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz


def test_param_count_analytic():
    cfg = VARIANTS["dense"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    true = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    est = cfg.param_count()
    assert abs(true - est) / true < 0.01
