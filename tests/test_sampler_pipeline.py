import numpy as np

from repro.core.graph import build_csr
from repro.data.pipeline import Prefetcher, RecsysPipeline, TokenPipeline, shard_batch
from repro.data.sampler import NeighborSampler, block_capacity
from repro.data.synthetic import make_benchmark_graph
from repro.data.partition import balanced_bfs_partition, edge_cut, hash_partition


def test_sampler_block_valid():
    g = make_benchmark_graph("wiki", n_dcs=4)
    csr = build_csr(g.n_nodes, g.src, g.dst, symmetrize=True)
    s = NeighborSampler(csr, [3, 2], seed=0)
    seeds = np.arange(8)
    blk = s.sample(seeds)
    n_max, e_max = block_capacity(8, [3, 2])
    assert blk.node_ids.shape == (n_max,)
    assert blk.edge_src.shape == (e_max,)
    # every real edge's endpoints are valid positions
    es, ed = blk.edge_src[blk.edge_mask], blk.edge_dst[blk.edge_mask]
    assert (blk.node_mask[es]).all() and (blk.node_mask[ed]).all()
    # message edges point toward the requesting frontier node
    real_nodes = blk.node_ids[blk.node_mask]
    assert len(np.unique(real_nodes)) == len(real_nodes)  # dedup


def test_pipeline_deterministic():
    p = TokenPipeline(1000, 4, 8, seed=3)
    a, b = p.batch_at(5), p.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_prefetcher_order():
    p = TokenPipeline(100, 2, 4)
    pf = Prefetcher(p, start_step=10)
    s0, b0 = pf.next()
    s1, b1 = pf.next()
    pf.stop()
    assert (s0, s1) == (10, 11)
    np.testing.assert_array_equal(b0["tokens"], p.batch_at(10)["tokens"])


def test_shard_batch():
    p = TokenPipeline(100, 8, 4)
    b = p.batch_at(0)
    s0 = shard_batch(b, 0, 4)
    s3 = shard_batch(b, 3, 4)
    assert s0["tokens"].shape == (2, 4)
    np.testing.assert_array_equal(s3["tokens"], b["tokens"][6:8])


def test_bfs_partition_cut_better_than_hash():
    g = make_benchmark_graph("snb", n_dcs=4)
    hp = hash_partition(g.n_nodes, 4)
    bp = balanced_bfs_partition(g.n_nodes, g.src, g.dst, 4)
    assert edge_cut(bp, g.src, g.dst) < edge_cut(hp, g.src, g.dst)
    # balanced within 25%
    counts = np.bincount(bp)
    assert counts.max() <= 1.3 * counts.min()


def test_recsys_pipeline():
    p = RecsysPipeline(1000, 50, 8, 10)
    b = p.batch_at(0)
    assert b["hist_items"].shape == (8, 10)
    assert (b["hist_items"] < 1000).all()
