"""Launcher-module tests: mesh construction errors, roofline math, dry-run
artifact schema (consumes the checked-in results when present)."""
import glob
import json
import os

import pytest

from repro.launch import roofline
from repro.launch.roofline import _parse_collectives


def test_mesh_requires_devices():
    from repro.launch.mesh import make_production_mesh

    with pytest.raises(RuntimeError):
        make_production_mesh()  # 1 CPU device < 256


def test_collective_parser():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar = f32[128]{0} all-reduce(f32[128]{0} %y), replica_groups=[2,16]<=[32], to_apply=%add
    """
    out = _parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    ag_bytes = 16 * 1024 * 2
    assert out["all-gather"]["tensor_bytes"] == ag_bytes
    assert out["all-gather"]["wire_bytes"] == pytest.approx(ag_bytes * 15 / 16)
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["wire_bytes"] == pytest.approx(2 * 128 * 4 * 15 / 16)


def test_model_flops_positive():
    for arch, shape, fam in [
        ("yi-6b", "train_4k", "lm"),
        ("deepseek-v2-lite-16b", "prefill_32k", "lm"),
        ("gemma3-27b", "decode_32k", "lm"),
        ("egnn", "molecule", "gnn"),
        ("equiformer-v2", "ogb_products", "gnn"),
        ("bst", "retrieval_cand", "recsys"),
    ]:
        mf = roofline.model_flops(arch, shape, fam)
        assert mf is not None and mf > 0


def test_moe_active_flops_below_total():
    from repro.configs import get_arch

    cfg = get_arch("deepseek-v2-lite-16b").cfg
    assert cfg.active_param_count() < cfg.param_count()


@pytest.mark.skipif(
    not glob.glob(os.path.join(roofline.RESULTS_DIR, "dryrun_single_*.json")),
    reason="dry-run artifacts not present",
)
def test_dryrun_artifacts_complete():
    """All 40 cells present per mesh; live cells carry the analysis fields."""
    for mesh in ("single", "multi"):
        files = glob.glob(
            os.path.join(roofline.RESULTS_DIR, f"dryrun_{mesh}_*.json")
        )
        if not files:
            continue
        assert len(files) == 40
        n_skip = 0
        for f in files:
            with open(f) as fh:
                r = json.load(fh)
            if r.get("skipped"):
                n_skip += 1
                continue
            assert r.get("ok"), (f, r.get("error"))
            assert r["production"]["flops_per_device"] >= 0
            assert "collectives" in r["production"]
        assert n_skip == 4  # long_500k on the 4 full-attention LMs


@pytest.mark.skipif(
    not os.path.exists(os.path.join(roofline.RESULTS_DIR, "roofline.json")),
    reason="roofline not generated",
)
def test_roofline_rows():
    rows = roofline.load_all("single")
    live = [r for r in rows if not r.get("skipped")]
    assert len(live) == 36
    for r in live:
        assert r["compute_s"] >= 0 and r["memory_s"] >= 0 and r["collective_s"] >= 0
        assert r["dominant"] in ("compute", "memory", "collective")
        assert 0 <= r["roofline_fraction"] <= 1.0 + 1e-9
