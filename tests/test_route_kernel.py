"""Fused route-expansion kernel: Pallas/subset impls vs the jnp oracle vs
``route_online``.

Correctness bar (the fast-path acceptance): every impl produces the scalar
router's exact greedy picks — same coverage argmax, same lowest-DC-id
tie-break, same layer escalation — and the integrated fast path is
bit-identical to the numpy batch path (shared exact f64 epilogue).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
    from _hypothesis_stub import given, settings, st

from repro.core.routing import (
    RouteFastConfig,
    get_route_fast_config,
    route_online,
    route_online_batch,
    set_route_fast_config,
)
from repro.kernels import ops, ref
from repro.kernels.autotune import Autotuner, set_autotuner
from repro.kernels.route_expand import route_expand


def _rand_problem(
    rng,
    R,
    k_lo,
    k_hi,
    D,
    L,
    p_rep=0.35,
    all_ties=False,
    single_origin=False,
    empty_layers=False,
):
    """Random packed batch + layer hierarchy for kernel-level differentials."""
    lens = rng.integers(k_lo, k_hi + 1, R)
    K = int(lens.max())
    bits = np.zeros((R, K), np.int32)
    sizes = np.zeros((R, K), np.float32)
    pow2 = 1 << np.arange(D)
    for r in range(R):
        k = int(lens[r])
        rep = (
            np.ones((k, D), bool)
            if all_ties
            else rng.random((k, D)) < p_rep
        )
        bits[r, :k] = (rep * pow2).sum(axis=1)
        sizes[r, :k] = (rng.random(k) + 0.25).astype(np.float32)
    origin = (
        np.zeros(R, np.int64) if single_origin else rng.integers(0, D, R)
    )
    # comp hierarchy: identity at layer 0, then random monotone coarsenings;
    # with empty_layers the first expansion layer stays identity, so every
    # origin cluster is a singleton and the greedy must escalate through it
    comp = np.zeros((L + 1, D), np.int64)
    comp[0] = np.arange(D)
    prev = np.arange(D)
    for layer in range(1, L + 1):
        if empty_layers and layer == 1:
            comp[layer] = prev
            continue
        groups = max(1, D // (layer + 1))
        prev = rng.integers(0, groups, int(prev.max()) + 1)[prev]
        comp[layer] = prev
    rtt = rng.random((D, D)).astype(np.float32) * 0.2
    rtt = rtt + rtt.T
    np.fill_diagonal(rtt, 0.0)
    ibw = (1.0 / (rng.random((D, D)) * 1e9 + 1e8)).astype(np.float32)
    np.fill_diagonal(ibw, 0.0)
    return bits, sizes, lens.astype(np.int32), origin.astype(np.int32), comp, rtt, ibw


def _assert_outputs_match(got, want, lens):
    served_g, bytes_g, layers_g, miss_g, strag_g, wan_g = got
    served_w, bytes_w, layers_w, miss_w, strag_w, wan_w = want
    for r, k in enumerate(lens):
        np.testing.assert_array_equal(served_g[r, :k], served_w[r, :k])
    np.testing.assert_array_equal(np.asarray(layers_g), np.asarray(layers_w))
    np.testing.assert_array_equal(np.asarray(miss_g), np.asarray(miss_w))
    np.testing.assert_allclose(bytes_g, bytes_w, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(strag_g, strag_w, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(wan_g, wan_w, rtol=1e-5, atol=1e-4)


SWEEP = [
    # R, k_lo, k_hi, D, L, p_rep, all_ties, single_origin, empty_layers
    (8, 1, 24, 5, 3, 0.35, False, False, False),
    (16, 2, 40, 4, 1, 0.5, False, False, False),
    (8, 1, 16, 8, 5, 0.2, False, False, False),
    (8, 4, 20, 5, 3, 0.0, True, False, False),  # all ties -> lowest DC id
    (8, 1, 24, 5, 3, 0.35, False, True, False),  # single-origin batch
    (8, 1, 24, 6, 4, 0.3, False, False, True),  # empty first layer
    (4, 1, 8, 5, 2, 0.05, False, False, False),  # mostly-unresolvable items
]


@pytest.mark.parametrize(
    "R,k_lo,k_hi,D,L,p_rep,ties,single,empty", SWEEP
)
def test_kernel_matches_oracle(R, k_lo, k_hi, D, L, p_rep, ties, single, empty):
    rng = np.random.default_rng(R * 1000 + D * 10 + L)
    prob = _rand_problem(
        rng, R, k_lo, k_hi, D, L, p_rep,
        all_ties=ties, single_origin=single, empty_layers=empty,
    )
    lens = prob[2]
    want = ops.route_expand_batch(*prob, use_kernel=False)
    got = tuple(
        np.asarray(o)
        for o in route_expand(*prob, block_r=8, interpret=True)
    )
    _assert_outputs_match(got, want, lens)


@pytest.mark.parametrize(
    "R,k_lo,k_hi,D,L,p_rep,ties,single,empty", SWEEP
)
def test_subsets_matches_oracle(R, k_lo, k_hi, D, L, p_rep, ties, single, empty):
    rng = np.random.default_rng(R * 7 + D * 31 + L)
    bits, sizes, lens, origin, comp, rtt, ibw = _rand_problem(
        rng, R, k_lo, k_hi, D, L, p_rep,
        all_ties=ties, single_origin=single, empty_layers=empty,
    )
    served_w, _, layers_w, miss_w, _, _ = ops.route_expand_batch(
        bits, sizes, lens, origin, comp, rtt, ibw, use_kernel=False
    )
    # flatten the padded tile into the subset router's stream signature
    req_id = np.repeat(np.arange(len(lens)), lens)
    bits_flat = np.concatenate(
        [bits[r, : lens[r]] for r in range(len(lens))]
    ).astype(np.int64)
    served, layers, miss = ops.route_expand_subsets(
        bits_flat, req_id, len(lens), origin.astype(np.int64), comp
    )
    np.testing.assert_array_equal(layers, np.asarray(layers_w))
    np.testing.assert_array_equal(miss, np.asarray(miss_w))
    lo = 0
    for r, k in enumerate(lens):
        np.testing.assert_array_equal(served[lo : lo + k], served_w[r, :k])
        lo += k


def test_field_word_boundary_consistency():
    """K just below / above the 10-bit field-word gate (512 padded slots)
    must give identical picks: the packed coverage path vs the 1-bit
    fallback is an internal detail, never a behaviour change."""
    rng = np.random.default_rng(99)
    for k_hi in (500, 600):  # pads to 512 (field path) / 1024 (fallback)
        bits, sizes, lens, origin, comp, rtt, ibw = _rand_problem(
            rng, 4, k_hi - 4, k_hi, 5, 3
        )
        want = ops.route_expand_batch(
            bits, sizes, lens, origin, comp, rtt, ibw, use_kernel=False
        )
        req_id = np.repeat(np.arange(4), lens)
        bits_flat = np.concatenate(
            [bits[r, : lens[r]] for r in range(4)]
        ).astype(np.int64)
        served, layers, miss = ops.route_expand_subsets(
            bits_flat, req_id, 4, origin.astype(np.int64), comp
        )
        lo = 0
        for r, k in enumerate(lens):
            np.testing.assert_array_equal(served[lo : lo + k], want[0][r, :k])
            lo += k
        np.testing.assert_array_equal(layers, np.asarray(want[2]))


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    R=st.integers(1, 12),
    D=st.integers(2, 8),
    L=st.integers(1, 4),
    p=st.floats(0.0, 1.0),
)
def test_subsets_vs_oracle_property(seed, R, D, L, p):
    rng = np.random.default_rng(seed)
    bits, sizes, lens, origin, comp, rtt, ibw = _rand_problem(
        rng, R, 1, 20, D, L, p_rep=p
    )
    served_w, _, layers_w, miss_w, _, _ = ops.route_expand_batch(
        bits, sizes, lens, origin, comp, rtt, ibw, use_kernel=False
    )
    req_id = np.repeat(np.arange(R), lens)
    bits_flat = np.concatenate(
        [bits[r, : lens[r]] for r in range(R)]
    ).astype(np.int64)
    served, layers, miss = ops.route_expand_subsets(
        bits_flat, req_id, R, origin.astype(np.int64), comp
    )
    np.testing.assert_array_equal(layers, np.asarray(layers_w))
    np.testing.assert_array_equal(miss, np.asarray(miss_w))
    lo = 0
    for r, k in enumerate(lens):
        np.testing.assert_array_equal(served[lo : lo + k], served_w[r, :k])
        lo += k


# --------------------------------------------------- integrated fast path
@pytest.fixture
def force_fast():
    """Drop every size gate so the fast path takes any batch; restore after."""
    old = get_route_fast_config()
    set_route_fast_config(RouteFastConfig(min_requests=2))
    yield
    set_route_fast_config(old)


def _store_requests(pats, n_dcs, n=30):
    reqs = []
    for i, p in enumerate(pats):
        if len(reqs) >= n:
            break
        if len(p.items):
            reqs.append((p.items, i % n_dcs))
    return reqs


def test_fast_path_matches_route_online(small_store, force_fast):
    store = small_store
    reqs = _store_requests(
        store.workload.patterns, store.lg.env.n_dcs
    )
    batch = route_online_batch(store.lg, store.state, reqs, fast=True)
    for (items, origin), b in zip(reqs, batch):
        s = route_online(store.lg, store.state, items, origin)
        np.testing.assert_array_equal(s.served_by, b.served_by)
        assert s.layers_used == b.layers_used
        assert s.n_missing == b.n_missing
        assert s.latency_s == pytest.approx(b.latency_s, rel=1e-6)


def test_fast_path_bit_identical_to_numpy_batch(small_store, force_fast):
    store = small_store
    reqs = _store_requests(
        store.workload.patterns, store.lg.env.n_dcs
    )
    a = route_online_batch(store.lg, store.state, reqs, fast=False)
    b = route_online_batch(store.lg, store.state, reqs, fast=True)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.served_by, y.served_by)
        # exact float equality: both paths share the f64 host epilogue
        assert x.latency_s == y.latency_s
        assert x.per_dc_latency == y.per_dc_latency
        assert x.wan_bytes == y.wan_bytes
        assert x.layers_used == y.layers_used and x.n_missing == y.n_missing


def test_fast_path_tile_impl_via_autotuner(small_store, force_fast):
    """A winner table pinning the tile oracle must route identically: the
    autotuner only ever changes *which* impl runs, never the picks."""
    old = set_autotuner(Autotuner())
    try:
        reqs = _store_requests(
            small_store.workload.patterns, small_store.lg.env.n_dcs, n=12
        )
        base = route_online_batch(
            small_store.lg, small_store.state, reqs, fast=False
        )
        tuner = set_autotuner(Autotuner())
        # pin impl=ref for every signature the batch can bucket to
        from repro.kernels.autotune import shape_bucket, signature_key

        lens = [len(it) for it, _ in reqs]
        sig = (
            shape_bucket(len(reqs)),
            shape_bucket(max(lens)),
            small_store.lg.env.n_dcs,
            small_store.lg.n_layers,
        )
        tuner.load({
            "version": 1,
            "tables": {
                tuner.device_kind(): {
                    "route_expand": {
                        signature_key(sig): {"config": {"impl": "ref"}}
                    }
                }
            },
        })
        got = route_online_batch(
            small_store.lg, small_store.state, reqs, fast=True
        )
        for x, y in zip(base, got):
            np.testing.assert_array_equal(x.served_by, y.served_by)
            assert x.latency_s == y.latency_s
            assert x.per_dc_latency == y.per_dc_latency
    finally:
        set_autotuner(old)


@pytest.mark.parametrize("R", [2, 3, 17])
def test_fast_path_odd_batch_sizes(small_store, force_fast, R):
    store = small_store
    reqs = _store_requests(store.workload.patterns, store.lg.env.n_dcs, n=R)
    a = route_online_batch(store.lg, store.state, reqs, fast=False)
    b = route_online_batch(store.lg, store.state, reqs, fast=True)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.served_by, y.served_by)
        assert x.latency_s == y.latency_s


def test_fast_flag_false_never_dispatches(small_store, monkeypatch):
    """fast=False must not touch the kernels module at all."""
    import repro.core.routing as routing

    called = []
    monkeypatch.setattr(
        routing, "_route_batch_fast",
        lambda *a, **k: called.append(1),
    )
    reqs = _store_requests(small_store.workload.patterns, 4, n=8)
    route_online_batch(small_store.lg, small_store.state, reqs, fast=False)
    assert not called
